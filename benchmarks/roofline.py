"""Roofline-term extraction from the dry-run artifacts (§Roofline contract).

Per (arch x shape x mesh) cell, from runs/dryrun/<mesh>/<cell>.json:

  compute term    = FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory term     = bytes_accessed / (chips x 819e9 B/s HBM)
  collective term = wire_bytes / (chips x 50e9 B/s ICI link)

All three use PER-DEVICE quantities from the compiled artifact divided by
per-chip peaks (equivalent to the global/(chips x peak) form).

FLOPs source: XLA's cost analysis counts while-loop bodies ONCE, so any
cell whose graph still contains loops (scan_layers prefill cells, chunked
attention/GLA scans) under-reports.  We therefore also compute an ANALYTIC
per-device FLOPs (6*N*D for train, 2*N_active*D for decode/prefill, +
attention term 2*B*S^2*H*dh*(2 or 3)/dp) and report both; the roofline
terms use max(hlo, analytic) and the MODEL/HLO ratio flags the gap.
"""

from __future__ import annotations

import json
import math
import os

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e-class target)
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

SHAPES = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def analytic_flops_per_device(arch, shape_name: str, devices: int,
                              params: int) -> float:
    """Rough per-device FLOPs: 6ND train / 2ND decode-prefill + attention."""
    kind, seq, batch = SHAPES[shape_name]
    active = _active_params(arch)
    if kind == "train":
        tokens = seq * batch
        base = 6.0 * active * tokens
        att = _attention_flops(arch, seq, batch, causal=True) * 3.0  # fwd+bwd
    elif kind == "prefill":
        tokens = seq * batch
        base = 2.0 * active * tokens
        att = _attention_flops(arch, seq, batch, causal=True)
    else:  # decode: one token, full-cache attention
        tokens = batch
        base = 2.0 * active * tokens
        att = _attention_flops(arch, seq, batch, causal=False, decode=True)
    return (base + att) / devices


def _active_params(arch) -> int:
    """Per-token active parameters (MoE: top_k of num_experts)."""
    total = arch.param_count()
    moe_frac = 0.0
    for seg in tuple(arch.lm.prelude) + tuple(arch.lm.segments):
        if seg.block.moe is not None:
            m = seg.block.moe
            # expert params scale down by top_k/num_experts
            expert_params = m.num_experts * (
                m.d_model * m.d_ff * (3 if m.gated else 2)
            )
            layers = seg.count * (arch.lm.repeats if seg in arch.lm.segments else 1)
            moe_frac += expert_params * layers * (1.0 - m.top_k / m.num_experts)
    return int(total - moe_frac)


def _attention_flops(arch, seq, batch, causal=True, decode=False) -> float:
    fl = 0.0
    for seg in tuple(arch.lm.prelude) + tuple(arch.lm.segments):
        b = seg.block
        if b.kind != "attn":
            continue
        layers = seg.count * (arch.lm.repeats if seg in arch.lm.segments else 1)
        hd, hq = b.hd, b.heads
        eff = min(b.window, seq) if b.window else seq
        if decode:
            per_tok = 2 * 2 * hq * hd * eff          # qk + pv against cache
            fl += layers * batch * per_tok
        else:
            factor = 0.5 if causal else 1.0
            fl += layers * batch * 2 * 2 * hq * hd * seq * eff * factor
    return fl


def load_cells(out_dir: str, mesh: str):
    d = os.path.join(out_dir, mesh)
    cells = []
    if not os.path.isdir(d):
        return cells
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                cells.append(json.load(fh))
    return cells


def terms(rec: dict, arch=None) -> dict:
    dev = rec["devices"]
    hlo_flops = rec["cost"]["flops_per_device"]
    analytic = (
        analytic_flops_per_device(arch, rec["shape"], dev, rec.get("params", 0))
        if arch is not None
        else 0.0
    )
    flops = max(hlo_flops, analytic)
    compute = flops / PEAK_FLOPS
    memory = rec["cost"]["bytes_accessed_per_device"] / HBM_BW
    collective = rec["collective_wire_bytes_per_device"] / ICI_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda t: t[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "hlo_flops": hlo_flops,
        "analytic_flops": analytic,
        "model_hlo_ratio": (analytic / hlo_flops) if hlo_flops else float("inf"),
        "bound_s": max(compute, memory, collective),
        "useful_frac": compute / max(compute, memory, collective, 1e-30),
    }


def run(out_dir: str = "runs/dryrun", mesh: str = "single"):
    from benchmarks.common import Csv
    from repro.configs import get_arch

    csv = Csv(f"Roofline terms per (arch x shape), mesh={mesh} "
              f"[seconds per step; bottleneck = max term]")
    for rec in load_cells(out_dir, mesh):
        tag = f"{rec['arch']}/{rec['shape']}/{rec.get('backend','dense')}"
        if "skipped" in rec:
            csv.row(tag, None, f"SKIP({rec['skipped']})")
            continue
        if "error" in rec:
            csv.row(tag, None, f"ERROR({rec['error'][:60]})")
            continue
        t = terms(rec, get_arch(rec["arch"]))
        csv.row(
            tag, None,
            f"compute={t['compute_s']:.3e}s,memory={t['memory_s']:.3e}s,"
            f"collective={t['collective_s']:.3e}s,bound={t['dominant']},"
            f"compute_frac={t['useful_frac']:.2f},"
            f"mem/dev={rec['memory']['peak_estimate_per_device']/2**30:.1f}GiB",
        )


if __name__ == "__main__":
    import sys

    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "single")
