"""Roofline analysis: find- and update-path bytes models + dry-run terms.

Three surfaces share this module:

**Find-path roofline (the PR-6 fused-find contract).**  A find is
memory-bound: the fused kernel makes exactly one pass over each query's
candidate bucket rows and one value-row fetch, so its cost IS its bytes.
Per query, with S slots/bucket and P candidate buckets (buckets_per_key):

    metadata   = P * (S          # digest row, uint8
                      + 2 * 4*S  # key hi/lo planes, uint32
                      + 2 * 4*S) # score hi/lo planes (FindResult readout)
    value      = dim * 4         # ONE fused value-row slice, f32
    bytes/find = metadata + value

The HBM roofline ceiling is then `HBM_BW / bytes_per_find` KV/s, and the
achieved find rates from `BENCH_exp2.json` (when present in `bench_dir`)
are reported as distance-to-roofline fractions.  `run()` returns a `Csv`
so `benchmarks.run` emits it as `BENCH_roofline.json` in the
bench-trajectory/v1 schema — the CI perf trajectory carries the model
next to the measurements it bounds.

**Update-path roofline (the fused update_scan contract).**  A gradient
step is also memory-bound, but the row moves BOTH ways (read + optimizer
apply + write-back) and carries its optimizer state (`aux` columns).  Per
deduped query, with R = 4*(dim+aux) the f32 row bytes:

    metadata    = P * (S + 2 * 4*S)   # digest + key planes; scores untouched
    fused       = metadata + 2*R      # in-kernel RMW: one read, one write
    composed    = metadata + 4*R      # gather materializes compact rows to
                                      # HBM, the host apply reads them back,
                                      # scatter writes: 2x the row traffic

`update_bytes` is the model `exp9_train_apply` records its byte deltas
from; the savings fraction grows with dim+aux (config C rowwise_adagrad:
2x on the row plane).

**Dry-run step terms** (§Roofline contract, unchanged): per
(arch x shape x mesh) cell from runs/dryrun/<mesh>/<cell>.json,

  compute term    = FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory term     = bytes_accessed / (chips x 819e9 B/s HBM)
  collective term = wire_bytes / (chips x 50e9 B/s ICI link)

FLOPs source: XLA's cost analysis counts while-loop bodies ONCE, so any
cell whose graph still contains loops under-reports; an ANALYTIC
per-device FLOPs is computed alongside and the terms use max(hlo,
analytic).  `scripts/gen_roofline_md.py` renders these via
`load_cells`/`terms`.
"""

from __future__ import annotations

import json
import math
import os

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e-class target)
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

SLOTS = 128              # slots per bucket (core.table.SLOTS_PER_BUCKET)
CONFIGS = {"A": 8, "B": 32, "C": 64}   # exp2's paper configs (dim)


# =============================================================================
# Find-path bytes model
# =============================================================================


def find_bytes(dim: int, *, buckets_per_key: int = 1,
               slots: int = SLOTS) -> dict:
    """Bytes one fused find moves per query, split by plane."""
    digest = slots                      # uint8 row per candidate bucket
    keys = 2 * 4 * slots                # key hi/lo uint32 rows
    scores = 2 * 4 * slots              # score hi/lo uint32 rows
    metadata = buckets_per_key * (digest + keys + scores)
    value = 4 * dim                     # one f32 value-row slice
    return {
        "digest": buckets_per_key * digest,
        "keys": buckets_per_key * keys,
        "scores": buckets_per_key * scores,
        "value": value,
        "total": metadata + value,
    }


def find_ceiling_kv_s(dim: int, *, buckets_per_key: int = 1,
                      slots: int = SLOTS) -> float:
    """HBM roofline on finds/s: one fused pass is pure memory traffic."""
    return HBM_BW / find_bytes(dim, buckets_per_key=buckets_per_key,
                               slots=slots)["total"]


# =============================================================================
# Update-path bytes model (the fused update_scan contract)
# =============================================================================


def update_bytes(dim: int, aux: int, *, buckets_per_key: int = 1,
                 slots: int = SLOTS) -> dict:
    """Bytes one gradient-step update moves per deduped query, fused vs
    the composed locate+gather+apply+scatter it replaced (module
    docstring for the derivation; scores are untouched on this path)."""
    digest = slots                      # uint8 row per candidate bucket
    keys = 2 * 4 * slots                # key hi/lo uint32 rows
    metadata = buckets_per_key * (digest + keys)
    row = 4 * (dim + aux)               # f32 value row incl. optimizer aux
    return {
        "metadata": metadata,
        "row": row,
        "fused": metadata + 2 * row,        # in-kernel read + write-back
        "composed": metadata + 4 * row,     # extra compact-row round trip
    }


def update_ceiling_kv_s(dim: int, aux: int, *, buckets_per_key: int = 1,
                        slots: int = SLOTS) -> float:
    """HBM roofline on fused updates/s."""
    return HBM_BW / update_bytes(dim, aux, buckets_per_key=buckets_per_key,
                                 slots=slots)["fused"]


def load_exp2(bench_dir: str) -> list[dict]:
    """Achieved find rows from a prior `BENCH_exp2.json`, if any:
    [{name, dim, kv_per_s}] for rows named find/cfgX(dim=D)/lf=L."""
    import re

    path = os.path.join(bench_dir, "BENCH_exp2.json")
    if not os.path.isfile(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    out = []
    for row in doc.get("rows", []):
        m = re.match(r"find/cfg\w\(dim=(\d+)[^)]*\)/lf=([\d.]+)",
                     row.get("name", ""))
        if m and row.get("kv_per_s"):
            out.append({"name": row["name"], "dim": int(m.group(1)),
                        "kv_per_s": float(row["kv_per_s"])})
    return out


def run_find_roofline(csv=None, bench_dir: str = "runs/bench"):
    """Bytes-per-find + ceiling per config, and (when exp2 artifacts are
    present) each measured find rate's distance to its roofline."""
    from benchmarks.common import Csv

    csv = csv or Csv("Roofline: fused find/update bytes models + exp2 "
                     "distance [ceiling = HBM_BW / bytes-per-op]")
    for name, dim in CONFIGS.items():
        for p in (1, 2):
            b = find_bytes(dim, buckets_per_key=p)
            ceil = find_ceiling_kv_s(dim, buckets_per_key=p)
            csv.row(
                f"find-model/cfg{name}(dim={dim})/P={p}", None,
                f"bytes/find={b['total']}"
                f"(digest={b['digest']}+keys={b['keys']}"
                f"+scores={b['scores']}+value={b['value']}),"
                f"ceiling={ceil/1e6:.0f}M-KV/s@{HBM_BW/1e9:.0f}GB/s",
                kv_s=ceil,
            )
    # the update-path model: per config x optimizer-aux class, the fused
    # vs composed bytes and the row-plane saving the fused kernel banks
    for name, dim in CONFIGS.items():
        for opt_name, aux in (("sgd", 0), ("rowwise_adagrad", 1),
                              ("adagrad", dim)):
            b = update_bytes(dim, aux, buckets_per_key=2)
            ceil = update_ceiling_kv_s(dim, aux, buckets_per_key=2)
            saved = b["composed"] - b["fused"]
            csv.row(
                f"update-model/cfg{name}(dim={dim},{opt_name})/P=2", None,
                f"fused={b['fused']}B,composed={b['composed']}B"
                f"(meta={b['metadata']}+row={b['row']}x2|4),"
                f"saved={saved}B/update({100 * saved / b['composed']:.0f}%),"
                f"ceiling={ceil/1e6:.0f}M-KV/s@{HBM_BW/1e9:.0f}GB/s",
                kv_s=ceil,
            )
    achieved = load_exp2(bench_dir)
    if not achieved:
        csv.row("find-distance", None,
                f"no BENCH_exp2.json under {bench_dir}: run exp2 with "
                "--json-out first for distance rows")
    for rec in achieved:
        # exp2's measured tables are single-bucket; CPU-interpret numbers
        # are far off the TPU roofline by design — the DISTANCE is the
        # trajectory metric, comparable run-over-run
        ceil = find_ceiling_kv_s(rec["dim"], buckets_per_key=1)
        frac = rec["kv_per_s"] / ceil
        csv.row(f"find-distance/{rec['name']}", None,
                f"achieved={rec['kv_per_s']/1e6:.2f}M-KV/s,"
                f"ceiling={ceil/1e6:.0f}M-KV/s,frac={frac:.2e}",
                kv_s=rec["kv_per_s"])
    return csv


# =============================================================================
# Dry-run step terms (arch x shape cells)
# =============================================================================

SHAPES = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def analytic_flops_per_device(arch, shape_name: str, devices: int,
                              params: int) -> float:
    """Rough per-device FLOPs: 6ND train / 2ND decode-prefill + attention."""
    kind, seq, batch = SHAPES[shape_name]
    active = _active_params(arch)
    if kind == "train":
        tokens = seq * batch
        base = 6.0 * active * tokens
        att = _attention_flops(arch, seq, batch, causal=True) * 3.0  # fwd+bwd
    elif kind == "prefill":
        tokens = seq * batch
        base = 2.0 * active * tokens
        att = _attention_flops(arch, seq, batch, causal=True)
    else:  # decode: one token, full-cache attention
        tokens = batch
        base = 2.0 * active * tokens
        att = _attention_flops(arch, seq, batch, causal=False, decode=True)
    return (base + att) / devices


def _active_params(arch) -> int:
    """Per-token active parameters (MoE: top_k of num_experts)."""
    total = arch.param_count()
    moe_frac = 0.0
    for seg in tuple(arch.lm.prelude) + tuple(arch.lm.segments):
        if seg.block.moe is not None:
            m = seg.block.moe
            # expert params scale down by top_k/num_experts
            expert_params = m.num_experts * (
                m.d_model * m.d_ff * (3 if m.gated else 2)
            )
            layers = seg.count * (arch.lm.repeats if seg in arch.lm.segments else 1)
            moe_frac += expert_params * layers * (1.0 - m.top_k / m.num_experts)
    return int(total - moe_frac)


def _attention_flops(arch, seq, batch, causal=True, decode=False) -> float:
    fl = 0.0
    for seg in tuple(arch.lm.prelude) + tuple(arch.lm.segments):
        b = seg.block
        if b.kind != "attn":
            continue
        layers = seg.count * (arch.lm.repeats if seg in arch.lm.segments else 1)
        hd, hq = b.hd, b.heads
        eff = min(b.window, seq) if b.window else seq
        if decode:
            per_tok = 2 * 2 * hq * hd * eff          # qk + pv against cache
            fl += layers * batch * per_tok
        else:
            factor = 0.5 if causal else 1.0
            fl += layers * batch * 2 * 2 * hq * hd * seq * eff * factor
    return fl


def load_cells(out_dir: str, mesh: str):
    d = os.path.join(out_dir, mesh)
    cells = []
    if not os.path.isdir(d):
        return cells
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                cells.append(json.load(fh))
    return cells


def terms(rec: dict, arch=None) -> dict:
    dev = rec["devices"]
    hlo_flops = rec["cost"]["flops_per_device"]
    analytic = (
        analytic_flops_per_device(arch, rec["shape"], dev, rec.get("params", 0))
        if arch is not None
        else 0.0
    )
    flops = max(hlo_flops, analytic)
    compute = flops / PEAK_FLOPS
    memory = rec["cost"]["bytes_accessed_per_device"] / HBM_BW
    collective = rec["collective_wire_bytes_per_device"] / ICI_BW
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda t: t[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "hlo_flops": hlo_flops,
        "analytic_flops": analytic,
        "model_hlo_ratio": (analytic / hlo_flops) if hlo_flops else float("inf"),
        "bound_s": max(compute, memory, collective),
        "useful_frac": compute / max(compute, memory, collective, 1e-30),
    }


def _dryrun_terms(csv, out_dir: str, mesh: str):
    from repro.configs import get_arch

    for rec in load_cells(out_dir, mesh):
        tag = f"step/{mesh}/{rec['arch']}/{rec['shape']}/" \
              f"{rec.get('backend', 'dense')}"
        if "skipped" in rec:
            csv.row(tag, None, f"SKIP({rec['skipped']})")
            continue
        if "error" in rec:
            csv.row(tag, None, f"ERROR({rec['error'][:60]})")
            continue
        t = terms(rec, get_arch(rec["arch"]))
        csv.row(
            tag, None,
            f"compute={t['compute_s']:.3e}s,memory={t['memory_s']:.3e}s,"
            f"collective={t['collective_s']:.3e}s,bound={t['dominant']},"
            f"compute_frac={t['useful_frac']:.2f},"
            f"mem/dev={rec['memory']['peak_estimate_per_device']/2**30:.1f}GiB",
        )


def run(csv=None, bench_dir: str = "runs/bench",
        dryrun_dir: str = "runs/dryrun"):
    """The benchmarks.run entry: find-path roofline always, dry-run step
    terms for whichever meshes have artifacts.  Returns the Csv."""
    csv = run_find_roofline(csv, bench_dir=bench_dir)
    for mesh in ("single", "multi"):
        if os.path.isdir(os.path.join(dryrun_dir, mesh)):
            _dryrun_terms(csv, dryrun_dir, mesh)
    return csv


if __name__ == "__main__":
    import sys

    run(bench_dir=sys.argv[1] if len(sys.argv) > 1 else "runs/bench")
