"""Exp#4 (paper §5.5, Table 11): single- vs dual-bucket mode.

  * first-eviction load factor: single ≈0.633 (birthday paradox on
    128-slot buckets), dual ≈0.977 (P2C warm-up phase D1);
  * top-N score retention after 3x-capacity Zipf-scored ingestion at
    λ=1.0: dual > single (paper: 99.44% vs 95.39%);
  * steady-state cache hit ratio: dual >= single;
  * throughput: dual is comparable or better at λ=1.0 (premature-eviction
    overhead avoided).

All table traffic goes through the `HKVTable` handle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, fill_batches, fill_table, kv_per_s, \
    make_insert_jit, time_fn
from repro.core import HKVTable, U64, u64

CAPACITY = 64 * 128
BATCH = 4096


def first_eviction_lf(dual: bool, rng) -> float:
    """First-eviction λ is a max-over-buckets statistic, so it DEPENDS on
    bucket count (paper: 1M buckets -> 0.633; fewer buckets push it up).
    We use 128 buckets (the largest CPU-friendly size) and small insert
    batches for λ granularity; tests/test_cache_semantics measures 0.63±
    at the same scale."""
    table = HKVTable.create(capacity=128 * 128, dim=1,
                            buckets_per_key=2 if dual else 1)
    ins_r = jax.jit(lambda t, h, l, v: t.insert_or_assign(U64(h, l), v))
    zeros = jnp.zeros((256, 1), jnp.float32)
    while True:
        keys = rng.integers(0, 2**60, size=256).astype(np.uint64)
        k = u64.from_uint64(keys)
        res = ins_r(table, k.hi, k.lo, zeros)
        table = res.table
        st = np.asarray(res.status)
        if np.any((st == 3) | (st == 4)):
            return float(table.load_factor())


def retention(dual: bool, rng) -> tuple[float, float]:
    table = HKVTable.create(
        capacity=CAPACITY, dim=1, buckets_per_key=2 if dual else 1,
        score_policy="custom",
    )
    n_stream = 3 * CAPACITY
    keys = rng.permutation(n_stream).astype(np.uint64)
    ins_c = jax.jit(lambda t, h, l, v, sh, sl: t.insert_or_assign(
        U64(h, l), v, custom_scores=U64(sh, sl)).table)
    for kb in fill_batches(keys, 2048):
        k = u64.from_uint64(kb)
        sc = u64.from_uint64(kb)  # score == key: ideal top-N known
        table = ins_c(table, k.hi, k.lo, jnp.zeros((2048, 1)), sc.hi, sc.lo)
    exp = table.export_batch(0, table.cfg.num_buckets)
    live = np.asarray(exp.mask)
    got = set(map(int, ((np.asarray(exp.key_hi, np.uint64) << np.uint64(32))
                        | np.asarray(exp.key_lo, np.uint64))[live]))
    ideal = set(range(n_stream - CAPACITY, n_stream))
    topn = len(got & ideal) / CAPACITY
    lf = float(table.load_factor())
    return topn, lf


def hit_ratio(dual: bool, rng) -> float:
    from repro.data import zipf_keys

    table = HKVTable.create(
        capacity=CAPACITY, dim=1, buckets_per_key=2 if dual else 1,
        score_policy="lru",
    )
    ins_h = make_insert_jit()
    con_h = jax.jit(lambda t, h, l: t.contains(U64(h, l)))
    zeros1 = jnp.zeros((2048, 1), jnp.float32)
    hits = total = 0
    for step in range(40):
        keys = zipf_keys(rng, 2048, 0.99, 16 * CAPACITY)
        k = u64.from_uint64(keys)
        if step >= 20:
            found = np.asarray(con_h(table, k.hi, k.lo))
            hits += int(found.sum())
            total += len(keys)
        table = ins_h(table, k.hi, k.lo, zeros1)
    return hits / max(total, 1)


def run(csv: Csv | None = None):
    csv = csv or Csv("Exp#4 single- vs dual-bucket (Table 11)")
    rng = np.random.default_rng(3)
    res = {}
    for dual in (False, True):
        tag = "dual" if dual else "single"
        lf1 = first_eviction_lf(dual, rng)
        csv.row(f"4/{tag}/first_eviction_lf", None,
                f"{lf1:.3f}[paper:{0.977 if dual else 0.633}]")
        topn, lf = retention(dual, rng)
        csv.row(f"4/{tag}/topN_retention", None,
                f"{topn*100:.2f}%[paper:{99.44 if dual else 95.39}%],final_lf={lf:.3f}")
        hr = hit_ratio(dual, np.random.default_rng(99))
        csv.row(f"4/{tag}/hit_ratio_zipf0.99", None, f"{hr*100:.2f}%")
        # throughput at lambda=1.0
        table = HKVTable.create(capacity=CAPACITY, dim=32,
                                buckets_per_key=2 if dual else 1)
        fill = rng.integers(0, 2**50, size=2 * CAPACITY).astype(np.uint64)
        table = fill_table(table, fill)
        q = u64.from_uint64(rng.integers(0, 2**51, size=BATCH).astype(np.uint64))
        find_j = jax.jit(lambda t, h, l: t.find(U64(h, l)).values)
        ins_j = jax.jit(
            lambda t, h, l, v: t.insert_or_assign(U64(h, l), v).table)
        tf = time_fn(find_j, table, q.hi, q.lo)
        ti = time_fn(ins_j, table, q.hi, q.lo, jnp.zeros((BATCH, 32)))
        res[tag] = (tf, ti)
        csv.row(f"4/{tag}/find_lf1.0", tf, f"{kv_per_s(BATCH, tf)/1e6:.2f}M-KV/s")
        csv.row(f"4/{tag}/insert_lf1.0", ti, f"{kv_per_s(BATCH, ti)/1e6:.2f}M-KV/s")
    csv.row("4/dual_vs_single/insert_ratio", None,
            f"{res['single'][1]/res['dual'][1]:.2f}x[paper:1.64x@lf1.0]")
    return csv


if __name__ == "__main__":
    run()
