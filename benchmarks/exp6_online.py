"""Exp#6: continuous online serving — sustained throughput, hit rate, and
SLO latency under mixed trainer/server traffic (the paper's title
scenario, Fig. 1).

Two sections:

1. The classic sweep: `OnlineEmbeddingEngine` serves zipfian embedding
   lookups from a `TieredHKVTable` behind a `TablePublisher`, while an
   `OnlineTrainer` interleaves streaming find_or_insert + fused-session
   gradient updates and publishes whole handles — §3.5's
   reader/updater/inserter triple under real interleave, with eviction
   live at every structural op.  Axes: hot fraction × update:read ratio
   × miss policy; acceptance: admit hit rate >= readonly on the same
   replay.

2. The admission-granularity arm: the SAME bursty request replay (by
   default Poisson-burst arrivals, `--arrival` picks steady/burst/
   diurnal), paced open-loop in wall clock, driven through wave-granular
   admission vs continuous-batch admission (per-lane splice, dispatch on
   fill, double-buffered staging).  Keys are admitted in the same FIFO
   order under the same admit policy, so hit rates match (up to
   wave-boundary duplicate placement — the delta is in the artifact);
   the comparison isolates admission granularity.  Reported: p50/p99 of
   the per-request queue-wait / service / total latency split; the
   acceptance bar is continuous p99 TOTAL latency (queue-wait + service)
   below wave-granular at equal hit rate.

    PYTHONPATH=src python -m benchmarks.exp6_online            # full sweep
    PYTHONPATH=src python -m benchmarks.exp6_online --smoke    # CI smoke
    PYTHONPATH=src python -m benchmarks.exp6_online --arrival burst
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import TieredHKVTable
from repro.data import ARRIVAL_KINDS, arrival_sizes, zipf_keys
from repro.serving import (EmbeddingRequest, OnlineEmbeddingEngine,
                           OnlineTrainer, TablePublisher)

DIM = 16
ALPHA = 1.05
FULL = dict(cold_capacity=32 * 128, wave=1024, waves=32,
            fracs=(0.125, 0.25), ratios=(0.125, 0.5), ticks=96)
SMOKE = dict(cold_capacity=8 * 128, wave=256, waves=12,
             fracs=(0.125, 0.25), ratios=(0.125, 0.5), ticks=48)


def _drive(table, *, policy, ratio, wave, waves, serve_stream, train_stream):
    """One engine+trainer replay; returns (hit_rate, hot_rate, kv_per_s,
    published) with the rates over the second half of the replay (the
    first half warms the tiers)."""
    pub = TablePublisher(table)
    trainer = OnlineTrainer(publisher=pub, publish_every=1, lr=0.1)
    eng = OnlineEmbeddingEngine(
        pub, wave_size=wave, miss_policy=policy,
        promote=(policy == "readonly"),   # best pure-read config
    )
    grads = jnp.ones((wave, DIM), jnp.float32)
    due = 0.0
    for i in range(waves):
        eng.submit(EmbeddingRequest(
            rid=i, keys=serve_stream[i * wave:(i + 1) * wave]))
        eng.step()
        due += ratio
        while due >= 1.0:    # update:read interleave
            trainer.train_step(train_stream[i * wave:(i + 1) * wave], grads)
            due -= 1.0
    half = eng.reports[waves // 2:]
    keys = sum(r.size for r in half)
    hits = sum(r.hits for r in half)
    hot = sum(r.hot_hits for r in half)
    secs = sum(r.latency_s for r in half)
    return (hits / max(keys, 1), hot / max(keys, 1),
            keys / max(secs, 1e-12), pub.published)


REQ_KEYS = 32     # per-user request size: a tick's arrival is many small
                  # requests, not one giant batch (segment-level splice)
TICK_OVER_WAVE = 1.6   # tick period as a multiple of the measured wave
                       # latency: ~60% device utilization at steady load


def _drive_slo(make_table, *, admission, sizes, stream, wave,
               tick_s=None):
    """One OPEN-LOOP arrival replay through one admission mode; returns
    (EngineMetrics, makespan_s, keys, tick_s).

    Arrivals are paced in wall clock: tick i's requests are due at
    `i * T` where T is calibrated off a measured warmup wave (pass
    `tick_s` to reuse one calibration across modes — both arms must see
    the SAME arrival timeline), and each request's `t_submit` is
    pre-stamped with its DUE time — a server that falls behind
    (wave-granular admission blocking through its serving cycle) is
    charged the queue-wait its late admission caused, the standard
    coordinated-omission-safe measurement.  Between arrivals the driver
    runs `poll()`, the event-loop seam that reaps finished waves at
    device pace.  Each tick's arrival is split into per-user requests
    of REQ_KEYS keys.  Warmup (jit compile + timed clean waves) runs on
    a DISJOINT key range and is cleared from the books — identically
    for both admission modes."""
    import time

    eng = OnlineEmbeddingEngine(make_table(), wave_size=wave,
                                miss_policy="admit", admission=admission)
    high = np.uint64(1) << np.uint64(62)
    for w in range(4):     # wave 0 compiles; waves 1-3 time clean waves
        warm = (np.arange(1, wave + 1, dtype=np.uint64)
                | high | np.uint64(w * wave))
        eng.submit(EmbeddingRequest(rid=-1 - w, keys=warm))
        eng.run_until_drained()
    if tick_s is None:
        tick_s = TICK_OVER_WAVE * float(np.median(
            [r.latency_s for r in eng.reports[1:]]))
    eng.reports.clear()
    eng.completed.clear()
    pos, rid = 0, 0
    t0 = time.perf_counter()
    for i, sz in enumerate(sizes):
        due = t0 + i * tick_s
        while True:                      # event loop until tick i is due
            eng.poll()
            rem = due - time.perf_counter()
            if rem <= 0:
                break
            # coarse sleep: waking every 1 ms keeps the reap timely
            # without the poll loop stealing host cycles from the
            # device's own compute threads mid-wave
            time.sleep(min(rem, 1e-3))
        for lo in range(0, int(sz), REQ_KEYS):
            take = min(REQ_KEYS, int(sz) - lo)
            req = EmbeddingRequest(rid=rid, keys=stream[pos:pos + take])
            req.t_submit = due           # intended arrival, not late admit
            eng.submit(req)
            pos += take
            rid += 1
        eng.step()
    eng.run_until_drained()
    makespan = time.perf_counter() - t0
    return eng.metrics(skip_warmup=False), makespan, pos, tick_s


REPS = 3          # interleaved A/B repeats per mode; medians reported —
                  # host load drifts on minute timescales, and two arms
                  # run minutes apart, so single-shot ratios swing both
                  # ways; alternating reps put both modes through the
                  # same drift and the median squeezes the tail out


def _admission_arm(csv: Csv, p: dict, arrival: str):
    """Continuous-batch vs wave-granular admission under one arrival
    shape (identical replay, identical hit rate by construction).
    Modes alternate for `REPS` repeats; per-mode medians are reported."""
    wave, ticks = p["wave"], p["ticks"]
    cold_cap = p["cold_capacity"]
    hot_cap = max(128, cold_cap // 8 // 128 * 128)
    sizes = arrival_sizes(arrival, np.random.default_rng(13), ticks, wave)
    stream = zipf_keys(np.random.default_rng(7), int(sizes.sum()), ALPHA,
                       2 * cold_cap)

    def make_table():
        return TieredHKVTable.create(hot_capacity=hot_cap,
                                     cold_capacity=cold_cap, dim=DIM)

    runs = {"wave": [], "continuous": []}
    tick_s = None
    for _rep in range(REPS):
        for admission in ("wave", "continuous"):
            m, makespan, nkeys, tick_s = _drive_slo(
                make_table, admission=admission, sizes=sizes, stream=stream,
                wave=wave, tick_s=tick_s)  # ONE calibration, shared timeline
            runs[admission].append((m, makespan, nkeys))
    ms = {}
    for admission, reps in runs.items():
        med = int(np.argsort([m.p99_total_s for m, _, _ in reps])[len(reps) // 2])
        m, makespan, nkeys = reps[med]
        ms[admission] = m
        kv_s = nkeys / max(makespan, 1e-12)   # waves overlap in continuous
        # mode, so throughput is keys/makespan, not summed wave latencies
        csv.row(
            f"arrival({arrival})/{admission}_p99_total", m.p99_total_s,
            f"hit={m.hit_rate*100:.1f}%,p99_qw={m.p99_queue_wait_s*1e3:.1f}ms,"
            f"p99_svc={m.p99_service_s*1e3:.1f}ms,"
            f"p50_total={m.p50_total_s*1e3:.1f}ms,"
            f"reqs={m.requests},reps={len(reps)},{kv_s/1e6:.2f}M-KV/s",
            kv_s=kv_s)
    w, c = ms["wave"], ms["continuous"]
    ratio = w.p99_total_s / max(c.p99_total_s, 1e-12)
    # same FIFO key order + same admit policy ⇒ hit rates match up to
    # wave-boundary duplicate placement; report the delta so the
    # equal-hit-rate claim is checkable from the artifact
    dhit = (c.hit_rate - w.hit_rate) * 100
    csv.row(
        f"arrival({arrival})/continuous_uplift", None,
        f"p99_total {ratio:.2f}x lower,hit_delta={dhit:+.2f}pp,"
        f"median-of-{REPS},continuous-vs-wave")
    return ms


def run(csv: Csv | None = None, smoke: bool = False,
        arrival: str = "burst") -> Csv:
    p = SMOKE if smoke else FULL
    cold_cap, wave, waves = p["cold_capacity"], p["wave"], p["waves"]
    tag = " [smoke]" if smoke else ""
    csv = csv or Csv(
        f"Exp#6 online serving: QPS & hit rate vs hot fraction × "
        f"update:read ratio (zipf α={ALPHA}) + continuous-vs-wave "
        f"admission SLO{tag}")
    serve_rng = np.random.default_rng(7)
    train_rng = np.random.default_rng(11)
    # working set ~2x cold capacity: nothing fits anywhere (exp5 regime)
    n = wave * waves
    serve_stream = zipf_keys(serve_rng, n, ALPHA, 2 * cold_cap)
    train_stream = zipf_keys(train_rng, n, ALPHA, 2 * cold_cap)

    for frac in p["fracs"]:
        hot_cap = max(128, int(cold_cap * frac) // 128 * 128)
        for ratio in p["ratios"]:
            cell = f"f={frac},u:r={ratio}"
            rates = {}
            for policy in ("readonly", "admit"):
                table = TieredHKVTable.create(
                    hot_capacity=hot_cap, cold_capacity=cold_cap, dim=DIM)
                hr, hot_r, qps, published = _drive(
                    table, policy=policy, ratio=ratio, wave=wave,
                    waves=waves, serve_stream=serve_stream,
                    train_stream=train_stream)
                rates[policy] = hr
                csv.row(f"tiered({cell})/{policy}_hit_rate", None,
                        f"{hr*100:.1f}%,hot={hot_r*100:.1f}%,"
                        f"published={published}")
                csv.row(f"tiered({cell})/{policy}_qps", None,
                        f"{qps/1e6:.2f}M-KV/s", kv_s=qps)
            csv.row(f"tiered({cell})/admit_uplift", None,
                    f"+{(rates['admit']-rates['readonly'])*100:.1f}pp,"
                    "admit-vs-readonly")
    _admission_arm(csv, p, arrival)
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI artifact run")
    ap.add_argument("--arrival", choices=ARRIVAL_KINDS, default="burst",
                    help="arrival process for the admission-granularity "
                         "arm (steady | burst | diurnal)")
    a = ap.parse_args()
    run(smoke=a.smoke, arrival=a.arrival)
