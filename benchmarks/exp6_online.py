"""Exp#6: continuous online serving — sustained throughput and hit rate
under mixed trainer/server traffic (the paper's title scenario, Fig. 1).

The `OnlineEmbeddingEngine` serves zipfian embedding lookups from a
`TieredHKVTable` behind a `TablePublisher`, while an `OnlineTrainer`
interleaves streaming find_or_insert + fused-session gradient updates and
publishes whole handles — §3.5's reader/updater/inserter triple under
real interleave, with eviction live at every structural op.

Swept axes:
  hot fraction       hot-tier capacity / cold capacity (as exp5);
  update:read ratio  trainer steps per served wave (0.125 = one update
                     per 8 waves; 0.5 = one per 2);
  miss policy        'readonly' (find, promote=True — the best pure-read
                     config) vs 'admit' (find_or_insert: served misses
                     are admitted themselves).

Reported per cell: steady-state hit rate (second half of the replay) and
sustained KV/s through the engine (wave wall-clock, host timers).  The
acceptance bar: the admit policy's hit rate >= the read-only policy's on
the same zipfian replay — admission can only add residents the trainer
alone would not have inserted.

    PYTHONPATH=src python -m benchmarks.exp6_online            # full sweep
    PYTHONPATH=src python -m benchmarks.exp6_online --smoke    # CI smoke
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import TieredHKVTable
from repro.data import zipf_keys
from repro.serving import (EmbeddingRequest, OnlineEmbeddingEngine,
                           OnlineTrainer, TablePublisher)

DIM = 16
ALPHA = 1.05
FULL = dict(cold_capacity=32 * 128, wave=1024, waves=32,
            fracs=(0.125, 0.25), ratios=(0.125, 0.5))
SMOKE = dict(cold_capacity=8 * 128, wave=256, waves=12,
             fracs=(0.125, 0.25), ratios=(0.125, 0.5))


def _drive(table, *, policy, ratio, wave, waves, serve_stream, train_stream):
    """One engine+trainer replay; returns (hit_rate, hot_rate, kv_per_s,
    published) with the rates over the second half of the replay (the
    first half warms the tiers)."""
    pub = TablePublisher(table)
    trainer = OnlineTrainer(publisher=pub, publish_every=1, lr=0.1)
    eng = OnlineEmbeddingEngine(
        pub, wave_size=wave, miss_policy=policy,
        promote=(policy == "readonly"),   # best pure-read config
    )
    grads = jnp.ones((wave, DIM), jnp.float32)
    due = 0.0
    for i in range(waves):
        eng.submit(EmbeddingRequest(
            rid=i, keys=serve_stream[i * wave:(i + 1) * wave]))
        eng.step()
        due += ratio
        while due >= 1.0:    # update:read interleave
            trainer.train_step(train_stream[i * wave:(i + 1) * wave], grads)
            due -= 1.0
    half = eng.reports[waves // 2:]
    keys = sum(r.size for r in half)
    hits = sum(r.hits for r in half)
    hot = sum(r.hot_hits for r in half)
    secs = sum(r.latency_s for r in half)
    return (hits / max(keys, 1), hot / max(keys, 1),
            keys / max(secs, 1e-12), pub.published)


def run(csv: Csv | None = None, smoke: bool = False) -> Csv:
    p = SMOKE if smoke else FULL
    cold_cap, wave, waves = p["cold_capacity"], p["wave"], p["waves"]
    tag = " [smoke]" if smoke else ""
    csv = csv or Csv(
        f"Exp#6 online serving: QPS & hit rate vs hot fraction × "
        f"update:read ratio (zipf α={ALPHA}){tag}")
    serve_rng = np.random.default_rng(7)
    train_rng = np.random.default_rng(11)
    # working set ~2x cold capacity: nothing fits anywhere (exp5 regime)
    n = wave * waves
    serve_stream = zipf_keys(serve_rng, n, ALPHA, 2 * cold_cap)
    train_stream = zipf_keys(train_rng, n, ALPHA, 2 * cold_cap)

    for frac in p["fracs"]:
        hot_cap = max(128, int(cold_cap * frac) // 128 * 128)
        for ratio in p["ratios"]:
            cell = f"f={frac},u:r={ratio}"
            rates = {}
            for policy in ("readonly", "admit"):
                table = TieredHKVTable.create(
                    hot_capacity=hot_cap, cold_capacity=cold_cap, dim=DIM)
                hr, hot_r, qps, published = _drive(
                    table, policy=policy, ratio=ratio, wave=wave,
                    waves=waves, serve_stream=serve_stream,
                    train_stream=train_stream)
                rates[policy] = hr
                csv.row(f"tiered({cell})/{policy}_hit_rate", None,
                        f"{hr*100:.1f}%,hot={hot_r*100:.1f}%,"
                        f"published={published}")
                csv.row(f"tiered({cell})/{policy}_qps", None,
                        f"{qps/1e6:.2f}M-KV/s", kv_s=qps)
            csv.row(f"tiered({cell})/admit_uplift", None,
                    f"+{(rates['admit']-rates['readonly'])*100:.1f}pp,"
                    "admit-vs-readonly")
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI artifact run")
    run(smoke=ap.parse_args().smoke)
