"""Shared benchmark machinery.

Wall-clock numbers from this container are CPU-XLA timings — useful for
RELATIVE comparisons (λ sweeps, ablations, single-vs-dual) which is exactly
how the paper uses its figures; absolute B-KV/s targets are H100/TPU
numbers and live in the roofline analysis instead.  Each timing is the
median of `reps` calls after a warmup (jit compile excluded).
"""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, reps: int = 5, warmup: int = 2):
    """Median seconds per call of an already-jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def kv_per_s(batch: int, seconds: float) -> float:
    return batch / max(seconds, 1e-12)


from repro.core.u64 import EMPTY_KEY  # noqa: E402 — the one sentinel definition


def make_insert_jit():
    """One jitted insert_or_assign closure over a KVTable handle.

    The handle is a pytree (static cfg/backend in aux data), so one
    closure serves every table — HKV or baseline — and every fill batch;
    retraces happen per distinct config, exactly like a static cfg arg.
    """
    import jax

    from repro.core import U64

    @jax.jit
    def ins(table, kh, kl, v):
        return table.insert_or_assign(U64(kh, kl), v).table

    return ins


def fill_table(table, keys: np.ndarray, batch: int = 4096, ins=None):
    """Stream `keys` into any KVTable handle; returns the filled handle."""
    import jax.numpy as jnp

    from repro.core import u64

    ins = ins or make_insert_jit()
    zeros = jnp.zeros((batch, table.dim), jnp.float32)
    for kb in fill_batches(keys, batch):
        k = u64.from_uint64(kb)
        table = ins(table, k.hi, k.lo, zeros)
    return table


def fill_batches(keys: np.ndarray, batch: int = 4096):
    """Yield constant-shape batches padded with the EMPTY sentinel.

    Constant shapes keep every insert on ONE jit cache entry — variable
    tail batches would otherwise recompile per shape."""
    n = len(keys)
    for i in range(0, n, batch):
        kb = keys[i : i + batch]
        if len(kb) < batch:
            kb = np.concatenate([kb, np.full(batch - len(kb), EMPTY_KEY, np.uint64)])
        yield kb


class Csv:
    """name,us_per_call,derived printer (the benchmarks.run contract).

    Rows are also RETAINED so `benchmarks.run` can emit a `BENCH_<exp>.json`
    trajectory artifact (see `to_json`) — the CSV stdout stays byte-for-byte
    what it always was."""

    def __init__(self, title: str):
        self.title = title
        self.rows: list[dict] = []
        print(f"# === {title} ===")
        print("name,us_per_call,derived")

    def row(self, name: str, seconds: float | None, derived: str,
            kv_s: float | None = None):
        us = "" if seconds is None else f"{seconds * 1e6:.1f}"
        print(f"{name},{us},{derived}")
        self.rows.append({
            "name": name,
            "us_per_call": None if seconds is None else seconds * 1e6,
            "derived": derived,
            "kv_per_s": kv_s if kv_s is not None else _kv_s_of(derived),
        })

    def to_json(self, experiment: str, *, commit: str, timestamp: str) -> dict:
        """The stable trajectory schema (`bench-trajectory/v1`): one object
        per experiment run, identifying (commit, timestamp) passed IN by the
        driver — this function never reads a clock — plus per-variant rows
        with the numeric KV/s where the row reports one."""
        return {
            "schema": "bench-trajectory/v1",
            "experiment": experiment,
            "title": self.title,
            "commit": commit,
            "timestamp": timestamp,
            "rows": self.rows,
        }


def _kv_s_of(derived: str) -> float | None:
    """Parse the conventional '<x>M-KV/s' marker out of a derived string."""
    import re

    m = re.search(r"([0-9.]+)M-KV/s", derived)
    return float(m.group(1)) * 1e6 if m else None
