"""Exp#5: the tier hierarchy (DESIGN.md §2.5) — hit rate and throughput
vs hot-tier fraction under zipfian traffic.

The claim under test (the tentpole's acceptance bar): a `TieredHKVTable`
whose HOT capacity is smaller than the working set sustains a measurably
higher hit rate than a single HKV table of the SAME hot capacity, because
demotion parks the tail in the cold tier and miss-path promotion pulls
re-accessed keys back up — while a flat table of that size can only evict
the tail out of existence.  A flat table at the COLD capacity is also run
as the "what if it all fit in HBM" reference line (the tiered tables hold
hot+cold slots, so it is a comparison point, not a strict bound).

Replay: a fixed zipfian key stream (`repro.data.zipf_keys`, hot keys
scattered by fmix64) drives `find_or_insert` on every table; the hit rate
is the `found` fraction over the second half of the replay (the first half
warms the tiers).  Conservation is tracked from the tiered results'
counters: pairs leave the hierarchy only at the cold tier's boundary and
are counted in `dropped`.

    PYTHONPATH=src python -m benchmarks.exp5_tiered            # full sweep
    PYTHONPATH=src python -m benchmarks.exp5_tiered --smoke    # CI smoke
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, kv_per_s, time_fn
from repro.core import HKVTable, TieredHKVTable, U64, u64
from repro.data import zipf_keys

DIM = 16
ALPHA = 1.05           # zipfian skew: hot head + heavy tail
FULL = dict(cold_capacity=32 * 128, batch=1024, steps=32, fracs=(0.125, 0.25, 0.5))
SMOKE = dict(cold_capacity=8 * 128, batch=256, steps=10, fracs=(0.25,))


def _replay(table, key_stream, batch, steps):
    """Drive `find_or_insert` over the stream; returns (table, per-step hit
    rates, total dropped) — dropped is 0 for tables without the counter."""
    ins = jax.jit(
        lambda t, kh, kl, v: _step(t, U64(kh, kl), v))
    zeros = jnp.zeros((batch, DIM), jnp.float32)
    hits, dropped = [], 0
    for i in range(steps):
        kb = u64.from_uint64(key_stream[i * batch : (i + 1) * batch])
        table, found, drop = ins(table, kb.hi, kb.lo, zeros)
        hits.append(float(np.asarray(found).mean()))
        dropped += int(drop)
    return table, hits, dropped


def _step(t, k, v):
    r = t.find_or_insert(k, v)
    drop = getattr(r, "dropped", jnp.zeros((), jnp.int32))
    return r.table, r.found, drop


def run(csv: Csv | None = None, smoke: bool = False) -> Csv:
    p = SMOKE if smoke else FULL
    cold_cap, batch, steps = p["cold_capacity"], p["batch"], p["steps"]
    tag = " [smoke]" if smoke else ""
    csv = csv or Csv(f"Exp#5 tier hierarchy: hit rate & throughput vs "
                     f"hot fraction (zipf α={ALPHA}){tag}")
    rng = np.random.default_rng(42)
    # working set ~2x the cold capacity: nothing fits entirely anywhere
    stream = zipf_keys(rng, batch * steps, ALPHA, 2 * cold_cap)
    half = steps // 2

    def hit_rate(hits):
        return float(np.mean(hits[half:]))

    # flat reference at the COLD capacity — the "what if the whole cold
    # tier fit in HBM" comparison point (the tiered tables below hold
    # hot+cold slots, so this is a reference line, not a strict bound)
    ref = HKVTable.create(capacity=cold_cap, dim=DIM)
    ref, ref_hits, _ = _replay(ref, stream, batch, steps)
    csv.row(f"single(cap={cold_cap})/hit_rate", None,
            f"{hit_rate(ref_hits)*100:.1f}%,flat-reference-at-cold-capacity")

    for frac in p["fracs"]:
        hot_cap = max(128, int(cold_cap * frac) // 128 * 128)
        tiered = TieredHKVTable.create(
            hot_capacity=hot_cap, cold_capacity=cold_cap, dim=DIM)
        single = HKVTable.create(capacity=hot_cap, dim=DIM)

        tiered, t_hits, t_drop = _replay(tiered, stream, batch, steps)
        single, s_hits, _ = _replay(single, stream, batch, steps)
        thr, shr = hit_rate(t_hits), hit_rate(s_hits)
        csv.row(f"tiered(hot={hot_cap},f={frac})/hit_rate", None,
                f"{thr*100:.1f}%,dropped={t_drop}")
        csv.row(f"single(cap={hot_cap})/hit_rate", None,
                f"{shr*100:.1f}%,same-hot-capacity")
        csv.row(f"tiered(f={frac})/hit_rate_uplift", None,
                f"+{(thr-shr)*100:.1f}pp,vs-same-hot-capacity")

        # residency + conservation view (exact accounting is pinned in
        # tests/test_tiered.py; this row makes drops visible in the data)
        csv.row(f"tiered(f={frac})/residency", None,
                f"hot={int(tiered.hot.size())},cold={int(tiered.cold.size())},"
                f"distinct={int(tiered.size())}")

        # steady-state throughput of the training op on the warmed tables
        kb = u64.from_uint64(stream[:batch])
        zeros = jnp.zeros((batch, DIM), jnp.float32)
        for name, tbl in (("tiered", tiered), ("single", single)):
            fn = jax.jit(lambda t, kh, kl, v: _step(t, U64(kh, kl), v))
            sec = time_fn(fn, tbl, kb.hi, kb.lo, zeros)
            csv.row(f"{name}(f={frac})/find_or_insert", sec,
                    f"{kv_per_s(batch, sec)/1e6:.2f}M-KV/s",
                    kv_s=kv_per_s(batch, sec))
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI artifact run")
    run(smoke=ap.parse_args().smoke)
