"""Exp#9: end-to-end DLRM training steps/sec, fused vs composed updater.

The paper's own workload (`examples/dlrm_continuous.py`, config B scaled):
26 sparse fields through one HKV table, dense bottom MLP, dot interaction,
click-through logistic loss.  The measured quantity is the FULL train
step — lookup_train (inserter) + forward/backward + the embedding
gradient apply — under two apply arms, per optimizer variant:

  fused      `HKVEmbedding.apply_grads` as shipped: compacted dedupe +
             segment-sum + ONE structured `update_rows` dispatch (on
             backend='kernel' a single fused update_scan launch)
  composed   the pre-fusion sequence the fused op replaced, as the
             SEPARATE dispatches it actually was: find_rows (locate +
             gather, rows materialize to HBM) -> optimizer apply ->
             assign (locate + scatter) — the gradient apply crosses
             three launch boundaries and round-trips the row batch

The MLP front half (lookup_train + forward/backward + dense update) is
one shared jitted function; the arms differ ONLY in how many dispatches
the gradient apply takes.  That boundary structure is the thing the
fused kernel removes — timing both arms inside one jit would let XLA
CSE/fuse the composed passes back together and measure nothing.  Timings
are CPU-XLA relative numbers (per benchmarks.common); the KERNEL-path
deltas ride along as trace-time launch accounting (shim counters around
the kernel wrappers, like exp2's) plus the `roofline.update_bytes`
model — so the artifact carries steps/sec, launches eliminated, and
bytes saved per update in one place.

    PYTHONPATH=src python -m benchmarks.exp9_train_apply
    PYTHONPATH=src python -m benchmarks.run exp9_train_apply \
        --json-out runs/bench --timestamp ...
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_fn
from benchmarks.roofline import update_bytes
from repro.configs.hkv_dlrm import PAPER_CONFIGS, scaled
from repro.core import ops as core_ops
from repro.data import zipf_keys
from repro.embedding.sparse_opt import SparseOptimizer
from repro.models.common import dense_init

BATCH = 128
SCALE = 2**14            # config B capacity 128M -> 8k slots on CPU
OPTIMIZERS = ("sgd", "sgdm", "rowwise_adagrad", "adagrad")


def _make_steps(cfg, emb):
    """(step_fused, step_composed): Python step functions over shared
    jitted pieces.  The front half (lookup + fwd/bwd + dense update) is
    ONE jitted fn both arms call; the apply phase is one dispatch
    (fused) vs three (composed) — the launch structure under test."""
    from repro.core import merge as merge_mod
    from repro.core import u64
    from repro.core.u64 import U64

    d, nf = cfg.dim, cfg.num_sparse
    opt = emb.optimizer

    def forward(params, emb_rows, dense_x):
        z = jax.nn.relu(dense_x @ params["bottom1"]) @ params["bottom2"]
        feats = jnp.concatenate([z[:, None, :], emb_rows], axis=1)
        inter = jnp.einsum("bnd,bmd->bnm", feats, feats)
        iu = jnp.triu_indices(nf + 1, k=1)
        flat = inter[:, iu[0], iu[1]]
        h = jnp.concatenate([z, flat], axis=1)
        return (jax.nn.relu(h @ params["top1"]) @ params["top2"])[:, 0]

    def loss_fn(params, emb_rows, dense_x, labels):
        logits = forward(params, emb_rows, dense_x)
        return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                        + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1))

    @jax.jit
    def front(table, params, toks, dense_x, labels):
        table, rows = emb.lookup_train(table, toks)
        loss, (gp, ge) = grad_fn(params, rows, dense_x, labels)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, gp)
        return table, params, ge, loss

    # fused apply: ONE dispatch (dedupe + structured update_rows)
    @jax.jit
    def apply_fused(table, toks, ge):
        return emb.apply_grads(table, toks, ge)

    # composed apply: the dedupe (shared by both routes pre- and
    # post-PR) plus THREE table-op dispatches
    @jax.jit
    def dedupe(toks, ge):
        keys = emb.keys_of(toks)
        g = ge.reshape(-1, d)
        n = g.shape[0]
        dd = merge_mod.dedupe_keys(keys)
        uh = jnp.full((n,), u64.EMPTY_HI, jnp.uint32).at[dd.gid].set(
            keys.hi[dd.idx_sorted])
        ul = jnp.full((n,), u64.EMPTY_LO, jnp.uint32).at[dd.gid].set(
            keys.lo[dd.idx_sorted])
        g_sum = jax.ops.segment_sum(g[dd.idx_sorted], dd.gid,
                                    num_segments=n,
                                    indices_are_sorted=True)
        return uh, ul, g_sum

    @jax.jit
    def gather(table, uh, ul):                 # locate + gather
        r = table.find_rows(U64(uh, ul))
        return r.rows, r.found

    @jax.jit
    def apply_opt(rows, g_sum, found):         # the optimizer pass
        new = opt.apply(rows, g_sum, d)
        return jnp.where(found[:, None], new, rows)

    @jax.jit
    def scatter(table, uh, ul, new):           # locate + scatter
        return table.assign(U64(uh, ul), new)

    def step_fused(table, params, toks, dense_x, labels):
        table, params, ge, loss = front(table, params, toks, dense_x,
                                        labels)
        return apply_fused(table, toks, ge), params, loss

    def step_composed(table, params, toks, dense_x, labels):
        table, params, ge, loss = front(table, params, toks, dense_x,
                                        labels)
        uh, ul, g_sum = dedupe(toks, ge)
        rows, found = gather(table, uh, ul)
        new = apply_opt(rows, g_sum, found)
        return scatter(table, uh, ul, new), params, loss

    return step_fused, step_composed


def _batch(rng, cfg):
    field_keys = np.stack(
        [zipf_keys(rng, BATCH, 0.99, 10**6) ^ np.uint64(f << 56)
         for f in range(cfg.num_sparse)], axis=1)
    toks = jnp.asarray((field_keys & np.uint64(0x7FFFFFFF)).astype(np.int64),
                       jnp.int32)
    dense_x = jnp.asarray(rng.normal(size=(BATCH, cfg.dense_features)),
                          jnp.float32)
    labels = jnp.asarray(rng.integers(0, 2, size=BATCH), jnp.float32)
    return toks, dense_x, labels


def _params(cfg, key):
    ks = jax.random.split(key, 4)
    d, nf = cfg.dim, cfg.num_sparse
    return {
        "bottom1": dense_init(ks[0], cfg.dense_features, 64),
        "bottom2": dense_init(ks[1], 64, d),
        "top1": dense_init(ks[2], d + nf * (nf + 1) // 2, 64),
        "top2": dense_init(ks[3], 64, 1),
    }


def _count_kernel_launches(emb, table, toks, grads):
    """Trace-time launch accounting on backend='kernel': the fused
    apply_grads vs the composed kernel sequence it replaced (restored in
    `finally`, exactly like exp2's find counter)."""
    from repro.kernels import digest_scan as _ds
    from repro.kernels import gather as _ga
    from repro.kernels import ops as kops
    from repro.kernels import scatter as _sc
    from repro.kernels import update_scan as _upd

    slots = [(_upd, "update_scan_tlp"), (_upd, "update_scan_pipeline"),
             (_ds, "digest_scan_tlp"), (_ds, "digest_scan_pipeline"),
             (_ga, "gather_rows"), (_sc, "scatter_rows")]
    originals = {(m, n): getattr(m, n) for m, n in slots}
    counts = {"n": 0}

    def shim(orig):
        def f(*a, **kw):
            counts["n"] += 1
            return orig(*a, **kw)
        return f

    try:
        for m, n in slots:
            setattr(m, n, shim(originals[(m, n)]))
        kemb = dataclasses.replace(emb, backend="kernel")
        ktable = table.with_backend("kernel")
        kemb.apply_grads(ktable, toks, grads)
        fused = counts["n"]
        counts["n"] = 0
        keys = kemb.keys_of(toks)
        g_sum = grads.reshape(-1, emb.dim)
        kops.update_composed_kernel(ktable.state, ktable.cfg, keys, g_sum,
                                    emb.optimizer)
        composed = counts["n"]
    finally:
        for (m, n), v in originals.items():
            setattr(m, n, v)
    return fused, composed


def run(csv: Csv | None = None):
    csv = csv or Csv("Exp#9 DLRM train steps/sec: fused vs composed "
                     "updater x optimizer (config B scaled)")
    base = scaled(PAPER_CONFIGS["B"], scale=SCALE)
    key = jax.random.PRNGKey(0)

    for opt_name in OPTIMIZERS:
        opt = SparseOptimizer(opt_name, lr=0.01)
        emb = dataclasses.replace(base.embedding(), optimizer=opt,
                                  backend="jnp")
        rates = {}
        steps = dict(zip(("fused", "composed"), _make_steps(base, emb)))
        for arm in ("fused", "composed"):
            rng = np.random.default_rng(9)         # identical streams/arms
            table = emb.create()
            params = _params(base, key)
            step = steps[arm]
            # warm the table AND the jit cache before timing
            for _ in range(3):
                toks, dense_x, labels = _batch(rng, base)
                table, params, _ = step(table, params, toks, dense_x,
                                        labels)
            toks, dense_x, labels = _batch(rng, base)
            t = time_fn(step, table, params, toks, dense_x, labels,
                        reps=9, warmup=2)
            rates[arm] = 1.0 / t
            uniq = len(np.unique(np.asarray(toks)))
            csv.row(f"step/{opt_name}/{arm}", t,
                    f"{rates[arm]:.1f}steps/s,"
                    f"{BATCH * base.num_sparse}lookups+{uniq}uniq-updates")
        csv.row(f"step/{opt_name}/speedup", None,
                f"fused/composed={rates['fused'] / rates['composed']:.3f}x"
                "[>=1: the fused apply never loses]")

    # kernel-path deltas: launches eliminated (trace-time accounting, tiny
    # table — interpret mode) + the roofline bytes model per update
    opt = SparseOptimizer("rowwise_adagrad", lr=0.01)
    tiny = dataclasses.replace(scaled(PAPER_CONFIGS["B"], scale=2**19),
                               num_sparse=4)
    emb = dataclasses.replace(tiny.embedding(), optimizer=opt,
                              backend="jnp")
    rng = np.random.default_rng(11)
    table = emb.create()
    toks = jnp.asarray(rng.integers(0, 64, size=(32, tiny.num_sparse)),
                       jnp.int32)
    table, _ = emb.lookup_train(table, toks)
    grads = jnp.asarray(rng.normal(size=(32, tiny.num_sparse, tiny.dim)),
                        jnp.float32)
    fused_l, composed_l = _count_kernel_launches(emb, table, toks, grads)
    csv.row("kernel-launches/apply_grads", None,
            f"fused={fused_l},composed={composed_l},"
            f"eliminated={composed_l - fused_l}/step")
    b = update_bytes(base.dim, opt.aux_dim(base.dim), buckets_per_key=2)
    csv.row("bytes-model/cfgB(rowwise_adagrad)", None,
            f"fused={b['fused']}B,composed={b['composed']}B,"
            f"saved={b['composed'] - b['fused']}B/update"
            f"({100 * (b['composed'] - b['fused']) / b['composed']:.0f}%)")
    return csv


if __name__ == "__main__":
    run()
