"""Exp#1 (paper §5.2, Fig. 6, Tables 3/6): load-factor sensitivity,
HKV vs dictionary-semantic baselines.

Reproduced claims (hardware-independent form):
  * HKV find cost is λ-INDEPENDENT (<5% variation 0.5->1.0) and every
    upsert resolves in place at λ=1.0;
  * open addressing degrades with λ (probe growth) and FAILS inserts at
    capacity; bucketed-P2C silently drops inserts at λ=1.0 (BP2HT's 48%);
  * structural probe counts match Table 3 (HKV: 1 bucket row; P2C: 2;
    OA: grows super-linearly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, fill_batches, kv_per_s, make_insert_jit, time_fn
from repro.baselines import BucketedP2CTable, OpenAddressingTable
from repro.core import ops, table, u64

CAPACITY = 128 * 128   # 16,384 slots
BATCH = 4096
DIM = 32
LAMBDAS = (0.25, 0.50, 0.75, 0.95, 1.00)


def _fill_hkv(cfg, state, rng, target, ins):
    """Fill to target λ with constant-shape sentinel-padded batches."""
    zeros = jnp.zeros((BATCH, DIM), jnp.float32)
    empty = np.uint64(0xFFFFFFFFFFFFFFFF)
    for _ in range(200):  # λ→1 convergence is asymptotic (evictions begin)
        lf = float(ops.load_factor(state))
        if lf >= target - 1e-6:
            break
        need = min(int((target - lf) * cfg.capacity) + 1, BATCH)
        keys = np.full(BATCH, empty, np.uint64)
        keys[:need] = rng.integers(0, 2**50, size=need).astype(np.uint64)
        k = u64.from_uint64(keys)
        state = ins(state, k.hi, k.lo, zeros)
    return state


def run(csv: Csv | None = None):
    csv = csv or Csv("Exp#1 load-factor sensitivity (Fig. 6 / Tables 3+6)")
    rng = np.random.default_rng(0)

    # ---- HKV ----------------------------------------------------------------
    cfg = table.HKVConfig(capacity=CAPACITY, dim=DIM, buckets_per_key=1)
    state = table.create(cfg)
    find_j = jax.jit(lambda s, kh, kl: ops.find(s, cfg, u64.U64(kh, kl)).values)
    ins_j = make_insert_jit(cfg)
    hkv_find = {}
    for lam in LAMBDAS:
        state = _fill_hkv(cfg, state, rng, lam, ins_j)
        # query mix: half hits, half misses (the paper's uniform-random sweep)
        qk = rng.integers(0, 2**50, size=BATCH).astype(np.uint64)
        k = u64.from_uint64(qk)
        t = time_fn(find_j, state, k.hi, k.lo)
        hkv_find[lam] = t
        csv.row(f"hkv/find/lf={lam:.2f}", t, f"{kv_per_s(BATCH, t)/1e6:.2f}M-KV/s")
        vk = u64.from_uint64(rng.integers(0, 2**50, size=BATCH).astype(np.uint64))
        ti = time_fn(ins_j, state, vk.hi, vk.lo, jnp.zeros((BATCH, DIM)))
        csv.row(f"hkv/insert/lf={lam:.2f}", ti,
                f"{kv_per_s(BATCH, ti)/1e6:.2f}M-KV/s,resolved-in-place")
    spread = (max(hkv_find.values()) - min(hkv_find.values())) / min(hkv_find.values())
    csv.row("hkv/find/lf-variation", None, f"{spread*100:.1f}%[paper:<5%]")

    # ---- Open addressing (WarpCore/cuCollections family) ---------------------
    oa = OpenAddressingTable(capacity=CAPACITY, dim=DIM)
    oas = oa.create()
    oaf = jax.jit(lambda s, kh, kl: oa.find(s, u64.U64(kh, kl)))
    oai = jax.jit(lambda s, kh, kl, v: oa.insert(s, u64.U64(kh, kl), v))
    zeros2k = jnp.zeros((2048, DIM), jnp.float32)
    empty = np.uint64(0xFFFFFFFFFFFFFFFF)
    filled = 0
    for lam in LAMBDAS:
        target = int(lam * CAPACITY)
        while filled < target:
            need = min(target - filled, 2048)
            keys = np.full(2048, empty, np.uint64)
            keys[:need] = rng.integers(0, 2**50, size=need).astype(np.uint64)
            k = u64.from_uint64(keys)
            rep = oai(oas, k.hi, k.lo, zeros2k)
            oas = rep.state
            filled += int(np.asarray(rep.ok).sum())
        qk = rng.integers(0, 2**50, size=BATCH).astype(np.uint64)
        k = u64.from_uint64(qk)
        t = time_fn(oaf, oas, k.hi, k.lo)
        probes = float(np.asarray(oaf(oas, k.hi, k.lo).probes).mean())
        csv.row(f"openaddr/find/lf={lam:.2f}", t,
                f"{kv_per_s(BATCH, t)/1e6:.2f}M-KV/s,avg_probes={probes:.1f}")
    # capability gap: inserting beyond capacity FAILS
    extra = rng.integers(2**51, 2**52, size=2048).astype(np.uint64)
    rep = oa.insert(oas, u64.from_uint64(extra), jnp.zeros((2048, DIM)))
    fail = 1.0 - float(np.asarray(rep.ok).mean())
    csv.row("openaddr/insert-at-capacity", None, f"fail_rate={fail*100:.0f}%")

    # ---- Bucketed P2C (BGHT/BP2HT family) ------------------------------------
    p2c = BucketedP2CTable(capacity=CAPACITY, dim=DIM)
    ps = p2c.create()
    p2cf = jax.jit(lambda s, kh, kl: p2c.find(s, u64.U64(kh, kl)))
    p2ci = jax.jit(lambda s, kh, kl, v: p2c.insert(s, u64.U64(kh, kl), v))
    filled = 0
    for lam in LAMBDAS:
        target = int(lam * CAPACITY)
        attempts = 0
        while filled < target and attempts < 50:
            need = min(target - filled + 64, 2048)
            keys = np.full(2048, empty, np.uint64)
            keys[:need] = rng.integers(0, 2**50, size=need).astype(np.uint64)
            k = u64.from_uint64(keys)
            rep = p2ci(ps, k.hi, k.lo, zeros2k)
            ps = rep.state
            filled += int(np.asarray(rep.ok).sum())
            attempts += 1
        qk = rng.integers(0, 2**50, size=BATCH).astype(np.uint64)
        k = u64.from_uint64(qk)
        t = time_fn(p2cf, ps, k.hi, k.lo)
        csv.row(f"bucketp2c/find/lf={lam:.2f}", t,
                f"{kv_per_s(BATCH, t)/1e6:.2f}M-KV/s,probes<=2,"
                f"reached_lf={filled/CAPACITY:.2f}")
    extra = rng.integers(2**51, 2**52, size=2048).astype(np.uint64)
    rep = p2c.insert(ps, u64.from_uint64(extra), jnp.zeros((2048, DIM)))
    ok = float(np.asarray(rep.ok).mean())
    csv.row("bucketp2c/insert-at-lf1.0", None,
            f"success={ok*100:.0f}%[paper:BP2HT=48%]")


if __name__ == "__main__":
    run()
