"""Exp#1 (paper §5.2, Fig. 6, Tables 3/6): load-factor sensitivity,
HKV vs dictionary-semantic baselines.

Reproduced claims (hardware-independent form):
  * HKV find cost is λ-INDEPENDENT (<5% variation 0.5->1.0) and every
    upsert resolves in place at λ=1.0;
  * open addressing degrades with λ (probe growth) and FAILS inserts at
    capacity; bucketed-P2C silently drops inserts at λ=1.0 (BP2HT's 48%);
  * structural probe counts match Table 3 (HKV: 1 bucket row; P2C: 2;
    OA: grows super-linearly).

Every table runs through ONE harness over the `KVTable` protocol
(`repro.core.api`): the same fill loop, the same jitted find/insert
closures, the same row format — the capability gap shows up in the data
(`.ok` rates, reached λ), not in per-table driver code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import EMPTY_KEY, Csv, kv_per_s, make_insert_jit, time_fn
from repro.baselines import DictKVTable
from repro.core import HKVTable, U64, u64

CAPACITY = 128 * 128   # 16,384 slots
BATCH = 4096
DIM = 32
LAMBDAS = (0.25, 0.50, 0.75, 0.95, 1.00)


def fill_to_lambda(table, target: float, rng, ins, batch: int = 2048,
                   max_attempts: int = 200):
    """Drive any KVTable to load factor `target` with fresh random keys.

    Constant-shape sentinel-padded batches; stops at the target, at
    `max_attempts`, or when the table stops accepting keys (the
    dictionary-semantic stall the experiment is designed to expose).
    The stall detector tolerates several zero-progress rounds: near
    λ=1.0 an HKV batch of fresh keys can resolve purely by in-place
    eviction (size unchanged) while convergence continues — only a
    sustained stall means insert capability is exhausted.
    """
    zeros = jnp.zeros((batch, table.dim), jnp.float32)
    prev, stalled = -1, 0
    for _ in range(max_attempts):
        lf = float(table.load_factor())
        if lf >= target - 1e-6:
            break
        size = int(table.size())
        stalled = stalled + 1 if size == prev else 0
        if stalled >= 16:  # sustained no-progress: capability exhausted
            break
        prev = size
        need = min(int((target - lf) * table.capacity) + 1, batch)
        keys = np.full(batch, EMPTY_KEY, np.uint64)
        keys[:need] = rng.integers(0, 2**50, size=need).astype(np.uint64)
        k = u64.from_uint64(keys)
        table = ins(table, k.hi, k.lo, zeros)
    return table


def bench_table(csv: Csv, name: str, table, rng):
    """The one measurement path every table goes through."""
    ins = make_insert_jit()
    find_j = jax.jit(lambda t, kh, kl: t.find(U64(kh, kl)))
    find_times = {}
    for lam in LAMBDAS:
        table = fill_to_lambda(table, lam, rng, ins)
        reached = float(table.load_factor())
        qk = rng.integers(0, 2**50, size=BATCH).astype(np.uint64)
        k = u64.from_uint64(qk)
        t = time_fn(find_j, table, k.hi, k.lo)
        find_times[lam] = t
        rep = find_j(table, k.hi, k.lo)
        probes = getattr(rep, "probes", None)
        extra = (f",avg_probes={float(np.asarray(probes).mean()):.1f}"
                 if probes is not None else "")
        csv.row(f"{name}/find/lf={lam:.2f}", t,
                f"{kv_per_s(BATCH, t)/1e6:.2f}M-KV/s,"
                f"reached_lf={reached:.3f}{extra}")
        vk = u64.from_uint64(rng.integers(0, 2**50, size=BATCH).astype(np.uint64))
        ti = time_fn(ins, table, vk.hi, vk.lo, jnp.zeros((BATCH, DIM)))
        csv.row(f"{name}/insert/lf={lam:.2f}", ti,
                f"{kv_per_s(BATCH, ti)/1e6:.2f}M-KV/s")
    spread = (max(find_times.values()) - min(find_times.values())) / min(
        find_times.values()
    )
    csv.row(f"{name}/find/lf-variation", None, f"{spread*100:.1f}%")
    # capability at capacity: fresh keys against the (near-)full table
    extra_k = rng.integers(2**51, 2**52, size=2048).astype(np.uint64)
    rep = table.insert_or_assign(u64.from_uint64(extra_k),
                                 jnp.zeros((2048, DIM)))
    ok = float(np.asarray(rep.ok).mean())
    csv.row(f"{name}/insert-at-capacity", None,
            f"resolved={ok*100:.0f}%,failed={100*(1-ok):.0f}%")
    return table


def run(csv: Csv | None = None):
    csv = csv or Csv("Exp#1 load-factor sensitivity (Fig. 6 / Tables 3+6) "
                     "[one KVTable harness]")
    rng = np.random.default_rng(0)
    tables = {
        # single-bucket HKV: the baseline-comparable configuration
        "hkv": HKVTable.create(capacity=CAPACITY, dim=DIM, buckets_per_key=1),
        # WarpCore / cuCollections family
        "openaddr": DictKVTable.open_addressing(CAPACITY, DIM),
        # BGHT / BP2HT family
        "bucketp2c": DictKVTable.bucketed_p2c(CAPACITY, DIM),
    }
    for name, table in tables.items():
        bench_table(csv, name, table, rng)
    return csv


if __name__ == "__main__":
    run()
