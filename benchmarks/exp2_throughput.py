"""Exp#2 (paper §5.3, Figs. 7/8): end-to-end API throughput across the
paper's configs A/B/C (dim 8/32/64) at λ=0.5 and λ=1.0, plus the tiered
(config D analogue) key-side-vs-value-copy decomposition.

Reproduced structure: find* (pointer-returning / key-side only) is
dimension-INDEPENDENT; find (value copy) scales with dim; assign varies
little with λ (non-structural); insert_or_assign pays a bounded eviction
overhead at λ=1.0.

All ops run through the `HKVTable` handle; the inserter backend is part
of the handle (DESIGN.md §4):

    PYTHONPATH=src python -m benchmarks.exp2_throughput --backend kernel

'jnp' (default) times the pure-jnp batch closure; 'kernel' times the
Pallas paths (fused find_scan readers + upsert_scan inserters); 'fused'
runs the dedicated reader arm instead — per-λ fused-find timings, the
per-query work counters whose flat curve is the paper's λ-independence
claim, and a launch-accounting row vs the replaced digest_scan+gather
composition.  Off-TPU the kernels execute in interpret mode — the
numbers then measure the Python interpreter, not the hardware, so kernel
runs shrink the batch to stay tractable and are labelled accordingly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, fill_table, kv_per_s, make_insert_jit, time_fn
from repro.core import HKVTable, U64, u64

CAPACITY = 64 * 128
BATCH = 4096
CONFIGS = {"A": 8, "B": 32, "C": 64}


def _insert_batch(backend: str) -> int:
    """Interpret-mode kernels pay a per-grid-step Python cost off-TPU;
    keep the measured batch small enough to finish in seconds."""
    if backend == "kernel" and jax.default_backend() != "tpu":
        return 512
    return BATCH


def _fill(table, rng, lam, ins):
    n = int(lam * table.capacity)
    keys = rng.integers(0, 2**50, size=n).astype(np.uint64)
    return fill_table(table, keys, ins=ins), keys


def _count_launches(state, cfg, keys):
    """Trace-time kernel-launch accounting: invocations of the fused
    find_scan vs the digest_scan+gather composition it replaced.  The
    wrappers resolve these module attributes at call time, so swapping in
    counting shims (restored in `finally`) observes the real dispatch."""
    from repro.core import ops as core_ops
    from repro.kernels import digest_scan as _ds
    from repro.kernels import find_scan as _fs
    from repro.kernels import gather as _ga
    from repro.kernels import ops as kops

    slots = [(_fs, "find_scan_tlp"), (_fs, "find_scan_pipeline"),
             (_ds, "digest_scan_tlp"), (_ds, "digest_scan_pipeline"),
             (_ga, "gather_rows")]
    originals = {(m, n): getattr(m, n) for m, n in slots}
    counts = {"n": 0}

    def shim(orig):
        def f(*a, **kw):
            counts["n"] += 1
            return orig(*a, **kw)
        return f

    try:
        for m, n in slots:
            setattr(m, n, shim(originals[(m, n)]))
        core_ops.find(state, cfg, keys, backend="kernel")
        fused = counts["n"]
        counts["n"] = 0
        kops.find_composed_kernel(state, cfg, keys)
        composed = counts["n"]
    finally:
        for (m, n), v in originals.items():
            setattr(m, n, v)
    return fused, composed


def _fused_reader_run(csv: Csv, rng):
    """--backend fused: the PR-6 reader-path arm.  Times the single-pass
    fused find across load factors, reports its per-query work counters
    (the find-curve SHAPE: flat in λ), and counts kernel launches against
    the replaced digest_scan+gather composition."""
    from repro.core import find as find_mod
    from repro.core import ops as core_ops

    dim = 32
    batch = _insert_batch("kernel")     # interpret-mode tractable off-TPU
    ins = make_insert_jit()
    find_j = jax.jit(lambda t, h, l: t.find(U64(h, l)).values)
    work = {}
    for lam in (0.5, 0.75, 1.0):
        table = HKVTable.create(capacity=CAPACITY, dim=dim,
                                buckets_per_key=2, backend="jnp")
        table, keys = _fill(table, rng, lam, ins)
        table = table.with_backend("kernel")
        hot = u64.from_uint64(rng.choice(keys, size=batch))
        probe = find_mod.probe_keys(table.cfg, hot)
        # rows touched per query: candidate bucket rows actually scanned
        # (bucket2 can alias bucket1) + exactly ONE fused value row
        meta = 1.0 + float(np.asarray(probe.bucket2 != probe.bucket1).mean())
        work[lam] = meta + 1.0
        t = time_fn(find_j, table, hot.hi, hot.lo)
        r = core_ops.find(table.state, table.cfg, hot, backend="kernel")
        hit = float(np.asarray(r.found).mean())
        csv.row(f"fused-find/dim={dim}/lf={lam}", t,
                f"{kv_per_s(batch, t)/1e6:.2f}M-KV/s,"
                f"rows_per_find={work[lam]:.2f}(meta={meta:.2f}+value=1),"
                f"hit_rate={hit:.2f}")
    lo, hi = min(work.values()), max(work.values())
    csv.row("fused-find/curve-shape", None,
            f"work-variation={100 * (hi - lo) / lo:.1f}% over lf 0.5-1.0 "
            "[paper: find cost is λ-independent]")
    fused_l, composed_l = _count_launches(table.state, table.cfg, hot)
    csv.row("fused-find/launches", None,
            f"fused={fused_l},composed={composed_l},"
            f"eliminated={composed_l - fused_l}/find")
    return csv


def run(csv: Csv | None = None, backend: str = "jnp"):
    tag = "" if backend == "jnp" else (
        " [readers: fused find arm]" if backend == "fused"
        else f" [inserters backend={backend}]")
    csv = csv or Csv(f"Exp#2 API throughput (configs A-C, Figs. 7/8){tag}")
    rng = np.random.default_rng(1)
    if backend == "fused":
        return _fused_reader_run(csv, rng)
    ibatch = _insert_batch(backend)
    ins_shared = make_insert_jit()
    for name, dim in CONFIGS.items():
        for lam in (0.5, 1.0):
            # fill on the jnp backend (interpret-mode kernels would make
            # the fill dominate off-TPU), measure on the requested one
            table = HKVTable.create(capacity=CAPACITY, dim=dim, backend="jnp")
            table, keys = _fill(table, rng, lam, ins_shared)
            table = table.with_backend(backend)
            hot = u64.from_uint64(rng.choice(keys, size=BATCH))
            hot_i = u64.from_uint64(rng.choice(keys, size=ibatch))
            vals = jnp.asarray(rng.normal(size=(ibatch, dim)), jnp.float32)

            find_j = jax.jit(lambda t, h, l: t.find(U64(h, l)).values)
            findp_j = jax.jit(lambda t, h, l: t.find_ptr(U64(h, l)).row)
            cont_j = jax.jit(lambda t, h, l: t.contains(U64(h, l)))
            ins_j = jax.jit(
                lambda t, h, l, v: t.insert_or_assign(U64(h, l), v).table)
            ine_j = jax.jit(
                lambda t, h, l, v: t.insert_and_evict(U64(h, l), v).table)
            asg_j = jax.jit(lambda t, h, l, v: t.assign(U64(h, l), v))

            for api, fn, n, args in (
                ("find", find_j, BATCH, (table, hot.hi, hot.lo)),
                ("find_ptr", findp_j, BATCH, (table, hot.hi, hot.lo)),
                ("contains", cont_j, BATCH, (table, hot.hi, hot.lo)),
                ("insert_or_assign", ins_j, ibatch, (table, hot_i.hi, hot_i.lo, vals)),
                ("insert_and_evict", ine_j, ibatch, (table, hot_i.hi, hot_i.lo, vals)),
                ("assign", asg_j, ibatch, (table, hot_i.hi, hot_i.lo, vals)),
            ):
                t = time_fn(fn, *args)
                csv.row(f"{api}/cfg{name}(dim={dim})/lf={lam}", t,
                        f"{kv_per_s(n, t)/1e6:.2f}M-KV/s")

    # config D (paper Table 5): HBM keys + HMEM (host-tier) values. The
    # paper's claim: the pointer-returning find* is tier-INDEPENDENT (keys
    # never leave HBM); value-copying find pays the host link per row.
    from repro.core import table as table_mod

    tabled = HKVTable.create(capacity=CAPACITY, dim=64, value_tier="hmem",
                             backend="jnp")
    tabled, keys = _fill(tabled, rng, 1.0, ins_shared)
    # re-pin after the fill: each jitted insert returns a fresh values
    # array placed by XLA's default (device) memory, undoing the
    # create-time pinned_host placement the tier measurement needs
    tabled = tabled.with_state(table_mod.place_value_tier(tabled.state))
    tabled = tabled.with_backend(backend)
    hot = u64.from_uint64(rng.choice(keys, size=BATCH))
    findd_j = jax.jit(lambda t, h, l: t.find(U64(h, l)).values)
    findpd_j = jax.jit(lambda t, h, l: t.find_ptr(U64(h, l)).row)
    td = time_fn(findd_j, tabled, hot.hi, hot.lo)
    tpd = time_fn(findpd_j, tabled, hot.hi, hot.lo)
    csv.row("find/cfgD(dim=64,hmem)/lf=1.0", td,
            f"{kv_per_s(BATCH, td)/1e6:.2f}M-KV/s,values-cross-tier")
    csv.row("find_ptr/cfgD(dim=64,hmem)/lf=1.0", tpd,
            f"{kv_per_s(BATCH, tpd)/1e6:.2f}M-KV/s,key-side-only"
            f"[paper:96% of pure-HBM]")
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp",
                    choices=("auto", "jnp", "kernel", "fused"),
                    help="table-op backend (kernel = Pallas paths; fused = "
                         "the reader-path launch-accounting arm)")
    run(backend=ap.parse_args().backend)
