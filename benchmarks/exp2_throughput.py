"""Exp#2 (paper §5.3, Figs. 7/8): end-to-end API throughput across the
paper's configs A/B/C (dim 8/32/64) at λ=0.5 and λ=1.0, plus the tiered
(config D analogue) key-side-vs-value-copy decomposition.

Reproduced structure: find* (pointer-returning / key-side only) is
dimension-INDEPENDENT; find (value copy) scales with dim; assign varies
little with λ (non-structural); insert_or_assign pays a bounded eviction
overhead at λ=1.0.

The inserter ops run on a selectable backend (DESIGN.md §4):

    PYTHONPATH=src python -m benchmarks.exp2_throughput --backend kernel

'jnp' (default) times the pure-jnp batch closure; 'kernel' times the fused
Pallas upsert path.  Off-TPU the kernels execute in interpret mode — the
numbers then measure the Python interpreter, not the hardware, so kernel
runs shrink the batch to stay tractable and are labelled accordingly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, fill_table, kv_per_s, make_insert_jit, time_fn
from repro.core import find as find_mod
from repro.core import ops, table, u64

CAPACITY = 64 * 128
BATCH = 4096
CONFIGS = {"A": 8, "B": 32, "C": 64}


def _insert_batch(backend: str) -> int:
    """Interpret-mode kernels pay a per-grid-step Python cost off-TPU;
    keep the measured batch small enough to finish in seconds."""
    if backend == "kernel" and jax.default_backend() != "tpu":
        return 512
    return BATCH


def _fill(cfg, rng, lam, ins):
    state = table.create(cfg)
    n = int(lam * cfg.capacity)
    keys = rng.integers(0, 2**50, size=n).astype(np.uint64)
    state = fill_table(cfg, state, keys, cfg.dim, ins=ins)
    return state, keys


def run(csv: Csv | None = None, backend: str = "jnp"):
    tag = "" if backend == "jnp" else f" [inserters backend={backend}]"
    csv = csv or Csv(f"Exp#2 API throughput (configs A-C, Figs. 7/8){tag}")
    rng = np.random.default_rng(1)
    ibatch = _insert_batch(backend)
    for name, dim in CONFIGS.items():
        cfg = table.HKVConfig(capacity=CAPACITY, dim=dim)
        ins_shared = make_insert_jit(cfg)
        for lam in (0.5, 1.0):
            state, keys = _fill(cfg, rng, lam, ins_shared)
            hot = u64.from_uint64(rng.choice(keys, size=BATCH))
            hot_i = u64.from_uint64(rng.choice(keys, size=ibatch))
            vals = jnp.asarray(rng.normal(size=(ibatch, dim)), jnp.float32)

            find_j = jax.jit(lambda s, h, l: ops.find(s, cfg, u64.U64(h, l)).values)
            findp_j = jax.jit(lambda s, h, l: find_mod.locate(s, cfg, u64.U64(h, l)).row)
            cont_j = jax.jit(lambda s, h, l: ops.contains(s, cfg, u64.U64(h, l)))
            ins_j = jax.jit(
                lambda s, h, l, v: ops.insert_or_assign(
                    s, cfg, u64.U64(h, l), v, backend=backend).state
            )
            ine_j = jax.jit(
                lambda s, h, l, v: ops.insert_and_evict(
                    s, cfg, u64.U64(h, l), v, backend=backend).state
            )
            asg_j = jax.jit(lambda s, h, l, v: ops.assign(s, cfg, u64.U64(h, l), v))

            for api, fn, n, args in (
                ("find", find_j, BATCH, (state, hot.hi, hot.lo)),
                ("find_ptr", findp_j, BATCH, (state, hot.hi, hot.lo)),
                ("contains", cont_j, BATCH, (state, hot.hi, hot.lo)),
                ("insert_or_assign", ins_j, ibatch, (state, hot_i.hi, hot_i.lo, vals)),
                ("insert_and_evict", ine_j, ibatch, (state, hot_i.hi, hot_i.lo, vals)),
                ("assign", asg_j, ibatch, (state, hot_i.hi, hot_i.lo, vals)),
            ):
                t = time_fn(fn, *args)
                csv.row(f"{api}/cfg{name}(dim={dim})/lf={lam}", t,
                        f"{kv_per_s(n, t)/1e6:.2f}M-KV/s")

    # config D (paper Table 5): HBM keys + HMEM (host-tier) values. The
    # paper's claim: the pointer-returning find* is tier-INDEPENDENT (keys
    # never leave HBM); value-copying find pays the host link per row.
    import dataclasses as _dc

    from repro.core import table as table_mod

    cfgd = table.HKVConfig(capacity=CAPACITY, dim=64, value_tier="hmem")
    state, keys = _fill(cfgd, rng, 1.0, make_insert_jit(cfgd))
    state = table_mod.place_value_tier(state)
    hot = u64.from_uint64(rng.choice(keys, size=BATCH))
    findd_j = jax.jit(lambda s, h, l: ops.find(s, cfgd, u64.U64(h, l)).values)
    findpd_j = jax.jit(lambda s, h, l: find_mod.locate(s, cfgd, u64.U64(h, l)).row)
    td = time_fn(findd_j, state, hot.hi, hot.lo)
    tpd = time_fn(findpd_j, state, hot.hi, hot.lo)
    csv.row("find/cfgD(dim=64,hmem)/lf=1.0", td,
            f"{kv_per_s(BATCH, td)/1e6:.2f}M-KV/s,values-cross-tier")
    csv.row("find_ptr/cfgD(dim=64,hmem)/lf=1.0", tpd,
            f"{kv_per_s(BATCH, tpd)/1e6:.2f}M-KV/s,key-side-only"
            f"[paper:96% of pure-HBM]")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp", choices=("auto", "jnp", "kernel"),
                    help="inserter-op backend (kernel = fused Pallas upsert path)")
    run(backend=ap.parse_args().backend)
