"""Obs experiment: the unified metrics registry as a bench artifact.

Runs a small instrumented workload — fill an HKV table to a couple of
load-factor points, then drive telemetry-on `find` + `insert_or_assign`
batches through a `TelemetrySink` — and folds the resulting
`MetricsRegistry` snapshot (accumulated `OpTelemetry` counters, derived
rates, and end-state `TableStats`) into the standard Csv rows, so
`benchmarks/run.py --json-out` lands the whole gauge set in the
`BENCH_obs.json` trajectory artifact alongside the perf experiments.

The row format reuses the `name,us_per_call,derived` contract with the
gauge value in `derived` (`gauge=<value>`); us_per_call stays empty —
these are counters, not timings.  The λ-flatness headline (probe count
independent of load factor) is therefore checkable straight off the
trajectory: compare `lf*.op.find.probes_per_query` rows across commits.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, fill_batches
from repro.core import HKVTable, u64
from repro.obs import MetricsRegistry, TelemetrySink

CAPACITY = 64 * 128
DIM = 16
BATCH = 2048
LAMBDAS = (0.5, 1.0)


def _instrumented_point(target_lf: float, rng, *, smoke: bool):
    """Fill to `target_lf`, then run telemetry-on find over the live keys."""
    capacity = CAPACITY // 4 if smoke else CAPACITY
    table = HKVTable.create(capacity=capacity, dim=DIM, backend="jnp")
    sink = TelemetrySink()
    n = int(target_lf * capacity)
    keys = rng.integers(1, 2**50, size=n).astype(np.uint64)
    zeros = jnp.zeros((BATCH, DIM), jnp.float32)
    for kb in fill_batches(keys, BATCH):
        k = u64.from_uint64(kb)
        table = table.insert_or_assign(k, zeros, telemetry=sink).table
    for kb in fill_batches(keys[: min(n, 4 * BATCH)], BATCH):
        k = u64.from_uint64(kb)
        table.find(k, telemetry=sink)
    return table, sink


def run(smoke: bool = False, csv: Csv | None = None):
    csv = csv or Csv("Obs: metrics-registry snapshot "
                     "(telemetry counters as trajectory gauges)")
    rng = np.random.default_rng(7)
    for lam in LAMBDAS:
        table, sink = _instrumented_point(lam, rng, smoke=smoke)
        reg = MetricsRegistry()
        reg.observe_telemetry(sink)
        reg.observe_table(table.stats())
        find = sink.by_op["find"].rates()
        csv.row(f"lf{lam:.2f}.op.find.probes_per_query", None,
                f"gauge={find['probes_per_query']:.4f}")
        csv.row(f"lf{lam:.2f}.op.find.digest_pass_rate", None,
                f"gauge={find['digest_pass_rate']:.4f}")
        csv.row(f"lf{lam:.2f}.op.find.hit_rate", None,
                f"gauge={find['hit_rate']:.4f}")
        for name, value in sorted(reg.snapshot().items()):
            csv.row(f"lf{lam:.2f}.{name}", None, f"gauge={value:g}")
    return csv
