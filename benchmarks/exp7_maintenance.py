"""Exp#7: wave-interleaved maintenance — serving QPS + hit rate with the
MaintenanceScheduler on/off × sweep budget (zipf workload).

The claim under test (DESIGN.md §Maintenance): moving eviction work off
the serving path is free or better.  A tiered table served under the
'admit' policy demotes REACTIVELY — every hot-tier admission at λ=1.0
evicts a victim and upserts it cold-side inside the wave.  With the
scheduler running a watermark rebalance between waves, the same demotion
work happens proactively under a budget, so waves find hot headroom:

  hit rate    must be equal-or-better at the same hot capacity (demoted
              entries stay resident cold-side — nothing leaves the
              hierarchy that reactive eviction would have kept);
  reactive demotions / wave   must strictly decrease (the acceptance
              bar: the work MOVED, it did not vanish — the scheduler's
              own `totals.demoted` shows where it went);
  p99 wave latency            reported per cell (the serving-path cost
              the reactive demotions were inflating).

Swept: scheduler off vs on at each sweep budget; zipf α=1.05 over a
working set ~2x the cold capacity (the exp5/exp6 nothing-fits regime).

    PYTHONPATH=src python -m benchmarks.exp7_maintenance            # full
    PYTHONPATH=src python -m benchmarks.exp7_maintenance --smoke    # CI
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Csv
from repro.core import TieredHKVTable
from repro.data import zipf_keys
from repro.maintenance import MaintenancePolicy, MaintenanceScheduler
from repro.serving import EmbeddingRequest, OnlineEmbeddingEngine

DIM = 16
ALPHA = 1.05
LOW, HIGH = 0.6, 0.85
FULL = dict(cold_capacity=32 * 128, hot_capacity=8 * 128, wave=1024,
            waves=32, budgets=(256, 1024))
SMOKE = dict(cold_capacity=8 * 128, hot_capacity=2 * 128, wave=256,
             waves=12, budgets=(64, 256))


def _drive(p, stream, budget):
    """One engine replay at a fixed hot capacity; budget=None = scheduler
    off.  Returns (metrics, scheduler_totals | None)."""
    table = TieredHKVTable.create(hot_capacity=p["hot_capacity"],
                                  cold_capacity=p["cold_capacity"], dim=DIM)
    sched = None
    if budget is not None:
        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=1, sweep_budget=budget,
            low_watermark=LOW, high_watermark=HIGH))
    eng = OnlineEmbeddingEngine(table, wave_size=p["wave"],
                                miss_policy="admit", scheduler=sched)
    wave = p["wave"]
    for i in range(p["waves"]):
        eng.submit(EmbeddingRequest(
            rid=i, keys=stream[i * wave:(i + 1) * wave]))
        eng.step()
    half = eng.reports[p["waves"] // 2:]
    keys = sum(r.size for r in half)
    hits = sum(r.hits for r in half)
    secs = sum(r.latency_s for r in half)
    dem = sum(r.demotions for r in half) / max(len(half), 1)
    m = eng.metrics()
    steady = dict(hit_rate=hits / max(keys, 1),
                  qps=keys / max(secs, 1e-12),
                  dem_per_wave=dem, p99=m.p99_latency_s)
    return steady, (sched.totals if sched else None)


def run(csv: Csv | None = None, smoke: bool = False) -> Csv:
    p = SMOKE if smoke else FULL
    tag = " [smoke]" if smoke else ""
    csv = csv or Csv(
        f"Exp#7 maintenance: serving QPS & hit rate, scheduler on/off x "
        f"sweep budget (zipf α={ALPHA}, admit policy){tag}")
    rng = np.random.default_rng(7)
    n = p["wave"] * p["waves"]
    stream = zipf_keys(rng, n, ALPHA, 2 * p["cold_capacity"])

    off, _ = _drive(p, stream, None)
    csv.row("sched_off/hit_rate", None, f"{off['hit_rate']*100:.1f}%")
    csv.row("sched_off/qps", None, f"{off['qps']/1e6:.2f}M-KV/s",
            kv_s=off["qps"])
    csv.row("sched_off/reactive_dem_per_wave", None,
            f"{off['dem_per_wave']:.1f}")
    csv.row("sched_off/p99_wave_s", None, f"{off['p99']*1e3:.2f}ms")

    for budget in p["budgets"]:
        cell = f"sched_on(budget={budget})"
        on, totals = _drive(p, stream, budget)
        csv.row(f"{cell}/hit_rate", None,
                f"{on['hit_rate']*100:.1f}%,"
                f"delta={(on['hit_rate']-off['hit_rate'])*100:+.1f}pp")
        csv.row(f"{cell}/qps", None, f"{on['qps']/1e6:.2f}M-KV/s",
                kv_s=on["qps"])
        csv.row(f"{cell}/reactive_dem_per_wave", None,
                f"{on['dem_per_wave']:.1f},off={off['dem_per_wave']:.1f}")
        csv.row(f"{cell}/p99_wave_s", None, f"{on['p99']*1e3:.2f}ms")
        csv.row(f"{cell}/proactive_moves", None,
                f"demoted={totals.demoted},dropped={totals.dropped},"
                f"time={totals.time_s*1e3:.0f}ms")
        # the acceptance bar, visible in the artifact: demotions moved
        # off the upsert path, hit rate no worse
        ok = (on["dem_per_wave"] < off["dem_per_wave"]
              and on["hit_rate"] >= off["hit_rate"] - 1e-9)
        csv.row(f"{cell}/acceptance", None,
                "PASS" if ok else "FAIL")
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for the CI artifact run")
    run(smoke=ap.parse_args().smoke)
