"""Exp#3 (paper §5.4): component ablations.

  3a  digest pre-filter on/off (Table 7): speedup from the one-cache-line
      candidate filter vs full 128-key compares.
  3b  eviction overhead (λ=0.5 vs λ=1.0 insert_or_assign) — bounded,
      because the eviction scan is always exactly one 128-slot bucket.
  3c  cache hit rate by scoring policy x Zipf α (Table 8).
  3d  admission-control burst (Table 9): low-score burst fully rejected
      (Δhit = 0), high-score burst displaces residents.
  3e  triple-group concurrency adaptation (Exp#3e): reader+updater ops
      FUSED into one jitted program (the role split lets XLA overlap them)
      vs serialized separate dispatches — plus the op-session planner,
      which additionally shares ONE locate across the commuting pair.
  3f  upsert backend (DESIGN.md §4): insert_or_assign throughput on the
      pure-jnp batch closure vs the fused Pallas upsert path.  Off-TPU the
      kernel executes in interpret mode, so 3f reports it as a correctness
      checkpoint (statuses must agree), not a wall-clock comparison.

All table traffic goes through the `HKVTable` handle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, fill_batches, fill_table, kv_per_s, \
    make_insert_jit, time_fn
from repro.core import HKVTable, U64, u64
from repro.data import zipf_keys

CAPACITY = 64 * 128
BATCH = 4096


def _fill_full(table, rng):
    keys = rng.integers(0, 2**50, size=2 * table.capacity).astype(np.uint64)
    return fill_table(table, keys), keys


def run(csv: Csv | None = None):
    csv = csv or Csv("Exp#3 component ablations (Tables 7/8/9 + concurrency)")
    rng = np.random.default_rng(2)

    # ---- 3a: digest contribution ---------------------------------------------
    # The paper's Table-7 speedup is a MEMORY-TRANSACTION effect: a miss
    # with the digest costs one 128 B digest row + fp_rate expected full-key
    # reads; without it, both 4 B key planes of all 128 slots load (1 KiB).
    # The pure-jnp CPU path computes both compares regardless (no I/O to
    # save), so we report the structural I/O ratio driven by the MEASURED
    # false-positive rate, and validate fp_rate == 1/256 per slot.
    for lam_name, lam in (("0.50", 0.5), ("1.00", 1.0)):
        table = HKVTable.create(capacity=CAPACITY, dim=32)
        n = int(lam * CAPACITY)
        keys = rng.integers(0, 2**50, size=n).astype(np.uint64)
        table = fill_table(table, keys)
        q = u64.from_uint64(rng.integers(0, 2**51, size=BATCH).astype(np.uint64))
        probe = table.probe_keys(q)
        drow = np.asarray(table.state.digests)[np.asarray(probe.bucket1)]
        fp = float((drow == np.asarray(probe.digest)[:, None]).sum(axis=1).mean())
        s = table.cfg.slots_per_bucket
        bytes_with = s * 1 + fp * 8          # digest row + fp full keys
        bytes_without = s * 8                # both uint32 key planes
        csv.row(f"3a/digest/lf={lam_name}", None,
                f"fp_per_miss={fp:.2f}[paper:~{lam*s/256:.2f}],"
                f"io_reduction={bytes_without/bytes_with:.2f}x"
                f"[paper wall-clock:1.65-2.61x on H100]")

    # ---- 3b: eviction overhead -----------------------------------------------
    ins_j = make_insert_jit()
    for lam in (0.5, 1.0):
        table = HKVTable.create(capacity=CAPACITY, dim=32)
        n = int(lam * CAPACITY)
        keys = rng.integers(0, 2**50, size=max(n, 1)).astype(np.uint64)
        table = fill_table(table, keys, ins=ins_j)
        fresh = u64.from_uint64(rng.integers(2**51, 2**52, size=BATCH).astype(np.uint64))
        t = time_fn(ins_j, table, fresh.hi, fresh.lo, jnp.zeros((BATCH, 32)))
        csv.row(f"3b/insert/lf={lam}", t, f"{kv_per_s(BATCH, t)/1e6:.2f}M-KV/s")

    # ---- 3c: hit rate by policy x zipf alpha (Table 8) ------------------------
    for policy in ("lru", "lfu", "epoch_lru", "epoch_lfu"):
        for alpha in (0.50, 0.75, 0.99, 1.25):
            table = HKVTable.create(capacity=32 * 128, dim=4,
                                    score_policy=policy)
            ins_p = make_insert_jit()
            con_p = jax.jit(lambda t, h, l: t.contains(U64(h, l)))
            zeros4 = jnp.zeros((2048, 4), jnp.float32)
            rng_a = np.random.default_rng(42)
            hits = total = 0
            steps = 40
            key_space = 16 * table.capacity
            for step in range(steps):
                keys = zipf_keys(rng_a, 2048, alpha, key_space)
                k = u64.from_uint64(keys)
                if step >= steps // 2:  # measure after warm-up
                    found = np.asarray(con_p(table, k.hi, k.lo))
                    hits += int(found.sum())
                    total += len(keys)
                table = ins_p(table, k.hi, k.lo, zeros4)
            csv.row(f"3c/hit_rate/{policy}/alpha={alpha}", None,
                    f"{100*hits/max(total,1):.1f}%")

    # ---- 3d: admission control burst (Table 9) --------------------------------
    table = HKVTable.create(capacity=32 * 128, dim=4, score_policy="custom")
    resident = rng.integers(0, 2**40, size=3 * table.capacity).astype(np.uint64)
    ins_c = jax.jit(lambda t, h, l, v, sh, sl: t.insert_or_assign(
        U64(h, l), v, custom_scores=U64(sh, sl)).table)
    sc1000 = u64.from_uint64(np.full(4096, 1000, np.uint64))
    for kb in fill_batches(resident, 4096):
        k = u64.from_uint64(kb)
        table = ins_c(table, k.hi, k.lo, jnp.zeros((4096, 4)),
                      sc1000.hi, sc1000.lo)
    probe = rng.choice(resident, size=2048)
    pre = float(np.asarray(table.contains(probe)).mean())
    burst = rng.integers(2**41, 2**42, size=1024).astype(np.uint64)
    for score, label in ((1, "low"), (10**9, "high")):
        r = table.insert_or_assign(
            burst, jnp.zeros((1024, 4)),
            custom_scores=np.full(1024, score, np.uint64),
        )
        post = float(np.asarray(r.table.contains(probe)).mean())
        admitted = float(np.isin(np.asarray(r.status), (2, 3)).mean())
        csv.row(f"3d/burst/{label}_score", None,
                f"admitted={admitted*100:.0f}%,dhit={100*(post-pre):+.2f}pp")

    # ---- 3e: role-fused vs serialized dispatch --------------------------------
    table = HKVTable.create(capacity=CAPACITY, dim=16)
    table, keys = _fill_full(table, rng)
    ra = u64.from_uint64(rng.choice(keys[-CAPACITY:], size=BATCH))
    vals = jnp.asarray(rng.normal(size=(BATCH, 16)), jnp.float32)

    def fused(t, ah, al, v):
        # reader + updater in ONE program: the non-structural role contract
        # means XLA may interleave/overlap them freely
        out = t.find(U64(ah, al)).values
        t2 = t.assign(U64(ah, al), v)
        return out, t2

    def session_fused(t, ah, al, v):
        # the op-session planner: same two ops, one shared locate
        k = U64(ah, al)
        s = t.session()
        hit = s.find(k)
        s.assign(k, v)
        t2 = s.commit()
        return hit.get().values, t2

    fused_j = jax.jit(fused)
    sess_j = jax.jit(session_fused)
    find_j = jax.jit(lambda t, h, l: t.find(U64(h, l)).values)
    asg_j = jax.jit(lambda t, h, l, v: t.assign(U64(h, l), v))

    tf = time_fn(fused_j, table, ra.hi, ra.lo, vals)
    tss = time_fn(sess_j, table, ra.hi, ra.lo, vals)

    def serialized(t):
        out = find_j(t, ra.hi, ra.lo)
        t2 = asg_j(t, ra.hi, ra.lo, vals)
        return out, t2

    ts = time_fn(serialized, table)
    csv.row("3e/reader+updater/fused", tf, f"{kv_per_s(2*BATCH, tf)/1e6:.2f}M-op/s")
    csv.row("3e/reader+updater/session(one-locate)", tss,
            f"{kv_per_s(2*BATCH, tss)/1e6:.2f}M-op/s")
    csv.row("3e/reader+updater/serialized", ts,
            f"{kv_per_s(2*BATCH, ts)/1e6:.2f}M-op/s,fused_speedup={ts/tf:.2f}x,"
            f"session_speedup={ts/tss:.2f}x")

    # ---- 3f: upsert backend (jnp batch closure vs fused Pallas path) ----------
    on_tpu = jax.default_backend() == "tpu"
    n3f = 1024 if on_tpu else 256  # interpret mode: keep the grid small
    table = HKVTable.create(capacity=8 * 128, dim=16)
    keys3f = u64.from_uint64(rng.integers(0, 2**50, size=n3f).astype(np.uint64))
    vals3f = jnp.asarray(rng.normal(size=(n3f, 16)), jnp.float32)
    results = {}
    for backend in ("jnp", "kernel"):
        tb = table.with_backend(backend)
        fn = jax.jit(lambda t, h, l, v: t.insert_or_assign(U64(h, l), v).status)
        t = time_fn(fn, tb, keys3f.hi, keys3f.lo, vals3f, reps=3, warmup=1)
        results[backend] = (t, np.asarray(fn(tb, keys3f.hi, keys3f.lo, vals3f)))
        mode = "xla" if (backend == "jnp" or on_tpu) else "interpret"
        csv.row(f"3f/upsert_backend/{backend}", t,
                f"{kv_per_s(n3f, t)/1e6:.2f}M-KV/s[{mode}]")
    agree = np.array_equal(results["jnp"][1], results["kernel"][1])
    csv.row("3f/upsert_backend/status_parity", None, f"bit_identical={agree}")
    return csv


if __name__ == "__main__":
    run()
