"""Benchmark entry point: one experiment per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                   # all experiments
  PYTHONPATH=src python -m benchmarks.run exp1 exp4         # subset
  PYTHONPATH=src python -m benchmarks.run exp2 --backend kernel
  PYTHONPATH=src python -m benchmarks.run exp5 exp6_online exp7_maintenance \
      --smoke --json-out runs/bench --timestamp 2026-07-26T00:00:00Z

Output: `name,us_per_call,derived` CSV blocks per experiment on stdout.
`roofline` emits the fused find/update bytes models + distance-to-roofline
against any BENCH_exp2.json in the --json-out dir (dry-run step terms ride
along when runs/dryrun/ artifacts exist).  `exp9_train_apply` measures
end-to-end DLRM train steps/sec under the fused vs composed updater arms
per optimizer variant, with kernel launch/byte deltas.  --backend selects the table-op
implementation for exp2 (DESIGN.md §4); `fused` adds the reader-path
launch-accounting arm on top of the kernel backend.

Trajectory artifacts: with `--json-out DIR`, each experiment additionally
writes `DIR/BENCH_<exp>.json` in the stable `bench-trajectory/v1` schema —
{schema, experiment, title, commit, timestamp, rows[{name, us_per_call,
derived, kv_per_s}]} — so successive CI runs accumulate a comparable perf
trajectory.  The timestamp is PASSED IN (the driver owns the clock; runs
are reproducible byte-for-byte given the same tree), and the commit is
taken from $BENCH_COMMIT or `git rev-parse HEAD`.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys


def _commit() -> str:
    c = os.environ.get("BENCH_COMMIT")
    if c:
        return c
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def _pop_flag(args: list, flag: str, *, takes_value: bool = True):
    if flag not in args:
        return None if takes_value else False
    i = args.index(flag)
    if not takes_value:
        del args[i]
        return True
    if i + 1 >= len(args):
        sys.exit(f"error: {flag} requires a value")
    v = args[i + 1]
    del args[i : i + 2]
    return v


def main() -> None:
    args = sys.argv[1:]
    backend = _pop_flag(args, "--backend") or "jnp"
    if backend not in ("auto", "jnp", "kernel", "fused"):
        sys.exit("error: --backend requires one of auto|jnp|kernel|fused")
    json_out = _pop_flag(args, "--json-out")
    timestamp = _pop_flag(args, "--timestamp")
    smoke = _pop_flag(args, "--smoke", takes_value=False)
    if json_out and not timestamp:
        sys.exit("error: --json-out requires --timestamp (the driver passes "
                 "the clock in; artifacts never read one)")
    known = {"exp1", "exp2", "exp3", "exp4", "exp5", "exp6_online",
             "exp7_maintenance", "exp9_train_apply", "roofline", "obs"}
    bad = [a for a in args if a not in known]
    if bad:
        sys.exit(f"error: unknown argument(s) {bad}; experiments: {sorted(known)}, "
                 "options: --backend auto|jnp|kernel|fused --smoke "
                 "--json-out DIR --timestamp TS")
    if backend != "jnp" and args and "exp2" not in args:
        sys.exit("error: --backend only applies to exp2; add exp2 to the "
                 "selection or drop the flag")
    if smoke and args and not ({"exp5", "exp6_online",
                                "exp7_maintenance", "obs"} & set(args)):
        sys.exit("error: --smoke only applies to exp5/exp6_online/"
                 "exp7_maintenance/obs; add one to the selection or drop "
                 "the flag")
    sel = set(args)
    commit = _commit() if json_out else ""

    def want(name):
        return not sel or name in sel

    def emit(name, csv):
        if not json_out or csv is None:
            return
        os.makedirs(json_out, exist_ok=True)
        path = os.path.join(json_out, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(csv.to_json(name, commit=commit, timestamp=timestamp),
                      f, indent=1)
        print(f"# wrote {path}")

    if want("exp1"):
        from benchmarks import exp1_load_factor

        emit("exp1", exp1_load_factor.run())
    if want("exp2"):
        from benchmarks import exp2_throughput

        emit("exp2", exp2_throughput.run(backend=backend))
    if want("exp3"):
        from benchmarks import exp3_ablation

        emit("exp3", exp3_ablation.run())
    if want("exp4"):
        from benchmarks import exp4_dual_bucket

        emit("exp4", exp4_dual_bucket.run())
    if want("exp5"):
        from benchmarks import exp5_tiered

        emit("exp5", exp5_tiered.run(smoke=bool(smoke)))
    if want("exp6_online"):
        from benchmarks import exp6_online

        emit("exp6_online", exp6_online.run(smoke=bool(smoke)))
    if want("exp7_maintenance"):
        from benchmarks import exp7_maintenance

        emit("exp7_maintenance", exp7_maintenance.run(smoke=bool(smoke)))
    if want("exp9_train_apply"):
        from benchmarks import exp9_train_apply

        emit("exp9_train_apply", exp9_train_apply.run())
    if want("obs"):
        from benchmarks import exp_obs

        emit("obs", exp_obs.run(smoke=bool(smoke)))
    if want("roofline"):
        from benchmarks import roofline

        # read exp2 artifacts from the SAME --json-out dir when set, so a
        # single invocation's distance rows reflect the run it just wrote
        emit("roofline", roofline.run(bench_dir=json_out or "runs/bench"))


if __name__ == "__main__":
    main()
