"""Benchmark entry point: one experiment per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                   # all experiments
  PYTHONPATH=src python -m benchmarks.run exp1 exp4         # subset
  PYTHONPATH=src python -m benchmarks.run exp2 --backend kernel

Output: `name,us_per_call,derived` CSV blocks per experiment.  Roofline
rows appear when dry-run artifacts exist under runs/dryrun/.  --backend
selects the inserter-op implementation for exp2 (DESIGN.md §4).
"""

from __future__ import annotations

import sys


def main() -> None:
    args = sys.argv[1:]
    backend = "jnp"
    if "--backend" in args:
        i = args.index("--backend")
        if i + 1 >= len(args) or args[i + 1] not in ("auto", "jnp", "kernel"):
            sys.exit("error: --backend requires one of auto|jnp|kernel")
        backend = args[i + 1]
        del args[i : i + 2]
    known = {"exp1", "exp2", "exp3", "exp4", "roofline"}
    bad = [a for a in args if a not in known]
    if bad:
        sys.exit(f"error: unknown argument(s) {bad}; experiments: {sorted(known)}, "
                 "options: --backend auto|jnp|kernel")
    if backend != "jnp" and args and "exp2" not in args:
        sys.exit("error: --backend only applies to exp2; add exp2 to the "
                 "selection or drop the flag")
    sel = set(args)

    def want(name):
        return not sel or name in sel

    if want("exp1"):
        from benchmarks import exp1_load_factor

        exp1_load_factor.run()
    if want("exp2"):
        from benchmarks import exp2_throughput

        exp2_throughput.run(backend=backend)
    if want("exp3"):
        from benchmarks import exp3_ablation

        exp3_ablation.run()
    if want("exp4"):
        from benchmarks import exp4_dual_bucket

        exp4_dual_bucket.run()
    if want("roofline"):
        import os

        from benchmarks import roofline

        if os.path.isdir("runs/dryrun/single"):
            roofline.run(mesh="single")
        if os.path.isdir("runs/dryrun/multi"):
            roofline.run(mesh="multi")


if __name__ == "__main__":
    main()
