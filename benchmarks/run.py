"""Benchmark entry point: one experiment per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all experiments
  PYTHONPATH=src python -m benchmarks.run exp1 exp4  # subset

Output: `name,us_per_call,derived` CSV blocks per experiment.  Roofline
rows appear when dry-run artifacts exist under runs/dryrun/.
"""

from __future__ import annotations

import sys


def main() -> None:
    sel = set(sys.argv[1:])

    def want(name):
        return not sel or name in sel

    if want("exp1"):
        from benchmarks import exp1_load_factor

        exp1_load_factor.run()
    if want("exp2"):
        from benchmarks import exp2_throughput

        exp2_throughput.run()
    if want("exp3"):
        from benchmarks import exp3_ablation

        exp3_ablation.run()
    if want("exp4"):
        from benchmarks import exp4_dual_bucket

        exp4_dual_bucket.run()
    if want("roofline"):
        import os

        from benchmarks import roofline

        if os.path.isdir("runs/dryrun/single"):
            roofline.run(mesh="single")
        if os.path.isdir("runs/dryrun/multi"):
            roofline.run(mesh="multi")


if __name__ == "__main__":
    main()
