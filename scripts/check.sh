#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): run the full test suite from a
# fresh checkout, deterministically.
#
#   scripts/check.sh            # tier-1: pytest -x -q (full suite)
#   scripts/check.sh --fast     # CI gate: skip @pytest.mark.slow tests
#   scripts/check.sh -q tests/  # any extra pytest args pass through
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${1:-}" = "--fast" ]; then
    shift
    exec python -m pytest -x -q -m "not slow" "$@"
fi
if [ "$#" -gt 0 ]; then
    exec python -m pytest "$@"
fi
exec python -m pytest -x -q
