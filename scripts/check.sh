#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): run the full test suite from a
# fresh checkout, deterministically.
#
#   scripts/check.sh            # tier-1: pytest -x -q (full suite)
#   scripts/check.sh --fast     # CI gate: skip @pytest.mark.slow tests,
#                               # with a coverage floor when pytest-cov
#                               # is installed (requirements-dev.txt)
#   scripts/check.sh --analyze  # hkv-lint static contract checks
#                               # (python -m repro.analysis); extra args
#                               # pass through (e.g. --format github)
#   scripts/check.sh -q tests/  # any extra pytest args pass through
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${1:-}" = "--analyze" ]; then
    shift
    # Exit status = number of unwaived findings, so CI gates directly.
    exec python -m repro.analysis "$@"
fi
if [ "${1:-}" = "--fast" ]; then
    shift
    # Trace-export smoke: serve a few waves with the span tracer wired
    # and validate the Chrome trace-event JSON schema (ph/ts/name on
    # every event) — the observability stack's end-to-end gate.  The
    # trace lands in runs/trace/ so CI can upload it as an artifact
    # next to the bench-trajectory JSONs.
    mkdir -p runs/trace
    python -m repro.launch.serve --smoke --waves 4 --wave-size 64 \
        --maintain --trace-out runs/trace/serve_trace.json \
        --metrics-out runs/trace/serve_metrics.prom
    python - <<'PY'
import json
doc = json.load(open("runs/trace/serve_trace.json"))
evs = doc["traceEvents"]
assert evs, "trace smoke produced no events"
for ev in evs:
    assert "ph" in ev and "ts" in ev and "name" in ev, f"bad event: {ev}"
print(f"trace smoke OK: {len(evs)} events")
PY
    # Coverage gate: floor is a RATCHET (raise it when coverage rises,
    # never lower it to make a PR pass).  Where pytest-cov is absent
    # (minimal containers) the gate degrades to plain pytest — CI always
    # installs it, so the floor is enforced on every push.  The floor
    # only applies to the FULL fast suite: with extra args (a subset
    # selection) coverage would be trivially low, so it is skipped.
    if [ "$#" -eq 0 ] && python -c "import pytest_cov" >/dev/null 2>&1; then
        exec python -m pytest -x -q -m "not slow" \
            --cov=repro --cov-report=term --cov-report=xml:coverage.xml \
            --cov-fail-under=67
    fi
    exec python -m pytest -x -q -m "not slow" "$@"
fi
if [ "$#" -gt 0 ]; then
    exec python -m pytest "$@"
fi
exec python -m pytest -x -q
