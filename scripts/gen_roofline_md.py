"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run artifacts.

  PYTHONPATH=src python scripts/gen_roofline_md.py [runs/dryrun]
"""

import json
import os
import sys

sys.path.insert(0, "src")

from benchmarks.roofline import load_cells, terms  # noqa: E402
from repro.configs import get_arch  # noqa: E402

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "gemma-2b", "h2o-danube-1.8b", "qwen2-0.5b", "yi-6b",
    "llama4-maverick-400b-a17b", "moonshot-v1-16b-a3b", "zamba2-1.2b",
    "qwen2-vl-2b", "musicgen-medium", "xlstm-1.3b",
]


def fmt_sec(x):
    return f"{x*1e3:.2f}ms" if x >= 1e-3 else f"{x*1e6:.0f}us"


def main(out_dir="runs/dryrun"):
    for mesh in ("single", "multi"):
        cells = {(r["arch"], r["shape"], r.get("backend", "dense")): r
                 for r in load_cells(out_dir, mesh)}
        if not cells:
            continue
        print(f"\n### Dry-run grid — mesh `{mesh}` "
              f"({'2x16x16=512' if mesh=='multi' else '16x16=256'} chips)\n")
        print("| arch | shape | status | compile | FLOPs/dev | mem/dev | "
              "wire-bytes/dev | collectives |")
        print("|---|---|---|---|---|---|---|---|")
        for a in ARCH_ORDER:
            for s in SHAPE_ORDER:
                for backend in ("dense", "hkv"):
                    r = cells.get((a, s, backend))
                    if r is None:
                        continue
                    tag = f"{a}" + (" +hkv-emb" if backend == "hkv" else "")
                    if "skipped" in r:
                        print(f"| {tag} | {s} | SKIP({r['skipped'][:24]}) | | | | | |")
                        continue
                    if "error" in r:
                        print(f"| {tag} | {s} | ERROR | | | | | "
                              f"{r['error'][:40]} |")
                        continue
                    colls = ",".join(
                        f"{k.split('-')[1] if '-' in k else k}:{v['count']}"
                        for k, v in sorted(r["collectives"].items())
                    )
                    print(
                        f"| {tag} | {s} | ok | {r['compile_s']:.0f}s "
                        f"| {r['cost']['flops_per_device']:.2e} "
                        f"| {r['memory']['peak_estimate_per_device']/2**30:.1f}GiB "
                        f"| {r['collective_wire_bytes_per_device']/2**20:.0f}MiB "
                        f"| {colls} |"
                    )
        if mesh != "single":
            continue
        print(f"\n### Roofline terms — mesh `{mesh}` (per training/serving step)\n")
        print("| arch | shape | compute | memory | collective | bound | "
              "MODEL/HLO flops | note |")
        print("|---|---|---|---|---|---|---|---|")
        for a in ARCH_ORDER:
            arch = get_arch(a)
            for s in SHAPE_ORDER:
                for backend in ("dense", "hkv"):
                    r = cells.get((a, s, backend))
                    if r is None or "skipped" in r or "error" in r:
                        if r is not None and "skipped" in r:
                            print(f"| {a} | {s} | | | | SKIP | | {r['skipped'][:30]} |")
                        continue
                    t = terms(r, arch)
                    note = ""
                    if t["model_hlo_ratio"] > 1.5:
                        note = "HLO undercounts loops; analytic used"
                    elif t["model_hlo_ratio"] < 0.7:
                        note = f"HLO/model={1/max(t['model_hlo_ratio'],1e-9):.1f}x (remat/overhead)"
                    tag = f"{a}" + (" +hkv" if backend == "hkv" else "")
                    print(
                        f"| {tag} | {s} | {fmt_sec(t['compute_s'])} "
                        f"| {fmt_sec(t['memory_s'])} | {fmt_sec(t['collective_s'])} "
                        f"| **{t['dominant']}** | {t['model_hlo_ratio']:.2f} | {note} |"
                    )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun")
