"""Online embedding serving under continuous ingestion — the paper's
deployment scenario (Fig. 1), driven end-to-end through the serving
stack: an `OnlineEmbeddingEngine` (reader role) serves zipfian lookups
from a `TieredHKVTable` behind a `TablePublisher`, while an
`OnlineTrainer` (updater + inserter roles) streams gradient updates
against its private successor chain and publishes whole handles.
Eviction runs live at every structural op; the engine's miss policy
('admit') makes served misses admit themselves.

The tail of the script shows the cross-process publication path: the
served table is drained through `export_delta` and replayed into a fresh
replica with `ingest_delta` — the multi-host publish seam.

    PYTHONPATH=src python examples/online_serving.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import HKVTable, TieredHKVTable
from repro.data import zipf_keys
from repro.serving import (EmbeddingRequest, OnlineEmbeddingEngine,
                           OnlineTrainer, TablePublisher, export_delta,
                           ingest_delta)

DIM = 16
WAVE = 512
HOT, COLD = 8 * 128, 64 * 128


def main():
    table = TieredHKVTable.create(
        hot_capacity=HOT, cold_capacity=COLD, dim=DIM,
        score_policy="lfu",  # LFU: best hit rate at α≈1 (Table 8)
    )
    pub = TablePublisher(table)
    trainer = OnlineTrainer(publisher=pub, publish_every=2, lr=0.1)
    eng = OnlineEmbeddingEngine(pub, wave_size=WAVE, miss_policy="admit")

    serve_rng = np.random.default_rng(1)
    train_rng = np.random.default_rng(0)
    key_space = 2 * COLD
    grads = jnp.full((WAVE, DIM), 0.1, jnp.float32)

    hit_hist = []
    for step in range(40):
        # --- online training path: zipfian batch (inserter + updater) -------
        trainer.train_step(
            zipf_keys(train_rng, WAVE, 1.05, key_space), grads)

        # --- concurrent serving path: wave-batched lookups (reader) ---------
        eng.submit(EmbeddingRequest(
            rid=step, keys=zipf_keys(serve_rng, WAVE, 1.05, key_space)))
        r = eng.step()
        hit_hist.append(r.hit_rate)
        if step % 10 == 9:
            m = eng.metrics()
            print(f"step {step:3d}: hit={100*np.mean(hit_hist[-10:]):5.1f}% "
                  f"hot={100*m.hot_rate:5.1f}% kv/s={m.kv_per_s/1e3:6.1f}k "
                  f"published=v{pub.version}")

    m = eng.metrics()
    print(f"steady state: hit-rate trend "
          f"{100*np.mean(hit_hist[:10]):.1f}% -> "
          f"{100*np.mean(hit_hist[-10:]):.1f}%, "
          f"p99 wave latency {m.p99_latency_s*1e3:.1f} ms")
    assert np.mean(hit_hist[-10:]) > np.mean(hit_hist[:10])

    # --- cross-process publish: export the hierarchy, replay into a replica --
    delta = export_delta(pub.table)
    replica = ingest_delta(HKVTable.create(capacity=HOT + COLD, dim=DIM),
                           delta)
    probe = zipf_keys(serve_rng, WAVE, 1.05, key_space)
    src = pub.table.find(probe, promote=False)
    dst = replica.find(probe)
    agree = float(np.mean(np.asarray(src.found) == np.asarray(dst.found)))
    print(f"delta publish: {delta.count} entries -> replica; "
          f"probe membership agreement {100*agree:.1f}%")
    assert agree > 0.95
    print("ok.")


if __name__ == "__main__":
    main()
