"""Online embedding serving under continuous ingestion — the paper's
deployment scenario (Fig. 1): an inference path reading embeddings (reader
role) interleaved with an online-training path ingesting new feature IDs
(inserter role) against the SAME table at load factor 1.0.

    PYTHONPATH=src python examples/online_serving.py
"""

import numpy as np
import jax.numpy as jnp

from repro.data import zipf_keys
from repro.embedding.dynamic import HKVEmbedding
from repro.embedding.sparse_opt import SparseOptimizer


def main():
    emb = HKVEmbedding(
        capacity=64 * 128, dim=16,
        optimizer=SparseOptimizer("rowwise_adagrad", lr=0.1),
        buckets_per_key=2, score_policy="lfu",  # LFU: best hit rate at α≈1 (Table 8)
    )
    table = emb.create()   # an HKVTable handle — the one surface for all roles
    rng = np.random.default_rng(0)
    serve_rng = np.random.default_rng(1)

    hit_hist = []
    for step in range(60):
        # --- online training path: ingest a Zipfian batch (inserter) --------
        train_keys = zipf_keys(rng, 1024, 0.99, 64 * emb.capacity)
        toks = jnp.asarray(train_keys.astype(np.int64), jnp.int32)  # low bits
        table, rows = emb.lookup_train(table, toks)
        # one sparse-SGD step pulling embeddings toward a target
        g = (rows - 1.0) * 0.1
        table = emb.apply_grads(table, toks, g)

        # --- concurrent inference path: read-only lookups (reader) ----------
        # (same low-32-bit token-id truncation as the training path)
        serve_keys = zipf_keys(serve_rng, 2048, 0.99, 64 * emb.capacity)
        hit = float(np.asarray(
            table.contains(serve_keys.astype(np.uint32))
        ).mean())
        hit_hist.append(hit)
        if step % 10 == 9:
            print(f"step {step:3d}: lf={float(table.load_factor()):.3f} "
                  f"serve_hit_rate={100*np.mean(hit_hist[-10:]):.1f}%")

    lf = float(table.load_factor())
    print(f"steady state: lf={lf:.3f}, hit-rate trend "
          f"{100*np.mean(hit_hist[:10]):.1f}% -> {100*np.mean(hit_hist[-10:]):.1f}%")
    assert lf > 0.99
    assert np.mean(hit_hist[-10:]) > np.mean(hit_hist[:10])
    print("ok.")


if __name__ == "__main__":
    main()
