"""End-to-end driver: train a reduced LM with the HKV dynamic-embedding
backend for a few hundred steps, with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm_hkv.py [--steps 200]

This is the paper's deployment story in miniature: the token embedding is
a cache-semantic HKV table (find_or_insert each batch, sparse rowwise-
adagrad through the updater role), the backbone is a GQA transformer, and
the driver checkpoints the table + params + data cursor atomically — a
simulated failure at step 2/3 of the run restores and replays exactly.
"""

import argparse
import shutil

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()

    import sys

    from repro.launch import train as train_mod

    ckpt_dir = "runs/example_hkv_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    argv = sys.argv
    sys.argv = [
        "train", "--arch", args.arch, "--smoke",
        "--steps", str(args.steps), "--batch", "4", "--seq", "64",
        "--backend", "hkv", "--ckpt-dir", ckpt_dir,
        "--checkpoint-every", "25",
    ]
    try:
        hist = train_mod.main()
    finally:
        sys.argv = argv
    losses = hist["loss"]
    k = max(len(losses) // 10, 1)
    print(f"loss: first-{k}-avg {np.mean(losses[:k]):.3f} -> "
          f"last-{k}-avg {np.mean(losses[-k:]):.3f}")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "no learning signal!"
    print("ok.")


if __name__ == "__main__":
    main()
