"""Quickstart: the HKV cache-semantic hash table in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's core semantics end to end on CPU through the public
`HKVTable` handle: batched upsert with in-place eviction at load factor
1.0, digest-accelerated lookup, scoring policies, admission control,
dual-bucket retention, the updater role, a fused op session, and the
two-tier hierarchy (capacity beyond HBM, DESIGN.md §2.5).
This file is the executable version of the README quickstart.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import HKVTable, TieredHKVTable, U64


def main():
    # A 16k-slot table of 32-dim float values, dual-bucket, LRU scoring —
    # the paper's config-B analogue at laptop scale.  The handle carries
    # cfg/backend statically; only the state arrays flow through ops.
    table = HKVTable.create(
        capacity=128 * 128, dim=32, buckets_per_key=2, score_policy="lru"
    )
    rng = np.random.default_rng(0)

    # --- continuous online ingestion: 3x capacity through a full table ------
    print("ingesting 3x capacity ...")
    for step in range(12):
        keys = rng.integers(0, 2**50, size=4096).astype(np.uint64)
        values = jnp.asarray(rng.normal(size=(4096, 32)), jnp.float32)
        res = table.insert_or_assign(keys, values)   # keys: raw numpy uint64
        table = res.table
        status = np.asarray(res.status)
        print(
            f"  step {step:2d}: lf={float(table.load_factor()):.3f} "
            f"updated={np.sum(status == 1):4d} inserted={np.sum(status == 2):4d} "
            f"evicted={np.sum(status == 3):4d} rejected={np.sum(status == 4):4d}"
        )
    assert float(table.load_factor()) > 0.99  # full == normal operating point

    # --- reader role: digest-accelerated find --------------------------------
    q = rng.integers(0, 2**50, size=1024).astype(np.uint64)
    found = table.find(q)
    print(f"find: {int(found.found.sum())}/1024 hits at lf=1.0 "
          f"(misses cost one bucket row each — Prop. 3.1)")

    # --- updater role via an op session (one shared probe) -------------------
    exp = table.export_batch(0, 4)
    live = np.asarray(exp.mask)
    some = U64(jnp.asarray(np.asarray(exp.key_hi)[live][:16]),
               jnp.asarray(np.asarray(exp.key_lo)[live][:16]))
    sess = table.session()
    sess.assign(some, jnp.ones((16, 32)))   # updater
    check = sess.find(some)                 # reader — shares the same locate
    table = sess.commit()
    print(sess.explain())
    assert bool(np.allclose(np.asarray(check.get().values), 1.0))
    print("assign+find fused: 16 rows updated in place, one probe, "
          "no structural change")

    # --- admission control (custom scores) -----------------------------------
    t = HKVTable.create(capacity=512, dim=4, score_policy="custom")
    res = t.insert_or_assign(
        np.arange(1024, dtype=np.uint64),
        jnp.zeros((1024, 4)),
        custom_scores=np.full(1024, 100, np.uint64),
    )
    low = res.table.insert_or_assign(
        np.arange(5000, 5128, dtype=np.uint64),
        jnp.zeros((128, 4)),
        custom_scores=np.full(128, 1, np.uint64),
    )
    print(f"admission control: low-score burst -> "
          f"{int((np.asarray(low.status) == 4).sum())}/128 rejected (Table 9)")

    # --- capacity beyond HBM: the two-tier hierarchy (§3.6 / DESIGN §2.5) ----
    # A small HBM hot tier in front of a large host-capacity cold tier:
    # hot-tier evictions DEMOTE (with their values) instead of vanishing,
    # and re-accessed cold keys PROMOTE back up on the miss path.
    tiered = TieredHKVTable.create(
        hot_capacity=2 * 128, cold_capacity=32 * 128, dim=8)
    early = np.arange(1, 257, dtype=np.uint64)
    tiered = tiered.insert_or_assign(early, jnp.full((256, 8), 5.0)).table
    # churn the hot tier with 4x its capacity of fresh keys
    for i in range(4):
        churn = np.arange(10_000 + 256 * i, 10_256 + 256 * i, dtype=np.uint64)
        r = tiered.insert_or_assign(churn, jnp.zeros((256, 8)))
        tiered = r.table
    out = tiered.find(early)               # cold hits -> promoted on access
    tiered = out.table                     # keep the successor handle
    print(f"tiered: {int(out.found.sum())}/256 early keys survived a 4x "
          f"hot-capacity churn (hot hits: {int(out.hot_hit.sum())}, "
          f"promoted back: {int(out.promoted)}, demoted victims: "
          f"{int(out.demoted)}, lost: {int(out.dropped)})")
    assert bool(np.asarray(out.found).all())
    assert bool(np.allclose(np.asarray(out.values), 5.0))
    print("ok.")


if __name__ == "__main__":
    main()
