"""Quickstart: the HKV cache-semantic hash table in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's core semantics end to end on CPU: batched upsert with
in-place eviction at load factor 1.0, digest-accelerated lookup, scoring
policies, admission control, dual-bucket retention, and the updater role.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import ops, table, u64


def main():
    # A 16k-slot table of 32-dim float values, dual-bucket, LRU scoring —
    # the paper's config-B analogue at laptop scale.
    cfg = table.HKVConfig(
        capacity=128 * 128, dim=32, buckets_per_key=2, score_policy="lru"
    )
    state = table.create(cfg)
    rng = np.random.default_rng(0)

    # --- continuous online ingestion: 3x capacity through a full table ------
    print("ingesting 3x capacity ...")
    for step in range(12):
        keys = u64.from_uint64(rng.integers(0, 2**50, size=4096).astype(np.uint64))
        values = jnp.asarray(rng.normal(size=(4096, 32)), jnp.float32)
        res = ops.insert_or_assign(state, cfg, keys, values)
        state = res.state
        status = np.asarray(res.status)
        print(
            f"  step {step:2d}: lf={float(ops.load_factor(state)):.3f} "
            f"updated={np.sum(status == 1):4d} inserted={np.sum(status == 2):4d} "
            f"evicted={np.sum(status == 3):4d} rejected={np.sum(status == 4):4d}"
        )
    assert float(ops.load_factor(state)) > 0.99  # full == normal operating point

    # --- reader role: digest-accelerated find --------------------------------
    q = u64.from_uint64(rng.integers(0, 2**50, size=1024).astype(np.uint64))
    found = ops.find(state, cfg, q)
    print(f"find: {int(found.found.sum())}/1024 hits at lf=1.0 "
          f"(misses cost one bucket row each — Prop. 3.1)")

    # --- updater role: in-place value update (non-structural) ----------------
    exp = ops.export_batch(state, cfg, 0, 4)
    live = np.asarray(exp.mask)
    some = u64.U64(jnp.asarray(np.asarray(exp.key_hi)[live][:16]),
                   jnp.asarray(np.asarray(exp.key_lo)[live][:16]))
    state = ops.assign(state, cfg, some, jnp.ones((16, 32)))
    check = ops.find(state, cfg, some)
    assert bool(np.allclose(np.asarray(check.values), 1.0))
    print("assign: 16 rows updated in place, no structural change")

    # --- admission control (custom scores) -----------------------------------
    cfg_c = table.HKVConfig(capacity=512, dim=4, score_policy="custom")
    st = table.create(cfg_c)
    res = ops.insert_or_assign(
        st, cfg_c,
        u64.from_uint64(np.arange(1024, dtype=np.uint64)),
        jnp.zeros((1024, 4)),
        custom_scores=u64.from_uint64(np.full(1024, 100, np.uint64)),
    )
    low = ops.insert_or_assign(
        res.state, cfg_c,
        u64.from_uint64(np.arange(5000, 5128, dtype=np.uint64)),
        jnp.zeros((128, 4)),
        custom_scores=u64.from_uint64(np.full(128, 1, np.uint64)),
    )
    print(f"admission control: low-score burst -> "
          f"{int((np.asarray(low.status) == 4).sum())}/128 rejected (Table 9)")
    print("ok.")


if __name__ == "__main__":
    main()
