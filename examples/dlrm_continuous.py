"""The paper's own workload: DLRM-style recommender with HKV embedding
tables under continuous online ingestion (configs A–D of Table 5, scaled).

    PYTHONPATH=src python examples/dlrm_continuous.py

26 sparse criteo-style feature fields share one HKV table (feature-id key
space is hashed-disjoint per field); dense features go through a bottom
MLP; the interaction is a dot-product over field embeddings; training is
click-through logistic regression on synthetic Zipfian streams.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.hkv_dlrm import PAPER_CONFIGS, scaled
from repro.data import zipf_keys
from repro.models.common import dense_init


def main():
    cfg = scaled(PAPER_CONFIGS["B"], scale=2**13)  # 16k slots on CPU
    emb = cfg.embedding()
    table = emb.create()   # HKVTable handle
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    d = cfg.dim
    nf = cfg.num_sparse
    params = {
        "bottom1": dense_init(ks[0], cfg.dense_features, 64),
        "bottom2": dense_init(ks[1], 64, d),
        "top1": dense_init(ks[2], d + nf * (nf + 1) // 2, 64),
        "top2": dense_init(ks[3], 64, 1),
    }

    def forward(params, emb_rows, dense_x):
        # emb_rows: [B, nf, d]; dense_x: [B, 13]
        z = jax.nn.relu(dense_x @ params["bottom1"]) @ params["bottom2"]  # [B, d]
        feats = jnp.concatenate([z[:, None, :], emb_rows], axis=1)       # [B, nf+1, d]
        inter = jnp.einsum("bnd,bmd->bnm", feats, feats)
        iu = jnp.triu_indices(nf + 1, k=1)
        flat = inter[:, iu[0], iu[1]]                                    # [B, nf(nf+1)/2]
        h = jnp.concatenate([z, flat], axis=1)
        return (jax.nn.relu(h @ params["top1"]) @ params["top2"])[:, 0]

    def loss_fn(params, emb_rows, dense_x, labels):
        logits = forward(params, emb_rows, dense_x)
        return jnp.mean(
            jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        )

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1)))

    batch = 256
    lr = 0.05
    losses = []
    for step in range(80):
        # each field hashes into its own slice of the key space
        field_keys = np.stack(
            [zipf_keys(rng, batch, 0.99, 10**6) ^ np.uint64(f << 56) for f in range(nf)],
            axis=1,
        )  # [B, nf] uint64 — but tokens api wants int32; use low bits + field salt
        toks = jnp.asarray((field_keys & np.uint64(0x7FFFFFFF)).astype(np.int64), jnp.int32)
        dense_x = jnp.asarray(rng.normal(size=(batch, cfg.dense_features)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 2, size=batch), jnp.float32)

        table, rows = emb.lookup_train(table, toks)
        loss, (gp, ge) = grad_fn(params, rows, dense_x, labels)
        params = jax.tree.map(lambda p, g: p - lr * g, params, gp)
        table = emb.apply_grads(table, toks, ge)
        losses.append(float(loss))
        if step % 20 == 19:
            print(f"step {step:3d}: loss={np.mean(losses[-20:]):.4f} "
                  f"lf={float(table.load_factor()):.3f}")

    assert np.mean(losses[-20:]) < np.mean(losses[:20])
    print(f"loss {np.mean(losses[:20]):.4f} -> {np.mean(losses[-20:]):.4f}  ok.")


if __name__ == "__main__":
    main()
