"""Unit + property tests for the (hi, lo) uint32-pair 64-bit representation."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import u64

u64s = st.integers(min_value=0, max_value=2**64 - 1)


@settings(max_examples=50, deadline=None)
@given(st.lists(u64s, min_size=1, max_size=32))
def test_roundtrip(xs):
    arr = np.array(xs, np.uint64)
    assert np.array_equal(u64.to_uint64(u64.from_uint64(arr)), arr)


@settings(max_examples=50, deadline=None)
@given(st.lists(u64s, min_size=2, max_size=16), st.lists(u64s, min_size=2, max_size=16))
def test_ordering_matches_uint64(a, b):
    n = min(len(a), len(b))
    an, bn = np.array(a[:n], np.uint64), np.array(b[:n], np.uint64)
    aj, bj = u64.from_uint64(an), u64.from_uint64(bn)
    assert np.array_equal(np.asarray(u64.lt(aj, bj)), an < bn)
    assert np.array_equal(np.asarray(u64.le(aj, bj)), an <= bn)
    assert np.array_equal(np.asarray(u64.eq(aj, bj)), an == bn)
    assert np.array_equal(u64.to_uint64(u64.minimum(aj, bj)), np.minimum(an, bn))
    assert np.array_equal(u64.to_uint64(u64.maximum(aj, bj)), np.maximum(an, bn))


@settings(max_examples=50, deadline=None)
@given(st.lists(u64s, min_size=1, max_size=16), st.integers(0, 2**32 - 1))
def test_add_u32_carry(xs, inc):
    arr = np.array(xs, np.uint64)
    got = u64.to_uint64(u64.add_u32(u64.from_uint64(arr), jnp.uint32(inc)))
    want = arr + np.uint64(inc)  # numpy wraps mod 2^64, as we must
    assert np.array_equal(got, want)


@settings(max_examples=50, deadline=None)
@given(st.lists(u64s, min_size=1, max_size=64))
def test_hash_pair_device_matches_host(xs):
    arr = np.array(xs, np.uint64)
    h1d, h2d = u64.hash_pair(u64.from_uint64(arr))
    h1h, h2h = u64.hash_pair_np(arr)
    assert np.array_equal(np.asarray(h1d), h1h)
    assert np.array_equal(np.asarray(h2d), h2h)


def test_hash_avalanche_and_decorrelation():
    """Sequential keys must spread over buckets and digests uniformly-ish,
    and h1/h2 must be decorrelated (dual-bucket correctness depends on it)."""
    keys = np.arange(100_000, dtype=np.uint64)
    h1, h2 = u64.hash_pair_np(keys)
    for h in (h1, h2):
        buckets = h % np.uint32(1024)
        counts = np.bincount(buckets, minlength=1024)
        # chi-square-ish sanity: max deviation < 5 sigma of poisson mean
        mean = len(keys) / 1024
        assert np.abs(counts - mean).max() < 5 * np.sqrt(mean) + 10
    same_bucket = (h1 % np.uint32(256)) == (h2 % np.uint32(256))
    assert same_bucket.mean() < 0.01  # ~1/256 expected
    digests = (h1 >> np.uint32(24)) & np.uint32(0xFF)
    dcounts = np.bincount(digests, minlength=256)
    assert dcounts.min() > 0  # all digest values reachable


def test_empty_sentinel_is_max():
    s = u64.empty_sentinel((4,))
    assert bool(np.all(np.asarray(u64.is_empty(s))))
    other = u64.from_uint64(np.array([0, 1, 2**63, 2**64 - 2], np.uint64))
    assert bool(np.all(np.asarray(u64.lt(other, s))))
