"""Baseline dictionary-semantic tables: correctness + the λ-pathology the
paper builds its case on (probe growth, insert failure at capacity)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.baselines import BucketedP2CTable, OpenAddressingTable
from repro.core import u64


# (insert/find roundtrips now live in the parametrized KVTable contract
# suite, tests/test_kvtable_conformance.py; this file keeps the baselines'
# UNSHARED behaviors: probe growth and capacity failure.)


@pytest.mark.parametrize("cls", [OpenAddressingTable, BucketedP2CTable])
def test_dictionary_semantics_fail_at_capacity(cls):
    """The capability gap (paper §5.2): dict-semantic tables cannot absorb
    more keys than capacity — inserts FAIL rather than evict."""
    rng = np.random.default_rng(1)
    t = cls(capacity=512, dim=1)
    st = t.create()
    keys = rng.permutation(10_000_000)[: 2 * 512].astype(np.uint64)
    rep = t.insert(st, u64.from_uint64(keys), jnp.zeros((1024, 1)))
    ok = np.asarray(rep.ok)
    assert ok.sum() < 1024  # some inserts MUST fail
    assert ok.sum() <= 512


def test_open_addressing_probe_growth():
    """Fig. 2c: probe distance grows super-linearly with λ (vs HKV's 1)."""
    rng = np.random.default_rng(2)
    t = OpenAddressingTable(capacity=4096, dim=1)
    st = t.create()
    probes_at = {}
    inserted = []
    for lam in (0.25, 0.5, 0.85, 0.95):
        target = int(lam * 4096)
        while len(inserted) < target:
            k = rng.permutation(10_000_000)[: target - len(inserted)].astype(np.uint64)
            rep = t.insert(st, u64.from_uint64(k), jnp.zeros((len(k), 1)))
            st = rep.state
            inserted.extend(k[np.asarray(rep.ok)].tolist())
        sample = np.array(inserted, np.uint64)[
            rng.integers(0, len(inserted), size=256)
        ]
        f = t.find(st, u64.from_uint64(sample))
        probes_at[lam] = float(np.asarray(f.probes).mean())
    assert probes_at[0.95] > probes_at[0.5] > 0
    assert probes_at[0.95] > 2.0  # long chains at high λ
    assert probes_at[0.25] < 1.5


def test_p2c_both_buckets_bounded_probes():
    rng = np.random.default_rng(3)
    t = BucketedP2CTable(capacity=1024, dim=2)
    st = t.create()
    keys = rng.permutation(10_000_000)[:900].astype(np.uint64)
    rep = t.insert(st, u64.from_uint64(keys), jnp.zeros((900, 2)))
    st = rep.state
    f = t.find(st, u64.from_uint64(keys))
    assert np.asarray(f.probes).max() <= 2  # bounded 2-bucket probe
