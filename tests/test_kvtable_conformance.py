"""ONE parametrized contract suite for every `KVTable` implementation.

The `KVTable` protocol (repro.core.api) is implemented by five table
families; this file is the single place their shared semantics are
pinned, replacing the per-impl ad-hoc roundtrip tests that used to live
in test_api.py / test_baselines.py:

  hkv_jnp      `HKVTable`, pure-jnp inserter backend
  hkv_kernel   `HKVTable`, fused Pallas upsert path (interpret mode on CPU)
  dict_oa      `DictKVTable` over open addressing (WarpCore family)
  dict_p2c     `DictKVTable` over bucketed power-of-two-choices (BGHT)
  tiered       `TieredHKVTable` (hot HBM + cold hmem hierarchy)
  sharded      `ShardedHKVTable` on a 1-device mesh (slow: shard_map
               compiles per op on CPU)

Covered: find / contains / insert_or_assign / find_or_insert / assign /
erase / clear / size / export_batch, plus EMPTY-sentinel padding and the
key-form normalization contract.  Where the contract FAMILIES differ by
design — dictionary tables may fail inserts where HKV evicts; sharded
tables recompute init rows owner-side — the differences are encoded in
the per-impl capability table below, not skipped silently.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import HKVTable, KVTable, TieredHKVTable, U64
from repro.core import ops as core_ops
from repro.baselines import DictKVTable
from repro.embedding.sparse_opt import SparseOptimizer

BATCH = 64     # one jit cache entry per op across every test
DIM = 4
EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)

IMPLS = [
    "hkv_jnp",
    "hkv_kernel",
    "dict_oa",
    "dict_p2c",
    "tiered",
    "sharded",   # 1-device mesh; fast BECAUSE ops go through the jitted
                 # wrappers below (eager shard_map would recompile per call)
]

CAPS = {
    # has_export: export_batch/num_buckets exposed (sharded: per-shard
    #             drain + concatenation — the PR-5 ROADMAP close)
    # caller_init: find_or_insert takes the caller's init rows
    # has_scores: score metadata exists, so score/epoch sweep predicates
    #             are meaningful (dictionary tables carry zero planes —
    #             key predicates only)
    # has_find_rows: full-row reads + session-fused read mixes (find /
    #             find_rows / contains over one shared locate) — the HKV
    #             handle surface the PR-6 fused find kernel serves
    # has_row_update: session-structured `ops.RowUpdate` gradient steps
    #             (the apply_grads surface; ONE fused update_scan launch
    #             on backend='kernel') — flat HKV tables only: tiered
    #             routes updates through the hot tier, dictionaries have
    #             no updater surface
    "hkv_jnp": dict(has_export=True, caller_init=True, has_scores=True,
                    has_find_rows=True, has_row_update=True),
    "hkv_kernel": dict(has_export=True, caller_init=True, has_scores=True,
                       has_find_rows=True, has_row_update=True),
    "dict_oa": dict(has_export=True, caller_init=True, has_scores=False,
                    has_find_rows=False, has_row_update=False),
    "dict_p2c": dict(has_export=True, caller_init=True, has_scores=False,
                     has_find_rows=False, has_row_update=False),
    "tiered": dict(has_export=True, caller_init=True, has_scores=True,
                   has_find_rows=False, has_row_update=False),
    "sharded": dict(has_export=True, caller_init=False, has_scores=True,
                    has_find_rows=False, has_row_update=False),
}

_MESH = None


def _mesh():
    global _MESH
    if _MESH is None:
        import jax

        _MESH = jax.make_mesh((1,), ("d",))
    return _MESH


def make_table(impl: str):
    if impl == "hkv_jnp":
        return HKVTable.create(capacity=2 * 128, dim=DIM, backend="jnp")
    if impl == "hkv_kernel":
        return HKVTable.create(capacity=2 * 128, dim=DIM, backend="kernel")
    if impl == "dict_oa":
        return DictKVTable.open_addressing(capacity=256, dim=DIM)
    if impl == "dict_p2c":
        return DictKVTable.bucketed_p2c(capacity=256, dim=DIM)
    if impl == "tiered":
        return TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                     dim=DIM)
    if impl == "sharded":
        from repro.distributed.table_sharding import ShardedHKVTable

        return ShardedHKVTable.create(_mesh(), capacity=4 * 128, dim=DIM)
    raise AssertionError(impl)


# -- batch helpers (constant shapes: BATCH lanes, EMPTY-padded) ---------------


def pad_keys(keys) -> np.ndarray:
    keys = np.asarray(keys, np.uint64)
    out = np.full(BATCH, EMPTY, np.uint64)
    out[: len(keys)] = keys
    return out


def rows_for(keys: np.ndarray) -> jnp.ndarray:
    """Deterministic per-key value rows (column j = key + j)."""
    base = np.where(keys == EMPTY, 0, keys.astype(np.float64))
    return jnp.asarray(
        base[:, None] + np.arange(DIM)[None, :], jnp.float32)


# -- jitted op wrappers -------------------------------------------------------
#
# Every op goes through ONE module-level jitted closure: handles are
# pytrees with static cfg/mesh aux, so each (impl, op) pair compiles once
# for the whole matrix.  This is what makes the sharded param tractable —
# eager shard_map would otherwise recompile per call.


@jax.jit
def _j_read_plain(t, kh, kl):
    r = t.find(U64(kh, kl))
    return r.values[:, :DIM], r.found


@jax.jit
def _j_read_pure(t, kh, kl):        # tiered/sharded: no miss-path promotion
    r = t.find(U64(kh, kl), promote=False)
    return r.values[:, :DIM], r.found


@jax.jit
def _j_contains(t, kh, kl):
    return t.contains(U64(kh, kl))


@jax.jit
def _j_find_rows(t, kh, kl):
    r = t.find_rows(U64(kh, kl))
    return r.rows[:, :DIM], r.found, r.score_hi, r.score_lo


@jax.jit
def _j_session_read(t, kh, kl):
    """find + contains + find_rows fused over ONE shared locate."""
    k = U64(kh, kl)
    s = t.session()
    f = s.find(k)
    c = s.contains(k)
    r = s.find_rows(k)
    s.commit()     # readers only: the committed table is unchanged
    return (f.get().values[:, :DIM], f.get().found,
            r.get().rows[:, :DIM], c.get())


@jax.jit
def _j_upsert(t, kh, kl, v):
    r = t.insert_or_assign(U64(kh, kl), v)
    return r.table, r.ok


@jax.jit
def _j_foi(t, kh, kl, init):
    r = t.find_or_insert(U64(kh, kl), init)
    return r.table, r.values[:, :DIM], r.found


@jax.jit
def _j_foi_ownerinit(t, kh, kl):    # sharded: owner-side init rows
    r = t.find_or_insert(U64(kh, kl))
    return r.table, r.values[:, :DIM], r.found


@jax.jit
def _j_assign(t, kh, kl, v):
    return t.assign(U64(kh, kl), v)


@jax.jit
def _j_erase(t, kh, kl):
    return t.erase(U64(kh, kl))


@jax.jit
def _j_clear(t):
    return t.clear()


@jax.jit
def _j_size(t):
    return t.size()


# lr=0.5 x integer grads: the sgd step is exact in float32, so the
# updater contract below asserts equality, not allclose
_OPT = SparseOptimizer("sgd", lr=0.5)


@jax.jit
def _j_row_update(t, kh, kl, g):
    """The apply_grads shape: pre-update find + structured RowUpdate +
    contains in ONE session (the find shares its locate with the update)."""
    k = U64(kh, kl)
    s = t.session()
    f = s.find(k)
    r = s.update_rows(k, core_ops.RowUpdate(_OPT, g))
    c = s.contains(k)
    t2 = s.commit()
    return t2, f.get().values[:, :DIM], r.get().found, c.get()


@jax.jit
def _j_row_update_solo(t, kh, kl, g):
    """Structured RowUpdate alone — the ONE-launch fused route."""
    s = t.session()
    r = s.update_rows(U64(kh, kl), core_ops.RowUpdate(_OPT, g))
    t2 = s.commit()
    return t2, r.get().found


SWEEP_BUDGET = 32    # static per jit entry; >= every test's match count


@jax.jit
def _j_erase_if(t, pred):
    r = t.erase_if(pred)
    return r.table, r.swept


@jax.jit
def _j_evict_if(t, pred):
    r = t.evict_if(pred, SWEEP_BUDGET)
    return r.table, r.evicted, r.count


@jax.jit
def _j_stats(t):
    return t.stats()


def _planes(keys):
    if isinstance(keys, U64):
        return keys.hi, keys.lo
    keys = np.asarray(keys, np.uint64)
    return (jnp.asarray((keys >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)))


def read(table, keys):
    """Pure-reader find: (values, found), never mutating the table."""
    kh, kl = _planes(keys)
    if isinstance(table, TieredHKVTable) or hasattr(table, "mesh"):
        vals, found = _j_read_pure(table, kh, kl)
    else:
        vals, found = _j_read_plain(table, kh, kl)
    return np.asarray(vals), np.asarray(found)


def contains(table, keys):
    return np.asarray(_j_contains(table, *_planes(keys)))


def upsert(table, keys, values):
    t, ok = _j_upsert(table, *_planes(keys), values)
    return t, np.asarray(ok)


def find_or_insert(table, keys, init):
    if CAPS_CURRENT["caller_init"]:
        t, vals, found = _j_foi(table, *_planes(keys), init)
    else:
        t, vals, found = _j_foi_ownerinit(table, *_planes(keys))
    return t, np.asarray(vals), np.asarray(found)


def assign(table, keys, values):
    return _j_assign(table, *_planes(keys), values)


def erase(table, keys):
    return _j_erase(table, *_planes(keys))


def clear(table):
    return _j_clear(table)


def size(table) -> int:
    return int(_j_size(table))


CAPS_CURRENT = None


@pytest.fixture(params=IMPLS)
def impl(request):
    global CAPS_CURRENT
    CAPS_CURRENT = CAPS[request.param]
    return request.param


@pytest.fixture
def table(impl):
    return make_table(impl)


KEYS = np.arange(1, 25, dtype=np.uint64) * np.uint64(7919)  # 24 distinct keys


class TestReaderContract:
    def test_empty_table_reads(self, table):
        k = pad_keys(KEYS)
        vals, found = read(table, k)
        assert not found.any()
        assert np.allclose(vals, 0.0)
        assert not contains(table, k).any()
        assert size(table) == 0
        assert table.capacity > 0

    def test_find_agrees_with_contains(self, table):
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        _, found = read(t, k)
        assert np.array_equal(found, contains(t, k))


class TestInserterContract:
    def test_insert_find_roundtrip(self, table):
        k = pad_keys(KEYS)
        v = rows_for(k)
        t, ok = upsert(table, k, v)
        # low load: every impl places every key (deterministic fixed batch)
        assert ok[: len(KEYS)].all()
        assert not ok[len(KEYS):].any()       # EMPTY padding is never "ok"
        vals, found = read(t, k)
        assert found[: len(KEYS)].all()
        assert not found[len(KEYS):].any()
        assert np.allclose(vals[: len(KEYS)], np.asarray(v)[: len(KEYS)])
        assert size(t) == len(KEYS)

    def test_overwrite_updates_in_place(self, table):
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        t, ok = upsert(t, k, rows_for(k) + 100.0)
        assert ok[: len(KEYS)].all()
        vals, _ = read(t, k)
        assert np.allclose(vals[: len(KEYS)],
                           np.asarray(rows_for(k))[: len(KEYS)] + 100.0)
        assert size(t) == len(KEYS)     # no duplicate placements

    def test_duplicate_lanes_last_writer_wins(self, table):
        key = np.uint64(4242)
        k = pad_keys([key, key, key])
        v = jnp.asarray(
            np.stack([np.full(DIM, 1.0), np.full(DIM, 2.0),
                      np.full(DIM, 3.0)]
                     + [np.zeros(DIM)] * (BATCH - 3)), jnp.float32)
        t, _ = upsert(table, k, v)
        vals, found = read(t, pad_keys([key]))
        assert found[0]
        assert np.allclose(vals[0], 3.0)
        assert size(t) == 1

    def test_find_or_insert_admits_then_hits(self, table):
        k = pad_keys(KEYS)
        init = rows_for(k) + 0.5
        t, vals1, found1 = find_or_insert(table, k, init)
        assert not found1[: len(KEYS)].any()   # nothing existed
        if CAPS_CURRENT["caller_init"]:
            assert np.allclose(vals1[: len(KEYS)],
                               np.asarray(init)[: len(KEYS)])
        t, vals2, found2 = find_or_insert(t, k, rows_for(k) - 9.0)
        assert found2[: len(KEYS)].all()       # second pass: all hits
        # hits return the STORED rows (the first call's admissions)
        assert np.allclose(vals2[: len(KEYS)], vals1[: len(KEYS)])
        assert size(t) == len(KEYS)


class TestFusedReadContract:
    """The PR-6 reader surface: find_rows and session-fused read mixes
    must agree lane-for-lane with plain find/contains.  Running the matrix
    over BOTH HKV backends conformance-tests the fused find kernel path
    against the jnp one on identical states."""

    def _mixed(self, table):
        """Residents + erased keys + never-inserted keys in one batch."""
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        t = erase(t, pad_keys(KEYS[:6]))
        q = pad_keys(np.concatenate([KEYS, np.array([999983], np.uint64)]))
        return t, q

    def test_find_rows_matches_find(self, table):
        if not CAPS_CURRENT["has_find_rows"]:
            pytest.skip("no full-row read surface on this impl")
        t, q = self._mixed(table)
        vals, found = read(t, q)
        rows, rfound, shi, slo = map(np.asarray, _j_find_rows(t, *_planes(q)))
        np.testing.assert_array_equal(rfound, found)
        np.testing.assert_array_equal(rows, vals)
        # scores mask exactly like values: live lanes carry the entry's
        # score, misses/erased/padding lanes read zero
        score = (shi.astype(np.uint64) << np.uint64(32)) | slo.astype(
            np.uint64)
        assert (score[found] > 0).all()
        assert (score[~found] == 0).all()

    def test_session_read_matches_unfused(self, table):
        if not CAPS_CURRENT["has_find_rows"]:
            pytest.skip("no session find_rows surface on this impl")
        t, q = self._mixed(table)
        vals, found = read(t, q)
        f_vals, f_found, rows, cont = map(
            np.asarray, _j_session_read(t, *_planes(q)))
        np.testing.assert_array_equal(f_found, found)
        np.testing.assert_array_equal(cont, found)
        np.testing.assert_array_equal(f_vals, vals)
        np.testing.assert_array_equal(rows, vals)


class TestUpdaterContract:
    def test_assign_writes_existing_only(self, table):
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        half = len(KEYS) // 2
        wk = pad_keys(np.concatenate([KEYS[:half],
                                      np.array([999983], np.uint64)]))
        t2 = assign(t, wk, jnp.full((BATCH, DIM), -5.0, jnp.float32))
        vals, found = read(t2, k)
        assert np.allclose(vals[:half], -5.0)
        assert np.allclose(vals[half: len(KEYS)],
                           np.asarray(rows_for(k))[half: len(KEYS)])
        # the missing key was NOT created (assign is non-structural)
        _, f999 = read(t2, pad_keys([999983]))
        assert not f999[0]
        assert size(t2) == len(KEYS)

    def test_row_update_trains_residents_only(self, table):
        """The apply_grads-shaped structured gradient step: residents move
        by exactly -lr*g, misses/padding train nothing and are NOT
        admitted, and both the fused solo route and the mixed session
        (find sharing its locate with the update) agree."""
        if not CAPS_CURRENT["has_row_update"]:
            pytest.skip("no structured row-update surface on this impl")
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        before = np.asarray(rows_for(k))
        q = pad_keys(np.concatenate([KEYS[:8],
                                     np.array([999983], np.uint64)]))
        g = jnp.full((BATCH, DIM), 2.0, jnp.float32)
        t2, pre_vals, found, cont = _j_row_update(t, *_planes(q), g)
        assert found[:8].all() and not found[8:].any()
        # the session's find ran BEFORE the update and sees pre-step rows
        np.testing.assert_array_equal(pre_vals[:8], before[:8])
        # contains after the update: same residency (updater != inserter)
        np.testing.assert_array_equal(np.asarray(cont), found)
        vals, vfound = read(t2, k)
        np.testing.assert_array_equal(vals[:8], before[:8] - 1.0)  # .5*2
        np.testing.assert_array_equal(vals[8: len(KEYS)],
                                      before[8: len(KEYS)])
        _, f999 = read(t2, pad_keys([999983]))
        assert not f999[0]
        assert size(t2) == len(KEYS)
        # the solo structured route (fused ONE-launch path) lands the
        # identical state
        t3, found3 = _j_row_update_solo(t, *_planes(q), g)
        np.testing.assert_array_equal(np.asarray(found3), found)
        vals3, _ = read(t3, k)
        np.testing.assert_array_equal(vals3, vals)


class TestStructuralContract:
    def test_erase_removes_and_is_idempotent(self, table):
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        half = len(KEYS) // 2
        gone = pad_keys(np.concatenate([KEYS[:half],
                                        np.array([999983], np.uint64)]))
        t2 = erase(t, gone)
        _, found = read(t2, k)
        assert not found[:half].any()
        assert found[half: len(KEYS)].all()
        assert size(t2) == len(KEYS) - half
        t3 = erase(t2, gone)                   # idempotent
        assert size(t3) == len(KEYS) - half
        # erased keys can be re-inserted and found again
        t4, ok = upsert(t3, pad_keys(KEYS[:half]), rows_for(pad_keys(KEYS[:half])))
        assert ok[:half].all()
        _, found4 = read(t4, k)
        assert found4[: len(KEYS)].all()

    def test_clear_empties_and_reuses(self, table):
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        t2 = clear(t)
        assert size(t2) == 0
        _, found = read(t2, k)
        assert not found.any()
        t3, ok = upsert(t2, k, rows_for(k))
        assert ok[: len(KEYS)].all()
        assert size(t3) == len(KEYS)


class TestMaintenanceContract:
    """The PR-5 surface: predicated sweeps + TableStats on every impl."""

    def test_erase_if_key_range(self, table):
        from repro.core import SweepPredicate

        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        lo, hi = int(KEYS[4]), int(KEYS[12])
        t2, swept = _j_erase_if(t, SweepPredicate.key_in_range(lo, hi))
        inside = (KEYS >= lo) & (KEYS < hi)
        assert int(swept) == inside.sum()
        _, found = read(t2, k)
        np.testing.assert_array_equal(found[: len(KEYS)], ~inside)
        assert size(t2) == len(KEYS) - inside.sum()
        # swept slots are reusable
        t3, ok = upsert(t2, k, rows_for(k))
        assert ok[: len(KEYS)].all()

    def test_erase_if_score_threshold(self, table):
        if not CAPS_CURRENT["has_scores"]:
            pytest.skip("dictionary tables carry no score metadata")
        from repro.core import SweepPredicate

        a, b = pad_keys(KEYS[:12]), pad_keys(KEYS[12:])
        t, _ = upsert(table, a, rows_for(a))       # clock 1
        t, _ = upsert(t, b, rows_for(b))           # clock 2
        # LRU scores = insert clock; threshold 2 expires only round 1
        # (tiered: demoted copies carry TRANSLATED scores, same domain)
        t2, swept = _j_erase_if(t, SweepPredicate.score_below(2))
        assert int(swept) >= 12                    # >=: inclusive cold copies
        _, found = read(t2, pad_keys(KEYS))
        assert not found[:12].any()
        assert found[12: len(KEYS)].all()

    def test_evict_if_returns_the_removed_entries(self, table):
        from repro.core import SweepPredicate

        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        lo, hi = int(KEYS[0]), int(KEYS[8])
        want = {int(x) for x in KEYS[(KEYS >= lo) & (KEYS < hi)]}
        t2, stream, count = _j_evict_if(
            t, SweepPredicate.key_in_range(lo, hi))
        assert int(count) == len(want)
        mask = np.asarray(stream.mask)
        khi = np.asarray(stream.key_hi, np.uint64)
        klo = np.asarray(stream.key_lo, np.uint64)
        got = {int((khi[i] << np.uint64(32)) | klo[i])
               for i in np.nonzero(mask)[0]}
        assert got == want
        # the evicted lanes carry the stored rows (the demotion transport)
        vals = np.asarray(stream.values)
        for i in np.nonzero(mask)[0]:
            key = (khi[i] << np.uint64(32)) | klo[i]
            np.testing.assert_allclose(
                vals[i, :DIM], np.asarray(rows_for(np.array([key]))[0]))
        # and are gone from the table
        _, found = read(t2, k)
        np.testing.assert_array_equal(
            found[: len(KEYS)], ~((KEYS >= lo) & (KEYS < hi)))

    def test_stats_sanity(self, table):
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        s = _j_stats(t)
        assert int(s.size) == len(KEYS)
        lf = float(s.load_factor)
        assert 0.0 < lf <= 1.0
        hist = np.asarray(s.occupancy_hist)
        assert (hist >= 0).all()
        # weighted occupancy equals the live count
        assert (hist * np.arange(len(hist))).sum() >= len(KEYS)
        q = np.asarray(_j_stats(t).score_quantiles(), np.uint64)
        assert q.shape == (5,)
        assert (np.diff(q.astype(np.int64)) >= 0).all()

    def test_empty_table_stats(self, table):
        s = _j_stats(table)
        assert int(s.size) == 0
        assert float(s.load_factor) == 0.0


class TestExportContract:
    def test_export_batch_streams_the_live_set(self, table):
        if not CAPS_CURRENT["has_export"]:
            pytest.skip("no export surface (sharded checkpoint: ROADMAP)")
        k = pad_keys(KEYS)
        t, _ = upsert(table, k, rows_for(k))
        t = erase(t, pad_keys(KEYS[:4]))
        seen = {}
        for b in range(t.num_buckets):
            exp = t.export_batch(b, 1)
            mask = np.asarray(exp.mask)
            khi = np.asarray(exp.key_hi, np.uint64)
            klo = np.asarray(exp.key_lo, np.uint64)
            vals = np.asarray(exp.values)
            for i in np.nonzero(mask)[0]:
                key = int((khi[i] << np.uint64(32)) | klo[i])
                assert key not in seen, "duplicate key in export stream"
                seen[key] = vals[i, :DIM]
        assert sorted(seen) == sorted(int(x) for x in KEYS[4:])
        fv, _ = read(t, k)
        for j, key in enumerate(KEYS):
            if key in seen:
                assert np.allclose(seen[int(key)], fv[j])


# -- reusable one-shot roundtrip (composed handles import this; e.g. the
# sharded-over-tiered test in test_tiered.py) --------------------------------


def protocol_roundtrip(table):
    """The single code path the benchmarks use, over any KVTable."""
    assert isinstance(table, KVTable)
    keys = np.arange(1, 65, dtype=np.uint64)
    vals = jnp.broadcast_to(jnp.arange(64, dtype=jnp.float32)[:, None],
                            (64, table.dim)) + 1.0
    rep = table.insert_or_assign(keys, vals)
    assert bool(np.asarray(rep.ok).all())
    table = rep.table
    assert int(table.size()) == 64
    assert 0.0 < float(table.load_factor()) <= 1.0
    f = table.find(keys)
    assert bool(np.asarray(f.found).all())
    np.testing.assert_allclose(np.asarray(f.values), np.asarray(vals))
    miss = table.find(np.arange(1000, 1010, dtype=np.uint64))
    assert not bool(np.asarray(miss.found).any())
    np.testing.assert_array_equal(np.asarray(miss.values), 0.0)
    assert bool(np.asarray(table.contains(keys)).all())
    return table


class TestKeyNormalization:
    def test_key_forms_are_equivalent(self, table):
        from repro.core import normalize_keys

        ids = [3, 17, 255]
        t, _ = upsert(table, pad_keys(np.array(ids, np.uint64)),
                      rows_for(pad_keys(np.array(ids, np.uint64))))
        # the signed-int form resolves to the same keys, negatives to the
        # EMPTY padding sentinel — and every impl ignores those lanes
        as_list = list(map(int, ids)) + [-1] * (BATCH - len(ids))
        _, found = read(t, normalize_keys(np.array(as_list, np.int64)))
        assert found[: len(ids)].all()
        assert not found[len(ids):].any()     # negative = EMPTY padding

    def test_protocol_isinstance(self, table):
        assert isinstance(table, KVTable)
