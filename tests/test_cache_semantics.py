"""System-level cache-semantic invariants (paper Definition 2.1 + §5 claims).

These are the hardware-independent reproduction targets from the paper:

  CS1  every full-bucket upsert resolves in place (evict or reject) —
       status is never a capacity failure, table shape never changes;
  CS2  no rehashing / no external maintenance — the state arrays keep
       identical shapes across any op sequence;
  CS3  lookup cost bounded independent of cumulative insertions —
       structural property of locate(); validated here as digest-filter
       statistics (Prop. 3.1: ~0.5 expected false-positive key compares
       per miss).

Plus the quantitative claims:
  * first-eviction load factor: single ≈0.633, dual ≈0.977 (Table 11);
  * dual-bucket top-N retention > single (Table 11);
  * admission control blocks low-score bursts entirely (Table 9).
"""

import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import find as find_mod
from repro.core import merge, ops, table, u64


def _fill_to(state, cfg, rng, target_lf, batch=512, key_hi=2**40):
    """Insert random keys until load factor >= target."""
    while float(ops.load_factor(state)) < target_lf:
        keys = rng.integers(0, key_hi, size=batch).astype(np.uint64)
        vals = jnp.zeros((batch, cfg.dim), jnp.float32)
        state = ops.insert_or_assign(state, cfg, u64.from_uint64(keys), vals).state
    return state


class TestCS1FullCapacityResolution:
    def test_upsert_at_lambda_1_never_fails(self):
        rng = np.random.default_rng(0)
        cfg = table.HKVConfig(capacity=8 * 128, dim=4, score_policy="lru")
        state = _fill_to(table.create(cfg), cfg, rng, 1.0)
        assert float(ops.load_factor(state)) == 1.0
        # continuous ingestion at lambda=1.0: every upsert resolves in place
        for _ in range(5):
            keys = rng.integers(0, 2**40, size=256).astype(np.uint64)
            res = ops.insert_or_assign(
                state, cfg, u64.from_uint64(keys), jnp.zeros((256, 4))
            )
            state = res.state
            status = np.asarray(res.status)
            # every valid entry resolved: updated, inserted, evicted or rejected
            assert np.all(np.isin(status, [1, 2, 3, 4]))
            assert np.any(status == 3)  # evictions are happening
            assert float(ops.load_factor(state)) == 1.0  # size conserved

    def test_rejected_only_when_score_below_bucket_min(self):
        """Admission control (Table 9): a low-score burst is fully rejected,
        a high-score burst fully admitted."""
        rng = np.random.default_rng(1)
        cfg = table.HKVConfig(capacity=4 * 128, dim=2, score_policy="custom")
        state = table.create(cfg)
        base = rng.integers(0, 2**40, size=cfg.capacity * 3).astype(np.uint64)
        res = ops.insert_or_assign(
            state,
            cfg,
            u64.from_uint64(base),
            jnp.zeros((len(base), 2)),
            custom_scores=u64.from_uint64(np.full(len(base), 100, np.uint64)),
        )
        state = res.state
        assert float(ops.load_factor(state)) == 1.0
        burst = rng.integers(2**41, 2**42, size=128).astype(np.uint64)
        low = ops.insert_or_assign(
            state, cfg, u64.from_uint64(burst), jnp.zeros((128, 2)),
            custom_scores=u64.from_uint64(np.full(128, 1, np.uint64)),
        )
        assert np.all(np.asarray(low.status) == 4)  # all rejected, Δhit = 0
        high = ops.insert_or_assign(
            state, cfg, u64.from_uint64(burst), jnp.zeros((128, 2)),
            custom_scores=u64.from_uint64(np.full(128, 10**9, np.uint64)),
        )
        assert np.all(np.asarray(high.status) == 3)  # all admitted by eviction


class TestCS2NoRehash:
    def test_state_shapes_invariant_under_any_op_sequence(self):
        rng = np.random.default_rng(2)
        cfg = table.HKVConfig(capacity=2 * 128, dim=3, buckets_per_key=2)
        state = table.create(cfg)
        shapes0 = jax.tree_shapes = [x.shape for x in state]
        for i in range(8):
            keys = u64.from_uint64(rng.integers(0, 10_000, size=64).astype(np.uint64))
            vals = jnp.zeros((64, 3))
            state = ops.insert_or_assign(state, cfg, keys, vals).state
            state = ops.assign(state, cfg, keys, vals + 1.0)
            state = ops.erase(state, cfg, keys[:8])
            assert [x.shape for x in state] == shapes0


class TestCS3BoundedLookup:
    def test_digest_false_positive_rate(self):
        """Prop 3.1: per-bucket miss ≈ S/256 ≈ 0.5 false-positive key compares."""
        rng = np.random.default_rng(3)
        cfg = table.HKVConfig(capacity=32 * 128, dim=2)
        state = _fill_to(table.create(cfg), cfg, rng, 1.0)
        misses = rng.integers(2**50, 2**51, size=4096).astype(np.uint64)
        mk = u64.from_uint64(misses)
        probe = find_mod.probe_keys(cfg, mk)
        drow = np.asarray(state.digests)[np.asarray(probe.bucket1)]
        fp = (drow == np.asarray(probe.digest)[:, None]).sum(axis=1)
        # E[fp per miss] = 128/256 = 0.5 at lambda=1.0
        assert 0.3 < fp.mean() < 0.7
        assert not bool(np.asarray(ops.contains(state, cfg, mk)).any())


class TestFirstEvictionLoadFactor:
    """Paper Table 11: single-bucket first eviction at λ≈0.633 (birthday
    paradox on 128-slot buckets), dual-bucket at λ≈0.977."""

    def _first_eviction_lf(self, dual: bool) -> float:
        rng = np.random.default_rng(4)
        cfg = table.HKVConfig(
            capacity=128 * 128, dim=1, buckets_per_key=2 if dual else 1
        )
        state = table.create(cfg)
        batch = 512
        inserted = 0
        while True:
            keys = rng.integers(0, 2**60, size=batch).astype(np.uint64)
            res = ops.insert_or_assign(
                state, cfg, u64.from_uint64(keys), jnp.zeros((batch, 1))
            )
            state = res.state
            status = np.asarray(res.status)
            if np.any((status == 3) | (status == 4)):
                return float(ops.load_factor(state))
            inserted += batch
            assert inserted <= cfg.capacity + batch

    def test_single_bucket_birthday_paradox(self):
        lf = self._first_eviction_lf(dual=False)
        assert 0.55 < lf < 0.72, f"single-bucket first eviction at {lf}"

    def test_dual_bucket_delays_eviction(self):
        lf = self._first_eviction_lf(dual=True)
        assert lf > 0.93, f"dual-bucket first eviction at {lf}"


class TestRetention:
    def test_dual_bucket_improves_topn_retention(self):
        """Table 11: top-N score retention, dual > single, at λ=1.0."""
        results = {}
        for dual in (False, True):
            rng = np.random.default_rng(5)
            cfg = table.HKVConfig(
                capacity=32 * 128,
                dim=1,
                buckets_per_key=2 if dual else 1,
                score_policy="custom",
            )
            state = table.create(cfg)
            n_stream = cfg.capacity * 3
            keys = rng.permutation(n_stream).astype(np.uint64)
            scores = keys.copy()  # score == key rank: ideal top-N is known exactly
            for i in range(0, n_stream, 512):
                kb, sb = keys[i : i + 512], scores[i : i + 512]
                state = ops.insert_or_assign(
                    state, cfg,
                    u64.from_uint64(kb),
                    jnp.zeros((len(kb), 1)),
                    custom_scores=u64.from_uint64(sb),
                ).state
            exp = ops.export_batch(state, cfg, 0, cfg.num_buckets)
            live = np.asarray(exp.mask)
            got = set(
                map(int, ((np.asarray(exp.key_hi, np.uint64) << np.uint64(32))
                          | np.asarray(exp.key_lo, np.uint64))[live])
            )
            ideal = set(range(n_stream - cfg.capacity, n_stream))
            results[dual] = len(got & ideal) / cfg.capacity
        assert results[True] > results[False]
        assert results[True] > 0.97  # paper: 99.44 %
        assert results[False] > 0.90  # paper: 95.39 %


class TestTripleGroupCommutativity:
    """§3.5 adaptation: updater ops on disjoint keys commute; reader ops
    never change state (the dependency-structure version of role isolation)."""

    def test_updaters_commute_on_disjoint_keys(self):
        rng = np.random.default_rng(6)
        cfg = table.HKVConfig(capacity=2 * 128, dim=2)
        state = table.create(cfg)
        keys = rng.permutation(200)[:64].astype(np.uint64)
        state = ops.insert_or_assign(
            state, cfg, u64.from_uint64(keys), jnp.zeros((64, 2))
        ).state
        ka, kb = u64.from_uint64(keys[:32]), u64.from_uint64(keys[32:])
        va = jnp.ones((32, 2)) * 2.0
        vb = jnp.ones((32, 2)) * 3.0
        s_ab = ops.assign(ops.assign(state, cfg, ka, va), cfg, kb, vb)
        s_ba = ops.assign(ops.assign(state, cfg, kb, vb), cfg, ka, va)
        for x, y in zip(s_ab, s_ba):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_readers_are_pure(self):
        rng = np.random.default_rng(7)
        cfg = table.HKVConfig(capacity=128, dim=2)
        state = table.create(cfg)
        keys = u64.from_uint64(rng.integers(0, 1000, 32).astype(np.uint64))
        state = ops.insert_or_assign(state, cfg, keys, jnp.zeros((32, 2))).state
        before = [np.asarray(x).copy() for x in state]
        ops.find(state, cfg, keys)
        ops.contains(state, cfg, keys)
        ops.size(state)
        for b, a in zip(before, state):
            np.testing.assert_array_equal(b, np.asarray(a))
