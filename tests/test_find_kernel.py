"""Kernel/core parity for the FUSED Pallas find path (interpret mode).

Same acceptance bar as test_upsert_kernel.py / test_sweep_kernel.py:
BIT-IDENTITY.  The fused kernel (`kernels/find_scan.py`) resolves digest
pre-filter + full-key confirm + dual-bucket merge + score readout + value
gather in ONE launch; it must produce exactly the (found, bucket, slot,
scores, values) of

  * the jnp reference (`core.find.locate` + `gather_values` + the score
    readout in `core.ops.find`/`find_rows`), and
  * the pre-fusion composition it replaced (digest_scan locate x
    buckets_per_key + gather_rows — kept as
    `kernels.ops.find_composed_kernel`),

for both variants (tlp / pipeline), masked/EMPTY-padded lanes, duplicate
keys in batch, wide (>32-bit) keys, hit/miss/secondary-bucket-collision
cases, and under jit/vmap wrapping.  The launch-count tests pin the
acceptance criterion that fusion eliminates >= 1 kernel launch per find.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import find as find_mod
from repro.core import merge, ops, table, u64
from repro.core.api import HKVTable
from repro.kernels import digest_scan as _ds
from repro.kernels import find_scan as _fs
from repro.kernels import gather as _ga
from repro.kernels import ops as kops
from repro.kernels import ref

EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)

VARIANTS = ("tlp", "pipeline")


def _query_batch(rng, resident, n_hit, n_miss, n_pad, dup_frac=0.25):
    """Hits drawn from `resident` (with duplicates), wide-key misses,
    EMPTY-sentinel padding lanes — the full parity matrix in one batch."""
    hits = rng.choice(resident, size=n_hit)
    ndup = int(n_hit * dup_frac)
    if ndup:
        hits[rng.integers(0, n_hit, size=ndup)] = rng.choice(hits, size=ndup)
    misses = rng.integers(2**50, 2**60, size=n_miss).astype(np.uint64)
    pads = np.full(n_pad, EMPTY, np.uint64)
    q = np.concatenate([hits, misses, pads])
    rng.shuffle(q)
    return q


def _filled_table(rng, cfg, n_fill):
    """A table with live/empty mix and wide keys (>= 2**32)."""
    keys = rng.integers(1, 2**50, size=n_fill).astype(np.uint64)
    vals = jnp.asarray(rng.normal(size=(n_fill, cfg.dim)), jnp.float32)
    state = merge.upsert(table.create(cfg), cfg, u64.from_uint64(keys),
                         vals).state
    return state, keys


def _ref_find(state, cfg, keys):
    """The jnp oracle assembled exactly as core.ops.find/find_rows do."""
    loc = find_mod.locate(state, cfg, keys)
    rows = find_mod.gather_values(state, loc, None, cfg.value_tier)
    shi = jnp.where(loc.found, state.score_hi[loc.bucket, loc.slot], 0)
    slo = jnp.where(loc.found, state.score_lo[loc.bucket, loc.slot], 0)
    return loc, rows, shi, slo


def _assert_fused_equal(r, state, cfg, keys, ctx=""):
    loc, rows, shi, slo = _ref_find(state, cfg, keys)
    np.testing.assert_array_equal(np.asarray(r.found), np.asarray(loc.found),
                                  err_msg=f"{ctx}: found")
    np.testing.assert_array_equal(np.asarray(r.bucket), np.asarray(loc.bucket),
                                  err_msg=f"{ctx}: bucket")
    np.testing.assert_array_equal(np.asarray(r.slot), np.asarray(loc.slot),
                                  err_msg=f"{ctx}: slot")
    np.testing.assert_array_equal(np.asarray(r.row), np.asarray(loc.row),
                                  err_msg=f"{ctx}: row")
    np.testing.assert_array_equal(np.asarray(r.values), np.asarray(rows),
                                  err_msg=f"{ctx}: values")
    np.testing.assert_array_equal(np.asarray(r.score_hi), np.asarray(shi),
                                  err_msg=f"{ctx}: score_hi")
    np.testing.assert_array_equal(np.asarray(r.score_lo), np.asarray(slo),
                                  err_msg=f"{ctx}: score_lo")


# =============================================================================
# Raw kernel vs the pure-jnp oracle (ref.find_scan_ref)
# =============================================================================


@pytest.mark.parametrize("dual", [False, True])
@pytest.mark.parametrize("variant", VARIANTS)
def test_find_scan_matches_ref(variant, dual):
    """The kernel in isolation, exact-tile batch (no padding seam)."""
    rng = np.random.default_rng(7 + dual)
    cfg = table.HKVConfig(capacity=4 * 128, dim=8,
                          buckets_per_key=2 if dual else 1)
    state, resident = _filled_table(rng, cfg, 400)
    q = _query_batch(rng, resident, 96, 24, 8)
    k = u64.from_uint64(q)
    probe = find_mod.probe_keys(cfg, k)
    b2 = probe.bucket2 if dual else probe.bucket1
    args = (state.digests, state.key_hi, state.key_lo, state.score_hi,
            state.score_lo, state.values, probe.bucket1, b2,
            probe.digest.astype(jnp.uint32), k.hi, k.lo)
    want = ref.find_scan_ref(*args)
    if variant == "tlp":
        got = _fs.find_scan_tlp(*args, interpret=True)
    else:
        got = _fs.find_scan_pipeline(*args, q_tile=128, interpret=True)
    for g, w, name in zip(got, want,
                          ("found", "sel", "slot", "shi", "slo", "vals")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{variant} dual={dual} {name}")


@pytest.mark.parametrize("variant", VARIANTS)
def test_find_scan_use_digest_false_matches_ref(variant):
    """The Exp#3a ablation arm: key-only compare, no digest pre-filter."""
    rng = np.random.default_rng(13)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, use_digest=False)
    state, resident = _filled_table(rng, cfg, 200)
    q = _query_batch(rng, resident, 100, 20, 8)
    k = u64.from_uint64(q)
    probe = find_mod.probe_keys(cfg, k)
    args = (state.digests, state.key_hi, state.key_lo, state.score_hi,
            state.score_lo, state.values, probe.bucket1, probe.bucket1,
            probe.digest.astype(jnp.uint32), k.hi, k.lo)
    want = ref.find_scan_ref(*args, use_digest=False)
    if variant == "tlp":
        got = _fs.find_scan_tlp(*args, use_digest=False, interpret=True)
    else:
        got = _fs.find_scan_pipeline(*args, q_tile=128, use_digest=False,
                                     interpret=True)
    for g, w, name in zip(got, want,
                          ("found", "sel", "slot", "shi", "slo", "vals")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{variant} {name}")
    # the fused wrapper honors cfg.use_digest end-to-end
    r = kops.find_fused_kernel(state, cfg, k, variant=variant, interpret=True)
    _assert_fused_equal(r, state, cfg, k, f"{variant} use_digest=False")


# =============================================================================
# Wrapper vs the core jnp reference AND the old composition
# =============================================================================


@pytest.mark.parametrize("dual", [False, True])
@pytest.mark.parametrize("variant", VARIANTS)
def test_fused_wrapper_bit_identical_to_core(variant, dual):
    """find_fused_kernel vs locate+gather+scores, odd batch sizes included
    (the pipeline variant's padding seam)."""
    rng = np.random.default_rng(31 * (1 + dual))
    cfg = table.HKVConfig(capacity=4 * 128, dim=8,
                          buckets_per_key=2 if dual else 1, score_policy="lfu")
    state, resident = _filled_table(rng, cfg, 700)  # λ beyond 1.0: evictions
    for n in (1, 37, 128, 193):
        q = _query_batch(rng, resident, max(1, n - n // 4 - n // 8),
                         n // 4, n // 8)[:n]
        k = u64.from_uint64(q)
        r = kops.find_fused_kernel(state, cfg, k, variant=variant,
                                   interpret=True)
        _assert_fused_equal(r, state, cfg, k,
                            f"{variant} dual={dual} n={n}")


@pytest.mark.parametrize("dual", [False, True])
@pytest.mark.parametrize("variant", VARIANTS)
def test_fused_matches_old_composition(variant, dual):
    """The replaced pair (digest_scan locate + gather_rows) and the fused
    pass agree bit-for-bit — the regression seam of this PR."""
    rng = np.random.default_rng(41 + dual)
    cfg = table.HKVConfig(capacity=2 * 128, dim=16,
                          buckets_per_key=2 if dual else 1)
    state, resident = _filled_table(rng, cfg, 300)
    q = _query_batch(rng, resident, 80, 30, 18)
    k = u64.from_uint64(q)
    v_new, f_new = kops.find_kernel(state, cfg, k, variant=variant,
                                    interpret=True)
    v_old, f_old = kops.find_composed_kernel(state, cfg, k, variant=variant,
                                             interpret=True)
    np.testing.assert_array_equal(np.asarray(f_new), np.asarray(f_old))
    np.testing.assert_array_equal(np.asarray(v_new), np.asarray(v_old))


def test_secondary_bucket_hits_are_exercised_and_identical():
    """Drive a small dual table to λ=1.0 so some residents live in their
    SECONDARY bucket, then pin that the fused path resolves them."""
    rng = np.random.default_rng(5)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, buckets_per_key=2)
    # chunked inserts: load-balance picks the emptier candidate per step,
    # so once primaries fill, later keys land in their secondary bucket
    state = table.create(cfg)
    resident = rng.integers(1, 2**50, size=600).astype(np.uint64)
    for chunk in np.split(resident, 12):
        vals = jnp.asarray(rng.normal(size=(len(chunk), cfg.dim)),
                           jnp.float32)
        state = merge.upsert(state, cfg, u64.from_uint64(chunk), vals).state
    assert float(state.load_factor()) == 1.0
    k = u64.from_uint64(np.unique(resident))
    loc = find_mod.locate(state, cfg, k)
    probe = find_mod.probe_keys(cfg, k)
    in_b2 = np.asarray(loc.found & (loc.bucket == probe.bucket2)
                       & (probe.bucket2 != probe.bucket1))
    assert in_b2.any(), "fill did not produce secondary-bucket residents"
    for variant in VARIANTS:
        r = kops.find_fused_kernel(state, cfg, k, variant=variant,
                                   interpret=True)
        _assert_fused_equal(r, state, cfg, k, f"{variant} secondary")


# =============================================================================
# Dispatch: ops-layer backends, sessions, tiers, jit/vmap
# =============================================================================


def test_ops_reader_backend_parity():
    rng = np.random.default_rng(11)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, buckets_per_key=2)
    state, resident = _filled_table(rng, cfg, 300)
    q = _query_batch(rng, resident, 60, 20, 4)
    k = u64.from_uint64(q)
    fj = ops.find(state, cfg, k, backend="jnp")
    fk = ops.find(state, cfg, k, backend="kernel")
    for f in fj._fields:
        np.testing.assert_array_equal(np.asarray(getattr(fj, f)),
                                      np.asarray(getattr(fk, f)),
                                      err_msg=f"find.{f}")
    rj = ops.find_rows(state, cfg, k, backend="jnp")
    rk = ops.find_rows(state, cfg, k, backend="kernel")
    for f in rj._fields:
        np.testing.assert_array_equal(np.asarray(getattr(rj, f)),
                                      np.asarray(getattr(rk, f)),
                                      err_msg=f"find_rows.{f}")
    np.testing.assert_array_equal(
        np.asarray(ops.contains(state, cfg, k, backend="jnp")),
        np.asarray(ops.contains(state, cfg, k, backend="kernel")))
    lj = ops.find_ptr(state, cfg, k, backend="jnp")
    lk = ops.find_ptr(state, cfg, k, backend="kernel")
    for f in lj._fields:
        np.testing.assert_array_equal(np.asarray(getattr(lj, f)),
                                      np.asarray(getattr(lk, f)),
                                      err_msg=f"find_ptr.{f}")


def test_reader_backend_validation():
    cfg = table.HKVConfig(capacity=128, dim=4)
    state = table.create(cfg)
    k = u64.from_uint64(np.asarray([1], np.uint64))
    with pytest.raises(ValueError, match="backend"):
        ops.find(state, cfg, k, backend="cuda")
    with pytest.raises(ValueError, match="variant"):
        kops.find_fused_kernel(state, cfg, k, variant="warp")


def test_hmem_tier_falls_back_to_tier_gather():
    """Host-tier value planes keep the §3.6 crossing contract: the kernel
    locates, tier_gather moves rows — results identical to jnp."""
    rng = np.random.default_rng(23)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, value_tier="hmem")
    state, resident = _filled_table(rng, cfg, 200)
    q = _query_batch(rng, resident, 50, 10, 4)
    k = u64.from_uint64(q)
    r = kops.find_fused_kernel(state, cfg, k, interpret=True)
    _assert_fused_equal(r, state, cfg, k, "hmem")
    fj = ops.find_rows(state, cfg, k, backend="jnp")
    fk = ops.find_rows(state, cfg, k, backend="kernel")
    for f in fj._fields:
        np.testing.assert_array_equal(np.asarray(getattr(fj, f)),
                                      np.asarray(getattr(fk, f)),
                                      err_msg=f"hmem find_rows.{f}")


def test_fused_find_under_jit_and_vmap():
    rng = np.random.default_rng(19)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, buckets_per_key=2)
    state, resident = _filled_table(rng, cfg, 300)
    tk = HKVTable.wrap(state, cfg, backend="kernel")
    q = _query_batch(rng, resident, 50, 10, 4)
    k = u64.from_uint64(q)

    # jit: the handle path (fused pass inside the traced region)
    jfind = jax.jit(lambda t, hi, lo: t.find(u64.U64(hi, lo)))
    got = jfind(tk, k.hi, k.lo)
    want = tk.with_backend("jnp").find(k)
    for f in want._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)),
                                      err_msg=f"jit find.{f}")

    # vmap: map the raw kernel over a stacked query axis (Pallas adds a
    # grid dim); each mapped row must equal its solo run
    probe = find_mod.probe_keys(cfg, k)
    args = lambda sl: (probe.bucket1[sl], probe.bucket2[sl],
                       probe.digest.astype(jnp.uint32)[sl], k.hi[sl],
                       k.lo[sl])
    half = len(q) // 2
    stacked = tuple(jnp.stack([a, b]) for a, b in
                    zip(args(slice(0, half)), args(slice(half, 2 * half))))
    fn = lambda b1, b2, qd, qh, ql: _fs.find_scan_tlp(
        state.digests, state.key_hi, state.key_lo, state.score_hi,
        state.score_lo, state.values, b1, b2, qd, qh, ql, interpret=True)
    vout = jax.vmap(fn)(*stacked)
    solo0 = fn(*args(slice(0, half)))
    solo1 = fn(*args(slice(half, 2 * half)))
    for i, name in enumerate(("found", "sel", "slot", "shi", "slo", "vals")):
        np.testing.assert_array_equal(np.asarray(vout[i][0]),
                                      np.asarray(solo0[i]),
                                      err_msg=f"vmap row0 {name}")
        np.testing.assert_array_equal(np.asarray(vout[i][1]),
                                      np.asarray(solo1[i]),
                                      err_msg=f"vmap row1 {name}")


# =============================================================================
# Launch accounting: fusion eliminates >= 1 launch per find
# =============================================================================


class TestLaunchBudget:
    def _counters(self, monkeypatch):
        counts = {"find_scan": 0, "digest_scan": 0, "gather": 0}

        def wrap(mod, name, key):
            orig = getattr(mod, name)

            def counting(*a, **kw):
                counts[key] += 1
                return orig(*a, **kw)

            monkeypatch.setattr(mod, name, counting)

        wrap(_fs, "find_scan_tlp", "find_scan")
        wrap(_fs, "find_scan_pipeline", "find_scan")
        wrap(_ds, "digest_scan_tlp", "digest_scan")
        wrap(_ds, "digest_scan_pipeline", "digest_scan")
        wrap(_ga, "gather_rows", "gather")
        return counts

    @pytest.mark.parametrize("dual", [False, True])
    def test_fused_find_is_one_launch(self, dual, monkeypatch):
        """Old composition: buckets_per_key digest_scan launches + one
        gather launch.  Fused: ONE find_scan launch — >= 1 eliminated
        (2 in dual mode), the PR's acceptance criterion."""
        rng = np.random.default_rng(3)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4,
                              buckets_per_key=2 if dual else 1)
        state, resident = _filled_table(rng, cfg, 200)
        k = u64.from_uint64(resident[:64])
        counts = self._counters(monkeypatch)
        ops.find(state, cfg, k, backend="kernel")
        assert (counts["find_scan"], counts["digest_scan"],
                counts["gather"]) == (1, 0, 0)
        counts.update(find_scan=0)
        kops.find_composed_kernel(state, cfg, k, interpret=True)
        old = counts["digest_scan"] + counts["gather"]
        assert counts["digest_scan"] == (2 if dual else 1)
        assert counts["gather"] == 1
        assert old - 1 >= 1  # launches eliminated per find

    def test_find_ptr_stays_metadata_only(self, monkeypatch):
        """The pointer path must NOT ride the fused pass (no value
        traffic) — it takes the digest_scan locate."""
        rng = np.random.default_rng(4)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4)
        state, resident = _filled_table(rng, cfg, 100)
        k = u64.from_uint64(resident[:32])
        counts = self._counters(monkeypatch)
        ops.find_ptr(state, cfg, k, backend="kernel")
        assert counts == {"find_scan": 0, "digest_scan": 1, "gather": 0}


# =============================================================================
# find_many: batched multi-table lookup in one launch
# =============================================================================


@pytest.mark.parametrize("variant", VARIANTS)
def test_find_many_matches_per_table_finds(variant):
    rng = np.random.default_rng(29)
    cfg = table.HKVConfig(capacity=2 * 128, dim=8, buckets_per_key=2)
    states, keysets = [], []
    for _ in range(3):
        state, resident = _filled_table(rng, cfg, 250)
        states.append(state)
        keysets.append(u64.from_uint64(
            _query_batch(rng, resident, 40, 10, 5)))
    many = kops.find_many_kernel(states, cfg, keysets, variant=variant,
                                 interpret=True)
    assert len(many) == 3
    for t, (state, k) in enumerate(zip(states, keysets)):
        solo = kops.find_fused_kernel(state, cfg, k, variant=variant,
                                      interpret=True)
        for f in solo._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(many[t], f)),
                np.asarray(getattr(solo, f)),
                err_msg=f"{variant} table {t} {f}")
        _assert_fused_equal(many[t], state, cfg, k, f"{variant} many[{t}]")


def test_find_many_is_one_launch(monkeypatch):
    rng = np.random.default_rng(31)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4)
    states, keysets = [], []
    for _ in range(4):
        state, resident = _filled_table(rng, cfg, 150)
        states.append(state)
        keysets.append(u64.from_uint64(resident[:32]))
    counts = {"find_scan": 0}
    orig = _fs.find_scan_pipeline

    def counting(*a, **kw):
        counts["find_scan"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(_fs, "find_scan_pipeline", counting)
    kops.find_many_kernel(states, cfg, keysets, interpret=True)
    assert counts["find_scan"] == 1  # 4 tables, ONE launch


def test_find_many_validation():
    cfg = table.HKVConfig(capacity=2 * 128, dim=4)
    cfg_h = table.HKVConfig(capacity=2 * 128, dim=4, value_tier="hmem")
    k = u64.from_uint64(np.asarray([1], np.uint64))
    assert kops.find_many_kernel([], cfg, []) == []
    with pytest.raises(ValueError, match="hbm"):
        kops.find_many_kernel([table.create(cfg_h)], cfg_h, [k])
    other = table.create(table.HKVConfig(capacity=4 * 128, dim=4))
    with pytest.raises(ValueError, match="geometry"):
        kops.find_many_kernel([table.create(cfg), other], cfg, [k, k])
