"""Stateful differential fuzz: random op sequences vs the sequential oracle.

Random mixed op sequences — insert_or_assign / find / find_or_insert /
assign / update_rows (the structured gradient step) / accum_or_assign /
erase / clear, with duplicate keys, EMPTY padding, wide (high-plane)
keys, and mixed caller key FORMS (numpy uint64, signed int64 with
negative-as-padding, python int lists) — replay
against `core.oracle.OracleTable` on BOTH inserter backends (pure jnp and
the fused Pallas path in interpret mode).

After every op the full table state is drained and compared: key set,
values, AND scores must match the oracle exactly — any divergence is a
bug in the table code (the oracle is the spec; fixes land in the engine,
never by weakening the oracle).

Two drivers over ONE harness:
  * a hypothesis `RuleBasedStateMachine` (the fuzzer proper; skipped
    cleanly where hypothesis is absent, like the other property tests);
  * a seeded deterministic replay that always runs, so the differential
    harness itself is exercised in every environment.

Key forms go through `normalize_keys` (the production entry point); the
normalized planes then feed module-level jitted op wrappers so each
(op, backend) pair compiles once across all examples.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ops
from repro.core.api import HKVTable, normalize_keys
from repro.core.oracle import OracleTable
from repro.core.predicates import SweepPredicate
from repro.core.u64 import U64
from repro.embedding.sparse_opt import SparseOptimizer

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import RuleBasedStateMachine, invariant, rule

    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

CAP = 2 * 128
DIM = 4
LANES = 16                      # fixed batch width: one jit entry per op
EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)
POLICY = "lru"
DUAL = 2


# -- jitted op wrappers (state flows; cfg/backend ride the handle aux) --------


@jax.jit
def _upsert(t, kh, kl, v):
    r = t.insert_or_assign(U64(kh, kl), v)
    return r.table, r.status


@jax.jit
def _foi(t, kh, kl, init):
    r = t.find_or_insert(U64(kh, kl), init)
    return r.table, r.values, r.found, r.status


@jax.jit
def _find(t, kh, kl):
    r = t.find(U64(kh, kl))
    return r.values, r.found


@jax.jit
def _find_rows(t, kh, kl):
    r = t.find_rows(U64(kh, kl))
    return r.rows, r.found, r.score_hi, r.score_lo


@jax.jit
def _session_read(t, kh, kl, v):
    """Session-fused read mix: find + assign + find_rows + contains on ONE
    key batch share a single locate; on the kernel backend the value legs
    ride the fused find pass."""
    k = U64(kh, kl)
    s = t.session()
    f = s.find(k)
    s.assign(k, v)
    r = s.find_rows(k)
    c = s.contains(k)
    t2 = s.commit()
    fr, rr = f.get(), r.get()
    return (t2, fr.values, fr.found, rr.rows, rr.score_hi, rr.score_lo,
            c.get())


@jax.jit
def _assign(t, kh, kl, v):
    return t.assign(U64(kh, kl), v)


# lr=0.5 keeps the sgd step EXACT in float32 over the integer value/grad
# pools, so the oracle mirror is equality, not allclose
_OPT = SparseOptimizer("sgd", lr=0.5)


@jax.jit
def _session_update(t, kh, kl, g):
    """The apply_grads-shaped op: a structured RowUpdate committed through
    a session — the fused ONE-launch gradient step on backend='kernel'."""
    s = t.session()
    r = s.update_rows(U64(kh, kl), ops.RowUpdate(_OPT, g))
    t2 = s.commit()
    return t2, r.get().found


@jax.jit
def _accum(t, kh, kl, v):
    r = t.accum_or_assign(U64(kh, kl), v)
    return r.table, r.status


@jax.jit
def _erase(t, kh, kl):
    return t.erase(U64(kh, kl))


@jax.jit
def _clear(t):
    return t.clear()


@jax.jit
def _export(t):
    return t.export_batch(0, CAP // 128)


EVICT_BUDGET = 8


@jax.jit
def _erase_if(t, pred):
    r = t.erase_if(pred)
    return r.table, r.swept


@jax.jit
def _evict_if(t, pred):
    r = t.evict_if(pred, EVICT_BUDGET)
    return r.table, r.evicted, r.count


# =============================================================================
# The differential harness (hypothesis-free)
# =============================================================================


class DifferentialHarness:
    """One table+oracle pair; each op asserts result parity, and
    `check_state()` asserts full-contents parity (keys, values, scores)."""

    def __init__(self, backend: str):
        self.table = HKVTable.create(
            capacity=CAP, dim=DIM, buckets_per_key=DUAL,
            score_policy=POLICY, backend=backend)
        self.oracle = OracleTable(CAP, DIM, buckets_per_key=DUAL,
                                  policy=POLICY)

    @staticmethod
    def _planes(caller):
        k = normalize_keys(caller)               # the production entry point
        return k.hi, k.lo

    def upsert(self, canonical, caller, v):
        self.table, status = _upsert(self.table, *self._planes(caller),
                                     jnp.asarray(v))
        want = self.oracle.insert_or_assign(canonical, v)
        assert np.array_equal(np.asarray(status), np.asarray(want, np.int8))

    def find_or_insert(self, canonical, caller, v):
        self.table, vals, found, status = _foi(
            self.table, *self._planes(caller), jnp.asarray(v))
        want_st, want_vals = self.oracle.find_or_insert(canonical, v)
        assert np.array_equal(np.asarray(status), np.asarray(want_st, np.int8))
        assert np.array_equal(np.asarray(found),
                              np.asarray(want_st, np.int8) == 1)
        assert np.array_equal(np.asarray(vals), want_vals.astype(np.float32))

    def find(self, canonical, caller):
        vals, found = _find(self.table, *self._planes(caller))
        want_found, want_vals = self.oracle.find(canonical)
        assert np.array_equal(np.asarray(found), want_found)
        assert np.array_equal(np.asarray(vals), want_vals.astype(np.float32))

    def _lane_scores(self, canonical):
        """Per-lane (score_hi, score_lo) the read path must report: the
        oracle entry's score for resident keys, zero for misses/padding."""
        entries = {k: int(e.score) for k, e in self.oracle.items()}
        want = np.array([entries.get(int(k), 0) for k in canonical],
                        np.uint64)
        return ((want >> np.uint64(32)).astype(np.uint32),
                (want & np.uint64(0xFFFFFFFF)).astype(np.uint32))

    def find_rows(self, canonical, caller):
        rows, found, shi, slo = _find_rows(self.table, *self._planes(caller))
        want_found, want_vals = self.oracle.find(canonical)
        assert np.array_equal(np.asarray(found), want_found)
        assert np.array_equal(np.asarray(rows)[:, :DIM],
                              want_vals.astype(np.float32))
        wshi, wslo = self._lane_scores(canonical)
        assert np.array_equal(np.asarray(shi, np.uint32), wshi), \
            "find_rows score_hi"
        assert np.array_equal(np.asarray(slo, np.uint32), wslo), \
            "find_rows score_lo"

    def session_read(self, canonical, caller, v):
        (self.table, f_vals, f_found, rows, shi, slo, cont) = _session_read(
            self.table, *self._planes(caller), jnp.asarray(v))
        # session order is find -> assign -> find_rows/contains: the first
        # read sees pre-assign values, the second the assigned rows
        want_found, want_vals = self.oracle.find(canonical)
        assert np.array_equal(np.asarray(f_found), want_found)
        assert np.array_equal(np.asarray(f_vals),
                              want_vals.astype(np.float32))
        self.oracle.assign(canonical, v)
        want_found2, want_vals2 = self.oracle.find(canonical)
        assert np.array_equal(np.asarray(cont), want_found2)
        assert np.array_equal(np.asarray(rows)[:, :DIM],
                              want_vals2.astype(np.float32))
        wshi, wslo = self._lane_scores(canonical)
        assert np.array_equal(np.asarray(shi, np.uint32), wshi), \
            "session score_hi"
        assert np.array_equal(np.asarray(slo, np.uint32), wslo), \
            "session score_lo"

    def assign(self, canonical, caller, v):
        self.table = _assign(self.table, *self._planes(caller), jnp.asarray(v))
        self.oracle.assign(canonical, v)

    def update_rows(self, canonical, caller, g):
        """Structured gradient step.  PRECONDITION (the apply_grads
        contract): live lanes are unique — callers dedupe+segment-sum, so
        the fuzz drivers dedupe too.  Misses train nothing."""
        self.table, found = _session_update(
            self.table, *self._planes(caller), jnp.asarray(g))
        want_found, want_vals = self.oracle.find(canonical)
        assert np.array_equal(np.asarray(found), want_found), \
            "update_rows found mask"
        # sgd mirror: rows[k] -= lr*g on hit lanes; oracle.assign is
        # existing-only so miss/padding lanes are naturally ignored
        self.oracle.assign(canonical,
                           want_vals.astype(np.float32) - 0.5 * np.asarray(
                               g, np.float32))

    def accum(self, canonical, caller, v):
        self.table, status = _accum(self.table, *self._planes(caller),
                                    jnp.asarray(v))
        want = self.oracle.accum_or_assign(canonical, v)
        assert np.array_equal(np.asarray(status), np.asarray(want, np.int8))

    def erase(self, canonical, caller):
        self.table = _erase(self.table, *self._planes(caller))
        self.oracle.erase(canonical)

    def clear(self):
        self.table = _clear(self.table)
        self.oracle.clear()

    # predicated sweeps (kind, a, b) — the maintenance bulk ops.  The
    # oracle mirrors `match_planes` and the coldest-first rank order, so
    # swept counts AND the evicted stream must match lane-for-lane.

    @staticmethod
    def _pred(kind, a, b):
        if kind == "always":
            return SweepPredicate.always()
        if kind == "score_lt":
            return SweepPredicate.score_below(a)
        if kind == "score_ge":
            return SweepPredicate.score_at_least(a)
        if kind == "epoch_lt":
            return SweepPredicate.expire_before(a >> 32)
        return SweepPredicate.key_in_range(a, b)

    def erase_if(self, kind, a=0, b=0):
        self.table, swept = _erase_if(self.table, self._pred(kind, a, b))
        want = self.oracle.erase_if(kind, a, b)
        assert int(swept) == want, f"erase_if({kind}) count"

    def evict_if(self, kind, a=0, b=0):
        self.table, ev, count = _evict_if(self.table,
                                          self._pred(kind, a, b))
        want = self.oracle.evict_if(kind, EVICT_BUDGET, a, b)
        assert int(count) == len(want), f"evict_if({kind}) count"
        mask = np.asarray(ev.mask)
        keys = ((np.asarray(ev.key_hi, np.uint64) << np.uint64(32))
                | np.asarray(ev.key_lo, np.uint64))
        scores = ((np.asarray(ev.score_hi, np.uint64) << np.uint64(32))
                  | np.asarray(ev.score_lo, np.uint64))
        vals = np.asarray(ev.values)
        assert not mask[len(want):].any()
        for lane, (k, s, v) in enumerate(want):
            assert mask[lane]
            assert int(keys[lane]) == k, f"lane {lane} key"
            assert int(scores[lane]) == s, f"lane {lane} score"
            assert np.array_equal(vals[lane, :DIM],
                                  np.asarray(v, np.float32)[:DIM]), \
                f"lane {lane} value"

    def check_state(self):
        exp = _export(self.table)
        mask = np.asarray(exp.mask)
        keys = ((np.asarray(exp.key_hi, np.uint64) << np.uint64(32))
                | np.asarray(exp.key_lo, np.uint64))
        scores = ((np.asarray(exp.score_hi, np.uint64) << np.uint64(32))
                  | np.asarray(exp.score_lo, np.uint64))
        vals = np.asarray(exp.values)
        got = {int(k): (int(s), vals[i, :DIM])
               for i, (k, s, m) in enumerate(zip(keys, scores, mask)) if m}
        want = {k: (int(e.score), np.asarray(e.value, np.float32)[:DIM])
                for k, e in self.oracle.items()}
        assert set(got) == set(want), (
            f"key sets diverge: extra={sorted(set(got) - set(want))[:8]} "
            f"missing={sorted(set(want) - set(got))[:8]}")
        for k, (s, v) in got.items():
            ws, wv = want[k]
            assert s == ws, f"score diverges at key {k}: {s} != {ws}"
            assert np.array_equal(v, wv.astype(np.float32)), \
                f"value diverges at key {k}: {v} != {wv}"
        assert int(ops.size(self.table.state)) == self.oracle.size()


def to_caller_form(ids, form: str):
    """ids: python ints, negative = padding lane.  Returns (canonical
    uint64 [LANES], the caller-form key argument)."""
    ids = list(ids) + [-1] * (LANES - len(ids))
    canonical = np.array([EMPTY if i < 0 else np.uint64(i) for i in ids],
                         np.uint64)
    if form == "uint64":
        return canonical, canonical.copy()
    if form == "signed":
        return canonical, np.array(ids, np.int64)
    return canonical, list(ids)


OPS = ("upsert", "find_or_insert", "find", "find_rows", "session_read",
       "assign", "update_rows", "accum", "erase", "erase_if", "evict_if",
       "clear")
FORMS = ("uint64", "signed", "list")
PRED_KINDS = ("always", "score_lt", "score_ge", "epoch_lt", "key_range")


def random_pred_args(rng):
    """(kind, a, b) with operands sized to the harness's key/score pools
    (LRU clocks stay < ~200; keys live in [0, 61] plus the wide band)."""
    kind = PRED_KINDS[rng.integers(0, len(PRED_KINDS))]
    if kind in ("score_lt", "score_ge"):
        return kind, int(rng.integers(0, 80)), 0
    if kind == "epoch_lt":
        return kind, int(rng.integers(0, 2)) << 32, 0
    if kind == "key_range":
        lo = int(rng.integers(0, 61))
        return kind, lo, lo + int(rng.integers(1, 40))
    return kind, 0, 0


# =============================================================================
# Driver 1: seeded deterministic replay (always runs)
# =============================================================================


@pytest.mark.parametrize("backend", ["jnp", "kernel"])
def test_seeded_differential_replay(backend):
    rng = np.random.default_rng(2026)
    h = DifferentialHarness(backend)
    for step in range(60):
        op = OPS[rng.integers(0, len(OPS))] if step % 17 == 16 else \
            OPS[rng.integers(0, len(OPS) - 1)]   # clear is rare
        n = int(rng.integers(1, LANES + 1))
        ids = [int(x) for x in rng.integers(-2, 61, size=n)]
        if rng.random() < 0.2:   # wide keys: the high plane
            ids[0] = int(rng.integers(2**32, 2**32 + 5))
        if op == "update_rows":     # the dedupe precondition (see harness)
            ids = list(dict.fromkeys(ids))
        canonical, caller = to_caller_form(
            ids, FORMS[rng.integers(0, len(FORMS))])
        v = (rng.integers(0, 6, size=(LANES, 1)).astype(np.float32)
             * np.ones((1, DIM), np.float32))
        if op == "upsert":
            h.upsert(canonical, caller, v)
        elif op == "find_or_insert":
            h.find_or_insert(canonical, caller, v)
        elif op == "find":
            h.find(canonical, caller)
        elif op == "find_rows":
            h.find_rows(canonical, caller)
        elif op == "session_read":
            h.session_read(canonical, caller, v)
        elif op == "assign":
            h.assign(canonical, caller, v)
        elif op == "update_rows":
            h.update_rows(canonical, caller, v)
        elif op == "accum":
            h.accum(canonical, caller, v)
        elif op == "erase":
            h.erase(canonical, caller)
        elif op == "erase_if":
            h.erase_if(*random_pred_args(rng))
        elif op == "evict_if":
            h.evict_if(*random_pred_args(rng))
        else:
            h.clear()
        h.check_state()


# =============================================================================
# Driver 2: hypothesis stateful machine (the fuzzer proper)
# =============================================================================

def _pred_args(kind, a, span, ep):
    """Map drawn integers onto (kind, a, b) operands per predicate kind."""
    if kind == "epoch_lt":
        return kind, ep << 32, 0
    if kind == "key_range":
        return kind, a, a + span
    return kind, a, 0


if HAVE_HYPOTHESIS:
    _SMALL = st.integers(0, 60)                  # collision-heavy pool
    _WIDE = st.integers(2**32, 2**32 + 4)        # exercises the high plane
    _PAD = st.just(-1)                           # padding lane

    @st.composite
    def key_batch(draw):
        n = draw(st.integers(1, LANES))
        ids = draw(st.lists(st.one_of(_SMALL, _WIDE, _PAD),
                            min_size=n, max_size=n))
        return to_caller_form(ids, draw(st.sampled_from(FORMS)))

    @st.composite
    def unique_key_batch(draw):
        """Deduped lanes — the update_rows/apply_grads precondition."""
        ids = list(dict.fromkeys(draw(st.lists(
            st.one_of(_SMALL, _WIDE, _PAD), min_size=1, max_size=LANES))))
        return to_caller_form(ids, draw(st.sampled_from(FORMS)))

    @st.composite
    def value_batch(draw):
        vals = draw(st.lists(st.integers(0, 5),
                             min_size=LANES, max_size=LANES))
        return (np.array(vals, np.float32)[:, None]
                * np.ones((1, DIM), np.float32))

    class DifferentialMachine(RuleBasedStateMachine):
        backend = "jnp"

        def __init__(self):
            super().__init__()
            self.h = DifferentialHarness(self.backend)

        @rule(kb=key_batch(), v=value_batch())
        def upsert(self, kb, v):
            self.h.upsert(kb[0], kb[1], v)

        @rule(kb=key_batch(), v=value_batch())
        def find_or_insert(self, kb, v):
            self.h.find_or_insert(kb[0], kb[1], v)

        @rule(kb=key_batch())
        def find(self, kb):
            self.h.find(kb[0], kb[1])

        @rule(kb=key_batch())
        def find_rows(self, kb):
            self.h.find_rows(kb[0], kb[1])

        @rule(kb=key_batch(), v=value_batch())
        def session_read(self, kb, v):
            self.h.session_read(kb[0], kb[1], v)

        @rule(kb=key_batch(), v=value_batch())
        def assign(self, kb, v):
            self.h.assign(kb[0], kb[1], v)

        @rule(kb=unique_key_batch(), v=value_batch())
        def update_rows(self, kb, v):
            self.h.update_rows(kb[0], kb[1], v)

        @rule(kb=key_batch(), v=value_batch())
        def accum(self, kb, v):
            self.h.accum(kb[0], kb[1], v)

        @rule(kb=key_batch())
        def erase(self, kb):
            self.h.erase(kb[0], kb[1])

        @rule(kind=st.sampled_from(PRED_KINDS),
              a=st.integers(0, 80), span=st.integers(1, 40),
              ep=st.integers(0, 2))
        def erase_if(self, kind, a, span, ep):
            self.h.erase_if(*_pred_args(kind, a, span, ep))

        @rule(kind=st.sampled_from(PRED_KINDS),
              a=st.integers(0, 80), span=st.integers(1, 40),
              ep=st.integers(0, 2))
        def evict_if(self, kind, a, span, ep):
            self.h.evict_if(*_pred_args(kind, a, span, ep))

        @rule()
        def clear(self):
            self.h.clear()

        @invariant()
        def table_matches_oracle(self):
            self.h.check_state()

    class JnpDifferential(DifferentialMachine):
        backend = "jnp"

    class KernelDifferential(DifferentialMachine):
        backend = "kernel"

    # >= 25 examples total in the default (non-slow) suite
    _SETTINGS = settings(max_examples=15, stateful_step_count=10,
                         deadline=None, print_blob=True)

    TestJnpDifferential = JnpDifferential.TestCase
    TestJnpDifferential.settings = _SETTINGS
    TestKernelDifferential = KernelDifferential.TestCase
    TestKernelDifferential.settings = _SETTINGS


# =============================================================================
# Driver 3: telemetry neutrality (the obs PR's hard contract)
# =============================================================================
#
# The `telemetry=` seam must be a pure observer: op results bit-identical
# with the channel on or off, and `telemetry=None` (the default) must
# compile to exactly the same launch set — zero extra pallas_calls.


def _tel_wrappers():
    """Telemetry-on twins of the jitted op wrappers: the sink lives
    INSIDE the jitted fn (created per trace, returned as a pytree leaf
    set via `total()`), so accumulation composes with jit."""
    from repro.obs.telemetry import TelemetrySink

    @jax.jit
    def upsert_tel(t, kh, kl, v):
        sink = TelemetrySink()
        r = t.insert_or_assign(U64(kh, kl), v, telemetry=sink)
        return t_out(r.table, r.status), sink.total()

    @jax.jit
    def foi_tel(t, kh, kl, init):
        sink = TelemetrySink()
        r = t.find_or_insert(U64(kh, kl), init, telemetry=sink)
        return t_out(r.table, r.values, r.found, r.status), sink.total()

    @jax.jit
    def find_tel(t, kh, kl):
        sink = TelemetrySink()
        r = t.find(U64(kh, kl), telemetry=sink)
        return t_out(r.values, r.found), sink.total()

    @jax.jit
    def erase_tel(t, kh, kl):
        sink = TelemetrySink()
        return t_out(t.erase(U64(kh, kl), telemetry=sink)), sink.total()

    def t_out(*xs):
        return xs if len(xs) > 1 else xs[0]

    return upsert_tel, foi_tel, find_tel, erase_tel


@pytest.mark.parametrize("backend", ["jnp", "kernel"])
def test_telemetry_on_replay_is_bit_identical(backend):
    """Two identical tables driven by the same seeded op sequence — one
    through the plain wrappers, one with a TelemetrySink threaded.  Every
    result and the drained end state must match bit-for-bit, and the
    sink must actually have observed the traffic."""
    upsert_tel, foi_tel, find_tel, erase_tel = _tel_wrappers()
    rng = np.random.default_rng(777)
    t_plain = HKVTable.create(capacity=CAP, dim=DIM, buckets_per_key=DUAL,
                              score_policy=POLICY, backend=backend)
    t_tel = HKVTable.create(capacity=CAP, dim=DIM, buckets_per_key=DUAL,
                            score_policy=POLICY, backend=backend)
    lanes_seen = 0
    for step in range(24):
        n = int(rng.integers(1, LANES + 1))
        ids = [int(x) for x in rng.integers(-2, 61, size=n)]
        if rng.random() < 0.2:
            ids[0] = int(rng.integers(2**32, 2**32 + 5))
        canonical, _ = to_caller_form(ids, "uint64")
        k = normalize_keys(canonical)
        v = (rng.integers(0, 6, size=(LANES, 1)).astype(np.float32)
             * np.ones((1, DIM), np.float32))
        op = step % 4
        if op == 0:
            t_plain, st_p = _upsert(t_plain, k.hi, k.lo, jnp.asarray(v))
            (t_tel, st_t), tel = upsert_tel(t_tel, k.hi, k.lo,
                                            jnp.asarray(v))
            assert np.array_equal(np.asarray(st_p), np.asarray(st_t))
        elif op == 1:
            t_plain, vals_p, f_p, st_p = _foi(t_plain, k.hi, k.lo,
                                              jnp.asarray(v))
            (t_tel, vals_t, f_t, st_t), tel = foi_tel(t_tel, k.hi, k.lo,
                                                      jnp.asarray(v))
            assert np.array_equal(np.asarray(vals_p), np.asarray(vals_t))
            assert np.array_equal(np.asarray(f_p), np.asarray(f_t))
            assert np.array_equal(np.asarray(st_p), np.asarray(st_t))
        elif op == 2:
            vals_p, f_p = _find(t_plain, k.hi, k.lo)
            (vals_t, f_t), tel = find_tel(t_tel, k.hi, k.lo)
            assert np.array_equal(np.asarray(vals_p), np.asarray(vals_t))
            assert np.array_equal(np.asarray(f_p), np.asarray(f_t))
        else:
            t_plain = _erase(t_plain, k.hi, k.lo)
            t_tel, tel = erase_tel(t_tel, k.hi, k.lo)
        lanes_seen += int(np.asarray(tel.lanes))
        # bit-identity of the full state after every mutating step
        ep, et = _export(t_plain), _export(t_tel)
        for field in ep._fields:
            assert np.array_equal(np.asarray(getattr(ep, field)),
                                  np.asarray(getattr(et, field))), \
                f"state field {field} diverged at step {step} ({backend})"
    assert lanes_seen > 0   # the sink really observed the traffic


def test_telemetry_none_compiles_to_same_launch_set():
    """`telemetry=None` (the default) must add ZERO pallas_calls: the
    jaxpr of the kernel-backed find with the kwarg spelled out equals the
    kwarg-free jaxpr — same equation count, same number of pallas_call
    primitives (the launch-count pin, same accounting as
    test_find_kernel.py::TestLaunchBudget)."""
    t = HKVTable.create(capacity=CAP, dim=DIM, buckets_per_key=DUAL,
                        score_policy=POLICY, backend="kernel")
    k = normalize_keys(np.arange(1, LANES + 1, dtype=np.uint64))

    def n_pallas(jaxpr):
        return sum(1 for eqn in jaxpr.jaxpr.eqns
                   if "pallas" in eqn.primitive.name)

    plain = jax.make_jaxpr(lambda tt, kh, kl: _count_probe(tt, kh, kl))(
        t, k.hi, k.lo)
    spelled = jax.make_jaxpr(
        lambda tt, kh, kl: _count_probe(tt, kh, kl, telemetry=None))(
        t, k.hi, k.lo)
    assert n_pallas(plain) == n_pallas(spelled)
    assert len(plain.jaxpr.eqns) == len(spelled.jaxpr.eqns)


def _count_probe(tt, kh, kl, **kw):
    r = tt.find(U64(kh, kl), **kw)
    return r.values, r.found
