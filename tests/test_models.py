"""Per-arch reduced-config smoke tests + model-machinery correctness.

Every assigned architecture instantiates a structure-preserving smoke
config and runs one forward/train step on CPU (shape + finiteness), plus a
prefill-vs-decode consistency check on a tiny homogeneous model.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.models import ssm
from repro.models.common import blocked_causal_attention
from repro.models.moe import MoECfg, moe_apply, moe_init


def _batch_for(arch, b=2, s=32):
    rng = np.random.default_rng(0)
    vocab = arch.smoke.vocab
    toks = jnp.asarray(rng.integers(0, vocab, size=(b, s)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, vocab, size=(b, s)), jnp.int32)
    kwargs = {}
    if arch.lm.frontend == "vision":
        sv = 8
        kwargs["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(b, sv, arch.smoke.d_model)), jnp.float32
        )
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        kwargs["mrope_positions"] = jnp.broadcast_to(pos[None], (3, b, s))
    return toks, labels, kwargs


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_grad(name):
    arch = get_arch(name)
    model = arch.model(smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    toks, labels, kwargs = _batch_for(arch)

    def loss_fn(p):
        loss, aux = model.loss(p, toks, labels, **kwargs)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    # sane LM init: loss ~ log(vocab)
    assert float(loss) < np.log(arch.smoke.vocab) * 3
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_decode_shapes(name):
    arch = get_arch(name)
    model = arch.model(smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    b = 2
    state = model.init_decode_state(batch=b, max_len=16)
    toks = jnp.zeros((b,), jnp.int32)
    if arch.lm.embedding_backend == "hkv":
        pytest.skip("hkv decode covered in integration test")
    logits, state = model.decode_step(params, toks, state)
    assert logits.shape == (b, arch.smoke.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, _ = model.decode_step(params, toks, state)
    assert np.isfinite(np.asarray(logits2)).all()


def test_prefill_matches_decode():
    """Running prefill(t0..t_{n-1}) then decode(t_n) must equal prefill of
    the full sequence — KV caches, ring buffers and recurrent states agree."""
    for name in ("qwen2-0.5b", "zamba2-1.2b", "xlstm-1.3b", "h2o-danube-1.8b",
                 "musicgen-medium"):
        arch = get_arch(name)
        model = arch.model(smoke=True)
        params = model.init(jax.random.PRNGKey(1))
        rng = np.random.default_rng(2)
        toks = jnp.asarray(rng.integers(0, arch.smoke.vocab, size=(1, 12)), jnp.int32)
        max_len = 16
        # full prefill of 12 tokens: logits predict token 13
        full_logits, _ = model.prefill(params, toks, max_len)
        # prefill 11 tokens, decode the 12th
        part_logits, state = model.prefill(params, toks[:, :-1], max_len)
        dec_logits, state = model.decode_step(params, toks[:, -1], state)
        np.testing.assert_allclose(
            np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2,
        )


def test_blocked_attention_matches_naive():
    rng = np.random.default_rng(3)
    b, s, h, dh = 2, 64, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, dh)), jnp.float32)

    def naive(q, k, v, window=None):
        kk = jnp.repeat(k, h // k.shape[2], axis=2)
        vv = jnp.repeat(v, h // v.shape[2], axis=2)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(dh)
        pos = np.arange(s)
        mask = pos[:, None] >= pos[None, :]
        if window:
            mask &= (pos[:, None] - pos[None, :]) < window
        sc = jnp.where(jnp.asarray(mask)[None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vv)

    for window in (None, 16):
        for qc, kc in ((16, 16), (64, 32), (8, 64)):
            got = blocked_causal_attention(q, k, v, window=window, q_chunk=qc, kv_chunk=kc)
            want = naive(q, k, v, window)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4)


def test_chunked_gla_matches_sequential():
    rng = np.random.default_rng(4)
    b, s, h, n, p = 2, 37, 3, 8, 5
    q = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))) * 0.2, jnp.float32)
    for chunk in (8, 16, 64):
        y, st = ssm.chunked_gla(q, k, v, log_a, chunk=chunk)
        y_ref, st_ref = ssm.gla_reference(q, k, v, log_a)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=1e-4, atol=1e-4)


def test_moe_capacity_and_combination():
    rng = np.random.default_rng(5)
    cfg = MoECfg(num_experts=4, top_k=2, d_model=16, d_ff=32)
    params = moe_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    y, aux = moe_apply(cfg, params, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["load_balance"]) > 0
    assert 0.0 <= float(aux["dropped_frac"]) < 0.5


def test_param_counts_in_expected_range():
    """Full configs must land near their nominal sizes (catches config typos)."""
    expect = {
        "gemma-2b": (2.0e9, 3.3e9),
        "qwen2-0.5b": (0.4e9, 0.7e9),
        "yi-6b": (5.5e9, 7.0e9),
        "h2o-danube-1.8b": (1.5e9, 2.1e9),
        # assigned 48L x 64e (overrides upstream 27L): ~28B total, ~3B active
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
        "musicgen-medium": (1.3e9, 2.1e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "llama4-maverick-400b-a17b": (330e9, 440e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
