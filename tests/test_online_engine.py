"""OnlineEmbeddingEngine + publisher: miss-policy matrix, swap atomicity,
metrics sanity against the oracle, and the delta publication path."""

import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import HKVTable, TieredHKVTable
from repro.core.oracle import OracleTable
from repro.serving import (EmbeddingRequest, OnlineEmbeddingEngine,
                           OnlineTrainer, StaticSource, TablePublisher,
                           export_delta, ingest_delta)

DIM = 4
EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)


def _tiered_with_cold_resident(keys_cold):
    """A tiered table where `keys_cold` live ONLY in the cold tier (forced
    there by demotion from a tiny hot tier, then hot cleared via erase of
    a disjoint filler set is fragile — instead upsert into cold directly
    through the tier handles)."""
    t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                              dim=DIM)
    r = t.cold.insert_or_assign(
        keys_cold, jnp.ones((len(keys_cold), DIM)),
        custom_scores=np.arange(1, len(keys_cold) + 1, dtype=np.uint64))
    return t.with_tiers(t.hot, r.table)


class TestMissPolicyMatrix:
    KEYS = np.arange(1, 17, dtype=np.uint64)

    def _serve_once(self, table, policy, promote):
        eng = OnlineEmbeddingEngine(table, wave_size=32, miss_policy=policy,
                                    promote=promote)
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.run_until_drained()
        return eng, eng.completed[0]

    def test_readonly_pure_reader_leaves_table_untouched(self):
        t = _tiered_with_cold_resident(self.KEYS)
        eng, req = self._serve_once(t, "readonly", promote=False)
        assert req.found.all()                   # served from the cold tier
        src = eng.source.table
        assert src is t                          # no successor was installed
        assert not bool(np.asarray(t.hot.contains(self.KEYS)).any())

    def test_readonly_promote_reinstalls_cold_hits_hot(self):
        t = _tiered_with_cold_resident(self.KEYS)
        eng, req = self._serve_once(t, "readonly", promote=True)
        assert req.found.all()
        succ = eng.source.table
        assert succ is not t
        assert bool(np.asarray(succ.hot.contains(self.KEYS)).all())

    def test_readonly_misses_get_default_rows_and_stay_out(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        eng, req = self._serve_once(t, "readonly", promote=True)
        assert not req.found.any()
        assert np.allclose(req.values, 0.0)      # default vector fallback
        # reject policy: misses were NOT admitted
        eng2, req2 = self._serve_once(eng.source.table, "readonly",
                                      promote=True)
        assert not req2.found.any()

    def test_admit_installs_misses_for_the_next_wave(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        eng, req = self._serve_once(t, "admit", promote=False)
        assert not req.found.any()               # first sight: all misses
        eng2, req2 = self._serve_once(eng.source.table, "admit",
                                      promote=False)
        assert req2.found.all()                  # admitted by wave 1
        # stored rows are the admit-time init rows
        assert np.allclose(req2.values, req.values)

    def test_custom_default_row_feeds_miss_values_and_admission(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(
            t, wave_size=32, miss_policy="admit",
            default_row=lambda k: jnp.full((k.hi.shape[0], DIM), 2.5))
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.run_until_drained()
        assert np.allclose(eng.completed[0].values, 2.5)
        f = eng.source.table.find(self.KEYS)
        assert np.allclose(np.asarray(f.values), 2.5)


class TestWavePacking:
    def test_large_request_spans_waves_and_small_ones_pack(self):
        t = HKVTable.create(capacity=4 * 128, dim=DIM)
        keys = np.arange(1, 101, dtype=np.uint64)
        t = t.insert_or_assign(keys, jnp.asarray(
            np.tile(keys.astype(np.float32)[:, None], (1, DIM)))).table
        eng = OnlineEmbeddingEngine(t, wave_size=32, miss_policy="readonly")
        big = EmbeddingRequest(rid=0, keys=keys)          # 100 keys: 4 waves
        small = [EmbeddingRequest(rid=i + 1,
                                  keys=np.array([i + 1], np.uint64))
                 for i in range(3)]
        eng.submit(big)
        for r in small:
            eng.submit(r)
        done = eng.run_until_drained()
        assert {r.rid for r in done} == {0, 1, 2, 3}
        assert big.done and big.found.all()
        for j in range(DIM):
            assert np.array_equal(big.values[:, j],
                                  keys.astype(np.float32))
        m = eng.metrics()
        assert m.keys == 103 and m.hits == 103
        assert m.waves == 4                       # 100 + 3 packed into 4*32
        assert m.kv_per_s > 0 and m.p99_latency_s >= m.p50_latency_s


class TestPublisherAtomicity:
    def test_reader_never_observes_a_half_published_table(self):
        """Stamped tables: version i's table holds value-stamp i in every
        row.  A racing reader must always see ONE stamp across its whole
        find — a torn publish would mix stamps."""
        keys = np.arange(1, 33, dtype=np.uint64)
        base = HKVTable.create(capacity=2 * 128, dim=DIM)
        base = base.insert_or_assign(
            keys, jnp.zeros((len(keys), DIM))).table
        stamped = [base]
        for i in range(1, 12):
            stamped.append(
                base.assign(keys, jnp.full((len(keys), DIM), float(i))))
        pub = TablePublisher(stamped[0])
        stop = threading.Event()
        torn = []

        def reader():
            while not stop.is_set():
                version, t = pub.snapshot()
                vals = np.asarray(t.find(keys).values)
                uniq = np.unique(vals)
                if len(uniq) != 1:
                    torn.append(("mixed-stamps", version, uniq))
                elif int(uniq[0]) != version:
                    torn.append(("stamp-version-mismatch", version, uniq))

        threads = [threading.Thread(target=reader) for _ in range(2)]
        for th in threads:
            th.start()
        for i in range(1, 12):
            assert pub.publish(stamped[i]) == i
        stop.set()
        for th in threads:
            th.join()
        assert not torn, torn[:3]
        assert pub.version == 11

    def test_offer_is_beaten_by_a_concurrent_publish(self):
        t0 = HKVTable.create(capacity=2 * 128, dim=DIM)
        pub = TablePublisher(t0)
        v, t = pub.snapshot()
        t1 = pub.publish(
            t0.insert_or_assign(np.arange(4, dtype=np.uint64),
                                jnp.ones((4, DIM))).table)
        # the engine's offer from the stale snapshot must be rejected
        stale_succ = t0.insert_or_assign(
            np.arange(10, 14, dtype=np.uint64), jnp.ones((4, DIM))).table
        assert not pub.offer(v, stale_succ)
        assert pub.rejected_offers == 1
        assert bool(np.asarray(pub.table.contains(
            np.arange(4, dtype=np.uint64))).all())
        # a fresh-snapshot offer applies
        v2, t2 = pub.snapshot()
        assert pub.offer(v2, stale_succ)
        assert pub.version == v2 + 1

    def test_engine_waves_never_mix_versions(self):
        """Each wave records the version it served from; the stamp of the
        rows it returned must match that version exactly."""
        keys = np.arange(1, 17, dtype=np.uint64)
        base = HKVTable.create(capacity=2 * 128, dim=DIM)
        base = base.insert_or_assign(keys, jnp.zeros((len(keys), DIM))).table
        pub = TablePublisher(base)
        eng = OnlineEmbeddingEngine(pub, wave_size=16,
                                    miss_policy="readonly")
        for i in range(5):
            eng.submit(EmbeddingRequest(rid=i, keys=keys.copy()))
            eng.step()
            pub.publish(base.assign(keys,
                                    jnp.full((len(keys), DIM), float(i + 1))))
        for i, (req, rep) in enumerate(zip(eng.completed, eng.reports)):
            stamps = np.unique(req.values)
            assert len(stamps) == 1
            assert int(stamps[0]) == rep.table_version == i


class TestMetricsMatchOracle:
    def test_hit_rate_matches_oracle_replay(self):
        """Admit-policy waves over a flat table vs OracleTable replaying
        the same batches: per-wave hit counts must agree exactly."""
        rng = np.random.default_rng(5)
        cap, wave = 2 * 128, 32
        t = HKVTable.create(capacity=cap, dim=DIM, buckets_per_key=2)
        orc = OracleTable(cap, DIM, buckets_per_key=2, policy="lru")
        eng = OnlineEmbeddingEngine(t, wave_size=wave, miss_policy="admit")
        zeros = np.zeros((wave, DIM), np.float32)
        for i in range(12):
            keys = rng.integers(0, 3 * cap, size=wave).astype(np.uint64)
            eng.submit(EmbeddingRequest(rid=i, keys=keys))
            rep = eng.step()
            st, _ = orc.find_or_insert(keys, zeros)
            want_hits = int(np.sum(np.asarray(st) == 1))
            assert rep.hits == want_hits, f"wave {i}"
            assert rep.size == wave
        m = eng.metrics()
        assert m.waves == 12 and m.keys == 12 * wave
        assert m.hits == sum(r.hits for r in eng.reports)
        assert 0.0 < m.hit_rate < 1.0


class TestTrainerAndDelta:
    def test_trainer_session_updates_are_visible_to_the_engine(self):
        pub = TablePublisher(HKVTable.create(capacity=2 * 128, dim=DIM))
        tr = OnlineTrainer(publisher=pub, publish_every=1, lr=0.5)
        keys = np.arange(1, 9, dtype=np.uint64)
        for _ in range(3):
            tr.train_step(keys, jnp.ones((len(keys), DIM)))
        eng = OnlineEmbeddingEngine(pub, wave_size=16,
                                    miss_policy="readonly")
        eng.submit(EmbeddingRequest(rid=0, keys=keys))
        eng.run_until_drained()
        req = eng.completed[0]
        assert req.found.all()
        assert np.allclose(req.values, -1.5)      # 3 steps * lr .5 * grad 1

    @pytest.mark.parametrize("src", ["flat", "tiered"])
    def test_export_ingest_delta_roundtrip(self, src):
        keys = np.arange(1, 151, dtype=np.uint64)
        vals = jnp.asarray(np.tile(keys.astype(np.float32)[:, None],
                                   (1, DIM)))
        if src == "flat":
            t = HKVTable.create(capacity=2 * 128, dim=DIM).insert_or_assign(
                keys, vals).table
        else:
            t = TieredHKVTable.create(hot_capacity=128,
                                      cold_capacity=2 * 128,
                                      dim=DIM).insert_or_assign(
                keys, vals).table
        delta = export_delta(t, chunk_buckets=1)
        assert delta.count == 150
        dst = ingest_delta(HKVTable.create(capacity=4 * 128, dim=DIM), delta,
                           batch=64)
        f = dst.find(keys)
        assert bool(np.asarray(f.found).all())
        assert np.allclose(np.asarray(f.values), np.asarray(vals))

    def test_delta_carry_scores_into_custom_policy(self):
        keys = np.arange(1, 17, dtype=np.uint64)
        scores = keys * np.uint64(10)
        t = HKVTable.create(capacity=2 * 128, dim=DIM,
                            score_policy="custom")
        t = t.insert_or_assign(keys, jnp.ones((len(keys), DIM)),
                               custom_scores=scores).table
        delta = export_delta(t)
        assert np.array_equal(np.sort(delta.scores),
                              np.sort(scores.astype(np.uint64)))
        dst = ingest_delta(
            HKVTable.create(capacity=2 * 128, dim=DIM,
                            score_policy="custom"),
            delta, carry_scores=True)
        exp = export_delta(dst)
        assert np.array_equal(
            np.sort(exp.scores), np.sort(scores.astype(np.uint64)))
        # the documented tiered destination (custom-policy hot tier)
        tiered_dst = ingest_delta(
            TieredHKVTable.create(hot_capacity=2 * 128,
                                  cold_capacity=4 * 128, dim=DIM,
                                  score_policy="custom"),
            delta, carry_scores=True)
        texp = export_delta(tiered_dst)
        assert np.array_equal(
            np.sort(texp.scores), np.sort(scores.astype(np.uint64)))
        assert bool(np.asarray(
            tiered_dst.contains(keys)).all())


class TestStaticSource:
    def test_static_source_accepts_every_offer(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        s = StaticSource(t)
        v, tt = s.snapshot()
        assert v == 0 and tt is t
        t2 = t.insert_or_assign(np.arange(4, dtype=np.uint64),
                                jnp.ones((4, DIM))).table
        assert s.offer(v, t2)
        assert s.table is t2
