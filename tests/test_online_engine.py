"""OnlineEmbeddingEngine + publisher: miss-policy matrix, swap atomicity,
metrics sanity against the oracle, and the delta publication path."""

import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import HKVTable, TieredHKVTable
from repro.core.oracle import OracleTable
from repro.serving import (EmbeddingRequest, OnlineEmbeddingEngine,
                           OnlineTrainer, StaticSource, TablePublisher,
                           export_delta, ingest_delta)

DIM = 4
EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)


def _tiered_with_cold_resident(keys_cold):
    """A tiered table where `keys_cold` live ONLY in the cold tier (forced
    there by demotion from a tiny hot tier, then hot cleared via erase of
    a disjoint filler set is fragile — instead upsert into cold directly
    through the tier handles)."""
    t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                              dim=DIM)
    r = t.cold.insert_or_assign(
        keys_cold, jnp.ones((len(keys_cold), DIM)),
        custom_scores=np.arange(1, len(keys_cold) + 1, dtype=np.uint64))
    return t.with_tiers(t.hot, r.table)


class TestMissPolicyMatrix:
    KEYS = np.arange(1, 17, dtype=np.uint64)

    def _serve_once(self, table, policy, promote):
        eng = OnlineEmbeddingEngine(table, wave_size=32, miss_policy=policy,
                                    promote=promote)
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.run_until_drained()
        return eng, eng.completed[0]

    def test_readonly_pure_reader_leaves_table_untouched(self):
        t = _tiered_with_cold_resident(self.KEYS)
        eng, req = self._serve_once(t, "readonly", promote=False)
        assert req.found.all()                   # served from the cold tier
        src = eng.source.table
        assert src is t                          # no successor was installed
        assert not bool(np.asarray(t.hot.contains(self.KEYS)).any())

    def test_readonly_promote_reinstalls_cold_hits_hot(self):
        t = _tiered_with_cold_resident(self.KEYS)
        eng, req = self._serve_once(t, "readonly", promote=True)
        assert req.found.all()
        succ = eng.source.table
        assert succ is not t
        assert bool(np.asarray(succ.hot.contains(self.KEYS)).all())

    def test_readonly_misses_get_default_rows_and_stay_out(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        eng, req = self._serve_once(t, "readonly", promote=True)
        assert not req.found.any()
        assert np.allclose(req.values, 0.0)      # default vector fallback
        # reject policy: misses were NOT admitted
        eng2, req2 = self._serve_once(eng.source.table, "readonly",
                                      promote=True)
        assert not req2.found.any()

    def test_admit_installs_misses_for_the_next_wave(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        eng, req = self._serve_once(t, "admit", promote=False)
        assert not req.found.any()               # first sight: all misses
        eng2, req2 = self._serve_once(eng.source.table, "admit",
                                      promote=False)
        assert req2.found.all()                  # admitted by wave 1
        # stored rows are the admit-time init rows
        assert np.allclose(req2.values, req.values)

    def test_custom_default_row_feeds_miss_values_and_admission(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(
            t, wave_size=32, miss_policy="admit",
            default_row=lambda k: jnp.full((k.hi.shape[0], DIM), 2.5))
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.run_until_drained()
        assert np.allclose(eng.completed[0].values, 2.5)
        f = eng.source.table.find(self.KEYS)
        assert np.allclose(np.asarray(f.values), 2.5)


class TestWavePacking:
    def test_large_request_spans_waves_and_small_ones_pack(self):
        t = HKVTable.create(capacity=4 * 128, dim=DIM)
        keys = np.arange(1, 101, dtype=np.uint64)
        t = t.insert_or_assign(keys, jnp.asarray(
            np.tile(keys.astype(np.float32)[:, None], (1, DIM)))).table
        eng = OnlineEmbeddingEngine(t, wave_size=32, miss_policy="readonly")
        big = EmbeddingRequest(rid=0, keys=keys)          # 100 keys: 4 waves
        small = [EmbeddingRequest(rid=i + 1,
                                  keys=np.array([i + 1], np.uint64))
                 for i in range(3)]
        eng.submit(big)
        for r in small:
            eng.submit(r)
        done = eng.run_until_drained()
        assert {r.rid for r in done} == {0, 1, 2, 3}
        assert big.done and big.found.all()
        for j in range(DIM):
            assert np.array_equal(big.values[:, j],
                                  keys.astype(np.float32))
        m = eng.metrics()
        assert m.keys == 103 and m.hits == 103
        assert m.waves == 4                       # 100 + 3 packed into 4*32
        assert m.kv_per_s > 0 and m.p99_latency_s >= m.p50_latency_s


class TestPublisherAtomicity:
    def test_reader_never_observes_a_half_published_table(self):
        """Stamped tables: version i's table holds value-stamp i in every
        row.  A racing reader must always see ONE stamp across its whole
        find — a torn publish would mix stamps."""
        keys = np.arange(1, 33, dtype=np.uint64)
        base = HKVTable.create(capacity=2 * 128, dim=DIM)
        base = base.insert_or_assign(
            keys, jnp.zeros((len(keys), DIM))).table
        stamped = [base]
        for i in range(1, 12):
            stamped.append(
                base.assign(keys, jnp.full((len(keys), DIM), float(i))))
        pub = TablePublisher(stamped[0])
        stop = threading.Event()
        torn = []

        def reader():
            while not stop.is_set():
                version, t = pub.snapshot()
                vals = np.asarray(t.find(keys).values)
                uniq = np.unique(vals)
                if len(uniq) != 1:
                    torn.append(("mixed-stamps", version, uniq))
                elif int(uniq[0]) != version:
                    torn.append(("stamp-version-mismatch", version, uniq))

        threads = [threading.Thread(target=reader) for _ in range(2)]
        for th in threads:
            th.start()
        for i in range(1, 12):
            assert pub.publish(stamped[i]) == i
        stop.set()
        for th in threads:
            th.join()
        assert not torn, torn[:3]
        assert pub.version == 11

    def test_offer_is_beaten_by_a_concurrent_publish(self):
        t0 = HKVTable.create(capacity=2 * 128, dim=DIM)
        pub = TablePublisher(t0)
        v, t = pub.snapshot()
        t1 = pub.publish(
            t0.insert_or_assign(np.arange(4, dtype=np.uint64),
                                jnp.ones((4, DIM))).table)
        # the engine's offer from the stale snapshot must be rejected
        stale_succ = t0.insert_or_assign(
            np.arange(10, 14, dtype=np.uint64), jnp.ones((4, DIM))).table
        assert not pub.offer(v, stale_succ)
        assert pub.rejected_offers == 1
        assert bool(np.asarray(pub.table.contains(
            np.arange(4, dtype=np.uint64))).all())
        # a fresh-snapshot offer applies
        v2, t2 = pub.snapshot()
        assert pub.offer(v2, stale_succ)
        assert pub.version == v2 + 1

    def test_engine_waves_never_mix_versions(self):
        """Each wave records the version it served from; the stamp of the
        rows it returned must match that version exactly."""
        keys = np.arange(1, 17, dtype=np.uint64)
        base = HKVTable.create(capacity=2 * 128, dim=DIM)
        base = base.insert_or_assign(keys, jnp.zeros((len(keys), DIM))).table
        pub = TablePublisher(base)
        eng = OnlineEmbeddingEngine(pub, wave_size=16,
                                    miss_policy="readonly")
        for i in range(5):
            eng.submit(EmbeddingRequest(rid=i, keys=keys.copy()))
            eng.step()
            pub.publish(base.assign(keys,
                                    jnp.full((len(keys), DIM), float(i + 1))))
        for i, (req, rep) in enumerate(zip(eng.completed, eng.reports)):
            stamps = np.unique(req.values)
            assert len(stamps) == 1
            assert int(stamps[0]) == rep.table_version == i


class TestMetricsMatchOracle:
    def test_hit_rate_matches_oracle_replay(self):
        """Admit-policy waves over a flat table vs OracleTable replaying
        the same batches: per-wave hit counts must agree exactly."""
        rng = np.random.default_rng(5)
        cap, wave = 2 * 128, 32
        t = HKVTable.create(capacity=cap, dim=DIM, buckets_per_key=2)
        orc = OracleTable(cap, DIM, buckets_per_key=2, policy="lru")
        eng = OnlineEmbeddingEngine(t, wave_size=wave, miss_policy="admit")
        zeros = np.zeros((wave, DIM), np.float32)
        for i in range(12):
            keys = rng.integers(0, 3 * cap, size=wave).astype(np.uint64)
            eng.submit(EmbeddingRequest(rid=i, keys=keys))
            rep = eng.step()
            st, _ = orc.find_or_insert(keys, zeros)
            want_hits = int(np.sum(np.asarray(st) == 1))
            assert rep.hits == want_hits, f"wave {i}"
            assert rep.size == wave
        m = eng.metrics()
        assert m.waves == 12 and m.keys == 12 * wave
        assert m.hits == sum(r.hits for r in eng.reports)
        assert 0.0 < m.hit_rate < 1.0


class TestTrainerAndDelta:
    def test_trainer_session_updates_are_visible_to_the_engine(self):
        pub = TablePublisher(HKVTable.create(capacity=2 * 128, dim=DIM))
        tr = OnlineTrainer(publisher=pub, publish_every=1, lr=0.5)
        keys = np.arange(1, 9, dtype=np.uint64)
        for _ in range(3):
            tr.train_step(keys, jnp.ones((len(keys), DIM)))
        eng = OnlineEmbeddingEngine(pub, wave_size=16,
                                    miss_policy="readonly")
        eng.submit(EmbeddingRequest(rid=0, keys=keys))
        eng.run_until_drained()
        req = eng.completed[0]
        assert req.found.all()
        assert np.allclose(req.values, -1.5)      # 3 steps * lr .5 * grad 1

    @pytest.mark.parametrize("src", ["flat", "tiered"])
    def test_export_ingest_delta_roundtrip(self, src):
        keys = np.arange(1, 151, dtype=np.uint64)
        vals = jnp.asarray(np.tile(keys.astype(np.float32)[:, None],
                                   (1, DIM)))
        if src == "flat":
            t = HKVTable.create(capacity=2 * 128, dim=DIM).insert_or_assign(
                keys, vals).table
        else:
            t = TieredHKVTable.create(hot_capacity=128,
                                      cold_capacity=2 * 128,
                                      dim=DIM).insert_or_assign(
                keys, vals).table
        delta = export_delta(t, chunk_buckets=1)
        assert delta.count == 150
        dst = ingest_delta(HKVTable.create(capacity=4 * 128, dim=DIM), delta,
                           batch=64)
        f = dst.find(keys)
        assert bool(np.asarray(f.found).all())
        assert np.allclose(np.asarray(f.values), np.asarray(vals))

    def test_delta_carry_scores_into_custom_policy(self):
        keys = np.arange(1, 17, dtype=np.uint64)
        scores = keys * np.uint64(10)
        t = HKVTable.create(capacity=2 * 128, dim=DIM,
                            score_policy="custom")
        t = t.insert_or_assign(keys, jnp.ones((len(keys), DIM)),
                               custom_scores=scores).table
        delta = export_delta(t)
        assert np.array_equal(np.sort(delta.scores),
                              np.sort(scores.astype(np.uint64)))
        dst = ingest_delta(
            HKVTable.create(capacity=2 * 128, dim=DIM,
                            score_policy="custom"),
            delta, carry_scores=True)
        exp = export_delta(dst)
        assert np.array_equal(
            np.sort(exp.scores), np.sort(scores.astype(np.uint64)))
        # the documented tiered destination (custom-policy hot tier)
        tiered_dst = ingest_delta(
            TieredHKVTable.create(hot_capacity=2 * 128,
                                  cold_capacity=4 * 128, dim=DIM,
                                  score_policy="custom"),
            delta, carry_scores=True)
        texp = export_delta(tiered_dst)
        assert np.array_equal(
            np.sort(texp.scores), np.sort(scores.astype(np.uint64)))
        assert bool(np.asarray(
            tiered_dst.contains(keys)).all())


class TestStaticSource:
    def test_static_source_accepts_every_offer(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        s = StaticSource(t)
        v, tt = s.snapshot()
        assert v == 0 and tt is t
        t2 = t.insert_or_assign(np.arange(4, dtype=np.uint64),
                                jnp.ones((4, DIM))).table
        assert s.offer(v, t2)
        assert s.table is t2

    def test_static_source_rejects_stale_offers(self):
        """StaticSource runs the SAME compare-and-swap as TablePublisher:
        an offer from a superseded snapshot must lose, and versions bump
        from the CURRENT snapshot (never replay the caller's number)."""
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        s = StaticSource(t)
        v0, _ = s.snapshot()
        keys = np.arange(1, 5, dtype=np.uint64)
        t1 = t.insert_or_assign(keys, jnp.ones((4, DIM))).table
        assert s.offer(v0, t1)                   # fresh: applies
        v1, _ = s.snapshot()
        assert v1 == v0 + 1
        stale = t.insert_or_assign(keys, jnp.full((4, DIM), 9.0)).table
        assert not s.offer(v0, stale)            # stale: rejected
        assert s.rejected_offers == 1
        assert s.table is t1                     # newer table survives
        assert s.snapshot()[0] == v1             # version not clobbered

    def test_engine_and_scheduler_offers_interleave_without_clobber(self):
        """Two offer paths race on one StaticSource: the engine's admit
        waves and the maintenance scheduler's between-wave steps.  Every
        applied offer must bump the version; nothing may silently reuse a
        version or resurrect an older table."""
        from repro.maintenance import MaintenancePolicy, MaintenanceScheduler

        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=1, sweep_budget=64))
        eng = OnlineEmbeddingEngine(t, wave_size=16, miss_policy="admit",
                                    scheduler=sched)
        rng = np.random.default_rng(3)
        for i in range(6):
            keys = rng.integers(1, 4 * 128, size=16).astype(np.uint64)
            eng.submit(EmbeddingRequest(rid=i, keys=keys))
            eng.step()
        src = eng.source
        # every wave admitted (offer) and every scheduler step offered:
        # each accepted offer is exactly one version bump
        assert src.snapshot()[0] == src.offered
        assert src.offered + src.rejected_offers == (
            len(eng.reports) + sched.totals.runs - sched.totals.skipped_offers
        ) - sched.totals.deferred + sched.totals.skipped_offers
        # the admitted keys are actually in the final table (no clobber)
        last = eng.completed[-1]
        assert bool(np.asarray(
            src.table.contains(last.keys)).all())


class TestAuxColumnContract:
    """Tables carrying in-row optimizer state (rowwise_adagrad-style
    aux columns) must never leak aux columns to serving clients — the
    admit path slices served rows to exactly `table.dim`."""

    KEYS = np.arange(1, 33, dtype=np.uint64)

    @pytest.mark.parametrize("kind", ["flat", "tiered"])
    def test_admit_serves_dim_wide_rows_on_aux_tables(self, kind):
        if kind == "flat":
            t = HKVTable.create(capacity=2 * 128, dim=DIM, aux_value_dim=1)
        else:
            t = TieredHKVTable.create(hot_capacity=128,
                                      cold_capacity=2 * 128, dim=DIM,
                                      aux_value_dim=1)
        assert t.dim == DIM                       # dim excludes aux
        eng = OnlineEmbeddingEngine(t, wave_size=32, miss_policy="admit")
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.run_until_drained()
        req = eng.completed[0]
        assert req.values.shape == (len(self.KEYS), DIM)   # aux never leaks
        # admitted: the re-serve hits and is still exactly dim wide
        eng2 = OnlineEmbeddingEngine(eng.source.table, wave_size=32,
                                     miss_policy="admit")
        eng2.submit(EmbeddingRequest(rid=1, keys=self.KEYS.copy()))
        eng2.run_until_drained()
        req2 = eng2.completed[0]
        assert req2.found.all()
        assert req2.values.shape == (len(self.KEYS), DIM)
        # server-side rows still carry the aux column
        total = getattr(eng2.source.table, "hot", eng2.source.table)
        assert total.cfg.total_value_dim == DIM + 1

    def test_readonly_on_aux_table_is_dim_wide_too(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM, aux_value_dim=1)
        t = t.find_or_insert(self.KEYS,
                             jnp.ones((len(self.KEYS), DIM))).table
        eng = OnlineEmbeddingEngine(t, wave_size=32, miss_policy="readonly")
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.run_until_drained()
        req = eng.completed[0]
        assert req.found.all()
        assert req.values.shape == (len(self.KEYS), DIM)
        assert np.allclose(req.values, 1.0)


class TestWaveFnRebuild:
    """The cached wave closure is keyed on the published table's static
    signature: a mid-stream publish of a structurally different successor
    (flat→tiered, dim change) must rebuild the closure — stale baked-in
    flags would drop promotion or serve the wrong width."""

    KEYS = np.arange(1, 17, dtype=np.uint64)

    def test_flat_to_tiered_publish_rebuilds_and_promotes(self):
        flat = HKVTable.create(capacity=2 * 128, dim=DIM).insert_or_assign(
            self.KEYS, jnp.ones((len(self.KEYS), DIM))).table
        pub = TablePublisher(flat)
        eng = OnlineEmbeddingEngine(pub, wave_size=16,
                                    miss_policy="readonly", promote=True)
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.step()
        assert eng.completed[0].found.all()
        # flat + promote is a pure read: no successor was offered
        assert pub.offered == 0
        # mid-stream: the trainer retiers — keys now live ONLY cold
        pub.publish(_tiered_with_cold_resident(self.KEYS))
        eng.submit(EmbeddingRequest(rid=1, keys=self.KEYS.copy()))
        eng.step()
        req = eng.completed[1]
        assert req.found.all()
        assert np.allclose(req.values, 1.0)
        # the REBUILT closure promotes: cold hits were re-admitted hot and
        # the successor handle was offered back
        assert pub.offered == 1
        assert bool(np.asarray(pub.table.hot.contains(self.KEYS)).all())

    def test_dim_change_publish_serves_new_width(self):
        pub = TablePublisher(
            HKVTable.create(capacity=2 * 128, dim=DIM).insert_or_assign(
                self.KEYS, jnp.ones((len(self.KEYS), DIM))).table)
        eng = OnlineEmbeddingEngine(pub, wave_size=16,
                                    miss_policy="readonly")
        eng.submit(EmbeddingRequest(rid=0, keys=self.KEYS.copy()))
        eng.step()
        assert eng.completed[0].values.shape[1] == DIM
        wide = 2 * DIM
        pub.publish(
            HKVTable.create(capacity=2 * 128, dim=wide).insert_or_assign(
                self.KEYS, jnp.full((len(self.KEYS), wide), 3.0)).table)
        eng.submit(EmbeddingRequest(rid=1, keys=self.KEYS.copy()))
        eng.step()
        req = eng.completed[1]
        assert req.values.shape[1] == wide       # not the stale width
        assert np.allclose(req.values, 3.0)

    def test_scheduler_step_fn_rebuilds_on_signature_change(self):
        from repro.maintenance import MaintenancePolicy, MaintenanceScheduler

        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=1, sweep_budget=64))
        flat = HKVTable.create(capacity=2 * 128, dim=DIM)
        sched.run(flat)
        sig_flat = sched._step_sig
        tiered = TieredHKVTable.create(hot_capacity=128,
                                       cold_capacity=2 * 128, dim=DIM)
        t2, rep = sched.run(tiered)              # must not reuse the flat fn
        assert sched._step_sig != sig_flat
        assert isinstance(t2, TieredHKVTable)
        assert sched.totals.runs == 2


class TestRequestShapes:
    """Requests larger than a wave and zero-length requests, through both
    miss policies AND both admission modes, checked lane-exactly against
    a one-shot find oracle on the same table."""

    @pytest.mark.parametrize("policy", ["readonly", "admit"])
    @pytest.mark.parametrize("admission", ["wave", "continuous"])
    def test_spanning_and_empty_requests_match_oracle(self, policy,
                                                      admission):
        cap, wave = 4 * 128, 32
        # DISTINCT keys: found/values then match the one-shot oracle even
        # across wave boundaries (duplicates would hit after an earlier
        # wave's admission)
        keys = np.arange(1, 101, dtype=np.uint64)        # 100 keys: 4 waves
        present = keys[::2]                              # half pre-resident
        vals = jnp.asarray(np.tile(
            present.astype(np.float32)[:, None], (1, DIM)))
        t = HKVTable.create(capacity=cap, dim=DIM).insert_or_assign(
            present, vals).table
        oracle = t.find(keys)                            # ONE-shot, pre-serve
        want_found = np.asarray(oracle.found)
        want_vals = np.where(want_found[:, None],
                             np.asarray(oracle.values), 0.0)
        eng = OnlineEmbeddingEngine(t, wave_size=wave, miss_policy=policy,
                                    admission=admission)
        big = EmbeddingRequest(rid=0, keys=keys)
        empty = EmbeddingRequest(rid=1, keys=np.zeros(0, np.uint64))
        eng.submit(big)
        eng.submit(empty)
        done = eng.run_until_drained()
        assert {r.rid for r in done} == {0, 1}
        assert empty.done and empty.values.shape == (0, DIM)
        assert big.done
        assert np.array_equal(big.found, want_found)
        assert np.allclose(big.values, want_vals)
        assert eng.idle
        m = eng.metrics()
        assert m.keys == 100
        assert m.hits == int(want_found.sum())
        if policy == "admit":                    # misses were admitted
            f2 = eng.source.table.find(keys)
            assert bool(np.asarray(f2.found).all())

    @pytest.mark.parametrize("admission", ["wave", "continuous"])
    def test_zero_length_only_completes_without_a_launch(self, admission):
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(t, wave_size=16, miss_policy="readonly",
                                    admission=admission)
        req = EmbeddingRequest(rid=0, keys=np.zeros(0, np.uint64))
        eng.submit(req)
        eng.run_until_drained()
        assert req.done and req.values.shape == (0, DIM)
        assert req.found.shape == (0,)
        assert not eng.reports                   # no wave was launched
        assert eng.idle


class TestContinuousAdmission:
    """Continuous-batch admission: splice-on-submit, dispatch-on-fill,
    poll() reaping, pipeline collapse — and result equivalence with the
    wave-granular path on the same replay."""

    def test_results_and_hit_rate_match_wave_mode(self):
        rng = np.random.default_rng(9)
        cap, wave = 4 * 128, 32
        reqs = [rng.integers(1, 3 * cap, size=rng.integers(1, 80))
                .astype(np.uint64) for _ in range(12)]

        def drive(admission):
            eng = OnlineEmbeddingEngine(
                HKVTable.create(capacity=cap, dim=DIM, buckets_per_key=2),
                wave_size=wave, miss_policy="admit", admission=admission)
            for i, k in enumerate(reqs):
                eng.submit(EmbeddingRequest(rid=i, keys=k.copy()))
            eng.run_until_drained()
            return eng

        w, c = drive("wave"), drive("continuous")
        # identical FIFO packing => identical waves => identical results
        by_rid_w = {r.rid: r for r in w.completed}
        by_rid_c = {r.rid: r for r in c.completed}
        assert by_rid_w.keys() == by_rid_c.keys()
        for rid in by_rid_w:
            assert np.array_equal(by_rid_w[rid].found, by_rid_c[rid].found)
            assert np.allclose(by_rid_w[rid].values, by_rid_c[rid].values)
        mw, mc = w.metrics(), c.metrics()
        assert mw.keys == mc.keys
        assert mw.hits == mc.hits                # equal hit rate, exactly
        assert mw.waves == mc.waves              # dense packing held

    def test_submit_dispatches_filled_waves_eagerly(self):
        t = HKVTable.create(capacity=4 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(t, wave_size=32, miss_policy="admit",
                                    admission="continuous")
        # 100 keys = 3 full waves dispatched AT SUBMIT + 4 staged keys
        eng.submit(EmbeddingRequest(
            rid=0, keys=np.arange(1, 101, dtype=np.uint64)))
        assert len(eng._flights) == 3
        assert eng._stage_used == 4
        assert not eng.idle
        eng.run_until_drained()
        assert eng.completed[0].done
        assert len(eng.reports) == 4
        assert eng.idle

    def test_poll_reaps_without_dispatching(self):
        t = HKVTable.create(capacity=4 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(t, wave_size=16, miss_policy="admit",
                                    admission="continuous")
        eng.submit(EmbeddingRequest(
            rid=0, keys=np.arange(1, 17, dtype=np.uint64)))   # fills: flies
        assert len(eng._flights) == 1
        # poll never blocks and never dispatches; eventually the wave lands
        import jax
        jax.block_until_ready(eng._flights[0].out[1:])
        rep = eng.poll()
        assert rep is not None and rep.size == 16
        assert not eng._flights
        assert eng.completed and eng.completed[0].done
        # staged-but-unfilled keys stay staged across poll
        eng.submit(EmbeddingRequest(
            rid=1, keys=np.arange(32, 36, dtype=np.uint64)))
        assert eng.poll() is None
        assert eng._stage_used == 4 and not eng.idle
        eng.run_until_drained()
        assert eng.completed[1].done and eng.idle

    def test_slo_split_is_consistent(self):
        t = HKVTable.create(capacity=4 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(t, wave_size=16, miss_policy="admit",
                                    admission="continuous")
        for i in range(6):
            eng.submit(EmbeddingRequest(
                rid=i, keys=np.arange(1 + 16 * i, 17 + 16 * i,
                                      dtype=np.uint64)))
        eng.run_until_drained()
        m = eng.metrics()
        assert m.requests == 6
        for r in eng.completed:
            assert r.t_submit <= r.t_admit <= r.t_done
            assert abs(r.total_latency_s
                       - (r.queue_wait_s + r.service_s)) < 1e-9
        assert m.p99_total_s >= m.p50_total_s >= 0
        assert m.p99_queue_wait_s >= m.p50_queue_wait_s >= 0
        assert m.p99_service_s >= m.p50_service_s > 0

    def test_presubmitted_t_submit_is_honored(self):
        """Open-loop drivers pre-stamp the intended arrival time; the
        engine must not overwrite it (coordinated-omission safety)."""
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        eng = OnlineEmbeddingEngine(t, wave_size=16, miss_policy="admit",
                                    admission="continuous")
        req = EmbeddingRequest(rid=0,
                               keys=np.arange(1, 17, dtype=np.uint64))
        req.t_submit = 123.456
        eng.submit(req)
        eng.run_until_drained()
        assert req.t_submit == 123.456

    def test_scheduler_defers_when_staging_spent_the_budget(self):
        from repro.maintenance import MaintenancePolicy, MaintenanceScheduler

        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=1, sweep_budget=64))
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        eng = OnlineEmbeddingEngine(t, wave_size=16, miss_policy="admit",
                                    scheduler=sched, host_budget_s=1e-12)
        for i in range(5):
            eng.submit(EmbeddingRequest(
                rid=i, keys=np.arange(1 + 16 * i, 17 + 16 * i,
                                      dtype=np.uint64)))
            eng.step()
        # the first-ever step seeds the cost estimate; after that the
        # zero-slack budget defers every interval
        assert sched.totals.runs == 1
        assert sched.totals.deferred == 4
