"""Distributed-layer tests on an 8-device host mesh (subprocess isolation:
the main test process must keep 1 device for the smoke tests)."""

import json
import subprocess
import sys
import textwrap

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import shard_map
"""


def _run(body: str) -> dict:
    import os

    code = _PRELUDE + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src", "XLA_FLAGS": ""},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_hkv_embedding_roundtrip_and_grads():
    """All-to-all routed table: lookup inserts, serve agrees, grads descend,
    and the result matches a single-device (unsharded) HKV embedding."""
    out = _run("""
    from repro.embedding.dynamic import HKVEmbedding
    from repro.embedding.sparse_opt import SparseOptimizer
    from repro.distributed.table_sharding import ShardedHKVEmbedding
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    emb = HKVEmbedding(capacity=8*128*8, dim=8,
                       optimizer=SparseOptimizer("rowwise_adagrad", lr=0.5))
    semb = ShardedHKVEmbedding(emb=emb, axis_names=("data", "model"))
    state = semb.create_sharded(mesh)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 5000, size=(4, 32)), jnp.int32)

    @jax.jit
    def train_lookup(state, toks):
        return semb.lookup(mesh, state, toks, train=True)

    @jax.jit
    def serve_lookup(state, toks):
        _, rows, _ = semb.lookup(mesh, state, toks, train=False)
        return rows

    @jax.jit
    def grad_apply(state, toks, g):
        return semb.apply_grads(mesh, state, toks, g)

    state, rows, ovf = train_lookup(state, toks)
    served = serve_lookup(state, toks)
    agree = bool(jnp.allclose(rows, served, atol=1e-6))
    # gradient step: pull rows toward 1.0
    target = jnp.ones_like(rows)
    loss0 = float(jnp.mean((rows - target) ** 2))
    g = 2 * (rows - target) / rows.size
    state = grad_apply(state, toks, g)
    rows2 = serve_lookup(state, toks)
    loss1 = float(jnp.mean((rows2 - target) ** 2))
    print(json.dumps({"agree": agree, "overflow": int(ovf),
                      "loss0": loss0, "loss1": loss1}))
    """)
    assert out["agree"]
    assert out["overflow"] == 0
    assert out["loss1"] < out["loss0"]


def test_sharded_lookup_matches_unsharded_init_rows():
    """Deterministic init: sharded cold-start rows == HKVEmbedding defaults."""
    out = _run("""
    from repro.embedding.dynamic import HKVEmbedding
    from repro.distributed.table_sharding import ShardedHKVEmbedding
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    emb = HKVEmbedding(capacity=8*128*8, dim=4)
    semb = ShardedHKVEmbedding(emb=emb, axis_names=("data", "model"))
    state = semb.create_sharded(mesh)
    toks = jnp.asarray(np.arange(16).reshape(2, 8), jnp.int32)

    @jax.jit
    def train_lookup(state, toks):
        return semb.lookup(mesh, state, toks, train=True)

    state, rows, _ = train_lookup(state, toks)
    want = emb.default_rows(emb.keys_of(toks)).reshape(rows.shape)
    print(json.dumps({"match": bool(jnp.allclose(rows, want, atol=1e-6))}))
    """)
    assert out["match"]


def test_compressed_psum_close_to_exact():
    out = _run("""
    from repro.distributed.compression import compressed_psum
    mesh = jax.make_mesh((8,), ("d",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 1000)), jnp.float32)

    def body(x):
        return compressed_psum(x, "d")

    y = jax.jit(shard_map(body, mesh=mesh, in_specs=P("d"),
                              out_specs=P("d"), check_vma=False))(x)
    exact = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
    err = float(jnp.max(jnp.abs(y - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    print(json.dumps({"rel_err": err}))
    """)
    assert out["rel_err"] < 0.05  # int8 quantization error bound


def test_error_feedback_accumulates():
    out = _run("""
    from repro.distributed.compression import ef_compress_grads, init_error_state
    mesh = jax.make_mesh((8,), ("d",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(8, 512)), jnp.float32)}

    def body(g):
        e = init_error_state({"w": g["w"]})
        synced, e2 = ef_compress_grads(g, e, "d")
        # second step: error feedback should be non-zero
        return synced["w"], e2["w"]

    s, e = jax.jit(shard_map(body, mesh=mesh, in_specs=({"w": P("d")},),
                                 out_specs=(P("d"), P("d")), check_vma=False))(g)
    exact = jnp.broadcast_to(g["w"].mean(0, keepdims=True), g["w"].shape)
    rel = float(jnp.max(jnp.abs(s - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    print(json.dumps({"rel": rel, "err_norm": float(jnp.abs(e).sum())}))
    """)
    assert out["rel"] < 0.05
    assert out["err_norm"] > 0  # residual carried


def test_pipeline_matches_sequential():
    out = _run("""
    from repro.distributed.pipeline import pipeline_apply
    mesh = jax.make_mesh((4,), ("pod",))
    rng = np.random.default_rng(2)
    ws = jnp.asarray(rng.normal(size=(4, 16, 16)) / 4.0, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(6, 8, 16)), jnp.float32)  # 6 microbatches

    def stage(w, x):
        return jnp.tanh(x @ w)

    got = pipeline_apply(mesh, "pod", stage, ws, xs)
    want = xs
    for i in range(4):
        want = jnp.tanh(want @ ws[i])
    print(json.dumps({"close": bool(jnp.allclose(got, want, atol=1e-5))}))
    """)
    assert out["close"]


def test_param_specs_cover_every_leaf():
    """Sharding rules must produce valid specs for every arch's params."""
    out = _run("""
    from repro.configs import ARCH_NAMES, get_arch
    from repro.distributed.sharding import param_specs
    from repro.models.lm import CompositeLM
    bad = []
    for name in ARCH_NAMES:
        arch = get_arch(name)
        model = CompositeLM(arch.lm)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_specs(shapes)
        for (pa, leaf), (ps, spec) in zip(
            jax.tree_util.tree_leaves_with_path(shapes),
            jax.tree_util.tree_leaves_with_path(specs),
        ):
            if len([a for a in spec if a is not None]) > leaf.ndim:
                bad.append((name, jax.tree_util.keystr(pa)))
    print(json.dumps({"bad": bad}))
    """)
    assert out["bad"] == []
