"""Pallas kernel validation: sweep shapes/dtypes, assert against ref.py.

Kernels execute with interpret=True (Python on CPU) — the body semantics
are identical to a Mosaic compile on real TPUs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ops as core_ops
from repro.core import table, u64
from repro.kernels import digest_scan, gather, ref, scatter, score_scan
from repro.kernels import ops as kops


def _build_table(rng, capacity, dim, fill, dual=False, policy="lru"):
    cfg = table.HKVConfig(
        capacity=capacity, dim=dim, buckets_per_key=2 if dual else 1,
        score_policy=policy,
    )
    state = table.create(cfg)
    n = int(capacity * fill)
    if n:
        keys = rng.integers(0, 2**50, size=n).astype(np.uint64)
        vals = rng.normal(size=(n, dim)).astype(np.float32)
        state = core_ops.insert_or_assign(
            state, cfg, u64.from_uint64(keys), jnp.asarray(vals)
        ).state
    return cfg, state


@pytest.mark.parametrize("capacity,queries", [(2 * 128, 64), (8 * 128, 128), (16 * 128, 300)])
@pytest.mark.parametrize("fill", [0.0, 0.5, 1.0])
@pytest.mark.parametrize("variant", ["tlp", "pipeline"])
def test_digest_scan_matches_ref(capacity, queries, fill, variant):
    rng = np.random.default_rng(capacity + queries + int(fill * 10))
    cfg, state = _build_table(rng, capacity, 4, fill)
    # half the queries are present keys, half are misses
    present = rng.integers(0, 2**50, size=queries).astype(np.uint64)
    qk = u64.from_uint64(present)
    from repro.core import find as find_mod

    probe = find_mod.probe_keys(cfg, qk)
    fn = (
        digest_scan.digest_scan_tlp
        if variant == "tlp"
        else lambda *a, **k: digest_scan.digest_scan_pipeline(*a, q_tile=32, **k)
    )
    npad = -(-queries // 32) * 32 if variant == "pipeline" else queries
    pad = lambda x, f=0: jnp.concatenate(
        [x, jnp.full((npad - queries,), f, x.dtype)]
    ) if npad != queries else x
    slot_k, found_k = fn(
        state.digests, state.key_hi, state.key_lo,
        pad(probe.bucket1), pad(probe.digest.astype(jnp.uint32)),
        pad(qk.hi, u64.EMPTY_HI), pad(qk.lo, u64.EMPTY_LO),
        interpret=True,
    )
    slot_r, found_r = ref.digest_scan_ref(
        state.digests, state.key_hi, state.key_lo,
        probe.bucket1, probe.digest.astype(jnp.uint32), qk.hi, qk.lo,
    )
    np.testing.assert_array_equal(np.asarray(found_k)[:queries], np.asarray(found_r))
    fmask = np.asarray(found_r).astype(bool)
    np.testing.assert_array_equal(
        np.asarray(slot_k)[:queries][fmask], np.asarray(slot_r)[fmask]
    )


def test_locate_kernel_matches_core_locate():
    from repro.core import find as find_mod

    for dual in (False, True):
        rng = np.random.default_rng(7 + dual)
        cfg, state = _build_table(rng, 8 * 128, 4, 1.0, dual=dual)
        keys = u64.from_uint64(rng.integers(0, 2**50, size=256).astype(np.uint64))
        lk = kops.locate_kernel(state, cfg, keys, interpret=True)
        lr = find_mod.locate(state, cfg, keys)
        np.testing.assert_array_equal(np.asarray(lk.found), np.asarray(lr.found))
        m = np.asarray(lr.found)
        np.testing.assert_array_equal(np.asarray(lk.row)[m], np.asarray(lr.row)[m])


@pytest.mark.parametrize("dim", [4, 32, 128, 200])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_matches_ref(dim, dtype):
    rng = np.random.default_rng(dim)
    values = jnp.asarray(rng.normal(size=(512, dim)), dtype)
    rows = jnp.asarray(rng.integers(0, 512, size=100), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=100), jnp.int32)
    got = gather.gather_rows(values, rows, mask, interpret=True)
    want = ref.gather_rows_ref(values, rows, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dim", [8, 64, 256])
@pytest.mark.parametrize("add", [False, True])
def test_scatter_rows_matches_ref(dim, add):
    rng = np.random.default_rng(dim + add)
    values = jnp.asarray(rng.normal(size=(256, dim)), jnp.float32)
    rows = jnp.asarray(rng.permutation(256)[:64], jnp.int32)  # unique rows
    updates = jnp.asarray(rng.normal(size=(64, dim)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=64), jnp.int32)
    got = scatter.scatter_rows(values, rows, updates, mask, add=add, interpret=True)
    want = ref.scatter_rows_ref(values, rows, updates, mask, add=add)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("buckets,fill", [(8, 0.0), (16, 0.6), (32, 1.0)])
def test_bucket_stats_matches_ref(buckets, fill):
    rng = np.random.default_rng(buckets)
    cfg, state = _build_table(rng, buckets * 128, 2, fill)
    got = score_scan.bucket_stats(
        state.key_hi, state.key_lo, state.score_hi, state.score_lo,
        bucket_tile=8, interpret=True,
    )
    want = ref.bucket_stats_ref(
        state.key_hi, state.key_lo, state.score_hi, state.score_lo
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_find_kernel_end_to_end_matches_core_find():
    rng = np.random.default_rng(11)
    cfg, state = _build_table(rng, 8 * 128, 16, 0.9)
    hits = rng.integers(0, 2**50, size=128).astype(np.uint64)
    keys = u64.from_uint64(hits)
    vals_k, found_k = kops.find_kernel(state, cfg, keys, interpret=True)
    res_c = core_ops.find(state, cfg, keys)
    np.testing.assert_array_equal(np.asarray(found_k), np.asarray(res_c.found))
    np.testing.assert_allclose(
        np.asarray(vals_k), np.asarray(res_c.values), rtol=1e-6
    )


def test_assign_kernel_matches_core_assign():
    rng = np.random.default_rng(13)
    cfg, state = _build_table(rng, 4 * 128, 8, 0.0)
    keys_np = rng.permutation(10_000)[:128].astype(np.uint64)  # unique
    keys = u64.from_uint64(keys_np)
    state = core_ops.insert_or_assign(state, cfg, keys, jnp.zeros((128, 8))).state
    upd = jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)
    got = kops.assign_kernel(state, cfg, keys, upd, add=False, interpret=True)
    want = core_ops.assign(state, cfg, keys, upd)
    np.testing.assert_allclose(np.asarray(got.values), np.asarray(want.values), rtol=1e-6)
    got2 = kops.assign_kernel(state, cfg, keys, upd, add=True, interpret=True)
    want2 = core_ops.assign_add(state, cfg, keys, upd)
    np.testing.assert_allclose(np.asarray(got2.values), np.asarray(want2.values), rtol=1e-6)
