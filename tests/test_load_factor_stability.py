"""Paper §5.2 / Fig. 6 as a TEST: fused-find work is λ-INDEPENDENT.

The claim the exp1 benchmark plots is asserted here on memory-transaction
COUNTERS, not wall clock (CPU-XLA timing noise would drown a 5% effect):

  * HKV fused find touches a λ-independent number of rows per query —
    `buckets_per_key` metadata bucket rows + exactly one value row, with
    <5% variation from λ=0.50 to λ=1.00 — and resident queries keep a
    100% hit rate all the way to a FULL table;
  * open addressing's probe counter (`.probes` on its find result — the
    memory transactions the walk consumed) GROWS with λ on the same
    resident-query workload;
  * bucketed-P2C keeps flat probes but loses insert capability near
    capacity, while HKV resolves every upsert in place at λ=1.00.

Slow-marked: the fill loops drive three tables through a λ sweep.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from benchmarks.common import make_insert_jit
from benchmarks.exp1_load_factor import fill_to_lambda
from repro.baselines import DictKVTable
from repro.core import HKVTable, u64
from repro.core import find as find_mod
from repro.core import ops
from repro.kernels import ops as kops

pytestmark = pytest.mark.slow

CAP = 64 * 128   # 8,192 slots
DIM = 8
NQ = 1024
LAMBDAS = (0.50, 0.75, 1.00)


def _residents(table, rng, n):
    """Sample n keys currently stored in the table (via the export drain),
    so the query workload is all-hits at every λ."""
    exp = table.export_batch(0, table.num_buckets)
    mask = np.asarray(exp.mask).astype(bool)
    keys = ((np.asarray(exp.key_hi, np.uint64) << np.uint64(32))
            | np.asarray(exp.key_lo, np.uint64))
    live = keys[mask]
    assert len(live) > 0
    return rng.choice(live, size=n)


def test_hkv_fused_find_counters_flat_across_load():
    rng = np.random.default_rng(0)
    table = HKVTable.create(capacity=CAP, dim=DIM, buckets_per_key=2,
                            backend="kernel")
    ins = make_insert_jit()
    work, found_rate = {}, {}
    for lam in LAMBDAS:
        table = fill_to_lambda(table, lam, rng, ins)
        assert float(table.load_factor()) >= lam - 0.02
        q = u64.from_uint64(_residents(table, rng, NQ))
        probe = find_mod.probe_keys(table.cfg, q)
        # rows touched per query: the candidate bucket rows actually
        # scanned (bucket2 may alias bucket1) + ONE fused value row
        meta_rows = 1.0 + np.asarray(probe.bucket2 != probe.bucket1).mean()
        work[lam] = meta_rows + 1.0
        r = kops.find_fused_kernel(table.state, table.cfg, q)
        found_rate[lam] = float(np.asarray(r.found).mean())
        # bit-parity vs the jnp reference rides along at every λ
        fj = ops.find(table.state, table.cfg, q, backend="jnp")
        np.testing.assert_array_equal(np.asarray(r.found),
                                      np.asarray(fj.found))
        np.testing.assert_array_equal(np.asarray(r.values[:, :DIM]),
                                      np.asarray(fj.values))
    lo, hi = min(work.values()), max(work.values())
    assert (hi - lo) / lo < 0.05, f"fused-find work varies with λ: {work}"
    assert all(fr == 1.0 for fr in found_rate.values()), found_rate
    # and at λ=1.00 every upsert of fresh keys still resolves (eviction
    # in place — the cache semantics that make full-table operation work)
    fresh = u64.from_uint64(
        rng.integers(2**51, 2**52, size=512).astype(np.uint64))
    rep = table.insert_or_assign(fresh, jnp.zeros((512, DIM), jnp.float32))
    assert float(np.asarray(rep.ok).mean()) == 1.0


def test_open_addressing_probes_grow_with_load():
    rng = np.random.default_rng(1)
    table = DictKVTable.open_addressing(capacity=CAP, dim=DIM)
    ins = make_insert_jit()
    probes = {}
    # 0.95 not 1.00: OA insert capability dies before a full table — that
    # failure is asserted separately below
    for lam in (0.50, 0.75, 0.95):
        table = fill_to_lambda(table, lam, rng, ins)
        q = u64.from_uint64(_residents(table, rng, NQ))
        r = table.find(q)
        hit = np.asarray(r.found).astype(bool)
        assert hit.all()
        probes[lam] = float(np.asarray(r.probes)[hit].mean())
    assert probes[0.75] > probes[0.50]
    assert probes[0.95] > probes[0.50] * 1.05, (
        f"open addressing probe walk did not degrade: {probes}")


def test_bucketed_p2c_loses_inserts_where_hkv_does_not():
    rng = np.random.default_rng(2)
    table = DictKVTable.bucketed_p2c(capacity=CAP, dim=DIM)
    ins = make_insert_jit()
    table = fill_to_lambda(table, 1.0, rng, ins)
    fresh = u64.from_uint64(
        rng.integers(2**51, 2**52, size=2048).astype(np.uint64))
    rep = table.insert_or_assign(fresh, jnp.zeros((2048, DIM), jnp.float32))
    ok = float(np.asarray(rep.ok).mean())
    assert ok < 1.0, "P2C should drop inserts near capacity"
