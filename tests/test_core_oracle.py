"""Property tests: the batch-synchronous merge equals the sequential oracle.

This is the central correctness argument of the TPU adaptation (DESIGN.md
§2): applying paper Algorithm 2/3 sequentially in canonical batch order must
produce exactly the same table as `core.merge.upsert`'s vectorized top-S
union closure — per-key status codes AND final table contents (keys, values,
scores) — across policies, bucket modes, capacities, batch compositions,
duplicate keys, and sentinel padding.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ops, table, u64
from repro.core.oracle import OracleTable


def _drain(state, cfg):
    """(key -> (score, value)) dict of the live table contents."""
    exp = ops.export_batch(state, cfg, 0, cfg.num_buckets)
    mask = np.asarray(exp.mask)
    keys = (np.asarray(exp.key_hi, np.uint64) << np.uint64(32)) | np.asarray(
        exp.key_lo, np.uint64
    )
    scores = (np.asarray(exp.score_hi, np.uint64) << np.uint64(32)) | np.asarray(
        exp.score_lo, np.uint64
    )
    vals = np.asarray(exp.values)
    return {
        int(k): (int(s), vals[i, : cfg.dim])
        for i, (k, s, m) in enumerate(zip(keys, scores, mask))
        if m
    }


def _run_pair(policy, dual, capacity, dim, batches, key_space, seed):
    rng = np.random.default_rng(seed)
    cfg = table.HKVConfig(
        capacity=capacity, dim=dim, buckets_per_key=2 if dual else 1, score_policy=policy
    )
    state = table.create(cfg)
    orc = OracleTable(
        capacity, dim, buckets_per_key=2 if dual else 1, policy=policy
    )
    for bi, n in enumerate(batches):
        keys_np = rng.integers(0, key_space, size=n).astype(np.uint64)
        if n >= 4 and rng.random() < 0.5:  # inject sentinel padding entries
            keys_np[rng.integers(0, n, size=2)] = np.uint64(0xFFFFFFFFFFFFFFFF)
        vals_np = rng.normal(size=(n, dim)).astype(np.float32)
        res = ops.insert_or_assign(state, cfg, u64.from_uint64(keys_np), jnp.asarray(vals_np))
        state = res.state
        want = np.asarray(orc.insert_or_assign(keys_np, vals_np), np.int8)
        got = np.asarray(res.status)
        assert np.array_equal(got, want), (
            f"batch {bi}: status mismatch at {np.nonzero(got != want)[0][:8]}"
        )
    mine, theirs = _drain(state, cfg), {
        k: (e.score, e.value) for k, e in orc.items()
    }
    assert mine.keys() == theirs.keys()
    for k in mine:
        assert mine[k][0] == theirs[k][0], f"score mismatch for key {k}"
        np.testing.assert_allclose(mine[k][1], theirs[k][1], rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(
    policy=st.sampled_from(["lru", "lfu", "epoch_lru", "epoch_lfu"]),
    dual=st.booleans(),
    seed=st.integers(0, 2**31),
    key_space=st.sampled_from([50, 300, 5000]),
)
def test_merge_matches_oracle(policy, dual, seed, key_space):
    _run_pair(
        policy=policy,
        dual=dual,
        capacity=2 * 128,
        dim=2,
        batches=[48] * 8,
        key_space=key_space,
        seed=seed,
    )


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31), dual=st.booleans())
def test_merge_matches_oracle_oversubscribed(seed, dual):
    """Batches larger than the whole table — heavy rejection/eviction regime."""
    _run_pair(
        policy="lru",
        dual=dual,
        capacity=128,
        dim=2,
        batches=[200, 200, 200],
        key_space=100_000,
        seed=seed,
    )


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_merge_matches_oracle_heavy_duplicates(seed):
    """Tiny key space: most batch entries are duplicates (LFU counting path)."""
    _run_pair(
        policy="lfu",
        dual=False,
        capacity=128,
        dim=2,
        batches=[64] * 6,
        key_space=12,
        seed=seed,
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31), dual=st.booleans())
def test_custom_scores_match_oracle(seed, dual):
    rng = np.random.default_rng(seed)
    cfg = table.HKVConfig(
        capacity=128, dim=2, buckets_per_key=2 if dual else 1, score_policy="custom"
    )
    state = table.create(cfg)
    orc = OracleTable(128, 2, buckets_per_key=2 if dual else 1, policy="custom")
    for _ in range(5):
        keys_np = rng.integers(0, 4000, size=64).astype(np.uint64)
        vals_np = rng.normal(size=(64, 2)).astype(np.float32)
        scores_np = rng.integers(0, 50, size=64).astype(np.uint64)  # tie-heavy
        res = ops.insert_or_assign(
            state,
            cfg,
            u64.from_uint64(keys_np),
            jnp.asarray(vals_np),
            custom_scores=u64.from_uint64(scores_np),
        )
        state = res.state
        want = np.asarray(orc.insert_or_assign(keys_np, vals_np, scores_np), np.int8)
        assert np.array_equal(np.asarray(res.status), want)
    mine = _drain(state, cfg)
    theirs = {k: (e.score, e.value) for k, e in orc.items()}
    assert mine.keys() == theirs.keys()
    for k in mine:
        assert mine[k][0] == theirs[k][0]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), dual=st.booleans())
def test_find_or_insert_matches_oracle(seed, dual):
    rng = np.random.default_rng(seed)
    cfg = table.HKVConfig(
        capacity=2 * 128, dim=2, buckets_per_key=2 if dual else 1, score_policy="lru"
    )
    state = table.create(cfg)
    orc = OracleTable(2 * 128, 2, buckets_per_key=2 if dual else 1, policy="lru")
    for _ in range(6):
        keys_np = rng.integers(0, 700, size=48).astype(np.uint64)
        inits = rng.normal(size=(48, 2)).astype(np.float32)
        res = ops.find_or_insert(state, cfg, u64.from_uint64(keys_np), jnp.asarray(inits))
        state = res.state
        want_st, want_vals = orc.find_or_insert(keys_np, inits)
        assert np.array_equal(np.asarray(res.status), np.asarray(want_st, np.int8))
        np.testing.assert_allclose(np.asarray(res.values), want_vals, rtol=0, atol=0)
