"""Checkpointing, fault-tolerant driver, end-to-end smoke training."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import DataCursor
from repro.train import checkpoint as ckpt
from repro.train.driver import StepTimeout, TrainDriver


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 4))}}
        ckpt.save(str(tmp_path), 7, tree, extra={"seed": 1, "step": 7})
        assert ckpt.latest_step(str(tmp_path)) == 7
        restored, extra = ckpt.restore(str(tmp_path), 7, tree)
        assert extra == {"seed": 1, "step": 7}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gc_keeps_last_three(self, tmp_path):
        tree = {"x": jnp.zeros(2)}
        for s in range(5):
            ckpt.save(str(tmp_path), s, tree)
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(steps) == 3
        assert ckpt.latest_step(str(tmp_path)) == 4

    def test_async_then_restore(self, tmp_path):
        tree = {"x": jnp.arange(5)}
        ckpt.save_async(str(tmp_path), 3, tree, extra={"seed": 0, "step": 3})
        ckpt.wait_async()
        restored, _ = ckpt.restore(str(tmp_path), 3, tree)
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(5))


class TestDriver:
    def _make(self, tmp_path, failure_injector=None, timeout=None):
        # trivial "model": state = running sum; loss decreases deterministically
        def step_fn(state, batch):
            new = state + batch
            return new, {"loss": float(100.0 - new)}

        return TrainDriver(
            step_fn=step_fn,
            batch_fn=lambda step: 1.0,
            state=jnp.zeros(()),
            ckpt_dir=str(tmp_path),
            cursor=DataCursor(seed=0, step=0),
            checkpoint_every=3,
            failure_injector=failure_injector,
            step_timeout=timeout,
            log=lambda *a: None,
        )

    def test_runs_to_completion(self, tmp_path):
        d = self._make(tmp_path)
        hist = d.run(10)
        assert len(hist["loss"]) == 10
        assert float(d.state) == 10.0

    def test_recovers_from_injected_failure(self, tmp_path):
        boom = {"armed": True}

        def injector(step):
            if step == 5 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("simulated node failure")

        d = self._make(tmp_path, failure_injector=injector)
        hist = d.run(10)
        assert hist["restarts"] == 1
        # state is exactly as if no failure happened (restore + replay)
        assert float(d.state) == 10.0

    def test_gives_up_after_max_failures(self, tmp_path):
        def injector(step):
            raise RuntimeError("permafail")

        d = self._make(tmp_path, failure_injector=injector)
        d.max_failures = 2
        with pytest.raises(RuntimeError):
            d.run(10)

    def test_straggler_timeout_triggers_recovery(self, tmp_path):
        import time

        slow = {"armed": True}

        def injector(step):
            if step == 2 and slow["armed"]:
                slow["armed"] = False
                time.sleep(1.0)  # exceeds the 0.3 s budget -> StepTimeout

        d = self._make(tmp_path, failure_injector=injector, timeout=0.3)
        hist = d.run(5)
        assert hist["restarts"] == 1
        assert float(d.state) == 5.0


def test_end_to_end_smoke_training_dense():
    """A few steps of the real launcher path on a reduced arch: loss drops."""
    import shutil
    import sys

    from repro.launch import train as train_mod

    # hermetic: a stale checkpoint from a previous session would otherwise
    # be restored on any mid-run failure
    shutil.rmtree("/tmp/repro_ckpt_test", ignore_errors=True)
    argv = sys.argv
    sys.argv = [
        "train", "--arch", "qwen2-0.5b", "--smoke", "--steps", "8",
        "--batch", "2", "--seq", "32", "--ckpt-dir", "/tmp/repro_ckpt_test",
    ]
    try:
        hist = train_mod.main()
    finally:
        sys.argv = argv
    assert len(hist["loss"]) == 8
    assert hist["loss"][-1] < hist["loss"][0]  # learning


def test_end_to_end_smoke_training_hkv():
    """The paper-technique path: HKV dynamic embedding backend end to end.

    Assertion note: each step's loss is measured on a DIFFERENT batch of
    the Zipf stream, and per-batch difficulty varies by ~±0.3 nats at this
    scale — with both learning rates zeroed the endpoint-vs-endpoint
    comparison still swings either way, so `loss[-1] < loss[0]` over 6
    steps asserted batch noise, not learning (same-batch replay descends
    6.39 -> 3.8 over 8 steps, and per-step losses beat a frozen-table run
    from step 3 on).  The deterministic form: 12 steps, first-4 vs last-4
    means — a fixed-seed margin of ~0.18 nats.
    """
    import shutil
    import sys

    from repro.launch import train as train_mod

    shutil.rmtree("/tmp/repro_ckpt_test_hkv", ignore_errors=True)
    argv = sys.argv
    sys.argv = [
        "train", "--arch", "qwen2-0.5b", "--smoke", "--steps", "12",
        "--batch", "2", "--seq", "32", "--backend", "hkv",
        "--ckpt-dir", "/tmp/repro_ckpt_test_hkv",
    ]
    try:
        hist = train_mod.main()
    finally:
        sys.argv = argv
    assert len(hist["loss"]) == 12
    assert np.mean(hist["loss"][-4:]) < np.mean(hist["loss"][:4])
