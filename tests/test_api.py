"""HKVTable handle API: pytree/jit compatibility, key normalization,
op-session fusion parity, and the satellite regressions (accum_or_assign
status order, tier-aware export).  KVTable protocol conformance lives in
the parametrized suite, tests/test_kvtable_conformance.py."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HKVTable,
    U64,
    dedupe_keys,
    normalize_keys,
    u64,
)
from repro.core import find as find_mod


def _table(**kw):
    kw.setdefault("capacity", 8 * 128)
    kw.setdefault("dim", 4)
    return HKVTable.create(**kw)


def _keys(rng, n, lo=0, hi=2**50):
    return rng.integers(lo, hi, size=n).astype(np.uint64)


# =============================================================================
# Key normalization
# =============================================================================


class TestNormalizeKeys:
    def test_uint64_roundtrip(self):
        arr = np.array([0, 1, 2**33 + 7, 2**63 + 5], np.uint64)
        k = normalize_keys(arr)
        np.testing.assert_array_equal(u64.to_uint64(k), arr)

    def test_u64_passthrough(self):
        k = U64(jnp.zeros(3, jnp.uint32), jnp.arange(3, dtype=jnp.uint32))
        assert normalize_keys(k) is k

    def test_int_list(self):
        k = normalize_keys([1, 2, 3])
        np.testing.assert_array_equal(u64.to_uint64(k), [1, 2, 3])

    def test_negative_ints_become_empty_sentinel(self):
        for arr in (np.array([5, -1, 7], np.int64),
                    jnp.asarray([5, -1, 7], jnp.int32)):
            k = normalize_keys(arr)
            empt = np.asarray(u64.is_empty(k))
            np.testing.assert_array_equal(empt, [False, True, False])

    def test_signed_int64_wide_values(self):
        arr = np.array([2**40 + 3], np.int64)
        k = normalize_keys(arr)
        assert int(u64.to_uint64(k)[0]) == 2**40 + 3

    def test_uint32_zero_extended(self):
        k = normalize_keys(np.array([7, 9], np.uint32))
        np.testing.assert_array_equal(u64.to_uint64(k), [7, 9])

    def test_numpy_scalar_uint64_exact(self):
        # np scalars are not ndarrays; they must not fall into the jnp
        # path, which would truncate uint64 to the low 32 bits
        k = normalize_keys(np.uint64(2**40 + 7))
        assert int(u64.to_uint64(k)[0]) == 2**40 + 7

    def test_float_rejected(self):
        with pytest.raises(TypeError):
            normalize_keys(np.array([1.5]))

    def test_table_accepts_all_forms(self):
        t = _table()
        vals = jnp.ones((3, 4))
        r = t.insert_or_assign(np.array([1, 2, 3], np.uint64), vals)
        for form in ([1, 2, 3], np.array([1, 2, 3], np.int64),
                     jnp.asarray([1, 2, 3], jnp.int32)):
            found = r.table.contains(form)
            assert bool(np.asarray(found).all())


# =============================================================================
# Pytree / jit / scan compatibility (satellite: jit-compat coverage)
# =============================================================================


class TestHandlePytree:
    def test_tree_roundtrip_preserves_statics(self):
        t = _table(buckets_per_key=2, score_policy="lfu", backend="jnp")
        leaves, treedef = jax.tree_util.tree_flatten(t)
        t2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert t2.cfg == t.cfg and t2.backend == t.backend
        assert isinstance(t2, HKVTable)

    def test_jit_with_donated_state(self):
        t = _table()
        keys = _keys(np.random.default_rng(0), 64)
        vals = jnp.ones((64, 4))

        @jax.jit
        def step(table, kh, kl, v):
            return table.insert_or_assign(U64(kh, kl), v).table

        step_donating = jax.jit(step, donate_argnums=0)
        k = u64.from_uint64(keys)
        t_ref = step(t, k.hi, k.lo, vals)
        t_don = step_donating(t, k.hi, k.lo, vals)
        np.testing.assert_array_equal(np.asarray(t_ref.state.key_lo),
                                      np.asarray(t_don.state.key_lo))
        assert int(t_don.size()) == 64

    def test_scan_over_steps(self):
        t = _table()
        rng = np.random.default_rng(1)
        key_batches = np.stack([_keys(rng, 32) for _ in range(5)])
        kb = u64.from_uint64(key_batches)  # U64 with [5, 32] planes

        def body(table, k):
            res = table.insert_or_assign(U64(k[0], k[1]), jnp.ones((32, 4)))
            return res.table, res.status

        final, statuses = jax.lax.scan(
            body, t, (jnp.stack([kb.hi, kb.lo], axis=1)))
        assert statuses.shape == (5, 32)
        # sequential reference
        t_seq = t
        for i in range(5):
            t_seq = t_seq.insert_or_assign(
                key_batches[i], jnp.ones((32, 4))).table
        np.testing.assert_array_equal(np.asarray(final.state.key_lo),
                                      np.asarray(t_seq.state.key_lo))

    def test_with_backend_and_state(self):
        t = _table()
        assert t.with_backend("kernel").backend == "kernel"
        t2 = t.with_state(t.state)
        assert t2.cfg == t.cfg


# =============================================================================
# Op sessions (tentpole acceptance: one locate, bit-identical)
# =============================================================================


class _LocateCounter:
    def __init__(self, monkeypatch):
        self.count = 0
        real = find_mod.locate

        def counting(*a, **kw):
            self.count += 1
            return real(*a, **kw)

        monkeypatch.setattr(find_mod, "locate", counting)


class TestOpSession:
    def _filled(self):
        t = _table(capacity=4 * 128, dim=4)
        keys = _keys(np.random.default_rng(2), 200)
        return t.insert_or_assign(keys, jnp.ones((200, 4))).table, keys

    def test_find_assign_shares_one_locate_and_is_bit_identical(
            self, monkeypatch):
        table, keys = self._filled()
        q = u64.from_uint64(keys[:64])
        vals = jnp.full((64, 4), 2.0)

        # unfused reference: find then assign, two probes
        ref_find = table.find(q)
        ref_table = table.assign(q, vals)

        counter = _LocateCounter(monkeypatch)
        s = table.session()
        got_find = s.find(q)
        s.assign(q, vals)
        new_table = s.commit()
        assert counter.count == 1  # the acceptance criterion: ONE locate

        np.testing.assert_array_equal(np.asarray(got_find.get().values),
                                      np.asarray(ref_find.values))
        np.testing.assert_array_equal(np.asarray(got_find.get().found),
                                      np.asarray(ref_find.found))
        for a, b in zip(jax.tree.leaves(new_table), jax.tree.leaves(ref_table)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_session_matches_unfused_sequence_with_inserter(self):
        table, keys = self._filled()
        q = u64.from_uint64(keys[:32])
        fresh = _keys(np.random.default_rng(3), 32, lo=2**51, hi=2**52)
        vals = jnp.full((32, 4), 3.0)

        # unfused: contains, assign, insert, find (in order)
        ref_c = table.contains(q)
        t1 = table.assign(q, vals)
        r = t1.insert_or_assign(fresh, vals)
        ref_f = r.table.find(q)

        s = table.session()
        c = s.contains(q)
        s.assign(q, vals)
        st = s.insert_or_assign(fresh, vals)
        f = s.find(q)
        t2 = s.commit()
        np.testing.assert_array_equal(np.asarray(c.get()), np.asarray(ref_c))
        np.testing.assert_array_equal(np.asarray(st.get()), np.asarray(r.status))
        np.testing.assert_array_equal(np.asarray(f.get().values),
                                      np.asarray(ref_f.values))
        for a, b in zip(jax.tree.leaves(t2), jax.tree.leaves(r.table)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_inserter_invalidates_cached_locates(self, monkeypatch):
        table, keys = self._filled()
        q = u64.from_uint64(keys[:16])
        counter = _LocateCounter(monkeypatch)
        s = table.session()
        s.find(q)                                    # locate #1 (+0 internal)
        s.erase(q)                                   # serialization point
        s.find(q)                                    # must re-probe: locate #3
        s.commit()
        # erase issues its own locate internally; the second find must NOT
        # reuse the pre-erase locate
        assert counter.count == 3

    def test_update_rows_matches_find_rows_plus_assign(self):
        table, keys = self._filled()
        q = u64.from_uint64(keys[:48])
        fn = lambda rows: rows * 2.0 + 1.0

        got = table.find_rows(q)
        ref_table = table.assign(q, fn(got.rows))

        s = table.session()
        s.update_rows(q, fn)
        new_table = s.commit()
        for a, b in zip(jax.tree.leaves(new_table), jax.tree.leaves(ref_table)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_session_is_jittable(self):
        table, keys = self._filled()
        q = u64.from_uint64(keys[:32])
        vals = jnp.full((32, 4), 5.0)

        @jax.jit
        def fused(t, kh, kl, v):
            k = U64(kh, kl)
            s = t.session()
            hit = s.find(k)
            s.assign(k, v)
            t2 = s.commit()
            return hit.get().values, t2

        out, t2 = fused(table, q.hi, q.lo, vals)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(table.find(q).values))
        np.testing.assert_allclose(np.asarray(t2.find(q).values), 5.0)

    def test_explain_reports_groups_and_probes(self):
        table, keys = self._filled()
        q = u64.from_uint64(keys[:8])
        s = table.session()
        s.find(q)
        s.assign(q, jnp.ones((8, 4)))
        s.insert_or_assign(_keys(np.random.default_rng(5), 8), jnp.ones((8, 4)))
        plan = s.explain()
        assert "serialization point" in plan
        assert "shares locate" in plan
        assert "2 fused vs 3 unfused" in plan

    def test_distinct_temp_key_arrays_not_aliased(self):
        """id() of a freed array can be recycled; the session must retain
        originals so two different temp key batches never share a locate."""
        table, _ = self._filled()
        s = table.session()
        # both arrays are temporaries: without retention, numpy may reuse
        # the first array's address for the second
        s.find(np.arange(4, dtype=np.uint64))
        s.assign(np.arange(1000, 1004, dtype=np.uint64), jnp.ones((4, 4)))
        assert len(s._key_batches) == 2
        s2 = table.session()
        s2.find(np.arange(4, dtype=np.uint64))
        s2.assign(np.arange(4, dtype=np.uint64) + 0, jnp.ones((4, 4)))
        assert len(s2._key_batches) == 2  # value-equal but distinct objects

    def test_refs_error_before_commit(self):
        table, _ = self._filled()
        s = table.session()
        ref = s.find(np.array([1], np.uint64))
        with pytest.raises(RuntimeError):
            ref.get()


# =============================================================================
# Satellite regressions
# =============================================================================


class TestAccumOrAssignStatusOrder:
    def test_shuffled_duplicates_map_statuses_to_batch_positions(self):
        rng = np.random.default_rng(7)
        t = _table(capacity=4 * 128, dim=2)
        existing = np.arange(10, 20, dtype=np.uint64)
        t = t.insert_or_assign(existing, jnp.ones((10, 2))).table

        # batch: duplicates of existing + new keys, shuffled
        batch = np.array([15, 100, 15, 11, 100, 11, 101, 15], np.uint64)
        perm = rng.permutation(len(batch))
        batch = batch[perm]
        deltas = jnp.ones((len(batch), 2))
        res = t.accum_or_assign(batch, deltas)
        status = np.asarray(res.status)
        for i, k in enumerate(batch):
            expect = 1 if k in existing else 2  # UPDATED vs INSERTED
            assert status[i] == expect, (i, int(k), status.tolist())

    def test_accumulation_values(self):
        t = _table(capacity=4 * 128, dim=2)
        t = t.insert_or_assign(np.array([5], np.uint64),
                               jnp.full((1, 2), 10.0)).table
        batch = np.array([5, 6, 5, 6, 5], np.uint64)
        vals = jnp.ones((5, 2))
        res = t.accum_or_assign(batch, vals)
        out = res.table.find(np.array([5, 6], np.uint64))
        np.testing.assert_allclose(np.asarray(out.values)[0], 13.0)  # 10 + 3
        np.testing.assert_allclose(np.asarray(out.values)[1], 2.0)   # inserted sum

    def test_empty_sentinel_positions_invalid(self):
        t = _table(capacity=4 * 128, dim=2)
        batch = np.array([1, 0xFFFFFFFFFFFFFFFF, 2], np.uint64)
        res = t.accum_or_assign(batch, jnp.ones((3, 2)))
        status = np.asarray(res.status)
        assert status[1] == 0 and status[0] != 0 and status[2] != 0


class TestTierAwareExport:
    @pytest.mark.parametrize("tier", ["hbm", "hmem"])
    def test_export_values_match_find(self, tier):
        t = _table(capacity=2 * 128, dim=3, value_tier=tier)
        keys = _keys(np.random.default_rng(9), 100)
        vals = jnp.asarray(
            np.random.default_rng(9).normal(size=(100, 3)), jnp.float32)
        t = t.insert_or_assign(keys, vals).table
        exp = t.export_batch(0, t.cfg.num_buckets)
        live = np.asarray(exp.mask)
        assert live.sum() == len(set(keys.tolist()))
        got_keys = U64(jnp.asarray(np.asarray(exp.key_hi)[live]),
                       jnp.asarray(np.asarray(exp.key_lo)[live]))
        looked = t.find(got_keys)
        np.testing.assert_array_equal(np.asarray(exp.values)[live],
                                      np.asarray(looked.values))

    def test_export_batch_if_threshold_hmem(self):
        t = _table(capacity=2 * 128, dim=2, value_tier="hmem",
                   score_policy="custom")
        keys = np.arange(1, 33, dtype=np.uint64)
        t = t.insert_or_assign(keys, jnp.ones((32, 2)),
                               custom_scores=keys).table
        out = t.export_batch_if(0, t.cfg.num_buckets,
                                np.array([17], np.uint64))
        live = np.asarray(out.mask)
        kept = u64.to_uint64(U64(jnp.asarray(np.asarray(out.key_hi)[live]),
                                 jnp.asarray(np.asarray(out.key_lo)[live])))
        assert set(kept.tolist()) == set(range(17, 33))


# =============================================================================
# dedupe_keys helper
# =============================================================================


class TestDedupeKeys:
    def test_groups_and_inverse(self):
        keys = np.array([7, 3, 7, 9, 3, 7], np.uint64)
        d = dedupe_keys(keys)
        uniq = u64.to_uint64(d.unique)
        live = ~np.asarray(u64.is_empty(d.unique))
        assert sorted(uniq[live].tolist()) == [3, 7, 9]
        # inverse maps each original position to its group's rep slot
        inv = np.asarray(d.inverse)
        for i, k in enumerate(keys):
            assert uniq[inv[i]] == k

    def test_last_index_is_last_writer(self):
        keys = np.array([7, 3, 7], np.uint64)
        d = dedupe_keys(keys)
        # the rep slot of key 7 must carry original index 2 (its last occurrence)
        inv = np.asarray(d.inverse)
        assert int(np.asarray(d.last_index)[inv[0]]) == 2


# KVTable protocol conformance now lives in ONE parametrized suite over
# every implementation: tests/test_kvtable_conformance.py.
