"""TieredHKVTable: the two-tier hierarchy's contract (DESIGN.md §2.5).

Pinned here:
  * demotion cascade — hot-tier displacements (evicted victims AND
    hot-rejected incoming pairs) land in the cold tier with values intact;
  * miss-path promotion — cold hits re-enter the hot tier on access and
    their displaced victims cascade back down (inclusive-on-access);
  * conservation — no pair leaves the hierarchy except at the cold tier's
    boundary, and those losses are reported (`dropped`);
  * hit-rate uplift — hot capacity < working set beats a same-hot-capacity
    flat table under zipfian replay (the tentpole acceptance criterion);
  * score translation across per-tier policies;
  * KVTable protocol conformance (the same harness as test_api), the
    embedding layer over a tiered table, session/update_rows, checkpoint
    save/restore of both tiers, jit/scan/pytree behavior.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HKVTable,
    KVTable,
    TieredHKVTable,
    U64,
    translate_scores,
    u64,
)
from repro.core.scores import get_policy
from repro.data import zipf_keys


def _tiered(hot=2 * 128, cold=8 * 128, dim=4, **kw):
    return TieredHKVTable.create(hot_capacity=hot, cold_capacity=cold,
                                 dim=dim, **kw)


def _keys(rng, n, lo=0, hi=2**50):
    return rng.integers(lo, hi, size=n).astype(np.uint64)


# =============================================================================
# Demotion cascade
# =============================================================================


class TestDemotion:
    def test_hot_evictions_land_in_cold_with_values(self):
        """Fill hot past capacity; every displaced pair must be findable in
        the cold tier with its exact value."""
        t = _tiered(hot=128, cold=8 * 128, dim=2)
        rng = np.random.default_rng(0)
        seen = {}
        for step in range(4):
            kb = _keys(rng, 128)
            vals = np.full((128, 2), float(step + 1), np.float32)
            r = t.insert_or_assign(kb, jnp.asarray(vals))
            t = r.table
            for k in kb:
                seen[int(k)] = float(step + 1)
        assert int(t.hot.size()) == 128          # hot stayed at capacity
        assert int(t.cold.size()) > 0            # the cascade happened
        all_k = np.fromiter(seen, np.uint64)
        f = t.find(all_k, promote=False)
        assert bool(np.asarray(f.found).all())   # nothing was lost
        got = np.asarray(f.values)[:, 0]
        want = np.array([seen[int(k)] for k in all_k], np.float32)
        np.testing.assert_array_equal(got, want)

    def test_conservation_exact_when_cold_absorbs_everything(self):
        """size() + dropped == distinct keys inserted, batch after batch."""
        t = _tiered(hot=128, cold=16 * 128, dim=2)
        rng = np.random.default_rng(1)
        inserted, dropped = set(), 0
        for _ in range(6):
            kb = _keys(rng, 128)
            r = t.insert_or_assign(kb, jnp.ones((128, 2)))
            t = r.table
            dropped += int(r.dropped)
            inserted.update(int(k) for k in kb)
        assert dropped == 0                      # cold tier had room
        assert int(t.size()) == len(inserted)

    def test_drops_only_at_cold_boundary_and_are_reported(self):
        """With a tiny cold tier, pairs DO leave the hierarchy — exactly
        size + dropped == inserted, so nothing vanishes silently."""
        t = _tiered(hot=128, cold=128, dim=2)
        rng = np.random.default_rng(2)
        inserted, dropped = set(), 0
        for _ in range(6):
            kb = _keys(rng, 128)
            r = t.insert_or_assign(kb, jnp.ones((128, 2)))
            t = r.table
            dropped += int(r.dropped)
            inserted.update(int(k) for k in kb)
        assert dropped > 0
        # dropped counts pair EXITS; a key can re-enter on a later batch
        # and exit again, so exits >= distinct keys no longer resident
        assert dropped >= len(inserted) - int(t.size())
        assert int(t.size()) + dropped >= len(inserted)

    def test_hot_rejected_pairs_are_absorbed_by_cold(self):
        """Admission control: under LFU, a one-touch burst cannot displace
        high-count residents — the hot tier REJECTS it.  The hierarchy must
        absorb those pairs cold-side instead of dropping them."""
        t = _tiered(hot=128, cold=8 * 128, dim=2, score_policy="lfu")
        resident = np.arange(1, 129, dtype=np.uint64)
        for _ in range(5):  # count them up: hot residents become beatproof
            t = t.insert_or_assign(resident, jnp.ones((128, 2))).table
        burst = np.arange(10_000, 10_128, dtype=np.uint64)
        r = t.insert_or_assign(burst, jnp.full((128, 2), 7.0))
        status = np.asarray(r.status)
        assert (status == 4).all()               # hot rejected the burst...
        t = r.table
        assert int(r.demoted) == 128             # ...cold absorbed it
        f = t.find(burst, promote=False)
        assert bool(np.asarray(f.found).all())
        assert not bool(np.asarray(f.hot_hit).any())
        np.testing.assert_allclose(np.asarray(f.values), 7.0)
        assert bool(np.asarray(r.ok).all())      # placed SOMEWHERE

    def test_insert_with_aux_columns_pads_like_flat_table(self):
        """Regression: caller rows [N, dim] against aux-augmented value
        planes must pad exactly like the flat handle (the sparse-optimizer
        layout) — the demotion merge used to mix widths and crash."""
        t = _tiered(hot=128, cold=4 * 128, dim=4, aux_value_dim=2)
        rng = np.random.default_rng(10)
        for _ in range(3):  # overflow hot so demotion actually runs
            kb = _keys(rng, 128)
            r = t.insert_or_assign(kb, jnp.ones((128, 4)))
            t = r.table
        assert int(t.cold.size()) > 0
        f = t.find(kb, promote=False)
        assert bool(np.asarray(f.found).all())
        np.testing.assert_allclose(np.asarray(f.values), 1.0)

    def test_ok_is_false_when_both_tiers_reject(self):
        """`.ok` must report the cold tier's actual verdict: a pair
        rejected by hot AND rejected by the cold tier is not resident
        anywhere, so its lane reads False (duplicates included)."""
        t = _tiered(hot=128, cold=128, dim=2, score_policy="lfu")
        strong = np.arange(1, 129, dtype=np.uint64)
        for _ in range(4):                       # hot residents: count 4
            t = t.insert_or_assign(strong, jnp.ones((128, 2))).table
        # fill cold with count-3 pairs: evict the hot set via a stronger
        # burst, then re-establish it — twice to cycle scores up
        burst = np.repeat(np.arange(1000, 1032, dtype=np.uint64), 4)
        t = t.insert_or_assign(burst, jnp.ones((128, 2))).table
        cold_full = int(t.cold.size())
        # weak count-1 pairs: rejected by hot (min count >= 3 hot-side);
        # cold has 128 - cold_full free slots, rest compete and lose
        weak = np.repeat(np.arange(5000, 5064, dtype=np.uint64), 2)
        r = t.insert_or_assign(weak, jnp.ones((128, 2)))
        status = np.asarray(r.status)
        ok = np.asarray(r.ok)
        assert (status == 4).all()               # all hot-rejected
        resident = np.asarray(r.table.contains(weak))
        np.testing.assert_array_equal(ok, resident)  # ok == ground truth
        if cold_full + 64 > 128:                 # some really were dropped
            assert not ok.all()

    def test_demotion_write_back_freshens_stale_cold_copy(self):
        """Inclusive-cache coherence: promote a key, update its hot value,
        then force it out of hot — the cold copy must carry the UPDATED
        value (write-back on demotion), not the stale pre-promotion one."""
        t = _tiered(hot=128, cold=8 * 128, dim=2)
        key = np.array([42], np.uint64)
        t = t.insert_or_assign(key, jnp.full((1, 2), 1.0)).table
        # push it to cold, then promote it back via find
        t = t.insert_or_assign(np.arange(100, 356, dtype=np.uint64),
                               jnp.zeros((256, 2))).table
        t = t.find(key).table
        assert bool(np.asarray(t.find(key, promote=False).hot_hit).all())
        # update the hot copy (the cold copy still holds 1.0)
        t = t.assign(key, jnp.full((1, 2), 9.0))
        # force the key out of hot again
        t = t.insert_or_assign(np.arange(500, 756, dtype=np.uint64),
                               jnp.zeros((256, 2))).table
        f = t.find(key, promote=False)
        assert bool(np.asarray(f.found).all())
        np.testing.assert_allclose(np.asarray(f.values), 9.0)


# =============================================================================
# Miss-path promotion
# =============================================================================


class TestPromotion:
    def _overflowed(self, rng, dim=2):
        """A table whose hot tier was fully churned: early keys live cold."""
        t = _tiered(hot=128, cold=8 * 128, dim=dim)
        early = _keys(rng, 128, lo=1, hi=2**30)
        t = t.insert_or_assign(early, jnp.full((128, dim), 3.0)).table
        churn = _keys(rng, 256, lo=2**31, hi=2**32)
        t = t.insert_or_assign(churn, jnp.zeros((256, dim))).table
        cold_resident = ~np.asarray(t.find(early, promote=False).hot_hit)
        return t, early[cold_resident]

    def test_find_promotes_cold_hits_into_hot(self):
        rng = np.random.default_rng(3)
        t, cold_keys = self._overflowed(rng)
        assert len(cold_keys) > 0
        probe = cold_keys[:64]
        r = t.find(probe)
        assert bool(np.asarray(r.found).all())
        np.testing.assert_allclose(np.asarray(r.values), 3.0)
        assert int(r.promoted) == len(probe)
        # the NEXT access is a hot hit (inclusive-on-access)
        f2 = r.table.find(probe, promote=False)
        assert bool(np.asarray(f2.hot_hit).all())
        # inclusive: the cold copy survives promotion
        assert bool(np.asarray(r.table.cold.contains(probe)).all())

    def test_promotion_victims_cascade_down(self):
        rng = np.random.default_rng(4)
        t, cold_keys = self._overflowed(rng)
        probe = cold_keys[:64]
        pre = int(t.size())
        r = t.find(probe)
        # promotion displaced hot entries; they must now be cold-resident
        assert int(r.demoted) > 0
        assert int(r.table.size()) == pre        # promotion conserves keys
        assert int(r.dropped) == 0               # roomy cold tier: no exits

    def test_promote_false_is_a_pure_reader(self):
        rng = np.random.default_rng(5)
        t, cold_keys = self._overflowed(rng)
        r = t.find(cold_keys[:32], promote=False)
        for a, b in zip(jax.tree.leaves(r.table), jax.tree.leaves(t)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_find_or_insert_returns_cold_value_not_init(self):
        """The miss path must PROMOTE the trained cold row, not shadow it
        with a fresh init row."""
        rng = np.random.default_rng(6)
        t, cold_keys = self._overflowed(rng)
        probe = cold_keys[:32]
        r = t.find_or_insert(probe, jnp.full((32, 2), -5.0))
        assert bool(np.asarray(r.found).all())   # found: it lived in cold
        np.testing.assert_allclose(np.asarray(r.values), 3.0)  # cold value
        assert int(r.promoted) == len(probe)
        f2 = r.table.find(probe, promote=False)
        assert bool(np.asarray(f2.hot_hit).all())
        np.testing.assert_allclose(np.asarray(f2.values), 3.0)

    def test_find_or_insert_fresh_misses_admit_init(self):
        t = _tiered()
        fresh = np.arange(1, 33, dtype=np.uint64)
        r = t.find_or_insert(fresh, jnp.full((32, 4), 2.5))
        assert not bool(np.asarray(r.found).any())
        np.testing.assert_allclose(np.asarray(r.values), 2.5)
        assert bool(np.asarray(r.table.contains(fresh)).all())

    def test_rejected_cold_hit_keeps_its_cold_score(self):
        """Regression: a cold-resident key whose promotion is REJECTED by
        the hot tier must keep its accumulated cold score — re-demoting it
        with a fresh count-1 init would make every rejected re-access
        LOWER its eviction priority."""
        t = _tiered(hot=128, cold=4 * 128, dim=2, score_policy="lfu")
        strong = np.arange(1, 129, dtype=np.uint64)
        for _ in range(5):                       # hot residents: count 5
            t = t.insert_or_assign(strong, jnp.ones((128, 2))).table
        # park X in cold with an accumulated count-3 score: count it up
        # hot-side, then displace it with a stronger burst
        x = np.repeat(np.array([777], np.uint64), 3)
        t = t.insert_or_assign(x, jnp.ones((3, 2))).table  # count 3, evicts one
        burst = np.repeat(np.arange(1000, 1016, dtype=np.uint64), 8)  # count 8
        t = t.insert_or_assign(burst, jnp.ones((128, 2))).table
        xk = np.array([777], np.uint64)
        assert bool(np.asarray(t.cold.contains(xk)).all())
        score_before = int(np.asarray(t.cold.find(xk).score_lo)[0])
        # re-access via find_or_insert: hot rejects (count 1 < residents)
        r = t.find_or_insert(xk, jnp.zeros((1, 2)))
        assert int(np.asarray(r.status)[0]) == 4  # rejected by hot
        assert bool(np.asarray(r.ok)[0])          # still resident (cold)
        score_after = int(np.asarray(r.table.cold.find(xk).score_lo)[0])
        assert score_after == score_before        # NOT downgraded to 1

    def test_find_or_insert_single_hot_probe(self, monkeypatch):
        """The pre-pass locate is shared with the upsert closure through
        the loc= seam: one hot locate + cold reads, nothing re-probed."""
        from repro.core import find as find_mod

        t = _tiered(hot=128, cold=4 * 128, dim=2)
        t = t.insert_or_assign(np.arange(1, 65, dtype=np.uint64),
                               jnp.ones((64, 2))).table
        calls = {"n": 0}
        real = find_mod.locate

        def counting(*a, **kw):
            calls["n"] += 1
            return real(*a, **kw)

        monkeypatch.setattr(find_mod, "locate", counting)
        t.find_or_insert(np.arange(1, 65, dtype=np.uint64),
                         jnp.zeros((64, 2)))
        # hot pre-pass (1) + cold find_rows (1) + demotion upsert's own
        # locate on the cold tier (1); the hot closure reuses the pre-pass
        assert calls["n"] == 3

    def test_duplicate_keys_promote_once(self):
        rng = np.random.default_rng(7)
        t, cold_keys = self._overflowed(rng)
        dup = np.repeat(cold_keys[:8], 4)        # 8 distinct keys, 32 lanes
        r = t.find(dup)
        assert bool(np.asarray(r.found).all())
        assert int(r.promoted) == 8


# =============================================================================
# Hit-rate uplift (the tentpole acceptance criterion)
# =============================================================================


class TestHitRateUplift:
    def test_tiered_beats_same_hot_capacity_single_under_zipf(self):
        rng = np.random.default_rng(42)
        hot_cap, cold_cap, batch, steps = 128, 8 * 128, 256, 12
        stream = zipf_keys(rng, batch * steps, 1.05, 2 * cold_cap)
        tiered = _tiered(hot=hot_cap, cold=cold_cap, dim=4)
        single = HKVTable.create(capacity=hot_cap, dim=4)
        init = jnp.zeros((batch, 4), jnp.float32)

        def replay(table):
            hits = []
            for i in range(steps):
                kb = stream[i * batch : (i + 1) * batch]
                r = table.find_or_insert(kb, init)
                table = r.table
                hits.append(float(np.asarray(r.found).mean()))
            return float(np.mean(hits[steps // 2:]))

        hr_tiered, hr_single = replay(tiered), replay(single)
        # "measurably higher": demand several points, not noise
        assert hr_tiered > hr_single + 0.03, (hr_tiered, hr_single)


# =============================================================================
# Score translation
# =============================================================================


class TestScoreTranslation:
    def test_custom_destination_passes_scores_through(self):
        sc = U64(jnp.asarray([1, 2], jnp.uint32), jnp.asarray([3, 4], jnp.uint32))
        out = translate_scores(get_policy("lru"), get_policy("custom"), sc)
        assert out is sc

    def test_non_custom_destination_restamps(self):
        sc = U64(jnp.zeros(2, jnp.uint32), jnp.zeros(2, jnp.uint32))
        for dst in ("lru", "lfu", "epoch_lru", "epoch_lfu"):
            assert translate_scores(get_policy("custom"), get_policy(dst), sc) is None

    def test_demoted_pairs_keep_relative_order_in_custom_cold(self):
        """Default cold policy is 'custom': pairs demoted with LOW hot
        scores must lose cold-tier admission races against pairs demoted
        with HIGH hot scores."""
        # lfu hot tier: score == touch count, easy to control
        t = _tiered(hot=128, cold=128, dim=2, score_policy="lfu")
        hot_keys = np.arange(1, 129, dtype=np.uint64)
        for _ in range(3):                       # count=3 for the residents
            t = t.insert_or_assign(hot_keys, jnp.ones((128, 2))).table
        # displace all of them with a beating burst: count via duplicates is
        # not needed — lfu inits at batch multiplicity; use 4 repeats
        burst = np.repeat(np.arange(1000, 1032, dtype=np.uint64), 4)
        t = t.insert_or_assign(burst, jnp.ones((128, 2))).table
        # the displaced count-3 pairs now live in the 128-slot cold tier
        cold_before = np.asarray(t.cold.contains(hot_keys))
        assert cold_before.sum() > 0
        # hot rejects the count-1 weak burst (residents have count >= 3);
        # its pairs cascade to cold carrying translated score 1 — they may
        # claim EMPTY cold slots but must NOT displace the score-3 pairs
        weak = np.arange(5000, 5128, dtype=np.uint64)
        r = t.insert_or_assign(weak, jnp.ones((128, 2)))
        t2 = r.table
        cold_after = np.asarray(t2.cold.contains(hot_keys))
        np.testing.assert_array_equal(cold_after[cold_before],
                                      np.ones(cold_before.sum(), bool))
        # and with free slots exhausted, the surplus weak pairs were
        # rejected at the cold boundary — reported, not silent
        assert int(r.dropped) > 0


# =============================================================================
# Protocol conformance + handle behavior
# =============================================================================


class TestTieredProtocol:
    # (the tiered per-op contract now runs in the parametrized suite,
    # tests/test_kvtable_conformance.py)

    def test_isinstance_kvtable(self):
        assert isinstance(_tiered(), KVTable)

    def test_pytree_roundtrip_preserves_statics(self):
        t = _tiered(dim=2, score_policy="lfu")
        leaves, treedef = jax.tree_util.tree_flatten(t)
        t2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(t2, TieredHKVTable)
        assert t2.hot.cfg == t.hot.cfg and t2.cold.cfg == t.cold.cfg
        assert t2.promote_on_find == t.promote_on_find

    def test_jit_and_scan(self):
        t = _tiered(dim=2)
        keys = np.arange(1, 33, dtype=np.uint64)
        k = u64.from_uint64(keys)

        @jax.jit
        def step(table, kh, kl):
            r = table.find_or_insert(U64(kh, kl), jnp.ones((32, 2)))
            return r.table, r.found

        t2, found = step(t, k.hi, k.lo)
        assert not bool(np.asarray(found).any())

        def body(table, _):
            r = table.find_or_insert(U64(k.hi, k.lo), jnp.ones((32, 2)))
            return r.table, r.found
        final, founds = jax.lax.scan(body, t2, jnp.arange(3))
        assert bool(np.asarray(founds).all())    # present from step one

    def test_erase_kills_both_copies(self):
        rng = np.random.default_rng(8)
        t = _tiered(hot=128, cold=8 * 128, dim=2)
        keys = _keys(rng, 128, lo=1, hi=2**30)
        t = t.insert_or_assign(keys, jnp.ones((128, 2))).table
        t = t.insert_or_assign(_keys(rng, 128, lo=2**31, hi=2**32),
                               jnp.zeros((128, 2))).table
        t = t.find(keys[:16]).table              # some now live in BOTH tiers
        t = t.erase(keys[:16])
        assert not bool(np.asarray(t.contains(keys[:16])).any())
        # no resurrection through a later miss-path probe
        f = t.find(keys[:16])
        assert not bool(np.asarray(f.found).any())

    def test_geometry_mismatch_rejected(self):
        from repro.core.table import HKVConfig

        with pytest.raises(ValueError, match="geometry"):
            TieredHKVTable.from_configs(
                HKVConfig(capacity=128, dim=4),
                HKVConfig(capacity=256, dim=8),
            )

    def test_session_update_rows_hits_hot_rows(self):
        t = _tiered(dim=2)
        keys = np.arange(1, 17, dtype=np.uint64)
        t = t.insert_or_assign(keys, jnp.full((16, 2), 2.0)).table
        s = t.session()
        s.update_rows(keys, lambda rows: rows * 3.0)
        t2 = s.commit()
        assert isinstance(t2, TieredHKVTable)
        np.testing.assert_allclose(
            np.asarray(t2.find(keys, promote=False).values), 6.0)


# =============================================================================
# Embedding layer over a tiered table
# =============================================================================


class TestTieredEmbedding:
    def _emb(self):
        from repro.embedding.dynamic import HKVEmbedding
        from repro.embedding.sparse_opt import SparseOptimizer

        return HKVEmbedding(capacity=8 * 128, dim=8, hot_capacity=2 * 128,
                            optimizer=SparseOptimizer("sgd", lr=1.0))

    def test_train_serve_grads_cycle(self):
        emb = self._emb()
        t = emb.create()
        assert isinstance(t, TieredHKVTable)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, 4096, size=(2, 32)))
        t, rows = emb.lookup_train(t, toks)
        assert rows.shape == (2, 32, 8)
        g = jnp.ones_like(rows)
        t = emb.apply_grads(t, toks, g)
        served = emb.lookup_serve(t, toks)
        # sgd lr=1: served = init - 1.0 * summed grad (dup tokens sum)
        assert served.shape == rows.shape
        assert float(jnp.abs(served - rows).max()) > 0.5  # grads landed

    def test_trained_value_survives_demotion_and_promotion(self):
        """The capacity-beyond-HBM story end to end: train a row, churn it
        out of the hot tier, access it again — the TRAINED value comes
        back, not a re-init."""
        emb = self._emb()
        t = emb.create()
        toks = jnp.arange(64).reshape(1, 64)
        t, rows = emb.lookup_train(t, toks)
        t = emb.apply_grads(t, toks, jnp.ones_like(rows))
        trained = emb.lookup_serve(t, toks)
        # churn the hot tier with 4x its capacity of fresh tokens
        churn = jnp.arange(10_000, 10_000 + 1024).reshape(1, 1024)
        t, _ = emb.lookup_train(t, churn)
        assert not bool(np.asarray(
            t.find(emb.keys_of(toks), promote=False).hot_hit).all())
        t, rows2 = emb.lookup_train(t, toks)     # promotes back
        np.testing.assert_allclose(np.asarray(rows2), np.asarray(trained),
                                   rtol=1e-6)


# =============================================================================
# Checkpointing both tiers atomically
# =============================================================================


class TestTieredCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        from repro.train import checkpoint as ckpt

        rng = np.random.default_rng(9)
        t = _tiered(hot=128, cold=4 * 128, dim=3)
        for _ in range(3):
            t = t.insert_or_assign(_keys(rng, 128),
                                   jnp.asarray(rng.normal(size=(128, 3)),
                                               jnp.float32)).table
        ckpt.save_table(str(tmp_path), 7, t)
        restored, extra = ckpt.restore_table(str(tmp_path), 7, t)
        assert extra["table"]["kind"] == "TieredHKVTable"
        assert extra["table"]["hot"]["capacity"] == 128
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_structure_mismatch_rejected(self, tmp_path):
        from repro.train import checkpoint as ckpt

        t = _tiered(hot=128, cold=4 * 128, dim=3)
        ckpt.save_table(str(tmp_path), 1, t)
        other = _tiered(hot=4 * 128, cold=128, dim=3)  # swapped tiers
        with pytest.raises(ValueError, match="structure"):
            ckpt.restore_table(str(tmp_path), 1, other)


# =============================================================================
# Sharded-over-tiered (the existing conformance harness, unchanged)
# =============================================================================


@pytest.mark.slow  # shard_map compiles per op: minutes on CPU
def test_sharded_over_tiered_protocol_conformance():
    from tests.test_kvtable_conformance import protocol_roundtrip as \
        _protocol_roundtrip

    from repro.distributed.table_sharding import ShardedHKVTable
    from repro.embedding.dynamic import HKVEmbedding
    from repro.embedding.sparse_opt import SparseOptimizer

    mesh = jax.make_mesh((1,), ("data",))
    table = ShardedHKVTable.create(
        mesh,
        HKVEmbedding(capacity=4 * 128, dim=3, hot_capacity=128,
                     optimizer=SparseOptimizer("sgd")),
    )
    table = _protocol_roundtrip(table)
    r = table.find_or_insert(np.arange(1, 65, dtype=np.uint64))
    assert bool(np.asarray(r.found).all())
