"""Serving engine, accum_or_assign, HMEM tier, checkpointable table state."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core import ops, table, u64
from repro.serving.engine import Request, ServingEngine


class TestServingEngine:
    def test_waves_drain_and_match_sequential_decode(self):
        arch = get_arch("qwen2-0.5b")
        model = arch.model(smoke=True)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        vocab = arch.smoke.vocab

        eng = ServingEngine(model, params, max_batch=2, max_len=32)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, vocab, size=8).astype(np.int32),
                    max_new=4 + 2 * i)
            for i in range(4)  # 2 waves of 2
        ]
        for r in reqs:
            eng.submit(r)
        done = eng.run_until_drained()
        assert len(done) == 4
        for r in done:
            assert r.done and len(r.out) == r.max_new
        # lane 0 of wave 1 must match a standalone greedy decode
        r0 = reqs[0]
        logits, st = model.prefill(params, jnp.asarray(r0.prompt[None]), 32)
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(r0.max_new - 1):
            logits, st = model.decode_step(
                params, jnp.asarray([toks[-1]], jnp.int32), st
            )
            toks.append(int(jnp.argmax(logits[0])))
        # engine ran batch=2 (padded) — same tokens expected
        assert r0.out[: len(toks)] == toks


class TestAccumOrAssign:
    def test_accumulates_and_inserts(self):
        cfg = table.HKVConfig(capacity=2 * 128, dim=4)
        state = table.create(cfg)
        k = u64.from_uint64(np.arange(10, dtype=np.uint64))
        state = ops.insert_or_assign(state, cfg, k, jnp.ones((10, 4))).state
        # accum on 5 existing + 5 new, with a duplicated key in the batch
        mix = np.array([0, 1, 2, 3, 4, 100, 101, 102, 103, 0], np.uint64)
        res = ops.accum_or_assign(
            state, cfg, u64.from_uint64(mix), jnp.full((10, 4), 0.5)
        )
        got = ops.find(res.state, cfg, u64.from_uint64(np.array([0, 1, 100], np.uint64)))
        np.testing.assert_allclose(np.asarray(got.values)[0], 2.0)   # 1 + 0.5*2 dup
        np.testing.assert_allclose(np.asarray(got.values)[1], 1.5)   # 1 + 0.5
        np.testing.assert_allclose(np.asarray(got.values)[2], 0.5)   # fresh insert


class TestHMEMTier:
    def test_tiered_value_placement_structural(self):
        """Config-D analogue: hmem tier keeps key-side arrays separate from
        the value plane; on backends without host memory-kinds the split is
        structural but all ops remain correct."""
        cfg = table.HKVConfig(capacity=128, dim=8, value_tier="hmem")
        state = table.create(cfg)
        k = u64.from_uint64(np.arange(32, dtype=np.uint64))
        state = ops.insert_or_assign(state, cfg, k, jnp.ones((32, 8))).state
        out = ops.find(state, cfg, k)
        assert bool(np.asarray(out.found).all())
        np.testing.assert_allclose(np.asarray(out.values), 1.0)


class TestTableCheckpoint:
    def test_table_state_checkpoints_and_restores(self, tmp_path):
        from repro.train import checkpoint as ckpt

        cfg = table.HKVConfig(capacity=2 * 128, dim=4, score_policy="lfu")
        state = table.create(cfg)
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 10_000, size=200).astype(np.uint64)
        state = ops.insert_or_assign(
            state, cfg, u64.from_uint64(keys), jnp.ones((200, 4))
        ).state
        ckpt.save(str(tmp_path), 1, state)
        restored, _ = ckpt.restore(str(tmp_path), 1, state)
        # identical table contents AND scores (LFU counters survive restart)
        for a, b in zip(state, restored):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored table keeps serving
        out = ops.find(restored, cfg, u64.from_uint64(keys[:16]))
        assert bool(np.asarray(out.found).all())


def test_export_batch_if_threshold():
    cfg = table.HKVConfig(capacity=128, dim=2, score_policy="custom")
    state = table.create(cfg)
    keys = np.arange(64, dtype=np.uint64)
    state = ops.insert_or_assign(
        state, cfg, u64.from_uint64(keys), jnp.zeros((64, 2)),
        custom_scores=u64.from_uint64(keys * 10),
    ).state
    out = ops.export_batch_if(
        state, cfg, 0, cfg.num_buckets, u64.from_uint64(np.uint64(300))
    )
    mask = np.asarray(out.mask)
    scores = (np.asarray(out.score_hi, np.uint64) << np.uint64(32)) | np.asarray(
        out.score_lo, np.uint64
    )
    assert mask.sum() == np.sum(keys * 10 >= 300)
    assert (scores[mask] >= 300).all()
