"""hmem tier-crossing coverage under jit (§3.6 / DESIGN.md §2.5).

The 'hmem' value placement routes every value-plane touch through
`tier_gather`/`tier_scatter` — on TPU an explicit host<->device crossing,
on backends without an addressable host space a structural split.  Either
way the CONTRACT is: bit-identical results to the 'hbm' tier, under jit,
for every op that moves value rows.  Pinned here:

  * tier_gather/tier_scatter round-trip (set and add) under jit;
  * find_or_insert on a `value_tier='hmem'` table — states, statuses,
    values all bit-equal to the hbm twin;
  * export_batch streaming through `tier_gather` — bit-equal to hbm.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import HKVTable, U64, u64
from repro.core import table as table_mod


class TestGatherScatterRoundTrip:
    def test_jit_gather_matches_plain_indexing(self):
        rng = np.random.default_rng(0)
        values = jnp.asarray(rng.normal(size=(256, 8)), jnp.float32)
        rows = jnp.asarray(rng.integers(0, 256, size=64), jnp.int32)
        for tier in ("hbm", "hmem"):
            got = jax.jit(
                lambda v, r: table_mod.tier_gather(tier, v, r)
            )(values, rows)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(values)[np.asarray(rows)])

    def test_jit_scatter_then_gather_round_trips(self):
        rng = np.random.default_rng(1)
        values = jnp.zeros((256, 4), jnp.float32)
        rows = jnp.asarray(rng.permutation(256)[:64], jnp.int32)  # unique
        updates = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)

        for tier in ("hbm", "hmem"):
            @jax.jit
            def rt(v, r, up):
                v2 = table_mod.tier_scatter(tier, v, r, up)
                return table_mod.tier_gather(tier, v2, r), v2

            back, v2 = rt(values, rows, updates)
            np.testing.assert_array_equal(np.asarray(back), np.asarray(updates))
            # untouched rows stay zero
            mask = np.ones(256, bool)
            mask[np.asarray(rows)] = False
            assert not np.asarray(v2)[mask].any()

    def test_jit_scatter_add_accumulates(self):
        values = jnp.ones((64, 2), jnp.float32)
        rows = jnp.asarray([3, 3, 7], jnp.int32)  # duplicate rows accumulate
        updates = jnp.full((3, 2), 2.0, jnp.float32)
        for tier in ("hbm", "hmem"):
            v2 = jax.jit(
                lambda v, r, up: table_mod.tier_scatter(tier, v, r, up, add=True)
            )(values, rows, updates)
            got = np.asarray(v2)
            np.testing.assert_allclose(got[3], 5.0)   # 1 + 2 + 2
            np.testing.assert_allclose(got[7], 3.0)
            np.testing.assert_allclose(got[1], 1.0)

    def test_oob_drop_mode_under_jit(self):
        """mode='drop' is the masked-lane contract every op relies on."""
        values = jnp.zeros((16, 2), jnp.float32)
        rows = jnp.asarray([2, 16], jnp.int32)    # 16 = one past the end
        updates = jnp.ones((2, 2), jnp.float32)
        for tier in ("hbm", "hmem"):
            v2 = jax.jit(
                lambda v, r, up: table_mod.tier_scatter(tier, v, r, up)
            )(values, rows, updates)
            got = np.asarray(v2)
            np.testing.assert_allclose(got[2], 1.0)
            assert got.sum() == 2.0               # OOB lane dropped


def _twin_tables(dim=6, capacity=2 * 128):
    hbm = HKVTable.create(capacity=capacity, dim=dim, value_tier="hbm")
    hmem = HKVTable.create(capacity=capacity, dim=dim, value_tier="hmem")
    return hbm, hmem


class TestHmemOpParity:
    def test_find_or_insert_bit_identical_vs_hbm_under_jit(self):
        rng = np.random.default_rng(2)
        hbm, hmem = _twin_tables()

        @jax.jit
        def step(t, kh, kl, init):
            r = t.find_or_insert(U64(kh, kl), init)
            return r.table, r.values, r.found, r.status

        for _ in range(5):  # re-hits, inserts, evictions past capacity
            keys = rng.integers(0, 2**14, size=160).astype(np.uint64)
            k = u64.from_uint64(keys)
            init = jnp.asarray(rng.normal(size=(160, 6)), jnp.float32)
            hbm, v1, f1, s1 = step(hbm, k.hi, k.lo, init)
            hmem, v2, f2, s2 = step(hmem, k.hi, k.lo, init)
            np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
            np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        for a, b in zip(jax.tree.leaves(hbm.state), jax.tree.leaves(hmem.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_export_batch_bit_identical_vs_hbm_under_jit(self):
        rng = np.random.default_rng(3)
        hbm, hmem = _twin_tables()
        keys = rng.integers(0, 2**40, size=200).astype(np.uint64)
        vals = jnp.asarray(rng.normal(size=(200, 6)), jnp.float32)
        ins = jax.jit(lambda t, kh, kl, v: t.insert_or_assign(U64(kh, kl), v).table)
        k = u64.from_uint64(keys)
        hbm, hmem = ins(hbm, k.hi, k.lo, vals), ins(hmem, k.hi, k.lo, vals)
        nb = hbm.cfg.num_buckets

        exp = jax.jit(lambda t: t.export_batch(0, nb))
        e1, e2 = exp(hbm), exp(hmem)
        for f in ("key_hi", "key_lo", "values", "score_hi", "score_lo", "mask"):
            np.testing.assert_array_equal(
                np.asarray(getattr(e1, f)), np.asarray(getattr(e2, f)),
                err_msg=f"export.{f}")

    def test_insert_and_evict_stream_bit_identical_vs_hbm(self):
        """The demotion transport itself must be tier-independent: a hot
        tier would otherwise demote different pairs depending on where its
        values live."""
        rng = np.random.default_rng(4)
        hbm, hmem = _twin_tables(dim=4, capacity=128)

        @jax.jit
        def step(t, kh, kl, v):
            r = t.insert_and_evict(U64(kh, kl), v)
            return r.table, r.status, r.evicted

        for _ in range(3):
            keys = rng.integers(0, 2**40, size=128).astype(np.uint64)
            k = u64.from_uint64(keys)
            vals = jnp.asarray(rng.normal(size=(128, 4)), jnp.float32)
            hbm, s1, ev1 = step(hbm, k.hi, k.lo, vals)
            hmem, s2, ev2 = step(hmem, k.hi, k.lo, vals)
            np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
            for f in ev1._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(ev1, f)), np.asarray(getattr(ev2, f)),
                    err_msg=f"evicted.{f}")
        assert int(ev1.count()) > 0
