"""hkv-obs acceptance: telemetry neutrality, λ-flat counters, trace export.

The ISSUE's four acceptance criteria, plus unit coverage of the obs
building blocks:

  (a) op results are BIT-identical with the telemetry channel on vs off,
      on both backends (jnp and the fused Pallas path in interpret mode);
  (b) `telemetry=None` (the default) adds ZERO kernel launches — the
      trace-time launch accounting of test_find_kernel.py's
      TestLaunchBudget, re-run against the telemetry seam;
  (c) an exp2-style λ sweep reproduces the paper's flat (<5%) probe
      curve FROM THE TELEMETRY CHANNEL ITSELF (probes_per_query);
  (d) `launch/serve.py --trace-out` emits Chrome trace-event JSON that
      round-trips `json.load` with ph/ts/name on every event.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import merge, ops, table, u64
from repro.core.api import HKVTable, normalize_keys
from repro.core.predicates import SweepPredicate
from repro.core.tiered import TieredHKVTable
from repro.embedding.sparse_opt import SparseOptimizer
from repro.kernels import digest_scan as _ds
from repro.kernels import find_scan as _fs
from repro.kernels import gather as _ga
from repro.obs import (MetricsRegistry, NOOP_TRACER, OpTelemetry,
                       TelemetrySink, Tracer, as_tracer)
from repro.obs import telemetry as obs_telemetry
from repro.serving.embedding_engine import EngineMetrics

BACKENDS = ("jnp", "kernel")
DIM = 8
CAP = 8 * 128


def _filled(rng, cfg, n):
    keys = rng.integers(1, 2**50, size=n).astype(np.uint64)
    vals = jnp.asarray(rng.normal(size=(n, cfg.dim)), jnp.float32)
    state = merge.upsert(table.create(cfg), cfg, u64.from_uint64(keys),
                         vals).state
    return state, keys


def _tree_equal(a, b, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for i, (x, y) in enumerate(zip(la, lb)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            f"{msg}: leaf {i} diverged"


# =============================================================================
# (a) bit-identity: telemetry on/off, both backends, every op family
# =============================================================================


@pytest.mark.parametrize("backend", BACKENDS)
def test_op_results_bit_identical_with_telemetry(backend):
    rng = np.random.default_rng(11)
    cfg = table.HKVConfig(capacity=CAP, dim=DIM, buckets_per_key=2)
    state, resident = _filled(rng, cfg, 400)
    hits = rng.choice(resident, size=48)
    misses = rng.integers(2**50, 2**60, size=16).astype(np.uint64)
    k = u64.from_uint64(np.concatenate([hits, misses]))
    vals = jnp.asarray(rng.normal(size=(64, DIM)), jnp.float32)
    opt = SparseOptimizer("sgd", lr=0.5)
    pred = SweepPredicate.score_at_least(1)

    cases = {
        "find": lambda tel: ops.find(state, cfg, k, backend=backend,
                                     telemetry=tel),
        "find_rows": lambda tel: ops.find_rows(state, cfg, k,
                                               backend=backend,
                                               telemetry=tel),
        "find_ptr": lambda tel: ops.find_ptr(state, cfg, k, backend=backend,
                                             telemetry=tel),
        "contains": lambda tel: ops.contains(state, cfg, k, backend=backend,
                                             telemetry=tel),
        "insert_or_assign": lambda tel: ops.insert_or_assign(
            state, cfg, k, vals, backend=backend, telemetry=tel),
        "insert_and_evict": lambda tel: ops.insert_and_evict(
            state, cfg, k, vals, backend=backend, telemetry=tel),
        "find_or_insert": lambda tel: ops.find_or_insert(
            state, cfg, k, vals, backend=backend, telemetry=tel),
        "accum_or_assign": lambda tel: ops.accum_or_assign(
            state, cfg, k, vals, telemetry=tel),
        "update_rows": lambda tel: ops.update_rows(
            state, cfg, k, vals, opt, backend=backend, telemetry=tel),
        "assign": lambda tel: ops.assign(state, cfg, k, vals,
                                         telemetry=tel),
        "erase": lambda tel: ops.erase(state, cfg, k, telemetry=tel),
        "erase_if": lambda tel: ops.erase_if(state, cfg, pred,
                                             backend=backend,
                                             telemetry=tel),
        "evict_if": lambda tel: ops.evict_if(state, cfg, pred, 16,
                                             backend=backend,
                                             telemetry=tel),
    }
    for name, run in cases.items():
        sink = TelemetrySink()
        _tree_equal(run(None), run(sink), f"{name} [{backend}]")
        assert name in sink.by_op, name
        if name in ("erase_if", "evict_if"):   # sweeps: no key lanes
            assert int(np.asarray(sink.total().probed_buckets)) > 0, name
        else:
            assert int(np.asarray(sink.total().lanes)) == 64, name


def test_telemetry_counters_are_correct():
    """Spot-check the counter semantics on a known workload: fresh
    inserts are all misses+inserted; a re-find hits everything."""
    rng = np.random.default_rng(5)
    cfg = table.HKVConfig(capacity=CAP, dim=4, buckets_per_key=2)
    state = table.create(cfg)
    keys = rng.integers(1, 2**40, size=64).astype(np.uint64)
    k = u64.from_uint64(keys)
    vals = jnp.zeros((64, 4), jnp.float32)
    sink = TelemetrySink()
    res = ops.insert_or_assign(state, cfg, k, vals, telemetry=sink)
    up = sink.by_op["insert_or_assign"].to_dict()
    assert up["lanes"] == 64
    assert up["inserted"] + up["evicted"] + up["rejected"] == 64
    assert up["updated"] == 0
    assert up["probed_buckets"] >= 64            # >= one bucket per key
    assert up["second_probe"] == 64              # all lanes missed bucket1
    ops.find(res.state, cfg, k, telemetry=sink)
    fd = sink.by_op["find"].to_dict()
    assert fd["hits"] == 64 and fd["misses"] == 0
    rates = sink.by_op["find"].rates()
    assert rates["hit_rate"] == 1.0
    assert 1.0 <= rates["probes_per_query"] <= 2.0


def test_tiered_telemetry_records_tier_motion():
    t = TieredHKVTable.create(hot_capacity=2 * 128, cold_capacity=8 * 128,
                              dim=4, slots_per_bucket=8)
    sink = TelemetrySink()
    keys = np.arange(1, 400, dtype=np.uint64)
    vals = jnp.ones((len(keys), 4), jnp.float32)
    r = t.insert_or_assign(keys, vals, telemetry=sink)
    assert "insert_and_evict" in sink.by_op       # hot-tier admission op
    assert "tier" in sink.by_op                   # the demotion cascade
    tier = sink.by_op["tier"].to_dict()
    assert tier["demoted"] == int(np.asarray(r.demoted))
    r2 = r.table.find(keys[:16], promote=True, telemetry=sink)
    assert "find" in sink.by_op


# =============================================================================
# (b) zero launches with telemetry off — and none added when on
# =============================================================================


class TestLaunchNeutrality:
    def _counters(self, monkeypatch):
        counts = {"find_scan": 0, "digest_scan": 0, "gather": 0}

        def wrap(mod, name, key):
            orig = getattr(mod, name)

            def counting(*a, **kw):
                counts[key] += 1
                return orig(*a, **kw)

            monkeypatch.setattr(mod, name, counting)

        wrap(_fs, "find_scan_tlp", "find_scan")
        wrap(_fs, "find_scan_pipeline", "find_scan")
        wrap(_ds, "digest_scan_tlp", "digest_scan")
        wrap(_ds, "digest_scan_pipeline", "digest_scan")
        wrap(_ga, "gather_rows", "gather")
        return counts

    def test_telemetry_none_adds_zero_launches(self, monkeypatch):
        """Kernel-backed find with the default telemetry=None stays ONE
        fused launch (the test_find_kernel.py pin, re-asserted across
        the telemetry seam)."""
        rng = np.random.default_rng(3)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4, buckets_per_key=2)
        state, resident = _filled(rng, cfg, 200)
        k = u64.from_uint64(resident[:64])
        counts = self._counters(monkeypatch)
        ops.find(state, cfg, k, backend="kernel", telemetry=None)
        assert (counts["find_scan"], counts["digest_scan"],
                counts["gather"]) == (1, 0, 0)

    def test_telemetry_on_adds_zero_launches(self, monkeypatch):
        """The observers are pure jnp over already-fetched planes — a
        live sink must not change the kernel launch set either."""
        rng = np.random.default_rng(3)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4, buckets_per_key=2)
        state, resident = _filled(rng, cfg, 200)
        k = u64.from_uint64(resident[:64])
        counts = self._counters(monkeypatch)
        ops.find(state, cfg, k, backend="kernel", telemetry=TelemetrySink())
        assert (counts["find_scan"], counts["digest_scan"],
                counts["gather"]) == (1, 0, 0)

    def test_telemetry_none_jaxpr_is_unchanged(self):
        """Zero jaxpr growth: spelling out telemetry=None traces to the
        exact equation list of the kwarg-free call."""
        t = HKVTable.create(capacity=2 * 128, dim=4, backend="kernel")
        k = normalize_keys(np.arange(1, 17, dtype=np.uint64))

        def plain(tt, kh, kl):
            r = tt.find(u64.U64(kh, kl))
            return r.values, r.found

        def spelled(tt, kh, kl):
            r = tt.find(u64.U64(kh, kl), telemetry=None)
            return r.values, r.found

        ja = jax.make_jaxpr(plain)(t, k.hi, k.lo)
        jb = jax.make_jaxpr(spelled)(t, k.hi, k.lo)
        assert len(ja.jaxpr.eqns) == len(jb.jaxpr.eqns)


# =============================================================================
# (c) the λ-stability claim, measured from the telemetry channel
# =============================================================================


def test_probe_counter_flat_across_load_factor():
    """exp1/exp2's headline, from the device counters: probes_per_query
    for resident-key finds varies < 5% from λ=0.25 to λ=0.95 (HKV probes
    a structurally constant bucket set; occupancy never grows it)."""
    cfg = table.HKVConfig(capacity=32 * 128, dim=4, buckets_per_key=2)
    probes = {}
    for lam in (0.25, 0.5, 0.75, 0.95):
        rng = np.random.default_rng(17)   # same stream per λ point
        n = int(lam * cfg.capacity)
        state, resident = _filled(rng, cfg, n)
        q = u64.from_uint64(rng.choice(resident, size=512))
        sink = TelemetrySink()
        ops.find(state, cfg, q, telemetry=sink)
        probes[lam] = sink.by_op["find"].rates()["probes_per_query"]
    lo, hi = min(probes.values()), max(probes.values())
    assert (hi - lo) / lo < 0.05, f"probe curve not λ-flat: {probes}"


# =============================================================================
# (d) serve.py --trace-out emits loadable Chrome trace JSON
# =============================================================================


def test_serve_trace_out_round_trips(tmp_path):
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.prom"
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--waves", "4", "--wave-size", "64", "--maintain",
         "--trace-out", str(trace), "--metrics-out", str(metrics)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr
    doc = json.load(open(trace))
    evs = doc["traceEvents"]
    assert evs, "trace is empty"
    for ev in evs:
        assert "ph" in ev and "ts" in ev and "name" in ev, ev
    names = {ev["name"] for ev in evs}
    assert "wave.dispatch" in names and "wave.reap" in names
    assert "engine.submit" in names and "request" in names
    assert "maintenance.run" in names
    # durations are µs floats; complete spans carry them
    assert all("dur" in ev for ev in evs if ev["ph"] == "X")
    text = open(metrics).read()
    assert "# TYPE hkv_engine_waves gauge" in text
    assert "hkv_maintenance_deferred" in text
    assert "hkv_hot_load_factor" in text and "hkv_cold_load_factor" in text
    assert "deferred=" in r.stdout       # the SLO summary satellite


# =============================================================================
# Unit coverage: tracer, sink, registry, EngineMetrics.zero
# =============================================================================


def test_tracer_spans_and_instants():
    tr = Tracer()
    with tr.span("outer", tag="a"):
        tr.instant("mark", n=1)
        with tr.span("inner"):
            pass
    tr.complete_abs("abs", tr._t0, tr._t0 + 0.5, rid=7)
    assert len(tr) == 4
    doc = tr.to_chrome()
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["s"] == "t"
    assert by_name["outer"]["ph"] == "X"
    assert by_name["outer"]["args"] == {"tag": "a"}
    assert abs(by_name["abs"]["dur"] - 5e5) < 1e3   # 0.5 s in µs
    # spans nest: inner lies within outer
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]


def test_noop_tracer_absorbs_everything():
    assert as_tracer(None) is NOOP_TRACER
    t = Tracer()
    assert as_tracer(t) is t
    assert not NOOP_TRACER and len(NOOP_TRACER) == 0
    with NOOP_TRACER.span("x"):
        NOOP_TRACER.instant("y")
    NOOP_TRACER.complete("z", 0.0, 1.0)
    NOOP_TRACER.complete_abs("z", 0.0, 1.0)
    assert NOOP_TRACER.to_chrome() == {"traceEvents": []}
    with pytest.raises(RuntimeError):
        NOOP_TRACER.save("/tmp/nope.json")


def test_op_telemetry_pytree_algebra():
    a = OpTelemetry.of(lanes=4, hits=3, probed_buckets=8)
    b = OpTelemetry.of(lanes=2, misses=2, probed_buckets=2)
    m = a.merge(b).to_dict()
    assert m["lanes"] == 6 and m["hits"] == 3 and m["probed_buckets"] == 10
    z = OpTelemetry.zero().to_dict()
    assert all(v == 0 for v in z.values())
    # rates guard against zero denominators
    r = OpTelemetry.zero().rates()
    assert r["probes_per_query"] == 0.0 and r["hit_rate"] == 0.0
    # the pytree flattens to jax-able leaves (jit/psum compatibility)
    leaves = jax.tree_util.tree_leaves(a)
    assert len(leaves) == len(OpTelemetry._fields)


def test_sink_accumulates_and_snapshots():
    sink = TelemetrySink()
    assert bool(sink)
    sink.record("find", OpTelemetry.of(lanes=4, hits=2))
    sink.record("find", OpTelemetry.of(lanes=4, hits=4))
    sink.record("erase", OpTelemetry.of(lanes=1, swept=1))
    assert sink.calls == {"find": 2, "erase": 1}
    snap = sink.snapshot()
    assert snap["find"]["hits"] == 6
    tot = sink.total().to_dict()
    assert tot["lanes"] == 9 and tot["swept"] == 1


def test_metrics_registry_prometheus_exposition():
    reg = MetricsRegistry()
    reg.set("hkv_demo_total", 3, help="a demo counter")
    reg.set("hkv_demo_rate", 0.25)
    reg.inc("hkv_demo_total", 2)
    text = reg.prometheus()
    assert "# HELP hkv_demo_total a demo counter" in text
    assert "# TYPE hkv_demo_total gauge" in text
    assert "\nhkv_demo_total 5\n" in text
    assert "hkv_demo_rate 0.25" in text
    assert text.endswith("\n")
    sink = TelemetrySink()
    sink.record("find", OpTelemetry.of(lanes=8, hits=6, probed_buckets=8))
    reg.observe_telemetry(sink)
    assert reg.get("hkv_op_find_hits") == 6.0
    assert reg.get("hkv_op_find_probes_per_query") == 1.0
    assert reg.get("hkv_op_find_calls") == 1.0
    j = json.loads(reg.to_json(run="t"))
    assert j["schema"] == "hkv-metrics/v1" and j["run"] == "t"
    assert j["gauges"]["hkv_op_find_hits"] == 6.0


def test_engine_metrics_zero_is_well_formed():
    z = EngineMetrics.zero()
    assert z.waves == 0 and z.requests == 0
    assert z.p99_latency_s == 0.0 and z.p99_total_s == 0.0
    assert len(z) == len(EngineMetrics._fields)
    # the engine returns it for empty runs
    from repro.serving.embedding_engine import OnlineEmbeddingEngine
    t = HKVTable.create(capacity=2 * 128, dim=4)
    eng = OnlineEmbeddingEngine(t, wave_size=8)
    assert eng.metrics() == z


def test_registry_observes_engine_scheduler_and_stats():
    from repro.maintenance.scheduler import MaintenanceTotals
    reg = MetricsRegistry()
    reg.observe_engine(EngineMetrics.zero())
    reg.observe_maintenance(MaintenanceTotals(
        runs=3, expired=1, demoted=2, dropped=0, skipped_offers=1,
        time_s=0.5, deferred=4))
    t = HKVTable.create(capacity=2 * 128, dim=4)
    reg.observe_table(t.stats(), tier="hot")
    assert reg.get("hkv_engine_waves") == 0.0
    assert reg.get("hkv_maintenance_deferred") == 4.0
    assert reg.get("hkv_hot_capacity") == 256.0
    assert reg.get("hkv_hot_load_factor") == 0.0
