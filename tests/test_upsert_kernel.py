"""Kernel/core parity for the fused Pallas upsert path (interpret mode).

The acceptance bar is BIT-IDENTITY: for randomized batches — duplicates,
EMPTY-sentinel padding, full buckets at λ=1.0, dual-bucket configs, every
score policy — `upsert_kernel` must produce exactly the statuses, evicted
pairs, and post-state (keys, digests, scores, values) of the pure-jnp
`core.merge.upsert`.  Both share the batch-closure orchestration
(`DESIGN.md §4`), so these tests pin down the kernel stage semantics:
the fused probe (match + occupancy/min + dual-bucket selection), the
rank-r victim claim, and the gather/scatter value kernels.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import merge, ops, table, u64
from repro.core.oracle import OracleTable
from repro.kernels import ops as kops

EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)


def _random_batch(rng, n, key_space, dup_frac=0.25, pad_frac=0.05):
    keys = rng.integers(0, key_space, size=n).astype(np.uint64)
    ndup = int(n * dup_frac)
    if ndup:
        keys[rng.integers(0, n, size=ndup)] = rng.choice(keys, size=ndup)
    npad = int(n * pad_frac)
    if npad:
        keys[rng.integers(0, n, size=npad)] = EMPTY
    return keys


def _assert_states_equal(a, b, ctx=""):
    for f in ("key_hi", "key_lo", "digests", "score_hi", "score_lo", "values",
              "clock_hi", "clock_lo", "epoch"):
        av, bv = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        np.testing.assert_array_equal(av, bv, err_msg=f"{ctx}: state.{f}")


@pytest.mark.parametrize("dual", [False, True])
@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_upsert_kernel_bit_identical_over_full_table(dual, policy):
    """3x capacity through the table: warm-up inserts, λ=1.0 evictions."""
    rng = np.random.default_rng(17 * (1 + dual) + len(policy))
    cfg = table.HKVConfig(
        capacity=4 * 128, dim=8, buckets_per_key=2 if dual else 1,
        score_policy=policy,
    )
    sj = table.create(cfg)
    sk = table.create(cfg)
    for step in range(8):
        keys = _random_batch(rng, 192, 2**50)
        k = u64.from_uint64(keys)
        vals = jnp.asarray(rng.normal(size=(192, 8)), jnp.float32)
        rj = merge.upsert(sj, cfg, k, vals)
        rk = kops.upsert_kernel(sk, cfg, k, vals, interpret=True)
        sj, sk = rj.state, rk.state
        np.testing.assert_array_equal(
            np.asarray(rj.status), np.asarray(rk.status),
            err_msg=f"step {step} status",
        )
        _assert_states_equal(sj, sk, f"step {step}")
    assert float(sj.load_factor()) == 1.0  # the eviction regime was exercised


def test_insert_and_evict_kernel_returns_identical_evictions():
    rng = np.random.default_rng(3)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4)
    sj = table.create(cfg)
    sk = table.create(cfg)
    # fill past capacity so evictions actually occur
    for step in range(4):
        keys = _random_batch(rng, 160, 2**40)
        k = u64.from_uint64(keys)
        vals = jnp.asarray(rng.normal(size=(160, 4)), jnp.float32)
        rj = ops.insert_and_evict(sj, cfg, k, vals, backend="jnp")
        # the public kernel wrapper, exercised directly
        rk = kops.insert_and_evict_kernel(sk, cfg, k, vals, interpret=True)
        sj, sk = rj.state, rk.state
        np.testing.assert_array_equal(
            np.asarray(rj.status), np.asarray(rk.status),
            err_msg=f"step {step}: status",
        )
        for f in ("key_hi", "key_lo", "values", "score_hi", "score_lo", "mask"):
            np.testing.assert_array_equal(
                np.asarray(getattr(rj.evicted, f)),
                np.asarray(getattr(rk.evicted, f)),
                err_msg=f"step {step}: evicted.{f}",
            )
        _assert_states_equal(sj, sk, f"step {step}")
    assert int(rj.evicted.count()) > 0


def test_find_or_insert_kernel_matches_core():
    rng = np.random.default_rng(5)
    for dual in (False, True):
        cfg = table.HKVConfig(
            capacity=4 * 128, dim=8, buckets_per_key=2 if dual else 1
        )
        sj = table.create(cfg)
        sk = table.create(cfg)
        for step in range(6):
            keys = _random_batch(rng, 128, 2**18)  # small space -> many hits
            k = u64.from_uint64(keys)
            init = jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)
            rj = ops.find_or_insert(sj, cfg, k, init, backend="jnp")
            rk = ops.find_or_insert(sk, cfg, k, init, backend="kernel")
            sj, sk = rj.state, rk.state
            np.testing.assert_array_equal(np.asarray(rj.found), np.asarray(rk.found))
            np.testing.assert_array_equal(np.asarray(rj.status), np.asarray(rk.status))
            np.testing.assert_array_equal(np.asarray(rj.values), np.asarray(rk.values))
            _assert_states_equal(sj, sk, f"dual={dual} step {step}")


class TestFindOrInsertSinglePass:
    """The perf fix: find_or_insert used to run three probe passes
    (pre-locate, the upsert's internal locate, post-locate).  The closure
    now publishes post-op locations (`MergeResult.loc`), so find_or_insert
    issues NO probe beyond the upsert's own — pinned here, with bit-parity
    against an explicit old-style three-pass reference."""

    def _old_style(self, state, cfg, k, init):
        """The pre-fix sequence, spelled out: pre-locate + upsert +
        post-locate + gather (the parity reference)."""
        from repro.core import find as find_mod

        pre = find_mod.locate(state, cfg, k)
        res = merge.upsert(state, cfg, k, init, write_hit_values=False)
        post = find_mod.locate(res.state, cfg, k)
        vals = find_mod.gather_values(res.state, post, cfg.dim, cfg.value_tier)
        vals = jnp.where(post.found[:, None], vals, init[:, : cfg.dim])
        return res.state, vals, pre.found, res.status

    @pytest.mark.parametrize("policy", ["lru", "lfu"])
    @pytest.mark.parametrize("dual", [False, True])
    def test_parity_with_three_pass_reference(self, dual, policy):
        rng = np.random.default_rng(29 + dual)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4,
                              buckets_per_key=2 if dual else 1,
                              score_policy=policy)
        s_new = table.create(cfg)
        s_old = table.create(cfg)
        for step in range(6):  # drives past capacity: hits, evicts, rejects
            keys = _random_batch(rng, 160, 2**16)
            k = u64.from_uint64(keys)
            init = jnp.asarray(rng.normal(size=(160, 4)), jnp.float32)
            rn = ops.find_or_insert(s_new, cfg, k, init, backend="jnp")
            so, vo, fo, sto = self._old_style(s_old, cfg, k, init)
            s_new, s_old = rn.state, so
            np.testing.assert_array_equal(np.asarray(rn.found), np.asarray(fo))
            np.testing.assert_array_equal(np.asarray(rn.status), np.asarray(sto))
            np.testing.assert_array_equal(np.asarray(rn.values), np.asarray(vo))
            _assert_states_equal(s_new, s_old,
                                 f"dual={dual} {policy} step {step}")

    @pytest.mark.parametrize("backend", ["jnp", "kernel"])
    def test_hit_evicted_within_same_batch_reports_gone(self, backend):
        """The published post-op location must not be stale: under LFU a
        batch can HIT key A (count -> 2) and in the same launch admit a
        higher-count miss B that evicts A's slot.  find_or_insert must
        then return A's ephemeral init row (as the old re-probe did), and
        B's value must never leak into A's lane."""
        cfg = table.HKVConfig(capacity=128, dim=2, score_policy="lfu")
        state = table.create(cfg)
        a = np.array([1], np.uint64)
        others = np.arange(2, 129, dtype=np.uint64)    # fills the bucket
        state = ops.insert_or_assign(
            state, cfg, u64.from_uint64(a), jnp.full((1, 2), 50.0)).state
        for _ in range(3):                             # others: count 3
            state = ops.insert_or_assign(
                state, cfg, u64.from_uint64(others),
                jnp.zeros((127, 2))).state
        # batch: A (hit, count 1 -> 2) + B x3 (miss, init count 3 beats 2)
        batch = np.array([1, 999, 999, 999], np.uint64)
        init = jnp.asarray([[-1.0, -1.0], [7.0, 7.0], [7.0, 7.0], [7.0, 7.0]],
                           jnp.float32)
        res = ops.find_or_insert(state, cfg, u64.from_uint64(batch), init,
                                 backend=backend)
        status = np.asarray(res.status)
        assert status[0] == 1 and (status[1:] == 3).all()  # A updated, B evicts
        vals = np.asarray(res.values)
        np.testing.assert_array_equal(vals[0], [-1.0, -1.0])  # A: init, not B
        np.testing.assert_array_equal(vals[1], [7.0, 7.0])
        # A really is gone from the table
        gone = ops.contains(res.state, cfg, u64.from_uint64(a))
        assert not bool(np.asarray(gone)[0])

    def test_jnp_path_issues_exactly_one_locate(self, monkeypatch):
        from repro.core import find as find_mod

        calls = {"n": 0}
        real = find_mod.locate

        def counting(*a, **kw):
            calls["n"] += 1
            return real(*a, **kw)

        monkeypatch.setattr(find_mod, "locate", counting)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4)
        state = table.create(cfg)
        k = u64.from_uint64(np.arange(1, 65, dtype=np.uint64))
        ops.find_or_insert(state, cfg, k, jnp.zeros((64, 4)), backend="jnp")
        assert calls["n"] == 1  # the closure's own locate stage, nothing else

    @pytest.mark.parametrize("dual", [False, True])
    def test_kernel_path_probe_pass_budget(self, dual, monkeypatch):
        """At most the closure's own passes: single-bucket = 1 locate_kernel,
        dual = 2 fused upsert_probe passes (locate + target select).  The
        pre-fix wrapper added 2 more locate passes on top."""
        from repro.kernels import upsert_scan as _us

        counts = {"locate": 0, "probe": 0}
        real_lk, real_up = kops.locate_kernel, _us.upsert_probe

        def clk(*a, **kw):
            counts["locate"] += 1
            return real_lk(*a, **kw)

        def cup(*a, **kw):
            counts["probe"] += 1
            return real_up(*a, **kw)

        monkeypatch.setattr(kops, "locate_kernel", clk)
        monkeypatch.setattr(_us, "upsert_probe", cup)
        cfg = table.HKVConfig(capacity=2 * 128, dim=4,
                              buckets_per_key=2 if dual else 1)
        state = table.create(cfg)
        k = u64.from_uint64(np.arange(1, 65, dtype=np.uint64))
        kops.find_or_insert_kernel(state, cfg, k, jnp.zeros((64, 4)),
                                   interpret=True)
        if dual:
            assert (counts["locate"], counts["probe"]) == (0, 2)
        else:
            assert (counts["locate"], counts["probe"]) == (1, 0)


def test_custom_scores_admission_parity():
    """Admission control (Table 9): a low-score burst must be rejected
    identically by both backends; a high-score burst displaces residents."""
    rng = np.random.default_rng(11)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, score_policy="custom")
    mk_sc = lambda v, n: u64.from_uint64(np.full(n, v, np.uint64))
    sj = table.create(cfg)
    sk = table.create(cfg)
    resident = rng.integers(0, 2**40, size=3 * cfg.capacity).astype(np.uint64)
    for i in range(0, len(resident), 256):
        kb = resident[i : i + 256]
        k = u64.from_uint64(kb)
        v = jnp.zeros((len(kb), 4), jnp.float32)
        sj = ops.insert_or_assign(sj, cfg, k, v, mk_sc(1000, len(kb)), backend="jnp").state
        sk = ops.insert_or_assign(sk, cfg, k, v, mk_sc(1000, len(kb)), backend="kernel").state
    _assert_states_equal(sj, sk, "resident fill")
    burst = u64.from_uint64(rng.integers(2**41, 2**42, size=128).astype(np.uint64))
    zeros = jnp.zeros((128, 4), jnp.float32)
    for score, expect_any_admit in ((1, False), (10**9, True)):
        rj = ops.insert_or_assign(sj, cfg, burst, zeros, mk_sc(score, 128), backend="jnp")
        rk = ops.insert_or_assign(sk, cfg, burst, zeros, mk_sc(score, 128), backend="kernel")
        np.testing.assert_array_equal(np.asarray(rj.status), np.asarray(rk.status))
        _assert_states_equal(rj.state, rk.state, f"burst score={score}")
        admitted = np.isin(np.asarray(rk.status), (2, 3)).any()
        assert bool(admitted) == expect_any_admit


def test_kernel_path_matches_sequential_oracle():
    """End-to-end sanity against the per-key sequential oracle (contents)."""
    rng = np.random.default_rng(23)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4, buckets_per_key=2)
    state = table.create(cfg)
    orc = OracleTable(cfg.capacity, 4, buckets_per_key=2)
    for _ in range(5):
        keys = rng.integers(0, 2**30, size=160).astype(np.uint64)
        vals = rng.normal(size=(160, 4)).astype(np.float32)
        res = ops.insert_or_assign(
            state, cfg, u64.from_uint64(keys), jnp.asarray(vals), backend="kernel"
        )
        state = res.state
        want = np.asarray(orc.insert_or_assign(keys, vals), np.int8)
        np.testing.assert_array_equal(np.asarray(res.status), want)
    exp = ops.export_batch(state, cfg, 0, cfg.num_buckets)
    mask = np.asarray(exp.mask)
    got_keys = set(
        ((np.asarray(exp.key_hi, np.uint64) << np.uint64(32))
         | np.asarray(exp.key_lo, np.uint64))[mask].tolist()
    )
    want_keys = {k for k, _ in orc.items()}
    assert got_keys == want_keys


def test_backend_auto_and_validation():
    cfg = table.HKVConfig(capacity=128, dim=2)
    state = table.create(cfg)
    k = u64.from_uint64(np.arange(4, dtype=np.uint64))
    v = jnp.zeros((4, 2), jnp.float32)
    r = ops.insert_or_assign(state, cfg, k, v, backend="auto")  # -> jnp off-TPU
    assert np.isin(np.asarray(r.status), (2, 3)).all()
    with pytest.raises(ValueError, match="backend"):
        ops.insert_or_assign(state, cfg, k, v, backend="cuda")


def test_victim_order_is_deterministic_on_empty_slots():
    """Empties claim ascending slot order — both backends, bit-identical
    digests plane included (the structural scatter writes the same slots)."""
    cfg = table.HKVConfig(capacity=128, dim=2)  # one bucket: forced collisions
    keys = u64.from_uint64(np.arange(1, 9, dtype=np.uint64))
    vals = jnp.ones((8, 2), jnp.float32)
    sj = merge.upsert(table.create(cfg), cfg, keys, vals).state
    sk = kops.upsert_kernel(table.create(cfg), cfg, keys, vals, interpret=True).state
    _assert_states_equal(sj, sk)
    occ = np.asarray(sj.occupied_mask())[0]
    assert occ[:8].all() and not occ[8:].any()  # lowest slots first
