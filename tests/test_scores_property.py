"""Property tests for ScorePolicy transitions (paper §3.3, Table 8).

The epoch_lfu contract under test (hypothesis-randomized):

  * update_score RESETS the frequency counter to the batch multiplicity
    EXACTLY when the application epoch differs from the entry's stored
    epoch (hi plane) — and only then;
  * within an unchanged epoch the counter accumulates, so the uint64
    total order (epoch << 32 | count) is preserved: scores never move
    backwards, and two entries touched in the same epoch order by
    accumulated frequency.
"""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import u64  # noqa: E402
from repro.core.scores import get_policy  # noqa: E402
from repro.core.u64 import U64  # noqa: E402

U32 = st.integers(0, 2**32 - 1)
COUNT = st.integers(1, 2**10)
POLICY = get_policy("epoch_lfu")


def _update(old_hi, old_lo, epoch, count):
    clock = U64(jnp.uint32(0), jnp.uint32(0))  # epoch_lfu ignores the clock
    new = POLICY.update_score(
        U64(jnp.asarray([old_hi], jnp.uint32), jnp.asarray([old_lo], jnp.uint32)),
        clock,
        jnp.uint32(epoch),
        jnp.asarray([count], jnp.uint32),
        None,
    )
    return int(np.asarray(new.hi)[0]), int(np.asarray(new.lo)[0])


class TestEpochLfuRollover:
    @settings(max_examples=60, deadline=None)
    @given(old_epoch=U32, old_count=U32, epoch=U32, count=COUNT)
    def test_reset_exactly_when_epoch_changes(self, old_epoch, old_count,
                                              epoch, count):
        hi, lo = _update(old_epoch, old_count, epoch, count)
        assert hi == epoch                       # the new epoch is stamped
        if epoch != old_epoch:
            assert lo == count                   # rollover: counter RESET
        else:
            assert lo == (old_count + count) % 2**32  # accumulate (mod u32)

    @settings(max_examples=60, deadline=None)
    @given(epoch=U32, old_count=st.integers(0, 2**31), count=COUNT)
    def test_same_epoch_update_is_monotone_u64(self, epoch, old_count, count):
        """Within one epoch (no rollover, no u32 counter overflow) a touch
        can only RAISE the score — eviction priority never regresses."""
        hypothesis.assume(old_count + count < 2**32)
        hi, lo = _update(epoch, old_count, epoch, count)
        old_u = (epoch << 32) | old_count
        new_u = (hi << 32) | lo
        assert new_u > old_u

    @settings(max_examples=60, deadline=None)
    @given(epoch=U32, ca=st.integers(0, 2**31), cb=st.integers(0, 2**31),
           count=COUNT)
    def test_total_order_by_frequency_within_epoch(self, epoch, ca, cb, count):
        """Two entries in the same epoch: updating both by the same batch
        multiplicity preserves their relative u64 order (the bucket-min
        eviction scan sees a stable ranking)."""
        hypothesis.assume(ca + count < 2**32 and cb + count < 2**32)
        ha, la = _update(epoch, ca, epoch, count)
        hb, lb = _update(epoch, cb, epoch, count)
        before = np.sign(ca - cb)
        after = np.sign(((ha << 32) | la) - ((hb << 32) | lb))
        assert before == after

    @settings(max_examples=40, deadline=None)
    @given(old_epoch=U32, old_count=U32, epoch=U32, count=COUNT)
    def test_matches_u64_plane_semantics(self, old_epoch, old_count, epoch,
                                         count):
        """The (hi, lo) planes ARE the uint64: reconstructing through the
        u64 helpers gives the same number the planes encode."""
        hi, lo = _update(old_epoch, old_count, epoch, count)
        packed = int(np.asarray(u64.to_uint64(
            U64(jnp.asarray([hi], jnp.uint32), jnp.asarray([lo], jnp.uint32))
        ))[0])
        assert packed == (hi << 32) | lo

    def test_init_score_counts_batch_multiplicity(self):
        sc = POLICY.init_score(
            U64(jnp.uint32(0), jnp.uint32(0)), jnp.uint32(5),
            jnp.asarray([3], jnp.uint32), None, (1,),
        )
        assert int(np.asarray(sc.hi)[0]) == 5
        assert int(np.asarray(sc.lo)[0]) == 3
