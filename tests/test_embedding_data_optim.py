"""Embedding backends, sparse optimizers, data pipeline, dense optimizers."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import DataCursor, HostPrefetcher, TokenStream, zipf_keys, zipf_ranks
from repro.embedding import DenseEmbedding, HKVEmbedding
from repro.embedding.sparse_opt import SparseOptimizer
from repro.optim import adamw, adamw8bit, adafactor, sgdm
from repro.optim.optimizers import apply_updates


class TestHKVEmbedding:
    def _emb(self, **kw):
        kw.setdefault("capacity", 8 * 128)
        kw.setdefault("dim", 16)
        return HKVEmbedding(**kw)

    def test_lookup_train_then_serve_roundtrip(self):
        emb = self._emb()
        state = emb.create()
        toks = jnp.asarray(np.arange(32).reshape(4, 8), jnp.int32)
        state, rows = emb.lookup_train(state, toks)
        assert rows.shape == (4, 8, 16)
        served = emb.lookup_serve(state, toks)
        np.testing.assert_allclose(np.asarray(served), np.asarray(rows), rtol=1e-6)

    def test_init_rows_deterministic_and_serve_fallback(self):
        emb = self._emb()
        state = emb.create()
        toks = jnp.asarray([[5, 6, 7]], jnp.int32)
        cold = emb.lookup_serve(state, toks)  # nothing inserted yet
        state, warm = emb.lookup_train(state, toks)
        np.testing.assert_allclose(np.asarray(cold), np.asarray(warm), rtol=1e-6)

    def test_gradient_step_reduces_loss(self):
        emb = self._emb(optimizer=SparseOptimizer("rowwise_adagrad", lr=0.5))
        state = emb.create()
        toks = jnp.asarray([[1, 2, 3, 1]], jnp.int32)  # duplicate token 1
        state, rows = emb.lookup_train(state, toks)
        target = jnp.ones_like(rows)

        def loss_fn(r):
            return jnp.mean((r - target) ** 2)

        l0 = loss_fn(rows)
        g = jax.grad(loss_fn)(rows)
        state = emb.apply_grads(state, toks, g)
        rows2 = emb.lookup_serve(state, toks)
        assert float(loss_fn(rows2)) < float(l0)
        # duplicate-token gradient accumulated once per unique key:
        r2 = np.asarray(rows2)
        np.testing.assert_allclose(r2[0, 0], r2[0, 3], rtol=1e-6)

    def test_padding_tokens_ignored(self):
        emb = self._emb()
        table = emb.create()
        toks = jnp.asarray([[3, -1, 4]], jnp.int32)
        table, rows = emb.lookup_train(table, toks)
        assert int(table.size()) == 2

    def test_continuous_ingestion_stays_full(self):
        emb = self._emb(capacity=2 * 128, dim=4)
        table = emb.create()
        for step in range(8):
            toks = jnp.asarray(
                np.random.default_rng(step).integers(0, 10**9, size=(1, 128)), jnp.int32
            )
            table, _ = emb.lookup_train(table, toks)
        assert float(table.load_factor()) == 1.0
        # next batch still resolves in place
        table, rows = emb.lookup_train(table, toks + 1)
        assert np.isfinite(np.asarray(rows)).all()


class TestSparseOptimizers:
    @pytest.mark.parametrize("name", ["sgd", "sgdm", "rowwise_adagrad", "adagrad"])
    def test_descends(self, name):
        opt = SparseOptimizer(name, lr=0.1)
        dim = 8
        rows = jnp.concatenate(
            [jnp.ones((4, dim)), jnp.zeros((4, opt.aux_dim(dim)))], axis=1
        )
        g = jnp.ones((4, dim))
        new = opt.apply(rows, g, dim)
        assert new.shape == (4, dim + opt.aux_dim(dim))
        assert float(new[:, :dim].mean()) < 1.0  # moved against the gradient


class TestData:
    def test_zipf_skew(self):
        rng = np.random.default_rng(0)
        r = zipf_ranks(rng, 200_000, 0.99, 1_000_000)
        top1 = np.mean(r == 0)
        # continuous-CDF approximation of discrete Zipf: top-rank mass for
        # alpha≈1, K=1e6 lands near 0.05 (discrete: ~0.07) — close enough
        # for the Table 8 sensitivity sweep
        assert 0.03 < top1 < 0.2
        assert np.mean(r < 100) > 0.3

    def test_zipf_keys_scattered(self):
        rng = np.random.default_rng(0)
        k = zipf_keys(rng, 10_000, 1.0, 10**6)
        assert len(np.unique(k >> np.uint64(56))) > 200  # high bits well spread

    def test_token_stream_deterministic_and_sharded(self):
        s0 = TokenStream(seed=7, batch=4, seq=16, vocab=1000, rank=0, world=2)
        s1 = TokenStream(seed=7, batch=4, seq=16, vocab=1000, rank=1, world=2)
        a0, l0 = s0.batch_at(3)
        b0, _ = s0.batch_at(3)
        np.testing.assert_array_equal(a0, b0)  # deterministic
        a1, _ = s1.batch_at(3)
        assert not np.array_equal(a0, a1)      # ranks differ
        np.testing.assert_array_equal(l0[:, :-1], a0[:, 1:])  # shifted labels

    def test_prefetcher_resumes_from_cursor(self):
        seen = []
        fn = lambda step: step * 10
        pf = HostPrefetcher(fn, DataCursor(seed=0, step=5), depth=2)
        for _ in range(3):
            seen.append(next(pf))
        pf.close()
        assert seen == [50, 60, 70]
        assert pf.cursor.step == 8


class TestDenseOptimizers:
    @pytest.mark.parametrize("mk", [adamw, adamw8bit, adafactor, sgdm])
    def test_quadratic_descent(self, mk):
        opt = mk()
        params = {"w": jnp.ones((64, 32)), "b": jnp.ones((32,))}
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

        l0 = float(loss(params))
        for _ in range(10):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(loss(params)) < l0

    def test_adamw8bit_moment_memory(self):
        opt = adamw8bit()
        params = {"w": jnp.ones((1024, 256))}
        state = opt.init(params)
        q = state["mu"]["w"]["q"]
        assert q.dtype == jnp.int8
        assert q.size == 1024 * 256  # int8 vs f32: 4x moment memory saving


def test_dense_embedding():
    emb = DenseEmbedding(vocab=100, dim=8)
    params = emb.init(jax.random.PRNGKey(0))
    toks = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    out = emb.lookup(params, toks)
    assert out.shape == (2, 2, 8)
    logits = emb.attend(params, out)
    assert logits.shape == (2, 2, 100)
