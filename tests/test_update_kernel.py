"""Kernel/core parity for the FUSED Pallas updater path (interpret mode).

Same acceptance bar as test_find_kernel.py: BIT-IDENTITY.  The fused
updater kernel (`kernels/update_scan.py`) resolves digest pre-filter +
full-key confirm + dual-bucket merge + in-kernel sparse-optimizer apply +
masked row write-back in ONE launch; it must produce exactly the
(found, values-plane) of

  * the jnp oracle (`kernels.ref.update_scan_ref`),
  * the core jnp reference (`core.ops.update_rows(backend='jnp')` =
    locate + gather + `SparseOptimizer.apply` + assign), and
  * the pre-fusion kernel composition it replaced (digest_scan locate x
    buckets_per_key + gather_rows + host apply + scatter_rows — kept as
    `kernels.ops.update_composed_kernel`),

for ALL FOUR optimizer variants (sgd/sgdm/rowwise_adagrad/adagrad), both
kernel variants (tlp/pipeline), miss lanes under full-table rejection
(cache semantics: un-admitted keys never write), EMPTY padding, odd-n
padding seams, and under jit/vmap.  The launch-count tests pin the PR's
acceptance criterion: the whole gradient step — including through
`OpSession.commit` and `HKVEmbedding.apply_grads` — is ONE kernel launch
(was >= 3 composed).

Bit-identity across eager/jit/batch/row contexts leans on the
``_rounded`` FMA pin in `embedding.sparse_opt` — see that module.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import find as find_mod
from repro.core import merge, ops, table, u64
from repro.core.api import HKVTable
from repro.embedding.dynamic import HKVEmbedding
from repro.embedding.sparse_opt import SparseOptimizer
from repro.kernels import digest_scan as _ds
from repro.kernels import gather as _ga
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels import scatter as _sc
from repro.kernels import update_scan as _upd

EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)

VARIANTS = ("tlp", "pipeline")
OPTIMIZERS = ("sgd", "sgdm", "rowwise_adagrad", "adagrad")


def _opt_cfg(opt_name, *, dual=True, dim=8, capacity=2 * 128, **kw):
    opt = SparseOptimizer(opt_name, lr=0.05)
    cfg = table.HKVConfig(capacity=capacity, dim=dim,
                          buckets_per_key=2 if dual else 1,
                          aux_value_dim=opt.aux_dim(dim), **kw)
    return opt, cfg


def _filled_table(rng, cfg, n_fill):
    """A table with live/empty mix, wide keys, and NON-ZERO aux columns
    (abs-normal, so adagrad accumulators stay in sqrt's domain)."""
    keys = rng.integers(1, 2**50, size=n_fill).astype(np.uint64)
    v = cfg.dim + cfg.aux_value_dim
    vals = jnp.asarray(np.abs(rng.normal(size=(n_fill, v))), jnp.float32)
    state = merge.upsert(table.create(cfg), cfg, u64.from_uint64(keys),
                         vals).state
    return state, keys


def _unique_query(rng, resident, n_hit, n_miss, n_pad):
    """UNIQUE hits + unique wide-key misses + EMPTY padding lanes — the
    updater's precondition (callers dedupe) with the full lane matrix."""
    hits = rng.choice(np.unique(resident), size=n_hit, replace=False)
    misses = np.unique(
        rng.integers(2**50, 2**60, size=4 * n_miss + 4).astype(np.uint64)
    )[:n_miss]
    pads = np.full(n_pad, EMPTY, np.uint64)
    q = np.concatenate([hits, misses, pads])
    rng.shuffle(q)
    return q


def _grads(rng, n, dim):
    return jnp.asarray(rng.normal(size=(n, dim)), jnp.float32)


def _ref_update(state, cfg, k, grads, opt):
    """The jnp oracle assembled exactly as update_rows_kernel feeds it."""
    probe = find_mod.probe_keys(cfg, k)
    b2 = probe.bucket2 if cfg.buckets_per_key == 2 else probe.bucket1
    return ref.update_scan_ref(
        state.digests, state.key_hi, state.key_lo, state.values,
        probe.bucket1, b2, probe.digest.astype(jnp.uint32), k.hi, k.lo,
        probe.valid.astype(jnp.int32), grads, opt, cfg.dim,
        use_digest=cfg.use_digest)


def _assert_update_equal(res, want_found, want_values, ctx=""):
    np.testing.assert_array_equal(
        np.asarray(res.found), np.asarray(want_found).astype(bool),
        err_msg=f"{ctx}: found")
    np.testing.assert_array_equal(
        np.asarray(res.state.values), np.asarray(want_values),
        err_msg=f"{ctx}: values")


# =============================================================================
# Raw kernel vs the pure-jnp oracle (ref.update_scan_ref)
# =============================================================================


@pytest.mark.parametrize("dual", [False, True])
@pytest.mark.parametrize("variant", VARIANTS)
def test_update_scan_matches_ref(variant, dual):
    """The kernel in isolation, exact-tile batch (no padding seam)."""
    rng = np.random.default_rng(7 + dual)
    opt, cfg = _opt_cfg("rowwise_adagrad", dual=dual, capacity=4 * 128)
    state, resident = _filled_table(rng, cfg, 400)
    q = _unique_query(rng, resident, 96, 24, 8)
    k = u64.from_uint64(q)
    probe = find_mod.probe_keys(cfg, k)
    b2 = probe.bucket2 if dual else probe.bucket1
    grads = _grads(rng, len(q), cfg.dim)
    args = (state.digests, state.key_hi, state.key_lo, state.values,
            probe.bucket1, b2, probe.digest.astype(jnp.uint32), k.hi, k.lo,
            probe.valid.astype(jnp.int32), grads)
    want_found, want_values = ref.update_scan_ref(*args, opt=opt, dim=cfg.dim)
    if variant == "tlp":
        got_found, got_values = _upd.update_scan_tlp(
            *args, opt=opt, dim=cfg.dim, interpret=True)
    else:
        got_found, got_values = _upd.update_scan_pipeline(
            *args, q_tile=128, opt=opt, dim=cfg.dim, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_found),
                                  np.asarray(want_found),
                                  err_msg=f"{variant} dual={dual} found")
    np.testing.assert_array_equal(np.asarray(got_values),
                                  np.asarray(want_values),
                                  err_msg=f"{variant} dual={dual} values")


@pytest.mark.parametrize("variant", VARIANTS)
def test_update_scan_use_digest_false_matches_ref(variant):
    """The Exp#3a ablation arm: key-only confirm, no digest pre-filter."""
    rng = np.random.default_rng(13)
    opt, cfg = _opt_cfg("sgd", dual=False, dim=4, use_digest=False)
    state, resident = _filled_table(rng, cfg, 200)
    q = _unique_query(rng, resident, 100, 20, 8)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    want_found, want_values = _ref_update(state, cfg, k, grads, opt)
    res = kops.update_rows_kernel(state, cfg, k, grads, opt, variant=variant,
                                  interpret=True)
    _assert_update_equal(res, want_found, want_values,
                         f"{variant} use_digest=False")


# =============================================================================
# Wrapper: all four optimizers, bit-identical to the jnp reference
# =============================================================================


@pytest.mark.parametrize("opt_name", OPTIMIZERS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_all_optimizers_bit_identical(variant, opt_name):
    """The acceptance criterion: update_rows(backend='kernel') ==
    update_rows(backend='jnp') == update_scan_ref, bit for bit, for every
    optimizer variant."""
    rng = np.random.default_rng(hash(opt_name) % 1000)
    opt, cfg = _opt_cfg(opt_name)
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 48, 12, 4)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    want_found, want_values = _ref_update(state, cfg, k, grads, opt)
    res = kops.update_rows_kernel(state, cfg, k, grads, opt, variant=variant,
                                  interpret=True)
    _assert_update_equal(res, want_found, want_values,
                         f"{variant} {opt_name} vs ref")
    core = ops.update_rows(state, cfg, k, grads, opt, backend="jnp")
    _assert_update_equal(res, core.found, core.state.values,
                         f"{variant} {opt_name} vs core jnp")


@pytest.mark.parametrize("variant", VARIANTS)
def test_odd_n_padding_seams(variant):
    """Pipeline tile remainder + tlp singleton grids: every odd batch size
    agrees with the jnp reference (EMPTY padding never writes)."""
    rng = np.random.default_rng(31)
    opt, cfg = _opt_cfg("rowwise_adagrad", capacity=4 * 128)
    state, resident = _filled_table(rng, cfg, 400)
    for n in (1, 37, 128, 193):
        q = _unique_query(rng, resident, max(1, n - n // 4 - n // 8),
                          n // 4, n // 8)[:n]
        k = u64.from_uint64(q)
        grads = _grads(rng, n, cfg.dim)
        want_found, want_values = _ref_update(state, cfg, k, grads, opt)
        res = kops.update_rows_kernel(state, cfg, k, grads, opt,
                                      variant=variant, interpret=True)
        _assert_update_equal(res, want_found, want_values,
                             f"{variant} n={n}")


@pytest.mark.parametrize("variant", VARIANTS)
def test_fused_matches_composed(variant):
    """The replaced composition (locate + gather_rows + host apply +
    scatter_rows) and the fused pass agree bit-for-bit — the regression
    seam of this PR."""
    rng = np.random.default_rng(41)
    opt, cfg = _opt_cfg("adagrad", dim=16)
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 60, 20, 8)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    fused = kops.update_rows_kernel(state, cfg, k, grads, opt,
                                    variant=variant, interpret=True)
    composed = kops.update_composed_kernel(state, cfg, k, grads, opt,
                                           variant=variant, interpret=True)
    _assert_update_equal(fused, composed.found, composed.state.values,
                         f"{variant} fused vs composed")


@pytest.mark.parametrize("variant", VARIANTS)
def test_miss_lanes_never_write_under_full_rejection(variant):
    """Cache semantics: a batch of entirely non-resident keys (plus EMPTY
    padding) must leave the value plane BITWISE untouched — rejected
    embeddings do not train."""
    rng = np.random.default_rng(53)
    opt, cfg = _opt_cfg("sgdm")
    state, _resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, np.asarray([1], np.uint64), 0, 48, 16)[1:]
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim) * 1e6  # any write would be visible
    res = kops.update_rows_kernel(state, cfg, k, grads, opt, variant=variant,
                                  interpret=True)
    assert not np.asarray(res.found).any()
    np.testing.assert_array_equal(np.asarray(res.state.values),
                                  np.asarray(state.values),
                                  err_msg=f"{variant}: miss lane wrote")


def test_secondary_bucket_residents_train():
    """Drive a dual table to λ=1.0 so some residents live in their
    SECONDARY bucket, then pin that the fused updater trains them."""
    rng = np.random.default_rng(5)
    opt, cfg = _opt_cfg("rowwise_adagrad", dim=4)
    state = table.create(cfg)
    resident = rng.integers(1, 2**50, size=600).astype(np.uint64)
    v = cfg.dim + cfg.aux_value_dim
    for chunk in np.split(resident, 12):
        vals = jnp.asarray(np.abs(rng.normal(size=(len(chunk), v))),
                           jnp.float32)
        state = merge.upsert(state, cfg, u64.from_uint64(chunk), vals).state
    assert float(state.load_factor()) == 1.0
    uq = np.unique(resident)[:128]
    k = u64.from_uint64(uq)
    loc = find_mod.locate(state, cfg, k)
    probe = find_mod.probe_keys(cfg, k)
    in_b2 = np.asarray(loc.found & (loc.bucket == probe.bucket2)
                       & (probe.bucket2 != probe.bucket1))
    assert in_b2.any(), "fill did not produce secondary-bucket residents"
    grads = _grads(rng, len(uq), cfg.dim)
    want_found, want_values = _ref_update(state, cfg, k, grads, opt)
    for variant in VARIANTS:
        res = kops.update_rows_kernel(state, cfg, k, grads, opt,
                                      variant=variant, interpret=True)
        _assert_update_equal(res, want_found, want_values,
                             f"{variant} secondary")
    # the secondary-bucket residents actually changed
    rows_b2 = np.asarray(loc.row)[in_b2]
    assert (np.asarray(want_values)[rows_b2]
            != np.asarray(state.values)[rows_b2]).any()


# =============================================================================
# Dispatch: ops layer, sessions, tiers, jit/vmap
# =============================================================================


def test_ops_updater_backend_parity():
    """ops.update_rows: kernel vs jnp, plus the shared-loc and
    update_scores composed paths, all bit-identical."""
    rng = np.random.default_rng(11)
    opt, cfg = _opt_cfg("rowwise_adagrad")
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 48, 12, 4)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    rj = ops.update_rows(state, cfg, k, grads, opt, backend="jnp")
    rk = ops.update_rows(state, cfg, k, grads, opt, backend="kernel")
    _assert_update_equal(rk, rj.found, rj.state.values, "backend parity")
    # session-shared loc: the composed path against a caller's locate
    loc = find_mod.locate(state, cfg, k)
    rl = ops.update_rows(state, cfg, k, grads, opt, loc=loc,
                         backend="kernel")
    _assert_update_equal(rl, rj.found, rj.state.values, "shared loc")
    # update_scores=True composes through assign's score touch: both
    # backends take the same composed path — value planes still agree
    rsj = ops.update_rows(state, cfg, k, grads, opt, update_scores=True,
                          backend="jnp")
    rsk = ops.update_rows(state, cfg, k, grads, opt, update_scores=True,
                          backend="kernel")
    _assert_update_equal(rsk, rsj.found, rsj.state.values, "update_scores")
    assert np.asarray(rsj.state.score_lo != state.score_lo).any()


def test_updater_backend_validation():
    opt, cfg = _opt_cfg("sgd", dim=4)
    state = table.create(cfg)
    k = u64.from_uint64(np.asarray([1], np.uint64))
    g = jnp.zeros((1, 4), jnp.float32)
    with pytest.raises(ValueError, match="backend"):
        ops.update_rows(state, cfg, k, g, opt, backend="cuda")
    with pytest.raises(ValueError, match="variant"):
        kops.update_rows_kernel(state, cfg, k, g, opt, variant="warp")


def test_hmem_tier_keeps_locate_plus_tier_split():
    """Host-tier value planes keep the §3.6 crossing contract: the kernel
    locates, rows cross via tier_gather/tier_scatter — results identical
    to the jnp path."""
    rng = np.random.default_rng(23)
    opt, cfg = _opt_cfg("rowwise_adagrad", value_tier="hmem")
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 40, 10, 4)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    rj = ops.update_rows(state, cfg, k, grads, opt, backend="jnp")
    rk = ops.update_rows(state, cfg, k, grads, opt, backend="kernel")
    _assert_update_equal(rk, rj.found, rj.state.values, "hmem")


def test_session_row_update_matches_callable_and_unfused():
    """The session surface: a structured RowUpdate commit must equal the
    legacy callable form AND the unfused ops sequence, on both backends."""
    rng = np.random.default_rng(29)
    opt, cfg = _opt_cfg("adagrad")
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 40, 10, 4)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    want = ops.update_rows(state, cfg, k, grads, opt, backend="jnp")
    for backend in ("jnp", "kernel"):
        t = HKVTable.wrap(state, cfg, backend=backend)
        s = t.session()
        r = s.update_rows(k, ops.RowUpdate(opt, grads))
        t2 = s.commit()
        np.testing.assert_array_equal(np.asarray(t2.state.values),
                                      np.asarray(want.state.values),
                                      err_msg=f"{backend} RowUpdate")
        got = r.get()
        np.testing.assert_array_equal(np.asarray(got.found),
                                      np.asarray(want.found))
        s2 = t.session()
        s2.update_rows(k, lambda rows: opt.apply(rows, grads, cfg.dim))
        t3 = s2.commit()
        np.testing.assert_array_equal(np.asarray(t3.state.values),
                                      np.asarray(want.state.values),
                                      err_msg=f"{backend} callable")


def test_session_shared_locate_still_exact():
    """A find before the RowUpdate on the same key batch caches a locate;
    the RowUpdate then takes the composed path against it — still
    bit-identical to the standalone op."""
    rng = np.random.default_rng(37)
    opt, cfg = _opt_cfg("sgd")
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 40, 10, 4)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    want = ops.update_rows(state, cfg, k, grads, opt, backend="jnp")
    t = HKVTable.wrap(state, cfg, backend="kernel")
    s = t.session()
    s.find(k)
    s.update_rows(k, ops.RowUpdate(opt, grads))
    t2 = s.commit()
    np.testing.assert_array_equal(np.asarray(t2.state.values),
                                  np.asarray(want.state.values))


def test_update_under_jit_and_vmap():
    rng = np.random.default_rng(19)
    opt, cfg = _opt_cfg("rowwise_adagrad", dim=4)
    state, resident = _filled_table(rng, cfg, 180)
    q = _unique_query(rng, resident, 40, 10, 4)
    k = u64.from_uint64(q)
    grads = _grads(rng, len(q), cfg.dim)
    want = ops.update_rows(state, cfg, k, grads, opt, backend="jnp")

    # jit: the kernel dispatch inside a traced region
    jup = jax.jit(lambda st, hi, lo, g: ops.update_rows(
        st, cfg, u64.U64(hi, lo), g, opt, backend="kernel"))
    got = jup(state, k.hi, k.lo, grads)
    _assert_update_equal(got, want.found, want.state.values, "jit")

    # vmap: two tables x two query sets mapped over a leading axis — each
    # mapped row must equal its solo run (Pallas adds a batch grid dim)
    state2, resident2 = _filled_table(rng, cfg, 160)
    q2 = _unique_query(rng, resident2, 40, 10, 4)
    k2 = u64.from_uint64(q2)
    grads2 = _grads(rng, len(q2), cfg.dim)

    def run(st, hi, lo, g):
        return ops.update_rows(st, cfg, u64.U64(hi, lo), g, opt,
                               backend="kernel")

    stacked_state = jax.tree.map(lambda a, b: jnp.stack([a, b]),
                                 state, state2)
    vout = jax.vmap(run)(stacked_state, jnp.stack([k.hi, k2.hi]),
                         jnp.stack([k.lo, k2.lo]),
                         jnp.stack([grads, grads2]))
    solo0 = run(state, k.hi, k.lo, grads)
    solo1 = run(state2, k2.hi, k2.lo, grads2)
    for i, solo in enumerate((solo0, solo1)):
        np.testing.assert_array_equal(np.asarray(vout.found[i]),
                                      np.asarray(solo.found),
                                      err_msg=f"vmap row{i} found")
        np.testing.assert_array_equal(np.asarray(vout.state.values[i]),
                                      np.asarray(solo.state.values),
                                      err_msg=f"vmap row{i} values")


# =============================================================================
# apply_grads: the fused front half (dedupe + segment-sum + ONE op)
# =============================================================================


def _manual_apply_grads(emb, table_h, tokens, grads):
    """Per-unique reference computed with numpy dedupe + the jnp op."""
    uniq, inv = np.unique(np.asarray(tokens), return_inverse=True)
    g_sum = np.zeros((len(uniq), emb.dim), np.float32)
    np.add.at(g_sum, inv, np.asarray(grads).reshape(-1, emb.dim))
    keys = emb.keys_of(jnp.asarray(uniq.astype(np.int32)))
    return ops.update_rows(table_h.state, table_h.cfg, keys,
                           jnp.asarray(g_sum), emb.optimizer, backend="jnp")


@pytest.mark.parametrize("backend", ["jnp", "kernel"])
def test_apply_grads_duplicate_heavy_regression(backend):
    """Satellite regression: duplicate-heavy batches must train each
    unique row ONCE with the segment-summed gradient (the compacted
    dedupe), on both backends, bit-identical to the per-unique jnp op."""
    emb = HKVEmbedding(capacity=256, dim=8,
                       optimizer=SparseOptimizer("rowwise_adagrad", lr=0.05),
                       backend=backend)
    t = emb.create()
    rng = np.random.default_rng(61)
    # 12 distinct tokens across 96 lanes: ~8x duplication
    tokens = jnp.asarray(rng.integers(0, 12, 96, dtype=np.int32))
    t, _rows = emb.lookup_train(t, tokens)
    grads = jnp.asarray(rng.normal(size=(96, 8)), jnp.float32)
    want = _manual_apply_grads(emb, t, tokens, grads)
    t2 = emb.apply_grads(t, tokens, grads)
    np.testing.assert_array_equal(np.asarray(t2.state.values),
                                  np.asarray(want.state.values),
                                  err_msg=f"{backend} duplicate-heavy")


def test_apply_grads_extreme_duplication_single_step():
    """All lanes one token: the row must move by exactly ONE optimizer
    step consuming the batch TOTAL (a double-apply would shrink the
    adagrad step visibly)."""
    opt = SparseOptimizer("sgd", lr=0.5)
    emb = HKVEmbedding(capacity=256, dim=4, optimizer=opt, backend="jnp")
    t = emb.create()
    tokens = jnp.full((64,), 7, jnp.int32)
    t, _ = emb.lookup_train(t, tokens)
    before = np.asarray(emb.lookup_serve(t, jnp.asarray([7]))).reshape(4)
    grads = jnp.ones((64, 4), jnp.float32)
    t2 = emb.apply_grads(t, tokens, grads)
    after = np.asarray(emb.lookup_serve(t2, jnp.asarray([7]))).reshape(4)
    np.testing.assert_allclose(after, before - 0.5 * 64.0, rtol=1e-5)


# =============================================================================
# Launch accounting: the whole gradient step is ONE launch
# =============================================================================


class TestLaunchBudget:
    def _counters(self, monkeypatch):
        counts = {"update_scan": 0, "digest_scan": 0, "gather": 0,
                  "scatter": 0}

        def wrap(mod, name, key):
            orig = getattr(mod, name)

            def counting(*a, **kw):
                counts[key] += 1
                return orig(*a, **kw)

            monkeypatch.setattr(mod, name, counting)

        wrap(_upd, "update_scan_tlp", "update_scan")
        wrap(_upd, "update_scan_pipeline", "update_scan")
        wrap(_ds, "digest_scan_tlp", "digest_scan")
        wrap(_ds, "digest_scan_pipeline", "digest_scan")
        wrap(_ga, "gather_rows", "gather")
        wrap(_sc, "scatter_rows", "scatter")
        return counts

    @pytest.mark.parametrize("dual", [False, True])
    def test_fused_update_is_one_launch(self, dual, monkeypatch):
        """Old composition: buckets_per_key digest_scan launches + one
        gather + one scatter (>= 3).  Fused: ONE update_scan launch —
        >= 2 eliminated per gradient step (3 in dual mode), the PR's
        acceptance criterion."""
        rng = np.random.default_rng(3)
        opt, cfg = _opt_cfg("rowwise_adagrad", dual=dual, dim=4)
        state, resident = _filled_table(rng, cfg, 150)
        k = u64.from_uint64(np.unique(resident)[:64])
        grads = _grads(rng, 64, cfg.dim)
        counts = self._counters(monkeypatch)
        ops.update_rows(state, cfg, k, grads, opt, backend="kernel")
        assert (counts["update_scan"], counts["digest_scan"],
                counts["gather"], counts["scatter"]) == (1, 0, 0, 0)
        counts.update(update_scan=0)
        kops.update_composed_kernel(state, cfg, k, grads, opt,
                                    interpret=True)
        old = counts["digest_scan"] + counts["gather"] + counts["scatter"]
        assert counts["digest_scan"] == (2 if dual else 1)
        assert counts["gather"] == 1
        assert counts["scatter"] == 1
        assert old >= 3 and old - 1 >= 2  # launches eliminated per step

    def test_session_row_update_is_one_launch(self, monkeypatch):
        """OpSession.commit must NOT pre-locate a structured RowUpdate —
        the whole committed gradient step is one update_scan launch."""
        rng = np.random.default_rng(4)
        opt, cfg = _opt_cfg("sgd", dim=4)
        state, resident = _filled_table(rng, cfg, 150)
        k = u64.from_uint64(np.unique(resident)[:32])
        grads = _grads(rng, 32, cfg.dim)
        counts = self._counters(monkeypatch)
        t = HKVTable.wrap(state, cfg, backend="kernel")
        s = t.session()
        s.update_rows(k, ops.RowUpdate(opt, grads))
        s.commit()
        assert counts == {"update_scan": 1, "digest_scan": 0, "gather": 0,
                          "scatter": 0}

    def test_apply_grads_is_one_launch(self, monkeypatch):
        """End to end: HKVEmbedding.apply_grads = dedupe (XLA) + ONE
        kernel launch (was 3+ and 2x row traffic)."""
        emb = HKVEmbedding(capacity=256, dim=4,
                           optimizer=SparseOptimizer("rowwise_adagrad"),
                           backend="kernel")
        t = emb.create()
        rng = np.random.default_rng(5)
        tokens = jnp.asarray(rng.integers(0, 40, 64, dtype=np.int32))
        t, _ = emb.lookup_train(t, tokens)
        counts = self._counters(monkeypatch)
        emb.apply_grads(t, tokens,
                        jnp.asarray(rng.normal(size=(64, 4)), jnp.float32))
        assert counts == {"update_scan": 1, "digest_scan": 0, "gather": 0,
                          "scatter": 0}
