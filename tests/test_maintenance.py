"""Maintenance subsystem (DESIGN.md §Maintenance): predicated sweeps,
TTL/epoch expiry, TableStats, watermark rebalancing, and the
wave-interleaved MaintenanceScheduler — semantics-level tests (the
kernel/jnp bit-parity of the sweep mask lives in test_sweep_kernel.py,
the cross-impl contract in test_kvtable_conformance.py)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (HKVTable, SweepPredicate, TieredHKVTable, U64)
from repro.data import zipf_keys
from repro.maintenance import (MaintenancePolicy, MaintenanceScheduler,
                               rebalance)
from repro.serving import EmbeddingRequest, OnlineEmbeddingEngine
from repro.serving.publisher import StaticSource, TablePublisher

DIM = 4


def keys_u64(*ids) -> np.ndarray:
    return np.asarray(ids, np.uint64)


def rows(keys, fill=None):
    base = np.asarray(keys, np.float64) if fill is None else np.full(
        len(keys), fill, np.float64)
    return jnp.asarray(base[:, None] + np.arange(DIM)[None, :], jnp.float32)


# =============================================================================
# Predicates
# =============================================================================


class TestSweepPredicate:
    def test_kinds_match_expected_sets(self):
        keys = U64(jnp.zeros((4,), jnp.uint32),
                   jnp.asarray([1, 5, 9, 20], jnp.uint32))
        scores = U64(jnp.asarray([0, 0, 1, 2], jnp.uint32),
                     jnp.asarray([3, 7, 0, 0], jnp.uint32))
        np.testing.assert_array_equal(
            SweepPredicate.always().matches(keys, scores), [1, 1, 1, 1])
        np.testing.assert_array_equal(
            SweepPredicate.score_below(7).matches(keys, scores),
            [1, 0, 0, 0])
        np.testing.assert_array_equal(
            SweepPredicate.score_at_least(7).matches(keys, scores),
            [0, 1, 1, 1])
        np.testing.assert_array_equal(
            SweepPredicate.expire_before(2).matches(keys, scores),
            [1, 1, 1, 0])
        np.testing.assert_array_equal(
            SweepPredicate.key_in_range(5, 20).matches(keys, scores),
            [0, 1, 1, 0])

    def test_wide_threshold_crosses_the_plane_split(self):
        keys = U64(jnp.zeros((2,), jnp.uint32), jnp.zeros((2,), jnp.uint32))
        scores = U64(jnp.asarray([1, 2], jnp.uint32),
                     jnp.asarray([0, 0], jnp.uint32))
        pred = SweepPredicate.score_below((2 << 32) | 5)
        np.testing.assert_array_equal(pred.matches(keys, scores), [1, 1])
        pred = SweepPredicate.score_below((1 << 32) | 0)
        np.testing.assert_array_equal(pred.matches(keys, scores), [0, 0])

    def test_predicate_is_a_jit_pytree_one_compile_per_kind(self):
        t = HKVTable.create(capacity=128, dim=DIM)
        t = t.insert_or_assign(keys_u64(1, 2, 3), rows([1, 2, 3])).table
        calls = []

        @jax.jit
        def sweep(t, pred):
            calls.append(None)   # traced once per (structure, shapes)
            return t.erase_if(pred).swept

        for thr in (10, 20, 30):
            sweep(t, SweepPredicate.key_in_range(0, thr))
        assert len(calls) == 1   # thresholds flow as data, not structure

    def test_bad_kind_rejected(self):
        with pytest.raises(ValueError):
            SweepPredicate._make("bogus")


# =============================================================================
# erase_if / evict_if on the flat handle
# =============================================================================


class TestEraseIf:
    def test_key_range_erases_exactly_the_range(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM)
        ids = np.arange(1, 41, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        r = t.erase_if(SweepPredicate.key_in_range(10, 20))
        assert int(r.swept) == 10
        assert int(r.table.size()) == 30
        found = np.asarray(r.table.contains(ids))
        np.testing.assert_array_equal(found, (ids < 10) | (ids >= 20))
        # erased slots are reusable
        t2 = r.table.insert_or_assign(keys_u64(10), rows([10])).table
        assert bool(t2.contains(keys_u64(10))[0])

    def test_score_threshold_under_lfu(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM, score_policy="lfu")
        ids = np.arange(1, 31, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        t = t.insert_or_assign(ids[:5], rows(ids[:5])).table  # count -> 2
        r = t.erase_if(SweepPredicate.score_below(2))
        assert int(r.swept) == 25
        remaining = np.asarray(r.table.contains(ids))
        np.testing.assert_array_equal(remaining, np.arange(1, 31) <= 5)

    def test_values_are_cleared_with_the_slots(self):
        t = HKVTable.create(capacity=128, dim=DIM)
        t = t.insert_or_assign(keys_u64(7), rows([7], fill=3.0)).table
        r = t.erase_if(SweepPredicate.always())
        assert int(r.swept) == 1
        assert float(jnp.abs(r.table.state.values).sum()) == 0.0


class TestEvictIf:
    def test_coldest_first_rank_order_and_budget(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM, score_policy="lfu")
        ids = np.arange(1, 21, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        # heat up the odd keys: their LFU count rises to 2
        t = t.insert_or_assign(ids[::2], rows(ids[::2])).table
        r = t.evict_if(SweepPredicate.always(), budget=10)
        assert int(r.count) == 10
        got = ((np.asarray(r.evicted.key_hi, np.uint64) << np.uint64(32))
               | np.asarray(r.evicted.key_lo, np.uint64))
        # the 10 coldest are exactly the count-1 keys, ascending key order
        np.testing.assert_array_equal(got, ids[1::2])
        assert np.asarray(r.evicted.mask).all()
        # evicted rows carry their values (the demotion transport)
        np.testing.assert_allclose(np.asarray(r.evicted.values)[:, :DIM],
                                   np.asarray(rows(ids[1::2])))
        assert int(r.table.size()) == 10

    def test_dynamic_limit_caps_the_moves(self):
        t = HKVTable.create(capacity=128, dim=DIM)
        ids = np.arange(1, 21, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        r = t.evict_if(SweepPredicate.always(), budget=16,
                       limit=jnp.int32(3))
        assert int(r.count) == 3
        assert int(r.table.size()) == 17
        assert not np.asarray(r.evicted.mask)[3:].any()

    def test_budget_validation_and_clamp(self):
        t = HKVTable.create(capacity=128, dim=DIM)
        with pytest.raises(ValueError):
            t.evict_if(SweepPredicate.always(), budget=0)
        # over-capacity budgets clamp (uniform across impls) — a caller
        # may size the budget to the WHOLE hierarchy's capacity
        ids = np.arange(1, 11, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        r = t.evict_if(SweepPredicate.always(), budget=10**6)
        assert int(r.count) == 10

    def test_tiered_eviction_leaves_no_stale_cold_copy(self):
        """An evicted key must leave the WHOLE hierarchy: a hot-evicted
        key's stale inclusive cold copy (left behind by promotion) must
        not keep serving hits after the stream reported the key gone."""
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        ids = np.arange(1, 200, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table   # overfill -> demotions
        # promote every cold-resident key back hot (stale copies remain)
        t = t.find(ids).table
        r = t.evict_if(SweepPredicate.key_in_range(1, 200), budget=1)
        mask = np.asarray(r.evicted.mask)
        assert mask.any()
        khi = np.asarray(r.evicted.key_hi, np.uint64)
        klo = np.asarray(r.evicted.key_lo, np.uint64)
        gone = np.array([(khi[i] << np.uint64(32)) | klo[i]
                         for i in np.nonzero(mask)[0]], np.uint64)
        assert not np.asarray(r.table.contains(gone)).any()


# =============================================================================
# TTL / epoch expiry
# =============================================================================


class TestTTLExpiry:
    def test_expire_before_on_epoch_lru(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM,
                            score_policy="epoch_lru")
        old = np.arange(1, 11, dtype=np.uint64)
        t = t.insert_or_assign(old, rows(old)).table       # epoch 0
        t = t.set_epoch(3)
        fresh = np.arange(100, 110, dtype=np.uint64)
        t = t.insert_or_assign(fresh, rows(fresh)).table   # epoch 3
        r = t.erase_if(SweepPredicate.expire_before(t.epoch))
        assert int(r.swept) == 10
        assert not np.asarray(r.table.contains(old)).any()
        assert np.asarray(r.table.contains(fresh)).all()

    def test_touch_refreshes_the_epoch_stamp(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM,
                            score_policy="epoch_lru")
        ids = np.arange(1, 11, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        t = t.set_epoch(2)
        t = t.insert_or_assign(ids[:3], rows(ids[:3])).table  # re-touch
        r = t.erase_if(SweepPredicate.expire_before(2))
        assert int(r.swept) == 7       # the 3 touched keys survived
        np.testing.assert_array_equal(np.asarray(r.table.contains(ids)),
                                      np.arange(1, 11) <= 3)

    def test_tiered_expiry_kills_cold_copies_too(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM, score_policy="epoch_lru")
        # overfill hot so demotions put epoch-0 entries cold-side
        ids = np.arange(1, 200, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        t = t.set_epoch(5)
        r = t.erase_if(SweepPredicate.expire_before(5))
        assert int(r.table.size()) == 0   # no resurrection from cold
        # and the hierarchy is usable afterwards
        t2 = r.table.insert_or_assign(keys_u64(7), rows([7])).table
        assert bool(t2.contains(keys_u64(7))[0])


# =============================================================================
# TableStats
# =============================================================================


class TestTableStats:
    def test_flat_stats_shapes_and_values(self):
        t = HKVTable.create(capacity=2 * 128, dim=DIM, score_policy="lfu")
        ids = np.arange(1, 41, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        t = t.insert_or_assign(ids[:10], rows(ids[:10])).table
        s = t.stats()
        assert int(s.size) == 40
        assert int(s.capacity) == 2 * 128
        assert abs(float(s.load_factor) - 40 / 256) < 1e-6
        hist = np.asarray(s.occupancy_hist)
        assert hist.shape == (129,)
        assert hist.sum() == 2                        # one count per bucket
        assert (hist * np.arange(129)).sum() == 40    # weighted = size
        q = s.score_quantiles()
        assert q.shape == (5,)
        assert (np.diff(q.astype(np.int64)) >= 0).all()   # monotone
        assert q[0] == 1 and q[-1] == 2               # LFU counts 1 and 2

    def test_empty_table_stats(self):
        s = HKVTable.create(capacity=128, dim=DIM).stats()
        assert int(s.size) == 0
        assert float(s.load_factor) == 0.0
        assert np.asarray(s.occupancy_hist)[0] == 1
        assert (s.score_quantiles() == 0).all()

    def test_tiered_stats_dedupe_and_tier_detail(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM)
        ids = np.arange(1, 200, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        s = t.stats()
        assert int(s.size) == int(t.size())           # inclusive copies deduped
        hot, cold = t.tier_stats()
        assert int(hot.size) == int(t.hot.size())
        assert int(cold.size) == int(t.cold.size())
        assert float(hot.load_factor) == 1.0          # hot tier is full
        assert int(s.capacity) == t.capacity

    def test_stats_under_jit(self):
        t = HKVTable.create(capacity=128, dim=DIM)
        t = t.insert_or_assign(keys_u64(1, 2), rows([1, 2])).table
        s = jax.jit(lambda t: t.stats())(t)
        assert int(s.size) == 2


# =============================================================================
# Watermark rebalancing
# =============================================================================


class TestRebalance:
    def _full_hot(self):
        t = TieredHKVTable.create(hot_capacity=2 * 128,
                                  cold_capacity=8 * 128, dim=DIM,
                                  score_policy="lfu")
        ids = np.arange(1, 257, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table        # hot at λ=1.0
        t = t.insert_or_assign(ids[128:], rows(ids[128:])).table  # heat half
        return t, ids

    def test_sweeps_down_to_the_low_watermark(self):
        t, ids = self._full_hot()
        pre_hot = int(t.hot.size())      # < 256: admission rejects at ties
        pre_cold = int(t.cold.size())
        r = rebalance(t, low_watermark=0.5, high_watermark=0.75, budget=512)
        # swept exactly down to the low watermark (128 slots)
        assert int(r.moved) == pre_hot - 128
        assert int(r.table.hot.size()) == 128
        # the demoted entries remain resident cold-side — nothing left
        # the hierarchy
        assert int(r.dropped) == 0
        assert int(r.table.cold.size()) >= pre_cold
        assert np.asarray(r.table.contains(ids)).all()

    def test_noop_below_the_high_watermark(self):
        t = TieredHKVTable.create(hot_capacity=2 * 128,
                                  cold_capacity=8 * 128, dim=DIM)
        ids = np.arange(1, 101, dtype=np.uint64)   # ~39% occupancy
        t = t.insert_or_assign(ids, rows(ids)).table
        r = rebalance(t, low_watermark=0.5, high_watermark=0.75, budget=512)
        assert int(r.moved) == 0
        assert int(r.table.hot.size()) == 100

    def test_budget_bounds_the_moves(self):
        t, _ids = self._full_hot()
        r = rebalance(t, low_watermark=0.25, high_watermark=0.5, budget=32)
        assert int(r.moved) == 32

    def test_bad_watermarks_rejected(self):
        t, _ = self._full_hot()
        with pytest.raises(ValueError):
            rebalance(t, low_watermark=0.9, high_watermark=0.5)

    def test_freed_headroom_absorbs_admissions_without_eviction(self):
        t, _ids = self._full_hot()
        r = rebalance(t, low_watermark=0.5, high_watermark=0.75, budget=512)
        new = np.arange(1000, 1100, dtype=np.uint64)
        res = r.table.insert_or_assign(new, rows(new))
        # admissions land in swept slots: zero reactive demotions
        assert int(res.demoted) == 0


# =============================================================================
# The scheduler
# =============================================================================


class TestScheduler:
    def test_ttl_policy_expires_after_the_window(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM, score_policy="epoch_lru")
        ids = np.arange(1, 30, dtype=np.uint64)
        t = t.insert_or_assign(ids, rows(ids)).table
        sched = MaintenanceScheduler(MaintenancePolicy(
            ttl_epochs=2, advance_epoch=True, sweep_budget=64))
        sizes = []
        for _ in range(4):
            t, _rep = sched.run(t)
            sizes.append(int(t.size()))
        # alive through the TTL window, gone after it
        assert sizes[:2] == [29, 29]
        assert sizes[2:] == [0, 0]
        assert sched.totals.expired == 29

    def test_ttl_requires_epoch_policy(self):
        t = HKVTable.create(capacity=128, dim=DIM)  # lru
        sched = MaintenanceScheduler(MaintenancePolicy(ttl_epochs=1))
        with pytest.raises(ValueError, match="epoch"):
            sched.run(t)

    def test_on_wave_cadence_and_source_roundtrip(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM, score_policy="epoch_lru")
        t = t.insert_or_assign(keys_u64(1, 2, 3), rows([1, 2, 3])).table
        src = StaticSource(t)
        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=3, ttl_epochs=1, advance_epoch=True))
        ran = [sched.on_wave(src) is not None for _ in range(6)]
        assert ran == [False, False, True, False, False, True]
        assert int(src.table.size()) == 0     # expiry applied to the source

    def test_offer_loses_to_a_concurrent_publish(self):
        t = TieredHKVTable.create(hot_capacity=128, cold_capacity=2 * 128,
                                  dim=DIM, score_policy="epoch_lru")
        pub = TablePublisher(t)
        sched = MaintenanceScheduler(MaintenancePolicy(
            ttl_epochs=1, advance_epoch=True))

        class RacingSource:
            def snapshot(self):
                return pub.snapshot()

            def offer(self, version, table):
                pub.publish(t)                 # the trainer wins the race
                return pub.offer(version, table)

        rep = sched.on_wave(RacingSource())
        assert rep is not None and not rep.applied
        assert sched.totals.skipped_offers == 1
        assert pub.version == 1                # only the publish landed

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            MaintenancePolicy(every_waves=0)
        with pytest.raises(ValueError):
            MaintenancePolicy(sweep_budget=0)


# =============================================================================
# Engine integration — the exp7 acceptance shape in miniature
# =============================================================================


class TestEngineIntegration:
    def _drive(self, scheduler):
        rng = np.random.default_rng(7)
        table = TieredHKVTable.create(hot_capacity=2 * 128,
                                      cold_capacity=8 * 128, dim=8)
        eng = OnlineEmbeddingEngine(table, wave_size=256,
                                    miss_policy="admit",
                                    scheduler=scheduler)
        stream = zipf_keys(rng, 256 * 12, 1.05, 2 * 8 * 128)
        for i in range(12):
            eng.submit(EmbeddingRequest(
                rid=i, keys=stream[i * 256:(i + 1) * 256]))
            eng.step()
        return eng.metrics()

    def test_scheduler_moves_demotions_off_the_serving_path(self):
        m_off = self._drive(None)
        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=1, sweep_budget=256,
            low_watermark=0.5, high_watermark=0.8))
        m_on = self._drive(sched)
        # the acceptance bar: reactive demotions per wave strictly
        # decrease; hit rate does not regress at the same hot capacity
        assert m_off.demotions_per_wave > 0
        assert m_on.demotions_per_wave < m_off.demotions_per_wave
        assert m_on.hit_rate >= m_off.hit_rate - 1e-9
        assert sched.totals.demoted > 0       # the work moved, not vanished

    def test_wave_reports_carry_reactive_demotions(self):
        m = self._drive(None)
        assert m.reactive_demotions > 0
        assert m.reactive_demotions == round(
            m.demotions_per_wave * m.waves)
