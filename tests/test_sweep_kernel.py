"""Kernel/core parity for the Pallas bucket-sweep path (interpret mode).

Same acceptance bar as test_upsert_kernel.py: BIT-IDENTITY.  For
randomized interleaves of upserts and predicated sweeps — every predicate
kind, full buckets at λ=1.0, dual-bucket configs, LFU score ties —
`erase_if`/`evict_if` on backend='kernel' must produce exactly the
post-state (keys, digests, scores, values), swept counts, and evicted
streams of the pure-jnp reference.  Both share everything downstream of
the match mask (`core/ops.py` orchestration); the mask itself is the one
kernel-replaced stage and evaluates the same `match_planes` formula
(`core/predicates.py`), so these tests pin that the sweep_scan kernel's
liveness gating and per-kind compares honor the contract.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import merge, ops, table, u64
from repro.core.predicates import SweepPredicate
from repro.kernels import ops as kops
from repro.kernels import sweep_scan

EMPTY = np.uint64(0xFFFFFFFFFFFFFFFF)


def _assert_states_equal(a, b, ctx=""):
    for f in ("key_hi", "key_lo", "digests", "score_hi", "score_lo", "values",
              "clock_hi", "clock_lo", "epoch"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: state.{f}")


def _assert_streams_equal(a, b, ctx=""):
    for f in ("key_hi", "key_lo", "values", "score_hi", "score_lo", "mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: evicted.{f}")


def _random_preds(rng):
    """One predicate of each kind, with randomized operands."""
    lo = int(rng.integers(0, 2**40))
    return [
        SweepPredicate.always(),
        SweepPredicate.score_below(int(rng.integers(1, 64))),
        SweepPredicate.score_at_least(int(rng.integers(1, 64))),
        SweepPredicate.expire_before(int(rng.integers(0, 4))),
        SweepPredicate.key_in_range(lo, lo + int(rng.integers(1, 2**39))),
    ]


@pytest.mark.parametrize("kind_i", range(5))
def test_sweep_mask_kernel_matches_reference(kind_i):
    """The replaced stage in isolation: kernel mask == jnp mask, every
    kind, on a table with live/empty mix and wide keys."""
    rng = np.random.default_rng(11 + kind_i)
    cfg = table.HKVConfig(capacity=4 * 128, dim=4, score_policy="lfu")
    state = table.create(cfg)
    keys = rng.integers(0, 2**50, size=300).astype(np.uint64)
    vals = jnp.asarray(rng.normal(size=(300, 4)), jnp.float32)
    state = merge.upsert(state, cfg, u64.from_uint64(keys), vals).state
    pred = _random_preds(rng)[kind_i]
    ref = pred.matches(state.keys, state.scores) & state.occupied_mask()
    got = kops.sweep_mask_kernel(state, cfg, pred, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                  err_msg=f"kind={pred.kind}")


def test_sweep_match_counts_agree_with_mask():
    rng = np.random.default_rng(3)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4)
    state = table.create(cfg)
    keys = rng.integers(0, 2**20, size=200).astype(np.uint64)
    state = merge.upsert(
        state, cfg, u64.from_uint64(keys),
        jnp.zeros((200, 4), jnp.float32)).state
    pred = SweepPredicate.always()
    match, cnt = sweep_scan.sweep_match(
        state.key_hi, state.key_lo, state.score_hi, state.score_lo,
        pred.a_hi, pred.a_lo, pred.b_hi, pred.b_lo,
        kind=pred.kind, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(cnt), np.asarray(match).sum(axis=1))
    # odd bucket counts fall back to tile=1 (the wrapper's guard)
    m2, c2 = sweep_scan.sweep_match(
        state.key_hi[:3], state.key_lo[:3], state.score_hi[:3],
        state.score_lo[:3], pred.a_hi, pred.a_lo, pred.b_hi, pred.b_lo,
        kind=pred.kind, interpret=True)
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(match)[:3])


@pytest.mark.parametrize("dual", [False, True])
@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_randomized_sweeps_bit_identical_with_full_drain(dual, policy):
    """Interleave upserts (driving λ to 1.0) with randomized erase_if /
    evict_if sweeps on both backends; after every op the FULL state must
    be bit-identical, and a final export drains both tables to the same
    live set."""
    rng = np.random.default_rng(29 * (1 + dual) + len(policy))
    cfg = table.HKVConfig(
        capacity=4 * 128, dim=4, buckets_per_key=2 if dual else 1,
        score_policy=policy,
    )
    sj = table.create(cfg)
    sk = table.create(cfg)
    for step in range(6):
        keys = rng.integers(0, 2**50, size=192).astype(np.uint64)
        k = u64.from_uint64(keys)
        vals = jnp.asarray(rng.normal(size=(192, 4)), jnp.float32)
        sj = merge.upsert(sj, cfg, k, vals).state
        sk = kops.upsert_kernel(sk, cfg, k, vals, interpret=True).state
        pred = _random_preds(rng)[int(rng.integers(0, 5))]
        if step % 2:
            rj = ops.erase_if(sj, cfg, pred, backend="jnp")
            rk = ops.erase_if(sk, cfg, pred, backend="kernel")
            assert int(rj.swept) == int(rk.swept), f"step {step} swept"
        else:
            budget = int(rng.integers(1, 64))
            rj = ops.evict_if(sj, cfg, pred, budget, backend="jnp")
            rk = ops.evict_if(sk, cfg, pred, budget, backend="kernel")
            assert int(rj.count) == int(rk.count), f"step {step} count"
            _assert_streams_equal(rj.evicted, rk.evicted, f"step {step}")
        sj, sk = rj.state, rk.state
        _assert_states_equal(sj, sk, f"step {step} ({pred.kind})")
    # final full drain: identical live sets on both backends
    ej = ops.export_batch(sj, cfg, 0, cfg.num_buckets)
    ek = ops.export_batch(sk, cfg, 0, cfg.num_buckets)
    for f in ("key_hi", "key_lo", "values", "score_hi", "score_lo", "mask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ej, f)), np.asarray(getattr(ek, f)))


def test_evict_if_limit_parity():
    """The dynamic-limit seam (the rebalancer's path) on both backends."""
    rng = np.random.default_rng(5)
    cfg = table.HKVConfig(capacity=2 * 128, dim=4)
    k = u64.from_uint64(rng.integers(0, 2**30, size=200).astype(np.uint64))
    v = jnp.zeros((200, 4), jnp.float32)
    sj = merge.upsert(table.create(cfg), cfg, k, v).state
    sk = kops.upsert_kernel(table.create(cfg), cfg, k, v,
                            interpret=True).state
    for limit in (0, 7, 200):
        rj = ops.evict_if(sj, cfg, SweepPredicate.always(), 64,
                          limit=jnp.int32(limit), backend="jnp")
        rk = ops.evict_if(sk, cfg, SweepPredicate.always(), 64,
                          limit=jnp.int32(limit), backend="kernel")
        assert int(rj.count) == int(rk.count) == min(limit, 64)
        _assert_streams_equal(rj.evicted, rk.evicted, f"limit={limit}")
        _assert_states_equal(rj.state, rk.state, f"limit={limit}")
