"""Recompilation regressions: one compile per static signature.

PR 5 made ``SweepPredicate.kind`` the ONLY static axis of a predicate
(operands are traced u64 planes); the handle layer funnels every accepted
key form through ``normalize_keys`` into one aval.  These tests pin both
with ``jax.jit``'s cache counter so a weak_type leak or a Python operand
captured into the static signature fails CI as a named regression rather
than surfacing as a silent TPU perf cliff.

The dynamic compile-cache AUDIT (scenario table, findings) lives in
``repro.analysis.compile_cache``; this file is the narrow, always-on
regression net for the two contracts most likely to drift.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import HKVTable, normalize_keys
from repro.core.predicates import KINDS, SweepPredicate


def _table(backend="jnp"):
    return HKVTable.create(capacity=64, dim=4, slots_per_bucket=8,
                           backend=backend)


PREDS = {
    "always": (SweepPredicate.always(), SweepPredicate.always()),
    "score_lt": (SweepPredicate.score_below(3),
                 SweepPredicate.score_below(1 << 40)),
    "score_ge": (SweepPredicate.score_at_least(3),
                 SweepPredicate.score_at_least(1 << 40)),
    "epoch_lt": (SweepPredicate.expire_before(1),
                 SweepPredicate.expire_before(12)),
    "key_range": (SweepPredicate.key_in_range(1, 9),
                  SweepPredicate.key_in_range(1 << 33, 1 << 34)),
}


def test_predicate_kind_census_matches_kinds():
    # a new kind must be added to PREDS or the count assertions go stale
    assert set(PREDS) == set(KINDS)


@pytest.mark.parametrize("op", ["erase_if", "evict_if"])
def test_one_compile_per_predicate_kind(op):
    t = _table()
    if op == "erase_if":
        f = jax.jit(lambda tbl, p: tbl.erase_if(p).swept)
    else:
        f = jax.jit(lambda tbl, p: tbl.evict_if(p, 4).count)
    for kind in KINDS:
        for p in PREDS[kind]:
            f(t, p)
        assert f._cache_size() == list(KINDS).index(kind) + 1, (
            f"{op} recompiled within predicate kind {kind!r}: threshold "
            f"operands must be traced, not static")
    assert f._cache_size() == len(KINDS)


def test_one_compile_across_key_forms():
    t = _table()
    f = jax.jit(lambda tbl, keys: tbl.find(keys).values)
    forms = [
        normalize_keys([1, 2, -1, 4]),
        normalize_keys(np.arange(4, dtype=np.uint64)),
        normalize_keys(np.uint64([1 << 40, 2, 3, (1 << 63) + 5])),
        normalize_keys(np.array([7, 8, 9, 10], dtype=np.int32)),
    ]
    for keys in forms:
        f(t, keys)
    assert f._cache_size() == 1, (
        "normalize_keys must land every accepted key form on one aval "
        "(u64 plane pair, no weak_type drift)")


def test_one_compile_per_backend():
    f = jax.jit(lambda tbl, keys: tbl.contains(keys))
    keys = normalize_keys([1, 2, 3, 4])
    for backend in ("jnp", "kernel"):
        t = _table(backend)
        f(t, keys)
        f(t, keys)
    assert f._cache_size() == 2, (
        "backend is a static aux axis: one compile each, no growth on "
        "repeat calls")


def test_insert_values_and_scores_are_traced():
    t = _table()
    f = jax.jit(lambda tbl, keys, v: tbl.insert_or_assign(keys, v).status)
    keys = normalize_keys([1, 2, 3, 4])
    for fill in (0.0, 1.5, -2.0):
        f(t, keys, jnp.full((4, 4), fill, jnp.float32))
    assert f._cache_size() == 1

    g = jax.jit(lambda tbl, keys, s: tbl.assign_scores(keys, s))
    for sval in (3, 9, 1 << 40):
        g(t, keys, normalize_keys(np.uint64([sval] * 4)))
    assert g._cache_size() == 1, (
        "score operands (incl. wide u64) must share one compile")
