"""Whole-sequence determinism: the PR-1 victim-order guarantee extended
to entire op streams.

Contract: an identical seeded op sequence produces a BIT-IDENTICAL
`HKVState` — every key/digest/score plane, the value plane, clock, and
epoch — (a) across two fresh runs in one process, and (b) across the
`'jnp'` and `'kernel'` inserter backends (the fused Pallas path in
interpret mode off-TPU).  This is what makes checkpoint-replay
reconstruction (DESIGN.md §5) and the train→serve publisher's handle
swap reproducible: republishing a replayed table is byte-equivalent to
publishing the original.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ops
from repro.core.api import HKVTable
from repro.core.u64 import U64

CAP = 2 * 128
DIM = 4
LANES = 32


_JIT = {}


def _apply(table, op, keys, vals):
    """Dispatch one op through a cached jitted wrapper per op name."""
    if op not in _JIT:
        def make(op):
            if op == "upsert":
                return jax.jit(lambda t, kh, kl, v: t.insert_or_assign(
                    U64(kh, kl), v).table)
            if op == "foi":
                return jax.jit(lambda t, kh, kl, v: t.find_or_insert(
                    U64(kh, kl), v).table)
            if op == "evict":
                return jax.jit(lambda t, kh, kl, v: t.insert_and_evict(
                    U64(kh, kl), v).table)
            if op == "accum":
                return jax.jit(lambda t, kh, kl, v: t.accum_or_assign(
                    U64(kh, kl), v).table)
            if op == "assign":
                return jax.jit(lambda t, kh, kl, v: t.assign(U64(kh, kl), v))
            if op == "erase":
                return jax.jit(lambda t, kh, kl, v: t.erase(U64(kh, kl)))
            raise AssertionError(op)
        _JIT[op] = make(op)
    return _JIT[op](table, keys.hi, keys.lo, vals)


OPS = ("upsert", "foi", "evict", "accum", "assign", "erase")


def _run_sequence(backend: str, seed: int, steps: int = 40):
    """Replay the seeded sequence from a fresh table; returns HKVState."""
    rng = np.random.default_rng(seed)
    table = HKVTable.create(capacity=CAP, dim=DIM, buckets_per_key=2,
                            score_policy="lru", backend=backend)
    for _ in range(steps):
        op = OPS[rng.integers(0, len(OPS))]
        # oversubscribed key space: evictions and rejections happen
        keys = rng.integers(0, 4 * CAP, size=LANES).astype(np.uint64)
        keys[rng.random(LANES) < 0.1] = np.uint64(0xFFFFFFFFFFFFFFFF)
        k = U64(jnp.asarray((keys >> np.uint64(32)).astype(np.uint32)),
                jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)))
        vals = jnp.asarray(
            rng.integers(0, 7, size=(LANES, DIM)).astype(np.float32))
        table = _apply(table, op, k, vals)
    return table.state


def _assert_states_identical(a, b, ctx: str):
    for name in a._fields:
        av, bv = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert av.dtype == bv.dtype, f"{ctx}: {name} dtype"
        assert np.array_equal(av, bv), (
            f"{ctx}: state field {name!r} diverges at "
            f"{np.argwhere(av != bv)[:4].tolist()}")


def test_two_fresh_runs_are_bit_identical():
    s1 = _run_sequence("jnp", seed=7)
    s2 = _run_sequence("jnp", seed=7)
    _assert_states_identical(s1, s2, "run1 vs run2")


def test_jnp_and_kernel_backends_are_bit_identical():
    s_jnp = _run_sequence("jnp", seed=11)
    s_kernel = _run_sequence("kernel", seed=11)
    _assert_states_identical(s_jnp, s_kernel, "jnp vs kernel")


def test_different_seeds_actually_differ():
    """Guards the test itself: the sequence must be state-changing enough
    that determinism is a non-trivial claim."""
    s1 = _run_sequence("jnp", seed=7)
    s2 = _run_sequence("jnp", seed=8)
    assert int(ops.size(s1)) > 0
    same = all(
        np.array_equal(np.asarray(getattr(s1, f)), np.asarray(getattr(s2, f)))
        for f in s1._fields)
    assert not same
