"""hkv-lint's own suite: every checker flags its known-bad fixture, the
shipped tree is clean, and the findings model (waivers, formats) behaves.

The fixture tests are the teeth of the analyzer — a checker that never
fires is indistinguishable from a correct tree, so each rule is proven
against a deliberately broken input before the cleanliness assertions
are trusted.
"""

import pathlib
import subprocess
import sys

import pytest

from repro import analysis
from repro.analysis import findings as findings_mod
from repro.analysis import kernel_contracts as kc
from repro.analysis import oracle_coupling as oc
from repro.analysis import registry
from repro.analysis import roles as roles_checker
from repro.analysis import telemetry as tel_checker
from repro.analysis.fixtures import bad_kernels, bad_ops

REPO = pathlib.Path(__file__).resolve().parents[1]


def rules(fs):
    return sorted({f.rule for f in fs})


# ---------------------------------------------------------------------------
# fixtures: each checker demonstrably fires
# ---------------------------------------------------------------------------

class TestKernelFixtures:
    def test_unpaired_dma_flagged(self):
        fs = kc.check_traced_kernel(
            "fixture_unpaired_dma", "fixture", bad_kernels.trace_unpaired_dma())
        assert "dma-unpaired" in rules(fs)

    def test_unmasked_store_flagged(self):
        fs = kc.check_traced_kernel(
            "fixture_unmasked_store", "fixture",
            bad_kernels.trace_unmasked_store())
        assert rules(fs) == ["unmasked-store"]

    def test_direct_hbm_read_flagged(self):
        fs = kc.check_traced_kernel(
            "fixture_direct_hbm", "fixture", bad_kernels.trace_direct_hbm())
        assert "memory-space" in rules(fs)

    def test_trace_failure_is_a_finding(self):
        spec = registry.KernelSpec(
            "boom", "fixture", lambda: (_ for _ in ()).throw(RuntimeError("x")))
        fs = kc.check_kernels([spec])
        assert rules(fs) == ["trace-failed"]


class TestRolesFixture:
    def test_unannotated_op_flagged(self):
        fs = roles_checker.check_annotations(bad_ops, path="fixture")
        assert [(f.rule, f.subject) for f in fs] == \
            [("unannotated-op", "mystery_op")]

    def test_annotated_and_non_ops_not_flagged(self):
        subjects = {f.subject
                    for f in roles_checker.check_annotations(bad_ops)}
        assert "annotated_op" not in subjects
        assert "free_function" not in subjects
        assert "_private_helper" not in subjects


class TestTelemetryFixture:
    def test_missing_seam_flagged(self):
        fs = tel_checker.check_telemetry(bad_ops, path="fixture", exempt={})
        assert [(f.rule, f.subject) for f in fs] == \
            [("missing-telemetry-seam", "annotated_op")]

    def test_seamed_unannotated_and_exempt_not_flagged(self):
        # telemetered_op threads the seam; mystery_op has no role (the
        # roles checker owns that); an exemption silences annotated_op
        fs = tel_checker.check_telemetry(
            bad_ops, path="fixture",
            exempt={"annotated_op": "fixture rationale"})
        assert fs == []

    def test_stale_exemptions_flagged(self):
        fs = tel_checker.check_telemetry(
            bad_ops, path="fixture",
            exempt={"annotated_op": "ok",
                    "ghost_op": "no longer exists",
                    "telemetered_op": "grew the seam"})
        assert [(f.rule, f.subject) for f in fs] == \
            [("stale-exemption", "ghost_op"),
             ("stale-exemption", "telemetered_op")]


class TestForkFixture:
    FIXTURE = REPO / "src/repro/analysis/fixtures/bad_fork.py"

    def test_inline_match_formula_flagged(self):
        fs = oc.scan_source(self.FIXTURE.read_text(), "fixtures/bad_fork.py")
        assert rules(fs) == ["match-formula-fork"]
        assert len(fs) == 1, "control conjunction must not be flagged"

    def test_forked_definition_flagged(self):
        src = "def match_lanes(a, b, c, d):\n    return (a == c) & (b == d)\n"

        class FakePath:
            def __init__(self, text):
                self._t = text

            def read_text(self):
                return self._t

        files = [("repro/core/find.py", FakePath(src)),
                 ("repro/kernels/evil.py", FakePath(src))]
        fs = oc.check_multiplicity(files)
        assert "oracle-multiplicity" in rules(fs)


class TestCompileCacheAudit:
    def test_recompile_detected(self, monkeypatch):
        import repro.analysis.compile_cache as cc

        monkeypatch.setattr(cc, "scenarios", lambda: [
            cc.Scenario("hot", 1, lambda: 3),
            cc.Scenario("under", 2, lambda: 1),
            cc.Scenario("boom", 1,
                        lambda: (_ for _ in ()).throw(ValueError("x"))),
        ])
        fs = cc.check_compile_cache()
        assert rules(fs) == ["audit-error", "recompile", "under-exercised"]


# ---------------------------------------------------------------------------
# cleanliness: the shipped tree passes every checker
# ---------------------------------------------------------------------------

class TestShippedTreeClean:
    def test_kernel_contracts_clean(self):
        assert kc.check_kernels() == []

    def test_hmem_seam_clean(self):
        assert kc.check_hmem_seam() == []

    def test_roles_clean(self):
        assert roles_checker.check_roles() == []

    def test_oracle_coupling_clean(self):
        assert oc.check_oracle_coupling() == []

    def test_telemetry_clean(self):
        # every @roles-annotated op threads telemetry= or carries a
        # reviewed TELEMETRY_EXEMPT rationale, and no exemption is stale
        assert tel_checker.check_telemetry() == []

    def test_registry_covers_every_pallas_file(self):
        assert registry.unregistered_kernel_files() == []

    @pytest.mark.slow
    def test_compile_cache_clean(self):
        from repro.analysis.compile_cache import check_compile_cache
        assert check_compile_cache() == []


# ---------------------------------------------------------------------------
# findings model: waivers and output formats
# ---------------------------------------------------------------------------

def _finding(rule="unmasked-store", subject="k1", sev="error"):
    return findings_mod.Finding("kernel-contracts", rule, subject,
                                "msg with % and\nnewline",
                                path="src/x.py", line=3, severity=sev)


class TestFindingsModel:
    def test_waiver_glob_matches_and_annotates(self):
        w = findings_mod.Waiver("kernel-contracts", "unmasked-store", "k*",
                                "known benign: sentinel fill")
        out = findings_mod.apply_waivers([_finding()], (w,))
        assert out[0].waived and "sentinel" in out[0].waiver_reason
        assert findings_mod.unwaived(out) == []

    def test_waiver_requires_all_three_axes(self):
        w = findings_mod.Waiver("roles", "unmasked-store", "k*", "no")
        out = findings_mod.apply_waivers([_finding()], (w,))
        assert not out[0].waived
        assert findings_mod.unwaived(out) == out

    def test_warning_severity_not_fatal(self):
        out = findings_mod.apply_waivers([_finding(sev="warning")], ())
        assert findings_mod.unwaived(out) == []

    def test_text_format_summary_line(self):
        txt = findings_mod.format_text([_finding()])
        assert "hkv-lint: 1 finding(s), 1 fatal, 0 waived" in txt
        assert "src/x.py:3" in txt

    def test_github_format_escapes_workflow_commands(self):
        gh = findings_mod.format_github([_finding()])
        line = gh.splitlines()[0]
        assert line.startswith("::error file=src/x.py,line=3")
        assert "%25" in line and "%0A" in line

    def test_no_shipped_waivers(self):
        # satellite 1: the shipped tree is clean WITHOUT waivers; any
        # future waiver must come with a reviewed rationale here.
        assert findings_mod.WAIVERS == ()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCli:
    def test_run_all_subset_unknown_checker(self):
        with pytest.raises(SystemExit):
            analysis.run_all(only=["nope"])

    @pytest.mark.slow
    def test_cli_clean_exit_zero(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis",
             "--checker", "oracle-coupling", "--checker", "roles"],
            capture_output=True, text=True,
            cwd=REPO, env={"PYTHONPATH": str(REPO / "src"),
                           "PATH": "/usr/bin:/bin:/usr/local/bin",
                           "HOME": "/tmp"})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "0 fatal" in proc.stdout
