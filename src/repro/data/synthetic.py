"""Seeded synthetic data: Zipfian key streams + LM token batches.

The paper's workload (§2.1) is a power-law uint64 feature-ID stream under
continuous ingestion.  `zipf_ranks` draws ranks from a truncated Zipf(α)
via analytic inverse-CDF of the harmonic approximation (exact enough for
the α ∈ [0.5, 1.25] sweep of Table 8 and O(1) memory at any key-space
size); `zipf_keys` maps ranks through fmix64 so that hot keys are scattered
uniformly over the uint64 space (no accidental bucket locality).

Everything is seed-deterministic and rank-shardable: worker r of w draws
the same global stream and keeps its slice, so restarts resume exactly
(see data.pipeline.DataCursor).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def zipf_ranks(rng: np.random.Generator, n: int, alpha: float, k: int) -> np.ndarray:
    """Ranks in [0, k) with P(r) ∝ (r+1)^-alpha, via inverse harmonic CDF."""
    u = rng.random(n)
    if abs(alpha - 1.0) < 1e-9:
        h = np.log(k + 1.0)
        ranks = np.expm1(u * h)
    else:
        h = ((k + 1.0) ** (1.0 - alpha) - 1.0) / (1.0 - alpha)
        ranks = (u * h * (1.0 - alpha) + 1.0) ** (1.0 / (1.0 - alpha)) - 1.0
    return np.clip(ranks.astype(np.int64), 0, k - 1)


def _fmix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64).copy()
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xFF51AFD7ED558CCD)
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xC4CEB9FE1A85EC53)
        x ^= x >> np.uint64(33)
    return x


def zipf_keys(rng: np.random.Generator, n: int, alpha: float, key_space: int) -> np.ndarray:
    """Power-law uint64 feature IDs: rank -> fmix64(rank) (hot set scattered)."""
    return _fmix64(zipf_ranks(rng, n, alpha, key_space))


# =============================================================================
# Arrival processes — request sizes per serving tick (SLO workloads)
# =============================================================================
#
# The serving engine's SLO numbers (queue-wait vs service p50/p99) only
# mean something under NON-steady arrivals: a burst that outruns one
# wave's lanes queues, and the queue-wait it accrues is exactly what
# continuous-batch admission exists to cut.  Each generator returns an
# int64 array of REQUEST SIZES (keys per tick) for `ticks` serving ticks,
# calibrated so the mean load is `base_load * wave_size` keys/tick —
# comparable total work across arrival shapes.

ARRIVAL_KINDS = ("steady", "burst", "diurnal")


def steady_sizes(rng: np.random.Generator, ticks: int, wave_size: int,
                 *, base_load: float = 0.75) -> np.ndarray:
    """Constant-rate arrivals: every tick offers the same key count."""
    return np.full(ticks, max(1, int(round(base_load * wave_size))), np.int64)


def poisson_burst_sizes(rng: np.random.Generator, ticks: int, wave_size: int,
                        *, base_load: float = 0.5, burst_prob: float = 0.15,
                        burst_mult: float = 6.0) -> np.ndarray:
    """Poisson arrivals with a bursty modulated rate: each tick draws
    Poisson(λ) keys where λ is the base rate, except Bernoulli(burst_prob)
    ticks fire at `burst_mult`× — the flash-crowd shape whose queue
    depth exposes admission-granularity latency."""
    lam = base_load * wave_size
    bursty = rng.random(ticks) < burst_prob
    rates = np.where(bursty, burst_mult * lam, lam)
    return rng.poisson(rates).astype(np.int64)


def sinusoidal_sizes(rng: np.random.Generator, ticks: int, wave_size: int,
                     *, base_load: float = 0.5, amplitude: float = 0.9,
                     period: int = 32) -> np.ndarray:
    """Diurnal arrivals: Poisson around a sinusoidal rate —
    λ(t) = base * (1 + amplitude * sin(2πt/period)), floor 0.  The slow
    swell fills and drains the queue once per period."""
    t = np.arange(ticks)
    lam = base_load * wave_size * (
        1.0 + amplitude * np.sin(2.0 * np.pi * t / period))
    return rng.poisson(np.maximum(lam, 0.0)).astype(np.int64)


def arrival_sizes(kind: str, rng: np.random.Generator, ticks: int,
                  wave_size: int, **kwargs) -> np.ndarray:
    """Dispatch on arrival shape: 'steady' | 'burst' | 'diurnal'."""
    try:
        fn = {"steady": steady_sizes, "burst": poisson_burst_sizes,
              "diurnal": sinusoidal_sizes}[kind]
    except KeyError:
        raise ValueError(
            f"arrival kind {kind!r}; one of {ARRIVAL_KINDS}") from None
    return fn(rng, ticks, wave_size, **kwargs)


@dataclasses.dataclass
class TokenStream:
    """Deterministic LM token batches with Zipfian unigram statistics.

    Yields (tokens, labels) int32 [batch, seq]: labels are tokens shifted
    by one (next-token LM).  `rank`/`world` slice the global batch for DP.
    """

    seed: int
    batch: int           # per-host batch after DP slicing
    seq: int
    vocab: int
    alpha: float = 1.0
    rank: int = 0
    world: int = 1

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.rank, self.world])
        )
        toks = zipf_ranks(rng, self.batch * (self.seq + 1), self.alpha, self.vocab)
        toks = toks.reshape(self.batch, self.seq + 1).astype(np.int32)
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
