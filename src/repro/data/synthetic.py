"""Seeded synthetic data: Zipfian key streams + LM token batches.

The paper's workload (§2.1) is a power-law uint64 feature-ID stream under
continuous ingestion.  `zipf_ranks` draws ranks from a truncated Zipf(α)
via analytic inverse-CDF of the harmonic approximation (exact enough for
the α ∈ [0.5, 1.25] sweep of Table 8 and O(1) memory at any key-space
size); `zipf_keys` maps ranks through fmix64 so that hot keys are scattered
uniformly over the uint64 space (no accidental bucket locality).

Everything is seed-deterministic and rank-shardable: worker r of w draws
the same global stream and keeps its slice, so restarts resume exactly
(see data.pipeline.DataCursor).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def zipf_ranks(rng: np.random.Generator, n: int, alpha: float, k: int) -> np.ndarray:
    """Ranks in [0, k) with P(r) ∝ (r+1)^-alpha, via inverse harmonic CDF."""
    u = rng.random(n)
    if abs(alpha - 1.0) < 1e-9:
        h = np.log(k + 1.0)
        ranks = np.expm1(u * h)
    else:
        h = ((k + 1.0) ** (1.0 - alpha) - 1.0) / (1.0 - alpha)
        ranks = (u * h * (1.0 - alpha) + 1.0) ** (1.0 / (1.0 - alpha)) - 1.0
    return np.clip(ranks.astype(np.int64), 0, k - 1)


def _fmix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64).copy()
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xFF51AFD7ED558CCD)
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xC4CEB9FE1A85EC53)
        x ^= x >> np.uint64(33)
    return x


def zipf_keys(rng: np.random.Generator, n: int, alpha: float, key_space: int) -> np.ndarray:
    """Power-law uint64 feature IDs: rank -> fmix64(rank) (hot set scattered)."""
    return _fmix64(zipf_ranks(rng, n, alpha, key_space))


@dataclasses.dataclass
class TokenStream:
    """Deterministic LM token batches with Zipfian unigram statistics.

    Yields (tokens, labels) int32 [batch, seq]: labels are tokens shifted
    by one (next-token LM).  `rank`/`world` slice the global batch for DP.
    """

    seed: int
    batch: int           # per-host batch after DP slicing
    seq: int
    vocab: int
    alpha: float = 1.0
    rank: int = 0
    world: int = 1

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.rank, self.world])
        )
        toks = zipf_ranks(rng, self.batch * (self.seq + 1), self.alpha, self.vocab)
        toks = toks.reshape(self.batch, self.seq + 1).astype(np.int32)
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
