from repro.data.synthetic import (  # noqa: F401
    ARRIVAL_KINDS,
    TokenStream,
    arrival_sizes,
    poisson_burst_sizes,
    sinusoidal_sizes,
    steady_sizes,
    zipf_keys,
    zipf_ranks,
)
from repro.data.pipeline import HostPrefetcher, DataCursor  # noqa: F401
