from repro.data.synthetic import zipf_ranks, zipf_keys, TokenStream  # noqa: F401
from repro.data.pipeline import HostPrefetcher, DataCursor  # noqa: F401
