"""Host-side input pipeline: prefetch + restart-exact cursors.

HostPrefetcher overlaps host batch synthesis/IO with device compute using a
bounded background queue (the standard double-buffer: while step N runs on
device, batch N+1 is being produced and transferred).

DataCursor is the checkpointable pipeline position: (seed, step).  Because
every batch is a pure function of (seed, step, rank, world) — see
data.synthetic — restoring the cursor resumes the exact stream, and
re-sharding to a different DP world size remains deterministic.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional


@dataclasses.dataclass
class DataCursor:
    seed: int
    step: int = 0

    def to_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "DataCursor":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class HostPrefetcher:
    """Bounded background prefetch over a step-indexed batch function."""

    def __init__(
        self,
        batch_fn: Callable[[int], object],
        cursor: DataCursor,
        depth: int = 2,
    ):
        self._fn = batch_fn
        self.cursor = cursor
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next_to_produce = cursor.step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        while not self._stop.is_set():
            step = self._next_to_produce
            batch = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._next_to_produce = step + 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.cursor.step = step + 1  # checkpoint-after-consume semantics
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
