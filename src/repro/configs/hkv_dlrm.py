"""hkv_dlrm — the paper's own workload (Fig. 1): a DLRM-style recommender
whose sparse-feature embedding tables are HKV cache-semantic tables under
continuous online ingestion.

Matches the paper's benchmark configs (Table 5):
  config A: dim=8,  capacity=128M   (scaled to the dev grid by `scale`)
  config B: dim=32, capacity=128M
  config C: dim=64, capacity=64M
  config D: dim=64, capacity=128M, HBM+HMEM value tier
"""

from __future__ import annotations

import dataclasses

from repro.embedding.dynamic import HKVEmbedding
from repro.embedding.sparse_opt import SparseOptimizer


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    num_sparse: int = 26              # criteo-style sparse fields
    dense_features: int = 13
    dim: int = 32
    capacity: int = 128 * 1024 * 1024
    mlp_bottom: tuple = (512, 256)
    mlp_top: tuple = (1024, 512, 1)
    value_tier: str = "hbm"
    buckets_per_key: int = 2
    score_policy: str = "lru"

    def embedding(self) -> HKVEmbedding:
        return HKVEmbedding(
            capacity=self.capacity,
            dim=self.dim,
            optimizer=SparseOptimizer("rowwise_adagrad", lr=0.01),
            buckets_per_key=self.buckets_per_key,
            score_policy=self.score_policy,
            value_tier=self.value_tier,
        )


PAPER_CONFIGS = {
    "A": DLRMConfig("A", dim=8, capacity=128 * 2**20),
    "B": DLRMConfig("B", dim=32, capacity=128 * 2**20),
    "C": DLRMConfig("C", dim=64, capacity=64 * 2**20),
    "D": DLRMConfig("D", dim=64, capacity=128 * 2**20, value_tier="hmem"),
}


def scaled(cfg: DLRMConfig, scale: int) -> DLRMConfig:
    """Shrink capacity by `scale` for CPU-runnable examples/benches."""
    return dataclasses.replace(cfg, capacity=max(256, cfg.capacity // scale))
