"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures (each with its own shape grid) + the paper's own
recommendation workload (hkv_dlrm).
"""

from __future__ import annotations

import importlib

_MODULES = {
    "gemma-2b": "gemma_2b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "yi-6b": "yi_6b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "musicgen-medium": "musicgen_medium",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.arch()


def all_archs():
    return [get_arch(n) for n in ARCH_NAMES]
