"""Config machinery shared by the assigned architectures.

Each arch module exports `arch()` returning an ArchDef: the exact published
LMConfig, the standard shape grid, and a structurally-identical reduced
config for CPU smoke tests.  The FULL configs are only ever lowered via
ShapeDtypeStruct (dry-run) — never allocated on the dev container.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.blocks import BlockCfg
from repro.models.lm import CompositeLM, LMConfig, StackSegment
from repro.models.moe import MoECfg


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    global_batch: int
    skip: Optional[str] = None   # reason string if this cell is skipped


def standard_shapes(sub_quadratic: bool) -> tuple:
    """The assigned LM shape grid. long_500k decodes against a 524288-token
    context, which requires bounded attention state — full-attention archs
    mark it SKIP(full-attn) per the assignment."""
    return (
        ShapeCfg("train_4k", "train", 4096, 256),
        ShapeCfg("prefill_32k", "prefill", 32768, 32),
        ShapeCfg("decode_32k", "decode", 32768, 128),
        ShapeCfg(
            "long_500k", "decode", 524288, 1,
            skip=None if sub_quadratic else "full-attn: unbounded 500k KV state",
        ),
    )


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                     # dense | moe | hybrid | vlm | audio | ssm
    lm: LMConfig
    smoke: LMConfig
    shapes: tuple
    vision_tokens: int = 0          # stub-frontend patch count (vlm only)
    source: str = ""

    def model(self, smoke: bool = False) -> CompositeLM:
        return CompositeLM(self.smoke if smoke else self.lm)

    def shape(self, name: str) -> ShapeCfg:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(name)

    def param_count(self) -> int:
        """Analytic parameter count from shapes (no allocation)."""
        import math

        model = CompositeLM(self.lm)
        shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def attn_block(
    d_model, heads, kv_heads, d_ff, *, head_dim=0, qkv_bias=False, window=None,
    rope="rope", rope_theta=10000.0, act="silu", gated=True, moe=None,
) -> BlockCfg:
    return BlockCfg(
        kind="attn", d_model=d_model, heads=heads, kv_heads=kv_heads,
        head_dim=head_dim, qkv_bias=qkv_bias, window=window, rope=rope,
        rope_theta=rope_theta, d_ff=d_ff, act=act, gated=gated, moe=moe,
    )


def shrink_lm(cfg: LMConfig, vocab: int = 512) -> LMConfig:
    """Structure-preserving reduction for CPU smoke tests: same segment
    kinds and ordering, tiny widths/counts."""

    def shrink_block(b: BlockCfg) -> BlockCfg:
        kw = dataclasses.asdict(b)
        if b.moe is not None:
            kw["moe"] = MoECfg(
                num_experts=4,
                top_k=min(b.moe.top_k, 2),
                d_model=64,
                d_ff=32,
                act=b.moe.act,
                gated=b.moe.gated,
            )
        kw.update(
            d_model=64,
            heads=4 if b.heads else 0,
            kv_heads=max(1, (4 * b.kv_heads) // max(b.heads, 1)) if b.heads else 0,
            head_dim=16 if b.head_dim else 0,
            d_ff=128 if (b.d_ff and b.moe is None) else (0 if b.moe else b.d_ff),
            d_state=16,
            ssm_heads=2,
            window=min(b.window, 32) if b.window else None,
        )
        return BlockCfg(**kw)

    def shrink_seg(s: StackSegment) -> StackSegment:
        return StackSegment(shrink_block(s.block), count=min(s.count, 2), shared=s.shared)

    return dataclasses.replace(
        cfg,
        d_model=64,
        vocab=vocab,
        prelude=tuple(shrink_seg(s) for s in cfg.prelude),
        segments=tuple(shrink_seg(s) for s in cfg.segments),
        repeats=min(cfg.repeats, 2),
        dtype=jnp.float32,
        loss_chunk=16,
    )
