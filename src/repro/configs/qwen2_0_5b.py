"""qwen2-0.5b [dense]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias, tied embeddings, rope theta 1e6. [arXiv:2407.10671; hf]"""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment


def arch() -> ArchDef:
    blk = attn_block(
        d_model=896, heads=14, kv_heads=2, d_ff=4864, qkv_bias=True,
        rope_theta=1e6, act="silu", gated=True,
    )
    lm = LMConfig(
        name="qwen2-0.5b",
        d_model=896,
        vocab=151936,
        segments=(StackSegment(blk, 24),),
        tied_head=True,
    )
    return ArchDef(
        name="qwen2-0.5b",
        family="dense",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        source="arXiv:2407.10671; hf",
    )
