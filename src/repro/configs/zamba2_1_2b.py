"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d=2048 ssm_state=64 + one SHARED
full-attention block (32H MHA, d_ff=8192) invoked every 6 layers with the
same parameters (Zamba2's weight-shared global block; per-invocation LoRA
deltas are omitted — noted in DESIGN.md). vocab=32000.
[arXiv:2411.15242; hf]

Structure: prelude (mamba2 x 2) + 6 x [mamba2 x 6, shared attn] = 38 mamba
layers + 6 invocations of the shared block.  Recurrent state is O(1) per
layer, so long_500k runs."""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.blocks import BlockCfg
from repro.models.lm import LMConfig, StackSegment

D = 2048


def arch() -> ArchDef:
    mamba = BlockCfg(
        kind="mamba2", d_model=D, d_state=64, ssm_heads=64, expand=2, conv_width=4,
    )
    shared_attn = attn_block(d_model=D, heads=32, kv_heads=32, d_ff=8192,
                             act="gelu", gated=False)
    lm = LMConfig(
        name="zamba2-1.2b",
        d_model=D,
        vocab=32000,
        prelude=(StackSegment(mamba, 2),),
        segments=(StackSegment(mamba, 6), StackSegment(shared_attn, 1, shared=True)),
        repeats=6,
        tied_head=True,
    )
    return ArchDef(
        name="zamba2-1.2b",
        family="hybrid",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=True),
        source="arXiv:2411.15242; hf",
    )
