"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, QKV bias, tied embeddings. [arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, vision_tokens, d] that replace the first
vision_tokens positions; M-RoPE position ids arrive as a [3, B, S] input
(t/h/w components)."""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment


def arch() -> ArchDef:
    blk = attn_block(
        d_model=1536, heads=12, kv_heads=2, d_ff=8960, qkv_bias=True,
        rope="mrope", rope_theta=1e6, act="silu", gated=True,
    )
    lm = LMConfig(
        name="qwen2-vl-2b",
        d_model=1536,
        vocab=151936,
        segments=(StackSegment(blk, 28),),
        tied_head=True,
        frontend="vision",
    )
    return ArchDef(
        name="qwen2-vl-2b",
        family="vlm",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        vision_tokens=256,
        source="arXiv:2409.12191; hf",
    )
