"""musicgen-medium [audio]: 48L d=1536 24H (MHA kv=24) d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens, sinusoidal positions, GELU FFN (ungated),
no RoPE. [arXiv:2306.05284; hf]

The EnCodec tokenizer/delay-pattern frontend is the STUB per the
assignment: the backbone consumes one pre-flattened codebook token stream
(vocab 2048); text-conditioning cross-attention is out of the LM shape
grid and omitted (DESIGN.md §Arch-applicability)."""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment


def arch() -> ArchDef:
    blk = attn_block(
        d_model=1536, heads=24, kv_heads=24, d_ff=6144, rope="none",
        act="gelu", gated=False,
    )
    lm = LMConfig(
        name="musicgen-medium",
        d_model=1536,
        vocab=2048,
        segments=(StackSegment(blk, 48),),
        tied_head=False,
        pos_embedding="sinusoidal",
    )
    return ArchDef(
        name="musicgen-medium",
        family="audio",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        source="arXiv:2306.05284; hf",
    )
