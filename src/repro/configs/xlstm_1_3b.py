"""xlstm-1.3b [ssm]: 48 blocks d=2048, 4 heads, d_ff=0 (mixer-internal
projections), vocab=50304, xLSTM[7:1] — 7 mLSTM blocks per 1 sLSTM block.
[arXiv:2405.04517; unverified]

Recurrent state is O(1) per layer — long_500k runs.  Adaptation noted in
DESIGN.md: mLSTM input gate is sigmoid-stabilized (the paper's exponential
gate + stabilizer is kept for sLSTM, where it is exact)."""

from repro.configs.common import ArchDef, shrink_lm, standard_shapes
from repro.models.blocks import BlockCfg
from repro.models.lm import LMConfig, StackSegment

D = 2048


def arch() -> ArchDef:
    mlstm = BlockCfg(kind="mlstm", d_model=D, ssm_heads=4, expand=2)
    slstm = BlockCfg(kind="slstm", d_model=D, ssm_heads=4)
    lm = LMConfig(
        name="xlstm-1.3b",
        d_model=D,
        vocab=50304,
        segments=(StackSegment(mlstm, 7), StackSegment(slstm, 1)),
        repeats=6,
        tied_head=True,
    )
    return ArchDef(
        name="xlstm-1.3b",
        family="ssm",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=True),
        source="arXiv:2405.04517; unverified",
    )
