"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256, tied embeddings, sqrt(d) embed scale.
[arXiv:2403.08295; hf]"""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment


def arch() -> ArchDef:
    blk = attn_block(
        d_model=2048, heads=8, kv_heads=1, head_dim=256, d_ff=16384,
        act="gelu", gated=True,
    )
    lm = LMConfig(
        name="gemma-2b",
        d_model=2048,
        vocab=256000,
        segments=(StackSegment(blk, 18),),
        tied_head=True,
        embed_scale=True,
    )
    return ArchDef(
        name="gemma-2b",
        family="dense",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        source="arXiv:2403.08295; hf",
    )
