"""h2o-danube-1.8b [dense]: 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000,
llama+mistral mix with sliding-window attention (window 4096).
[arXiv:2401.16818; hf]

SWA bounds the decode KV state to the window, so the long_500k cell runs
(ring-buffer cache of 4096 per layer)."""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment

WINDOW = 4096


def arch() -> ArchDef:
    blk = attn_block(
        d_model=2560, heads=32, kv_heads=8, d_ff=6912, window=WINDOW,
        act="silu", gated=True,
    )
    lm = LMConfig(
        name="h2o-danube-1.8b",
        d_model=2560,
        vocab=32000,
        segments=(StackSegment(blk, 24),),
        tied_head=False,
    )
    return ArchDef(
        name="h2o-danube-1.8b",
        family="dense",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=True),  # SWA: bounded state
        source="arXiv:2401.16818; hf",
    )
