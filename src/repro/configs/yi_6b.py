"""yi-6b [dense]: 32L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA, untied head, rope theta 5e6. [arXiv:2403.04652; hf]"""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment


def arch() -> ArchDef:
    blk = attn_block(
        d_model=4096, heads=32, kv_heads=4, d_ff=11008, rope_theta=5e6,
        act="silu", gated=True,
    )
    lm = LMConfig(
        name="yi-6b",
        d_model=4096,
        vocab=64000,
        segments=(StackSegment(blk, 32),),
        tied_head=False,
    )
    return ArchDef(
        name="yi-6b",
        family="dense",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        source="arXiv:2403.04652; hf",
    )
