"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1, dense/MoE layers interleaved 1:1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Adaptations noted in DESIGN.md §Arch-applicability: softmax top-1 router
(upstream uses sigmoid routing + shared expert); early-fusion multimodality
is out of scope for the LM shape grid."""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment
from repro.models.moe import MoECfg

D = 5120


def arch() -> ArchDef:
    dense_blk = attn_block(d_model=D, heads=40, kv_heads=8, d_ff=8192,
                           act="silu", gated=True)
    moe_blk = attn_block(
        d_model=D, heads=40, kv_heads=8, d_ff=0, act="silu", gated=True,
        moe=MoECfg(num_experts=128, top_k=1, d_model=D, d_ff=8192),
    )
    lm = LMConfig(
        name="llama4-maverick-400b-a17b",
        d_model=D,
        vocab=202048,
        segments=(StackSegment(dense_blk, 1), StackSegment(moe_blk, 1)),
        repeats=24,
        tied_head=False,
    )
    return ArchDef(
        name="llama4-maverick-400b-a17b",
        family="moe",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )
