"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (MHA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight lineage).
[hf:moonshotai/Moonlight-16B-A3B; hf]

Note: with the ASSIGNED 48 layers (upstream Moonlight has 27) the total
parameter count is ~28 B; activated-per-token stays ~3 B (top-6 of 64),
matching the a3b label.  The assignment's layer count takes precedence."""

from repro.configs.common import ArchDef, attn_block, shrink_lm, standard_shapes
from repro.models.lm import LMConfig, StackSegment
from repro.models.moe import MoECfg

D = 2048


def arch() -> ArchDef:
    blk = attn_block(
        d_model=D, heads=16, kv_heads=16, d_ff=0, act="silu", gated=True,
        moe=MoECfg(num_experts=64, top_k=6, d_model=D, d_ff=1408),
    )
    lm = LMConfig(
        name="moonshot-v1-16b-a3b",
        d_model=D,
        vocab=163840,
        segments=(StackSegment(blk, 48),),
        tied_head=False,
    )
    return ArchDef(
        name="moonshot-v1-16b-a3b",
        family="moe",
        lm=lm,
        smoke=shrink_lm(lm),
        shapes=standard_shapes(sub_quadratic=False),
        source="hf:moonshotai/Moonlight-16B-A3B; hf",
    )
