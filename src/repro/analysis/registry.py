"""Registry of Pallas kernel entry points for the contract checker.

Every registered spec knows how to TRACE its kernel (``jax.make_jaxpr``
over tiny placeholder planes — no execution, no compile) and where its
source lives for findings.  New kernels must be registered here: the
cleanliness test asserts the registry covers every ``pl.pallas_call`` in
``src/repro/kernels``, so an unregistered kernel is itself a finding.

Trace shapes are deliberately tiny (8 buckets x 8 slots): the contracts
checked (DMA pairing, memory spaces, masked stores) are shape-independent
structure, and small shapes keep ``python -m repro.analysis`` fast.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.predicates import KINDS

B, S, V, N = 8, 8, 8, 8   # buckets, slots/bucket, value width, queries
Q_TILE = 4                # pipeline-variant tile (must divide N)


def _planes():
    u32 = lambda: jnp.zeros((B, S), jnp.uint32)
    return {
        "digests": jnp.zeros((B, S), jnp.uint8),
        "key_hi": u32(), "key_lo": u32(),
        "score_hi": u32(), "score_lo": u32(),
        "values": jnp.zeros((B * S, V), jnp.float32),
    }


def _queries():
    z32 = lambda: jnp.zeros((N,), jnp.uint32)
    return {
        "bucket1": jnp.zeros((N,), jnp.int32),
        "bucket2": jnp.zeros((N,), jnp.int32),
        "qdigest": z32(), "qkey_hi": z32(), "qkey_lo": z32(),
    }


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str                 # registry id, e.g. "find_scan_tlp"
    path: str                 # repo-relative source file for findings
    build: Callable[[], jax.core.ClosedJaxpr]

    def trace(self) -> jax.core.ClosedJaxpr:
        return self.build()


def _trace(fn, *args, **kwargs):
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


def _spec_digest_tlp():
    from repro.kernels import digest_scan as m
    p, q = _planes(), _queries()
    return _trace(m.digest_scan_tlp, p["digests"], p["key_hi"], p["key_lo"],
                  q["bucket1"], q["qdigest"], q["qkey_hi"], q["qkey_lo"])


def _spec_digest_pipeline():
    from repro.kernels import digest_scan as m
    p, q = _planes(), _queries()
    return _trace(m.digest_scan_pipeline, p["digests"], p["key_hi"],
                  p["key_lo"], q["bucket1"], q["qdigest"], q["qkey_hi"],
                  q["qkey_lo"], q_tile=Q_TILE)


def _spec_find_tlp():
    from repro.kernels import find_scan as m
    p, q = _planes(), _queries()
    return _trace(m.find_scan_tlp, p["digests"], p["key_hi"], p["key_lo"],
                  p["score_hi"], p["score_lo"], p["values"],
                  q["bucket1"], q["bucket2"], q["qdigest"], q["qkey_hi"],
                  q["qkey_lo"])


def _spec_find_pipeline():
    from repro.kernels import find_scan as m
    p, q = _planes(), _queries()
    return _trace(m.find_scan_pipeline, p["digests"], p["key_hi"],
                  p["key_lo"], p["score_hi"], p["score_lo"], p["values"],
                  q["bucket1"], q["bucket2"], q["qdigest"], q["qkey_hi"],
                  q["qkey_lo"], q_tile=Q_TILE)


def _spec_upsert_probe():
    from repro.kernels import upsert_scan as m
    p, q = _planes(), _queries()
    return _trace(m.upsert_probe, p["digests"], p["key_hi"], p["key_lo"],
                  p["score_hi"], p["score_lo"], q["bucket1"], q["bucket2"],
                  q["qdigest"], q["qkey_hi"], q["qkey_lo"])


def _spec_claim_scan():
    from repro.kernels import upsert_scan as m
    p, q = _planes(), _queries()
    return _trace(m.claim_scan, p["key_hi"], p["key_lo"], p["score_hi"],
                  p["score_lo"], q["bucket1"], jnp.zeros((N,), jnp.int32))


def _spec_sweep(kind):
    from repro.kernels import sweep_scan as m
    p = _planes()
    op = jnp.zeros((), jnp.uint32)
    return _trace(m.sweep_match, p["key_hi"], p["key_lo"], p["score_hi"],
                  p["score_lo"], op, op, op, op, kind=kind)


def _spec_bucket_stats():
    from repro.kernels import score_scan as m
    p = _planes()
    return _trace(m.bucket_stats, p["key_hi"], p["key_lo"], p["score_hi"],
                  p["score_lo"], bucket_tile=B)


def _spec_update(variant):
    from repro.embedding.sparse_opt import SparseOptimizer
    from repro.kernels import update_scan as m
    p, q = _planes(), _queries()
    # rowwise_adagrad with dim = V-1: dim + 1 aux col == V, so the trace
    # exercises both the embedding and aux column paths of the in-kernel
    # optimizer apply against the standard V-wide placeholder plane
    opt = SparseOptimizer("rowwise_adagrad")
    dim = V - 1
    qvalid = jnp.ones((N,), jnp.int32)
    grads = jnp.zeros((N, dim), jnp.float32)
    fn = m.update_scan_tlp if variant == "tlp" else m.update_scan_pipeline
    kw = {} if variant == "tlp" else {"q_tile": Q_TILE}
    return _trace(fn, p["digests"], p["key_hi"], p["key_lo"], p["values"],
                  q["bucket1"], q["bucket2"], q["qdigest"], q["qkey_hi"],
                  q["qkey_lo"], qvalid, grads, opt=opt, dim=dim, **kw)


def _spec_gather():
    from repro.kernels import gather as m
    p = _planes()
    return _trace(m.gather_rows, p["values"], jnp.zeros((N,), jnp.int32),
                  jnp.zeros((N,), jnp.int32))


def _spec_scatter(add):
    from repro.kernels import scatter as m
    p = _planes()
    return _trace(m.scatter_rows, p["values"], jnp.zeros((N,), jnp.int32),
                  jnp.zeros((N, V), jnp.float32), jnp.zeros((N,), jnp.int32),
                  add=add)


def kernel_specs() -> Sequence[KernelSpec]:
    specs = [
        KernelSpec("digest_scan_tlp", "src/repro/kernels/digest_scan.py",
                   _spec_digest_tlp),
        KernelSpec("digest_scan_pipeline", "src/repro/kernels/digest_scan.py",
                   _spec_digest_pipeline),
        KernelSpec("find_scan_tlp", "src/repro/kernels/find_scan.py",
                   _spec_find_tlp),
        KernelSpec("find_scan_pipeline", "src/repro/kernels/find_scan.py",
                   _spec_find_pipeline),
        KernelSpec("upsert_probe", "src/repro/kernels/upsert_scan.py",
                   _spec_upsert_probe),
        KernelSpec("claim_scan", "src/repro/kernels/upsert_scan.py",
                   _spec_claim_scan),
        KernelSpec("bucket_stats", "src/repro/kernels/score_scan.py",
                   _spec_bucket_stats),
        KernelSpec("update_scan_tlp", "src/repro/kernels/update_scan.py",
                   lambda: _spec_update("tlp")),
        KernelSpec("update_scan_pipeline", "src/repro/kernels/update_scan.py",
                   lambda: _spec_update("pipeline")),
        KernelSpec("gather_rows", "src/repro/kernels/gather.py", _spec_gather),
        KernelSpec("scatter_rows", "src/repro/kernels/scatter.py",
                   lambda: _spec_scatter(False)),
        KernelSpec("scatter_rows_add", "src/repro/kernels/scatter.py",
                   lambda: _spec_scatter(True)),
    ]
    for kind in KINDS:
        specs.append(KernelSpec(
            f"sweep_match[{kind}]", "src/repro/kernels/sweep_scan.py",
            lambda k=kind: _spec_sweep(k)))
    return specs


def unregistered_kernel_files() -> list:
    """Kernel source files that call pallas_call but have no spec.

    The contract checker can only enforce what it traces; a kernel file
    missing from the registry silently escapes every rule, so the checker
    reports such files as findings.
    """
    import pathlib

    registered = {spec.path for spec in kernel_specs()}
    kernels_dir = pathlib.Path(__file__).resolve().parents[1] / "kernels"
    missing = []
    for p in sorted(kernels_dir.glob("*.py")):
        rel = f"src/repro/kernels/{p.name}"
        if "pallas_call" in p.read_text() and rel not in registered:
            missing.append(rel)
    return missing
