"""CLI for hkv-lint: ``python -m repro.analysis``.

Exit status is the number of unwaived findings (capped at 99), so CI can
gate on it directly.  ``--format github`` emits ``::error file=...``
workflow commands that surface as PR annotations; ``--format text`` (the
default) prints one line per finding plus a summary.
"""

from __future__ import annotations

import argparse
import sys

from repro import analysis


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="hkv-lint: static contract checks for the "
                    "HierarchicalKV repro")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="finding output format (github = workflow-command "
                         "annotations)")
    ap.add_argument("--checker", action="append", metavar="NAME",
                    choices=analysis.CHECKERS,
                    help="run only this checker (repeatable); default: all")
    args = ap.parse_args(argv)

    findings = analysis.run_all(only=args.checker)
    fmt = (analysis.format_github if args.format == "github"
           else analysis.format_text)
    out = fmt(findings)
    if out:
        print(out)
    fatal = analysis.unwaived(findings)
    return min(len(fatal), 99)


if __name__ == "__main__":
    sys.exit(main())
