"""hkv-lint: static contract checking for the HierarchicalKV repro.

Five checkers, one findings model:

  kernel-contracts   trace every registered Pallas kernel in interpret
                     mode and walk the jaxpr for DMA start/wait pairing,
                     memory-space legality (plus the §3.6 hmem tier seam),
                     and mask-dominated stores.
  compile-cache      drive public handle ops across predicate kinds, key
                     forms, and backends, asserting exactly one compile
                     per static signature.
  roles              the §3.5 triple-group taxonomy — every op annotated
                     reader/updater/inserter, session records match the
                     annotations, and ``_plan()`` fences/fuses correctly.
  oracle-coupling    one key-match formula (``core.find.match_lanes``) and
                     one liveness formula (``core.u64.empty_lanes``),
                     referenced from every kernel stage; inline hi/lo
                     re-derivations are findings.
  telemetry          every ``@roles.*``-annotated op threads the optional
                     ``telemetry=`` device-counter channel or carries a
                     reviewed exemption (``analysis.telemetry
                     .TELEMETRY_EXEMPT``) — the observability surface
                     stays complete by construction.

Run with ``python -m repro.analysis`` (add ``--format github`` in CI).
"""

from __future__ import annotations

from repro.analysis.findings import (Finding, WAIVERS, apply_waivers,
                                     format_github, format_text, unwaived)

__all__ = ["Finding", "WAIVERS", "apply_waivers", "format_github",
           "format_text", "unwaived", "run_all", "CHECKERS"]


def _checkers():
    # imports deferred: each checker pulls in jax tracing machinery
    from repro.analysis.compile_cache import check_compile_cache
    from repro.analysis.kernel_contracts import check_hmem_seam, check_kernels
    from repro.analysis.oracle_coupling import check_oracle_coupling
    from repro.analysis.roles import check_roles
    from repro.analysis.telemetry import check_telemetry
    return {
        "kernel-contracts": lambda: check_kernels() + check_hmem_seam(),
        "compile-cache": check_compile_cache,
        "roles": check_roles,
        "oracle-coupling": check_oracle_coupling,
        "telemetry": check_telemetry,
    }


CHECKERS = ("kernel-contracts", "compile-cache", "roles", "oracle-coupling",
            "telemetry")


def run_all(only=None) -> list:
    """Run checkers (all, or the named subset) and apply waivers."""
    table = _checkers()
    names = list(only) if only else list(CHECKERS)
    findings = []
    for name in names:
        if name not in table:
            raise SystemExit(f"unknown checker {name!r}; "
                             f"choose from {', '.join(CHECKERS)}")
        findings.extend(table[name]())
    return apply_waivers(findings, WAIVERS)
