"""Checker 3: role/commutativity lint — the §3.5 triple-group taxonomy.

Four layers are cross-checked:

  unannotated-op     every public op entry point in ``repro.core.ops``
                     (module-level function whose first parameter is
                     ``state``) must carry a ``@roles.reader`` /
                     ``@roles.updater`` / ``@roles.inserter`` annotation —
                     a new op without a declared commutativity class is a
                     finding, because the session planner would otherwise
                     guess its fencing behaviour.
  role-mismatch      every op the ``OpSession`` records must record it
                     under the SAME role its ``core.ops`` counterpart is
                     annotated with (the session's fusion/fencing decisions
                     key off the recorded role).
  plan-taxonomy      ``session._plan()`` must obey the taxonomy on a probe
                     sequence: commuting reader/updater runs on one key
                     batch share a single locate; every inserter is a
                     singleton serialization group; and a reader AFTER an
                     inserter must issue a fresh locate (cached positions
                     died at the fence).
  engine-purity      the serving engine's admission path must respect the
                     taxonomy end-to-end: waves under
                     ``miss_policy='readonly', promote=False`` are PURE
                     READERS in BOTH admission modes (wave-granular and
                     continuous splice) — no successor handle may be
                     offered back to the source, and the engine's static
                     ``_mutates`` flag must say so; conversely an
                     ``admit`` engine that does not flag itself mutating
                     would silently drop its admissions.
"""

from __future__ import annotations

import inspect

import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding
from repro.core import api as api_mod
from repro.core import ops as ops_mod
from repro.core import roles as roles_mod

CHECKER = "roles"
_OPS_PATH = "src/repro/core/ops.py"
_API_PATH = "src/repro/core/api.py"

# session-only composite ops (no core.ops counterpart) and their roles.
# Empty since update_rows became a first-class @roles.updater op in
# core.ops (the fused gradient step) — kept as the registration point for
# any future session-only composite.
_SESSION_ONLY: dict = {}


def public_ops(module=ops_mod) -> dict:
    """Name -> function for every op entry point in the ops module."""
    out = {}
    for name, fn in vars(module).items():
        if name.startswith("_") or not inspect.isfunction(fn):
            continue
        if getattr(fn, "__module__", None) != module.__name__:
            continue
        params = list(inspect.signature(fn).parameters)
        if params and params[0] == "state":
            out[name] = fn
    return out


def check_annotations(module=ops_mod, path: str = _OPS_PATH) -> list[Finding]:
    out = []
    for name, fn in sorted(public_ops(module).items()):
        r = roles_mod.role_of(fn)
        line = None
        try:
            line = inspect.getsourcelines(fn)[1]
        except OSError:  # pragma: no cover
            pass
        if r is None:
            out.append(Finding(
                CHECKER, "unannotated-op", name,
                "public op entry point has no @roles.reader/updater/"
                "inserter annotation — declare its §3.5 commutativity "
                "class so the session planner can fence it correctly",
                path=path, line=line))
        elif r not in roles_mod.ROLES:  # pragma: no cover - role() validates
            out.append(Finding(CHECKER, "unknown-role", name,
                               f"annotation {r!r} is not one of "
                               f"{roles_mod.ROLES}", path=path,
                               line=line))
    return out


def _probe_session():
    t = api_mod.HKVTable.create(capacity=64, dim=4, slots_per_bucket=8)
    s = t.session()
    keys = api_mod.normalize_keys([1, 2, 3, 4])
    vals = jnp.zeros((4, 4), jnp.float32)
    # one of each recordable kind (keeps the recorded-role census complete)
    s.find(keys)
    s.find_rows(keys)
    s.contains(keys)
    s.assign(keys, vals)
    s.assign_add(keys, vals)
    s.assign_scores(keys, [5, 6, 7, 8])
    s.update_rows(keys, lambda rows: rows)
    s.insert_or_assign(keys, vals)
    s.find_or_insert(keys, vals)
    s.insert_and_evict(keys, vals)
    s.erase(keys)
    return s


def check_session_roles() -> list[Finding]:
    out = []
    ops = public_ops()
    s = _probe_session()
    for op in s._ops:
        if op.kind in _SESSION_ONLY:
            want = _SESSION_ONLY[op.kind]
            if op.role != want:
                out.append(Finding(
                    CHECKER, "role-mismatch", op.kind,
                    f"session records composite op as {op.role!r}; its "
                    f"declared class is {want!r}", path=_API_PATH))
            continue
        fn = ops.get(op.kind)
        if fn is None:
            out.append(Finding(
                CHECKER, "unknown-session-op", op.kind,
                "session records an op with no core.ops counterpart and "
                "no session-only registration", path=_API_PATH))
            continue
        want = roles_mod.role_of(fn)
        if want is not None and op.role != want:
            out.append(Finding(
                CHECKER, "role-mismatch", op.kind,
                f"session records role {op.role!r} but core.ops.{op.kind} "
                f"is annotated @roles.{want} — the planner would "
                f"{'skip a required fence' if want == roles_mod.INSERTER else 'fence needlessly'}",
                path=_API_PATH))
    return out


def check_plan_taxonomy() -> list[Finding]:
    out = []
    t = api_mod.HKVTable.create(capacity=64, dim=4, slots_per_bucket=8)
    k1 = api_mod.normalize_keys([1, 2, 3, 4])
    k2 = api_mod.normalize_keys([9, 10, 11, 12])
    vals = jnp.zeros((4, 4), jnp.float32)
    s = t.session()
    s.find(k1)                      # issues locate[k1]
    s.assign(k1, vals)              # must SHARE locate[k1]
    s.find(k2)                      # distinct batch: own locate
    s.insert_or_assign(k1, vals)    # serialization point
    s.find(k1)                      # must RE-issue: cache died at fence
    groups = s._plan()

    def finding(rule, msg):
        out.append(Finding(CHECKER, rule, "OpSession._plan", msg,
                           path=_API_PATH))

    if len(groups) != 3:
        finding("plan-shape",
                f"probe sequence should plan 3 groups "
                f"(commuting run | inserter | trailing reader), got "
                f"{len(groups)}")
        return out
    pre, ins, post = groups
    if [op.kind for op in pre] != ["find", "assign", "find"]:
        finding("plan-shape", f"first commuting group is "
                f"{[op.kind for op in pre]}, expected [find, assign, find]")
    if not (len(ins) == 1 and ins[0].role == roles_mod.INSERTER):
        finding("inserter-not-serialized",
                "inserter did not form a singleton serialization group")
    if len(pre) == 3:
        if pre[0].shares_locate:
            finding("locate-sharing", "first reader on a key batch must "
                    "issue (not share) its locate")
        if not pre[1].shares_locate:
            finding("locate-sharing", "updater on an already-probed key "
                    "batch must share the reader's locate (§3.5 commuting "
                    "rule)")
        if pre[2].shares_locate:
            finding("locate-sharing", "reader on a NEW key batch must "
                    "issue its own locate")
    if post and post[0].shares_locate:
        finding("stale-locate",
                "reader after an inserter shares a pre-fence locate — "
                "structural ops invalidate cached positions (§3.5)")
    return out


_ENGINE_PATH = "src/repro/serving/embedding_engine.py"


def check_engine_purity() -> list[Finding]:
    """Dynamic probe of the serving engine's admission path: a tiny
    tiered table with cold-resident keys (so a forbidden promotion WOULD
    be observable) is served under every admission mode.  Readonly
    non-promoting waves must leave the source untouched; admit waves
    must declare themselves mutating."""
    from repro.core.tiered import TieredHKVTable
    from repro.serving.embedding_engine import (EmbeddingRequest,
                                                OnlineEmbeddingEngine)

    out = []
    keys = np.arange(1, 13, dtype=np.uint64)

    def cold_resident():
        t = TieredHKVTable.create(hot_capacity=64, cold_capacity=128, dim=4,
                                  slots_per_bucket=8)
        r = t.cold.insert_or_assign(
            keys, jnp.ones((len(keys), 4), jnp.float32),
            custom_scores=np.arange(1, len(keys) + 1, dtype=np.uint64))
        return t.with_tiers(t.hot, r.table)

    for admission in ("wave", "continuous"):
        t = cold_resident()
        eng = OnlineEmbeddingEngine(t, wave_size=8, miss_policy="readonly",
                                    promote=False, admission=admission)
        eng.submit(EmbeddingRequest(rid=0, keys=keys))   # spans two waves
        eng.run_until_drained()
        if eng._mutates:
            out.append(Finding(
                CHECKER, "engine-impure-reader",
                f"OnlineEmbeddingEngine[{admission}]",
                "readonly+promote=False waves are flagged mutating — the "
                "pure-reader contract (no offer per wave) is broken",
                path=_ENGINE_PATH))
        if eng.source.table is not t:
            out.append(Finding(
                CHECKER, "engine-impure-reader",
                f"OnlineEmbeddingEngine[{admission}]",
                "readonly+promote=False admission installed a successor "
                "handle — the wave was not a pure reader",
                path=_ENGINE_PATH))
        if bool(np.asarray(eng.source.table.hot.contains(keys)).any()):
            out.append(Finding(
                CHECKER, "engine-impure-reader",
                f"OnlineEmbeddingEngine[{admission}]",
                "readonly+promote=False waves promoted cold hits into the "
                "hot tier (structural motion on a pure-reader path)",
                path=_ENGINE_PATH))
    # census completeness: the admit policy must flag itself mutating or
    # its admissions would never be offered back to the source
    eng = OnlineEmbeddingEngine(cold_resident(), wave_size=8,
                                miss_policy="admit")
    eng.submit(EmbeddingRequest(rid=0, keys=keys[:8]))
    eng.run_until_drained()
    if not eng._mutates:
        out.append(Finding(
            CHECKER, "engine-unflagged-mutator", "OnlineEmbeddingEngine",
            "admit-policy waves are not flagged mutating — admission "
            "successors would be dropped instead of offered",
            path=_ENGINE_PATH))
    return out


def check_roles() -> list[Finding]:
    return (check_annotations() + check_session_roles()
            + check_plan_taxonomy() + check_engine_purity())
