"""Finding model + waivers + output formats for hkv-lint.

A Finding is one contract violation located as precisely as the checker
can manage (repo-relative path + line where available, else the subject
name).  Findings are data; policy (exit code, display) lives in the CLI.

Waivers are IN-CODE and carry a rationale: a checker that cannot be
satisfied for a legitimate reason gets an entry in ``WAIVERS`` below with
the reason spelled out, and the finding is reported as waived (shown, but
not fatal).  An empty waiver list is the healthy state.
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Iterable, Optional

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass
class Finding:
    checker: str                     # kernel-contracts | compile-cache | roles | oracle-coupling
    rule: str                        # short machine id, e.g. "dma-unpaired"
    subject: str                     # kernel/op/file the finding is about
    message: str                     # human explanation incl. the contract
    path: Optional[str] = None       # repo-relative file
    line: Optional[int] = None       # 1-indexed
    severity: str = ERROR
    waived: bool = False
    waiver_reason: Optional[str] = None

    def location(self) -> str:
        if self.path and self.line:
            return f"{self.path}:{self.line}"
        return self.path or self.subject


@dataclasses.dataclass(frozen=True)
class Waiver:
    """An explicit, justified exemption.  `subject` may be a glob."""

    checker: str
    rule: str
    subject: str
    reason: str

    def matches(self, f: Finding) -> bool:
        return (self.checker == f.checker and self.rule == f.rule
                and fnmatch.fnmatch(f.subject, self.subject))


# The shipped tree is clean: no waivers.  To waive a finding, add
#   Waiver("<checker>", "<rule>", "<subject-glob>", "why this is OK"),
# here — the reason is rendered next to the finding in every report.
WAIVERS: tuple[Waiver, ...] = ()


def apply_waivers(findings: Iterable[Finding],
                  waivers: Iterable[Waiver] = WAIVERS) -> list[Finding]:
    out = []
    waivers = list(waivers)
    for f in findings:
        for w in waivers:
            if w.matches(f):
                f = dataclasses.replace(f, waived=True, waiver_reason=w.reason)
                break
        out.append(f)
    return out


def unwaived(findings: Iterable[Finding]) -> list[Finding]:
    return [f for f in findings if not f.waived and f.severity == ERROR]


def format_text(findings: list[Finding]) -> str:
    """One line per finding + a summary line (always present)."""
    lines = []
    for f in findings:
        tag = f"[{f.checker}/{f.rule}]"
        waive = f" (WAIVED: {f.waiver_reason})" if f.waived else ""
        lines.append(f"{f.location()}: {f.severity}: {tag} {f.subject}: "
                     f"{f.message}{waive}")
    fatal = len(unwaived(findings))
    waived_n = sum(1 for f in findings if f.waived)
    lines.append(f"hkv-lint: {len(findings)} finding(s), {fatal} fatal, "
                 f"{waived_n} waived")
    return "\n".join(lines)


def format_github(findings: list[Finding]) -> str:
    """GitHub Actions workflow-command annotations (::error/::warning)."""
    lines = []
    for f in findings:
        level = "warning" if (f.waived or f.severity == WARNING) else "error"
        loc = ""
        if f.path:
            loc = f" file={f.path}"
            if f.line:
                loc += f",line={f.line}"
        title = f"{f.checker}/{f.rule}"
        msg = f.message
        if f.waived:
            msg += f" (waived: {f.waiver_reason})"
        # workflow commands terminate at newline; escape per the spec
        msg = (msg.replace("%", "%25").replace("\r", "%0D")
                  .replace("\n", "%0A"))
        lines.append(f"::{level}{loc},title={title}::{f.subject}: {msg}")
    return "\n".join(lines)
