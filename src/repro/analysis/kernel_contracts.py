"""Checker 1: kernel jaxpr contracts (DMA pairing, memory spaces, masked stores).

Every registered kernel entry point (``repro.analysis.registry``) is traced
with ``jax.make_jaxpr`` — tiny shapes, no execution — and the Pallas kernel
body jaxpr is walked to enforce three contracts from DESIGN.md:

  dma-unpaired      every ``dma_start`` must have a matching ``dma_wait`` on
                    the same semaphore (and index pattern), and vice versa —
                    the §4.3 pipeline kernels' double-buffer discipline.  A
                    started-but-never-awaited copy reads garbage on hardware
                    (interpret mode hides it, which is why this is a static
                    check).
  memory-space      refs declared in ANY/HBM space may ONLY be touched by
                    async copies (``dma_start``/``dma_wait``); a direct
                    ``get``/``swap`` on an HBM ref compiles in interpret
                    mode but is illegal on TPU.  Semaphore refs may only
                    feed DMA/semaphore primitives.
  unmasked-store    every store into a float-dtype output ref or an
                    input/output-aliased ref must trace back to a
                    ``select_n`` (a ``jnp.where``-family mask select) — the
                    PR 1 ``scatter_rows`` stale-write bug class: an
                    unconditional lane store clobbers EMPTY slots or
                    masked-out rows.

Plus the §3.6 tier seam (``check_hmem_seam``): with ``value_tier='hmem'``
the host-resident value plane must never appear as a ``pallas_call``
operand — only row-granular gathers (``tier_gather``) may cross the
host/device boundary.
"""

from __future__ import annotations

from typing import Iterable, Optional

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.analysis import registry as registry_mod

Literal = jax.core.Literal

CHECKER = "kernel-contracts"

# primitives that pass a value through unchanged (for provenance walks)
_PASS = {"convert_element_type", "broadcast_in_dim", "reshape", "squeeze",
         "expand_dims", "copy", "slice", "transpose", "rev", "dynamic_slice"}
# call-like primitives whose params embed exactly one ClosedJaxpr under "jaxpr"
_CALLS = {"pjit", "closed_call", "core_call", "custom_jvp_call",
          "custom_vjp_call", "remat", "checkpoint"}
_DMA = {"dma_start", "dma_wait"}
_SEM_OK = _DMA | {"semaphore_signal", "semaphore_wait", "get_barrier_semaphore"}


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------


def _subjaxprs(eqn):
    """All raw Jaxpr objects embedded in an eqn's params."""
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for item in items:
            inner = getattr(item, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner           # ClosedJaxpr
            elif hasattr(item, "eqns"):
                yield item            # raw Jaxpr


def iter_pallas_calls(jaxpr):
    """Yield every pallas_call eqn reachable from a (Closed)Jaxpr."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        else:
            for sub in _subjaxprs(eqn):
                yield from iter_pallas_calls(sub)


def _space(var) -> Optional[str]:
    """Memory space of a ref var ('any', 'smem', 'vmem', 'semaphore_mem',
    'None' for blocked default), or None for non-ref values."""
    s = str(getattr(var, "aval", ""))
    if "MemRef<" not in s:
        return None
    return s.split("MemRef<", 1)[1].split(">", 1)[0].split("(")[0]


def _dtype(var):
    return getattr(getattr(var, "aval", None), "dtype", None)


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    return getattr(info, "name", None) or str(info) or "<pallas>"


def _body_and_layout(eqn):
    """(body jaxpr, guarded output arg positions, semaphore-legal set)."""
    body = eqn.params["jaxpr"]
    gm = eqn.params["grid_mapping"]
    n_idx = gm.num_index_operands
    n_in = gm.num_inputs
    n_out = gm.num_outputs
    out_base = n_idx + n_in
    guarded = set()
    for k in range(n_out):
        dt = _dtype(body.invars[out_base + k])
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            guarded.add(out_base + k)
    for _, out_idx in (eqn.params.get("input_output_aliases") or ()):
        guarded.add(out_base + out_idx)
    return body, guarded


# ---------------------------------------------------------------------------
# ref-origin walk: find DMA events + direct ref accesses across sub-jaxprs
# ---------------------------------------------------------------------------


def _map_inner_invars(eqn, inner, env):
    """Best-effort origin mapping from an eqn's operands to a sub-jaxpr's
    invars (pjit: 1:1; cond: invars[1:]; scan/while: positional over the
    const/carry prefix, where the refs live)."""
    name = eqn.primitive.name
    outer = list(eqn.invars)
    if name == "cond":
        outer = outer[1:]
    elif name == "while":
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        if inner is getattr(eqn.params.get("cond_jaxpr"), "jaxpr", None):
            outer = outer[:cn] + outer[cn + bn:]
        else:
            outer = outer[cn:]
    inner_env = {}
    for iv, ov in zip(inner.invars, outer):
        if not isinstance(ov, Literal):
            org = env.get(id(ov))
            if org is not None:
                inner_env[id(iv)] = org
    return inner_env


def _walk_refs(body, events):
    """Collect (prim_name, eqn, [origin-per-invar]) for ref-touching eqns.

    Origins are ('arg', i) for kernel invars, propagated through nested
    call/control-flow jaxprs; None for values produced inside the body.
    """
    env = {id(v): ("arg", i) for i, v in enumerate(body.invars)}

    def walk(jaxpr, env):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in _DMA or name in ("get", "swap", "addupdate",
                                        "masked_swap", "semaphore_signal",
                                        "semaphore_wait"):
                origins = [None if isinstance(v, Literal) else env.get(id(v))
                           for v in eqn.invars]
                events.append((name, eqn, origins))
            for sub in _subjaxprs(eqn):
                inner = getattr(sub, "jaxpr", sub)
                walk(inner, _map_inner_invars(eqn, inner, env))

    walk(body, env)
    return events


def _dma_signature(eqn, origins):
    """(sem origin, index pattern) identifying one DMA start/wait site.

    The semaphore ref is the invar in semaphore space; the pattern is the
    trailing operands after it — literal ints stay literal, data-dependent
    indices widen to '*' (matches anything)."""
    sem_pos = None
    for k, v in enumerate(eqn.invars):
        if _space(v) == "semaphore_mem":
            sem_pos = k
    if sem_pos is None:
        return None
    sem_origin = origins[sem_pos]
    pat = tuple(
        int(v.val) if isinstance(v, Literal) else "*"
        for v in eqn.invars[sem_pos + 1:]
    )
    return (sem_origin, pat)


def _patterns_unify(a, b):
    for x, y in zip(a, b):
        if x != "*" and y != "*" and x != y:
            return False
    return True


# ---------------------------------------------------------------------------
# per-kernel checks
# ---------------------------------------------------------------------------


def _check_dma_pairing(name, path, events):
    starts = [(eqn, _dma_signature(eqn, org)) for p, eqn, org in events
              if p == "dma_start"]
    waits = [(eqn, _dma_signature(eqn, org)) for p, eqn, org in events
             if p == "dma_wait"]
    out = []

    def unmatched(mine, others, rule, what, needs):
        for eqn, sig in mine:
            if sig is None:
                continue
            ok = any(o is not None and o[0] == sig[0]
                     and _patterns_unify(o[1], sig[1])
                     for _, o in others)
            if not ok:
                out.append(Finding(
                    CHECKER, rule, name,
                    f"{what} on semaphore {sig[0]} (index pattern "
                    f"{sig[1]}) has no matching {needs} anywhere in the "
                    f"kernel body — the async copy is never "
                    f"{'retired' if needs == 'dma_wait' else 'issued'}",
                    path=path))
    unmatched(starts, waits, "dma-unpaired", "dma_start", "dma_wait")
    unmatched(waits, starts, "dma-wait-unstarted", "dma_wait", "dma_start")
    return out


def _check_memory_spaces(name, path, body, events):
    out = []
    for prim, eqn, origins in events:
        for k, v in enumerate(eqn.invars):
            sp = _space(v)
            if sp == "any" and prim not in _DMA:
                out.append(Finding(
                    CHECKER, "memory-space", name,
                    f"direct {prim} on an ANY/HBM-space ref (arg "
                    f"{origins[k]}) — HBM planes may only move via "
                    f"dma_start/dma_wait (make_async_copy)",
                    path=path))
            if sp == "semaphore_mem" and prim not in _SEM_OK:
                out.append(Finding(
                    CHECKER, "memory-space", name,
                    f"{prim} on a DMA semaphore ref (arg {origins[k]}) — "
                    f"semaphores may only feed DMA/semaphore primitives",
                    path=path))
    return out


def _producers(jaxpr):
    d = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            d[id(ov)] = eqn
    return d


def _traces_to_select(var, jaxpr, bindings, depth=0):
    """True iff `var` provably flows from a select_n through pass-through
    prims, call boundaries (both directions), scan carries, and cond
    branches (all branches must be masked)."""
    if depth > 64:
        return False
    while True:
        if isinstance(var, Literal):
            return False
        prods = _producers(jaxpr)
        eqn = prods.get(id(var))
        if eqn is None:
            b = bindings.get(id(var))
            if b is None:
                return False          # scope invar (a raw ref/operand)
            jaxpr, var, bindings = b
            continue
        name = eqn.primitive.name
        if name == "select_n":
            return True
        if name in _PASS:
            var = eqn.invars[0]
            continue
        if name in _CALLS:
            inner = eqn.params["jaxpr"]
            inner = getattr(inner, "jaxpr", inner)
            k = [id(o) for o in eqn.outvars].index(id(var))
            inner_bind = dict(bindings)
            for iv, ov in zip(inner.invars, eqn.invars):
                inner_bind[id(iv)] = (jaxpr, ov, bindings)
            jaxpr, var, bindings = inner, inner.outvars[k], inner_bind
            continue
        if name == "scan":
            inner = eqn.params["jaxpr"]
            inner = getattr(inner, "jaxpr", inner)
            k = [id(o) for o in eqn.outvars].index(id(var))
            inner_bind = dict(bindings)
            for iv, ov in zip(inner.invars, eqn.invars):
                inner_bind[id(iv)] = (jaxpr, ov, bindings)
            jaxpr, var, bindings = inner, inner.outvars[k], inner_bind
            continue
        if name == "cond":
            k = [id(o) for o in eqn.outvars].index(id(var))
            branches = eqn.params["branches"]
            for br in branches:
                inner = getattr(br, "jaxpr", br)
                inner_bind = dict(bindings)
                for iv, ov in zip(inner.invars, eqn.invars[1:]):
                    inner_bind[id(iv)] = (jaxpr, ov, bindings)
                if not _traces_to_select(inner.outvars[k], inner, inner_bind,
                                         depth + 1):
                    return False
            return True
        return False


def _check_masked_stores(name, path, body, guarded):
    """Every swap into a guarded ref must store a select_n-derived value."""
    out = []

    def walk(jaxpr, env, bindings):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in ("swap", "masked_swap"):
                ref = eqn.invars[0]
                org = env.get(id(ref))
                if org is not None and org[0] == "arg" and org[1] in guarded:
                    val = eqn.invars[1]
                    if not _traces_to_select(val, jaxpr, bindings):
                        out.append(Finding(
                            CHECKER, "unmasked-store", name,
                            f"store into guarded ref arg{org[1]} (float "
                            f"output / aliased plane) does not derive from "
                            f"a select_n mask — unconditional lane stores "
                            f"clobber EMPTY slots or masked-out rows "
                            f"(the scatter_rows stale-write class)",
                            path=path))
            for sub in _subjaxprs(eqn):
                inner = getattr(sub, "jaxpr", sub)
                inner_env = _map_inner_invars(eqn, inner, env)
                inner_bind = dict(bindings)
                for iv, ov in zip(
                        inner.invars,
                        eqn.invars[1:] if eqn.primitive.name == "cond"
                        else eqn.invars):
                    if not isinstance(ov, Literal):
                        inner_bind[id(iv)] = (jaxpr, ov, bindings)
                walk(inner, inner_env, inner_bind)

    env = {id(v): ("arg", i) for i, v in enumerate(body.invars)}
    walk(body, env, {})
    return out


def check_traced_kernel(name, path, closed_jaxpr) -> list[Finding]:
    """All three jaxpr contracts over every pallas_call in a trace."""
    out = []
    calls = list(iter_pallas_calls(closed_jaxpr))
    if not calls:
        out.append(Finding(CHECKER, "no-pallas-call", name,
                           "registered kernel entry traced to zero "
                           "pallas_call eqns — registry builder is stale",
                           path=path))
    for eqn in calls:
        body, guarded = _body_and_layout(eqn)
        events = _walk_refs(body, [])
        kname = f"{name}:{_kernel_name(eqn)}"
        out += _check_dma_pairing(kname, path, events)
        out += _check_memory_spaces(kname, path, body, events)
        out += _check_masked_stores(kname, path, body, guarded)
    return out


def check_kernels(specs=None) -> list[Finding]:
    out = []
    for spec in (specs if specs is not None else registry_mod.kernel_specs()):
        try:
            cj = spec.trace()
        except Exception as e:  # a broken registry builder is itself fatal
            out.append(Finding(CHECKER, "trace-failed", spec.name,
                               f"tracing raised {type(e).__name__}: {e}",
                               path=spec.path))
            continue
        out += check_traced_kernel(spec.name, spec.path, cj)
    if specs is None:
        for rel in registry_mod.unregistered_kernel_files():
            out.append(Finding(
                CHECKER, "unregistered-kernel", rel,
                "file calls pallas_call but has no KernelSpec in "
                "analysis/registry.py — its kernels escape every contract "
                "rule; register a trace builder for it",
                path=rel))
    return out


# ---------------------------------------------------------------------------
# §3.6 tier seam: hmem value plane must never feed a pallas_call
# ---------------------------------------------------------------------------

# prims through which hmem-plane taint propagates (pure reshaping of the
# whole plane); a gather/take breaks taint by design — that IS tier_gather's
# row-granular crossing
_TAINT_PASS = _PASS | {"stop_gradient"}


def _taint_reaches_pallas(jaxpr, tainted, where):
    hits = []

    def walk(jaxpr, tainted):
        for eqn in jaxpr.eqns:
            tin = [v for v in eqn.invars
                   if not isinstance(v, Literal) and id(v) in tainted]
            if not tin:
                continue
            name = eqn.primitive.name
            if name == "pallas_call":
                hits.append(Finding(
                    CHECKER, "hmem-seam", where,
                    "the hmem (host-tier) value plane flows into a "
                    "pallas_call operand — §3.6 requires host values to "
                    "cross only via row-granular tier_gather/tier_scatter, "
                    "never as whole-plane kernel operands",
                    path="src/repro/kernels/ops.py"))
            elif name in _CALLS or name in ("scan", "while", "cond"):
                for sub in _subjaxprs(eqn):
                    inner = getattr(sub, "jaxpr", sub)
                    outer = (eqn.invars[1:] if name == "cond"
                             else eqn.invars)
                    inner_t = {id(iv) for iv, ov in zip(inner.invars, outer)
                               if not isinstance(ov, Literal)
                               and id(ov) in tainted}
                    walk(inner, inner_t)
            elif name in _TAINT_PASS:
                for ov in eqn.outvars:
                    tainted.add(id(ov))

    walk(jaxpr, set(tainted))
    return hits


def check_hmem_seam() -> list[Finding]:
    from repro.core import ops as ops_mod
    from repro.core import table as table_mod
    from repro.core.table import HKVConfig
    from repro.core.u64 import U64

    cfg = HKVConfig(capacity=64, dim=4, slots_per_bucket=8,
                    value_tier="hmem")
    state = table_mod.create(cfg)
    n = 4
    kh = jnp.zeros((n,), jnp.uint32)
    kl = jnp.zeros((n,), jnp.uint32)
    vals = jnp.zeros((n, 4), jnp.float32)

    cases = {
        "find[hmem,kernel]": lambda s, h, l, v: ops_mod.find(
            s, cfg, U64(h, l), backend="kernel").values,
        "insert_or_assign[hmem,kernel]": lambda s, h, l, v:
            ops_mod.insert_or_assign(s, cfg, U64(h, l), v,
                                     backend="kernel").state,
        "erase_if[hmem,kernel]": lambda s, h, l, v: ops_mod.erase_if(
            s, cfg, _always(), backend="kernel").state,
        "update_rows[hmem,kernel]": lambda s, h, l, v:
            ops_mod.update_rows(s, cfg, U64(h, l), v, _sgd(),
                                backend="kernel").state,
    }
    out = []
    for label, f in cases.items():
        cj = jax.make_jaxpr(f)(state, kh, kl, vals)
        leaves = jax.tree_util.tree_leaves(state)
        vidx = next(i for i, leaf in enumerate(leaves)
                    if leaf is state.values)
        tainted = {id(cj.jaxpr.invars[vidx])}
        out += _taint_reaches_pallas(cj.jaxpr, tainted, label)
    return out


def _always():
    from repro.core.predicates import SweepPredicate
    return SweepPredicate.always()


def _sgd():
    from repro.embedding.sparse_opt import SparseOptimizer
    return SparseOptimizer("sgd")
