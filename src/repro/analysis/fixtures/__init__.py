"""Known-bad fixtures for hkv-lint's own test suite.

Each module here violates exactly one contract the analyzer enforces.
They are NEVER imported by shipped code — only by ``tests/test_analysis.py``
(and by the analyzer when explicitly pointed at them) to prove each checker
actually fires.  The oracle-coupling tree scan excludes this directory.
"""
