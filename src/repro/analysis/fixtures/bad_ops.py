"""Fixture: an op entry point with no declared §3.5 role.

``roles.check_annotations`` pointed at this module must flag
``mystery_op`` with rule ``unannotated-op`` (and must NOT flag
``annotated_op``).
"""

from __future__ import annotations

from repro.core import roles


def mystery_op(state, cfg, keys):
    """BUG: no @roles.* annotation — commutativity class undeclared."""
    return state


@roles.reader
def annotated_op(state, cfg, keys):
    """Correctly annotated control case."""
    return keys


def _private_helper(state, cfg):
    """Underscore-prefixed: out of scope for the lint."""
    return state


def free_function(cfg, keys):
    """No leading ``state`` param: not an op entry point."""
    return keys
