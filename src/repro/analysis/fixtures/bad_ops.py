"""Fixture: an op entry point with no declared §3.5 role.

``roles.check_annotations`` pointed at this module must flag
``mystery_op`` with rule ``unannotated-op`` (and must NOT flag
``annotated_op``).
"""

from __future__ import annotations

from repro.core import roles


def mystery_op(state, cfg, keys):
    """BUG: no @roles.* annotation — commutativity class undeclared."""
    return state


@roles.reader
def annotated_op(state, cfg, keys):
    """Correctly annotated control case — but BUG for the telemetry
    lint: annotated without a ``telemetry=`` seam or exemption."""
    return keys


@roles.reader
def telemetered_op(state, cfg, keys, *, telemetry=None):
    """Threads the telemetry seam — the telemetry lint's control case."""
    return keys


def _private_helper(state, cfg):
    """Underscore-prefixed: out of scope for the lint."""
    return state


def free_function(cfg, keys):
    """No leading ``state`` param: not an op entry point."""
    return keys
