"""Deliberately broken Pallas kernels — one contract violation each.

``tests/test_analysis.py`` traces these through
``kernel_contracts.check_traced_kernel`` and asserts the matching finding
rule fires.  Shapes are tiny; the kernels are traced, never executed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

N, S, V = 4, 8, 8


def _unpaired_dma_kernel(rows_ref, v_hbm, out_ref, vbuf, vsem):
    i = pl.program_id(0)
    row = rows_ref[i]
    cp = pltpu.make_async_copy(v_hbm.at[pl.ds(row, 1), :], vbuf, vsem)
    cp.start()
    # BUG: no cp.wait() — the copy is never retired before vbuf is read
    out_ref[0, :] = jnp.where(row >= 0, vbuf[0, :], jnp.zeros_like(vbuf[0, :]))


def unpaired_dma(values, rows, *, interpret: bool = True):
    n = rows.shape[0]
    v = values.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec(memory_space=compat.HBM)],
        out_specs=pl.BlockSpec((1, v), lambda i, r: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, v), values.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    return pl.pallas_call(
        _unpaired_dma_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, v), values.dtype),
        interpret=interpret,
        name="fixture_unpaired_dma",
    )(rows, values)


def _unmasked_store_kernel(mask_ref, val_ref, out_ref):
    i = pl.program_id(0)
    # BUG: float output store ignores the mask — misses keep stale lanes
    out_ref[0, :] = val_ref[0, :] * jnp.float32(2.0)


def unmasked_store(values, mask, *, interpret: bool = True):
    n, v = values.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, v), lambda i, m: (i, 0))],
        out_specs=pl.BlockSpec((1, v), lambda i, m: (i, 0)),
    )
    return pl.pallas_call(
        _unmasked_store_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, v), values.dtype),
        interpret=interpret,
        name="fixture_unmasked_store",
    )(mask, values)


def _direct_hbm_kernel(rows_ref, v_hbm, out_ref):
    i = pl.program_id(0)
    # BUG: direct vector load from an ANY/HBM-space ref (no async copy)
    row = jnp.where(rows_ref[i] >= 0, v_hbm[0, :], v_hbm[0, :])
    out_ref[0, :] = jnp.where(rows_ref[i] >= 0, row, jnp.zeros_like(row))


def direct_hbm_read(values, rows, *, interpret: bool = True):
    n = rows.shape[0]
    v = values.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec(memory_space=compat.HBM)],
        out_specs=pl.BlockSpec((1, v), lambda i, r: (i, 0)),
    )
    return pl.pallas_call(
        _direct_hbm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, v), values.dtype),
        interpret=interpret,
        name="fixture_direct_hbm",
    )(rows, values)


def _args():
    return (jnp.zeros((N * S, V), jnp.float32),
            jnp.zeros((N,), jnp.int32))


def trace_unpaired_dma():
    vals, rows = _args()
    return jax.make_jaxpr(functools.partial(unpaired_dma))(vals, rows)


def trace_unmasked_store():
    vals, rows = _args()
    return jax.make_jaxpr(functools.partial(unmasked_store))(
        jnp.zeros((N, V), jnp.float32), rows)


def trace_direct_hbm():
    vals, rows = _args()
    return jax.make_jaxpr(functools.partial(direct_hbm_read))(vals, rows)
