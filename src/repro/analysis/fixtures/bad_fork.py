"""Fixture: a forked (inlined) copy of the key-match formula.

``oracle_coupling.scan_source`` over this file must flag the
``&``-conjunction of paired hi/lo equality compares in ``forked_match``
with rule ``match-formula-fork`` — the formula must come from
``core.find.match_lanes`` instead.  ``not_a_fork`` is the control: its
conjunction compares unrelated planes and must NOT be flagged.
"""

from __future__ import annotations

import jax.numpy as jnp


def forked_match(key_hi, key_lo, q_hi, q_lo):
    """BUG: re-derives the match formula instead of calling the oracle."""
    hits = (key_hi == q_hi) & (key_lo == q_lo)
    return jnp.where(hits, 1, 0)


def not_a_fork(scores, epochs, s_min, e_min):
    """Conjunction over unrelated planes — legitimate, must not flag."""
    keep = (scores == s_min) & (epochs == e_min)
    return keep
