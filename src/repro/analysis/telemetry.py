"""Checker 5: telemetry-seam lint — the device op-telemetry contract.

Every `@roles.*`-annotated op entry point in ``repro.core.ops`` must
either thread the optional ``telemetry=`` channel (a keyword-only
parameter; see the module docstring of ``core.ops`` and DESIGN.md
§Observability) or carry an explicit exemption HERE, with a rationale.
The rule keeps the observability surface complete by construction: a new
op lands with counters, or with a reviewed reason why counters are
meaningless for it — never silently without.

Exemptions are RULE-LOCAL, not global waivers: ``findings.WAIVERS`` is
pinned empty by ``tests/test_analysis.py`` (shipped code must be clean),
so ops that legitimately have no telemetry story register in
``TELEMETRY_EXEMPT`` below instead.

Two rules:

  missing-telemetry-seam   an annotated op with no ``telemetry``
                           keyword parameter and no exemption.
  stale-exemption          an exempted op that no longer exists, or that
                           HAS grown the seam — the entry is dead weight
                           and must be pruned so the list stays honest.
"""

from __future__ import annotations

import inspect

from repro.analysis.findings import Finding
from repro.analysis.roles import public_ops
from repro.core import ops as ops_mod
from repro.core import roles as roles_mod

CHECKER = "telemetry"
_OPS_PATH = "src/repro/core/ops.py"

# op name -> rationale.  Each entry is a REVIEWED decision that device
# counters are meaningless for the op, not a deferral.
TELEMETRY_EXEMPT: dict[str, str] = {
    "size": "whole-table scalar reduction; no probe path to count",
    "load_factor": "derived scalar over size(); no probe path to count",
    "export_batch": "bucket-range dump (checkpoint drain); traversal is "
                    "exhaustive by construction, not probe-driven",
    "export_batch_if": "predicated bucket-range dump; same exhaustive "
                       "traversal as export_batch",
    "clear": "unconditional state reset; nothing probe- or "
             "admission-shaped to observe",
}


def _has_telemetry_seam(fn) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins only
        return False
    p = params.get("telemetry")
    return p is not None and p.default is None


def check_telemetry(module=ops_mod, path: str = _OPS_PATH,
                    exempt: dict | None = None) -> list[Finding]:
    out = []
    if exempt is None:
        exempt = TELEMETRY_EXEMPT
    ops = public_ops(module)
    annotated = {name: fn for name, fn in ops.items()
                 if roles_mod.role_of(fn) is not None}
    for name, fn in sorted(annotated.items()):
        if _has_telemetry_seam(fn):
            continue
        if name in exempt:
            continue
        line = None
        try:
            line = inspect.getsourcelines(fn)[1]
        except OSError:  # pragma: no cover
            pass
        out.append(Finding(
            CHECKER, "missing-telemetry-seam", name,
            "@roles-annotated op has neither a `telemetry=` keyword "
            "channel nor a TELEMETRY_EXEMPT entry — thread the seam "
            "(record via ops._obs() under `telemetry is not None`) or "
            "register a reviewed exemption in analysis/telemetry.py",
            path=path, line=line))
    for name, why in sorted(exempt.items()):
        fn = ops.get(name)
        if fn is None:
            out.append(Finding(
                CHECKER, "stale-exemption", name,
                f"TELEMETRY_EXEMPT lists an op that no longer exists "
                f"(rationale was: {why!r}) — prune the entry",
                path="src/repro/analysis/telemetry.py"))
        elif _has_telemetry_seam(fn):
            out.append(Finding(
                CHECKER, "stale-exemption", name,
                "TELEMETRY_EXEMPT lists an op that now threads the "
                "seam — prune the entry so the list stays honest",
                path="src/repro/analysis/telemetry.py"))
    return out
