"""Checker 4: oracle coupling — one match formula, referenced everywhere.

The correctness seam of the whole table is the key-match formula
(§3.2: 128-bit split-plane equality, optionally digest-prefiltered) and
the EMPTY-sentinel liveness formula.  Both live in exactly one place —
``core.find.match_lanes`` and ``core.u64.empty_lanes`` — and every kernel
stage must call them rather than re-deriving the plane math inline.  A
fork is how upsert and find silently diverge on (say) digest handling,
which no unit test of either side catches.

Three AST rules over ``src/repro``:

  oracle-multiplicity   exactly one ``def match_lanes`` and one
                        ``def empty_lanes`` in the tree.
  oracle-uncoupled      each required module references the oracle it is
                        supposed to route through (see ``REQUIRED_REFS``).
  match-formula-fork    an ``&``-conjunction contains two equality
                        compares that are hi/lo mirror images of each
                        other (identifier multisets coincide once hi/lo
                        markers are normalized away) — the signature of an
                        inlined copy of the match formula.

Scope for the fork rule: ``kernels/`` and ``core/`` minus the oracle
definition sites themselves (``core/find.py``, ``core/u64.py``) and
``core/predicates.py`` (key_range legitimately compares against lo/hi
bounds).  ``baselines/`` is deliberately out of scope: differential
baselines must stay independent re-implementations.
"""

from __future__ import annotations

import ast
import pathlib

from repro.analysis.findings import Finding

CHECKER = "oracle-coupling"

ORACLES = ("match_lanes", "empty_lanes")

# module (relative to src/) -> oracle names it must reference
REQUIRED_REFS = {
    "repro/core/find.py": ("match_lanes",),          # definition + wrapper
    "repro/core/merge.py": ("match_lanes",),
    "repro/kernels/digest_scan.py": ("match_lanes",),
    "repro/kernels/find_scan.py": ("match_lanes",),
    "repro/kernels/update_scan.py": ("match_lanes",),
    "repro/kernels/upsert_scan.py": ("match_lanes", "empty_lanes"),
    "repro/kernels/sweep_scan.py": ("empty_lanes",),
    "repro/kernels/score_scan.py": ("empty_lanes",),
    "repro/kernels/ref.py": ("match_lanes", "empty_lanes"),
}

_DEF_SITES = {"repro/core/find.py": ("match_lanes",),
              "repro/core/u64.py": ("empty_lanes",)}

_FORK_SCOPE = ("repro/kernels", "repro/core")
_FORK_EXEMPT = ("repro/core/find.py", "repro/core/u64.py",
                "repro/core/predicates.py")


def src_root() -> pathlib.Path:
    # .../src/repro/analysis/oracle_coupling.py -> .../src
    return pathlib.Path(__file__).resolve().parents[2]


def _tree_files(root: pathlib.Path):
    for p in sorted(root.glob("repro/**/*.py")):
        rel = p.relative_to(root).as_posix()
        if rel.startswith("repro/analysis/"):
            continue   # the analyzer and its known-bad fixtures
        yield rel, p


def _norm_ident(name: str) -> str:
    """Erase hi/lo markers so mirror compares collapse to one shape."""
    s = name.lower()
    for tok in ("hi", "lo", "h", "l"):
        s = s.replace(tok, "#")
    return s


def _compare_idents(node: ast.Compare):
    """Identifier multiset of a single-Eq compare, else None."""
    if len(node.ops) != 1 or not isinstance(node.ops[0], ast.Eq):
        return None
    names = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            names.append(sub.id)
        elif isinstance(sub, ast.Attribute):
            names.append(sub.attr)
    return tuple(sorted(names)) if names else None


def _and_leaves(node: ast.BinOp):
    """Flatten a chain of ``&`` into its leaf operands."""
    for side in (node.left, node.right):
        if isinstance(side, ast.BinOp) and isinstance(side.op, ast.BitAnd):
            yield from _and_leaves(side)
        else:
            yield side


def scan_source(source: str, rel_path: str) -> list[Finding]:
    """Fork rule over one file's source (separable for fixture tests)."""
    out = []
    tree = ast.parse(source, filename=rel_path)
    claimed_parents = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.BitAnd)):
            continue
        if id(node) in claimed_parents:
            continue
        for sub in ast.walk(node):
            if sub is not node and isinstance(sub, ast.BinOp) \
                    and isinstance(sub.op, ast.BitAnd):
                claimed_parents.add(id(sub))
        shapes = {}
        for leaf in _and_leaves(node):
            if not isinstance(leaf, ast.Compare):
                continue
            idents = _compare_idents(leaf)
            if idents is None:
                continue
            norm = tuple(_norm_ident(n) for n in idents)
            if norm == idents:
                continue   # no hi/lo marker anywhere: not plane math
            other = shapes.get(norm)
            if other is not None and other != idents:
                out.append(Finding(
                    CHECKER, "match-formula-fork",
                    f"{rel_path}:{leaf.lineno}",
                    "hi/lo mirror equality pair inside an '&' conjunction "
                    "re-derives the key-match formula — route through "
                    "core.find.match_lanes / core.u64.empty_lanes instead",
                    path=f"src/{rel_path}", line=leaf.lineno))
            else:
                shapes.setdefault(norm, idents)
    return out


def check_multiplicity(files) -> list[Finding]:
    out = []
    defs = {name: [] for name in ORACLES}
    for rel, path in files:
        tree = ast.parse(path.read_text(), filename=rel)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in defs:
                defs[node.name].append((rel, node.lineno))
    for name, sites in defs.items():
        expect = [(rel, None) for rel, names in _DEF_SITES.items()
                  if name in names]
        if len(sites) != 1:
            where = ", ".join(f"{r}:{ln}" for r, ln in sites) or "nowhere"
            out.append(Finding(
                CHECKER, "oracle-multiplicity", name,
                f"expected exactly one definition of {name} "
                f"(in {expect[0][0]}), found {len(sites)}: {where}",
                path=f"src/{expect[0][0]}"))
        elif sites[0][0] != expect[0][0]:
            out.append(Finding(
                CHECKER, "oracle-multiplicity", name,
                f"{name} is defined in {sites[0][0]}, expected "
                f"{expect[0][0]}", path=f"src/{sites[0][0]}",
                line=sites[0][1]))
    return out


def check_required_refs(files) -> list[Finding]:
    out = []
    by_rel = dict(files)
    for rel, needed in sorted(REQUIRED_REFS.items()):
        path = by_rel.get(rel)
        if path is None:
            out.append(Finding(CHECKER, "oracle-uncoupled", rel,
                               "required module is missing from the tree",
                               path=f"src/{rel}"))
            continue
        tree = ast.parse(path.read_text(), filename=rel)
        refs = {sub.attr if isinstance(sub, ast.Attribute) else sub.id
                for sub in ast.walk(tree)
                if isinstance(sub, (ast.Attribute, ast.Name))}
        for oracle in needed:
            if oracle not in refs:
                out.append(Finding(
                    CHECKER, "oracle-uncoupled", f"{rel}::{oracle}",
                    f"module must route its plane math through {oracle} "
                    f"but never references it — an inline re-derivation "
                    f"(or dead seam) slipped in",
                    path=f"src/{rel}"))
    return out


def check_forks(files) -> list[Finding]:
    out = []
    for rel, path in files:
        if not rel.startswith(_FORK_SCOPE):
            continue
        if rel in _FORK_EXEMPT:
            continue
        out.extend(scan_source(path.read_text(), rel))
    return out


def check_oracle_coupling() -> list[Finding]:
    files = list(_tree_files(src_root()))
    return (check_multiplicity(files) + check_required_refs(files)
            + check_forks(files))
