"""Checker 2: compile-cache audit — one compile per static signature.

PR 5's contract: a ``SweepPredicate``'s *kind* is static pytree aux (one
compile per kind) while its threshold operands are traced (no recompile per
value).  The handle layer's contract: every accepted key form funnels
through ``normalize_keys`` into identical avals (no weak_type drift), and
the handle's cfg/backend are the only static axes.

This checker pins both DYNAMICALLY but cheaply: it drives jitted handle
ops across predicate kinds, key forms (negative-int, numpy-uint64, wide
u64), threshold values, and backends on a tiny table, counting compiles
with ``jax.jit``'s cache size.  ``expected`` is exact — a cache size above
it means a Python operand leaked into the static signature (a silent perf
cliff on TPU: each serving wave would recompile); below it means the
scenario under-exercised and the audit itself is stale.

Each scenario is one Finding at most; the audit is hermetic (fresh jitted
callables per run, nothing shared with user code).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding
from repro.core.api import HKVTable, normalize_keys
from repro.core.predicates import KINDS, SweepPredicate

CHECKER = "compile-cache"
_PATH = "src/repro/core/api.py"


def _table(backend: str = "jnp") -> HKVTable:
    return HKVTable.create(capacity=64, dim=4, slots_per_bucket=8,
                           backend=backend)


def _preds():
    """Two operand values per kind — same kind must share one compile."""
    return {
        "always": [SweepPredicate.always(), SweepPredicate.always()],
        "score_lt": [SweepPredicate.score_below(5),
                     SweepPredicate.score_below(9)],
        "score_ge": [SweepPredicate.score_at_least(5),
                     SweepPredicate.score_at_least(9)],
        "epoch_lt": [SweepPredicate.expire_before(2),
                     SweepPredicate.expire_before(7)],
        "key_range": [SweepPredicate.key_in_range(1, 9),
                      SweepPredicate.key_in_range(4, 6)],
    }


def _key_forms():
    """Every accepted key form, normalized — avals must coincide."""
    return [
        normalize_keys([1, 2, -1, 4]),                      # negative-int list
        normalize_keys(np.arange(4, dtype=np.uint64)),      # numpy uint64
        normalize_keys(np.uint64([1 << 40, 2, 3, (1 << 63) + 5])),  # wide
        normalize_keys(np.array([7, 8, 9, 10], dtype=np.int32)),    # signed
    ]


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    expected: int
    run: Callable[[], int]   # returns observed cache size


def _scenario_key_forms() -> Scenario:
    def run():
        t = _table()
        f = jax.jit(lambda tbl, keys: tbl.find(keys).values)
        for keys in _key_forms():
            f(t, keys)
        return f._cache_size()
    return Scenario("find across key forms (normalize_keys avals)", 1, run)


def _scenario_sweep_kinds(op: str) -> Scenario:
    def run():
        t = _table()
        if op == "erase_if":
            f = jax.jit(lambda tbl, p: tbl.erase_if(p).swept)
        else:
            f = jax.jit(lambda tbl, p: tbl.evict_if(p, 4).count)
        for kind, preds in _preds().items():
            for p in preds:
                f(t, p)
        return f._cache_size()
    return Scenario(f"{op} across predicate kinds x operand values",
                    len(KINDS), run)


def _scenario_backend_axis() -> Scenario:
    def run():
        f = jax.jit(lambda tbl, keys: tbl.contains(keys))
        keys = _key_forms()[0]
        for backend in ("jnp", "kernel"):
            t = _table(backend)
            f(t, keys)
            f(t, keys)   # repeat: must not grow
        return f._cache_size()
    return Scenario("contains across backends (static aux axis)", 2, run)


def _scenario_upsert_signatures() -> Scenario:
    def run():
        t = _table()
        vals = jnp.zeros((4, 4), jnp.float32)
        f = jax.jit(lambda tbl, keys, v: tbl.insert_or_assign(keys, v).status)
        g = jax.jit(lambda tbl, keys, v, cs:
                    tbl.insert_or_assign(keys, v, custom_scores=cs).status)
        for keys in _key_forms():
            f(t, keys, vals)
            g(t, keys, vals, normalize_keys([5, 6, 7, 8]))
        return f._cache_size() + g._cache_size()
    return Scenario("insert_or_assign across key forms (+custom scores)",
                    2, run)


def _scenario_score_values() -> Scenario:
    def run():
        t = _table()
        f = jax.jit(lambda tbl, keys, s: tbl.assign_scores(keys, s))
        keys = _key_forms()[0]
        for sval in (3, 9, 1 << 40):
            f(t, keys, normalize_keys(np.uint64([sval] * 4)))
        return f._cache_size()
    return Scenario("assign_scores across score values", 1, run)


def scenarios() -> list[Scenario]:
    return [
        _scenario_key_forms(),
        _scenario_sweep_kinds("erase_if"),
        _scenario_sweep_kinds("evict_if"),
        _scenario_backend_axis(),
        _scenario_upsert_signatures(),
        _scenario_score_values(),
    ]


def check_compile_cache() -> list[Finding]:
    out = []
    for sc in scenarios():
        try:
            got = sc.run()
        except Exception as e:
            out.append(Finding(CHECKER, "audit-error", sc.name,
                               f"scenario raised {type(e).__name__}: {e}",
                               path=_PATH))
            continue
        if got > sc.expected:
            out.append(Finding(
                CHECKER, "recompile", sc.name,
                f"expected {sc.expected} compile(s), observed {got} — a "
                f"value that should be traced (threshold, key planes) is "
                f"leaking into the static jit signature (weak_type drift "
                f"or Python-operand capture)",
                path=_PATH))
        elif got < sc.expected:
            out.append(Finding(
                CHECKER, "under-exercised", sc.name,
                f"expected {sc.expected} compile(s), observed {got} — the "
                f"audit scenario no longer drives distinct static "
                f"signatures; update the audit",
                path=_PATH))
    return out
