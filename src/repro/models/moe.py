"""Mixture-of-Experts FFN with capacity-bounded sort dispatch (EP-ready).

Static-shape dispatch: top-k assignments are sorted by expert, ranked
within expert (the same cummax trick the HKV merge uses), and scattered
into an [E, C, d] buffer — tokens past an expert's capacity C are dropped
(standard capacity-factor semantics, deterministic).  Expert FFNs run as a
single batched einsum over the expert dimension, which is the dimension EP
shards (buffer sharded [model, -, -]); under pjit the scatter/gather
becomes the dispatch/combine all-to-all on the model axis.

Aux outputs: load-balance loss (Switch-style) + router z-loss.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int                    # per-expert hidden
    act: str = "silu"
    gated: bool = True
    capacity_factor: float = 1.25


def moe_init(cfg: MoECfg, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    wi_out = cfg.d_ff * (2 if cfg.gated else 1)
    return {
        "router": dense_init(k1, cfg.d_model, cfg.num_experts),
        "wi": (
            jax.random.normal(k2, (cfg.num_experts, cfg.d_model, wi_out))
            * (1.0 / jnp.sqrt(cfg.d_model))
        ).astype(jnp.float32),
        "wo": (
            jax.random.normal(k3, (cfg.num_experts, cfg.d_ff, cfg.d_model))
            * (1.0 / jnp.sqrt(cfg.d_ff))
        ).astype(jnp.float32),
    }


def capacity(cfg: MoECfg, tokens: int) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # multiple of 8 for TPU sublane alignment


def moe_apply(cfg: MoECfg, params: dict, x: jax.Array) -> tuple[jax.Array, dict]:
    """x: [T, d] flattened tokens -> (y [T, d], aux losses)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(cfg, t)
    act = activation(cfg.act)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                   # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renormalize top-k

    # aux losses
    me = probs.mean(axis=0)                                  # mean prob per expert
    ce = jnp.zeros((e,)).at[expert.reshape(-1)].add(1.0) / (t * k)
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }

    # dispatch: sort (T*k) assignments by expert, rank within expert
    flat_e = expert.reshape(-1).astype(jnp.int32)            # [T*k]
    flat_g = gate.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_g[order], flat_t[order]
    iota = jnp.arange(t * k, dtype=jnp.int32)
    is_new = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    rank = iota - jax.lax.cummax(jnp.where(is_new, iota, -1))
    keep = rank < c
    slot = jnp.where(keep, se * c + rank, e * c)             # OOB -> dropped

    buf = jnp.zeros((e * c, d), x.dtype).at[slot].set(x[stok], mode="drop")
    buf = buf.reshape(e, c, d)
    # EP: pin the dispatch buffer to the expert axis so the expert einsums
    # run sharded (dispatch becomes the all-to-all) instead of GSPMD
    # all-gathering the expert weights
    from repro.distributed.sharding import maybe_constrain

    buf = maybe_constrain(buf, "model", None, None)

    # expert FFN (batched over the expert dim — the EP-sharded einsum)
    wi = params["wi"].astype(x.dtype)
    wo = params["wo"].astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    h = maybe_constrain(h, "model", None, None)
    if cfg.gated:
        hg, hu = jnp.split(h, 2, axis=-1)
        h = act(hg) * hu
    else:
        h = act(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo)
    out_buf = maybe_constrain(out_buf, "model", None, None).reshape(e * c, d)

    # combine: weighted un-dispatch
    gathered = out_buf[jnp.clip(slot, 0, e * c - 1)]
    contrib = jnp.where(keep[:, None], gathered * sg[:, None].astype(x.dtype), 0)
    y = jnp.zeros((t, d), x.dtype).at[stok].add(contrib)
    aux["dropped_frac"] = 1.0 - keep.mean()
    return y, aux
