"""Block zoo: attention (GQA/MQA/SWA/M-RoPE, dense-FFN or MoE), Mamba2 (SSD),
mLSTM and sLSTM — each with a full-sequence training path and a single-token
decode path over an explicit state (KV cache or recurrent state).

Every block is a pure (cfg, params, x, ...) -> x function; parameters are
plain dicts so layer stacks can be vmapped/scanned and sharded with
tree-structured PartitionSpecs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.common import (
    activation,
    apply_mrope,
    apply_rope,
    blocked_causal_attention,
    decode_attention,
    dense_init,
    init_rms,
    rms_norm,
)
from repro.models.moe import MoECfg, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    kind: str                       # attn | mamba2 | mlstm | slstm
    d_model: int
    # -- attn --
    heads: int = 0
    kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // heads
    qkv_bias: bool = False
    window: Optional[int] = None    # SWA band
    rope: str = "rope"              # rope | mrope | none
    rope_theta: float = 10000.0
    d_ff: int = 0
    act: str = "silu"
    gated: bool = True
    moe: Optional[MoECfg] = None
    # -- ssm family --
    d_state: int = 64               # N
    ssm_heads: int = 8              # H
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    qkv_block: int = 4              # mLSTM block-diagonal q/k/v blocksize
    # --
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_headdim(self) -> int:
        return self.d_inner // self.ssm_heads


class PosCtx(NamedTuple):
    """Positional context threaded through attention blocks."""

    positions: jax.Array            # [B, S] (train/prefill) or [B, 1] (decode)
    mrope_positions: Optional[jax.Array] = None  # [3, B, S]
    step: Optional[jax.Array] = None             # decode: current length


# =============================================================================
# Attention block (+ dense or MoE FFN)
# =============================================================================


def _attn_init(cfg: BlockCfg, key) -> dict:
    ks = jax.random.split(key, 8)
    hd, hq, hkv = cfg.hd, cfg.heads, cfg.kv_heads
    p = {
        "ln1": init_rms(cfg.d_model),
        "wq": dense_init(ks[0], cfg.d_model, hq * hd),
        "wk": dense_init(ks[1], cfg.d_model, hkv * hd),
        "wv": dense_init(ks[2], cfg.d_model, hkv * hd),
        "wo": dense_init(ks[3], hq * hd, cfg.d_model, scale=1.0 / math.sqrt(hq * hd)),
        "ln2": init_rms(cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    if cfg.moe is not None:
        p["moe"] = moe_init(cfg.moe, ks[4])
    else:
        p["ffn_wi"] = dense_init(ks[5], cfg.d_model, cfg.d_ff * (2 if cfg.gated else 1))
        p["ffn_wo"] = dense_init(ks[6], cfg.d_ff, cfg.d_model)
    return p


def _qkv(cfg: BlockCfg, p: dict, x: jax.Array, pos: PosCtx):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.hd, cfg.heads, cfg.kv_heads
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = h @ p["wq"].astype(h.dtype)
    k = h @ p["wk"].astype(h.dtype)
    v = h @ p["wv"].astype(h.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.rope == "rope":
        q = apply_rope(q, pos.positions, cfg.rope_theta)
        k = apply_rope(k, pos.positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        sec = _mrope_sections(hd)
        q = apply_mrope(q, pos.mrope_positions, cfg.rope_theta, sec)
        k = apply_mrope(k, pos.mrope_positions, cfg.rope_theta, sec)
    return q, k, v


def _mrope_sections(hd: int):
    """(t, h, w) frequency split covering head_dim/2 (Qwen2-VL uses 16/24/24
    at hd=128; scale proportionally elsewhere)."""
    half = hd // 2
    t = half // 4
    hw = (half - t) // 2
    return (t, hw, half - t - hw)


def _ffn(cfg: BlockCfg, p: dict, x: jax.Array):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        b, s, d = h.shape
        y, aux = moe_apply(cfg.moe, p["moe"], h.reshape(b * s, d))
        return y.reshape(b, s, d), aux
    act = activation(cfg.act)
    u = h @ p["ffn_wi"].astype(h.dtype)
    if cfg.gated:
        ug, uu = jnp.split(u, 2, axis=-1)
        u = act(ug) * uu
    else:
        u = act(u)
    return u @ p["ffn_wo"].astype(h.dtype), {}


def _attn_train(cfg: BlockCfg, p: dict, x: jax.Array, pos: PosCtx):
    q, k, v = _qkv(cfg, p, x, pos)
    o = blocked_causal_attention(q, k, v, window=cfg.window)
    b, s, _, _ = o.shape
    x = x + (o.reshape(b, s, -1) @ p["wo"].astype(x.dtype))
    f, aux = _ffn(cfg, p, x)
    return x + f, aux


def _attn_state_init(cfg: BlockCfg, batch: int, max_len: int, dtype) -> dict:
    cache_len = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, cache_len, cfg.kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attn_decode(cfg: BlockCfg, p: dict, x: jax.Array, state: dict, pos: PosCtx):
    """x: [B, 1, d]; SWA caches are ring buffers of length `window`."""
    q, k, v = _qkv(cfg, p, x, pos)
    cache_len = state["k"].shape[1]
    step = pos.step
    widx = jax.lax.rem(step, cache_len) if cfg.window else step
    kc = jax.lax.dynamic_update_slice(state["k"], k.astype(state["k"].dtype), (0, widx, 0, 0))
    vc = jax.lax.dynamic_update_slice(state["v"], v.astype(state["v"].dtype), (0, widx, 0, 0))
    cur = jnp.minimum(step + 1, cache_len)
    o = decode_attention(q, kc, vc, cur)
    b = x.shape[0]
    x = x + (o.reshape(b, 1, -1) @ p["wo"].astype(x.dtype))
    f, _ = _ffn(cfg, p, x)
    return x + f, {"k": kc, "v": vc}


# =============================================================================
# Mamba2 block (SSD via chunked GLA)
# =============================================================================


def _mamba2_init(cfg: BlockCfg, key) -> dict:
    ks = jax.random.split(key, 4)
    din, n, h = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    proj_out = 2 * din + 2 * n + h  # z, x, B, C, dt
    return {
        "ln": init_rms(cfg.d_model),
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, din)) * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),       # a = -exp(A_log) in [-1, -e^x)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "out_norm": init_rms(din),
        "out_proj": dense_init(ks[2], din, cfg.d_model),
    }


def _mamba2_split(cfg: BlockCfg, p: dict, x: jax.Array):
    din, n, h = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    u = rms_norm(x, p["ln"], cfg.norm_eps) @ p["in_proj"].astype(x.dtype)
    z, xs, Bm, Cm, dt = jnp.split(u, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    return z, xs, Bm, Cm, dt


def _mamba2_gla_inputs(cfg: BlockCfg, p: dict, xs, Bm, Cm, dt):
    b, s, _ = xs.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # [B,S,H]
    log_a = -jnp.exp(p["A_log"]) * dt                                # [B,S,H] <= 0
    xh = xs.reshape(b, s, h, pd)
    v = xh * dt[..., None].astype(xh.dtype)                          # dt-scaled input
    k = jnp.broadcast_to(Bm[:, :, None, :], (b, s, h, n))
    q = jnp.broadcast_to(Cm[:, :, None, :], (b, s, h, n))
    return q, k, v, log_a, xh


def _mamba2_out(cfg: BlockCfg, p: dict, x, y, xh, z):
    b, s = x.shape[0], x.shape[1]
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, s, cfg.d_inner) * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return x + y @ p["out_proj"].astype(x.dtype)


def _causal_conv(xs: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with taps [W, C]."""
    wlen = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xs.shape[1], :] * w[i][None, None, :].astype(xs.dtype)
        for i in range(wlen)
    )
    return jax.nn.silu(out + b.astype(xs.dtype))


def _mamba2_train(cfg: BlockCfg, p: dict, x: jax.Array, pos: PosCtx):
    z, xs, Bm, Cm, dt = _mamba2_split(cfg, p, x)
    xs = _causal_conv(xs, p["conv_w"], p["conv_b"])
    q, k, v, log_a, xh = _mamba2_gla_inputs(cfg, p, xs, Bm, Cm, dt)
    y, _ = ssm.chunked_gla(q, k, v, log_a)
    return _mamba2_out(cfg, p, x, y, xh, z), {}


def _mamba2_state_init(cfg: BlockCfg, batch: int, max_len: int, dtype) -> dict:
    return {
        "gla": jnp.zeros((batch, cfg.ssm_heads, cfg.d_state, cfg.ssm_headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
    }


def _mamba2_decode(cfg: BlockCfg, p: dict, x: jax.Array, state: dict, pos: PosCtx):
    z, xs, Bm, Cm, dt = _mamba2_split(cfg, p, x)          # all [B, 1, *]
    hist = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
    xs_c = _causal_conv(hist, p["conv_w"], p["conv_b"])[:, -1:, :]
    new_conv = hist[:, 1:, :].astype(state["conv"].dtype)
    q, k, v, log_a, xh = _mamba2_gla_inputs(cfg, p, xs_c, Bm, Cm, dt)
    y, gla = ssm.gla_step(state["gla"], q[:, 0], k[:, 0], v[:, 0], log_a[:, 0])
    out = _mamba2_out(cfg, p, x, y[:, None], xh, z)
    return out, {"gla": gla, "conv": new_conv}


# =============================================================================
# mLSTM block (xLSTM matrix memory via chunked GLA with a normalizer column)
# =============================================================================


def _mlstm_init(cfg: BlockCfg, key) -> dict:
    ks = jax.random.split(key, 6)
    din = cfg.d_inner
    qb = cfg.qkv_block
    # q/k/v are BLOCK-DIAGONAL projections (xLSTM's qkv_proj_blocksize):
    # [din/qb, qb, qb] — the parameter diet that puts xlstm-1.3b at 1.3 B.
    def bd(key):
        return (jax.random.normal(key, (din // qb, qb, qb)) / math.sqrt(qb)).astype(
            jnp.float32
        )

    return {
        "ln": init_rms(cfg.d_model),
        "up": dense_init(ks[0], cfg.d_model, 2 * din),   # u (mixer) + z (gate)
        "wq": bd(ks[1]),
        "wk": bd(ks[2]),
        "wv": bd(ks[3]),
        "wgate": dense_init(ks[4], din, 2 * cfg.ssm_heads),  # i, f pre-activations
        "down": dense_init(ks[5], din, cfg.d_model),
    }


def _block_diag_proj(x: jax.Array, w: jax.Array) -> jax.Array:
    """[..., din] @ block-diag([G, qb, qb]) -> [..., din]."""
    g, qb, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (g, qb))
    out = jnp.einsum("...gb,gbc->...gc", xb, w.astype(x.dtype))
    return out.reshape(x.shape)


def _mlstm_qkv(cfg: BlockCfg, p: dict, x: jax.Array):
    b, s, _ = x.shape
    din, h = cfg.d_inner, cfg.ssm_heads
    pd = din // h
    u, z = jnp.split(rms_norm(x, p["ln"], cfg.norm_eps) @ p["up"].astype(x.dtype), 2, -1)
    q = _block_diag_proj(u, p["wq"]).reshape(b, s, h, pd) / math.sqrt(pd)
    k = _block_diag_proj(u, p["wk"]).reshape(b, s, h, pd) / math.sqrt(pd)
    v = _block_diag_proj(u, p["wv"]).reshape(b, s, h, pd)
    gates = u @ p["wgate"].astype(u.dtype)
    i_pre, f_pre = jnp.split(gates.astype(jnp.float32), 2, -1)  # [B,S,H]
    log_f = -jax.nn.softplus(-f_pre)                             # log sigmoid(f)
    ig = jax.nn.sigmoid(i_pre)  # sigmoid input gate (stabilized adaptation)
    # normalizer column: v_aug = i * [v, 1]
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1) * ig[..., None].astype(v.dtype)
    return q, k, v_aug, log_f, z


def _mlstm_out(cfg: BlockCfg, p: dict, x, y_aug, z):
    y, norm = y_aug[..., :-1], y_aug[..., -1:]
    y = y / jnp.maximum(jnp.abs(norm), 1.0)
    b, s = x.shape[0], x.shape[1]
    h = y.reshape(b, s, cfg.d_inner) * jax.nn.silu(z)
    return x + h @ p["down"].astype(x.dtype)


def _mlstm_train(cfg: BlockCfg, p: dict, x: jax.Array, pos: PosCtx):
    q, k, v_aug, log_f, z = _mlstm_qkv(cfg, p, x)
    y_aug, _ = ssm.chunked_gla(q, k, v_aug, log_f)
    return _mlstm_out(cfg, p, x, y_aug, z), {}


def _mlstm_state_init(cfg: BlockCfg, batch: int, max_len: int, dtype) -> dict:
    pd = cfg.d_inner // cfg.ssm_heads
    return {"gla": jnp.zeros((batch, cfg.ssm_heads, pd, pd + 1), jnp.float32)}


def _mlstm_decode(cfg: BlockCfg, p: dict, x: jax.Array, state: dict, pos: PosCtx):
    q, k, v_aug, log_f, z = _mlstm_qkv(cfg, p, x)
    y_aug, gla = ssm.gla_step(state["gla"], q[:, 0], k[:, 0], v_aug[:, 0], log_f[:, 0])
    return _mlstm_out(cfg, p, x, y_aug[:, None], z), {"gla": gla}


# =============================================================================
# sLSTM block (scalar memory, exponential gating with stabilizer; sequential)
# =============================================================================


def _slstm_init(cfg: BlockCfg, key) -> dict:
    ks = jax.random.split(key, 3)
    d, h = cfg.d_model, cfg.ssm_heads
    pd = d // h
    return {
        "ln": init_rms(d),
        "wx": dense_init(ks[0], d, 4 * d),                  # z, i, f, o from input
        "r": (jax.random.normal(ks[1], (h, pd, 4 * pd)) / math.sqrt(pd)).astype(jnp.float32),
        "out": dense_init(ks[2], d, d),
    }


def _slstm_cell(cfg: BlockCfg, p: dict, xg, carry):
    """One step. xg: [B, 4d] input gate pre-activations; carry: (c,n,h,m)."""
    b = xg.shape[0]
    d, hh = cfg.d_model, cfg.ssm_heads
    pd = d // hh
    c, n, hprev, m = carry
    rec = jnp.einsum("bhp,hpq->bhq", hprev, p["r"].astype(hprev.dtype))  # [B,H,4pd]
    g = xg.reshape(b, hh, 4 * pd) + rec
    zg, ig, fg, og = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    log_f = -jax.nn.softplus(-fg)
    m_new = jnp.maximum(log_f + m, ig)                     # stabilizer
    i_s = jnp.exp(ig - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c = f_s * c + i_s * jnp.tanh(zg)
    n = f_s * n + i_s
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1.0)
    return (c, n, h.astype(hprev.dtype), m_new), h


def _slstm_state_init(cfg: BlockCfg, batch: int, max_len: int, dtype):
    hh, pd = cfg.ssm_heads, cfg.d_model // cfg.ssm_heads
    z32 = jnp.zeros((batch, hh, pd), jnp.float32)
    return {"c": z32, "n": z32, "h": jnp.zeros((batch, hh, pd), dtype),
            "m": jnp.full((batch, hh, pd), -1e30, jnp.float32)}


def _slstm_train(cfg: BlockCfg, p: dict, x: jax.Array, pos: PosCtx):
    b, s, d = x.shape
    xg = rms_norm(x, p["ln"], cfg.norm_eps) @ p["wx"].astype(x.dtype)  # [B,S,4d]
    st = _slstm_state_init(cfg, b, s, x.dtype)
    carry = (st["c"], st["n"], st["h"], st["m"])

    def step(carry, xg_t):
        return _slstm_cell(cfg, p, xg_t, carry)

    _, hs = jax.lax.scan(step, carry, xg.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    return x + h @ p["out"].astype(x.dtype), {}


def _slstm_decode(cfg: BlockCfg, p: dict, x: jax.Array, state: dict, pos: PosCtx):
    xg = rms_norm(x, p["ln"], cfg.norm_eps) @ p["wx"].astype(x.dtype)
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, h = _slstm_cell(cfg, p, xg[:, 0], carry)
    b = x.shape[0]
    out = x + h.reshape(b, 1, -1).astype(x.dtype) @ p["out"].astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}


# =============================================================================
# dispatch tables
# =============================================================================

_INIT = {"attn": _attn_init, "mamba2": _mamba2_init, "mlstm": _mlstm_init,
         "slstm": _slstm_init}
_TRAIN = {"attn": _attn_train, "mamba2": _mamba2_train, "mlstm": _mlstm_train,
          "slstm": _slstm_train}
_STATE = {"attn": _attn_state_init, "mamba2": _mamba2_state_init,
          "mlstm": _mlstm_state_init, "slstm": _slstm_state_init}
_DECODE = {"attn": _attn_decode, "mamba2": _mamba2_decode,
           "mlstm": _mlstm_decode, "slstm": _slstm_decode}


def block_init(cfg: BlockCfg, key) -> dict:
    return _INIT[cfg.kind](cfg, key)


def block_train(cfg: BlockCfg, params: dict, x: jax.Array, pos: PosCtx):
    return _TRAIN[cfg.kind](cfg, params, x, pos)


def block_state_init(cfg: BlockCfg, batch: int, max_len: int, dtype) -> dict:
    return _STATE[cfg.kind](cfg, batch, max_len, dtype)


def block_decode(cfg: BlockCfg, params: dict, x: jax.Array, state: dict, pos: PosCtx):
    return _DECODE[cfg.kind](cfg, params, x, state, pos)
