from repro.models.lm import CompositeLM, LMConfig, StackSegment  # noqa: F401
from repro.models.blocks import BlockCfg  # noqa: F401
