"""Shared model machinery: norms, position encodings, attention primitives.

Attention is memory-bounded by construction: the full-sequence path is a
flash-style two-level blocked computation (lax.scan over KV chunks with an
online-softmax carry), so a 32 k-token prefill never materializes an
S x S score matrix — the working set is q_block x kv_chunk.  Sliding-window
(SWA) archs restrict the same machinery with a band mask.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rms(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# Position encodings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [B, S, H, Dh], positions: [B, S] int32."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float = 10000.0,
    sections=(16, 24, 24),
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions3 [3, B, S] = (t, h, w) ids; the
    head_dim/2 frequency slots are split into (t, h, w) sections."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    sec = np.cumsum((0,) + tuple(sections))
    assert sec[-1] == dh // 2, "mrope sections must cover head_dim/2"
    parts = []
    for i in range(3):
        ang_i = positions3[i][..., None].astype(jnp.float32) * freqs[sec[i] : sec[i + 1]]
        parts.append(ang_i)
    ang = jnp.concatenate(parts, axis=-1)  # [B, S, Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jax.Array, d_model: int) -> jax.Array:
    """MusicGen-style sinusoidal position embedding. positions: [B, S]."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# Blocked causal attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _band_mask(q_pos, kv_pos, window, s_valid):
    mask = q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - kv_pos[None, :]) < window
    mask &= (kv_pos < s_valid)[None, :]
    return mask


def _chunk_live(qi, kj, q_chunk, kv_chunk, window):
    """Is any (q, kv) pair of this chunk pair inside the causal band?"""
    last_q = qi * q_chunk + q_chunk - 1
    first_q = qi * q_chunk
    first_kv = kj * kv_chunk
    last_kv = kj * kv_chunk + kv_chunk - 1
    live = last_q >= first_kv
    if window is not None:
        live &= (first_q - last_kv) < window
    return live


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, window, q_chunk, kv_chunk, s_valid):
    out, _ = _flash_fwd_impl(q, k, v, window, q_chunk, kv_chunk, s_valid)
    return out


def _flash_fwd_impl(q, k, v, window, q_chunk, kv_chunk, s_valid):
    """Grouped-GQA flash forward: q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh].

    KV heads are NEVER repeated to Hq — the einsums carry a (group, rep)
    structure — so residuals (and dk/dv accumulators in the backward) stay
    at Hkv width: an Hq/Hkv (up to 8x) memory saving for GQA/MQA archs.
    Returns (out [B,S,Hq,Dh], lse [nq,B,G,R,qc]) — O(S*Dh) residuals.
    """
    b, s, hq, dh = q.shape
    g = k.shape[2]
    r = hq // g
    nq, nkv = s // q_chunk, s // kv_chunk
    scale = 1.0 / math.sqrt(dh)
    qb = q.reshape(b, nq, q_chunk, g, r, dh).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nkv, kv_chunk, g, dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nkv, kv_chunk, g, dh).transpose(1, 0, 3, 2, 4)
    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)

    def per_qchunk(args):
        qi, qc = args
        q_pos = qi * q_chunk + q_pos_base

        def per_kvchunk(carry, inp):
            m, l, acc = carry
            kj, kc, vc = inp

            def attend(args):
                m, l, acc = args
                sc = jnp.einsum("bgrqd,bgkd->bgrqk", qc, kc,
                                preferred_element_type=jnp.float32) * scale
                mask = _band_mask(q_pos, kj * kv_chunk + kv_pos_base, window, s_valid)
                sc = jnp.where(mask[None, None, None], sc, NEG_INF)
                m_new = jnp.maximum(m, sc.max(axis=-1))
                p = jnp.exp(sc - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bgrqk,bgkd->bgrqd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc_new

            live = _chunk_live(qi, kj, q_chunk, kv_chunk, window)
            m, l, acc = jax.lax.cond(live, attend, lambda a: a, (m, l, acc))
            return (m, l, acc), None

        m0 = jnp.full((b, g, r, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, r, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(per_kvchunk, (m0, l0, a0),
                                      (jnp.arange(nkv), kb, vb))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    outs, lses = jax.lax.map(per_qchunk, (jnp.arange(nq), qb))
    # outs: [nq, B, G, R, qc, dh] -> [B, S, Hq, dh]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, hq, dh)
    return out, lses  # lses: [nq, B, G, R, qc]


def _flash_fwd(q, k, v, window, q_chunk, kv_chunk, s_valid):
    out, lse = _flash_fwd_impl(q, k, v, window, q_chunk, kv_chunk, s_valid)
    return out, (q, k, v, out, lse)


def _flash_bwd(window, q_chunk, kv_chunk, s_valid, res, dout):
    """Flash backward: recompute p per chunk pair; O(S*Dh) live memory.

    The `tether` term (== 0.0, but data-dependent on the cotangent) is
    load-bearing: under lax.scan differentiation, partial evaluation hoists
    any cotangent-independent computation of this function into the FORWARD
    sweep and stacks it per layer x per chunk pair — resurrecting the
    O(S^2) residuals flash attention exists to avoid.  Tying the score
    recomputation to dout forces the whole backward to run in the backward
    sweep, where its chunk buffers are transient."""
    q, k, v, out, lse = res
    tether = (jnp.sum(dout[0, 0, 0, 0].astype(jnp.float32)) * 0.0).astype(q.dtype)
    q = q + tether
    b, s, hq, dh = q.shape
    g = k.shape[2]
    r = hq // g
    nq, nkv = s // q_chunk, s // kv_chunk
    scale = 1.0 / math.sqrt(dh)
    qb = q.reshape(b, nq, q_chunk, g, r, dh).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nkv, kv_chunk, g, dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nkv, kv_chunk, g, dh).transpose(1, 0, 3, 2, 4)
    dob = dout.reshape(b, nq, q_chunk, g, r, dh).transpose(1, 0, 3, 4, 2, 5)
    outb = out.reshape(b, nq, q_chunk, g, r, dh).transpose(1, 0, 3, 4, 2, 5)
    # delta = rowsum(dout * out): [nq, B, G, R, qc]
    delta = jnp.sum(dob.astype(jnp.float32) * outb.astype(jnp.float32), axis=-1)
    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)

    def per_qchunk(carry, inp):
        dk_acc, dv_acc = carry
        qi, qc, doc, lsec, delc = inp

        def per_kvchunk(dq, inp2):
            kj, kc, vc, dkj, dvj = inp2

            def attend(args):
                dq, dkj, dvj = args
                sc = jnp.einsum("bgrqd,bgkd->bgrqk", qc, kc,
                                preferred_element_type=jnp.float32) * scale
                mask = _band_mask(qi * q_chunk + q_pos_base,
                                  kj * kv_chunk + kv_pos_base, window, s_valid)
                p = jnp.where(mask[None, None, None],
                              jnp.exp(sc - lsec[..., None]), 0.0)
                # dk/dv sum over the rep dim — the GQA reduction happens
                # HERE, at Hkv width, instead of a post-hoc segment-sum
                dv_c = jnp.einsum("bgrqk,bgrqd->bgkd", p.astype(doc.dtype), doc,
                                  preferred_element_type=jnp.float32)
                dp = jnp.einsum("bgrqd,bgkd->bgrqk", doc, vc,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - delc[..., None]) * scale
                dq_c = jnp.einsum("bgrqk,bgkd->bgrqd", ds.astype(kc.dtype), kc,
                                  preferred_element_type=jnp.float32)
                dk_c = jnp.einsum("bgrqk,bgrqd->bgkd", ds.astype(qc.dtype), qc,
                                  preferred_element_type=jnp.float32)
                return dq + dq_c, dkj + dk_c, dvj + dv_c

            live = _chunk_live(qi, kj, q_chunk, kv_chunk, window)
            dq, dkj, dvj = jax.lax.cond(live, attend, lambda a: a, (dq, dkj, dvj))
            return dq, (dkj, dvj)

        dq0 = jnp.zeros((b, g, r, q_chunk, dh), jnp.float32)
        dq, (dk_new, dv_new) = jax.lax.scan(
            per_kvchunk, dq0, (jnp.arange(nkv), kb, vb, dk_acc, dv_acc)
        )
        return (dk_new, dv_new), dq

    dk0 = jnp.zeros((nkv, b, g, kv_chunk, dh), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk, dv), dqs = jax.lax.scan(
        per_qchunk, (dk0, dv0),
        (jnp.arange(nq), qb, dob, lse, delta),
    )
    dq = dqs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, hq, dh).astype(q.dtype)
    dk = dk.transpose(1, 0, 3, 2, 4).reshape(b, s, g, dh).astype(k.dtype)
    dv = dv.transpose(1, 0, 3, 2, 4).reshape(b, s, g, dh).astype(v.dtype)
    return dq, dk, dv


# optimize_remat: without it, lax.scan's partial-eval hoists the backward's
# primal-only work (the recomputed p matrices — O(S^2)!) into the forward
# sweep and stacks it per chunk pair, defeating the whole flash structure.
_flash.defvjp(_flash_fwd, _flash_bwd)


def blocked_causal_attention(
    q: jax.Array,        # [B, S, Hq, Dh]
    k: jax.Array,        # [B, S, Hkv, Dh]
    v: jax.Array,        # [B, S, Hkv, Dh]
    *,
    window: Optional[int] = None,   # SWA band (None = full causal)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style causal (optionally banded) attention, pure JAX.

    Forward: two-level blocking with an online-softmax carry — never
    materializes S x S.  Backward: custom_vjp that RECOMPUTES p per chunk
    pair (the flash recurrence), so residuals are O(S x Dh) instead of the
    O(S^2) a scan-of-scans autodiff would store.  SWA skips chunk pairs
    entirely outside the band (compute and bandwidth): O(S x window) work.
    GQA is computed GROUPED — KV heads are never expanded to Hq, so the
    k/v residuals and dk/dv accumulators stay at Hkv width (§Perf iter. 3).
    """
    b, s, hq, dh = q.shape
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    nq = -(-s // q_chunk)
    nkv = -(-s // kv_chunk)
    pad_q = nq * q_chunk - s
    pad_kv = nkv * kv_chunk - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    out = _flash(q, k, v, window, q_chunk, kv_chunk, s)
    return out[:, :s]


def decode_attention(
    q: jax.Array,        # [B, 1, Hq, Dh] — one new token
    k_cache: jax.Array,  # [B, S_cache, Hkv, Dh]
    v_cache: jax.Array,
    cur_len: jax.Array,  # [] int32 — number of valid cache positions
) -> jax.Array:
    """Single-step attention against a KV cache (masked beyond cur_len).

    GQA is computed *grouped* (q reshaped to [.., Hkv, rep, ..]) so the KV
    cache is never replicated to Hq — with 32 k caches that replication
    would dominate device memory."""
    b, sc, hkv, dh = k_cache.shape
    hq = q.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, 1, hkv, rep, dh)
    scale = 1.0 / math.sqrt(dh)
    sc_ = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_cache,
                     preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(sc) < cur_len
    sc_ = jnp.where(mask[None, None, None, None, :], sc_, NEG_INF)
    p = jax.nn.softmax(sc_, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v_cache)
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Dense layers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(jnp.float32)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; labels < 0 are masked out."""
    mask = labels >= 0
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), jnp.clip(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
