"""CompositeLM: every assigned architecture as a segment/repeat block stack.

A model is `prelude + repeats x segments`, where each StackSegment is
`count` identical blocks (scanned) of one BlockCfg.  This one structure
covers the whole zoo:

  homogeneous decoders      1 segment, count = L, repeats = 1
  interleaved dense/MoE     segments [(attn+dense, 1), (attn+moe, 1)], x L/2
  xLSTM 7:1                 segments [(mLSTM, 7), (sLSTM, 1)], x L/8
  Zamba2 shared-attention   prelude (mamba2, 2) + [(mamba2, 6), (attn, 1,
                            shared=True)] x 6 — the attention block's params
                            are stored ONCE and reused every repeat (its KV
                            cache is still per-invocation)

Layer scans keep the HLO size O(#segment kinds), not O(#layers) — a 48-layer
model lowers the same number of ops as a 1-layer model per segment, which is
what makes 80 dry-run compiles tractable and keeps live HLO small on device.

The embedding is backend-switchable (dense | hkv).  With the HKV backend the
token rows arrive as an explicit `embeds` input (the structural
find_or_insert happens OUTSIDE the differentiated function — inserter role),
and the LM head is untied.  Loss is computed in sequence chunks so the
[B, S, vocab] logits tensor never materializes (vocab 256 k x 4 k seq would
otherwise dominate memory).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.embedding.dense import DenseEmbedding
from repro.models.blocks import (
    BlockCfg,
    PosCtx,
    block_decode,
    block_init,
    block_state_init,
    block_train,
)
from repro.models import blocks as blocks_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    blocked_causal_attention,
    cross_entropy_loss,
    dense_init,
    init_rms,
    rms_norm,
    sinusoidal_embedding,
)


@dataclasses.dataclass(frozen=True)
class StackSegment:
    block: BlockCfg
    count: int
    shared: bool = False


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    vocab: int
    segments: tuple
    repeats: int = 1
    prelude: tuple = ()
    tied_head: bool = True
    pos_embedding: str = "none"          # none | sinusoidal
    embed_scale: bool = False            # gemma: x *= sqrt(d)
    embedding_backend: str = "dense"     # dense | hkv
    frontend: Optional[str] = None       # None | vision
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    loss_chunk: int = 512
    aux_weights: tuple = (("load_balance", 0.01), ("router_z", 0.001))
    remat: bool = True                   # activation-checkpoint each block
    # scan_layers=False unrolls layer loops in the TRAIN path (python loop).
    # Scan-over-layers + flash attention's custom_vjp currently interact
    # badly under lax.scan linearization: the backward's recomputed p
    # matrices are hoisted into the forward sweep and stacked per chunk
    # pair, resurrecting an O(S^2) (and poorly shardable) buffer.  Unrolling
    # restores plain reverse-mode AD, where the custom bwd runs opaquely.
    # Costs: HLO size O(layers) in the train graph (compile time), while
    # prefill/decode keep scanning (their memory is fine).
    scan_layers: bool = False

    @property
    def num_layers(self) -> int:
        pre = sum(s.count for s in self.prelude)
        rep = sum(s.count for s in self.segments) * self.repeats
        return pre + rep


def _aux_zero(seg: StackSegment) -> dict:
    if seg.block.moe is not None:
        return {"load_balance": jnp.float32(0), "router_z": jnp.float32(0),
                "dropped_frac": jnp.float32(0)}
    return {}


def _aux_add(a: dict, b: dict) -> dict:
    return {k: a[k] + b[k] for k in a} if a else {}


class CompositeLM:
    def __init__(self, cfg: LMConfig):
        self.cfg = cfg
        if cfg.embedding_backend == "dense":
            self.embedding = DenseEmbedding(cfg.vocab, cfg.d_model)
        else:
            self.embedding = None  # rows provided externally (HKV path)

    # ------------------------------------------------------------------ init

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, 4 + len(cfg.prelude) + len(cfg.segments))
        params: dict = {"final_norm": init_rms(cfg.d_model)}
        ki = iter(range(len(keys)))
        if self.embedding is not None:
            params["embed"] = self.embedding.init(keys[next(ki)])
        if not cfg.tied_head or self.embedding is None:
            params["head"] = dense_init(keys[next(ki)], cfg.d_model, cfg.vocab)

        def stacked_init(block, key, *lead):
            n = 1
            for d in lead:
                n *= d
            ks = jax.random.split(key, n).reshape(lead + (2,))
            f = lambda k: block_init(block, k)
            for _ in lead:
                f = jax.vmap(f)
            return f(ks)

        params["prelude"] = [
            stacked_init(s.block, keys[next(ki)], s.count) for s in cfg.prelude
        ]
        params["repeat"] = []
        params["shared"] = []
        for s in cfg.segments:
            k = keys[next(ki)]
            if s.shared:
                params["shared"].append(block_init(s.block, k))
                params["repeat"].append(None)
            else:
                params["repeat"].append(stacked_init(s.block, k, cfg.repeats, s.count))
                params["shared"].append(None)
        return params

    # --------------------------------------------------------------- forward

    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        x = self.embedding.lookup(params["embed"], tokens).astype(cfg.dtype)
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cfg.dtype)
        return x

    def _inputs(self, params, tokens, embeds, frontend_embeds, mrope_positions):
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(cfg.dtype)
            if cfg.embed_scale:
                x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cfg.dtype)
        else:
            x = self._embed_tokens(params, tokens)
        if frontend_embeds is not None:  # stub modality frontend (vision)
            sv = frontend_embeds.shape[1]
            x = jnp.concatenate([frontend_embeds.astype(cfg.dtype), x[:, sv:]], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.pos_embedding == "sinusoidal":
            x = x + sinusoidal_embedding(positions, cfg.d_model).astype(cfg.dtype)
        pos = PosCtx(positions=positions, mrope_positions=mrope_positions)
        return x, pos

    def _apply_stack(self, params, x, pos):
        cfg = self.cfg
        aux_total = {"load_balance": jnp.float32(0), "router_z": jnp.float32(0)}

        def scan_layers(seg, seg_params, x):
            a0 = _aux_zero(seg)
            import functools

            bt = functools.partial(block_train, seg.block)
            if cfg.remat:
                # per-block activation checkpointing: backward recomputes the
                # block from its input; only layer boundaries are saved
                bt = jax.checkpoint(bt)

            def body(carry, lp):
                x, aux = carry
                x2, a = bt(lp, x, pos)
                return (x2, _aux_add(aux, a)), None

            if cfg.scan_layers:
                (x, aux), _ = jax.lax.scan(body, (x, a0), seg_params)
            else:
                n = jax.tree.leaves(seg_params)[0].shape[0]
                aux = a0
                for i in range(n):
                    (x, aux), _ = body(
                        (x, aux), jax.tree.map(lambda a: a[i], seg_params)
                    )
            return x, aux

        def fold_aux(aux_total, aux):
            for k in ("load_balance", "router_z"):
                if k in aux:
                    aux_total[k] = aux_total[k] + aux[k]
            return aux_total

        for seg, sp in zip(cfg.prelude, params["prelude"]):
            x, aux = scan_layers(seg, sp, x)
            aux_total = fold_aux(aux_total, aux)

        if cfg.segments:
            rep_xs = [p for p in params["repeat"] if p is not None]

            def rep_body(carry, slices):
                x, aux_total = carry
                it = iter(slices)
                for si, seg in enumerate(cfg.segments):
                    sp = (
                        jax.tree.map(lambda a: a[None], params["shared"][si])
                        if seg.shared
                        else next(it)
                    )
                    x, aux = scan_layers(seg, sp, x)
                    aux_total = fold_aux(aux_total, aux)
                return (x, aux_total), None

            if cfg.scan_layers:
                (x, aux_total), _ = jax.lax.scan(
                    rep_body, (x, aux_total), tuple(rep_xs)
                )
            else:
                for r in range(cfg.repeats):
                    (x, aux_total), _ = rep_body(
                        (x, aux_total),
                        tuple(jax.tree.map(lambda a: a[r], p) for p in rep_xs),
                    )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux_total

    def hidden(self, params, tokens=None, *, embeds=None, frontend_embeds=None,
               mrope_positions=None):
        x, pos = self._inputs(params, tokens, embeds, frontend_embeds, mrope_positions)
        return self._apply_stack(params, x, pos)

    # ------------------------------------------------------------------ loss

    def logits(self, params, hidden_chunk):
        cfg = self.cfg
        if cfg.tied_head and self.embedding is not None and "head" not in params:
            return self.embedding.attend(params["embed"], hidden_chunk)
        return hidden_chunk @ params["head"].astype(hidden_chunk.dtype)

    def loss(self, params, tokens=None, labels=None, *, embeds=None,
             frontend_embeds=None, mrope_positions=None):
        cfg = self.cfg
        h, aux = self.hidden(
            params, tokens, embeds=embeds, frontend_embeds=frontend_embeds,
            mrope_positions=mrope_positions,
        )
        b, s, d = h.shape
        ck = min(cfg.loss_chunk, s)
        assert s % ck == 0
        hc = h.reshape(b, s // ck, ck, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(b, s // ck, ck).transpose(1, 0, 2)

        def per_chunk(args):
            hx, lx = args
            return cross_entropy_loss(self.logits(params, hx), lx)

        ce = jnp.mean(jax.lax.map(per_chunk, (hc, lc)))
        total = ce
        for k, w in cfg.aux_weights:
            total = total + w * aux.get(k, 0.0)
        return total, {"ce": ce, **aux}

    # ----------------------------------------------------------------- serve

    def _all_segments(self):
        """Yields ('prelude'|'repeat', idx, segment)."""
        for i, s in enumerate(self.cfg.prelude):
            yield "prelude", i, s
        for i, s in enumerate(self.cfg.segments):
            yield "repeat", i, s

    def init_decode_state(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        st = {"prelude": [], "repeat": [], "pos": jnp.zeros((), jnp.int32)}
        for s in cfg.prelude:
            one = block_state_init(s.block, batch, max_len, cfg.dtype)
            st["prelude"].append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (s.count,) + a.shape), one))
        for s in cfg.segments:
            one = block_state_init(s.block, batch, max_len, cfg.dtype)
            st["repeat"].append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.repeats, s.count) + a.shape), one))
        return st

    def decode_step(self, params, tokens, state, *, embeds=None):
        """One new token per sequence. tokens: [B] int32 (or embeds [B,1,d])."""
        cfg = self.cfg
        step = state["pos"]
        if embeds is None:
            x = self._embed_tokens(params, tokens[:, None])
        else:
            x = embeds.astype(cfg.dtype)
            if cfg.embed_scale:
                x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cfg.dtype)
        b = x.shape[0]
        positions = jnp.full((b, 1), step, jnp.int32)
        if cfg.pos_embedding == "sinusoidal":
            x = x + sinusoidal_embedding(positions, cfg.d_model).astype(cfg.dtype)
        mrope = jnp.broadcast_to(positions[None], (3, b, 1))
        pos = PosCtx(positions=positions, mrope_positions=mrope, step=step)

        new_state = {"prelude": [], "repeat": [], "pos": step + 1}

        def scan_layers(seg, seg_params, seg_state, x):
            def body(x, inp):
                lp, ls = inp
                x2, ls2 = block_decode(seg.block, lp, x, ls, pos)
                return x2, ls2

            if cfg.scan_layers:
                x, new_ls = jax.lax.scan(body, x, (seg_params, seg_state))
            else:
                n = jax.tree.leaves(seg_params)[0].shape[0]
                outs = []
                for i in range(n):
                    x, ls2 = body(x, (jax.tree.map(lambda a: a[i], seg_params),
                                      jax.tree.map(lambda a: a[i], seg_state)))
                    outs.append(ls2)
                new_ls = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            return x, new_ls

        for i, s in enumerate(cfg.prelude):
            x, ns = scan_layers(s, params["prelude"][i], state["prelude"][i], x)
            new_state["prelude"].append(ns)

        if cfg.segments:
            rep_params = [p for p in params["repeat"] if p is not None]

            def rep_body(x, slices):
                pslices, sslices = slices
                it = iter(pslices)
                new_sts = []
                for si, seg in enumerate(cfg.segments):
                    sp = (
                        jax.tree.map(lambda a: a[None], params["shared"][si])
                        if seg.shared
                        else next(it)
                    )
                    x, ns = scan_layers(seg, sp, sslices[si], x)
                    new_sts.append(ns)
                return x, tuple(new_sts)

            if cfg.scan_layers:
                x, new_rep = jax.lax.scan(
                    rep_body, x, (tuple(rep_params), tuple(state["repeat"]))
                )
                new_state["repeat"] = list(new_rep)
            else:
                reps = []
                for r in range(cfg.repeats):
                    x, ns = rep_body(
                        x,
                        (tuple(jax.tree.map(lambda a: a[r], p) for p in rep_params),
                         tuple(jax.tree.map(lambda a: a[r], s) for s in state["repeat"])),
                    )
                    reps.append(ns)
                new_state["repeat"] = list(
                    jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
                )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.logits(params, x)[:, 0]
        return logits, new_state

    def prefill(self, params, tokens, max_len: int, *, embeds=None,
                frontend_embeds=None, mrope_positions=None):
        """Process a prompt, build decode state, return last-position logits.

        Implemented as hidden() for the logits plus a state-building pass:
        attention blocks re-derive K/V (cheap relative to attention itself),
        SSM blocks get their final recurrent state from the chunked scan.
        """
        cfg = self.cfg
        x, pos = self._inputs(params, tokens, embeds, frontend_embeds, mrope_positions)
        s = x.shape[1]
        state = {"prelude": [], "repeat": [], "pos": jnp.zeros((), jnp.int32) + s}

        def scan_layers(seg, seg_params, x):
            def body(x, lp):
                x2, st = _block_prefill(seg.block, lp, x, pos, max_len, cfg.dtype)
                return x2, st

            if cfg.scan_layers:
                return jax.lax.scan(body, x, seg_params)
            n = jax.tree.leaves(seg_params)[0].shape[0]
            sts = []
            for i in range(n):
                x, st = body(x, jax.tree.map(lambda a: a[i], seg_params))
                sts.append(st)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs), *sts)

        for i, seg in enumerate(cfg.prelude):
            x, st = scan_layers(seg, params["prelude"][i], x)
            state["prelude"].append(st)

        if cfg.segments:
            rep_params = [p for p in params["repeat"] if p is not None]

            def rep_body(x, pslices):
                it = iter(pslices)
                sts = []
                for si, seg in enumerate(cfg.segments):
                    sp = (
                        jax.tree.map(lambda a: a[None], params["shared"][si])
                        if seg.shared
                        else next(it)
                    )
                    x, st = scan_layers(seg, sp, x)
                    sts.append(st)
                return x, tuple(sts)

            if cfg.scan_layers:
                x, rep_states = jax.lax.scan(rep_body, x, tuple(rep_params))
                state["repeat"] = list(rep_states)
            else:
                reps = []
                for r in range(cfg.repeats):
                    x, sts = rep_body(
                        x, tuple(jax.tree.map(lambda a: a[r], p) for p in rep_params)
                    )
                    reps.append(sts)
                state["repeat"] = list(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.logits(params, x[:, -1:])[:, 0]
        return logits, state


# ---------------------------------------------------------------------------
# per-block prefill (full-seq forward that also emits the decode state)
# ---------------------------------------------------------------------------


def _block_prefill(bcfg: BlockCfg, p: dict, x, pos: PosCtx, max_len: int, dtype):
    b, s, _ = x.shape
    if bcfg.kind == "attn":
        q, k, v = blocks_mod._qkv(bcfg, p, x, pos)
        o = blocked_causal_attention(q, k, v, window=bcfg.window)
        x = x + (o.reshape(b, s, -1) @ p["wo"].astype(x.dtype))
        f, _ = blocks_mod._ffn(bcfg, p, x)
        x = x + f
        clen = min(max_len, bcfg.window) if bcfg.window else max_len
        kc = jnp.zeros((b, clen, bcfg.kv_heads, bcfg.hd), dtype)
        vc = jnp.zeros_like(kc)
        if bcfg.window and s >= clen:
            # ring layout: absolute position t lives in slot t % window
            tail_k, tail_v = k[:, -clen:], v[:, -clen:]
            shift = (s - clen) % clen
            kc = jnp.roll(tail_k.astype(dtype), shift, axis=1)
            vc = jnp.roll(tail_v.astype(dtype), shift, axis=1)
        else:
            kc = jax.lax.dynamic_update_slice(kc, k.astype(dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(dtype), (0, 0, 0, 0))
        return x, {"k": kc, "v": vc}
    if bcfg.kind == "mamba2":
        z, xs, Bm, Cm, dt = blocks_mod._mamba2_split(bcfg, p, x)
        xs_c = blocks_mod._causal_conv(xs, p["conv_w"], p["conv_b"])
        q, k, v, log_a, xh = blocks_mod._mamba2_gla_inputs(bcfg, p, xs_c, Bm, Cm, dt)
        y, gla = ssm_mod.chunked_gla(q, k, v, log_a)
        out = blocks_mod._mamba2_out(bcfg, p, x, y, xh, z)
        w = bcfg.conv_width - 1
        conv_hist = xs[:, -w:] if s >= w else jnp.pad(xs, ((0, 0), (w - s, 0), (0, 0)))
        return out, {"gla": gla, "conv": conv_hist.astype(dtype)}
    if bcfg.kind == "mlstm":
        q, k, v_aug, log_f, zg = blocks_mod._mlstm_qkv(bcfg, p, x)
        y_aug, gla = ssm_mod.chunked_gla(q, k, v_aug, log_f)
        return blocks_mod._mlstm_out(bcfg, p, x, y_aug, zg), {"gla": gla}
    if bcfg.kind == "slstm":
        xg = rms_norm(x, p["ln"], bcfg.norm_eps) @ p["wx"].astype(x.dtype)
        st = blocks_mod._slstm_state_init(bcfg, b, s, x.dtype)
        carry = (st["c"], st["n"], st["h"], st["m"])

        def step(carry, xg_t):
            return blocks_mod._slstm_cell(bcfg, p, xg_t, carry)

        carry, hs = jax.lax.scan(step, carry, xg.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2, 3).reshape(b, s, -1).astype(x.dtype)
        out = x + h @ p["out"].astype(x.dtype)
        return out, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    raise ValueError(bcfg.kind)
