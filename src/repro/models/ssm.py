"""Chunked gated-linear-attention (GLA) primitive + recurrent step.

Mamba2's SSD and xLSTM's mLSTM are both instances of the same recurrence

    S_t = a_t * S_{t-1} + k_t ⊗ v_t          (state: [N, P] per head)
    y_t = q_t · S_t

with per-(head, step) scalar decay a_t ∈ (0, 1].  `chunked_gla` evaluates it
in O(S·N·P + S·L) time with the standard chunked formulation (intra-chunk
quadratic term + inter-chunk state scan), which is also the TPU-friendly
form: every term is a matmul over chunk-sized tiles, and sequence length
enters only through the (parallelizable) chunk scan.

All decay arithmetic happens in log space with log a ≤ 0, so every
exponential in the algorithm is ≤ 1 — unconditionally stable in bf16.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_gla(
    q: jax.Array,       # [B, S, H, N]
    k: jax.Array,       # [B, S, H, N]
    v: jax.Array,       # [B, S, H, P]
    log_a: jax.Array,   # [B, S, H]  (log decay, <= 0)
    chunk: int = 128,
    initial_state: jax.Array | None = None,  # [B, H, N, P]
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, H, P], final_state [B, H, N, P])."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded steps must not decay the carried state: log a = 0
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))

    # [nc, B, L, H, ...]
    qc = q.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    lac = log_a.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def per_chunk(state, inp):
        qq, kk, vv, la = inp                      # [B, L, H, *]
        A = jnp.cumsum(la, axis=1)                # inclusive cum-log-decay [B, L, H]
        # intra-chunk: score_ij = (q_i . k_j) * exp(A_i - A_j), j <= i
        sc = jnp.einsum("bihn,bjhn->bhij", qq, kk, preferred_element_type=jnp.float32)
        decay = A.transpose(0, 2, 1)[:, :, :, None] - A.transpose(0, 2, 1)[:, :, None, :]
        sc = sc * jnp.exp(jnp.where(causal[None, None], decay, -jnp.inf))
        y_intra = jnp.einsum("bhij,bjhp->bihp", sc.astype(vv.dtype), vv,
                             preferred_element_type=jnp.float32)
        # inter-chunk: y_i += exp(A_i) * q_i . S_prev
        qdec = qq * jnp.exp(A)[..., None].astype(qq.dtype)
        y_inter = jnp.einsum("bihn,bhnp->bihp", qdec, state.astype(qq.dtype),
                             preferred_element_type=jnp.float32)
        # state update: S' = exp(A_L) S + sum_j exp(A_L - A_j) k_j (x) v_j
        a_last = A[:, -1, :]                      # [B, H]
        kdec = kk * jnp.exp(a_last[:, None, :] - A)[..., None].astype(kk.dtype)
        outer = jnp.einsum("bjhn,bjhp->bhnp", kdec, vv,
                           preferred_element_type=jnp.float32)
        state = state * jnp.exp(a_last)[..., None, None] + outer
        return state, (y_intra + y_inter).astype(v.dtype)

    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )
    final_state, ys = jax.lax.scan(per_chunk, state0, (qc, kc, vc, lac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, p)
    return y[:, :s], final_state


def gla_step(
    state: jax.Array,   # [B, H, N, P]
    q: jax.Array,       # [B, H, N]
    k: jax.Array,       # [B, H, N]
    v: jax.Array,       # [B, H, P]
    log_a: jax.Array,   # [B, H]
) -> tuple[jax.Array, jax.Array]:
    """Single decode step of the same recurrence. Returns (y [B,H,P], state)."""
    state = state * jnp.exp(log_a)[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", k, v, preferred_element_type=jnp.float32
    )
    y = jnp.einsum("bhn,bhnp->bhp", q, state.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(v.dtype), state


def gla_reference(q, k, v, log_a):
    """O(S^2)-free sequential oracle for tests: step-by-step recurrence."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    state = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        y, state = gla_step(state, q[:, t], k[:, t], v[:, t], log_a[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state
