"""The public HKV surface: the `HKVTable` handle + role-aware op sessions.

Layering (DESIGN.md §API layer):

  handle   `HKVTable` — a pytree-registered value object binding an
           `HKVState` (the single leaf) to its static description
           (`HKVConfig`, inserter backend).  Because cfg/backend live in
           pytree aux data, a handle passes through `jax.jit` (donatable),
           `jax.lax.scan` carries, checkpoint trees, and `shard_map`
           without any (state, cfg) re-threading by the caller.
  engine   `repro.core.ops` — the free functions the handle delegates to.
           They remain the single implementation of every op; the handle
           adds no semantics, only binding + key normalization.
  session  `OpSession` — the paper's triple-group taxonomy (§3.5) made
           first-class: record reader/updater/inserter ops, share one
           `locate` across commuting ops on the same key batch, serialize
           only at inserters, and show the fused plan via `explain()`.

Key normalization: every handle/session op accepts keys as a `U64` pair,
a numpy `uint64` array, a python int list, or a signed int array (negative
ids become the EMPTY padding sentinel, matching the embedding layer) —
all funneled through `normalize_keys`, the single conversion point.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import find as find_mod
from repro.core import ops as ops_mod
from repro.core import roles as roles_mod
from repro.core import table as table_mod
from repro.core import u64
from repro.core.predicates import SweepPredicate
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64

# =============================================================================
# Key normalization — the single entry point for every key-shaped argument
# =============================================================================


def normalize_keys(keys: Any) -> U64:
    """Coerce caller keys to the canonical U64 (hi, lo) plane pair.

    Accepted forms:
      * `U64`                      — passed through;
      * numpy uint64 array/scalar  — exact 64-bit split (host-side);
      * signed int array (numpy or jax) / python int list — non-negative
        ids map to their unsigned value; NEGATIVE ids become the EMPTY
        sentinel (the padding convention of the embedding layer);
      * unsigned 32-bit arrays     — zero-extended into the low plane.
    """
    if isinstance(keys, U64):
        return keys
    if isinstance(keys, (list, tuple, int, np.generic)):
        # np.generic: numpy SCALARS (np.uint64(x)) are not ndarrays and
        # would otherwise fall through to jnp.asarray, which downcasts
        # uint64 to uint32 when x64 is disabled
        keys = np.atleast_1d(np.asarray(keys))
    if isinstance(keys, np.ndarray):
        keys = np.atleast_1d(keys)
        if keys.dtype == np.uint64:
            return u64.from_uint64(keys)
        if np.issubdtype(keys.dtype, np.signedinteger):
            arr = keys.astype(np.int64)
            neg = arr < 0
            as_u = arr.astype(np.uint64)
            hi = np.where(neg, u64.EMPTY_HI,
                          (as_u >> np.uint64(32)).astype(np.uint32))
            lo = np.where(neg, u64.EMPTY_LO,
                          (as_u & u64.UINT32_MASK).astype(np.uint32))
            return U64(jnp.asarray(hi.astype(np.uint32)),
                       jnp.asarray(lo.astype(np.uint32)))
        if np.issubdtype(keys.dtype, np.unsignedinteger):
            lo = jnp.asarray(keys.astype(np.uint32))
            return U64(jnp.zeros(lo.shape, jnp.uint32), lo)
        raise TypeError(f"cannot use {keys.dtype} array as table keys")
    x = jnp.atleast_1d(jnp.asarray(keys))
    if x.dtype == jnp.uint32:
        return U64(jnp.zeros(x.shape, jnp.uint32), x)
    if jnp.issubdtype(x.dtype, jnp.signedinteger):
        neg = x < 0
        if x.dtype.itemsize == 8:  # int64 under jax x64: keep the high bits
            hi_bits = jax.lax.shift_right_logical(x, 32).astype(jnp.uint32)
        else:
            hi_bits = jnp.zeros(x.shape, jnp.uint32)
        return U64(
            jnp.where(neg, jnp.uint32(u64.EMPTY_HI), hi_bits),
            jnp.where(neg, jnp.uint32(u64.EMPTY_LO), x.astype(jnp.uint32)),
        )
    raise TypeError(f"cannot use {x.dtype} values as table keys")


def dedupe_keys(keys: Any) -> "DedupeResult":
    """Public dedupe helper: key normalization + the engine's canonical
    dedupe (`repro.core.merge.dedupe_keys`, the single implementation).

    Consumers (embedding gradient paths, shard routing) use this instead of
    reaching into merge internals: route/reduce per `unique`, then map
    per-group results back with `inverse`.
    """
    from repro.core import merge as merge_mod

    return merge_mod.dedupe_keys(normalize_keys(keys))


def _key_identity(keys: Any):
    """Identity token for session key-batch sharing.

    Two ops recorded with the *same object* (same U64 planes or the same
    array) share a locate; distinct objects are conservatively treated as
    distinct batches even if value-equal.
    """
    if isinstance(keys, U64):
        return ("u64", id(keys.hi), id(keys.lo))
    return ("obj", id(keys))


# =============================================================================
# Handle-level result tuples (state replaced by the new handle)
# =============================================================================


class TableUpsert(NamedTuple):
    table: "HKVTable"
    status: jax.Array    # int8 [N] — merge status codes, batch order

    @property
    def ok(self) -> jax.Array:
        """bool [N] — key is present after the op (updated/inserted/evicted)."""
        return (self.status >= ops_mod.STATUS_UPDATED) & (
            self.status <= ops_mod.STATUS_EVICTED
        )


class TableInsertAndEvict(NamedTuple):
    table: "HKVTable"
    status: jax.Array
    evicted: "ops_mod.EvictionStream"   # the in-launch eviction hand-off


class TableFindOrInsert(NamedTuple):
    table: "HKVTable"
    values: jax.Array
    found: jax.Array
    status: jax.Array
    evicted: "ops_mod.EvictionStream"   # populated iff return_evicted


class TableSweep(NamedTuple):
    table: "HKVTable"
    swept: jax.Array     # int32 [] — entries removed by the sweep


class TableEvictIf(NamedTuple):
    table: "HKVTable"
    evicted: "ops_mod.EvictionStream"   # rank-aligned: lane i = i-th coldest
    count: jax.Array     # int32 [] — live lanes in the stream


# =============================================================================
# The KVTable protocol — the one benchmark/consumer-facing contract
# =============================================================================


@runtime_checkable
class KVTable(Protocol):
    """Minimal table-object contract shared by `HKVTable`, the dict-semantic
    baselines (`repro.baselines.DictKVTable`), and `ShardedHKVTable`.

    Handles are immutable values: mutating ops return a result whose
    `.table` field is the successor handle.  `find(...)` results expose
    `.values` and `.found`; `insert_or_assign(...)` results expose
    `.table` and `.ok` (per-key success — for HKV, admission; for
    dictionary-semantic tables, placement).
    """

    @property
    def capacity(self) -> int: ...

    def find(self, keys: Any) -> Any: ...

    def insert_or_assign(self, keys: Any, values: jax.Array) -> Any: ...

    def contains(self, keys: Any) -> jax.Array: ...

    def size(self) -> jax.Array: ...

    def load_factor(self) -> jax.Array: ...

    # maintenance surface (DESIGN.md §Maintenance): predicated sweeps +
    # whole-table observability.  Results expose `.table`/`.swept` for
    # erase_if and `.table`/`.evicted`/`.count` for evict_if.
    def erase_if(self, pred: SweepPredicate) -> Any: ...

    def evict_if(self, pred: SweepPredicate, budget: int) -> Any: ...

    def stats(self) -> Any: ...


def table_signature(table: Any) -> tuple:
    """Static identity of a KVTable handle, for caching compiled closures.

    Long-lived consumers that bake a handle's STATIC properties into a
    jitted closure (the serving engine's wave fn, the maintenance
    scheduler's step fn) key the cache on this tuple and rebuild when a
    published successor changes shape: table family, backend, dim /
    total_value_dim (aux optimizer columns), and score policy.  Covers
    every handle family — tiered handles recurse per tier, handles
    without an `HKVConfig` (dict baselines, sharded) fall back to type +
    backend + dim."""
    hot, cold = getattr(table, "hot", None), getattr(table, "cold", None)
    if hot is not None and cold is not None:
        return (type(table).__name__, table_signature(hot),
                table_signature(cold))
    cfg = getattr(table, "cfg", None)
    if cfg is not None and hasattr(cfg, "total_value_dim"):
        return (type(table).__name__, getattr(table, "backend", None),
                cfg.dim, cfg.total_value_dim, cfg.score_policy)
    return (type(table).__name__, getattr(table, "backend", None),
            int(getattr(table, "dim", 0)))


# =============================================================================
# HKVTable — the handle
# =============================================================================


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HKVTable:
    """Cache-semantic HKV hash table as a jit-friendly handle.

    `state` is the only pytree leaf; `cfg` and `backend` are static aux
    data, so a jitted function taking an `HKVTable` specializes per config
    (exactly like passing cfg statically) while the state arrays flow —
    and may be donated — as ordinary buffers.

        table = HKVTable.create(capacity=128 * 128, dim=32)
        res = table.insert_or_assign(keys, values)   # res.table, res.status
        out = res.table.find(keys)                   # out.values, out.found
    """

    state: HKVState
    cfg: HKVConfig
    backend: str = "auto"

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        return (self.state,), (self.cfg, self.backend)

    @classmethod
    def tree_unflatten(cls, aux, children):
        cfg, backend = aux
        return cls(state=children[0], cfg=cfg, backend=backend)

    # -- construction ----------------------------------------------------------

    @classmethod
    def create(cls, cfg: Optional[HKVConfig] = None, *, backend: str = "auto",
               **cfg_kwargs) -> "HKVTable":
        """Allocate an empty table from an `HKVConfig` (or its kwargs)."""
        if cfg is None:
            cfg = HKVConfig(**cfg_kwargs)
        elif cfg_kwargs:
            cfg = dataclasses.replace(cfg, **cfg_kwargs)
        return cls(state=table_mod.create(cfg), cfg=cfg, backend=backend)

    @classmethod
    def wrap(cls, state: HKVState, cfg: HKVConfig,
             backend: str = "auto") -> "HKVTable":
        """Bind an existing state (e.g. a shard-local state under shard_map)."""
        return cls(state=state, cfg=cfg, backend=backend)

    def with_state(self, state: HKVState) -> "HKVTable":
        return dataclasses.replace(self, state=state)

    def with_backend(self, backend: str) -> "HKVTable":
        return dataclasses.replace(self, backend=backend)

    # -- config views ----------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.cfg.capacity

    @property
    def dim(self) -> int:
        return self.cfg.dim

    @property
    def num_buckets(self) -> int:
        """Export-space bucket count (the `export_batch` iteration bound)."""
        return self.cfg.num_buckets

    def keys(self, keys: Any) -> U64:
        """Expose the normalization point (useful for pre-normalizing once)."""
        return normalize_keys(keys)

    # -- readers ---------------------------------------------------------------

    # Readers thread the handle backend so backend='kernel' rides the FUSED
    # find_scan pass (one launch: match + scores + values) with no API
    # change — every handle-based consumer (tiered probes, shard bodies,
    # engine waves) inherits it automatically (DESIGN.md §Readers).
    #
    # Every keyed method also forwards the optional `telemetry=` sink to
    # the underlying op (DESIGN.md §Observability); `None` is the exact
    # pre-telemetry path.

    def find(self, keys: Any, *, telemetry=None) -> ops_mod.FindResult:
        return ops_mod.find(self.state, self.cfg, normalize_keys(keys),
                            backend=self.backend, telemetry=telemetry)

    def find_ptr(self, keys: Any, *, telemetry=None) -> find_mod.Locate:
        return ops_mod.find_ptr(self.state, self.cfg, normalize_keys(keys),
                                backend=self.backend, telemetry=telemetry)

    def find_rows(self, keys: Any, *,
                  telemetry=None) -> ops_mod.FindRowsResult:
        return ops_mod.find_rows(self.state, self.cfg, normalize_keys(keys),
                                 backend=self.backend, telemetry=telemetry)

    def contains(self, keys: Any, *, telemetry=None) -> jax.Array:
        return ops_mod.contains(self.state, self.cfg, normalize_keys(keys),
                                backend=self.backend, telemetry=telemetry)

    def probe_keys(self, keys: Any) -> find_mod.Probe:
        return find_mod.probe_keys(self.cfg, normalize_keys(keys))

    def size(self) -> jax.Array:
        return ops_mod.size(self.state)

    def load_factor(self) -> jax.Array:
        return ops_mod.load_factor(self.state)

    def export_batch(self, bucket_start: int,
                     bucket_count: int) -> ops_mod.ExportResult:
        return ops_mod.export_batch(self.state, self.cfg, bucket_start,
                                    bucket_count)

    def export_batch_if(self, bucket_start: int, bucket_count: int,
                        score_threshold: Any) -> ops_mod.ExportResult:
        return ops_mod.export_batch_if(self.state, self.cfg, bucket_start,
                                       bucket_count,
                                       normalize_keys(score_threshold))

    # -- updaters (non-structural; return the successor handle) ---------------

    def assign(self, keys: Any, values: jax.Array,
               update_scores: bool = False, *,
               telemetry=None) -> "HKVTable":
        return self.with_state(ops_mod.assign(
            self.state, self.cfg, normalize_keys(keys), values,
            update_scores=update_scores, telemetry=telemetry,
        ))

    def assign_add(self, keys: Any, deltas: jax.Array, *,
                   telemetry=None) -> "HKVTable":
        return self.with_state(ops_mod.assign_add(
            self.state, self.cfg, normalize_keys(keys), deltas,
            telemetry=telemetry,
        ))

    def assign_scores(self, keys: Any, scores: Any, *,
                      telemetry=None) -> "HKVTable":
        return self.with_state(ops_mod.assign_scores(
            self.state, self.cfg, normalize_keys(keys),
            normalize_keys(scores), telemetry=telemetry,
        ))

    # -- inserters (structural; return result tuples with `.table`) -----------

    def insert_or_assign(self, keys: Any, values: jax.Array,
                         custom_scores: Optional[Any] = None, *,
                         telemetry=None) -> TableUpsert:
        res = ops_mod.insert_or_assign(
            self.state, self.cfg, normalize_keys(keys), values,
            custom_scores=_opt_keys(custom_scores), backend=self.backend,
            telemetry=telemetry,
        )
        return TableUpsert(table=self.with_state(res.state), status=res.status)

    def insert_and_evict(self, keys: Any, values: jax.Array,
                         custom_scores: Optional[Any] = None, *,
                         telemetry=None) -> TableInsertAndEvict:
        res = ops_mod.insert_and_evict(
            self.state, self.cfg, normalize_keys(keys), values,
            custom_scores=_opt_keys(custom_scores), backend=self.backend,
            telemetry=telemetry,
        )
        return TableInsertAndEvict(table=self.with_state(res.state),
                                   status=res.status, evicted=res.evicted)

    def find_or_insert(self, keys: Any, init_values: jax.Array,
                       custom_scores: Optional[Any] = None,
                       return_evicted: bool = False, *,
                       telemetry=None) -> TableFindOrInsert:
        res = ops_mod.find_or_insert(
            self.state, self.cfg, normalize_keys(keys), init_values,
            custom_scores=_opt_keys(custom_scores), backend=self.backend,
            return_evicted=return_evicted, telemetry=telemetry,
        )
        return TableFindOrInsert(table=self.with_state(res.state),
                                 values=res.values, found=res.found,
                                 status=res.status, evicted=res.evicted)

    def ingest(self, keys: Any, init_values: jax.Array,
               custom_scores: Optional[Any] = None, *,
               telemetry=None) -> TableUpsert:
        res = ops_mod.ingest(
            self.state, self.cfg, normalize_keys(keys), init_values,
            custom_scores=_opt_keys(custom_scores), backend=self.backend,
            telemetry=telemetry,
        )
        return TableUpsert(table=self.with_state(res.state), status=res.status)

    def accum_or_assign(self, keys: Any, values: jax.Array,
                        custom_scores: Optional[Any] = None, *,
                        telemetry=None) -> TableUpsert:
        res = ops_mod.accum_or_assign(
            self.state, self.cfg, normalize_keys(keys), values,
            custom_scores=_opt_keys(custom_scores), telemetry=telemetry,
        )
        return TableUpsert(table=self.with_state(res.state), status=res.status)

    def erase(self, keys: Any, *, telemetry=None) -> "HKVTable":
        return self.with_state(ops_mod.erase(self.state, self.cfg,
                                             normalize_keys(keys),
                                             telemetry=telemetry))

    def clear(self) -> "HKVTable":
        return self.with_state(ops_mod.clear(self.state, self.cfg))

    # -- maintenance (predicated sweeps + observability; DESIGN.md
    # §Maintenance) --------------------------------------------------------

    def erase_if(self, pred: SweepPredicate, *, telemetry=None) -> TableSweep:
        """Inserter (structural). Remove every live entry matching `pred`
        (TTL expiry: `SweepPredicate.expire_before(epoch)`)."""
        res = ops_mod.erase_if(self.state, self.cfg, pred,
                               backend=self.backend, telemetry=telemetry)
        return TableSweep(table=self.with_state(res.state), swept=res.swept)

    def evict_if(self, pred: SweepPredicate, budget: int,
                 limit: Optional[jax.Array] = None, *,
                 telemetry=None) -> TableEvictIf:
        """Inserter (structural). Remove up to `budget` matching entries,
        coldest first, returning them as an `EvictionStream` (the
        maintenance primitive tier rebalancing demotes through)."""
        res = ops_mod.evict_if(self.state, self.cfg, pred, budget,
                               limit=limit, backend=self.backend,
                               telemetry=telemetry)
        return TableEvictIf(table=self.with_state(res.state),
                            evicted=res.evicted, count=res.count)

    def stats(self) -> Any:
        """Whole-table `TableStats` (occupancy histogram, score quantiles,
        load factor — repro.maintenance.stats)."""
        from repro.maintenance import stats as stats_mod  # deferred: layering

        s = self.state
        return stats_mod.stats_from_planes(s.key_hi, s.key_lo,
                                           s.score_hi, s.score_lo)

    @property
    def epoch(self) -> jax.Array:
        """The application epoch (the epoch_* policies' TTL clock)."""
        return self.state.epoch

    def set_epoch(self, epoch: Any) -> "HKVTable":
        """Stamp a new application epoch (uint32; the TTL window clock)."""
        return self.with_state(table_mod.set_epoch(self.state, epoch))

    # -- sessions --------------------------------------------------------------

    def session(self) -> "OpSession":
        """Open a role-aware op session against this handle (see OpSession)."""
        return OpSession(self)


def _opt_keys(x: Optional[Any]) -> Optional[U64]:
    return None if x is None else normalize_keys(x)


# =============================================================================
# Op sessions — the triple-group taxonomy as a planner
# =============================================================================

# The session's role vocabulary IS the annotation vocabulary (core.roles):
# hkv-lint cross-checks every recorded op's role against the @roles.*
# annotation on its core.ops counterpart.
_READER, _UPDATER, _INSERTER = (roles_mod.READER, roles_mod.UPDATER,
                                roles_mod.INSERTER)


class SessionRef:
    """Deferred result of a session op; `.value` is set by `commit()`."""

    __slots__ = ("op", "value", "_committed")

    def __init__(self, op: str):
        self.op = op
        self.value = None
        self._committed = False

    def get(self):
        if not self._committed:
            raise RuntimeError(
                f"session op {self.op!r} not executed yet — call session.commit()"
            )
        return self.value

    def __repr__(self):
        state = "pending" if not self._committed else f"value={type(self.value).__name__}"
        return f"<SessionRef {self.op} {state}>"


@dataclasses.dataclass
class _RecordedOp:
    kind: str                    # op name
    role: str                    # reader | updater | inserter
    key_ref: Optional[int]       # index into session key batches (None: keyless)
    args: tuple                  # op-specific payload
    ref: SessionRef
    shares_locate: bool = False  # resolved at plan time


class OpSession:
    """Collect table ops, fuse commuting probes, serialize only at inserters.

    The paper's triple-group role taxonomy (§3.5) gives three facts the
    planner exploits:

      * READERS and UPDATERS never change bucket membership, so the
        (bucket, slot, row) positions returned by `locate` stay valid
        across any run of them — ops on the same key batch can share ONE
        probe where an unfused sequence would issue one each;
      * UPDATERS thread state (values/scores change) but commute with
        readers' key-side work;
      * INSERTERS are structural: each one is a serialization point that
        invalidates every cached locate.

    Usage::

        s = table.session()
        hit = s.find(keys)                  # reader  — SessionRef
        s.assign(keys, new_values)          # updater — shares hit's locate
        st = s.insert_or_assign(k2, v2)     # inserter — serialization point
        table = s.commit()                  # execute; refs hold results
        print(s.explain())                  # the fused plan, human-readable

    Results are bit-identical to issuing the same ops unfused in the same
    order: sharing a locate is exact (not approximate) because locate
    output depends only on the key plane, which non-structural ops never
    write.
    """

    def __init__(self, table: HKVTable):
        self._table = table
        self._ops: list[_RecordedOp] = []
        self._key_ids: dict = {}       # identity token -> batch index
        self._key_batches: list[U64] = []
        self._key_objs: list = []      # originals, retained — see _key_ref
        self._committed = False
        self._result_table: Optional[HKVTable] = None

    # -- key batch bookkeeping -------------------------------------------------

    def _key_ref(self, keys: Any) -> int:
        tok = _key_identity(keys)
        if tok not in self._key_ids:
            self._key_ids[tok] = len(self._key_batches)
            self._key_batches.append(normalize_keys(keys))
            # retain the ORIGINAL object: identity is id()-based, and a
            # garbage-collected array's id can be recycled by a later,
            # different key batch — which would silently alias the two
            self._key_objs.append(keys)
        return self._key_ids[tok]

    def _record(self, kind: str, role: str, keys: Any, *args) -> SessionRef:
        if self._committed:
            raise RuntimeError("session already committed; open a new one")
        ref = SessionRef(kind)
        kref = None if keys is None else self._key_ref(keys)
        self._ops.append(_RecordedOp(kind, role, kref, args, ref))
        return ref

    # -- recorded ops ----------------------------------------------------------

    # readers
    def find(self, keys: Any) -> SessionRef:
        return self._record("find", _READER, keys)

    def find_rows(self, keys: Any) -> SessionRef:
        return self._record("find_rows", _READER, keys)

    def contains(self, keys: Any) -> SessionRef:
        return self._record("contains", _READER, keys)

    # updaters
    def assign(self, keys: Any, values: jax.Array,
               update_scores: bool = False) -> SessionRef:
        return self._record("assign", _UPDATER, keys, values, update_scores)

    def assign_add(self, keys: Any, deltas: jax.Array) -> SessionRef:
        return self._record("assign_add", _UPDATER, keys, deltas)

    def assign_scores(self, keys: Any, scores: Any) -> SessionRef:
        return self._record("assign_scores", _UPDATER, keys,
                            normalize_keys(scores))

    def update_rows(self, keys: Any, fn, update_scores: bool = False
                    ) -> SessionRef:
        """Updater. Fused read-modify-write: rows[k] = fn(rows[k]) for
        existing keys (misses untouched; fn sees zero rows there).

        `fn` is either a callable mapping the gathered full-width rows
        [N, dim+aux] to replacement rows, or an `ops.RowUpdate` — the
        structured gradient-step payload (sparse-optimizer variant +
        segment-summed grads).  A callable shares the session's ONE locate
        (the unfused find_rows + assign issues two); a `RowUpdate` with no
        already-shared locate goes further: commit() routes it whole to
        `ops.update_rows`, which on the kernel backend is the fused
        update_scan pass — probe + optimizer apply + write-back in ONE
        launch.  The ref resolves to an `ops.UpdateRowsResult` for a
        `RowUpdate` and to the gathered `FindRowsResult` for a callable.
        """
        return self._record("update_rows", _UPDATER, keys, fn, update_scores)

    # inserters
    def insert_or_assign(self, keys: Any, values: jax.Array,
                         custom_scores: Optional[Any] = None) -> SessionRef:
        return self._record("insert_or_assign", _INSERTER, keys, values,
                            _opt_keys(custom_scores))

    def find_or_insert(self, keys: Any, init_values: jax.Array,
                       custom_scores: Optional[Any] = None) -> SessionRef:
        return self._record("find_or_insert", _INSERTER, keys, init_values,
                            _opt_keys(custom_scores))

    def insert_and_evict(self, keys: Any, values: jax.Array,
                         custom_scores: Optional[Any] = None) -> SessionRef:
        return self._record("insert_and_evict", _INSERTER, keys, values,
                            _opt_keys(custom_scores))

    def erase(self, keys: Any) -> SessionRef:
        return self._record("erase", _INSERTER, keys)

    # -- planning --------------------------------------------------------------

    def _plan(self) -> list[list[_RecordedOp]]:
        """Split the op list into fusion groups at inserter boundaries and
        mark which non-structural ops reuse a previously issued locate."""
        groups: list[list[_RecordedOp]] = []
        cur: list[_RecordedOp] = []
        seen: set = set()
        for op in self._ops:
            if op.role == _INSERTER:
                if cur:
                    groups.append(cur)
                    cur = []
                op.shares_locate = False
                groups.append([op])
                seen = set()
            else:
                op.shares_locate = op.key_ref in seen
                if op.key_ref is not None:
                    seen.add(op.key_ref)
                cur.append(op)
        if cur:
            groups.append(cur)
        return groups

    def explain(self) -> str:
        """Human-readable fused plan: groups, shared probes, serialization
        points.  Safe to call before or after commit()."""
        lines = [f"session plan: {len(self._ops)} ops, "
                 f"{len(self._key_batches)} key batch(es)"]
        probes = 0
        for gi, group in enumerate(self._plan()):
            if group[0].role == _INSERTER:
                op = group[0]
                probes_here = 1
                probes += probes_here
                lines.append(
                    f"  group {gi} [INSERTER — serialization point]: "
                    f"{op.kind}(keys#{op.key_ref}) — invalidates cached locates"
                )
                continue
            fresh = {op.key_ref for op in group if not op.shares_locate}
            probes += len(fresh)
            lines.append(
                f"  group {gi} [reader/updater — commuting]: "
                f"{len(group)} op(s), {len(fresh)} locate(s)"
            )
            for op in group:
                tag = "shares" if op.shares_locate else "issues"
                lines.append(f"    {op.kind}(keys#{op.key_ref}) — {tag} "
                             f"locate[keys#{op.key_ref}]")
        unfused = sum(1 for op in self._ops if op.key_ref is not None)
        lines.append(f"  probes: {probes} fused vs {unfused} unfused")
        return "\n".join(lines)

    # -- execution -------------------------------------------------------------

    def commit(self) -> HKVTable:
        """Execute the recorded plan; fill every SessionRef; return the
        successor handle.  Idempotent (a second call returns the cached
        result table)."""
        if self._committed:
            return self._result_table
        state, cfg, backend = (self._table.state, self._table.cfg,
                               self._table.backend)
        locs: dict[int, find_mod.Locate] = {}
        for group in self._plan():
            for op in group:
                keys = (None if op.key_ref is None
                        else self._key_batches[op.key_ref])
                if op.role == _INSERTER:
                    locs.clear()  # structural op: cached positions die
                    state = self._run_inserter(op, state, cfg, backend, keys)
                    locs.clear()
                    continue
                loc = locs.get(op.key_ref)
                # a structured RowUpdate with no locate to share does its
                # own (fused) probe inside ops.update_rows — pre-locating
                # here would break the ONE-launch contract
                structured = (op.kind == "update_rows"
                              and isinstance(op.args[0], ops_mod.RowUpdate))
                if loc is None and op.kind != "noop" and not structured:
                    # the shared probe is backend-aware too: on the kernel
                    # backend the session's one locate per key batch runs
                    # the digest_scan kernel (bit-identical to jnp locate)
                    loc = ops_mod.find_ptr(state, cfg, keys, backend=backend)
                    locs[op.key_ref] = loc
                state = self._run_nonstructural(op, state, cfg, keys, loc,
                                                backend)
        for op in self._ops:
            op.ref._committed = True
        self._committed = True
        self._result_table = self._table.with_state(state)
        return self._result_table

    def _run_nonstructural(self, op, state, cfg, keys, loc, backend):
        if op.kind == "find":
            op.ref.value = ops_mod.find(state, cfg, keys, loc=loc,
                                        backend=backend)
        elif op.kind == "find_rows":
            op.ref.value = ops_mod.find_rows(state, cfg, keys, loc=loc,
                                             backend=backend)
        elif op.kind == "contains":
            op.ref.value = ops_mod.contains(state, cfg, keys, loc=loc,
                                            backend=backend)
        elif op.kind == "assign":
            values, update_scores = op.args
            state = ops_mod.assign(state, cfg, keys, values,
                                   update_scores=update_scores, loc=loc)
            op.ref.value = state
        elif op.kind == "assign_add":
            (deltas,) = op.args
            state = ops_mod.assign_add(state, cfg, keys, deltas, loc=loc)
            op.ref.value = state
        elif op.kind == "assign_scores":
            (scores,) = op.args
            state = ops_mod.assign_scores(state, cfg, keys, scores, loc=loc)
            op.ref.value = state
        elif op.kind == "update_rows":
            fn, update_scores = op.args
            if isinstance(fn, ops_mod.RowUpdate):
                # structured gradient step: ops.update_rows owns the whole
                # op (the fused update_scan kernel when backend resolves
                # to 'kernel' and no locate is shared)
                res = ops_mod.update_rows(
                    state, cfg, keys, fn.grads, fn.opt,
                    update_scores=update_scores, loc=loc, backend=backend)
                state = res.state
                op.ref.value = res
            else:
                got = ops_mod.find_rows(state, cfg, keys, loc=loc,
                                        backend=backend)
                state = ops_mod.assign(state, cfg, keys, fn(got.rows),
                                       update_scores=update_scores, loc=loc)
                op.ref.value = got
        else:  # pragma: no cover - guarded by _record
            raise AssertionError(op.kind)
        return state

    def _run_inserter(self, op, state, cfg, backend, keys):
        if op.kind == "insert_or_assign":
            values, cs = op.args
            res = ops_mod.insert_or_assign(state, cfg, keys, values,
                                           custom_scores=cs, backend=backend)
            op.ref.value = res.status
            return res.state
        if op.kind == "find_or_insert":
            init, cs = op.args
            res = ops_mod.find_or_insert(state, cfg, keys, init,
                                         custom_scores=cs, backend=backend)
            op.ref.value = (res.values, res.found, res.status)
            return res.state
        if op.kind == "insert_and_evict":
            values, cs = op.args
            res = ops_mod.insert_and_evict(state, cfg, keys, values,
                                           custom_scores=cs, backend=backend)
            op.ref.value = res
            return res.state
        if op.kind == "erase":
            state = ops_mod.erase(state, cfg, keys)
            op.ref.value = state
            return state
        raise AssertionError(op.kind)  # pragma: no cover
