"""Sequential Python oracle of the HKV contract (Algorithms 1–3).

A slow, obviously-correct host implementation used by property tests to
validate the batch-synchronous TPU closure (`core/merge.py`).  It applies
the paper's per-key algorithms one key at a time, in the *canonical batch
order* the closure is defined against (DESIGN.md §2):

  1. dedupe the batch (last value wins, multiplicities counted);
  2. apply all hit-updates;
  3. apply misses bucket-by-bucket in descending incoming-score order
     (ties: ascending key), with existing-wins-ties admission.

Under that order the sequential outcome equals the top-S union merge, which
is what `merge.upsert` computes vectorially.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.u64 import EMPTY_KEY as EMPTY, hash_pair_np


@dataclasses.dataclass
class OracleEntry:
    key: int
    score: int
    value: np.ndarray


class OracleTable:
    """Dict-of-buckets cache-semantic table with exact HKV hashing."""

    def __init__(self, capacity: int, dim: int, slots_per_bucket: int = 128,
                 buckets_per_key: int = 1, policy: str = "lru"):
        assert capacity % slots_per_bucket == 0
        self.num_buckets = capacity // slots_per_bucket
        self.slots = slots_per_bucket
        self.dual = buckets_per_key == 2
        self.policy = policy
        self.dim = dim
        self.buckets: List[Dict[int, OracleEntry]] = [dict() for _ in range(self.num_buckets)]
        self.clock = 0
        self.epoch = 0

    # -- routing (must match core/u64.py exactly) ----------------------------

    def route(self, key: int) -> Tuple[int, int]:
        h1, h2 = hash_pair_np(np.asarray([key], np.uint64))
        nb = self.num_buckets
        if nb & (nb - 1) == 0:
            b1, b2 = int(h1[0]) & (nb - 1), int(h2[0]) & (nb - 1)
        else:
            b1, b2 = int(h1[0]) % nb, int(h2[0]) % nb
        return b1, (b2 if self.dual else b1)

    def locate(self, key: int) -> Optional[int]:
        b1, b2 = self.route(key)
        if key in self.buckets[b1]:
            return b1
        if self.dual and key in self.buckets[b2]:
            return b2
        return None

    # -- scoring --------------------------------------------------------------

    def init_score(self, count: int, custom: Optional[int]) -> int:
        if self.policy == "lru":
            return self.clock
        if self.policy == "lfu":
            return count
        if self.policy == "epoch_lru":
            return (self.epoch << 32) | (self.clock & 0xFFFFFFFF)
        if self.policy == "epoch_lfu":
            return (self.epoch << 32) | (count & 0xFFFFFFFF)
        assert custom is not None
        return custom

    def update_score(self, old: int, count: int, custom: Optional[int]) -> int:
        if self.policy == "lru":
            return self.clock
        if self.policy == "lfu":
            return (old + count) & 0xFFFFFFFFFFFFFFFF
        if self.policy == "epoch_lru":
            return (self.epoch << 32) | (self.clock & 0xFFFFFFFF)
        if self.policy == "epoch_lfu":
            if (old >> 32) != self.epoch:
                return (self.epoch << 32) | (count & 0xFFFFFFFF)
            lo = ((old & 0xFFFFFFFF) + count) & 0xFFFFFFFF
            return (self.epoch << 32) | lo
        assert custom is not None
        return custom

    # -- batch ops (canonical order) -------------------------------------------

    def _dedupe(self, keys, values, customs):
        """last-writer-wins values + multiplicities, preserving first-seen order."""
        seen = {}
        for i, k in enumerate(keys):
            k = int(k)
            if k == int(EMPTY):
                continue
            if k not in seen:
                seen[k] = [0, i]
            seen[k][0] += 1
            seen[k][1] = i
        out = []
        for k, (count, last) in seen.items():
            out.append(
                (
                    k,
                    count,
                    None if values is None else np.array(values[last]),
                    None if customs is None else int(customs[last]),
                )
            )
        return out

    def insert_or_assign(self, keys, values, customs=None, write_hit_values=True):
        """Batch upsert in canonical order. Returns status per input position."""
        self.clock += 1
        entries = self._dedupe(keys, values, customs)
        status = {}
        # phase 1: hits
        misses = []
        for k, count, val, cust in entries:
            b = self.locate(k)
            if b is not None:
                e = self.buckets[b][k]
                e.score = self.update_score(e.score, count, cust)
                if write_hit_values:
                    e.value = val
                status[k] = 1
            else:
                misses.append((k, count, val, cust))
        # phase 2: misses, per-bucket descending score then ascending key
        scored = []
        for k, count, val, cust in misses:
            b1, b2 = self.route(k)
            s = self.init_score(count, cust)
            # dual-bucket two-phase selection against *current* state
            if self.dual:
                o1, o2 = len(self.buckets[b1]), len(self.buckets[b2])
                if o1 < self.slots or o2 < self.slots:
                    tb = b2 if o2 < o1 else b1
                else:
                    m1 = min(e.score for e in self.buckets[b1].values())
                    m2 = min(e.score for e in self.buckets[b2].values())
                    tb = b2 if m2 < m1 else b1
            else:
                tb = b1
            scored.append((tb, s, k, count, val))
        scored.sort(key=lambda t: (t[0], -t[1], t[2]))
        for tb, s, k, count, val in scored:
            bucket = self.buckets[tb]
            if len(bucket) < self.slots:
                bucket[k] = OracleEntry(k, s, val)
                status[k] = 2
                continue
            victim = min(bucket.values(), key=lambda e: (e.score, e.key))
            if s > victim.score:  # existing wins ties (batch-closure contract)
                del bucket[victim.key]
                bucket[k] = OracleEntry(k, s, val)
                status[k] = 3
            else:
                status[k] = 4
        return [status.get(int(k), 0) for k in keys]

    def find_or_insert(self, keys, init_values, customs=None):
        st = self.insert_or_assign(keys, init_values, customs, write_hit_values=False)
        vals = []
        for i, k in enumerate(keys):
            b = self.locate(int(k))
            if b is not None:
                vals.append(np.array(self.buckets[b][int(k)].value))
            else:
                vals.append(np.array(init_values[i]))
        return st, np.stack(vals) if vals else np.zeros((0, self.dim))

    def accum_or_assign(self, keys, values, customs=None):
        """Mirrors `ops.accum_or_assign` (the one-shot gradient upsert):
        within-batch duplicates of a key are pre-SUMMED; one += applies on
        hit — with the score updated at count=1, because the engine's
        phase-2 upsert sees the deduped batch — and misses insert the sum,
        admission-controlled in canonical order."""
        self.clock += 1
        sums: Dict[int, list] = {}
        for i, k in enumerate(keys):
            k = int(k)
            if k == int(EMPTY):
                continue
            if k not in sums:
                sums[k] = [np.zeros_like(np.asarray(values[i], np.float64)), None]
            sums[k][0] = sums[k][0] + np.asarray(values[i], np.float64)
            sums[k][1] = None if customs is None else int(customs[i])
        status = {}
        misses = []
        for k, (vsum, cust) in sums.items():
            b = self.locate(k)
            if b is not None:
                e = self.buckets[b][k]
                e.value = (np.asarray(e.value, np.float64) + vsum).astype(
                    np.asarray(e.value).dtype)
                e.score = self.update_score(e.score, 1, cust)
                status[k] = 1
            else:
                misses.append((k, vsum, cust))
        scored = []
        for k, vsum, cust in misses:
            b1, b2 = self.route(k)
            s = self.init_score(1, cust)
            if self.dual:
                o1, o2 = len(self.buckets[b1]), len(self.buckets[b2])
                if o1 < self.slots or o2 < self.slots:
                    tb = b2 if o2 < o1 else b1
                else:
                    m1 = min(e.score for e in self.buckets[b1].values())
                    m2 = min(e.score for e in self.buckets[b2].values())
                    tb = b2 if m2 < m1 else b1
            else:
                tb = b1
            scored.append((tb, s, k, vsum))
        scored.sort(key=lambda t: (t[0], -t[1], t[2]))
        for tb, s, k, vsum in scored:
            bucket = self.buckets[tb]
            if len(bucket) < self.slots:
                bucket[k] = OracleEntry(k, s, vsum.astype(np.float32))
                status[k] = 2
                continue
            victim = min(bucket.values(), key=lambda e: (e.score, e.key))
            if s > victim.score:
                del bucket[victim.key]
                bucket[k] = OracleEntry(k, s, vsum.astype(np.float32))
                status[k] = 3
            else:
                status[k] = 4
        return [status.get(int(k), 0) for k in keys]

    def find(self, keys):
        found, vals = [], []
        for k in keys:
            b = self.locate(int(k))
            if b is None:
                found.append(False)
                vals.append(np.zeros(self.dim, np.float32))
            else:
                found.append(True)
                vals.append(np.array(self.buckets[b][int(k)].value[: self.dim]))
        return np.array(found), np.stack(vals) if vals else np.zeros((0, self.dim))

    def assign(self, keys, values):
        for i, k in enumerate(keys):
            b = self.locate(int(k))
            if b is not None:
                self.buckets[b][int(k)].value = np.array(values[i])

    def contains(self, keys):
        return np.array([self.locate(int(k)) is not None for k in keys])

    # -- predicated sweeps (mirror core/predicates.py `match_planes`) ----------

    @staticmethod
    def _pred_match(kind: str, key: int, score: int, a: int, b: int) -> bool:
        if kind == "always":
            return True
        if kind == "score_lt":
            return score < a
        if kind == "score_ge":
            return score >= a
        if kind == "epoch_lt":
            return (score >> 32) < (a >> 32)
        if kind == "key_range":
            return a <= key < b
        raise ValueError(kind)

    def erase_if(self, kind: str, a: int = 0, b: int = 0) -> int:
        """Remove every entry matching the predicate; returns the count."""
        removed = 0
        for bucket in self.buckets:
            for k in [k for k, e in bucket.items()
                      if self._pred_match(kind, k, e.score, a, b)]:
                del bucket[k]
                removed += 1
        return removed

    def evict_if(self, kind: str, budget: int, a: int = 0, b: int = 0):
        """Remove up to `budget` matching entries, coldest first (ascending
        score then key — the engine's deterministic sweep order); returns
        them as a list of (key, score, value) in eviction rank order."""
        cands = []
        for bi, bucket in enumerate(self.buckets):
            for k, e in bucket.items():
                if self._pred_match(kind, k, e.score, a, b):
                    cands.append((e.score, k, bi))
        cands.sort()
        out = []
        for score, k, bi in cands[:budget]:
            e = self.buckets[bi].pop(k)
            out.append((k, score, np.array(e.value)))
        return out

    def erase(self, keys):
        for k in keys:
            b = self.locate(int(k))
            if b is not None:
                del self.buckets[b][int(k)]

    def clear(self):
        """Drop every entry; the clock/epoch survive (the table contract)."""
        self.buckets = [dict() for _ in range(self.num_buckets)]

    def size(self) -> int:
        return sum(len(b) for b in self.buckets)

    def items(self):
        for b in self.buckets:
            for k, e in b.items():
                yield k, e

    def load_factor(self) -> float:
        return self.size() / (self.num_buckets * self.slots)
