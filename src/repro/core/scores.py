"""ScoreFunctor policies (paper §3.3, Table 8).

The paper ships five scoring policies through a single in-line upsert
mechanism: kLru, kLfu, kEpochLru, kEpochLfu, kCustomized.  The score array
*is* the eviction metadata — there is no second data structure — so a policy
is nothing more than a rule for (a) the score given to a newly admitted key
and (b) the score transition applied when an existing key is touched.

Scores are uint64 (here: U64 = (hi, lo) uint32 pairs, identical total
order).  Eviction always removes the bucket-minimum score; admission rejects
incoming scores below the bucket minimum (Alg. 2 line 12).

Batch semantics note (TPU adaptation): a batched op may contain the same key
k times.  LFU-family policies count all k occurrences (score += k); LRU-family
policies collapse them to a single touch at the batch clock, exactly what k
sequential upserts at the same clock tick would produce.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.u64 import U64

POLICIES = ("lru", "lfu", "epoch_lru", "epoch_lfu", "custom")


@dataclasses.dataclass(frozen=True)
class ScorePolicy:
    """Pure-functional score transition rules for one policy."""

    name: str

    def __post_init__(self):
        if self.name not in POLICIES:
            raise ValueError(f"unknown score policy {self.name!r}; one of {POLICIES}")

    # -- helpers ------------------------------------------------------------

    @property
    def is_custom(self) -> bool:
        return self.name == "custom"

    @property
    def counts_frequency(self) -> bool:
        return self.name in ("lfu", "epoch_lfu")

    # -- transitions ---------------------------------------------------------

    def init_score(
        self,
        clock: U64,
        epoch: jax.Array,
        count: jax.Array,
        custom: Optional[U64],
        shape,
    ) -> U64:
        """Score assigned to a newly admitted key.

        clock:  global monotonic batch clock (U64 scalar)
        epoch:  uint32 application epoch (scalar)
        count:  uint32 [N] — occurrences of the key inside this batch
        custom: U64 [N] caller scores (policy 'custom' only)
        """
        if self.name == "lru":
            return U64(
                jnp.broadcast_to(clock.hi, shape),
                jnp.broadcast_to(clock.lo, shape),
            )
        if self.name == "lfu":
            # frequency counter starts at the number of batch occurrences
            return U64(jnp.zeros(shape, jnp.uint32), count.astype(jnp.uint32))
        if self.name == "epoch_lru":
            # hi = epoch, lo = clock low bits (recency within epoch)
            return U64(
                jnp.broadcast_to(epoch.astype(jnp.uint32), shape),
                jnp.broadcast_to(clock.lo, shape),
            )
        if self.name == "epoch_lfu":
            return U64(
                jnp.broadcast_to(epoch.astype(jnp.uint32), shape),
                count.astype(jnp.uint32),
            )
        assert self.name == "custom"
        if custom is None:
            raise ValueError("policy 'custom' requires caller-supplied scores")
        return custom

    def update_score(
        self,
        old: U64,
        clock: U64,
        epoch: jax.Array,
        count: jax.Array,
        custom: Optional[U64],
    ) -> U64:
        """Score transition when an existing key is touched (update/upsert)."""
        shape = old.hi.shape
        if self.name == "lru":
            return U64(
                jnp.broadcast_to(clock.hi, shape),
                jnp.broadcast_to(clock.lo, shape),
            )
        if self.name == "lfu":
            return u64.add_u32(old, count)
        if self.name == "epoch_lru":
            ep = jnp.broadcast_to(epoch.astype(jnp.uint32), shape)
            return U64(ep, jnp.broadcast_to(clock.lo, shape))
        if self.name == "epoch_lfu":
            ep = jnp.broadcast_to(epoch.astype(jnp.uint32), shape)
            # entering a new epoch resets the frequency counter
            fresh = ep != old.hi
            new_lo = jnp.where(fresh, count, old.lo + count)
            return U64(ep, new_lo.astype(jnp.uint32))
        assert self.name == "custom"
        if custom is None:
            raise ValueError("policy 'custom' requires caller-supplied scores")
        # caller-supplied scores overwrite (HKV's caller-managed contract)
        return custom


def get_policy(name: str) -> ScorePolicy:
    return ScorePolicy(name)
