"""TieredHKVTable — §3.6 tiered key-value separation grown into a real
two-tier cache hierarchy (DESIGN.md §2.5).

The paper's headline contract — every full-bucket upsert resolves by
eviction, with displaced pairs handed off in the same launch — is exactly
the transport a storage hierarchy needs.  This module composes two full
HKV tables behind the `KVTable` protocol:

  hot tier   a small, fast table whose value plane stays in HBM;
  cold tier  a larger table whose value plane uses the existing 'hmem'
             placement (`HKVConfig.value_tier`), HugeCTR/HPS-style.

Two data motions, both riding the typed `EvictionStream`
(`core.merge.EvictionStream`):

  DEMOTION    every hot-tier structural op runs as `insert_and_evict`;
              its displaced `(key, value, score)` pairs — plus incoming
              pairs the hot tier REJECTED — upsert into the cold tier
              with scores translated across the per-tier policies
              (`translate_scores`).  Nothing leaves the hierarchy except
              at the cold tier's own admission/eviction boundary, and
              those losses are counted and reported (`.dropped`).
  PROMOTION   hot-tier find misses probe the cold tier; cold hits are
              re-admitted into the hot tier (full-width rows, so aux
              optimizer columns travel with the embedding), and the hot
              entries THEY displace cascade back down through the same
              demotion path.  The hot tier is therefore an
              inclusive-on-access cache: a promoted key keeps its cold
              copy, which is freshened by write-back whenever the hot
              copy is demoted; reads always prefer the hot copy, so the
              cold copy is only visible after such a write-back.

Capacity semantics downstream: every consumer that drives a `KVTable`
handle upgrades from "table must fit in HBM" to "hot set must fit in
HBM" — the cold tier absorbs the working set's tail.

Layering: this module lives in `repro.core` and may call the op engine
(`core.ops`) directly; external consumers use the handle, which is a
registered pytree (the two tier handles are its children, so jit /
donate / scan / checkpoint-tree behavior is inherited from `HKVTable`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import find as find_mod
from repro.core import ops as ops_mod
from repro.core import u64
from repro.core.api import HKVTable, normalize_keys, _opt_keys
from repro.core.merge import EvictionStream
from repro.core.scores import ScorePolicy
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64


# =============================================================================
# Score translation across per-tier policies
# =============================================================================


def translate_scores(src: ScorePolicy, dst: ScorePolicy,
                     scores: U64) -> Optional[U64]:
    """Map scores from the source tier's policy domain into admission
    scores for the destination tier (DESIGN.md §2.5).

    * dst 'custom'  — pass the source scores through verbatim.  Every
      policy's scores are uint64 with eviction order = ascending value,
      so the u64 total order carries the source tier's relative
      hot/coldness into the destination unchanged.  This is the default
      cold-tier policy (`TieredHKVTable.create`): demoted pairs compete
      in the cold tier by exactly the score that got them evicted.
    * any other dst — return None: the destination stamps its own
      (clock/epoch/count) score at admission time.  Recency restarts and
      LFU-family counters restart at the batch multiplicity — per-tier
      clock domains are independent, so importing a foreign clock value
      would corrupt the destination's order.  Callers needing full
      cross-tier score fidelity run the destination tier on 'custom'.
    """
    if dst.is_custom:
        return scores
    return None


# =============================================================================
# State / result types
# =============================================================================


class TieredState(NamedTuple):
    """Both tiers' states as one pytree (the checkpoint/shard_map leaf set)."""

    hot: HKVState
    cold: HKVState


class TieredFind(NamedTuple):
    table: "TieredHKVTable"   # successor (promotion mutates the hierarchy)
    values: jax.Array         # [N, dim] — zeros where neither tier holds the key
    found: jax.Array          # bool [N] — present in EITHER tier
    hot_hit: jax.Array        # bool [N] — served from the hot tier
    promoted: jax.Array       # int32 — cold hits re-admitted into hot
    demoted: jax.Array        # int32 — hot victims cascaded into cold
    dropped: jax.Array        # int32 — UPPER BOUND on pairs that left the
                              #   hierarchy: cold-tier rejections + cold
                              #   evictions (an evicted cold copy may be an
                              #   inclusive duplicate whose hot copy lives
                              #   on — never an undercount; DESIGN §2.5)


class TieredUpsert(NamedTuple):
    table: "TieredHKVTable"
    status: jax.Array         # int8 [N] — hot-tier merge status codes
    demoted: jax.Array        # int32 — pairs handed down to the cold tier
    dropped: jax.Array        # int32 — upper bound on hierarchy exits (see
                              #   TieredFind.dropped)
    # bool [N] — key present SOMEWHERE in the hierarchy after the op:
    # admitted by the hot tier, or hot-rejected and actually absorbed by
    # the cold tier (its per-lane verdict, not an assumption).
    ok: jax.Array


class TieredFindOrInsert(NamedTuple):
    table: "TieredHKVTable"
    values: jax.Array         # [N, dim] — stored row (either tier) or init
    found: jax.Array          # bool [N] — existed in EITHER tier before the op
    status: jax.Array         # int8 [N] — hot-tier merge status codes
    promoted: jax.Array       # int32
    demoted: jax.Array        # int32
    dropped: jax.Array        # int32
    ok: jax.Array             # bool [N] — key resident SOMEWHERE after the op


class _DemoteResult(NamedTuple):
    cold: HKVTable
    demoted: jax.Array        # int32 — pairs upserted into the cold tier
    dropped: jax.Array        # int32 — pairs lost at the cold boundary
    placed: jax.Array         # bool [N] — lane's pair is now cold-resident


class TieredDemote(NamedTuple):
    table: "TieredHKVTable"
    demoted: jax.Array        # int32 — pairs the cold tier absorbed
    dropped: jax.Array        # int32 — pairs lost at the cold boundary


class TieredSweep(NamedTuple):
    table: "TieredHKVTable"
    swept: jax.Array          # int32 — entries removed across BOTH tiers
                              #   (inclusive hot/cold copies count twice —
                              #   both slots were freed)


class TieredEvictIf(NamedTuple):
    table: "TieredHKVTable"
    evicted: EvictionStream   # 2*budget lanes: hot stream then cold
                              #   stream, stale inclusive cold copies
                              #   masked out (hot copy authoritative)
    count: jax.Array          # int32 — live lanes in the stream


# =============================================================================
# The handle
# =============================================================================


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TieredHKVTable:
    """Two-tier HKV hierarchy behind the same handle discipline as
    `HKVTable`: the two tier handles are the pytree children (their states
    are the leaves; both cfgs ride as static aux), so a tiered handle
    jits, donates, scans, and checkpoints exactly like a flat one.

        table = TieredHKVTable.create(
            hot_capacity=8 * 128, cold_capacity=64 * 128, dim=32)
        res = table.insert_or_assign(keys, values)  # res.table, res.status
        out = res.table.find(keys)                  # out.table carries the
                                                    # promotion's effects

    `promote_on_find=False` makes `find` a pure reader (no re-admission);
    the default promotes, which is what makes the hot tier track the
    access distribution.
    """

    hot: HKVTable
    cold: HKVTable
    promote_on_find: bool = True

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        return (self.hot, self.cold), (self.promote_on_find,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        hot, cold = children
        return cls(hot=hot, cold=cold, promote_on_find=aux[0])

    # -- construction --------------------------------------------------------

    @classmethod
    def create(cls, *, hot_capacity: int, cold_capacity: int, dim: int,
               score_policy: str = "lru",
               cold_score_policy: str = "custom",
               cold_value_tier: str = "hmem",
               promote_on_find: bool = True,
               backend: str = "auto",
               **shared_cfg) -> "TieredHKVTable":
        """Allocate both tiers.  Value-plane geometry (dim, aux columns,
        dtype, slots per bucket) is shared — rows must move between tiers
        without reshaping; capacities and score policies are per-tier.

        The cold tier defaults to the 'custom' policy so demoted pairs
        carry their translated hot-tier scores (see `translate_scores`),
        and to the 'hmem' value placement (§3.6): host-capacity values,
        HBM key-side processing in both tiers.
        """
        hot_cfg = HKVConfig(capacity=hot_capacity, dim=dim,
                            score_policy=score_policy, **shared_cfg)
        cold_cfg = HKVConfig(capacity=cold_capacity, dim=dim,
                             score_policy=cold_score_policy,
                             value_tier=cold_value_tier, **shared_cfg)
        return cls.from_configs(hot_cfg, cold_cfg,
                                promote_on_find=promote_on_find,
                                backend=backend)

    @classmethod
    def from_configs(cls, hot_cfg: HKVConfig, cold_cfg: HKVConfig, *,
                     promote_on_find: bool = True,
                     backend: str = "auto") -> "TieredHKVTable":
        if hot_cfg.total_value_dim != cold_cfg.total_value_dim or (
                hot_cfg.value_dtype != cold_cfg.value_dtype):
            raise ValueError(
                "hot/cold tiers must share value-row geometry; got "
                f"{hot_cfg.total_value_dim}x{hot_cfg.value_dtype} vs "
                f"{cold_cfg.total_value_dim}x{cold_cfg.value_dtype}"
            )
        return cls(hot=HKVTable.create(hot_cfg, backend=backend),
                   cold=HKVTable.create(cold_cfg, backend=backend),
                   promote_on_find=promote_on_find)

    @classmethod
    def wrap(cls, state: TieredState, hot_cfg: HKVConfig,
             cold_cfg: HKVConfig, *, promote_on_find: bool = True,
             backend: str = "auto") -> "TieredHKVTable":
        """Bind existing tier states (e.g. shard-local under shard_map)."""
        return cls(hot=HKVTable.wrap(state.hot, hot_cfg, backend=backend),
                   cold=HKVTable.wrap(state.cold, cold_cfg, backend=backend),
                   promote_on_find=promote_on_find)

    # -- views ---------------------------------------------------------------

    @property
    def state(self) -> TieredState:
        return TieredState(hot=self.hot.state, cold=self.cold.state)

    def with_state(self, state: TieredState) -> "TieredHKVTable":
        return dataclasses.replace(
            self, hot=self.hot.with_state(state.hot),
            cold=self.cold.with_state(state.cold))

    def with_tiers(self, hot: HKVTable, cold: HKVTable) -> "TieredHKVTable":
        return dataclasses.replace(self, hot=hot, cold=cold)

    @property
    def capacity(self) -> int:
        return self.hot.capacity + self.cold.capacity

    @property
    def hot_fraction(self) -> float:
        return self.hot.capacity / self.capacity

    @property
    def dim(self) -> int:
        return self.hot.dim

    def keys(self, keys: Any) -> U64:
        return normalize_keys(keys)

    # -- readers -------------------------------------------------------------

    def contains(self, keys: Any, *, telemetry=None) -> jax.Array:
        """Pure reader: membership in either tier (never promotes)."""
        k = normalize_keys(keys)
        in_hot = self.hot.contains(k, telemetry=telemetry)
        return in_hot | self.cold.contains(_mask_keys(k, ~in_hot),
                                           telemetry=telemetry)

    def size(self) -> jax.Array:
        """Distinct live keys across the hierarchy.  Inclusive-on-access
        duplicates (a promoted key's cold copy) are counted ONCE — the
        hot key plane is probed against the cold tier, which is a
        capacity-sized membership scan; `size` is a diagnostic op, not a
        hot-path one."""
        hs = self.hot.state
        hot_keys = U64(hs.key_hi.reshape(-1), hs.key_lo.reshape(-1))
        dup = self.cold.contains(hot_keys) & ~u64.is_empty(hot_keys)
        return (self.hot.size() + self.cold.size()
                - jnp.sum(dup.astype(jnp.int32)))

    def load_factor(self) -> jax.Array:
        return self.size().astype(jnp.float32) / float(self.capacity)

    @property
    def num_buckets(self) -> int:
        """Export-space bucket count: hot buckets first, then cold —
        `export_batch` iterates one concatenated bucket index space."""
        return self.hot.num_buckets + self.cold.num_buckets

    def export_batch(self, bucket_start: int,
                     bucket_count: int) -> ops_mod.ExportResult:
        """Stream a contiguous range of the CONCATENATED bucket space
        (hot buckets [0, H), cold buckets [H, H+C)) — the checkpoint /
        publisher-delta read path.

        Inclusive-on-access duplicates are resolved in the hot tier's
        favor: a cold entry whose key is hot-resident is masked out, since
        its cold copy may be stale (write-back only freshens it on
        demotion, DESIGN.md §2.5).  The extra hot membership probe is a
        checkpoint-path cost, not a hot-path one."""
        hot_b = self.hot.num_buckets
        end = bucket_start + bucket_count
        parts = []
        if bucket_start < hot_b:
            parts.append(self.hot.export_batch(
                bucket_start, min(end, hot_b) - bucket_start))
        if end > hot_b:
            c0 = max(bucket_start - hot_b, 0)
            c = self.cold.export_batch(c0, end - hot_b - c0)
            dup = self.hot.contains(U64(c.key_hi, c.key_lo))
            parts.append(c._replace(mask=c.mask & ~dup))
        if len(parts) == 1:
            return parts[0]
        h, c = parts
        return ops_mod.ExportResult(*[
            jnp.concatenate([a, b]) for a, b in zip(h, c)
        ])

    # -- the demotion cascade ------------------------------------------------

    def _demote(self, cold: HKVTable, keys: U64, values: jax.Array,
                scores: U64, mask: jax.Array) -> _DemoteResult:
        """Upsert displaced pairs into the cold tier; count what it keeps
        and what leaves the hierarchy at its boundary.

        `keys/values/scores` are full lanes with `mask` selecting the
        live pairs (EvictionStream layout); masked-out lanes become the
        EMPTY sentinel, which every table op ignores.
        """
        mk = _mask_keys(keys, mask)
        cs = translate_scores(self.hot.cfg.policy, cold.cfg.policy,
                              scores)
        res = ops_mod.insert_and_evict(
            cold.state, cold.cfg, mk, values,
            custom_scores=cs, backend=cold.backend,
        )
        placed = mask & (res.status != ops_mod.STATUS_REJECTED)
        demoted = jnp.sum(placed.astype(jnp.int32))
        # losses at the cold boundary: rejected demotions + the cold
        # tier's own evictions (pairs pushed out of the last tier)
        dropped = (jnp.sum((mask & ~placed).astype(jnp.int32))
                   + res.evicted.count().astype(jnp.int32))
        return _DemoteResult(cold=cold.with_state(res.state),
                             demoted=demoted, dropped=dropped, placed=placed)

    def _demote_stream(self, cold: HKVTable,
                       stream: EvictionStream) -> _DemoteResult:
        return self._demote(cold, stream.keys, stream.values,
                            stream.scores, stream.mask)

    def demote(self, stream: EvictionStream) -> TieredDemote:
        """Hand a stream of (key, value, score) pairs down into the cold
        tier — the PUBLIC form of the demotion cascade (scores translated
        across the per-tier policies, losses at the cold boundary
        counted).  The maintenance rebalancer feeds `evict_if`'s hot-tier
        stream through here (repro.maintenance.rebalance)."""
        dem = self._demote_stream(self.cold, stream)
        return TieredDemote(table=self.with_tiers(self.hot, dem.cold),
                            demoted=dem.demoted, dropped=dem.dropped)

    # -- inserters -----------------------------------------------------------

    def insert_or_assign(self, keys: Any, values: jax.Array,
                         custom_scores: Optional[Any] = None, *,
                         telemetry=None) -> TieredUpsert:
        """Upsert into the hot tier; displaced pairs — victims evicted by
        admission AND incoming pairs the hot tier rejected — cascade into
        the cold tier.  `status` reports the hot tier's verdict; `.ok`
        also covers hot-rejected pairs absorbed by the cold tier."""
        k = normalize_keys(keys)
        cs = _opt_keys(custom_scores)
        values = ops_mod.pad_rows(values, self.hot.state.values)
        res = ops_mod.insert_and_evict(
            self.hot.state, self.hot.cfg, k, values,
            custom_scores=cs, backend=self.hot.backend, telemetry=telemetry,
        )
        hot = self.hot.with_state(res.state)
        first, rep_orig = _dedupe_lanes(k)
        dk, dv, ds, dm = self._displaced(k, values, res, rej_custom=cs,
                                         first=first)
        dem = self._demote(self.cold, dk, dv, ds, dm)
        if telemetry is not None:
            telemetry.record("tier", ops_mod._obs().tier_motion(
                demoted=dem.demoted, dropped=dem.dropped))
        return TieredUpsert(
            table=self.with_tiers(hot, dem.cold), status=res.status,
            demoted=dem.demoted, dropped=dem.dropped,
            ok=_hierarchy_ok(res.status, dem.placed, rep_orig),
        )

    def find_or_insert(self, keys: Any, init_values: jax.Array,
                       custom_scores: Optional[Any] = None, *,
                       telemetry=None) -> TieredFindOrInsert:
        """The training-path op: lookup across the hierarchy, admit
        misses, promote cold hits.

        `custom_scores` feeds the HOT tier's admission (meaningful under
        its 'custom' policy — the delta-ingest path; other policies stamp
        their own).  Caller scores apply to every lane, including
        promoted cold hits.

        Per key: hot hit -> stored hot row (scores touched).  Hot miss
        but cold hit -> the cold row is re-admitted into the hot tier
        (promotion) and returned.  Miss in both -> `init_values` row is
        admitted into the hot tier.  Every hot-tier displacement — victims
        of admission and rejected incoming pairs alike — cascades into
        the cold tier, so admission-rejected NEW keys land cold-side
        rather than vanishing (reported via `status` = REJECTED and the
        conservation counters).
        """
        k = normalize_keys(keys)
        cs = _opt_keys(custom_scores)
        # ONE hot probe: shared with the upsert closure through the PR-2
        # loc= seam (locate output depends only on the key plane, which
        # the cold reads below never touch)
        pre = self.hot.find_ptr(k)
        hot_pre = pre.found
        # probe the cold tier only for hot misses: full-width rows so aux
        # optimizer columns travel with a promoted embedding
        cold_rows = self.cold.find_rows(_mask_keys(k, ~hot_pre))
        cold_hit = cold_rows.found
        init_full = ops_mod.pad_rows(init_values, self.hot.state.values)
        admit_rows = jnp.where(cold_hit[:, None], cold_rows.rows, init_full)
        res = ops_mod.find_or_insert(
            self.hot.state, self.hot.cfg, k, admit_rows, custom_scores=cs,
            backend=self.hot.backend, return_evicted=True, loc=pre,
            telemetry=telemetry,
        )
        hot = self.hot.with_state(res.state)
        first, rep_orig = _dedupe_lanes(k)
        # rejected COLD HITS stay where they are: the pair never left the
        # cold tier, and re-demoting it would overwrite its accumulated
        # cold score with a fresh count-1 init (each rejected re-access
        # would make the key MORE evictable — exactly backwards)
        dk, dv, ds, dm = self._displaced(k, admit_rows, res, rej_custom=cs,
                                         first=first,
                                         already_cold=cold_hit)
        dem = self._demote(self.cold, dk, dv, ds, dm)
        promoted = jnp.sum((cold_hit & first
                            & (res.status >= ops_mod.STATUS_UPDATED)
                            & (res.status <= ops_mod.STATUS_EVICTED))
                           .astype(jnp.int32))
        if telemetry is not None:
            telemetry.record("tier", ops_mod._obs().tier_motion(
                promoted=promoted, demoted=dem.demoted, dropped=dem.dropped))
        return TieredFindOrInsert(
            table=self.with_tiers(hot, dem.cold),
            values=res.values,
            found=hot_pre | cold_hit,
            status=res.status,
            promoted=promoted,
            demoted=dem.demoted, dropped=dem.dropped,
            # rejected cold hits never left the cold tier: resident by
            # definition, without appearing in the demotion batch
            ok=(_hierarchy_ok(res.status, dem.placed, rep_orig)
                | ((res.status == ops_mod.STATUS_REJECTED) & cold_hit)),
        )

    def _displaced(self, k: U64, values: jax.Array, res,
                   rej_custom: Optional[U64] = None,
                   first: Optional[jax.Array] = None,
                   already_cold: Optional[jax.Array] = None):
        """Merge the eviction stream with hot-REJECTED incoming pairs into
        one positionally-aligned demotion batch.

        A lane either evicted a victim (admission succeeded) or was
        rejected — never both — so victim and rejected-incoming lanes are
        disjoint; and a rejected key cannot equal any victim key (a
        hot-resident key would have been a hit, not a rejection).

        Rejected incoming pairs carry their would-be admission score: the
        caller's custom score under the 'custom' policy, else a fresh
        hot-policy init score at the post-op clock (for LFU-family
        policies the within-batch multiplicity collapses to 1 — the
        demotion path's documented approximation, DESIGN.md §2.5).
        `already_cold` lanes are excluded: their pair never left the cold
        tier, so there is nothing to hand down.
        """
        st = res.evicted
        rej = (res.status == ops_mod.STATUS_REJECTED) & ~st.mask
        if already_cold is not None:
            rej &= ~already_cold
        # dedupe rejected lanes: only each key's first lane demotes (the
        # upsert closure already collapsed duplicates to one verdict)
        rej &= _first_occurrence(k) if first is None else first
        policy = self.hot.cfg.policy
        if policy.is_custom:
            rej_sc = rej_custom  # the hot upsert itself required these
        else:
            hs = res.state
            rej_sc = policy.init_score(
                U64(hs.clock_hi, hs.clock_lo), hs.epoch,
                jnp.ones(rej.shape, jnp.uint32), None, rej.shape,
            )
        keys = U64(jnp.where(st.mask, st.key_hi, k.hi),
                   jnp.where(st.mask, st.key_lo, k.lo))
        vals = jnp.where(st.mask[:, None], st.values,
                         values.astype(st.values.dtype))
        scores = U64(jnp.where(st.mask, st.score_hi, rej_sc.hi),
                     jnp.where(st.mask, st.score_lo, rej_sc.lo))
        return keys, vals, scores, st.mask | rej

    def ingest(self, keys: Any, init_values: jax.Array,
               custom_scores: Optional[Any] = None, *,
               telemetry=None) -> TieredUpsert:
        """Deferred-structural admit (the overlapped-ingest schedule):
        find_or_insert without the value readback.  Runs the FULL
        hierarchy motion — a cold-resident key must be PROMOTED, not
        shadowed by a fresh init row in hot (which would hide its trained
        value from every later read).  The readback is dead code XLA
        eliminates under jit."""
        r = self.find_or_insert(keys, init_values,
                                custom_scores=custom_scores,
                                telemetry=telemetry)
        return TieredUpsert(table=r.table, status=r.status,
                            demoted=r.demoted, dropped=r.dropped, ok=r.ok)

    # -- find with miss-path promotion ----------------------------------------

    def find(self, keys: Any, *, promote: Optional[bool] = None,
             telemetry=None) -> TieredFind:
        """Hierarchy lookup.  Hot misses probe the cold tier; cold hits
        are re-admitted into the hot tier (unless promotion is off), whose
        displaced victims cascade back down — the inclusive-on-access
        cache motion.  The read values are the pre-promotion rows either
        way (promotion never changes what this call returns, only where
        the NEXT access finds it)."""
        if promote is None:
            promote = self.promote_on_find
        k = normalize_keys(keys)
        # both probe legs go through the handle readers, so on the kernel
        # backend each is ONE fused find_scan pass (hot: values in-line;
        # cold hmem values cross tiers via the locate+tier_gather split)
        h = self.hot.find(k, telemetry=telemetry)
        cold_rows = self.cold.find_rows(_mask_keys(k, ~h.found),
                                        telemetry=telemetry)
        cold_hit = cold_rows.found
        values = jnp.where(h.found[:, None], h.values,
                           cold_rows.rows[:, : self.dim].astype(h.values.dtype))
        found = h.found | cold_hit
        zero = jnp.zeros((), jnp.int32)
        if not promote:
            return TieredFind(table=self, values=values, found=found,
                              hot_hit=h.found, promoted=zero, demoted=zero,
                              dropped=zero)
        # re-admit cold hits (first occurrence only: duplicates collapse),
        # carrying their cold scores across the policy translation.  Every
        # promoted key is a known hot MISS, so the closure's locate is
        # supplied as all-miss through the loc= seam — no extra probe.
        pk = _mask_keys(k, cold_hit & _first_occurrence(k))
        cs = translate_scores(self.cold.cfg.policy, self.hot.cfg.policy,
                              U64(cold_rows.score_hi, cold_rows.score_lo))
        n = pk.hi.shape[0]
        all_miss = find_mod.Locate(
            found=jnp.zeros((n,), bool),
            bucket=jnp.zeros((n,), jnp.int32),
            slot=jnp.zeros((n,), jnp.int32),
            row=jnp.zeros((n,), jnp.int32),
        )
        res = ops_mod.insert_and_evict(
            self.hot.state, self.hot.cfg, pk, cold_rows.rows,
            custom_scores=cs, backend=self.hot.backend, loc=all_miss,
        )
        hot = self.hot.with_state(res.state)
        dem = self._demote_stream(self.cold, res.evicted)
        promoted = jnp.sum(
            ((res.status == ops_mod.STATUS_INSERTED)
             | (res.status == ops_mod.STATUS_EVICTED)).astype(jnp.int32))
        if telemetry is not None:
            telemetry.record("tier", ops_mod._obs().tier_motion(
                promoted=promoted, demoted=dem.demoted, dropped=dem.dropped))
        return TieredFind(
            table=self.with_tiers(hot, dem.cold), values=values, found=found,
            hot_hit=h.found, promoted=promoted, demoted=dem.demoted,
            dropped=dem.dropped,
        )

    # -- updaters / sessions ---------------------------------------------------

    def assign(self, keys: Any, values: jax.Array,
               update_scores: bool = False) -> "TieredHKVTable":
        """Updater on the HOT tier only: in a promote-on-access hierarchy
        every trained/served row was just promoted, so hot-resident rows
        are exactly the writable set (cold copies refresh via write-back
        on demotion)."""
        return dataclasses.replace(
            self, hot=self.hot.assign(keys, values,
                                      update_scores=update_scores))

    def erase(self, keys: Any, *, telemetry=None) -> "TieredHKVTable":
        """Structural: remove keys from BOTH tiers (an inclusive-cache
        erase must kill the cold copy too or the key would resurrect on
        the next miss)."""
        return self.with_tiers(self.hot.erase(keys, telemetry=telemetry),
                               self.cold.erase(keys, telemetry=telemetry))

    def clear(self) -> "TieredHKVTable":
        return self.with_tiers(self.hot.clear(), self.cold.clear())

    # -- maintenance (predicated sweeps + observability; DESIGN.md
    # §Maintenance) -----------------------------------------------------------

    def erase_if(self, pred, *, telemetry=None) -> TieredSweep:
        """Structural sweep of BOTH tiers: like `erase`, an inclusive-cache
        removal must kill the cold copy too, or an expired key would
        resurrect on the next miss.  Works for TTL expiry on the default
        tier policies because demoted scores are translated verbatim into
        the cold tier's 'custom' domain — the epoch plane survives the
        crossing (`translate_scores`)."""
        hr = self.hot.erase_if(pred, telemetry=telemetry)
        cr = self.cold.erase_if(pred, telemetry=telemetry)
        return TieredSweep(table=self.with_tiers(hr.table, cr.table),
                           swept=hr.swept + cr.swept)

    def evict_if(self, pred, budget: int, *,
                 telemetry=None) -> TieredEvictIf:
        """Remove up to `budget` matching entries per tier, coldest first,
        returning them as one concatenated stream (hot lanes first).  An
        evicted entry leaves the WHOLE hierarchy: a hot-evicted key's
        stale inclusive cold copy is erased with it (same no-resurrection
        rule as `erase`/`erase_if` — the stream must not report a key
        gone while a cold hit could still serve it), and a cold lane
        whose key remains hot-resident is a stale inclusive copy whose
        slot is freed but whose lane is masked out of the stream (the hot
        copy is authoritative — same rule as `export_batch`)."""
        hr = ops_mod.evict_if(self.hot.state, self.hot.cfg, pred, budget,
                              backend=self.hot.backend, telemetry=telemetry)
        cr = ops_mod.evict_if(self.cold.state, self.cold.cfg, pred, budget,
                              backend=self.cold.backend, telemetry=telemetry)
        dup = self.hot.contains(cr.evicted.masked_keys())  # pre-sweep hot
        cmask = cr.evicted.mask & ~dup
        # hot-evicted keys: kill any surviving stale cold copy (the cold
        # sweep's own budget/rank order may not have reached it)
        cold_state = ops_mod.erase(cr.state, self.cold.cfg,
                                   hr.evicted.masked_keys())
        stream = EvictionStream(*[
            jnp.concatenate([getattr(hr.evicted, f),
                             getattr(cr.evicted, f)])
            for f in ("key_hi", "key_lo", "values", "score_hi", "score_lo")
        ], mask=jnp.concatenate([hr.evicted.mask, cmask]))
        return TieredEvictIf(
            table=self.with_tiers(self.hot.with_state(hr.state),
                                  self.cold.with_state(cold_state)),
            evicted=stream,
            count=hr.count + jnp.sum(cmask.astype(jnp.int32)),
        )

    def stats(self):
        """Hierarchy-level `TableStats`: histograms summed, size deduped
        across inclusive copies (== `size()`); per-tier detail via
        `tier_stats()`."""
        from repro.maintenance import stats as stats_mod  # deferred: layering

        hot, cold = self.tier_stats()
        return stats_mod.combine_stats(hot, cold, size=self.size())

    def tier_stats(self):
        """(hot TableStats, cold TableStats) — the per-tier load factors
        the watermark rebalancer and capacity planning read."""
        return self.hot.stats(), self.cold.stats()

    @property
    def epoch(self) -> jax.Array:
        return self.hot.epoch

    def set_epoch(self, epoch: Any) -> "TieredHKVTable":
        """Stamp the application epoch on BOTH tiers (one TTL clock for
        the whole hierarchy)."""
        return self.with_tiers(self.hot.set_epoch(epoch),
                               self.cold.set_epoch(epoch))

    def session(self) -> "TieredSession":
        """Role-aware op session over the HOT TIER ONLY (the writable
        set — see `assign`); `commit()` returns the tiered successor
        handle.  Session reads are hot-scoped: `s.find(k)` misses a
        cold-resident key that `table.find(k)` would hit — use the table
        surface for hierarchy-wide reads."""
        return TieredSession(self)


class TieredSession:
    """`OpSession` proxy over the HOT tier: records reader/updater/
    inserter ops against it and rebinds the tiered handle on commit.

    Scope contract (deliberate, documented at `TieredHKVTable.session`):
    ops see ONLY the hot tier.  That is exactly right for the session's
    consumer — the fused read-modify-write gradient path, whose keys were
    just promoted by their own lookup — and exactly wrong for hierarchy-
    wide reads, which belong on the table surface (`find`/`contains`).
    Within that scope, PR 2's fusion guarantees hold unchanged (shared
    locates are exact; inserters serialize)."""

    def __init__(self, table: TieredHKVTable):
        self._table = table
        self._inner = table.hot.session()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def commit(self) -> TieredHKVTable:
        hot = self._inner.commit()
        return dataclasses.replace(self._table, hot=hot)


# =============================================================================
# helpers
# =============================================================================


def _mask_keys(keys: U64, keep: jax.Array) -> U64:
    """EMPTY-sentinel out the lanes where ~keep (every op ignores them)."""
    return U64(jnp.where(keep, keys.hi, jnp.uint32(u64.EMPTY_HI)),
               jnp.where(keep, keys.lo, jnp.uint32(u64.EMPTY_LO)))


def _dedupe_lanes(keys: U64):
    """(first, rep_orig) over the batch: `first[i]` — lane i is its key's
    first occurrence (EMPTY lanes excluded); `rep_orig[i]` — the original
    position of lane i's group representative (maps a per-rep verdict back
    onto every duplicate lane)."""
    from repro.core import merge as merge_mod

    d = merge_mod.dedupe_keys(keys)
    n = keys.hi.shape[0]
    first = jnp.zeros((n,), bool).at[
        jnp.where(d.rep_mask, d.idx_sorted, n)
    ].set(True, mode="drop")
    return first, d.idx_sorted[d.inverse]


def _first_occurrence(keys: U64) -> jax.Array:
    return _dedupe_lanes(keys)[0]


def _hierarchy_ok(status: jax.Array, placed: jax.Array,
                  rep_orig: jax.Array) -> jax.Array:
    """Per-lane residency after an upsert: admitted by the hot tier, or
    hot-rejected with the demotion actually PLACED by the cold tier (its
    verdict lives at the group representative's lane — duplicates map to
    it through `rep_orig`)."""
    hot_ok = (status >= ops_mod.STATUS_UPDATED) & (
        status <= ops_mod.STATUS_EVICTED
    )
    return hot_ok | ((status == ops_mod.STATUS_REJECTED) & placed[rep_orig])
