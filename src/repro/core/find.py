"""Digest-accelerated lookup (paper §3.2, Algorithm 1).

Pure-jnp reference path.  The perf-critical variant lives in
``repro.kernels.digest_scan`` (Pallas, scalar-prefetched bucket rows); both
implement exactly this contract and are cross-checked in tests.

The lookup contract is the paper's Proposition 3.1 adapted to batch form:
for every query key, gather its candidate bucket row(s), compare the 8-bit
digests in one vectorized pass (the TPU analogue of the single 128 B
cache-line transaction: 128 digests = one VPU lane row), and compare full
keys only where digests match.  A miss is definitive after one bucket row
(single-bucket mode) or two rows (dual-bucket mode).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64


class Probe(NamedTuple):
    """Hash-derived routing info for a batch of keys."""

    bucket1: jax.Array   # int32 [N] primary bucket
    bucket2: jax.Array   # int32 [N] secondary bucket (== bucket1 in single mode)
    digest: jax.Array    # uint8 [N]
    valid: jax.Array     # bool  [N] — key is not the EMPTY sentinel


class Locate(NamedTuple):
    """Result of locating keys in the table (key-side only, no value touch)."""

    found: jax.Array     # bool  [N]
    bucket: jax.Array    # int32 [N] bucket holding the key (b1 if miss)
    slot: jax.Array      # int32 [N] slot holding the key (0 if miss)
    row: jax.Array       # int32 [N] value row = bucket * S + slot (position addressing)


def probe_keys(cfg: HKVConfig, keys: U64) -> Probe:
    h1, h2 = u64.hash_pair(keys)
    b1 = u64.bucket_from_hash(h1, cfg.num_buckets)
    if cfg.buckets_per_key == 2:
        b2 = u64.bucket_from_hash(h2, cfg.num_buckets)
    else:
        b2 = b1
    return Probe(
        bucket1=b1,
        bucket2=b2,
        digest=u64.digest_from_hash(h1),
        valid=~u64.is_empty(keys),
    )


def match_lanes(key_hi, key_lo, q_hi, q_lo, digests=None, q_digest=None):
    """THE key-match formula (paper §3.2, Algorithm 1 lines 4–10).

    Pure plane math — uint32/uint8 lane compares only, no gathers and no
    dtype casts — so the identical function body runs under jnp on
    ``[N, S]`` bucket rows *and* inside Pallas kernel bodies on ``[S]``
    (or ``[T, S]``) VMEM rows.  This is the single definition every probe
    stage must call: the jnp reference (via :func:`_match_in_bucket`) and
    the ``digest_scan`` / ``find_scan`` / ``upsert_scan`` kernels.  hkv-lint's
    oracle-coupling checker (``repro.analysis.oracle_coupling``) fails the
    build if a kernel re-derives this conjunction inline, so the kernel and
    reference paths cannot silently fork.

    When ``digests``/``q_digest`` are given the 8-bit digest pre-filter is
    folded into the mask (~1/256 false-positive rate, resolved by the full
    key compare in the same expression).  Callers pass them pre-broadcast
    and pre-cast: the formula itself never changes dtypes.
    """
    m = (key_hi == q_hi) & (key_lo == q_lo)
    if digests is not None:
        m = m & (digests == q_digest)
    return m


def _match_in_bucket(
    state: HKVState, bucket: jax.Array, keys: U64, digest: jax.Array,
    use_digest: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(hit[N], slot[N]) of `keys` within rows `bucket`.

    Digest pre-filter first (one int8 == over the 128-lane row), then full
    64-bit key compare only on digest matches — Algorithm 1 lines 4–10.
    use_digest=False is the Exp#3a ablation: all 128 full-key compares.
    """
    khi = state.key_hi[bucket]                       # uint32 [N, S]
    klo = state.key_lo[bucket]
    if use_digest:
        kmask = match_lanes(khi, klo, keys.hi[:, None], keys.lo[:, None],
                            state.digests[bucket], digest[:, None])
    else:
        kmask = match_lanes(khi, klo, keys.hi[:, None], keys.lo[:, None])
    hit = jnp.any(kmask, axis=1)
    slot = jnp.argmax(kmask, axis=1).astype(jnp.int32)
    return hit, slot


def locate(state: HKVState, cfg: HKVConfig, keys: U64, probe: Probe | None = None) -> Locate:
    """Find which (bucket, slot) holds each key, if any.

    Invariant: a key occupies at most one slot table-wide (the upsert path
    only inserts keys it failed to locate, and always into one bucket), so
    first-match is the unique match.
    """
    if probe is None:
        probe = probe_keys(cfg, keys)
    hit1, slot1 = _match_in_bucket(state, probe.bucket1, keys, probe.digest,
                                   cfg.use_digest)
    if cfg.buckets_per_key == 2:
        hit2, slot2 = _match_in_bucket(state, probe.bucket2, keys, probe.digest,
                                       cfg.use_digest)
        found = (hit1 | hit2) & probe.valid
        bucket = jnp.where(hit1, probe.bucket1, jnp.where(hit2, probe.bucket2, probe.bucket1))
        slot = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    else:
        found = hit1 & probe.valid
        bucket = probe.bucket1
        slot = jnp.where(hit1, slot1, 0)
    s = state.slots_per_bucket
    return Locate(found=found, bucket=bucket, slot=slot, row=bucket * s + slot)


def gather_values(state: HKVState, loc: Locate, dim: int | None = None,
                  tier: str = "hbm") -> jax.Array:
    """Position-addressed value gather (paper §3.6): row = bucket*S + slot.

    Missing keys return zeros.  `dim` trims aux optimizer-state columns.
    In 'hmem' tier mode only the touched rows cross the host boundary.
    """
    from repro.core import table as table_mod

    rows = table_mod.tier_gather(tier, state.values, loc.row)
    if dim is not None:
        rows = rows[:, :dim]
    return jnp.where(loc.found[:, None], rows, jnp.zeros_like(rows))
