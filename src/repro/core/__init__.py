"""HKV core: table state, op engine, and the public handle surface.

Consumers import the handle layer from here::

    from repro.core import HKVTable
    table = HKVTable.create(capacity=128 * 128, dim=32)

`repro.core.ops` / `repro.core.table` stay importable as the underlying
engine (DESIGN.md §API layer).
"""

from repro.core.api import (  # noqa: F401
    HKVTable,
    KVTable,
    OpSession,
    TableEvictIf,
    TableFindOrInsert,
    TableInsertAndEvict,
    TableSweep,
    TableUpsert,
    dedupe_keys,
    normalize_keys,
)
from repro.core.merge import EvictionStream  # noqa: F401
from repro.core.predicates import SweepPredicate  # noqa: F401
from repro.core.table import HKVConfig, HKVState  # noqa: F401
from repro.core.tiered import (  # noqa: F401
    TieredDemote,
    TieredEvictIf,
    TieredFind,
    TieredFindOrInsert,
    TieredHKVTable,
    TieredState,
    TieredSweep,
    TieredUpsert,
    translate_scores,
)
from repro.core.u64 import U64  # noqa: F401
