"""HKV op engine (paper §4.1) — STL-style ops over a pure-functional state.

NOTE (API layering, DESIGN.md §API layer): the *public* surface is the
`HKVTable` handle in `repro.core.api`, which binds (state, cfg, backend)
once and normalizes key dtypes; these free functions remain the single
underlying implementation the handle delegates to.  New consumer code
should prefer `HKVTable` / `table.session()`; call these directly only
from inside `repro.core` / `repro.kernels` or where the unbound form is
genuinely needed (e.g. custom shard_map bodies).

Non-structural ops accept an optional precomputed `loc=` (a
`find.Locate` for the same key batch against a state with identical key
planes) so an op session can share one probe across commuting ops.

Triple-group role taxonomy (paper §3.5) survives on TPU as *dependency
structure* rather than a lock protocol (DESIGN.md §2):

  READERS   (find, find_ptr, contains, size, load_factor, export_batch*):
            consume the state, produce no new state.  XLA may fuse and
            reorder them freely — they commute with each other.
  UPDATERS  (assign, assign_add, assign_scores, update_rows): produce a
            new state but touch only values/scores of *existing* keys — no
            slot allocation, no digest writes, no eviction.  Two updater
            ops on disjoint keys commute; the training step exploits this
            by fusing gradient-assign with the forward lookup.
            `update_rows` is the gradient step proper: it applies a static
            `SparseOptimizer` variant to each resident key's full row, and
            on backend='kernel' runs the FUSED update_scan pass — probe +
            in-kernel optimizer apply + masked write-back in ONE launch
            (was locate + gather + host apply + scatter, ≥3 launches and
            2× row traffic through HBM).
  INSERTERS (insert_or_assign, find_or_insert, insert_and_evict, erase,
            clear): structural — bucket membership changes.  These are the
            only ops that form serialization points in a step schedule.

Every op is batch-synchronous, jittable, static-shape, and accepts the
EMPTY sentinel (0xFFFF_FFFF_FFFF_FFFF) as a padding key that is ignored.

Kernel backends (DESIGN.md §4, §Readers): the hot ops exist in two
implementations — the pure-jnp reference in this package and the Pallas
kernel path in `repro.kernels` — selected by a
`backend='auto'|'jnp'|'kernel'` argument.  READERS find/find_rows dispatch
to the FUSED find_scan pass (`repro.kernels.ops.find_fused_kernel`: digest
pre-filter + full-key confirm + score readout + in-line value gather in
one launch); find_ptr/contains take the metadata-only locate kernel; when
a session supplies a shared `loc=`, the value stage alone runs on the
kernel (gather_rows).  The INSERTERS insert_or_assign, insert_and_evict,
and find_or_insert dispatch to the fused upsert_scan path
(`repro.kernels.ops.upsert_kernel`), which shares this module's
batch-closure orchestration; the sweeps erase_if/evict_if dispatch their
mask stage.  Every kernel path is bit-identical to its jnp reference.
'auto' resolves to 'kernel' on TPU and 'jnp' elsewhere (off-TPU the
kernels run in interpret mode — correct but slow, so it is opt-in).
size/export_batch*, assign_scores, erase, clear, and accum_or_assign
remain jnp-only: they are trivial reductions or metadata-plane scatters
with no kernel to win.

Telemetry channel (DESIGN.md §Observability): every keyed op takes an
optional keyword-only `telemetry=` sink (`repro.obs.TelemetrySink`).
When supplied, the op records a device-computed `OpTelemetry` counter
record (probes, digest-prefilter passes, hits/misses, the upsert status
histogram) — computed by a pure OBSERVER over the pre-op state using the
same `probe_keys`/`match_lanes` formulas the op itself uses, so both
backends report identical numbers and op results stay bit-identical.
`telemetry=None` (the default) is literally the pre-telemetry code path:
the observer import and every counter expression live inside the
`telemetry is not None` branch, so the default adds zero launches and
zero jaxpr growth (pinned by tests/test_obs.py).  Whole-table scans
(size, load_factor, export_batch*) and clear carry no per-key probe and
are exempt (`repro.analysis.telemetry.TELEMETRY_EXEMPT`).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import find as find_mod
from repro.core import roles
from repro.core import merge as merge_mod
from repro.core import table as table_mod
from repro.core import u64
from repro.core.merge import (
    STATUS_EVICTED,
    STATUS_INSERTED,
    STATUS_INVALID,
    STATUS_REJECTED,
    STATUS_UPDATED,
    EvictionStream,
    MergeResult,
)
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64

def _obs():
    """Deferred observer import — only the `telemetry is not None` branch
    pays it, keeping the default path free of the obs subsystem."""
    from repro.obs import telemetry as obs_telemetry

    return obs_telemetry


# =============================================================================
# Readers
# =============================================================================


class FindResult(NamedTuple):
    values: jax.Array   # [N, dim] (zeros where not found)
    found: jax.Array    # bool [N]
    score_hi: jax.Array  # uint32 [N] (0 where not found)
    score_lo: jax.Array


def _fused_find(state: HKVState, cfg: HKVConfig, keys: U64, backend: str):
    """The reader-side kernel dispatch: the fused find_scan pass when the
    backend resolves to 'kernel', else None (caller falls through to the
    jnp reference).  One launch resolves match + scores + values."""
    if _resolve_backend(backend) != "kernel":
        return None
    from repro.kernels import ops as kernel_ops  # deferred: kernels import core

    return kernel_ops.find_fused_kernel(state, cfg, keys)


def _gather_shared(state: HKVState, cfg: HKVConfig, loc, dim):
    """Value gather at a caller-supplied (session-shared) locate — kernel
    row pipeline on the hbm tier, jnp `tier_gather` otherwise."""
    if cfg.value_tier == "hbm":
        from repro.kernels import ops as kernel_ops

        return kernel_ops.gather_rows_kernel(
            state, loc, state.values.shape[1] if dim is None else dim)
    return find_mod.gather_values(state, loc, dim, cfg.value_tier)


@roles.reader
def find(state: HKVState, cfg: HKVConfig, keys: U64,
         loc: Optional[find_mod.Locate] = None, *,
         backend: str = "auto", telemetry=None) -> FindResult:
    """Reader. Digest-accelerated lookup with value copy (paper `find`).

    backend='kernel' (or 'auto' on TPU) runs the FUSED find_scan pass when
    no shared `loc` is supplied: probe, match, score readout, and value
    gather in one kernel launch.  With a session-shared `loc`, the value
    stage alone runs on the kernel.  Bit-identical either way.

    Consumer code: prefer `HKVTable.find` / `session.find` (repro.core.api).
    """
    if loc is None:
        r = _fused_find(state, cfg, keys, backend)
        if r is not None:
            if telemetry is not None:
                telemetry.record(
                    "find", _obs().observe_find(state, cfg, keys, r.found))
            return FindResult(values=r.values[:, : cfg.dim], found=r.found,
                              score_hi=r.score_hi, score_lo=r.score_lo)
        loc = find_mod.locate(state, cfg, keys)
        vals = find_mod.gather_values(state, loc, cfg.dim, cfg.value_tier)
    elif _resolve_backend(backend) == "kernel":
        vals = _gather_shared(state, cfg, loc, cfg.dim)
    else:
        vals = find_mod.gather_values(state, loc, cfg.dim, cfg.value_tier)
    if telemetry is not None:
        telemetry.record(
            "find", _obs().observe_find(state, cfg, keys, loc.found))
    shi = jnp.where(loc.found, state.score_hi[loc.bucket, loc.slot], 0)
    slo = jnp.where(loc.found, state.score_lo[loc.bucket, loc.slot], 0)
    return FindResult(values=vals, found=loc.found, score_hi=shi, score_lo=slo)


@roles.reader
def find_ptr(state: HKVState, cfg: HKVConfig, keys: U64, *,
             backend: str = "auto", telemetry=None) -> find_mod.Locate:
    """Reader. The paper's pointer-returning `find*`: key-side work only.

    Returns position handles (bucket, slot, row) instead of copying values —
    the position-based addressing contract of §3.6 means `row` *is* the
    value address.  Dimension-independent, like the paper's ~7 B-KV/s path.
    backend='kernel' runs the metadata-only digest_scan locate (no value
    traffic — the fused pass would fetch rows this op must not touch).
    """
    if _resolve_backend(backend) == "kernel":
        from repro.kernels import ops as kernel_ops

        loc = kernel_ops.locate_kernel(state, cfg, keys)
    else:
        loc = find_mod.locate(state, cfg, keys)
    if telemetry is not None:
        telemetry.record(
            "find_ptr", _obs().observe_find(state, cfg, keys, loc.found))
    return loc


@roles.reader
def contains(state: HKVState, cfg: HKVConfig, keys: U64,
             loc: Optional[find_mod.Locate] = None, *,
             backend: str = "auto", telemetry=None) -> jax.Array:
    """Reader. Membership only (no value traffic)."""
    if loc is None:
        loc = find_ptr(state, cfg, keys, backend=backend)
    if telemetry is not None:
        telemetry.record(
            "contains", _obs().observe_find(state, cfg, keys, loc.found))
    return loc.found


class FindRowsResult(NamedTuple):
    rows: jax.Array     # [N, dim + aux] full-width table rows (zeros on miss)
    found: jax.Array    # bool [N]
    row: jax.Array      # int32 [N] value-plane row index (position addressing)
    score_hi: jax.Array  # uint32 [N] entry scores (0 where not found) — the
    score_lo: jax.Array  # tier hierarchy translates these on promotion


@roles.reader
def find_rows(state: HKVState, cfg: HKVConfig, keys: U64,
              loc: Optional[find_mod.Locate] = None, *,
              backend: str = "auto", telemetry=None) -> FindRowsResult:
    """Reader. Full-width row gather (embedding + aux optimizer columns).

    The sparse-optimizer path: gathers the entire stored row so slot state
    colocated with the embedding travels with it.  Missing keys return
    zero rows — callers must mask by `found` (the usual consumer, a
    row-refresh via `assign`, drops misses anyway).  Scores ride along so
    a promotion (`core/tiered.py`) can move an entry between tiers without
    a second metadata probe.  backend='kernel' takes the same fused
    find_scan pass as `find` — the kernel already gathers full-width rows
    and reads out scores, so this op is one launch too."""
    if loc is None:
        r = _fused_find(state, cfg, keys, backend)
        if r is not None:
            if telemetry is not None:
                telemetry.record(
                    "find_rows",
                    _obs().observe_find(state, cfg, keys, r.found))
            return FindRowsResult(rows=r.values, found=r.found, row=r.row,
                                  score_hi=r.score_hi, score_lo=r.score_lo)
        loc = find_mod.locate(state, cfg, keys)
        rows = find_mod.gather_values(state, loc, None, cfg.value_tier)
    elif _resolve_backend(backend) == "kernel":
        rows = _gather_shared(state, cfg, loc, None)
    else:
        rows = find_mod.gather_values(state, loc, None, cfg.value_tier)
    if telemetry is not None:
        telemetry.record(
            "find_rows", _obs().observe_find(state, cfg, keys, loc.found))
    shi = jnp.where(loc.found, state.score_hi[loc.bucket, loc.slot], 0)
    slo = jnp.where(loc.found, state.score_lo[loc.bucket, loc.slot], 0)
    return FindRowsResult(rows=rows, found=loc.found, row=loc.row,
                          score_hi=shi, score_lo=slo)


@roles.reader
def size(state: HKVState) -> jax.Array:
    """Reader. Number of live entries."""
    return jnp.sum(state.occupied_mask().astype(jnp.int32))


@roles.reader
def load_factor(state: HKVState) -> jax.Array:
    return state.load_factor()


class ExportResult(NamedTuple):
    key_hi: jax.Array
    key_lo: jax.Array
    values: jax.Array
    score_hi: jax.Array
    score_lo: jax.Array
    mask: jax.Array   # bool — live & predicate-matching entries


@roles.reader
def export_batch(
    state: HKVState, cfg: HKVConfig, bucket_start: int, bucket_count: int
) -> ExportResult:
    """Reader. Stream a contiguous bucket range to the caller (checkpointing).

    Static-shape: returns bucket_count*S entries with a liveness mask.
    Value rows cross tiers through `tier_gather`, so an 'hmem' table's
    checkpoint export honors the explicit host<->device crossing contract
    (§3.6) instead of slicing the host-resident plane in device code.
    """
    sl = slice(bucket_start, bucket_start + bucket_count)
    khi = state.key_hi[sl].reshape(-1)
    klo = state.key_lo[sl].reshape(-1)
    mask = ~u64.is_empty(U64(khi, klo))
    s = cfg.slots_per_bucket
    rows = table_mod.tier_gather(
        cfg.value_tier, state.values,
        jnp.arange(bucket_start * s, (bucket_start + bucket_count) * s,
                   dtype=jnp.int32),
    )
    return ExportResult(
        key_hi=khi,
        key_lo=klo,
        values=rows,
        score_hi=state.score_hi[sl].reshape(-1),
        score_lo=state.score_lo[sl].reshape(-1),
        mask=mask,
    )


@roles.reader
def export_batch_if(
    state: HKVState,
    cfg: HKVConfig,
    bucket_start: int,
    bucket_count: int,
    score_threshold: U64,
) -> ExportResult:
    """Reader. export_batch with a score >= threshold predicate (paper §4.1)."""
    out = export_batch(state, cfg, bucket_start, bucket_count)
    ge = u64.ge(U64(out.score_hi, out.score_lo), score_threshold)
    return out._replace(mask=out.mask & ge)


# =============================================================================
# Updaters (non-structural writes)
# =============================================================================


@roles.updater
def assign(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    update_scores: bool = False,
    loc: Optional[find_mod.Locate] = None,
    *,
    telemetry=None,
) -> HKVState:
    """Updater. Write values of *existing* keys in place; misses are no-ops.

    Never allocates slots, never evicts, never touches digests — the
    non-structural contract that lets updater batches run concurrently in
    the paper and fuse freely under XLA here.

    Consumer code: prefer `HKVTable.assign` / `session.assign`.
    """
    if loc is None:
        loc = find_mod.locate(state, cfg, keys)
    if telemetry is not None:
        telemetry.record(
            "assign", _obs().observe_update(state, cfg, keys, loc.found))
    b, s = cfg.num_buckets, cfg.slots_per_bucket
    # last-writer-wins on within-batch duplicates: scatter in batch order
    row = jnp.where(loc.found, loc.row, b * s)
    vdim = state.values.shape[1]
    if values.shape[1] < vdim:  # caller wrote only the embedding columns
        pad = jnp.zeros((values.shape[0], vdim - values.shape[1]), state.values.dtype)
        old = table_mod.tier_gather(
            cfg.value_tier, state.values, jnp.clip(loc.row, 0, b * s - 1)
        )[:, values.shape[1]:]
        values = jnp.concatenate([values, jnp.where(loc.found[:, None], old, pad)], axis=1)
    new_values = table_mod.tier_scatter(
        cfg.value_tier, state.values, row, values.astype(state.values.dtype)
    )
    state = state._replace(values=new_values)
    if update_scores:
        state = table_mod.advance_clock(state)
        ones = jnp.ones((keys.hi.shape[0],), jnp.uint32)
        new_sc = cfg.policy.update_score(
            U64(state.score_hi[loc.bucket, loc.slot], state.score_lo[loc.bucket, loc.slot]),
            state.clock,
            state.epoch,
            ones,
            None,
        )
        hb = jnp.where(loc.found, loc.bucket, b)
        state = state._replace(
            score_hi=state.score_hi.at[hb, loc.slot].set(new_sc.hi, mode="drop"),
            score_lo=state.score_lo.at[hb, loc.slot].set(new_sc.lo, mode="drop"),
        )
    return state


@roles.updater
def assign_add(
    state: HKVState, cfg: HKVConfig, keys: U64, deltas: jax.Array,
    loc: Optional[find_mod.Locate] = None,
    *,
    telemetry=None,
) -> HKVState:
    """Updater. values[k] += delta for existing keys (duplicates accumulate).

    This is the embedding-gradient path: sparse grads apply as a
    non-structural scatter-add, the TPU analogue of the paper's concurrent
    updater kernels.
    """
    if loc is None:
        loc = find_mod.locate(state, cfg, keys)
    if telemetry is not None:
        telemetry.record(
            "assign_add", _obs().observe_update(state, cfg, keys, loc.found))
    b, s = cfg.num_buckets, cfg.slots_per_bucket
    row = jnp.where(loc.found, loc.row, b * s)
    if deltas.shape[1] < state.values.shape[1]:
        pad = jnp.zeros(
            (deltas.shape[0], state.values.shape[1] - deltas.shape[1]), state.values.dtype
        )
        deltas = jnp.concatenate([deltas.astype(state.values.dtype), pad], axis=1)
    return state._replace(values=table_mod.tier_scatter(
        cfg.value_tier, state.values, row, deltas.astype(state.values.dtype), add=True
    ))


@roles.updater
def assign_scores(
    state: HKVState, cfg: HKVConfig, keys: U64, scores: U64,
    loc: Optional[find_mod.Locate] = None,
    *,
    telemetry=None,
) -> HKVState:
    """Updater. Overwrite scores of existing keys (paper `assign_scores`)."""
    if loc is None:
        loc = find_mod.locate(state, cfg, keys)
    if telemetry is not None:
        telemetry.record(
            "assign_scores",
            _obs().observe_update(state, cfg, keys, loc.found))
    hb = jnp.where(loc.found, loc.bucket, cfg.num_buckets)
    return state._replace(
        score_hi=state.score_hi.at[hb, loc.slot].set(scores.hi, mode="drop"),
        score_lo=state.score_lo.at[hb, loc.slot].set(scores.lo, mode="drop"),
    )


class RowUpdate(NamedTuple):
    """Structured updater payload for the gradient step (`update_rows`).

    `OpSession.update_rows` accepts this in place of an opaque callable:
    a static `SparseOptimizer` variant plus the per-key (deduped,
    segment-summed) gradient rows.  Being structured — the planner can see
    *what* the update is — lets the session route the whole op to the
    fused update_scan kernel instead of forcing the generic
    locate/gather/fn/scatter decomposition.
    """

    opt: Any            # SparseOptimizer (hashable/static — selects the variant)
    grads: jax.Array    # [N, dim] segment-summed gradient rows


class UpdateRowsResult(NamedTuple):
    state: HKVState
    found: jax.Array    # bool [N] — lane's key was resident and its row trained


@roles.updater
def update_rows(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    grads: jax.Array,
    opt,
    *,
    update_scores: bool = False,
    loc: Optional[find_mod.Locate] = None,
    backend: str = "auto",
    telemetry=None,
) -> UpdateRowsResult:
    """Updater. The gradient step: apply the sparse optimizer `opt` (a
    static `SparseOptimizer` variant) to each *existing* key's full row
    [embedding | aux slot state] in place.  Misses are no-ops — cache
    semantics: un-admitted keys never train.

    PRECONDITION: keys unique within the batch, `grads` pre-summed per key
    (`HKVEmbedding.apply_grads` dedupes + segment-sums before calling).

    backend='kernel' (or 'auto' on TPU) with no shared `loc` and no score
    touch runs the FUSED update_scan pass: probe + full-key confirm +
    in-kernel optimizer apply + masked row write-back in ONE kernel launch
    (was locate + gather_rows + host `opt.apply` + scatter_rows — ≥3
    launches and 2× row traffic).  With a session-shared `loc` or
    `update_scores=True`, the value stages run composed against that
    locate.  Bit-identical either way (pinned in
    tests/test_update_kernel.py).

    Consumer code: prefer `session.update_rows` with a `RowUpdate` payload.
    """
    if (loc is None and not update_scores
            and _resolve_backend(backend) == "kernel"):
        from repro.kernels import ops as kernel_ops  # deferred: kernels import core

        r = kernel_ops.update_rows_kernel(state, cfg, keys, grads, opt)
        if telemetry is not None:
            telemetry.record(
                "update_rows",
                _obs().observe_update(state, cfg, keys, r.found))
        return UpdateRowsResult(state=r.state, found=r.found)
    if loc is None:
        loc = find_mod.locate(state, cfg, keys)
        rows = find_mod.gather_values(state, loc, None, cfg.value_tier)
    elif _resolve_backend(backend) == "kernel":
        rows = _gather_shared(state, cfg, loc, None)
    else:
        rows = find_mod.gather_values(state, loc, None, cfg.value_tier)
    if telemetry is not None:
        telemetry.record(
            "update_rows", _obs().observe_update(state, cfg, keys, loc.found))
    new_rows = opt.apply(rows, grads, cfg.dim).astype(state.values.dtype)
    new_rows = jnp.where(loc.found[:, None], new_rows, rows)
    state = assign(state, cfg, keys, new_rows, update_scores=update_scores,
                   loc=loc)
    return UpdateRowsResult(state=state, found=loc.found)


# =============================================================================
# Inserters (structural writes)
# =============================================================================


class UpsertResult(NamedTuple):
    state: HKVState
    status: jax.Array  # int8 [N]: 0 invalid / 1 updated / 2 inserted / 3 evicted / 4 rejected


def _resolve_backend(backend: str) -> str:
    """'auto' picks the Pallas path on TPU and jnp elsewhere: off-TPU the
    kernels execute in interpret mode, which validates semantics but is far
    slower than XLA — callers opt in explicitly with backend='kernel'."""
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("jnp", "kernel"):
        raise ValueError(
            f"unknown backend {backend!r}; one of 'auto'|'jnp'|'kernel'"
        )
    return backend


def _upsert_stages(backend: str, cfg: HKVConfig):
    """Resolve a backend name to UpsertStages (None = pure-jnp defaults)."""
    if _resolve_backend(backend) == "jnp":
        return None
    from repro.kernels import ops as kernel_ops  # deferred: kernels import core

    return kernel_ops.kernel_stages(cfg)


@roles.inserter
def insert_or_assign(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    custom_scores: Optional[U64] = None,
    *,
    backend: str = "auto",
    telemetry=None,
) -> UpsertResult:
    """Inserter. Update-or-insert with in-line eviction/admission (Alg. 2/3).

    Consumer code: prefer `HKVTable.insert_or_assign` (repro.core.api).
    """
    res = merge_mod.upsert(
        state, cfg, keys, _pad_aux(values, state), custom_scores=custom_scores,
        stages=_upsert_stages(backend, cfg),
    )
    if telemetry is not None:
        telemetry.record(
            "insert_or_assign",
            _obs().observe_upsert(state, cfg, keys, res.status))
    return UpsertResult(state=res.state, status=res.status)


class InsertAndEvictResult(NamedTuple):
    state: HKVState
    status: jax.Array
    evicted: EvictionStream   # positionally aligned with the input batch


@roles.inserter
def insert_and_evict(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    custom_scores: Optional[U64] = None,
    *,
    backend: str = "auto",
    loc: Optional[find_mod.Locate] = None,
    telemetry=None,
) -> InsertAndEvictResult:
    """Inserter. insert_or_assign that returns the displaced entries in the
    same launch as a typed `EvictionStream` (the paper's single-kernel
    eviction hand-off — the transport the tier hierarchy's demotion cascade
    rides on; see `core/tiered.py`).  `loc` is the probe-sharing seam: a
    caller that just located the same batch passes it through to the
    closure (see `merge.upsert`)."""
    res = merge_mod.upsert(
        state,
        cfg,
        keys,
        _pad_aux(values, state),
        custom_scores=custom_scores,
        return_evicted=True,
        stages=_upsert_stages(backend, cfg),
        loc=loc,
    )
    if telemetry is not None:
        telemetry.record(
            "insert_and_evict",
            _obs().observe_upsert(state, cfg, keys, res.status))
    return InsertAndEvictResult(state=res.state, status=res.status,
                                evicted=res.evicted)


class FindOrInsertResult(NamedTuple):
    state: HKVState
    values: jax.Array   # [N, dim] — existing value on hit, init value on admit/reject
    found: jax.Array    # bool [N] — key existed before this call
    status: jax.Array
    # Displaced pairs (lanes populated iff return_evicted; else the
    # zero-length placeholder) — lets a cold-start admit double as the
    # hot tier's demotion source in `core/tiered.py`.
    evicted: EvictionStream


@roles.inserter
def find_or_insert(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    init_values: jax.Array,
    custom_scores: Optional[U64] = None,
    *,
    backend: str = "auto",
    return_evicted: bool = False,
    loc: Optional[find_mod.Locate] = None,
    telemetry=None,
) -> FindOrInsertResult:
    """Inserter. Lookup; insert `init_values` for missing keys (cold-start).

    Hits keep their stored value (scores touched per policy); misses insert
    subject to admission control.  Returned rows: stored value for every key
    now present; the caller's init row for keys whose admission was rejected
    (an *ephemeral* value — the paper returns the same from its workspace).

    Probe cost: ONE probe pass (ZERO when the caller supplies `loc`).  The
    closure publishes every key's post-op location (`MergeResult.loc`), so
    the value readback is a position-addressed gather — no pre- or
    post-locate (the seams that used to cost two extra passes; pinned by
    tests/test_upsert_kernel.py).

    Consumer code: prefer `HKVTable.find_or_insert` (repro.core.api).
    """
    res = merge_mod.upsert(
        state,
        cfg,
        keys,
        _pad_aux(init_values, state),
        custom_scores=custom_scores,
        write_hit_values=False,
        return_evicted=return_evicted,
        stages=_upsert_stages(backend, cfg),
        loc=loc,
    )
    vals = _gather_post(res, cfg, init_values, backend)
    if telemetry is not None:
        telemetry.record(
            "find_or_insert",
            _obs().observe_upsert(state, cfg, keys, res.status,
                                  found=res.found))
    return FindOrInsertResult(state=res.state, values=vals, found=res.found,
                              status=res.status, evicted=res.evicted)


def _gather_post(res: MergeResult, cfg: HKVConfig, init_values: jax.Array,
                 backend: str) -> jax.Array:
    """Value readback at the closure-published post-op locations; rejected
    keys fall back to the caller's init row (ephemeral)."""
    if _resolve_backend(backend) == "kernel" and cfg.value_tier == "hbm":
        from repro.kernels import ops as kernel_ops

        vals = kernel_ops.gather_rows_kernel(res.state, res.loc, cfg.dim)
    else:
        vals = find_mod.gather_values(res.state, res.loc, cfg.dim,
                                      cfg.value_tier)
    return jnp.where(res.loc.found[:, None], vals, init_values[:, : cfg.dim])


@roles.inserter
def accum_or_assign(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    custom_scores: Optional[U64] = None,
    *,
    telemetry=None,
) -> UpsertResult:
    """Inserter. Paper API: ACCUMULATE into existing entries (+=), ASSIGN new
    ones — the one-shot gradient-accumulation upsert.

    Batch semantics: duplicates of a key within the batch are pre-summed,
    then a single += applies on hit (or the sum is inserted on miss,
    admission-controlled)."""
    n = keys.hi.shape[0]
    d = merge_mod.dedupe_keys(keys)
    v = _pad_aux(values, state)
    v_sum = jax.ops.segment_sum(v[d.idx_sorted], d.gid, num_segments=n)[d.gid]
    # phase 1: += on existing keys (updater-style, but score-touching)
    state2 = assign_add(state, cfg, d.unique, v_sum)
    # phase 2: structural insert of the remaining misses with the summed value
    cs = None
    if custom_scores is not None:
        # last-writer-wins on duplicate lanes' customs, matching the
        # insert_or_assign convention: each sorted slot takes its group's
        # LAST original occurrence (idx_sorted would take the first)
        cs = U64(custom_scores.hi[d.last_index], custom_scores.lo[d.last_index])
    res = merge_mod.upsert(
        state2, cfg, d.unique, v_sum, custom_scores=cs, write_hit_values=False
    )
    # res.status is in unique's (key-sorted, deduped) order: only each
    # group's representative slot carries the group status (the masked
    # duplicates are INVALID) — d.inverse maps every original position to
    # its group's representative slot.
    status = res.status[d.inverse]
    if telemetry is not None:
        telemetry.record(
            "accum_or_assign",
            _obs().observe_upsert(state, cfg, keys, status))
    return UpsertResult(state=res.state, status=status)


@roles.inserter
def ingest(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    init_values: jax.Array,
    custom_scores: Optional[U64] = None,
    *,
    backend: str = "auto",
    telemetry=None,
) -> UpsertResult:
    """Inserter. Admission-only upsert: misses insert `init_values`
    (admission-controlled), hits keep their stored value with scores
    touched per policy — find_or_insert without the value readback (the
    deferred-structural overlapped-ingest schedule, §3.5/Exp#3e)."""
    res = merge_mod.upsert(
        state, cfg, keys, _pad_aux(init_values, state),
        custom_scores=custom_scores, write_hit_values=False,
        stages=_upsert_stages(backend, cfg),
    )
    if telemetry is not None:
        telemetry.record(
            "ingest", _obs().observe_upsert(state, cfg, keys, res.status,
                                            found=res.found))
    return UpsertResult(state=res.state, status=res.status)


@roles.inserter
def erase(state: HKVState, cfg: HKVConfig, keys: U64, *,
          telemetry=None) -> HKVState:
    """Inserter (structural). Remove keys; freed slots return to the pool."""
    loc = find_mod.locate(state, cfg, keys)
    if telemetry is not None:
        telemetry.record(
            "erase", _obs().observe_erase(state, cfg, keys, loc.found))
    b, s = cfg.num_buckets, cfg.slots_per_bucket
    hb = jnp.where(loc.found, loc.bucket, b)
    row = jnp.where(loc.found, loc.row, b * s)
    n = keys.hi.shape[0]
    return state._replace(
        key_hi=state.key_hi.at[hb, loc.slot].set(jnp.full((n,), u64.EMPTY_HI), mode="drop"),
        key_lo=state.key_lo.at[hb, loc.slot].set(jnp.full((n,), u64.EMPTY_LO), mode="drop"),
        digests=state.digests.at[hb, loc.slot].set(
            jnp.full((n,), u64.EMPTY_DIGEST), mode="drop"
        ),
        score_hi=state.score_hi.at[hb, loc.slot].set(jnp.zeros((n,), jnp.uint32), mode="drop"),
        score_lo=state.score_lo.at[hb, loc.slot].set(jnp.zeros((n,), jnp.uint32), mode="drop"),
        values=table_mod.tier_scatter(
            cfg.value_tier, state.values, row,
            jnp.zeros((n, state.values.shape[1]), state.values.dtype),
        ),
    )


@roles.inserter
def clear(state: HKVState, cfg: HKVConfig) -> HKVState:
    """Inserter (structural). Drop every entry."""
    return table_mod.create(cfg)._replace(
        clock_hi=state.clock_hi, clock_lo=state.clock_lo, epoch=state.epoch
    )


# =============================================================================
# Predicated sweeps (the maintenance subsystem's bulk ops — DESIGN.md
# §Maintenance).  These run BETWEEN serving waves, not inside upserts:
# whole-table passes over the metadata planes, driven by a declarative
# `SweepPredicate` (core/predicates.py) so they compile under jit and
# evaluate identically on both backends (the kernel path accelerates the
# mask stage; everything downstream is shared orchestration, the
# UpsertStages pattern).
# =============================================================================


class SweepResult(NamedTuple):
    state: HKVState
    swept: jax.Array     # int32 [] — entries removed by this sweep


class EvictIfResult(NamedTuple):
    state: HKVState
    # Rank-aligned (NOT batch-aligned) stream: lane i carries the i-th
    # coldest matching entry (score asc, then key asc — a total order),
    # mask False beyond the matched/limit count.  Same transport type as
    # the upsert eviction hand-off, so the tier hierarchy demotes it
    # through the identical cascade (`TieredHKVTable.demote`).
    evicted: EvictionStream
    count: jax.Array     # int32 [] — live lanes in the stream


def _sweep_mask(state: HKVState, cfg: HKVConfig, pred,
                backend: str) -> jax.Array:
    """bool [B, S] — live entries matching `pred` (the one stage the
    Pallas sweep kernel replaces; both backends evaluate the same
    `match_planes` formula, so the masks are bit-identical)."""
    if _resolve_backend(backend) == "kernel":
        from repro.kernels import ops as kernel_ops  # deferred: kernels import core

        return kernel_ops.sweep_mask_kernel(state, cfg, pred)
    return pred.matches(state.keys, state.scores) & state.occupied_mask()


def _erase_slots(state: HKVState, cfg: HKVConfig, mask: jax.Array) -> HKVState:
    """Clear every slot where mask [B, S] is True: keys/digests to the
    EMPTY sentinels, scores to 0, value rows zeroed (via the tier-aware
    masked row clear, honoring the §3.6 crossing contract)."""
    return state._replace(
        key_hi=jnp.where(mask, jnp.uint32(u64.EMPTY_HI), state.key_hi),
        key_lo=jnp.where(mask, jnp.uint32(u64.EMPTY_LO), state.key_lo),
        digests=jnp.where(mask, jnp.uint8(u64.EMPTY_DIGEST), state.digests),
        score_hi=jnp.where(mask, jnp.uint32(0), state.score_hi),
        score_lo=jnp.where(mask, jnp.uint32(0), state.score_lo),
        values=table_mod.tier_mask_rows(cfg.value_tier, state.values,
                                        ~mask.reshape(-1)),
    )


@roles.inserter
def erase_if(state: HKVState, cfg: HKVConfig, pred, *,
             backend: str = "auto", telemetry=None) -> SweepResult:
    """Inserter (structural). Remove EVERY live entry matching `pred` —
    the paper-family `erase_if` bulk op (TTL/epoch expiry rides on this
    with the `expire_before` canned predicate).

    Consumer code: prefer `HKVTable.erase_if` (repro.core.api).
    """
    mask = _sweep_mask(state, cfg, pred, backend)
    swept = jnp.sum(mask.astype(jnp.int32))
    if telemetry is not None:
        telemetry.record("erase_if", _obs().observe_sweep(cfg, swept))
    return SweepResult(state=_erase_slots(state, cfg, mask), swept=swept)


@roles.inserter
def evict_if(state: HKVState, cfg: HKVConfig, pred, budget: int, *,
             limit: Optional[jax.Array] = None,
             backend: str = "auto", telemetry=None) -> EvictIfResult:
    """Inserter (structural). Remove up to `budget` matching entries,
    COLDEST FIRST (ascending score, ties by ascending key — deterministic
    and backend-independent), and hand them back as an `EvictionStream`.

    This is the maintenance primitive behind proactive tier rebalancing:
    the hierarchy evicts the coldest hot-tier entries here and demotes
    the returned stream into the cold tier, so the serving path's
    reactive upsert evictions become rare (DESIGN.md §Maintenance).

    `budget` is static (the stream's lane count, clamped to the table's
    capacity — so the protocol surface accepts whole-hierarchy budgets
    uniformly across impls); `limit` is an optional DYNAMIC cap <=
    budget — lanes at rank >= limit stay resident (the watermark
    rebalancer computes the needed move count at trace time).
    """
    b, s = cfg.num_buckets, cfg.slots_per_bucket
    c = b * s
    if budget < 1:
        raise ValueError(f"budget must be >= 1; got {budget}")
    budget = min(budget, c)
    mask = _sweep_mask(state, cfg, pred, backend)
    flat = mask.reshape(-1)
    iota = jnp.arange(c, dtype=jnp.int32)
    # candidates first, ordered coldest-first: sort by (non-candidate,
    # score, key); keys are unique table-wide, so the order is total
    nc, _sh, _sl, _kh, _kl, row = jax.lax.sort(
        (
            (~flat).astype(jnp.uint32),
            state.score_hi.reshape(-1),
            state.score_lo.reshape(-1),
            state.key_hi.reshape(-1),
            state.key_lo.reshape(-1),
            iota,
        ),
        num_keys=5,
        is_stable=False,
    )
    top = lambda a: a[:budget]
    row_t = top(row)
    lane = top(nc) == 0
    if limit is not None:
        lane &= jnp.arange(budget, dtype=jnp.int32) < limit
    bkt = row_t // s
    slot = row_t % s
    khi = state.key_hi[bkt, slot]
    klo = state.key_lo[bkt, slot]
    vals = table_mod.tier_gather(cfg.value_tier, state.values,
                                 jnp.where(lane, row_t, 0))
    vals = jnp.where(lane[:, None], vals, jnp.zeros_like(vals))
    stream = EvictionStream(
        key_hi=jnp.where(lane, khi, 0),
        key_lo=jnp.where(lane, klo, 0),
        values=vals,
        score_hi=jnp.where(lane, state.score_hi[bkt, slot], 0),
        score_lo=jnp.where(lane, state.score_lo[bkt, slot], 0),
        mask=lane,
    )
    # erase the evicted slots (OOB-drop the masked-out lanes)
    eb = jnp.where(lane, bkt, b)
    nlanes = budget
    state = state._replace(
        key_hi=state.key_hi.at[eb, slot].set(
            jnp.full((nlanes,), u64.EMPTY_HI), mode="drop"),
        key_lo=state.key_lo.at[eb, slot].set(
            jnp.full((nlanes,), u64.EMPTY_LO), mode="drop"),
        digests=state.digests.at[eb, slot].set(
            jnp.full((nlanes,), u64.EMPTY_DIGEST), mode="drop"),
        score_hi=state.score_hi.at[eb, slot].set(
            jnp.zeros((nlanes,), jnp.uint32), mode="drop"),
        score_lo=state.score_lo.at[eb, slot].set(
            jnp.zeros((nlanes,), jnp.uint32), mode="drop"),
        values=table_mod.tier_scatter(
            cfg.value_tier, state.values, jnp.where(lane, row_t, c),
            jnp.zeros((nlanes, state.values.shape[1]), state.values.dtype),
        ),
    )
    count = jnp.sum(lane.astype(jnp.int32))
    if telemetry is not None:
        telemetry.record("evict_if", _obs().observe_evict_if(cfg, count))
    return EvictIfResult(state=state, evicted=stream, count=count)


# =============================================================================
# helpers
# =============================================================================


def pad_rows(values: jax.Array, plane: jax.Array) -> jax.Array:
    """Zero-pad caller rows up to the value plane's width (aux optimizer
    cols) — the ONE padding/dtype point every row-writing path shares
    (flat ops here, the tier hierarchy in `core/tiered.py`)."""
    vdim = plane.shape[1]
    if values.shape[1] == vdim:
        return values.astype(plane.dtype)
    pad = jnp.zeros((values.shape[0], vdim - values.shape[1]), plane.dtype)
    return jnp.concatenate([values.astype(plane.dtype), pad], axis=1)


def _pad_aux(values: jax.Array, state: HKVState) -> jax.Array:
    return pad_rows(values, state.values)
