"""HKV table configuration + state (paper §3.1–§3.2, Fig. 4).

Memory layout mirrors the paper's bucket design, expressed as structure-of-
arrays (the natural XLA/TPU layout):

  digests : uint8  [B, S]   one contiguous 128-byte row per bucket — the
                            TPU analogue of the GPU L1 cache-line-aligned
                            digest array (one VPU lane row covers the whole
                            candidate set; see DESIGN.md §2)
  key_hi  : uint32 [B, S]   64-bit keys as two planes
  key_lo  : uint32 [B, S]
  score_hi: uint32 [B, S]   64-bit scores as two planes
  score_lo: uint32 [B, S]
  values  : vdtype [B*S, D] position-based addressing: the value of slot
                            (b, s) lives at row b*S + s — no per-entry
                            pointer anywhere (paper §3.6)

`values` may live on a different memory tier than the key-side arrays
(tiered key-value separation, §3.6): `value_tier='hmem'` requests host
memory placement (`memory_kind='pinned_host'` on TPU); key-side processing
stays in HBM either way.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.scores import ScorePolicy, get_policy
from repro.core.u64 import U64

SLOTS_PER_BUCKET = 128  # the paper's (and the TPU lane width's) natural choice

# Memories-API compat: `jax.memory.Space` appeared after 0.4.37.  Where the
# running JAX has no addressable host space the HMEM tier degrades to a
# structural split (placement stays wherever XLA put it) — same behaviour
# the CPU dev container always had.
_HOST_SPACE = getattr(getattr(jax, "memory", None), "Space", None)


def _to_host(x: jax.Array) -> jax.Array:
    return jax.device_put(x, _HOST_SPACE.Host) if _HOST_SPACE else x


def _to_device(x: jax.Array) -> jax.Array:
    return jax.device_put(x, _HOST_SPACE.Device) if _HOST_SPACE else x


@dataclasses.dataclass(frozen=True)
class HKVConfig:
    """Static configuration of an HKV table."""

    capacity: int                      # total slots (B * S)
    dim: int                           # value vector length
    slots_per_bucket: int = SLOTS_PER_BUCKET
    buckets_per_key: int = 1           # 1 = single-bucket, 2 = dual-bucket (§3.4)
    score_policy: str = "lru"
    value_dtype: jnp.dtype = jnp.float32
    value_tier: str = "hbm"            # 'hbm' | 'hmem' (tiered KV separation §3.6)
    # Optional per-slot optimizer-state columns appended to each value row
    # (momentum etc. colocated with the embedding row, HugeCTR-style).
    aux_value_dim: int = 0
    # Ablation switch (Exp#3a): disable the 8-bit digest pre-filter so every
    # lookup compares all 128 full keys (paper Table 7's "No digest" column).
    use_digest: bool = True

    def __post_init__(self):
        if self.capacity % self.slots_per_bucket != 0:
            raise ValueError(
                f"capacity {self.capacity} must be a multiple of "
                f"slots_per_bucket {self.slots_per_bucket}"
            )
        if self.buckets_per_key not in (1, 2):
            raise ValueError("buckets_per_key must be 1 or 2")
        if self.value_tier not in ("hbm", "hmem"):
            raise ValueError("value_tier must be 'hbm' or 'hmem'")
        if self.num_buckets < 1:
            raise ValueError("capacity must hold at least one bucket")

    @property
    def num_buckets(self) -> int:
        return self.capacity // self.slots_per_bucket

    @property
    def total_value_dim(self) -> int:
        return self.dim + self.aux_value_dim

    @property
    def policy(self) -> ScorePolicy:
        return get_policy(self.score_policy)

    def bytes_per_entry(self) -> int:
        # key 8 B + digest 1 B + score 8 B (paper §5.1: 17 B metadata) + value
        return 17 + self.total_value_dim * jnp.dtype(self.value_dtype).itemsize


class HKVState(NamedTuple):
    """The table as a pytree of arrays (pure-functional state)."""

    key_hi: jax.Array    # uint32 [B, S]
    key_lo: jax.Array    # uint32 [B, S]
    digests: jax.Array   # uint8  [B, S]
    score_hi: jax.Array  # uint32 [B, S]
    score_lo: jax.Array  # uint32 [B, S]
    values: jax.Array    # vdtype [B*S, D(+aux)]
    clock_hi: jax.Array  # uint32 [] — global monotonic batch clock (LRU)
    clock_lo: jax.Array  # uint32 []
    epoch: jax.Array     # uint32 [] — application epoch (epoch_* policies)

    # -- typed views ---------------------------------------------------------

    @property
    def keys(self) -> U64:
        return U64(self.key_hi, self.key_lo)

    @property
    def scores(self) -> U64:
        return U64(self.score_hi, self.score_lo)

    @property
    def clock(self) -> U64:
        return U64(self.clock_hi, self.clock_lo)

    @property
    def num_buckets(self) -> int:
        return self.key_hi.shape[0]

    @property
    def slots_per_bucket(self) -> int:
        return self.key_hi.shape[1]

    def occupied_mask(self) -> jax.Array:
        return ~u64.is_empty(self.keys)

    def load_factor(self) -> jax.Array:
        occ = jnp.sum(self.occupied_mask().astype(jnp.int32))
        return occ.astype(jnp.float32) / float(self.key_hi.size)

    def bucket_occupancy(self) -> jax.Array:
        """int32 [B] — number of live entries per bucket."""
        return jnp.sum(self.occupied_mask().astype(jnp.int32), axis=1)


def create(config: HKVConfig) -> HKVState:
    """Allocate an empty table."""
    b, s = config.num_buckets, config.slots_per_bucket
    state = HKVState(
        key_hi=jnp.full((b, s), u64.EMPTY_HI, jnp.uint32),
        key_lo=jnp.full((b, s), u64.EMPTY_LO, jnp.uint32),
        digests=jnp.full((b, s), u64.EMPTY_DIGEST, jnp.uint8),
        score_hi=jnp.zeros((b, s), jnp.uint32),
        score_lo=jnp.zeros((b, s), jnp.uint32),
        values=jnp.zeros((b * s, config.total_value_dim), config.value_dtype),
        clock_hi=jnp.zeros((), jnp.uint32),
        clock_lo=jnp.zeros((), jnp.uint32),
        epoch=jnp.zeros((), jnp.uint32),
    )
    if config.value_tier == "hmem":
        state = place_value_tier(state)
    return state


def place_value_tier(state: HKVState) -> HKVState:
    """Place the value plane on host memory where the backend supports it.

    On TPU this issues a device_put with memory_kind='pinned_host' (zero-copy
    mapped into the device address space — the paper's HMEM tier). Backends
    without host memory kinds (the CPU dev container) keep the array where it
    is; the tier then remains a structural split that the dry-run compiles.
    """
    try:
        return state._replace(values=_to_host(state.values))
    except (ValueError, RuntimeError, KeyError):
        return state


# ---------------------------------------------------------------------------
# Tiered key-value separation (§3.6): explicit value-plane tier crossings.
#
# In 'hmem' mode the value plane lives in host memory; key-side processing
# never leaves HBM.  Position-based addressing means only the TOUCHED ROWS
# ever cross the tier: a gather routes its indices to host, gathers there,
# and transfers just the result rows back (the paper's zero-copy mapped-
# pointer contract expressed in the XLA memories API); scatters go the
# other way.  'hbm' mode: passthrough.
# ---------------------------------------------------------------------------


def tier_gather(tier: str, values: jax.Array, rows: jax.Array) -> jax.Array:
    if tier != "hmem":
        return values[rows]
    out_h = values[_to_host(rows)]
    return _to_device(out_h)


def tier_scatter(tier: str, values: jax.Array, rows: jax.Array,
                 updates: jax.Array, *, add: bool = False,
                 mode: str = "drop") -> jax.Array:
    if tier != "hmem":
        op = values.at[rows]
        return op.add(updates, mode=mode) if add else op.set(updates, mode=mode)
    rows_h = _to_host(rows)
    upd_h = _to_host(updates)
    op = values.at[rows_h]
    return op.add(upd_h, mode=mode) if add else op.set(upd_h, mode=mode)


def tier_mask_rows(tier: str, values: jax.Array, keep: jax.Array) -> jax.Array:
    """Zero every value row where ~keep [B*S] (the whole-plane masked clear
    the maintenance sweeps use).  In 'hmem' mode only the keep mask crosses
    to the host — the value rows themselves never leave their tier."""
    if tier != "hmem":
        return jnp.where(keep[:, None], values, jnp.zeros_like(values))
    keep_h = _to_host(keep)
    return jnp.where(keep_h[:, None], values, jnp.zeros_like(values))


def advance_clock(state: HKVState) -> HKVState:
    """Tick the global LRU clock (one tick per batched op, paper's device clock)."""
    c = u64.add_u32(state.clock, jnp.uint32(1))
    return state._replace(clock_hi=c.hi, clock_lo=c.lo)


def set_epoch(state: HKVState, epoch) -> HKVState:
    return state._replace(epoch=jnp.asarray(epoch, jnp.uint32))


def value_row_index(bucket: jax.Array, slot: jax.Array, slots_per_bucket: int) -> jax.Array:
    """Position-based addressing (§3.6): value row = bucket * S + slot."""
    return bucket * slots_per_bucket + slot
