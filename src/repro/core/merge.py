"""Batch-synchronous bucket merge — the TPU closure of paper Algorithms 2 & 3.

The paper resolves each upsert with a per-warp CAS loop.  XLA/TPU has no
device-wide CAS; it has world-class sorts and segmented reductions.  This
module re-derives the paper's in-line score-driven upsert as a deterministic
*batch closure*:

  Applying Algorithm 2 sequentially, in canonical order (hits first, then
  misses per bucket in descending incoming-score order), to every entry of a
  batch yields per bucket exactly the **top-S-by-score union** of
  (existing entries ∪ incoming entries), with ties won by existing entries
  (then by lower key).  We compute that closure directly:

  phase 1 (non-structural) — batch keys already present are *updates*:
      value/score scatter at their (bucket, slot); no structure changes.
  phase 2 (structural)     — remaining keys are *insertions*: per target
      bucket, pair the r-th best incoming entry with the r-th weakest
      existing slot (empties weakest, then ascending score).  The classic
      two-sorted-lists argument shows this pairing realizes the top-S
      union merge:  incoming rank r is admitted iff it strictly beats
      victim rank r; admissions are a prefix of incoming ranks.

Properties preserved from the paper:
  * CS1 — every full-bucket upsert resolves in place (evict or reject);
  * CS2 — no rehash, no capacity failure, table shape never changes;
  * admission control (Alg. 2 line 12 / Alg. 3 line 7): an incoming entry
    that cannot beat the weakest survivor is Rejected;
  * eviction always removes the bucket-minimum-score entry(s);
  * dual-bucket two-phase policy (Alg. 3): D1 less-loaded while free slots
    exist, D2 lower-min-score at full occupancy.

Deviation (documented): on *exact* score ties Alg. 2 admits the incoming
key (`s < s_min` rejects), which makes sequential outcomes depend on batch
order.  The batch closure breaks ties in favor of existing entries, making
the result order-independent and idempotent.  LRU/epoch clocks are strictly
monotonic so ties between old and new scores only arise for LFU count
collisions and custom scores; Exp#3d shows admission behaviour matches the
paper's Table 9 in both regimes.

Everything is static-shape: a batch of N keys costs O(N log N) sort work
plus O(N·S) gathered bucket rows — no data-dependent shapes, no host
round-trips, jit/shard_map friendly.

The heavy stages (locate, target selection, victim extraction, value
gather/scatter) are pluggable via `UpsertStages` (DESIGN.md §4): the
pure-jnp defaults below are the reference, `repro.kernels.ops` swaps in
the fused Pallas kernels.  The orchestration — and therefore every
ordering decision — is shared, so the backends are bit-identical.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import find as find_mod
from repro.core import table as table_mod
from repro.core import u64
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64

# Per-entry status codes (reported in the original batch order).
STATUS_INVALID = np.int8(0)   # input slot held the EMPTY sentinel
STATUS_UPDATED = np.int8(1)   # key existed: value/score updated in place
STATUS_INSERTED = np.int8(2)  # inserted into an empty slot
STATUS_EVICTED = np.int8(3)   # inserted by evicting a minimum-score entry
STATUS_REJECTED = np.int8(4)  # admission control refused the entry


class UpsertStages(NamedTuple):
    """Pluggable backends for the heavy stages of the batch closure.

    `upsert` is one deterministic orchestration with five replaceable
    stages.  The defaults (`jnp_stages`) are the pure-jnp reference; the
    Pallas path (`repro.kernels.ops.upsert_kernel`) swaps in kernel-backed
    implementations of the same contracts.  Because the orchestration —
    dedupe, canonical sort, rank pairing, admission control, status
    accounting — is shared, the two backends are bit-identical by
    construction wherever the stage contracts are met (DESIGN.md §4).

      locate(state, cfg, keys, probe) -> find_mod.Locate
          digest pre-filter + full-key match of the deduped batch.
      select_target(state, cfg, probe) -> int32 [N]
          dual-bucket two-phase target selection (post-phase-1 state).
      victim_at_rank(state, cfg, bkt_g, rank) ->
          (slot int32[N], occupied bool[N], score U64[N], key U64[N])
          the rank-th weakest entry of each row of `bkt_g` under the
          total victim order (occupied asc, score asc, key asc, slot asc):
          empties first (lowest slot first), then ascending score.
      gather_values(cfg, values, rows, mask) -> [N, Dtot]
          masked row gather (evicted-value hand-off); zeros where ~mask.
      scatter_values(cfg, values, rows, updates, mask) -> new values
          masked row scatter; masked rows must be unique within the batch.
    """

    locate: object
    select_target: object
    victim_at_rank: object
    gather_values: object
    scatter_values: object


class EvictionStream(NamedTuple):
    """Displaced `(key, value, score)` pairs of one structural op — the
    paper's in-launch eviction hand-off (§3.6) as a first-class typed
    result.  This is the transport contract the tier hierarchy rides on
    (`core/tiered.py`): a hot-tier upsert's stream upserts into the cold
    tier (demotion), a promotion's displaced victims cascade back down.

    All arrays share the batch length N and align POSITIONALLY with the
    op's input batch: lane i carries the pair displaced by input key i
    (mask False = lane displaced nothing; its key/value/score lanes are
    zeros, NOT the EMPTY sentinel — mask before reusing them as keys,
    e.g. via `masked_keys()`)."""

    key_hi: jax.Array    # uint32 [N]
    key_lo: jax.Array    # uint32 [N]
    values: jax.Array    # vdtype [N, Dtot] full-width rows (incl. aux cols)
    score_hi: jax.Array  # uint32 [N]
    score_lo: jax.Array  # uint32 [N]
    mask: jax.Array      # bool [N] — lane carries a displaced pair

    @property
    def keys(self) -> U64:
        return U64(self.key_hi, self.key_lo)

    @property
    def scores(self) -> U64:
        return U64(self.score_hi, self.score_lo)

    def masked_keys(self) -> U64:
        """Keys with non-displacing lanes set to the EMPTY sentinel — the
        form a downstream table op ingests directly (EMPTY lanes are
        ignored by every op; raw zero lanes would be a VALID key 0)."""
        return U64(
            jnp.where(self.mask, self.key_hi, jnp.uint32(u64.EMPTY_HI)),
            jnp.where(self.mask, self.key_lo, jnp.uint32(u64.EMPTY_LO)),
        )

    def count(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32))

    @classmethod
    def zero(cls, n: int, vdim: int, vdtype) -> "EvictionStream":
        """A stream of n lanes displacing nothing (n=0: the placeholder
        returned when the caller did not request the eviction hand-off)."""
        z = jnp.zeros((n,), jnp.uint32)
        return cls(
            key_hi=z, key_lo=z,
            values=jnp.zeros((n, vdim), vdtype),
            score_hi=z, score_lo=z,
            mask=jnp.zeros((n,), bool),
        )


class MergeResult(NamedTuple):
    state: HKVState
    status: jax.Array            # int8 [N] in original batch order
    # The eviction hand-off: lanes populated iff return_evicted (else the
    # zero-length EvictionStream placeholder).
    evicted: EvictionStream
    # Post-op key locations (batch order), produced as a byproduct of the
    # closure so callers like find_or_insert need NO extra probe passes:
    found: jax.Array             # bool [N] — key existed BEFORE this op
    loc: find_mod.Locate         # where each key lives AFTER this op
                                 # (loc.found = present now: hit or admitted)


def _dedupe_sort(keys: U64):
    """Sort batch by key; derive group ids / multiplicities / last-writer index.

    Returns (in key-sorted space): keys_s, idx_s (original positions),
    gid (group id), count (group multiplicity broadcast to members),
    last_idx (original index of the group's last occurrence — the batch's
    last writer), rep_mask (True at each group's first sorted element for
    valid keys).
    """
    n = keys.hi.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    hi_s, lo_s, idx_s = jax.lax.sort((keys.hi, keys.lo, iota), num_keys=2, is_stable=True)
    keys_s = U64(hi_s, lo_s)
    prev = U64(jnp.roll(hi_s, 1), jnp.roll(lo_s, 1))
    is_first = jnp.concatenate(
        [jnp.ones((1,), bool), u64.ne(keys_s, prev).astype(bool)[1:]]
    )
    gid = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    ones = jnp.ones((n,), jnp.uint32)
    counts = jax.ops.segment_sum(ones, gid, num_segments=n)
    last_idx = jax.ops.segment_max(idx_s, gid, num_segments=n)
    valid_s = ~u64.is_empty(keys_s)
    rep_mask = is_first & valid_s
    return keys_s, idx_s, gid, counts[gid], last_idx[gid], rep_mask


class DedupeResult(NamedTuple):
    """Key-batch dedupe in sorted space (the engine's canonical form).

    All arrays have the batch length N.  `unique` carries each group's
    representative key at the group's first sorted slot and the EMPTY
    sentinel elsewhere — exactly the shape table ops expect (duplicates
    masked out, constant shape preserved).
    """

    unique: U64            # [N] EMPTY-padded representative keys (sorted space)
    idx_sorted: jax.Array  # int32 [N] original position of sorted slot j
    gid: jax.Array         # int32 [N] group id of sorted slot j
    rep_mask: jax.Array    # bool [N] True at each group's first sorted slot
    last_index: jax.Array  # int32 [N] original index of the group's LAST occurrence
    inverse: jax.Array     # int32 [N] original position -> its rep's sorted slot


def dedupe_keys(keys: U64) -> DedupeResult:
    """Public dedupe over the canonical key sort (shared by the engine and
    the api layer — see `repro.core.api.dedupe_keys` for the normalizing
    wrapper consumers use): route/reduce per `unique`, then map per-group
    results back with `inverse`."""
    n = keys.hi.shape[0]
    keys_s, idx_s, gid, _count, last_idx, rep = _dedupe_sort(keys)
    unique = u64.select(rep, keys_s, u64.empty_sentinel((n,)))
    rep_pos = jax.ops.segment_min(
        jnp.arange(n, dtype=jnp.int32), gid, num_segments=n
    )
    inverse = jnp.zeros((n,), jnp.int32).at[idx_s].set(rep_pos[gid])
    return DedupeResult(unique=unique, idx_sorted=idx_s, gid=gid,
                        rep_mask=rep, last_index=last_idx, inverse=inverse)


def _bucket_minscore_and_occ(state: HKVState, bucket: jax.Array):
    """(occupancy[N], min-score[N] as U64) of the given bucket rows.

    Empty slots are excluded from the min (treated as +inf); a fully empty
    bucket reports the all-ones max sentinel.
    """
    occ_row = ~u64.is_empty(U64(state.key_hi[bucket], state.key_lo[bucket]))
    occ = jnp.sum(occ_row.astype(jnp.int32), axis=1)
    shi = jnp.where(occ_row, state.score_hi[bucket], jnp.uint32(0xFFFFFFFF))
    slo = jnp.where(occ_row, state.score_lo[bucket], jnp.uint32(0xFFFFFFFF))
    # lexicographic min via single sort-free reduction: min hi, then min lo | hi==minhi
    min_hi = jnp.min(shi, axis=1)
    lo_cand = jnp.where(shi == min_hi[:, None], slo, jnp.uint32(0xFFFFFFFF))
    min_lo = jnp.min(lo_cand, axis=1)
    return occ, U64(min_hi, min_lo)


def _select_target_bucket(
    state: HKVState, cfg: HKVConfig, probe: find_mod.Probe
) -> jax.Array:
    """Dual-bucket two-phase selection (paper Alg. 3 / Fig. 5).

    D1 (warm-up): while either candidate has a free slot, insert into the
    less-occupied bucket (ties -> primary).  D2 (steady state): both full,
    evict in the bucket with the lower minimum score (ties -> primary).
    """
    if cfg.buckets_per_key == 1:
        return probe.bucket1
    s = cfg.slots_per_bucket
    occ1, min1 = _bucket_minscore_and_occ(state, probe.bucket1)
    occ2, min2 = _bucket_minscore_and_occ(state, probe.bucket2)
    any_free = (occ1 < s) | (occ2 < s)
    d1 = jnp.where(occ2 < occ1, probe.bucket2, probe.bucket1)
    d2 = jnp.where(u64.lt(min2, min1), probe.bucket2, probe.bucket1)
    return jnp.where(any_free, d1, d2)


def _jnp_victim_at_rank(state: HKVState, cfg: HKVConfig, bkt_g: jax.Array,
                        rank: jax.Array):
    """Rank-th weakest entry per gathered bucket row, via a per-row sort.

    Victim order is the 6-key lexicographic sort (occupied asc, score asc,
    key asc, slot asc) — fully deterministic: empties claim ascending slot
    order, occupied entries ascend by score then key (unique table-wide).
    """
    n = bkt_g.shape[0]
    s = cfg.slots_per_bucket
    row_occ = ~u64.is_empty(U64(state.key_hi[bkt_g], state.key_lo[bkt_g]))
    slot_iota = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (n, s))
    v_occ, v_shi, v_slo, v_khi, v_klo, v_slot = jax.lax.sort(
        (
            row_occ.astype(jnp.uint32),
            state.score_hi[bkt_g],
            state.score_lo[bkt_g],
            state.key_hi[bkt_g],
            state.key_lo[bkt_g],
            slot_iota,
        ),
        dimension=1,
        num_keys=6,
        is_stable=False,
    )
    r_cl = jnp.clip(rank, 0, s - 1)[:, None]
    take = lambda a: jnp.take_along_axis(a, r_cl, axis=1)[:, 0]
    return (
        take(v_slot),
        take(v_occ).astype(bool),
        U64(take(v_shi), take(v_slo)),
        U64(take(v_khi), take(v_klo)),
    )


def _jnp_gather_values(cfg: HKVConfig, values: jax.Array, rows: jax.Array,
                       mask: jax.Array) -> jax.Array:
    out = table_mod.tier_gather(cfg.value_tier, values, jnp.where(mask, rows, 0))
    return jnp.where(mask[:, None], out, jnp.zeros_like(out))


def _jnp_scatter_values(cfg: HKVConfig, values: jax.Array, rows: jax.Array,
                        updates: jax.Array, mask: jax.Array) -> jax.Array:
    oob = values.shape[0]  # mode='drop' discards masked-out lanes
    return table_mod.tier_scatter(
        cfg.value_tier, values, jnp.where(mask, rows, oob), updates
    )


def jnp_stages() -> UpsertStages:
    """The pure-jnp reference implementation of every upsert stage."""
    return UpsertStages(
        locate=lambda state, cfg, keys, probe: find_mod.locate(state, cfg, keys, probe),
        select_target=_select_target_bucket,
        victim_at_rank=_jnp_victim_at_rank,
        gather_values=_jnp_gather_values,
        scatter_values=_jnp_scatter_values,
    )


def upsert(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    *,
    custom_scores: Optional[U64] = None,
    write_hit_values: bool = True,
    update_hit_scores: bool = True,
    insert_values: Optional[jax.Array] = None,
    return_evicted: bool = False,
    stages: Optional[UpsertStages] = None,
    loc: Optional[find_mod.Locate] = None,
) -> MergeResult:
    """The batch closure of insert_or_assign / find_or_insert / insert_and_evict.

    values        : [N, Dtot] rows written on hit (when write_hit_values)
                    and inserted on miss (unless insert_values overrides).
    insert_values : optional distinct rows for the insertion path
                    (find_or_insert: hits keep their value, misses get inits).
    loc           : optional precomputed locate of `keys` (BATCH order)
                    against this state's key planes — the PR-2 probe-sharing
                    seam: when a caller just probed the same batch (e.g. the
                    tier hierarchy's pre-pass), the closure permutes it into
                    its sorted space instead of issuing its own locate.
                    Locate output depends only on the key plane, so the
                    substitution is exact.
    """
    n = keys.hi.shape[0]
    b, s = cfg.num_buckets, cfg.slots_per_bucket
    vdim = state.values.shape[1]
    policy = cfg.policy
    if insert_values is None:
        insert_values = values
    if stages is None:
        stages = jnp_stages()

    # One clock tick per batched op (the paper's per-launch device clock).
    state = table_mod.advance_clock(state)
    clock, epoch = state.clock, state.epoch

    # ---- dedupe ------------------------------------------------------------
    keys_s, idx_s, gid, count_s, last_idx_s, rep_mask = _dedupe_sort(keys)
    custom_s = None
    if custom_scores is not None:
        custom_s = U64(custom_scores.hi[last_idx_s], custom_scores.lo[last_idx_s])

    # status accumulated per group id, mapped back to batch order at the end
    status_g = jnp.zeros((n,), jnp.int8)

    # ---- phase 1: hits (non-structural updater work) ------------------------
    probe_s = find_mod.probe_keys(cfg, keys_s)
    if loc is None:
        loc = stages.locate(state, cfg, keys_s, probe_s)
    else:
        # caller-provided batch-order locate -> sorted space.  EMPTY lanes
        # are force-missed (a caller may pass a probe of the unmasked batch;
        # every use of `loc` below is already rep_mask/valid-gated, but the
        # mask keeps the permuted Locate self-consistent).
        valid_s = ~u64.is_empty(keys_s)
        loc = find_mod.Locate(
            found=loc.found[idx_s] & valid_s,
            bucket=loc.bucket[idx_s],
            slot=loc.slot[idx_s],
            row=loc.row[idx_s],
        )
    hit = loc.found & rep_mask

    old_sc = U64(state.score_hi[loc.bucket, loc.slot], state.score_lo[loc.bucket, loc.slot])
    new_sc = policy.update_score(old_sc, clock, epoch, count_s, custom_s)
    hb = jnp.where(hit & jnp.asarray(update_hit_scores), loc.bucket, b)  # OOB -> drop
    state = state._replace(
        score_hi=state.score_hi.at[hb, loc.slot].set(new_sc.hi, mode="drop"),
        score_lo=state.score_lo.at[hb, loc.slot].set(new_sc.lo, mode="drop"),
    )
    if write_hit_values:
        state = state._replace(
            values=stages.scatter_values(
                cfg, state.values, loc.row,
                values[last_idx_s].astype(state.values.dtype), hit,
            )
        )
    status_g = status_g.at[gid].max(jnp.where(hit, STATUS_UPDATED, STATUS_INVALID))

    # ---- phase 2: misses (structural inserter work) --------------------------
    miss = rep_mask & ~loc.found
    target = stages.select_target(state, cfg, probe_s)
    init_sc = policy.init_score(clock, epoch, count_s, custom_s, (n,))

    # bucket-sort the misses: (bucket, score desc, key asc) — canonical order
    bkt_key = jnp.where(miss, target, b).astype(jnp.int32)
    (bkt_m, _nsh, _nsl, khi_m, klo_m, idx_m, vrow_m, dig_m, shi_m, slo_m, gid_m) = jax.lax.sort(
        (
            bkt_key,
            ~init_sc.hi,       # bitwise-not => descending score order
            ~init_sc.lo,
            keys_s.hi,
            keys_s.lo,
            idx_s,
            last_idx_s,
            probe_s.digest,
            init_sc.hi,
            init_sc.lo,
            gid,
        ),
        num_keys=5,
        is_stable=False,
    )
    mask_m = bkt_m < b
    iota = jnp.arange(n, dtype=jnp.int32)
    is_newb = jnp.concatenate([jnp.ones((1,), bool), (bkt_m[1:] != bkt_m[:-1])])
    run_start = jax.lax.cummax(jnp.where(is_newb, iota, -1))
    rank = iota - run_start  # within-bucket rank r (incoming, descending score)

    # victim order per touched bucket row: empties first (ascending slot),
    # then ascending score, ties broken by ascending key then slot — a total
    # order, so victim choice is deterministic and backend-independent
    bkt_g = jnp.clip(bkt_m, 0, b - 1)
    victim_slot, victim_occ, victim_sc, victim_key = stages.victim_at_rank(
        state, cfg, bkt_g, rank
    )
    inc_sc = U64(shi_m, slo_m)
    # admission control: strictly beat the paired victim (existing wins ties)
    admitted = mask_m & (rank < s) & (~victim_occ | u64.gt(inc_sc, victim_sc))
    evicts = admitted & victim_occ

    # evicted outputs must be gathered before the overwrite
    victim_row = bkt_g * s + victim_slot
    if return_evicted:
        ev_values = stages.gather_values(cfg, state.values, victim_row, evicts)
    else:
        ev_values = jnp.zeros((n, vdim), state.values.dtype)

    # structural scatter (conflict-free: distinct (bucket, victim_slot) pairs)
    tb = jnp.where(admitted, bkt_m, b)
    state = state._replace(
        key_hi=state.key_hi.at[tb, victim_slot].set(khi_m, mode="drop"),
        key_lo=state.key_lo.at[tb, victim_slot].set(klo_m, mode="drop"),
        digests=state.digests.at[tb, victim_slot].set(dig_m, mode="drop"),
        score_hi=state.score_hi.at[tb, victim_slot].set(shi_m, mode="drop"),
        score_lo=state.score_lo.at[tb, victim_slot].set(slo_m, mode="drop"),
        values=stages.scatter_values(
            cfg, state.values, victim_row,
            insert_values[vrow_m].astype(state.values.dtype), admitted,
        ),
    )
    status_m = jnp.where(
        admitted,
        jnp.where(evicts, STATUS_EVICTED, STATUS_INSERTED),
        jnp.where(mask_m, STATUS_REJECTED, STATUS_INVALID),
    ).astype(jnp.int8)
    status_g = status_g.at[gid_m].max(status_m)

    # map group status back to original batch order (duplicates share status)
    status = jnp.zeros((n,), jnp.int8).at[idx_s].set(status_g[gid])

    # ---- post-op locations (batch order) ------------------------------------
    # The closure already knows where every key ended up: hits stayed at
    # their located (bucket, slot); admitted misses took their paired
    # victim's slot in the target bucket.  Publishing this kills the
    # pre/post re-probe passes in find_or_insert (one probe total).
    # A group is either a hit or a miss, so the two scatters are disjoint.
    #
    # One subtlety: a HIT can lose its slot within the same batch — an
    # admitted miss whose init score beats the hit's just-updated score
    # (reachable under LFU-family/custom policies, never under monotone
    # LRU clocks) claims it as a victim.  The published location must
    # then report the key as GONE, exactly like the old post-insert
    # re-probe did: check the final key plane at the hit's position.
    pos_b = jnp.zeros((n,), jnp.int32)
    pos_s = jnp.zeros((n,), jnp.int32)
    pos_in = jnp.zeros((n,), bool)
    hit_live = hit & find_mod.match_lanes(
        state.key_hi[loc.bucket, loc.slot], state.key_lo[loc.bucket, loc.slot],
        keys_s.hi, keys_s.lo)
    hg = jnp.where(hit_live, gid, n)
    pos_b = pos_b.at[hg].set(loc.bucket, mode="drop")
    pos_s = pos_s.at[hg].set(loc.slot, mode="drop")
    pos_in = pos_in.at[hg].set(True, mode="drop")
    ag = jnp.where(admitted, gid_m, n)
    pos_b = pos_b.at[ag].set(bkt_m, mode="drop")
    pos_s = pos_s.at[ag].set(victim_slot, mode="drop")
    pos_in = pos_in.at[ag].set(True, mode="drop")
    # sorted-space per-group results -> original batch order (dups share)
    to_batch = lambda a: jnp.zeros((n,), a.dtype).at[idx_s].set(a[gid])
    post_loc = find_mod.Locate(
        found=to_batch(pos_in),
        bucket=to_batch(pos_b),
        slot=to_batch(pos_s),
        row=to_batch(pos_b * s + pos_s),
    )
    pre_found = jnp.zeros((n,), bool).at[idx_s].set(loc.found)

    if return_evicted:
        zero32 = jnp.zeros((n,), jnp.uint32)
        oe = jnp.where(evicts, idx_m, n)  # original position of the evictor
        stream = EvictionStream(
            key_hi=zero32.at[oe].set(victim_key.hi, mode="drop"),
            key_lo=zero32.at[oe].set(victim_key.lo, mode="drop"),
            values=jnp.zeros((n, vdim), state.values.dtype)
            .at[oe]
            .set(ev_values, mode="drop"),
            score_hi=zero32.at[oe].set(victim_sc.hi, mode="drop"),
            score_lo=zero32.at[oe].set(victim_sc.lo, mode="drop"),
            mask=jnp.zeros((n,), bool).at[oe].set(evicts, mode="drop"),
        )
    else:
        stream = EvictionStream.zero(0, vdim, state.values.dtype)
    return MergeResult(state=state, status=status, evicted=stream,
                       found=pre_found, loc=post_loc)
