"""64-bit keys/scores as (hi, lo) uint32 pairs — the TPU-native representation.

The paper stores uint64 keys and uint64 scores. TPU VPU lanes are 32-bit and
JAX defaults to 32-bit integers, so we carry every 64-bit quantity as a pair
of uint32 planes (hi, lo).  All comparisons are lexicographic on (hi, lo),
which induces exactly the unsigned-uint64 total order, so score policies and
sentinel reservation behave identically to the paper's uint64 semantics.

The hash is a TPU adaptation of the paper's "GPU-optimized hash derived from
Murmur3": two coupled Murmur3 fmix32 finalizer passes yield two independent
32-bit hashes per key — h1 drives the primary bucket + the 8-bit digest,
h2 drives the secondary bucket (dual-bucket mode).  See DESIGN.md §2.
"""

from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

UINT32_MASK = np.uint64(0xFFFFFFFF)

# Reserved sentinel: the all-ones key marks an empty slot (the paper reserves
# EMPTY and LOCKED sentinels; the batch-synchronous TPU design needs no LOCKED).
EMPTY_HI = np.uint32(0xFFFFFFFF)
EMPTY_LO = np.uint32(0xFFFFFFFF)
# The same sentinel as one host-side uint64 (the padding value callers put
# in raw numpy key arrays) — the ONE definition every layer imports.
EMPTY_KEY = np.uint64(0xFFFFFFFFFFFFFFFF)
# Digest stored in empty slots. Any value is *correct* (key compare resolves
# false positives); 0xFF is reserved-looking and aids debugging.
EMPTY_DIGEST = np.uint8(0xFF)


class U64(NamedTuple):
    """A batch of 64-bit unsigned integers as two uint32 planes."""

    hi: jax.Array
    lo: jax.Array

    @property
    def shape(self):
        return self.hi.shape

    def __getitem__(self, idx):  # type: ignore[override]
        return U64(self.hi[idx], self.lo[idx])

    def reshape(self, *shape):
        return U64(self.hi.reshape(*shape), self.lo.reshape(*shape))


# ---------------------------------------------------------------------------
# Construction / conversion
# ---------------------------------------------------------------------------

def from_uint64(x: Union[np.ndarray, int]) -> U64:
    """Host-side conversion from numpy uint64 (or python int) to U64."""
    arr = np.asarray(x, dtype=np.uint64)
    hi = (arr >> np.uint64(32)).astype(np.uint32)
    lo = (arr & UINT32_MASK).astype(np.uint32)
    return U64(jnp.asarray(hi), jnp.asarray(lo))


def to_uint64(x: U64) -> np.ndarray:
    """Host-side conversion back to numpy uint64."""
    hi = np.asarray(jax.device_get(x.hi)).astype(np.uint64)
    lo = np.asarray(jax.device_get(x.lo)).astype(np.uint64)
    return (hi << np.uint64(32)) | lo


def make(hi, lo) -> U64:
    return U64(jnp.asarray(hi, jnp.uint32), jnp.asarray(lo, jnp.uint32))


def full(shape, value: int) -> U64:
    v = int(value)
    return U64(
        jnp.full(shape, np.uint32((v >> 32) & 0xFFFFFFFF), jnp.uint32),
        jnp.full(shape, np.uint32(v & 0xFFFFFFFF), jnp.uint32),
    )


def zeros(shape) -> U64:
    return U64(jnp.zeros(shape, jnp.uint32), jnp.zeros(shape, jnp.uint32))


def empty_sentinel(shape) -> U64:
    return U64(jnp.full(shape, EMPTY_HI, jnp.uint32), jnp.full(shape, EMPTY_LO, jnp.uint32))


# ---------------------------------------------------------------------------
# Comparison (lexicographic == unsigned 64-bit order)
# ---------------------------------------------------------------------------

def eq(a: U64, b: U64) -> jax.Array:
    return (a.hi == b.hi) & (a.lo == b.lo)


def ne(a: U64, b: U64) -> jax.Array:
    return (a.hi != b.hi) | (a.lo != b.lo)


def lt(a: U64, b: U64) -> jax.Array:
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo < b.lo))


def le(a: U64, b: U64) -> jax.Array:
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo <= b.lo))


def gt(a: U64, b: U64) -> jax.Array:
    return lt(b, a)


def ge(a: U64, b: U64) -> jax.Array:
    return le(b, a)


def select(pred: jax.Array, a: U64, b: U64) -> U64:
    return U64(jnp.where(pred, a.hi, b.hi), jnp.where(pred, a.lo, b.lo))


def minimum(a: U64, b: U64) -> U64:
    return select(le(a, b), a, b)


def maximum(a: U64, b: U64) -> U64:
    return select(ge(a, b), a, b)


def empty_lanes(hi, lo) -> jax.Array:
    """Plane-level EMPTY-sentinel test — the one liveness formula.

    Takes raw (hi, lo) uint32 planes rather than a U64 so the same body
    serves jnp table planes and VMEM rows inside Pallas kernel bodies
    (occupancy masks, sweep liveness).  Kernels must call this instead of
    re-deriving the all-ones compare inline — hkv-lint's oracle-coupling
    checker flags inline forks.
    """
    return (hi == EMPTY_HI) & (lo == EMPTY_LO)


def is_empty(a: U64) -> jax.Array:
    return empty_lanes(a.hi, a.lo)


# ---------------------------------------------------------------------------
# Arithmetic (used by score policies)
# ---------------------------------------------------------------------------

def add_u32(a: U64, inc) -> U64:
    """a + inc, where inc is uint32 (broadcastable). Carries into hi."""
    inc = jnp.asarray(inc, jnp.uint32)
    lo = a.lo + inc
    carry = (lo < a.lo).astype(jnp.uint32)  # wrapped => carry
    return U64(a.hi + carry, lo)


def add(a: U64, b: U64) -> U64:
    lo = a.lo + b.lo
    carry = (lo < a.lo).astype(jnp.uint32)
    return U64(a.hi + b.hi + carry, lo)


# ---------------------------------------------------------------------------
# Sortable encoding: map a U64 batch to a single sortable array.
#
# TPU sorts are cheapest on a single 32-bit key. Where full 64-bit order is
# required we sort on two keys via lax.sort; where an *approximate-but-total*
# order suffices (never here) one could pack. These helpers produce the
# operand lists for jax.lax.sort.
# ---------------------------------------------------------------------------

def sort_operands(a: U64) -> list:
    """Operands establishing u64 order for jax.lax.sort (hi major, lo minor)."""
    return [a.hi, a.lo]


# ---------------------------------------------------------------------------
# Hashing: Murmur3 fmix32-derived hash pair (TPU adaptation, DESIGN.md §2)
# ---------------------------------------------------------------------------

_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)
_SALT2 = np.uint32(0x7FEB352D)


def fmix32(h: jax.Array) -> jax.Array:
    """Murmur3 32-bit finalizer (avalanche) — pure uint32 ops."""
    h = jnp.asarray(h, jnp.uint32)
    h = h ^ (h >> 16)
    h = h * _C1
    h = h ^ (h >> 13)
    h = h * _C2
    h = h ^ (h >> 16)
    return h


def hash_pair(key: U64) -> tuple[jax.Array, jax.Array]:
    """Two decorrelated 32-bit hashes of a 64-bit key.

    h1 -> primary bucket + digest, h2 -> secondary bucket.  Both mix *all*
    64 input bits (hi feeds lo's pass and vice versa), so single-plane key
    patterns (e.g. sequential lows) still avalanche fully.
    """
    a = fmix32(key.hi ^ _GOLDEN)
    b = fmix32(key.lo ^ _SALT2)
    h1 = fmix32(a ^ key.lo)
    h2 = fmix32(b ^ key.hi ^ _GOLDEN)
    return h1, h2


def digest_from_hash(h1: jax.Array) -> jax.Array:
    """8-bit digest from the top byte of h1 (paper: bits [31:24] of the hash).

    Bucket selection uses the *low* bits of h1 (mod num_buckets), so digest
    and bucket index are decorrelated, as in the paper.
    """
    return ((h1 >> 24) & np.uint32(0xFF)).astype(jnp.uint8)


def bucket_from_hash(h: jax.Array, num_buckets: int) -> jax.Array:
    nb = np.uint32(num_buckets)
    if num_buckets & (num_buckets - 1) == 0:
        return (h & (nb - np.uint32(1))).astype(jnp.int32)
    return (h % nb).astype(jnp.int32)


# Reference (host/numpy) implementations for property tests -----------------

def fmix32_np(h: np.ndarray) -> np.ndarray:
    h = np.asarray(h, np.uint32).copy()
    with np.errstate(over="ignore"):
        h ^= h >> np.uint32(16)
        h = (h.astype(np.uint64) * np.uint64(0x85EBCA6B) & UINT32_MASK).astype(np.uint32)
        h ^= h >> np.uint32(13)
        h = (h.astype(np.uint64) * np.uint64(0xC2B2AE35) & UINT32_MASK).astype(np.uint32)
        h ^= h >> np.uint32(16)
    return h


def hash_pair_np(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    keys = np.asarray(keys, np.uint64)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    lo = (keys & UINT32_MASK).astype(np.uint32)
    a = fmix32_np(hi ^ np.uint32(_GOLDEN))
    b = fmix32_np(lo ^ np.uint32(_SALT2))
    h1 = fmix32_np(a ^ lo)
    h2 = fmix32_np(b ^ hi ^ np.uint32(_GOLDEN))
    return h1, h2
