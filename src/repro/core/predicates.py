"""SweepPredicate — the declarative predicate language of the maintenance
subsystem's bulk ops (`erase_if` / `evict_if`, DESIGN.md §Maintenance).

The upstream HKV library ships predicated bulk operations (`erase_if`,
`export_batch_if`) whose predicates are device function pointers; XLA has
no function pointers, and an arbitrary Python callable would defeat both
jit caching and the kernel path.  So predicates here are *data*: a small
closed algebra over the two metadata planes every table carries — keys
and scores — expressed as a registered pytree whose structure (the
comparison kind) is static aux and whose operands are traced uint32
scalars.  One predicate value therefore

  * passes through `jax.jit` boundaries like any other pytree argument
    (one compile per kind, operands flow as data);
  * evaluates identically on the pure-jnp reference path and inside the
    Pallas bucket-sweep kernel — both call the SAME `match_planes`
    plane-level formula, so backend bit-parity is by construction;
  * needs no per-impl translation: every `KVTable` impl evaluates it
    against whatever key/score planes it has (dictionary baselines carry
    zero scores — score predicates there are the caller's lookout, see
    the conformance capability table).

Kinds:

  always        every live entry (the watermark rebalancer's predicate:
                selection pressure comes from `evict_if`'s coldest-first
                rank order + budget, not from the match).
  score_lt      score  <  a      (the cold set below a threshold)
  score_ge      score  >= a      (complement; export-style filters)
  epoch_lt      score.hi < a.hi  (TTL/epoch expiry: under the epoch_lru /
                epoch_lfu policies the score's HIGH plane is the entry's
                last-touch epoch, so `expire_before(e)` matches entries
                not touched since epoch e — and under the cold tier's
                'custom' policy, translated epoch scores keep that plane)
  key_range     a <= key < b     (targeted invalidation of an id range)

Layering: this module is core-layer (imports only u64/jax) because
`core/ops.py` implements the sweep ops against it; the maintenance
subsystem (`repro.maintenance`) re-exports it as the public predicate
surface next to the scheduler that drives the sweeps.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.u64 import U64

KINDS = ("always", "score_lt", "score_ge", "epoch_lt", "key_range")


def _u32_scalar(x) -> jax.Array:
    return jnp.asarray(x, jnp.uint32).reshape(())


def _to_u64(x: Any) -> U64:
    """Coerce a threshold to a U64 scalar (python int, numpy uint64, a
    (hi, lo) U64, or a traced array — 64-bit dtypes split into both
    planes, 32-bit dtypes fill the low plane)."""
    if isinstance(x, U64):
        return U64(_u32_scalar(x.hi), _u32_scalar(x.lo))
    if isinstance(x, (int, np.integer)):
        v = int(x)
        if v < 0:
            raise ValueError(f"thresholds are unsigned; got {v}")
        return U64(_u32_scalar((v >> 32) & 0xFFFFFFFF), _u32_scalar(v & 0xFFFFFFFF))
    if isinstance(x, np.ndarray) and x.dtype.itemsize == 8:
        # host-side 64-bit scalar: exact split (jnp.asarray would
        # truncate to uint32 when x64 is disabled)
        return _to_u64(int(np.asarray(x).reshape(())))
    x = jnp.asarray(x)
    if x.dtype.itemsize == 8:   # uint64/int64 under jax x64: keep high bits
        xu = x.astype(jnp.uint64)
        hi = jax.lax.shift_right_logical(xu, jnp.asarray(32, jnp.uint64))
        return U64(_u32_scalar(hi), _u32_scalar(xu))
    return U64(_u32_scalar(0), _u32_scalar(x))


def _lt(a_hi, a_lo, b_hi, b_lo):
    """Plane-level lexicographic u64 '<' — written out so the same formula
    runs under jnp AND inside a Pallas kernel body."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def match_planes(kind: str, key_hi, key_lo, score_hi, score_lo,
                 a_hi, a_lo, b_hi, b_lo):
    """The single predicate formula, over raw uint32 planes.

    Liveness is NOT included — callers AND the result with their own
    occupancy mask (the EMPTY sentinel / tombstone conventions differ per
    table family).  Shared verbatim by the jnp reference
    (`SweepPredicate.matches`) and the Pallas sweep kernel
    (`repro.kernels.sweep_scan`), which is what makes the two backends
    bit-identical by construction.
    """
    if kind == "always":
        return jnp.ones(jnp.shape(key_hi), bool)
    if kind == "score_lt":
        return _lt(score_hi, score_lo, a_hi, a_lo)
    if kind == "score_ge":
        return ~_lt(score_hi, score_lo, a_hi, a_lo)
    if kind == "epoch_lt":
        return score_hi < a_hi
    if kind == "key_range":
        return ~_lt(key_hi, key_lo, a_hi, a_lo) & _lt(key_hi, key_lo, b_hi, b_lo)
    raise ValueError(f"unknown predicate kind {kind!r}; one of {KINDS}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SweepPredicate:
    """One declarative sweep predicate (see module docstring).

    `kind` is static pytree aux (it selects the compiled formula); the
    four operand planes are leaves, so thresholds flow through jit as
    data.  Unused operands are zero.
    """

    kind: str
    a_hi: jax.Array
    a_lo: jax.Array
    b_hi: jax.Array
    b_lo: jax.Array

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown predicate kind {self.kind!r}; one of {KINDS}")

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        return (self.a_hi, self.a_lo, self.b_hi, self.b_lo), (self.kind,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], *children)

    # -- canned constructors ---------------------------------------------------

    @classmethod
    def _make(cls, kind: str, a: U64 | None = None,
              b: U64 | None = None) -> "SweepPredicate":
        z = _u32_scalar(0)
        a = a or U64(z, z)
        b = b or U64(z, z)
        return cls(kind=kind, a_hi=_u32_scalar(a.hi), a_lo=_u32_scalar(a.lo),
                   b_hi=_u32_scalar(b.hi), b_lo=_u32_scalar(b.lo))

    @classmethod
    def always(cls) -> "SweepPredicate":
        """Match every live entry (rank order + budget do the selecting)."""
        return cls._make("always")

    @classmethod
    def score_below(cls, threshold: Any) -> "SweepPredicate":
        """score < threshold — the cold set (eviction order's low end)."""
        return cls._make("score_lt", _to_u64(threshold))

    @classmethod
    def score_at_least(cls, threshold: Any) -> "SweepPredicate":
        """score >= threshold (the complement filter)."""
        return cls._make("score_ge", _to_u64(threshold))

    @classmethod
    def expire_before(cls, epoch: Any) -> "SweepPredicate":
        """TTL/epoch expiry: entries whose score HIGH plane (the epoch
        stamp under epoch_lru/epoch_lfu — see `core/scores.py`) is below
        `epoch`.  The canned predicate the MaintenanceScheduler's TTL
        policy sweeps with."""
        return cls._make("epoch_lt", U64(_u32_scalar(epoch), _u32_scalar(0)))

    @classmethod
    def key_in_range(cls, lo: Any, hi: Any) -> "SweepPredicate":
        """lo <= key < hi — targeted invalidation of an id range."""
        return cls._make("key_range", _to_u64(lo), _to_u64(hi))

    # -- evaluation ------------------------------------------------------------

    def matches(self, keys: U64, scores: U64) -> jax.Array:
        """bool mask, same shape as the planes.  Liveness NOT included —
        AND with the caller's occupancy mask."""
        return match_planes(self.kind, keys.hi, keys.lo, scores.hi, scores.lo,
                            self.a_hi, self.a_lo, self.b_hi, self.b_lo)

    def __repr__(self):
        return f"SweepPredicate({self.kind})"
