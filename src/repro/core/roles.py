"""Op-role annotations — the §3.5 triple-group taxonomy, machine-readable.

The paper groups table operations into three roles with different
commutativity properties (DESIGN.md §3):

  reader    pure probes — commute with each other and with updaters on
            disjoint or identical key sets; never move keys between slots.
  updater   in-place mutations of located entries (values/scores) — keys
            keep their (bucket, slot), so a locate computed before the op
            is still valid after it.
  inserter  ops that create, move, or destroy entries — serialization
            points: any locate computed before an inserter is invalid
            after it.

``OpSession`` uses the roles to share one locate across a run of commuting
ops and to fence at inserters.  hkv-lint's role checker
(``repro.analysis.roles``) requires every public op entry point in
``core/ops.py`` to carry one of these annotations and cross-checks the
session's recorded roles against them, so a new op cannot silently join
the session machinery with the wrong commutativity class.
"""

from __future__ import annotations

from typing import Callable, Optional, TypeVar

READER = "reader"
UPDATER = "updater"
INSERTER = "inserter"
ROLES = (READER, UPDATER, INSERTER)

_ATTR = "__hkv_role__"

F = TypeVar("F", bound=Callable)


def role(name: str) -> Callable[[F], F]:
    """Decorator declaring an op entry point's §3.5 role.

    ``@role(roles.READER)`` etc.  The annotation is metadata only — it does
    not wrap the function — so jit/static-argnum behaviour is untouched.
    """
    if name not in ROLES:
        raise ValueError(f"unknown op role {name!r}; expected one of {ROLES}")

    def mark(fn: F) -> F:
        setattr(fn, _ATTR, name)
        return fn

    return mark


def reader(fn: F) -> F:
    return role(READER)(fn)


def updater(fn: F) -> F:
    return role(UPDATER)(fn)


def inserter(fn: F) -> F:
    return role(INSERTER)(fn)


def role_of(fn) -> Optional[str]:
    """The declared role of an op entry point, or None if unannotated.

    Sees through ``functools.partial``/``jax.jit`` wrappers exposing
    ``__wrapped__`` or ``func``.
    """
    seen = 0
    while fn is not None and seen < 8:
        r = getattr(fn, _ATTR, None)
        if r is not None:
            return r
        fn = getattr(fn, "__wrapped__", None) or getattr(fn, "func", None)
        seen += 1
    return None
