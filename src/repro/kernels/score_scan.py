"""Pallas TPU kernel: per-bucket score scan (paper §3.3 eviction scan).

The insert path's victim search scans all 128 scores of a bucket for the
minimum (Alg. 2 line 11).  On GPU that's a warp-cooperative cg::reduce; on
TPU it is a lane-dimension reduction over VMEM-tiled bucket rows.  This
kernel computes, for a tile of buckets at once:

  occupancy[b]           live-entry count (drives dual-bucket phase D1)
  min_score hi/lo [b]    lexicographic 64-bit min over live slots (D2 +
                         admission threshold)
  argmin[b]              victim slot

It is a straight tiled reduction — no dynamic indexing — so it also serves
as the package's reference Pallas pattern for plain VMEM BlockSpec tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.find import match_lanes
from repro.core.u64 import empty_lanes


def _stats_kernel(kh_ref, kl_ref, sh_ref, sl_ref, occ_ref, mh_ref, ml_ref, am_ref):
    ONES = jnp.uint32(0xFFFFFFFF)
    occ_mask = ~empty_lanes(kh_ref[...], kl_ref[...])
    occ_ref[:, 0] = jnp.sum(occ_mask.astype(jnp.int32), axis=1)
    shi = jnp.where(occ_mask, sh_ref[...], ONES)
    slo = jnp.where(occ_mask, sl_ref[...], ONES)
    min_hi = jnp.min(shi, axis=1)
    lo_cand = jnp.where(shi == min_hi[:, None], slo, ONES)
    min_lo = jnp.min(lo_cand, axis=1)
    mh_ref[:, 0] = min_hi
    ml_ref[:, 0] = min_lo
    is_min = match_lanes(shi, slo, min_hi[:, None], min_lo[:, None])
    am_ref[:, 0] = jnp.argmax(is_min, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bucket_tile", "interpret"))
def bucket_stats(tkey_hi, tkey_lo, score_hi, score_lo, *, bucket_tile: int = 8,
                 interpret: bool = True):
    """Per-bucket (occ, min_hi, min_lo, argmin) over the whole table.

    bucket_tile=8 keeps each block at the natural (8, 128) vreg shape:
    4 planes x 8x128 x 4 B = 16 KB of VMEM per step.
    """
    b, s = tkey_hi.shape
    assert b % bucket_tile == 0, "wrapper pads bucket count"
    grid = (b // bucket_tile,)
    in_spec = pl.BlockSpec((bucket_tile, s), lambda i: (i, 0))
    out_spec = pl.BlockSpec((bucket_tile, 1), lambda i: (i, 0))
    occ, mh, ml, am = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[in_spec] * 4,
        out_specs=[out_spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.uint32),
            jax.ShapeDtypeStruct((b, 1), jnp.uint32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
        name="hkv_bucket_stats",
    )(tkey_hi, tkey_lo, score_hi, score_lo)
    return occ[:, 0], mh[:, 0], ml[:, 0], am[:, 0]
