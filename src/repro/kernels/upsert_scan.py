"""Pallas TPU kernels for the fused upsert/evict path (paper §3.3, Alg. 2/3).

The paper resolves a full-bucket upsert *in line*: one kernel pass performs
digest pre-filter -> full-key match -> empty-slot claim -> score-argmin
eviction (or admission rejection), with dual-bucket selection picking the
target bucket.  This module is the TPU inserter-side counterpart of
``digest_scan`` (the reader side): two per-query row-pass kernels that,
together with the shared batch-closure orchestration in ``core/merge.py``,
kernel-complete the hottest mutation path (DESIGN.md §4).

  upsert_probe  one fused pass over a query's candidate bucket row(s):
                digest pre-filter + full-key compare (Alg. 1), occupancy
                count, lexicographic min-score reduction (Alg. 2 line 11),
                and the dual-bucket two-phase D1/D2 selection (Alg. 3 /
                Fig. 5) — all computed from a single HBM->VMEM row fetch
                per candidate bucket, the same one-transaction property
                the GPU design gets from its 128 B digest cache line.
  claim_scan    rank-r victim extraction: for a miss with within-bucket
                canonical rank r, return the r-th weakest slot of its
                target bucket under the total victim order (empty-first,
                then ascending score / key / slot).  Computed branch-free
                via pairwise lexicographic ranking over the 128-lane row
                (a 128x128 VPU compare block), so every query is
                independent — no serialization, conflict-free claims.

Both kernels execute with ``interpret=True`` off-TPU and are swept against
the pure-jnp stages in tests/test_upsert_kernel.py (bit-identical statuses,
evicted pairs, and post-state required).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.find import match_lanes
from repro.core.u64 import empty_lanes
from repro.kernels import compat


# =============================================================================
# upsert_probe: fused match + bucket-stats + dual-bucket selection
# =============================================================================


def _probe_kernel(use_digest, slots, b1_ref, b2_ref, qd_ref, qh_ref, ql_ref,
                  d1_ref, h1_ref, l1_ref, s1h_ref, s1l_ref,
                  d2_ref, h2_ref, l2_ref, s2h_ref, s2l_ref,
                  found_ref, hitsel_ref, slot_ref, tgtsel_ref):
    i = pl.program_id(0)
    qd = qd_ref[i]
    qh = qh_ref[i]
    ql = ql_ref[i]
    ONES = jnp.uint32(0xFFFFFFFF)

    def row_pass(d_ref, h_ref, l_ref, sh_ref, sl_ref):
        hh = h_ref[0, :]
        ll = l_ref[0, :]
        # full-key compare, gated by the one-lane-row digest pre-filter —
        # the shared `core.find.match_lanes` oracle
        if use_digest:
            m = match_lanes(hh, ll, qh, ql, d_ref[0, :].astype(jnp.uint32), qd)
        else:
            m = match_lanes(hh, ll, qh, ql)
        occ_mask = ~empty_lanes(hh, ll)
        # lexicographic u64 min over live slots (empties -> +inf sentinel)
        shi = jnp.where(occ_mask, sh_ref[0, :], ONES)
        slo = jnp.where(occ_mask, sl_ref[0, :], ONES)
        min_hi = jnp.min(shi)
        min_lo = jnp.min(jnp.where(shi == min_hi, slo, ONES))
        return (
            jnp.any(m),
            jnp.argmax(m).astype(jnp.int32),
            jnp.sum(occ_mask.astype(jnp.int32)),
            min_hi,
            min_lo,
        )

    hit1, slot1, occ1, m1h, m1l = row_pass(d1_ref, h1_ref, l1_ref, s1h_ref, s1l_ref)
    hit2, slot2, occ2, m2h, m2l = row_pass(d2_ref, h2_ref, l2_ref, s2h_ref, s2l_ref)

    found_ref[0, 0] = (hit1 | hit2).astype(jnp.int32)
    hitsel_ref[0, 0] = jnp.where(hit1, 0, 1).astype(jnp.int32)
    slot_ref[0, 0] = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    # dual-bucket two-phase policy: D1 less-loaded while free slots exist,
    # D2 lower-min-score at full occupancy (ties -> primary in both phases)
    any_free = (occ1 < slots) | (occ2 < slots)
    d1_sel = (occ2 < occ1).astype(jnp.int32)
    d2_sel = ((m2h < m1h) | ((m2h == m1h) & (m2l < m1l))).astype(jnp.int32)
    tgtsel_ref[0, 0] = jnp.where(any_free, d1_sel, d2_sel)


@functools.partial(jax.jit, static_argnames=("use_digest", "interpret"))
def upsert_probe(tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo,
                 bucket1, bucket2, qdigest, qkey_hi, qkey_lo, *,
                 use_digest: bool = True, interpret: bool = True):
    """Fused per-query probe over both candidate bucket rows.

    Returns (found, hit_sel, hit_slot, tgt_sel) int32 [N]:
      found    1 iff the key matched in either candidate bucket
      hit_sel  0 = matched (or defaulted) in bucket1, 1 = matched in bucket2
      hit_slot matching slot (0 on miss)
      tgt_sel  insertion target: 0 = bucket1, 1 = bucket2 (Alg. 3 selection)

    Single-bucket mode: pass bucket2 == bucket1; hit_sel/tgt_sel collapse
    to 0 by the tie--> -primary rule.
    """
    n = bucket1.shape[0]
    s = tdigests.shape[1]
    row = lambda i, b1, b2: (b1[i], 0)
    row2 = lambda i, b1, b2: (b2[i], 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),  # qdigest
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_hi
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_lo
            pl.BlockSpec((1, s), row),    # bucket1 digest row
            pl.BlockSpec((1, s), row),    # bucket1 key_hi row
            pl.BlockSpec((1, s), row),    # bucket1 key_lo row
            pl.BlockSpec((1, s), row),    # bucket1 score_hi row
            pl.BlockSpec((1, s), row),    # bucket1 score_lo row
            pl.BlockSpec((1, s), row2),   # bucket2 digest row
            pl.BlockSpec((1, s), row2),   # bucket2 key_hi row
            pl.BlockSpec((1, s), row2),   # bucket2 key_lo row
            pl.BlockSpec((1, s), row2),   # bucket2 score_hi row
            pl.BlockSpec((1, s), row2),   # bucket2 score_lo row
        ],
        out_specs=[pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0))] * 4,
    )
    found, hit_sel, hit_slot, tgt_sel = pl.pallas_call(
        functools.partial(_probe_kernel, use_digest, s),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, 1), jnp.int32)] * 4,
        interpret=interpret,
        name="hkv_upsert_probe",
    )(
        bucket1, bucket2, qdigest, qkey_hi, qkey_lo,
        tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo,
        tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo,
    )
    return found[:, 0], hit_sel[:, 0], hit_slot[:, 0], tgt_sel[:, 0]


# =============================================================================
# claim_scan: rank-r victim extraction (empty-slot claim / argmin eviction)
# =============================================================================


def _claim_kernel(slots, bkt_ref, rank_ref, kh_ref, kl_ref, sh_ref, sl_ref,
                  vslot_ref, vocc_ref, vsh_ref, vsl_ref, vkh_ref, vkl_ref):
    i = pl.program_id(0)
    r = rank_ref[i]
    hh = kh_ref[0, :]
    ll = kl_ref[0, :]
    occ = (~empty_lanes(hh, ll)).astype(jnp.uint32)
    shi = sh_ref[0, :]
    slo = sl_ref[0, :]
    slot_iota = jax.lax.iota(jnp.int32, slots)

    # Pairwise lexicographic rank under the victim total order
    # (occupied asc, score_hi asc, score_lo asc, key_hi asc, key_lo asc,
    # slot asc).  rho[s] = #entries strictly weaker than slot s; since the
    # 6-tuples are distinct (slot tiebreak), rho is a permutation and the
    # rank-r victim is the unique slot with rho == r.
    lt_m = jnp.zeros((slots, slots), jnp.bool_)
    eq_m = jnp.ones((slots, slots), jnp.bool_)
    for plane in (occ, shi, slo, hh, ll, slot_iota):
        lt_m = lt_m | (eq_m & (plane[:, None] < plane[None, :]))
        eq_m = eq_m & (plane[:, None] == plane[None, :])
    rho = jnp.sum(lt_m.astype(jnp.int32), axis=0)

    sel = rho == r
    pick32 = lambda a: jnp.max(jnp.where(sel, a, jnp.uint32(0)))
    vslot_ref[0, 0] = jnp.argmax(sel).astype(jnp.int32)
    vocc_ref[0, 0] = jnp.max(jnp.where(sel, occ, jnp.uint32(0))).astype(jnp.int32)
    vsh_ref[0, 0] = pick32(shi)
    vsl_ref[0, 0] = pick32(slo)
    vkh_ref[0, 0] = pick32(hh)
    vkl_ref[0, 0] = pick32(ll)


@functools.partial(jax.jit, static_argnames=("interpret",))
def claim_scan(tkey_hi, tkey_lo, tscore_hi, tscore_lo, buckets, rank, *,
               interpret: bool = True):
    """Per-query rank-r victim of each target bucket row.

    buckets : int32 [N] target bucket per (canonically sorted) miss
    rank    : int32 [N] within-bucket canonical rank, pre-clipped to [0, S)

    Returns (slot, occupied, score_hi, score_lo, key_hi, key_lo), each [N]:
    the entry the rank-r incoming key is paired against — an empty slot
    (claim), or the rank-r weakest live entry (evict if strictly beaten,
    reject otherwise).  Reads only: claims are scattered by the caller, so
    queries stay independent and the pass pipelines like the find path.
    """
    n = buckets.shape[0]
    s = tkey_hi.shape[1]
    row = lambda i, b: (b[i], 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),  # rank
            pl.BlockSpec((1, s), row),    # key_hi row
            pl.BlockSpec((1, s), row),    # key_lo row
            pl.BlockSpec((1, s), row),    # score_hi row
            pl.BlockSpec((1, s), row),    # score_lo row
        ],
        out_specs=[pl.BlockSpec((1, 1), lambda i, b: (i, 0))] * 6,
    )
    vslot, vocc, vsh, vsl, vkh, vkl = pl.pallas_call(
        functools.partial(_claim_kernel, s),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
        ],
        interpret=interpret,
        name="hkv_claim_scan",
    )(buckets, rank, tkey_hi, tkey_lo, tscore_hi, tscore_lo)
    return (vslot[:, 0], vocc[:, 0], vsh[:, 0], vsl[:, 0], vkh[:, 0], vkl[:, 0])
