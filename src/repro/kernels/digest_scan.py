"""Pallas TPU kernels for the digest-accelerated find path (paper §3.2, §4.3).

The GPU design: 128 one-byte digests fill one 128 B L1 cache line; a warp
scans them with 32 ``__vcmpeq4`` SIMD compares; only digest hits touch the
64-bit keys.  The TPU adaptation keeps the co-design but re-maps each level
of the hierarchy (DESIGN.md §2):

  GPU 128 B cache line  ->  one TPU vreg lane row: a bucket's 128 digests
                            occupy the 128-lane minor dimension of VMEM, so
                            one vector compare covers the entire candidate
                            set (the paper's "definitive miss in one
                            transaction" property).
  __vcmpeq4 SIMD scan   ->  a single int-eq over the lane dimension (VPU).
  __pipeline_memcpy_async-> explicit HBM->VMEM ``make_async_copy`` with a
                            two-deep double buffer: query q+1's bucket row
                            streams in while query q is compared (the
                            paper's Pipeline kernel, §4.3).

Two variants, mirroring the paper's kernel-selection tiers:

  tlp  (§4.3 TLPv1): one query per grid step; Pallas' pipeline emitter
       auto-double-buffers the scalar-prefetch-indexed bucket rows.
  pipeline (§4.3 Pipeline): Q queries per grid step with a manual two-slot
       DMA pipeline — the latency-hiding structure of the paper's 4-stage
       warp-cooperative kernel.

Both compute exactly ``ref.digest_scan_ref`` and are swept against it in
tests (interpret mode executes the kernel bodies on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.find import match_lanes
from repro.kernels import compat

LANES = 128  # TPU vreg minor dimension == slots per bucket


# =============================================================================
# TLP variant: one query per grid step, auto-pipelined bucket-row blocks
# =============================================================================


def _tlp_kernel(bidx_ref, qd_ref, qh_ref, ql_ref, td_ref, th_ref, tl_ref,
                slot_ref, found_ref):
    i = pl.program_id(0)
    qd = qd_ref[i]
    qh = qh_ref[i]
    ql = ql_ref[i]
    # one vector compare over the 128-lane digest row = the whole candidate
    # set; the mask formula is the shared core.find.match_lanes oracle
    m = match_lanes(th_ref[0, :], tl_ref[0, :], qh, ql,
                    td_ref[0, :].astype(jnp.uint32), qd)
    found_ref[0, 0] = jnp.any(m).astype(jnp.int32)
    slot_ref[0, 0] = jnp.argmax(m).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def digest_scan_tlp(tdigests, tkey_hi, tkey_lo, buckets, qdigest, qkey_hi,
                    qkey_lo, *, interpret: bool = True):
    """TLPv1: key-level parallelism, one bucket row per step."""
    n = buckets.shape[0]
    s = tdigests.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),  # qdigest (full)
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_hi
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_lo
            pl.BlockSpec((1, s), lambda i, b: (b[i], 0)),       # digest row
            pl.BlockSpec((1, s), lambda i, b: (b[i], 0)),       # key_hi row
            pl.BlockSpec((1, s), lambda i, b: (b[i], 0)),       # key_lo row
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, b: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b: (i, 0)),
        ],
    )
    slot, found = pl.pallas_call(
        _tlp_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
        name="hkv_digest_scan_tlp",
    )(buckets, qdigest, qkey_hi, qkey_lo, tdigests, tkey_hi, tkey_lo)
    return slot[:, 0], found[:, 0]


# =============================================================================
# Pipeline variant: Q queries per grid step, manual two-slot DMA double buffer
# =============================================================================


def _pipeline_kernel(q_tile, bidx_ref, qd_ref, qh_ref, ql_ref,
                     td_hbm, th_hbm, tl_hbm, slot_ref, found_ref,
                     dbuf, hbuf, lbuf, sems):
    i = pl.program_id(0)

    def row_copies(q, slot):
        b = bidx_ref[i * q_tile + q]
        return (
            pltpu.make_async_copy(td_hbm.at[pl.ds(b, 1), :], dbuf.at[slot], sems.at[slot, 0]),
            pltpu.make_async_copy(th_hbm.at[pl.ds(b, 1), :], hbuf.at[slot], sems.at[slot, 1]),
            pltpu.make_async_copy(tl_hbm.at[pl.ds(b, 1), :], lbuf.at[slot], sems.at[slot, 2]),
        )

    def issue(q, slot):
        for c in row_copies(q, slot):
            c.start()

    def wait(q, slot):
        for c in row_copies(q, slot):
            c.wait()

    # stage 1 prologue: prefetch query 0's bucket row
    issue(0, 0)

    def body(q, carry):
        slots, founds = carry
        cur = jax.lax.rem(q, 2)
        nxt = jax.lax.rem(q + 1, 2)

        # stage 1: issue next row's DMA while this row is in flight/compared
        @pl.when(q + 1 < q_tile)
        def _():
            issue(q + 1, nxt)

        wait(q, cur)
        # stage 2: vectorized digest + key compare (one lane-row each),
        # via the shared core.find.match_lanes oracle
        m = match_lanes(hbuf[cur, 0, :], lbuf[cur, 0, :],
                        qh_ref[0, q], ql_ref[0, q],
                        dbuf[cur, 0, :].astype(jnp.uint32), qd_ref[0, q])
        # stage 3: reduce to (found, slot)
        f = jnp.any(m).astype(jnp.int32)
        s = jnp.argmax(m).astype(jnp.int32)
        onehot = (jax.lax.iota(jnp.int32, q_tile) == q)
        return (jnp.where(onehot, s, slots), jnp.where(onehot, f, founds))

    init = (jnp.zeros((q_tile,), jnp.int32), jnp.zeros((q_tile,), jnp.int32))
    slots, founds = jax.lax.fori_loop(0, q_tile, body, init)
    # stage 4: one vector writeback per tile
    slot_ref[0, :] = slots
    found_ref[0, :] = founds


@functools.partial(jax.jit, static_argnames=("q_tile", "interpret"))
def digest_scan_pipeline(tdigests, tkey_hi, tkey_lo, buckets, qdigest,
                         qkey_hi, qkey_lo, *, q_tile: int = 128,
                         interpret: bool = True):
    """Pipeline variant (§4.3): per-tile manual DMA with double buffering.

    Queries are padded to a multiple of q_tile by the wrapper; the scratch
    working set is 2 x (128 digests + 2x128 uint32 keys) ≈ 2.3 KB of VMEM
    plus the (1, q_tile) query block — far under the ~16 MB VMEM budget,
    leaving headroom for the value-gather kernel's blocks.
    """
    n = buckets.shape[0]
    assert n % q_tile == 0, "wrapper must pad to a q_tile multiple"
    s = tdigests.shape[1]
    tiles = n // q_tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, q_tile), lambda i, b: (i, 0),
                         memory_space=compat.SMEM),
            pl.BlockSpec((1, q_tile), lambda i, b: (i, 0),
                         memory_space=compat.SMEM),
            pl.BlockSpec((1, q_tile), lambda i, b: (i, 0),
                         memory_space=compat.SMEM),
            pl.BlockSpec(memory_space=compat.HBM),  # digest plane
            pl.BlockSpec(memory_space=compat.HBM),  # key_hi plane
            pl.BlockSpec(memory_space=compat.HBM),  # key_lo plane
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile), lambda i, b: (i, 0)),
            pl.BlockSpec((1, q_tile), lambda i, b: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, 1, s), jnp.uint8),
            pltpu.VMEM((2, 1, s), jnp.uint32),
            pltpu.VMEM((2, 1, s), jnp.uint32),
            pltpu.SemaphoreType.DMA((2, 3)),
        ],
    )
    slot, found = pl.pallas_call(
        functools.partial(_pipeline_kernel, q_tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.int32),
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.int32),
        ],
        interpret=interpret,
        name="hkv_digest_scan_pipeline",
    )(
        buckets,
        qdigest.reshape(tiles, q_tile),
        qkey_hi.reshape(tiles, q_tile),
        qkey_lo.reshape(tiles, q_tile),
        tdigests,
        tkey_hi,
        tkey_lo,
    )
    return slot.reshape(n), found.reshape(n)
