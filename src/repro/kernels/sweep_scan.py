"""Pallas TPU kernel: predicated bucket sweep (DESIGN.md §Maintenance).

The maintenance subsystem's bulk ops (`erase_if` / `evict_if`) start from
one whole-table pass: evaluate a `SweepPredicate` against every slot's
key/score metadata and report the per-slot match mask plus per-bucket
match counts.  On GPU the upstream library runs this as a grid-stride
kernel over buckets; on TPU it is a tiled VMEM scan exactly like the
score reduction in ``score_scan`` — each grid step streams a tile of
bucket rows (4 uint32 planes) through the VPU and emits the mask.

Fusion: liveness (EMPTY-sentinel test), the predicate compare, and the
per-bucket count reduction all happen in the single row fetch — the
metadata planes cross HBM->VMEM once per sweep, not once per stage.

Bit-parity contract: the predicate math is `core.predicates.match_planes`
— the SAME formula the pure-jnp reference path evaluates — so the kernel
and reference masks are bit-identical by construction, and everything
downstream of the mask (the coldest-first rank sort, the erase scatters)
is shared orchestration in `core/ops.py` (the `UpsertStages` pattern,
DESIGN.md §4).  Pinned in tests/test_sweep_kernel.py by full-state drains
after randomized sweeps on both backends.

Threshold operands arrive as four (1, 1) uint32 arrays mapped to every
grid step (scalar broadcast), so one compiled kernel serves every
threshold value of a given predicate kind.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.predicates import match_planes
from repro.core.u64 import empty_lanes


def _sweep_kernel(kind, kh_ref, kl_ref, sh_ref, sl_ref,
                  ah_ref, al_ref, bh_ref, bl_ref, match_ref, cnt_ref):
    kh = kh_ref[...]
    kl = kl_ref[...]
    live = ~empty_lanes(kh, kl)
    m = live & match_planes(
        kind, kh, kl, sh_ref[...], sl_ref[...],
        ah_ref[0, 0], al_ref[0, 0], bh_ref[0, 0], bl_ref[0, 0],
    )
    match_ref[...] = m.astype(jnp.int32)
    cnt_ref[:, 0] = jnp.sum(m.astype(jnp.int32), axis=1)


@functools.partial(jax.jit,
                   static_argnames=("kind", "bucket_tile", "interpret"))
def sweep_match(tkey_hi, tkey_lo, score_hi, score_lo,
                a_hi, a_lo, b_hi, b_lo, *, kind: str,
                bucket_tile: int = 8, interpret: bool = True):
    """Whole-table predicate evaluation.

    Returns (match bool [B, S], per-bucket count int32 [B]); `match` is
    live-entry-gated (EMPTY slots never match).  `bucket_tile=8` keeps
    each block at the natural (8, 128) vreg shape.
    """
    b, s = tkey_hi.shape
    if b % bucket_tile:
        bucket_tile = 1
    grid = (b // bucket_tile,)
    in_spec = pl.BlockSpec((bucket_tile, s), lambda i: (i, 0))
    op_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    as11 = lambda x: jnp.asarray(x, jnp.uint32).reshape(1, 1)
    match, cnt = pl.pallas_call(
        functools.partial(_sweep_kernel, kind),
        grid=grid,
        in_specs=[in_spec] * 4 + [op_spec] * 4,
        out_specs=[pl.BlockSpec((bucket_tile, s), lambda i: (i, 0)),
                   pl.BlockSpec((bucket_tile, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
        name="hkv_sweep_match",
    )(tkey_hi, tkey_lo, score_hi, score_lo,
      as11(a_hi), as11(a_lo), as11(b_hi), as11(b_lo))
    return match.astype(bool), cnt[:, 0]
