"""Pallas-TPU API compatibility aliases.

The memory-space enum was renamed across JAX releases
(``pltpu.TPUMemorySpace`` -> ``pltpu.MemorySpace``) and older releases have
no distinct HBM member (``ANY`` leaves placement to the compiler, which
puts large operands in HBM).  Every kernel module imports the spaces from
here so the package runs on both API generations.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_SPACES = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

SMEM = _SPACES.SMEM
VMEM = _SPACES.VMEM
ANY = _SPACES.ANY
HBM = getattr(_SPACES, "HBM", _SPACES.ANY)
