"""Pallas TPU kernels for the FUSED find path (paper §4.3 + §3.6 in one pass).

PRs 1–5 kernel-completed the inserter (``upsert_scan``) and sweep
(``sweep_scan``) paths, but the reader still ran as a two-launch pair:
``digest_scan`` locate (one launch per candidate bucket) followed by a
position-addressed ``gather_rows`` value pass — re-deriving the row address
on-host between launches and paying a second grid's worth of latency.  The
paper's find kernel does not: a warp walks the digest line, confirms the
full key, and ``__pipeline_memcpy_async``-copies the value slice before it
retires the query.  This module is that kernel for TPU.

One scalar-prefetched pass per query over BOTH candidate bucket rows:

  1. digest pre-filter    one uint8 lane-row compare per candidate bucket
                          (the 128 B cache-line transaction of §3.2);
  2. full-key confirm     the same match formula as the jnp reference
                          ``core.find._match_in_bucket`` — key planes
                          compared, digest conjoined iff ``use_digest``
                          (shared-formula bit-parity, the sweep_scan rule);
  3. dual-bucket merge    hit1-wins-over-hit2, exactly
                          ``core.find.locate``'s merge;
  4. score readout        the hit slot's (score_hi, score_lo) lifted from
                          the streamed metadata rows, so ``FindResult`` /
                          ``FindRowsResult`` scores need no second probe;
  5. in-line value gather a data-dependent HBM->VMEM ``make_async_copy``
                          of the hit row at ``bucket * S + slot``.  The
                          row index exists only *inside* the kernel (it is
                          the match result), which is precisely why the
                          unfused path needed a second launch: BlockSpec
                          index maps cannot depend on in-kernel values,
                          but an explicit DMA can.

Two variants, mirroring ``digest_scan``'s kernel-selection tiers:

  tlp      one query per grid step; Pallas auto-double-buffers the ten
           scalar-prefetch-indexed metadata rows (5 planes x 2 buckets,
           the ``upsert_probe`` layout); the value row is an in-kernel DMA.
  pipeline Q queries per grid step with a manual two-slot DMA pipeline.
           Query q+1's metadata rows stream while query q is compared, and
           query q's value-row DMA is issued immediately after its match
           resolves and retired one iteration later — so the value copy of
           q overlaps the metadata fetch + compare of q+1 (the paper's
           4-stage latency-hiding structure, now including stage 4).

Both compute exactly ``ref.find_scan_ref`` and are swept against it and
against the jnp ``core.find`` oracle in tests/test_find_kernel.py
(interpret mode executes the kernel bodies on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.find import match_lanes
from repro.kernels import compat

LANES = 128  # TPU vreg minor dimension == slots per bucket


def _merge_hits(slots, sel_and_hits):
    """Shared dual-bucket merge: (found, sel, slot) from per-bucket hits —
    the exact `core.find.locate` merge (hit1 wins, miss defaults to b1)."""
    hit1, slot1, hit2, slot2 = sel_and_hits
    found = hit1 | hit2
    sel = jnp.where(hit1, 0, jnp.where(hit2, 1, 0)).astype(jnp.int32)
    slot = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    return found, sel, slot


# =============================================================================
# TLP variant: one query per grid step, auto-pipelined metadata row blocks
# =============================================================================


def _tlp_kernel(use_digest, slots, b1_ref, b2_ref, qd_ref, qh_ref, ql_ref,
                d1_ref, h1_ref, l1_ref, s1h_ref, s1l_ref,
                d2_ref, h2_ref, l2_ref, s2h_ref, s2l_ref, v_hbm,
                found_ref, sel_ref, slot_ref, shi_ref, slo_ref, val_ref,
                vbuf, vsem):
    i = pl.program_id(0)
    qd = qd_ref[i]
    qh = qh_ref[i]
    ql = ql_ref[i]

    def row_match(d_ref, h_ref, l_ref):
        # full-key compare, gated by the one-lane-row digest pre-filter —
        # the shared `core.find.match_lanes` oracle, so kernel and jnp
        # reference cannot fork
        if use_digest:
            m = match_lanes(h_ref[0, :], l_ref[0, :], qh, ql,
                            d_ref[0, :].astype(jnp.uint32), qd)
        else:
            m = match_lanes(h_ref[0, :], l_ref[0, :], qh, ql)
        return jnp.any(m), jnp.argmax(m).astype(jnp.int32)

    hit1, slot1 = row_match(d1_ref, h1_ref, l1_ref)
    hit2, slot2 = row_match(d2_ref, h2_ref, l2_ref)
    found, sel, slot = _merge_hits(slots, (hit1, slot1, hit2, slot2))

    # score readout from the already-streamed metadata rows (one onehot
    # lane reduction — no second metadata probe for FindResult scores)
    lane = jax.lax.iota(jnp.int32, slots) == slot
    pick = lambda a_ref, b_ref: jnp.max(jnp.where(
        lane, jnp.where(sel == 0, a_ref[0, :], b_ref[0, :]), jnp.uint32(0)))
    shi = jnp.where(found, pick(s1h_ref, s2h_ref), jnp.uint32(0))
    slo = jnp.where(found, pick(s1l_ref, s2l_ref), jnp.uint32(0))

    found_ref[0, 0] = found.astype(jnp.int32)
    sel_ref[0, 0] = sel
    slot_ref[0, 0] = slot
    shi_ref[0, 0] = shi
    slo_ref[0, 0] = slo

    # in-line value gather: position addressing (§3.6) resolved in-kernel.
    # Misses fetch row b1*S+0 (a valid address) and mask to zeros below —
    # the same contract as `find.gather_values`.
    b = jnp.where(sel == 0, b1_ref[i], b2_ref[i])
    row = b * slots + slot
    cp = pltpu.make_async_copy(v_hbm.at[pl.ds(row, 1), :], vbuf, vsem)
    cp.start()
    cp.wait()
    val_ref[0, :] = jnp.where(found, vbuf[0, :], jnp.zeros_like(vbuf[0, :]))


@functools.partial(jax.jit, static_argnames=("use_digest", "interpret"))
def find_scan_tlp(tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo, tvalues,
                  bucket1, bucket2, qdigest, qkey_hi, qkey_lo, *,
                  use_digest: bool = True, interpret: bool = True):
    """Fused find, TLP tier: one query per grid step.

    Returns (found, sel, slot, score_hi, score_lo, values):
      found     int32 [N] — 1 iff the key matched in either candidate bucket
      sel       int32 [N] — 0 = bucket1 holds it (or miss), 1 = bucket2
      slot      int32 [N] — matching slot (0 on miss)
      score_hi  uint32 [N] — hit entry's score planes (0 on miss)
      score_lo  uint32 [N]
      values    [N, V] — the hit row of the value plane (zeros on miss)

    Single-bucket mode: pass bucket2 == bucket1 (sel collapses to 0).
    """
    n = bucket1.shape[0]
    s = tdigests.shape[1]
    v = tvalues.shape[1]
    row = lambda i, b1, b2: (b1[i], 0)
    row2 = lambda i, b1, b2: (b2[i], 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),  # qdigest
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_hi
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_lo
            pl.BlockSpec((1, s), row),    # bucket1 digest row
            pl.BlockSpec((1, s), row),    # bucket1 key_hi row
            pl.BlockSpec((1, s), row),    # bucket1 key_lo row
            pl.BlockSpec((1, s), row),    # bucket1 score_hi row
            pl.BlockSpec((1, s), row),    # bucket1 score_lo row
            pl.BlockSpec((1, s), row2),   # bucket2 digest row
            pl.BlockSpec((1, s), row2),   # bucket2 key_hi row
            pl.BlockSpec((1, s), row2),   # bucket2 key_lo row
            pl.BlockSpec((1, s), row2),   # bucket2 score_hi row
            pl.BlockSpec((1, s), row2),   # bucket2 score_lo row
            pl.BlockSpec(memory_space=compat.HBM),  # value plane (in-kernel DMA)
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec((1, v), lambda i, b1, b2: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, v), tvalues.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    found, sel, slot, shi, slo, vals = pl.pallas_call(
        functools.partial(_tlp_kernel, use_digest, s),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((n, v), tvalues.dtype),
        ],
        interpret=interpret,
        name="hkv_find_scan_tlp",
    )(
        bucket1, bucket2, qdigest, qkey_hi, qkey_lo,
        tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo,
        tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo,
        tvalues,
    )
    return found[:, 0], sel[:, 0], slot[:, 0], shi[:, 0], slo[:, 0], vals


# =============================================================================
# Pipeline variant: Q queries per grid step, manual two-slot DMA double buffer
# =============================================================================


def _pipeline_kernel(use_digest, q_tile, slots,
                     b1_ref, b2_ref, qd_ref, qh_ref, ql_ref,
                     td, th, tl, tsh, tsl, tv,
                     found_ref, sel_ref, slot_ref, shi_ref, slo_ref, val_ref,
                     d1b, h1b, l1b, sh1b, sl1b,
                     d2b, h2b, l2b, sh2b, sl2b,
                     vbuf, sems, vsem):
    i = pl.program_id(0)
    v = tv.shape[1]

    def meta_copies(q, slot):
        base = i * q_tile + q
        b1 = b1_ref[base]
        b2 = b2_ref[base]
        planes = (td, th, tl, tsh, tsl)
        bufs1 = (d1b, h1b, l1b, sh1b, sl1b)
        bufs2 = (d2b, h2b, l2b, sh2b, sl2b)
        cps = []
        for j, (p, bf) in enumerate(zip(planes, bufs1)):
            cps.append(pltpu.make_async_copy(
                p.at[pl.ds(b1, 1), :], bf.at[slot], sems.at[slot, j]))
        for j, (p, bf) in enumerate(zip(planes, bufs2)):
            cps.append(pltpu.make_async_copy(
                p.at[pl.ds(b2, 1), :], bf.at[slot], sems.at[slot, 5 + j]))
        return cps

    def issue(q, slot):
        for c in meta_copies(q, slot):
            c.start()

    def wait(q, slot):
        for c in meta_copies(q, slot):
            c.wait()

    def vcopy(row, slot):
        return pltpu.make_async_copy(
            tv.at[pl.ds(row, 1), :], vbuf.at[slot], vsem.at[slot])

    # stage 1 prologue: prefetch query 0's two candidate bucket rows
    issue(0, 0)

    q_iota = jax.lax.iota(jnp.int32, q_tile)
    lane_iota = jax.lax.iota(jnp.int32, slots)

    def body(q, carry):
        founds, sels, slotsv, shis, slos, valsm, prev_row = carry
        cur = jax.lax.rem(q, 2)
        nxt = jax.lax.rem(q + 1, 2)

        # stage 1: issue next query's metadata DMAs while q's are compared
        @pl.when(q + 1 < q_tile)
        def _():
            issue(q + 1, nxt)

        wait(q, cur)
        qd = qd_ref[0, q]
        qh = qh_ref[0, q]
        ql = ql_ref[0, q]

        # stage 2: vectorized digest + key compare per candidate bucket,
        # via the shared `core.find.match_lanes` oracle
        def row_match(db, hb, lb):
            if use_digest:
                m = match_lanes(hb[cur, 0, :], lb[cur, 0, :], qh, ql,
                                db[cur, 0, :].astype(jnp.uint32), qd)
            else:
                m = match_lanes(hb[cur, 0, :], lb[cur, 0, :], qh, ql)
            return jnp.any(m), jnp.argmax(m).astype(jnp.int32)

        hit1, slot1 = row_match(d1b, h1b, l1b)
        hit2, slot2 = row_match(d2b, h2b, l2b)
        # stage 3: dual-bucket merge + score readout
        found, sel, slot = _merge_hits(slots, (hit1, slot1, hit2, slot2))
        lane = lane_iota == slot
        pick = lambda a, b: jnp.max(jnp.where(
            lane, jnp.where(sel == 0, a[cur, 0, :], b[cur, 0, :]),
            jnp.uint32(0)))
        shi = jnp.where(found, pick(sh1b, sh2b), jnp.uint32(0))
        slo = jnp.where(found, pick(sl1b, sl2b), jnp.uint32(0))

        base = i * q_tile + q
        b = jnp.where(sel == 0, b1_ref[base], b2_ref[base])
        row = b * slots + slot

        # stage 4a: issue q's value-row DMA — it overlaps query q+1's
        # metadata stream and compare, retiring one iteration later
        vcopy(row, cur).start()

        # stage 4b: retire query q-1's value row (its DMA has had a full
        # iteration of latency hiding)
        @pl.when(q >= 1)
        def _():
            vcopy(prev_row, nxt).wait()
        prev_found = jnp.sum(jnp.where(q_iota == q - 1, founds, 0)) != 0
        rowvec = jnp.where(prev_found, vbuf[nxt, 0, :],
                           jnp.zeros((v,), tv.dtype))
        place = (q_iota == q - 1) & (q >= 1)
        valsm = jnp.where(place[:, None], rowvec[None, :], valsm)

        onehot = q_iota == q
        return (
            jnp.where(onehot, found.astype(jnp.int32), founds),
            jnp.where(onehot, sel, sels),
            jnp.where(onehot, slot, slotsv),
            jnp.where(onehot, shi, shis),
            jnp.where(onehot, slo, slos),
            valsm,
            row,
        )

    init = (
        jnp.zeros((q_tile,), jnp.int32),
        jnp.zeros((q_tile,), jnp.int32),
        jnp.zeros((q_tile,), jnp.int32),
        jnp.zeros((q_tile,), jnp.uint32),
        jnp.zeros((q_tile,), jnp.uint32),
        jnp.zeros((q_tile, v), tv.dtype),
        jnp.int32(0),
    )
    founds, sels, slotsv, shis, slos, valsm, prev_row = jax.lax.fori_loop(
        0, q_tile, body, init)

    # epilogue: retire the last query's value row
    last = q_tile - 1
    vcopy(prev_row, last % 2).wait()
    rowvec = jnp.where(founds[last] != 0, vbuf[last % 2, 0, :],
                       jnp.zeros((v,), tv.dtype))
    valsm = jnp.where((q_iota == last)[:, None], rowvec[None, :], valsm)

    # one vector writeback per tile
    found_ref[0, :] = founds
    sel_ref[0, :] = sels
    slot_ref[0, :] = slotsv
    shi_ref[0, :] = shis
    slo_ref[0, :] = slos
    val_ref[...] = valsm


@functools.partial(jax.jit,
                   static_argnames=("q_tile", "use_digest", "interpret"))
def find_scan_pipeline(tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo,
                       tvalues, bucket1, bucket2, qdigest, qkey_hi, qkey_lo,
                       *, q_tile: int = 128, use_digest: bool = True,
                       interpret: bool = True):
    """Fused find, Pipeline tier: Q queries per grid step, manual DMA.

    Same outputs as `find_scan_tlp`.  Queries are padded to a multiple of
    q_tile by the wrapper.  Scratch working set: 2 x (10 metadata rows +
    one value row) ≈ 2 x (4.2 KB + V*4 B) — far under the VMEM budget even
    at the widest value rows, because the value plane itself stays in HBM
    and only the two in-flight hit rows are resident.
    """
    n = bucket1.shape[0]
    assert n % q_tile == 0, "wrapper must pad to a q_tile multiple"
    s = tdigests.shape[1]
    v = tvalues.shape[1]
    tiles = n // q_tile
    smem_block = lambda: pl.BlockSpec((1, q_tile), lambda i, b1, b2: (i, 0),
                                      memory_space=compat.SMEM)
    out_block = lambda: pl.BlockSpec((1, q_tile), lambda i, b1, b2: (i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(tiles,),
        in_specs=[
            smem_block(),   # qdigest
            smem_block(),   # qkey_hi
            smem_block(),   # qkey_lo
            pl.BlockSpec(memory_space=compat.HBM),  # digest plane
            pl.BlockSpec(memory_space=compat.HBM),  # key_hi plane
            pl.BlockSpec(memory_space=compat.HBM),  # key_lo plane
            pl.BlockSpec(memory_space=compat.HBM),  # score_hi plane
            pl.BlockSpec(memory_space=compat.HBM),  # score_lo plane
            pl.BlockSpec(memory_space=compat.HBM),  # value plane
        ],
        out_specs=[
            out_block(), out_block(), out_block(), out_block(), out_block(),
            pl.BlockSpec((q_tile, v), lambda i, b1, b2: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, 1, s), jnp.uint8),    # bucket1 digests
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket1 key_hi
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket1 key_lo
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket1 score_hi
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket1 score_lo
            pltpu.VMEM((2, 1, s), jnp.uint8),    # bucket2 digests
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket2 key_hi
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket2 key_lo
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket2 score_hi
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket2 score_lo
            pltpu.VMEM((2, 1, v), tvalues.dtype),  # value double buffer
            pltpu.SemaphoreType.DMA((2, 10)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    found, sel, slot, shi, slo, vals = pl.pallas_call(
        functools.partial(_pipeline_kernel, use_digest, q_tile, s),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.int32),
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.int32),
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.int32),
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.uint32),
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.uint32),
            jax.ShapeDtypeStruct((n, v), tvalues.dtype),
        ],
        interpret=interpret,
        name="hkv_find_scan_pipeline",
    )(
        bucket1, bucket2,
        qdigest.reshape(tiles, q_tile),
        qkey_hi.reshape(tiles, q_tile),
        qkey_lo.reshape(tiles, q_tile),
        tdigests, tkey_hi, tkey_lo, tscore_hi, tscore_lo, tvalues,
    )
    return (found.reshape(n), sel.reshape(n), slot.reshape(n),
            shi.reshape(n), slo.reshape(n), vals)
