"""Pallas TPU kernel: updater-role value write-back (paper §3.5 updater path).

assign / assign_add are the *non-structural* write role: they touch value
rows in place, never bucket structure.  On TPU this is a row-indexed
read-modify-write pipeline over the value plane, with the row stream
scalar-prefetched and the target row aliased input->output so only touched
rows move through VMEM.

PRECONDITION (enforced by callers, asserted in tests): the masked row ids
are unique within a batch.  The merge/assign paths dedupe before calling —
the same invariant the paper's updater kernels get from their
one-warp-per-key assignment.  Masked-out lanes rewrite the row unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _scatter_kernel(add, rows_ref, mask_ref, upd_ref, val_ref, out_ref):
    i = pl.program_id(0)
    live = mask_ref[i] != 0
    old = val_ref[0, :]
    upd = upd_ref[0, :].astype(old.dtype)
    new = old + upd if add else upd
    out_ref[0, :] = jnp.where(live, new, old)


@functools.partial(jax.jit, static_argnames=("add", "interpret"))
def scatter_rows(values, rows, updates, mask, *, add: bool,
                 interpret: bool = True):
    """values[rows[i]] = (values[rows[i]] +)? updates[i]  where mask[i].

    A masked-out lane rewrites its (clipped) row unchanged — which would
    clobber a masked-in write to the same row if it ran afterwards.  The
    lanes are therefore sorted masked-out-first before the grid launch:
    every no-op rewrite lands before any real write, so collisions between
    masked-out and masked-in rows are harmless.  That keeps the value plane
    aliased in place (no O(capacity) copies); the cost is one O(N·D) lane
    permutation.  Uniqueness is required of the masked-in rows only.
    """
    n = rows.shape[0]
    r_tot, d = values.shape
    # masked-out lanes first (ascending mask); stable keeps masked-in rows
    # in caller order (they are unique, so order among them is free anyway)
    mask_s, rows_s, perm = jax.lax.sort(
        (mask.astype(jnp.int32), jnp.clip(rows, 0, r_tot - 1),
         jnp.arange(n, dtype=jnp.int32)),
        num_keys=1, is_stable=True,
    )
    updates_s = updates[perm]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),   # mask
            pl.BlockSpec((1, d), lambda i, r: (i, 0)),           # update row
            pl.BlockSpec((1, d), lambda i, r: (r[i], 0)),        # value row (aliased)
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, r: (r[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_scatter_kernel, add),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(values.shape, values.dtype),
        input_output_aliases={3: 0},  # values plane updated in place
        interpret=interpret,
        name="hkv_scatter_rows",
    )(rows_s, mask_s, updates_s, values)
