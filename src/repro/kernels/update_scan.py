"""Pallas TPU kernels for the FUSED updater path (paper §3.5, updater role).

PR 1 kernel-completed the inserter (``upsert_scan``) and PR 6 the reader
(``find_scan``); the updater — the gradient path that dominates continuous
training — still ran as a three-launch composition: ``find_ptr`` locate,
``gather_rows`` value fetch, host-jnp optimizer math, ``scatter_rows``
write-back.  Every embedding row crossed HBM *twice* with a full kernel
boundary in between.  This module folds all four stages into ONE
scalar-prefetched pass per (deduped) query:

  1. probe + confirm      both candidate bucket rows stream in as
                          scalar-prefetch-indexed blocks and are matched
                          with the shared ``core.find.match_lanes`` oracle
                          (digest conjoined iff ``use_digest``) — the same
                          formula as ``find_scan`` and the jnp reference,
                          so kernel and oracle cannot fork;
  2. dual-bucket merge    hit1-wins-over-hit2, ``core.find.locate``'s merge;
  3. row RMW              an in-kernel HBM->VMEM DMA of the full value row
                          ``[dim + aux]`` at ``bucket * S + slot``, the
                          sparse optimizer applied *in-kernel* (static
                          variant per ``SparseOptimizer.name`` — the exact
                          ``SparseOptimizer.apply`` math on a [1, V] row
                          slice, so per-row equals batch application
                          bitwise), then a VMEM->HBM DMA back.

Mask domination (cache semantics — rejected embeddings do not train):

  * miss lanes resolve to row ``b1*S + 0`` (a valid address), read it, and
    write the freshly-read bytes back unchanged — the optimizer result is
    ``jnp.where``-selected away before the write DMA, so an un-admitted
    key never perturbs a resident row;
  * a ``qvalid`` lane gates the match IN-KERNEL: an EMPTY-padded query key
    would otherwise *match* an empty slot (empty slots store the all-ones
    sentinel in their key planes).  The find path can re-mask after the
    kernel because it only reads; an updater writes, so the gate must
    dominate the store inside the kernel.

Write-after-read ordering: each query's value RMW is fully serialized
(read.wait before apply, write.wait before the next query's read) because
miss lanes alias row ``b1*S+0`` and may collide with a hit lane's row.
The pipeline variant keeps its two-slot metadata double buffer — query
q+1's bucket rows stream while query q's row is read-modified-written —
so the latency hiding lives where the traffic is (metadata), and the
serialized value row is the correctness anchor.

PRECONDITION (enforced by callers, asserted in tests): query keys are
unique within a batch (the embedding layer dedupes and segment-sums
gradients first) — the same one-warp-per-key invariant as the paper's
update kernels.

Both variants compute exactly ``ref.update_scan_ref`` and are swept
against it in tests/test_update_kernel.py (interpret mode executes the
kernel bodies on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.find import match_lanes
from repro.kernels import compat
from repro.kernels.find_scan import _merge_hits

LANES = 128  # TPU vreg minor dimension == slots per bucket


# =============================================================================
# TLP variant: one query per grid step, auto-pipelined metadata row blocks
# =============================================================================


def _tlp_kernel(opt, dim, use_digest, slots,
                b1_ref, b2_ref, qd_ref, qh_ref, ql_ref, qv_ref,
                d1_ref, h1_ref, l1_ref, d2_ref, h2_ref, l2_ref,
                g_ref, v_hbm, found_ref, out_hbm, vbuf, rsem, wsem):
    del v_hbm  # aliased with out_hbm — all row traffic goes through out_hbm
    i = pl.program_id(0)
    qd = qd_ref[i]
    qh = qh_ref[i]
    ql = ql_ref[i]

    def row_match(d_ref, h_ref, l_ref):
        if use_digest:
            m = match_lanes(h_ref[0, :], l_ref[0, :], qh, ql,
                            d_ref[0, :].astype(jnp.uint32), qd)
        else:
            m = match_lanes(h_ref[0, :], l_ref[0, :], qh, ql)
        return jnp.any(m), jnp.argmax(m).astype(jnp.int32)

    hit1, slot1 = row_match(d1_ref, h1_ref, l1_ref)
    hit2, slot2 = row_match(d2_ref, h2_ref, l2_ref)
    found, sel, slot = _merge_hits(slots, (hit1, slot1, hit2, slot2))
    # qvalid gate must dominate the store: an EMPTY-padded query matches
    # empty slots (both are the all-ones key sentinel) and would otherwise
    # train a vacant row.
    found = found & (qv_ref[i] != 0)
    found_ref[0, 0] = found.astype(jnp.int32)

    b = jnp.where(sel == 0, b1_ref[i], b2_ref[i])
    row = b * slots + slot

    # serialized row RMW: read.wait -> apply -> masked write -> write.wait
    rd = pltpu.make_async_copy(out_hbm.at[pl.ds(row, 1), :], vbuf, rsem)
    rd.start()
    rd.wait()
    raw = vbuf[0, :]
    new = opt.apply(raw[None, :], g_ref[0, :][None, :], dim)[0]
    vbuf[0, :] = jnp.where(found, new.astype(raw.dtype), raw)
    wr = pltpu.make_async_copy(vbuf, out_hbm.at[pl.ds(row, 1), :], wsem)
    wr.start()
    wr.wait()


@functools.partial(jax.jit,
                   static_argnames=("opt", "dim", "use_digest", "interpret"))
def update_scan_tlp(tdigests, tkey_hi, tkey_lo, tvalues,
                    bucket1, bucket2, qdigest, qkey_hi, qkey_lo, qvalid,
                    grads, *, opt, dim: int,
                    use_digest: bool = True, interpret: bool = True):
    """Fused update, TLP tier: one query per grid step.

    tvalues is updated IN PLACE (input/output aliased).  Returns
    (found i32 [N], new_values [B*S, V]):
      found       1 iff the key matched a live slot AND qvalid[i] != 0
      new_values  the value plane with each hit row replaced by
                  ``opt.apply(row, grads[i], dim)``; miss/invalid lanes
                  leave their (aliased) rows bit-identical.

    ``opt`` is a static ``SparseOptimizer`` (frozen dataclass — hashable);
    its variant is compiled into the kernel body, not branched at runtime.
    Single-bucket mode: pass bucket2 == bucket1.
    """
    n = bucket1.shape[0]
    s = tdigests.shape[1]
    row = lambda i, b1, b2: (b1[i], 0)
    row2 = lambda i, b1, b2: (b2[i], 0)
    v = tvalues.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),  # qdigest
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_hi
            pl.BlockSpec(memory_space=compat.SMEM),  # qkey_lo
            pl.BlockSpec(memory_space=compat.SMEM),  # qvalid
            pl.BlockSpec((1, s), row),    # bucket1 digest row
            pl.BlockSpec((1, s), row),    # bucket1 key_hi row
            pl.BlockSpec((1, s), row),    # bucket1 key_lo row
            pl.BlockSpec((1, s), row2),   # bucket2 digest row
            pl.BlockSpec((1, s), row2),   # bucket2 key_hi row
            pl.BlockSpec((1, s), row2),   # bucket2 key_lo row
            pl.BlockSpec((1, grads.shape[1]), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec(memory_space=compat.HBM),  # value plane (aliased)
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec(memory_space=compat.HBM),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, v), tvalues.dtype),
            pltpu.SemaphoreType.DMA,   # read semaphore
            pltpu.SemaphoreType.DMA,   # write semaphore
        ],
    )
    found, vals = pl.pallas_call(
        functools.partial(_tlp_kernel, opt, dim, use_digest, s),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct(tvalues.shape, tvalues.dtype),
        ],
        input_output_aliases={13: 1},  # value plane updated in place
        interpret=interpret,
        name="hkv_update_scan_tlp",
    )(
        bucket1, bucket2, qdigest, qkey_hi, qkey_lo, qvalid,
        tdigests, tkey_hi, tkey_lo,
        tdigests, tkey_hi, tkey_lo,
        grads, tvalues,
    )
    return found[:, 0], vals


# =============================================================================
# Pipeline variant: Q queries per grid step, manual two-slot metadata buffer
# =============================================================================


def _pipeline_kernel(opt, dim, use_digest, q_tile, slots,
                     b1_ref, b2_ref, qd_ref, qh_ref, ql_ref, qv_ref,
                     td, th, tl, g_ref, v_hbm,
                     found_ref, out_hbm,
                     d1b, h1b, l1b, d2b, h2b, l2b,
                     vbuf, sems, rsem, wsem):
    del v_hbm  # aliased with out_hbm — all row traffic goes through out_hbm
    i = pl.program_id(0)

    def meta_copies(q, slot_):
        base = i * q_tile + q
        b1 = b1_ref[base]
        b2 = b2_ref[base]
        planes = (td, th, tl)
        cps = []
        for j, (p, bf) in enumerate(zip(planes, (d1b, h1b, l1b))):
            cps.append(pltpu.make_async_copy(
                p.at[pl.ds(b1, 1), :], bf.at[slot_], sems.at[slot_, j]))
        for j, (p, bf) in enumerate(zip(planes, (d2b, h2b, l2b))):
            cps.append(pltpu.make_async_copy(
                p.at[pl.ds(b2, 1), :], bf.at[slot_], sems.at[slot_, 3 + j]))
        return cps

    def issue(q, slot_):
        for c in meta_copies(q, slot_):
            c.start()

    def wait(q, slot_):
        for c in meta_copies(q, slot_):
            c.wait()

    # prologue: prefetch query 0's two candidate bucket rows
    issue(0, 0)

    q_iota = jax.lax.iota(jnp.int32, q_tile)

    def body(q, founds):
        cur = jax.lax.rem(q, 2)
        nxt = jax.lax.rem(q + 1, 2)

        # overlap: issue q+1's metadata DMAs while q is compared + RMW'd
        @pl.when(q + 1 < q_tile)
        def _():
            issue(q + 1, nxt)

        wait(q, cur)
        qd = qd_ref[0, q]
        qh = qh_ref[0, q]
        ql = ql_ref[0, q]

        def row_match(db, hb, lb):
            if use_digest:
                m = match_lanes(hb[cur, 0, :], lb[cur, 0, :], qh, ql,
                                db[cur, 0, :].astype(jnp.uint32), qd)
            else:
                m = match_lanes(hb[cur, 0, :], lb[cur, 0, :], qh, ql)
            return jnp.any(m), jnp.argmax(m).astype(jnp.int32)

        hit1, slot1 = row_match(d1b, h1b, l1b)
        hit2, slot2 = row_match(d2b, h2b, l2b)
        found, sel, slot = _merge_hits(slots, (hit1, slot1, hit2, slot2))
        found = found & (qv_ref[0, q] != 0)  # gate dominates the store

        base = i * q_tile + q
        b = jnp.where(sel == 0, b1_ref[base], b2_ref[base])
        row = b * slots + slot

        # serialized row RMW — miss lanes alias row b1*S+0, so query q's
        # write must retire before query q+1's read (no value-row overlap)
        rd = pltpu.make_async_copy(out_hbm.at[pl.ds(row, 1), :], vbuf, rsem)
        rd.start()
        rd.wait()
        raw = vbuf[0, :]
        new = opt.apply(raw[None, :], g_ref[pl.ds(q, 1), :], dim)[0]
        vbuf[0, :] = jnp.where(found, new.astype(raw.dtype), raw)
        wr = pltpu.make_async_copy(vbuf, out_hbm.at[pl.ds(row, 1), :], wsem)
        wr.start()
        wr.wait()

        return jnp.where(q_iota == q, found.astype(jnp.int32), founds)

    founds = jax.lax.fori_loop(
        0, q_tile, body, jnp.zeros((q_tile,), jnp.int32))
    found_ref[0, :] = founds


@functools.partial(jax.jit, static_argnames=(
    "q_tile", "opt", "dim", "use_digest", "interpret"))
def update_scan_pipeline(tdigests, tkey_hi, tkey_lo, tvalues,
                         bucket1, bucket2, qdigest, qkey_hi, qkey_lo, qvalid,
                         grads, *, q_tile: int = 128, opt, dim: int,
                         use_digest: bool = True, interpret: bool = True):
    """Fused update, Pipeline tier: Q queries per grid step, manual DMA.

    Same outputs and in-place aliasing as `update_scan_tlp`.  Queries are
    padded to a multiple of q_tile by the wrapper (padding lanes carry
    qvalid == 0, so they never write).  Scratch working set: 2 x 6
    metadata rows + one value row — the value plane itself stays in HBM.
    """
    n = bucket1.shape[0]
    assert n % q_tile == 0, "wrapper must pad to a q_tile multiple"
    s = tdigests.shape[1]
    v = tvalues.shape[1]
    g = grads.shape[1]
    tiles = n // q_tile
    smem_block = lambda: pl.BlockSpec((1, q_tile), lambda i, b1, b2: (i, 0),
                                      memory_space=compat.SMEM)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(tiles,),
        in_specs=[
            smem_block(),   # qdigest
            smem_block(),   # qkey_hi
            smem_block(),   # qkey_lo
            smem_block(),   # qvalid
            pl.BlockSpec(memory_space=compat.HBM),  # digest plane
            pl.BlockSpec(memory_space=compat.HBM),  # key_hi plane
            pl.BlockSpec(memory_space=compat.HBM),  # key_lo plane
            pl.BlockSpec((q_tile, g), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec(memory_space=compat.HBM),  # value plane (aliased)
        ],
        out_specs=[
            pl.BlockSpec((1, q_tile), lambda i, b1, b2: (i, 0)),
            pl.BlockSpec(memory_space=compat.HBM),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, 1, s), jnp.uint8),    # bucket1 digests
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket1 key_hi
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket1 key_lo
            pltpu.VMEM((2, 1, s), jnp.uint8),    # bucket2 digests
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket2 key_hi
            pltpu.VMEM((2, 1, s), jnp.uint32),   # bucket2 key_lo
            pltpu.VMEM((1, v), tvalues.dtype),   # value row (serialized RMW)
            pltpu.SemaphoreType.DMA((2, 6)),
            pltpu.SemaphoreType.DMA,   # value read semaphore
            pltpu.SemaphoreType.DMA,   # value write semaphore
        ],
    )
    found, vals = pl.pallas_call(
        functools.partial(_pipeline_kernel, opt, dim, use_digest, q_tile, s),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((tiles, q_tile), jnp.int32),
            jax.ShapeDtypeStruct(tvalues.shape, tvalues.dtype),
        ],
        input_output_aliases={10: 1},  # value plane updated in place
        interpret=interpret,
        name="hkv_update_scan_pipeline",
    )(
        bucket1, bucket2,
        qdigest.reshape(tiles, q_tile),
        qkey_hi.reshape(tiles, q_tile),
        qkey_lo.reshape(tiles, q_tile),
        qvalid.reshape(tiles, q_tile),
        tdigests, tkey_hi, tkey_lo, grads, tvalues,
    )
    return found.reshape(n), vals
