"""Jit'd wrappers over the Pallas kernels (+ dispatch and padding logic).

`interpret` defaults to True off-TPU: the kernel bodies execute in Python
on CPU (the validation mode this container supports) and compile to Mosaic
on real TPUs.  The wrappers are drop-in equivalents of the pure-jnp paths
in `repro.core` and are cross-checked against them in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import find as find_mod
from repro.core import u64
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64
from repro.kernels import digest_scan as _ds
from repro.kernels import gather as _ga
from repro.kernels import ref as _ref
from repro.kernels import scatter as _sc
from repro.kernels import score_scan as _ss


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, n: int, fill=0):
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def locate_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
) -> find_mod.Locate:
    """Kernel-backed drop-in for core.find.locate (single & dual bucket)."""
    if interpret is None:
        interpret = default_interpret()
    n = keys.hi.shape[0]
    probe = find_mod.probe_keys(cfg, keys)
    qd = probe.digest.astype(jnp.uint32)

    if variant == "pipeline":
        q_tile = min(128, n) if n % 128 else 128
        npad = -(-n // q_tile) * q_tile
        scan = functools.partial(
            _ds.digest_scan_pipeline, q_tile=q_tile, interpret=interpret
        )
    elif variant == "tlp":
        npad = n
        scan = functools.partial(_ds.digest_scan_tlp, interpret=interpret)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    def run(bucket):
        slot, found = scan(
            state.digests,
            state.key_hi,
            state.key_lo,
            _pad_to(bucket, npad),
            _pad_to(qd, npad),
            _pad_to(keys.hi, npad, u64.EMPTY_HI),
            _pad_to(keys.lo, npad, u64.EMPTY_LO),
        )
        return slot[:n], found[:n].astype(bool)

    slot1, hit1 = run(probe.bucket1)
    if cfg.buckets_per_key == 2:
        slot2, hit2 = run(probe.bucket2)
        found = (hit1 | hit2) & probe.valid
        bucket = jnp.where(hit1, probe.bucket1, jnp.where(hit2, probe.bucket2, probe.bucket1))
        slot = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    else:
        found = hit1 & probe.valid
        bucket, slot = probe.bucket1, jnp.where(hit1, slot1, 0)
    s = cfg.slots_per_bucket
    return find_mod.Locate(found=found, bucket=bucket, slot=slot, row=bucket * s + slot)


def find_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
):
    """Kernel-backed `find`: digest scan + position-addressed value gather."""
    if interpret is None:
        interpret = default_interpret()
    loc = locate_kernel(state, cfg, keys, variant=variant, interpret=interpret)
    rows = jnp.clip(loc.row, 0, state.values.shape[0] - 1)
    vals = _ga.gather_rows(
        state.values, rows, loc.found.astype(jnp.int32), interpret=interpret
    )
    return vals[:, : cfg.dim], loc.found


def assign_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    *,
    add: bool = False,
    interpret: bool | None = None,
) -> HKVState:
    """Kernel-backed updater (assign / assign_add).

    PRECONDITION: keys unique within the batch (callers dedupe; duplicate
    handling is the merge path's job).
    """
    if interpret is None:
        interpret = default_interpret()
    loc = locate_kernel(state, cfg, keys, interpret=interpret)
    vdim = state.values.shape[1]
    if values.shape[1] < vdim:
        values = jnp.concatenate(
            [values, jnp.zeros((values.shape[0], vdim - values.shape[1]), values.dtype)],
            axis=1,
        )
    rows = jnp.clip(loc.row, 0, state.values.shape[0] - 1)
    new_values = _sc.scatter_rows(
        state.values, rows, values, loc.found.astype(jnp.int32), add=add,
        interpret=interpret,
    )
    return state._replace(values=new_values)


def bucket_stats_kernel(state: HKVState, *, interpret: bool | None = None):
    """(occ, min_hi, min_lo, argmin) per bucket via the tiled scan kernel."""
    if interpret is None:
        interpret = default_interpret()
    b = state.key_hi.shape[0]
    tile = 8 if b % 8 == 0 else 1
    return _ss.bucket_stats(
        state.key_hi, state.key_lo, state.score_hi, state.score_lo,
        bucket_tile=tile, interpret=interpret,
    )


# Re-exported oracles for tests/benches
ref = _ref
