"""Jit'd wrappers over the Pallas kernels (+ dispatch and padding logic).

`interpret` defaults to True off-TPU: the kernel bodies execute in Python
on CPU (the validation mode this container supports) and compile to Mosaic
on real TPUs.  The wrappers are drop-in equivalents of the pure-jnp paths
in `repro.core` and are cross-checked against them in tests.

Role taxonomy coverage (paper §3.5; see also `repro.core.ops`):

  READERS    kernel-backed here: find_fused_kernel / find_kernel /
             find_many_kernel (the FUSED find_scan path: digest pre-filter
             + full-key confirm + score readout + in-line value gather in
             ONE launch over both candidate bucket rows — DESIGN.md
             §Readers), locate_kernel (digest_scan tlp/pipeline; the
             metadata-only path behind find_ptr/contains and the updaters),
             bucket_stats_kernel (score_scan).  jnp-only: size/load_factor/
             export_* (trivial reductions/slices — nothing for a kernel to
             win).
  UPDATERS   kernel-backed here: update_rows_kernel (the FUSED update_scan
             pass: probe + full-key confirm + in-kernel sparse-optimizer
             apply + masked row write-back in ONE launch — DESIGN.md
             §Updaters; update_composed_kernel is the pre-fusion
             locate + gather + host apply + scatter baseline), assign_kernel
             (assign / assign_add via scatter_rows).  jnp-only:
             assign_scores (scalar metadata scatter, no value traffic).
  INSERTERS  kernel-backed here: upsert_kernel / insert_and_evict_kernel /
             find_or_insert_kernel — the fused upsert_scan path (probe +
             claim row passes plus gather/scatter value stages) sharing
             `core.merge.upsert`'s batch-closure orchestration, so results
             are bit-identical to the pure-jnp path (DESIGN.md §4).
             jnp-only: erase, clear, accum_or_assign.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import find as find_mod
from repro.core import merge as merge_mod
from repro.core import table as table_mod
from repro.core import u64
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64
from repro.kernels import digest_scan as _ds
from repro.kernels import find_scan as _fs
from repro.kernels import gather as _ga
from repro.kernels import ref as _ref
from repro.kernels import scatter as _sc
from repro.kernels import score_scan as _ss
from repro.kernels import sweep_scan as _sw
from repro.kernels import update_scan as _upd
from repro.kernels import upsert_scan as _us


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, n: int, fill=0):
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def locate_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
) -> find_mod.Locate:
    """Kernel-backed drop-in for core.find.locate (single & dual bucket)."""
    if interpret is None:
        interpret = default_interpret()
    n = keys.hi.shape[0]
    probe = find_mod.probe_keys(cfg, keys)
    qd = probe.digest.astype(jnp.uint32)

    if variant == "pipeline":
        q_tile = min(128, n) if n % 128 else 128
        npad = -(-n // q_tile) * q_tile
        scan = functools.partial(
            _ds.digest_scan_pipeline, q_tile=q_tile, interpret=interpret
        )
    elif variant == "tlp":
        npad = n
        scan = functools.partial(_ds.digest_scan_tlp, interpret=interpret)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    def run(bucket):
        slot, found = scan(
            state.digests,
            state.key_hi,
            state.key_lo,
            _pad_to(bucket, npad),
            _pad_to(qd, npad),
            _pad_to(keys.hi, npad, u64.EMPTY_HI),
            _pad_to(keys.lo, npad, u64.EMPTY_LO),
        )
        return slot[:n], found[:n].astype(bool)

    slot1, hit1 = run(probe.bucket1)
    if cfg.buckets_per_key == 2:
        slot2, hit2 = run(probe.bucket2)
        found = (hit1 | hit2) & probe.valid
        bucket = jnp.where(hit1, probe.bucket1, jnp.where(hit2, probe.bucket2, probe.bucket1))
        slot = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    else:
        found = hit1 & probe.valid
        bucket, slot = probe.bucket1, jnp.where(hit1, slot1, 0)
    s = cfg.slots_per_bucket
    return find_mod.Locate(found=found, bucket=bucket, slot=slot, row=bucket * s + slot)


class FusedFind(NamedTuple):
    """Everything the fused find pass resolves per query, in one launch."""

    values: jax.Array    # [N, dim + aux] full-width hit rows (zeros on miss)
    found: jax.Array     # bool [N]
    bucket: jax.Array    # int32 [N] bucket holding the key (b1 on miss)
    slot: jax.Array      # int32 [N] slot holding the key (0 on miss)
    row: jax.Array       # int32 [N] value row = bucket * S + slot
    score_hi: jax.Array  # uint32 [N] hit entry scores (0 on miss)
    score_lo: jax.Array

    @property
    def loc(self) -> find_mod.Locate:
        return find_mod.Locate(found=self.found, bucket=self.bucket,
                               slot=self.slot, row=self.row)


def find_fused_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
) -> FusedFind:
    """The fused find pass (find_scan.py): digest pre-filter + full-key
    confirm + dual-bucket merge + score readout + in-line value gather, in
    ONE kernel launch — replacing the digest_scan (x buckets_per_key) +
    gather_rows composition and its on-host row-address round trip.

    Bit-identical to `core.find.locate` + `gather_values` + the score
    readout in `core.ops.find`/`find_rows` (the jnp oracle; pinned in
    tests/test_find_kernel.py).  Host-tier value planes ('hmem') keep the
    §3.6 crossing contract: the kernel locates, `tier_gather` moves rows.
    """
    if interpret is None:
        interpret = default_interpret()
    if cfg.value_tier != "hbm":
        # host-tier rows cross via the jnp tier contract; metadata still
        # resolves on the kernel locate path
        loc = locate_kernel(state, cfg, keys, variant=variant,
                            interpret=interpret)
        vals = find_mod.gather_values(state, loc, None, cfg.value_tier)
        shi = jnp.where(loc.found, state.score_hi[loc.bucket, loc.slot], 0)
        slo = jnp.where(loc.found, state.score_lo[loc.bucket, loc.slot], 0)
        return FusedFind(values=vals, found=loc.found, bucket=loc.bucket,
                         slot=loc.slot, row=loc.row, score_hi=shi,
                         score_lo=slo)

    n = keys.hi.shape[0]
    probe = find_mod.probe_keys(cfg, keys)
    qd = probe.digest.astype(jnp.uint32)
    if variant == "pipeline":
        q_tile = min(128, n) if n % 128 else 128
        npad = -(-n // q_tile) * q_tile
        scan = functools.partial(_fs.find_scan_pipeline, q_tile=q_tile,
                                 use_digest=cfg.use_digest,
                                 interpret=interpret)
    elif variant == "tlp":
        npad = n
        scan = functools.partial(_fs.find_scan_tlp,
                                 use_digest=cfg.use_digest,
                                 interpret=interpret)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    b2 = probe.bucket2 if cfg.buckets_per_key == 2 else probe.bucket1
    found, sel, slot, shi, slo, vals = scan(
        state.digests, state.key_hi, state.key_lo,
        state.score_hi, state.score_lo, state.values,
        _pad_to(probe.bucket1, npad),
        _pad_to(b2, npad),
        _pad_to(qd, npad),
        _pad_to(keys.hi, npad, u64.EMPTY_HI),
        _pad_to(keys.lo, npad, u64.EMPTY_LO),
    )
    # re-mask by probe validity: an EMPTY padding key may alias empty slots
    # in-kernel; the reference masks those out via probe.valid
    found = found[:n].astype(bool) & probe.valid
    sel, slot = sel[:n], slot[:n]
    bucket = jnp.where(sel == 1, b2, probe.bucket1)
    return FusedFind(
        values=jnp.where(found[:, None], vals[:n], 0),
        found=found,
        bucket=bucket,
        slot=slot,
        row=bucket * cfg.slots_per_bucket + slot,
        score_hi=jnp.where(found, shi[:n], 0),
        score_lo=jnp.where(found, slo[:n], 0),
    )


def find_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
):
    """Kernel-backed `find`: ONE fused pass (was digest_scan + gather_rows)."""
    r = find_fused_kernel(state, cfg, keys, variant=variant,
                          interpret=interpret)
    return r.values[:, : cfg.dim], r.found


def find_composed_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
):
    """The pre-fusion composition — digest_scan locate (one launch per
    candidate bucket) + position-addressed gather_rows launch — kept as the
    launch-count/parity baseline the fused path is measured against
    (tests/test_find_kernel.py, benchmarks/exp2 `fused` arm)."""
    if interpret is None:
        interpret = default_interpret()
    loc = locate_kernel(state, cfg, keys, variant=variant, interpret=interpret)
    rows = jnp.clip(loc.row, 0, state.values.shape[0] - 1)
    vals = _ga.gather_rows(
        state.values, rows, loc.found.astype(jnp.int32), interpret=interpret
    )
    return vals[:, : cfg.dim], loc.found


def find_many_kernel(
    states: Sequence[HKVState],
    cfg: HKVConfig,
    keys_list: Sequence[U64],
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
) -> list[FusedFind]:
    """Batched multi-table lookup: T same-geometry tables in ONE launch.

    The embedding layer keeps one table per feature; serving a wave used to
    launch one find per feature.  Same-geometry tables (same cfg) stack
    along the bucket axis — metadata planes [T*B, S], value plane
    [T*B*S, V] — and per-table probes offset their buckets by t*B, so the
    SAME fused kernel serves all features in a single grid.  Returns one
    `FusedFind` per table with table-local bucket/row indices.
    """
    if interpret is None:
        interpret = default_interpret()
    if not states:
        return []
    if cfg.value_tier != "hbm":
        raise ValueError("find_many_kernel requires the hbm value tier")
    b = cfg.num_buckets
    s = cfg.slots_per_bucket
    for st in states:
        if st.key_hi.shape != (b, s) or st.values.shape != states[0].values.shape:
            raise ValueError("find_many_kernel requires same-geometry tables")
    probes = [find_mod.probe_keys(cfg, k) for k in keys_list]
    counts = [k.hi.shape[0] for k in keys_list]
    off = lambda a, t: a + jnp.int32(t * b)
    b1 = jnp.concatenate([off(p.bucket1, t) for t, p in enumerate(probes)])
    b2s = [p.bucket2 if cfg.buckets_per_key == 2 else p.bucket1
           for p in probes]
    b2 = jnp.concatenate([off(x, t) for t, x in enumerate(b2s)])
    qd = jnp.concatenate([p.digest.astype(jnp.uint32) for p in probes])
    qh = jnp.concatenate([k.hi for k in keys_list])
    ql = jnp.concatenate([k.lo for k in keys_list])
    n = qh.shape[0]

    if variant == "pipeline":
        q_tile = min(128, n) if n % 128 else 128
        npad = -(-n // q_tile) * q_tile
        scan = functools.partial(_fs.find_scan_pipeline, q_tile=q_tile,
                                 use_digest=cfg.use_digest,
                                 interpret=interpret)
    elif variant == "tlp":
        npad = n
        scan = functools.partial(_fs.find_scan_tlp,
                                 use_digest=cfg.use_digest,
                                 interpret=interpret)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    stack = lambda f: jnp.concatenate([f(st) for st in states], axis=0)
    found, sel, slot, shi, slo, vals = scan(
        stack(lambda st: st.digests),
        stack(lambda st: st.key_hi),
        stack(lambda st: st.key_lo),
        stack(lambda st: st.score_hi),
        stack(lambda st: st.score_lo),
        stack(lambda st: st.values),
        _pad_to(b1, npad), _pad_to(b2, npad), _pad_to(qd, npad),
        _pad_to(qh, npad, u64.EMPTY_HI), _pad_to(ql, npad, u64.EMPTY_LO),
    )
    out: list[FusedFind] = []
    start = 0
    for t, (p, cnt) in enumerate(zip(probes, counts)):
        sl = slice(start, start + cnt)
        start += cnt
        f = found[sl].astype(bool) & p.valid
        b2_local = b2s[t]
        bucket = jnp.where(sel[sl] == 1, b2_local, p.bucket1)  # table-local
        out.append(FusedFind(
            values=jnp.where(f[:, None], vals[sl], 0),
            found=f,
            bucket=bucket,
            slot=slot[sl],
            row=bucket * s + slot[sl],
            score_hi=jnp.where(f, shi[sl], 0),
            score_lo=jnp.where(f, slo[sl], 0),
        ))
    return out


def assign_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    *,
    add: bool = False,
    interpret: bool | None = None,
) -> HKVState:
    """Kernel-backed updater (assign / assign_add).

    PRECONDITION: keys unique within the batch (callers dedupe; duplicate
    handling is the merge path's job).
    """
    if interpret is None:
        interpret = default_interpret()
    loc = locate_kernel(state, cfg, keys, interpret=interpret)
    vdim = state.values.shape[1]
    if values.shape[1] < vdim:
        values = jnp.concatenate(
            [values, jnp.zeros((values.shape[0], vdim - values.shape[1]), values.dtype)],
            axis=1,
        )
    rows = jnp.clip(loc.row, 0, state.values.shape[0] - 1)
    new_values = _sc.scatter_rows(
        state.values, rows, values, loc.found.astype(jnp.int32), add=add,
        interpret=interpret,
    )
    return state._replace(values=new_values)


class UpdateRows(NamedTuple):
    """Result of the fused updater pass: new state + which lanes trained."""

    state: HKVState
    found: jax.Array   # bool [N] — lane's key was resident and its row trained


def update_rows_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    grads: jax.Array,
    opt,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
) -> UpdateRows:
    """The fused updater pass (update_scan.py): probe + full-key confirm +
    in-kernel sparse-optimizer apply + masked row write-back in ONE kernel
    launch — replacing the locate + gather_rows + host `opt.apply` +
    scatter_rows composition and its 2x row traffic through HBM.

    PRECONDITION: keys unique within the batch, `grads` pre-summed per key
    (the embedding layer dedupes + segment-sums first).  Miss lanes and
    EMPTY padding never write (cache semantics: un-admitted keys do not
    train).  Bit-identical to `ref.update_scan_ref` and to the jnp
    `core.ops.update_rows` reference (pinned in tests/test_update_kernel.py).

    Host-tier value planes ('hmem') keep the §3.6 crossing contract: the
    kernel locates, the rows cross through tier_gather / tier_scatter with
    the optimizer applied on-device between the crossings.
    """
    if interpret is None:
        interpret = default_interpret()
    b, s = cfg.num_buckets, cfg.slots_per_bucket
    if cfg.value_tier != "hbm":
        loc = locate_kernel(state, cfg, keys, variant=variant,
                            interpret=interpret)
        rows = find_mod.gather_values(state, loc, None, cfg.value_tier)
        new_rows = opt.apply(rows, grads, cfg.dim).astype(state.values.dtype)
        new_rows = jnp.where(loc.found[:, None], new_rows, rows)
        new_values = table_mod.tier_scatter(
            cfg.value_tier, state.values,
            jnp.where(loc.found, loc.row, b * s), new_rows)
        return UpdateRows(state=state._replace(values=new_values),
                          found=loc.found)

    n = keys.hi.shape[0]
    probe = find_mod.probe_keys(cfg, keys)
    qd = probe.digest.astype(jnp.uint32)
    if variant == "pipeline":
        q_tile = min(128, n) if n % 128 else 128
        npad = -(-n // q_tile) * q_tile
        scan = functools.partial(_upd.update_scan_pipeline, q_tile=q_tile,
                                 opt=opt, dim=cfg.dim,
                                 use_digest=cfg.use_digest,
                                 interpret=interpret)
    elif variant == "tlp":
        npad = n
        scan = functools.partial(_upd.update_scan_tlp, opt=opt, dim=cfg.dim,
                                 use_digest=cfg.use_digest,
                                 interpret=interpret)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    b2 = probe.bucket2 if cfg.buckets_per_key == 2 else probe.bucket1
    # the qvalid gate travels INTO the kernel: EMPTY padding lanes match
    # empty slots, and unlike the read-only find pass an updater cannot
    # re-mask after the fact — the gate must dominate the store
    found, new_values = scan(
        state.digests, state.key_hi, state.key_lo, state.values,
        _pad_to(probe.bucket1, npad),
        _pad_to(b2, npad),
        _pad_to(qd, npad),
        _pad_to(keys.hi, npad, u64.EMPTY_HI),
        _pad_to(keys.lo, npad, u64.EMPTY_LO),
        _pad_to(probe.valid.astype(jnp.int32), npad),
        _pad_to(grads.astype(state.values.dtype), npad),
    )
    return UpdateRows(state=state._replace(values=new_values),
                      found=found[:n].astype(bool))


def update_composed_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    grads: jax.Array,
    opt,
    *,
    variant: str = "pipeline",
    interpret: bool | None = None,
) -> UpdateRows:
    """The pre-fusion updater composition — locate (one digest_scan launch
    per candidate bucket) + gather_rows + host-jnp `opt.apply` + scatter_rows
    — kept as the launch-count/parity baseline the fused pass is measured
    against (tests/test_update_kernel.py, benchmarks/exp9)."""
    if interpret is None:
        interpret = default_interpret()
    loc = locate_kernel(state, cfg, keys, variant=variant, interpret=interpret)
    rows_idx = jnp.clip(loc.row, 0, state.values.shape[0] - 1)
    rows = _ga.gather_rows(state.values, rows_idx,
                           loc.found.astype(jnp.int32), interpret=interpret)
    new_rows = opt.apply(rows, grads, cfg.dim).astype(state.values.dtype)
    new_values = _sc.scatter_rows(
        state.values, rows_idx, new_rows, loc.found.astype(jnp.int32),
        add=False, interpret=interpret)
    return UpdateRows(state=state._replace(values=new_values),
                      found=loc.found)


def sweep_mask_kernel(state: HKVState, cfg: HKVConfig, pred,
                      *, interpret: bool | None = None) -> jax.Array:
    """Kernel-backed predicate mask for the maintenance sweeps
    (`core.ops.erase_if` / `evict_if`): one fused pass over the metadata
    planes evaluating `pred` (a `core.predicates.SweepPredicate`) with
    liveness gating and per-bucket counting.  Returns the bool [B, S]
    match mask; bit-identical to the jnp reference because both evaluate
    `core.predicates.match_planes` (DESIGN.md §Maintenance)."""
    if interpret is None:
        interpret = default_interpret()
    match, _cnt = _sw.sweep_match(
        state.key_hi, state.key_lo, state.score_hi, state.score_lo,
        pred.a_hi, pred.a_lo, pred.b_hi, pred.b_lo,
        kind=pred.kind, interpret=interpret,
    )
    return match


def bucket_stats_kernel(state: HKVState, *, interpret: bool | None = None):
    """(occ, min_hi, min_lo, argmin) per bucket via the tiled scan kernel."""
    if interpret is None:
        interpret = default_interpret()
    b = state.key_hi.shape[0]
    tile = 8 if b % 8 == 0 else 1
    return _ss.bucket_stats(
        state.key_hi, state.key_lo, state.score_hi, state.score_lo,
        bucket_tile=tile, interpret=interpret,
    )


# =============================================================================
# Inserter path: fused upsert/evict kernels (upsert_scan + gather/scatter)
# =============================================================================


def _kernel_locate_stage(cfg: HKVConfig, interpret: bool):
    """UpsertStages.locate backed by the kernel match path.

    Single-bucket mode reuses the digest-scan reader kernel (3 planes, one
    row per query); dual mode uses the fused upsert_probe so both candidate
    rows stream through one pass instead of two kernel launches.
    """

    def locate_single(state: HKVState, _cfg: HKVConfig, keys: U64, probe):
        return locate_kernel(state, cfg, keys, interpret=interpret)

    if cfg.buckets_per_key == 1:
        return locate_single

    def locate(state: HKVState, _cfg: HKVConfig, keys: U64, probe):
        found, hit_sel, hit_slot, _tgt = _us.upsert_probe(
            state.digests, state.key_hi, state.key_lo,
            state.score_hi, state.score_lo,
            probe.bucket1, probe.bucket2,
            probe.digest.astype(jnp.uint32), keys.hi, keys.lo,
            use_digest=cfg.use_digest, interpret=interpret,
        )
        fnd = found.astype(bool) & probe.valid
        bucket = jnp.where(
            found.astype(bool) & (hit_sel == 1), probe.bucket2, probe.bucket1
        )
        s = cfg.slots_per_bucket
        return find_mod.Locate(
            found=fnd, bucket=bucket, slot=hit_slot, row=bucket * s + hit_slot
        )

    return locate


def _kernel_select_stage(cfg: HKVConfig, interpret: bool):
    """UpsertStages.select_target backed by the same fused probe pass.

    Runs against the post-phase-1 state (hit scores already updated), as the
    batch closure requires: D2's lower-min-score comparison must see this
    batch's score touches.
    """

    def select(state: HKVState, _cfg: HKVConfig, probe):
        if cfg.buckets_per_key == 1:
            return probe.bucket1
        zeros = jnp.zeros_like(probe.bucket1, jnp.uint32)
        _f, _hs, _sl, tgt_sel = _us.upsert_probe(
            state.digests, state.key_hi, state.key_lo,
            state.score_hi, state.score_lo,
            probe.bucket1, probe.bucket2,
            zeros, zeros, zeros,  # match result unused: stats-only pass
            use_digest=cfg.use_digest, interpret=interpret,
        )
        return jnp.where(tgt_sel == 1, probe.bucket2, probe.bucket1)

    return select


def _kernel_victim_stage(cfg: HKVConfig, interpret: bool):
    """UpsertStages.victim_at_rank backed by the claim_scan rank kernel."""

    def victim(state: HKVState, _cfg: HKVConfig, bkt_g, rank):
        s = cfg.slots_per_bucket
        vslot, vocc, vsh, vsl, vkh, vkl = _us.claim_scan(
            state.key_hi, state.key_lo, state.score_hi, state.score_lo,
            bkt_g, jnp.clip(rank, 0, s - 1), interpret=interpret,
        )
        return vslot, vocc.astype(bool), U64(vsh, vsl), U64(vkh, vkl)

    return victim


def _kernel_gather_stage(cfg: HKVConfig, interpret: bool):
    jnp_gather = merge_mod.jnp_stages().gather_values

    def gather(_cfg: HKVConfig, values, rows, mask):
        if cfg.value_tier != "hbm":  # host-tier rows cross via the jnp path
            return jnp_gather(cfg, values, rows, mask)
        rows = jnp.clip(rows, 0, values.shape[0] - 1)
        return _ga.gather_rows(values, rows, mask.astype(jnp.int32),
                               interpret=interpret)

    return gather


def _kernel_scatter_stage(cfg: HKVConfig, interpret: bool):
    jnp_scatter = merge_mod.jnp_stages().scatter_values

    def scatter(_cfg: HKVConfig, values, rows, updates, mask):
        if cfg.value_tier != "hbm":
            return jnp_scatter(cfg, values, rows, updates, mask)
        rows = jnp.clip(rows, 0, values.shape[0] - 1)
        return _sc.scatter_rows(values, rows, updates.astype(values.dtype),
                                mask.astype(jnp.int32), add=False,
                                interpret=interpret)

    return scatter


def kernel_stages(cfg: HKVConfig, *, interpret: bool | None = None
                  ) -> merge_mod.UpsertStages:
    """Kernel-backed implementations of every upsert stage contract."""
    if interpret is None:
        interpret = default_interpret()
    return merge_mod.UpsertStages(
        locate=_kernel_locate_stage(cfg, interpret),
        select_target=_kernel_select_stage(cfg, interpret),
        victim_at_rank=_kernel_victim_stage(cfg, interpret),
        gather_values=_kernel_gather_stage(cfg, interpret),
        scatter_values=_kernel_scatter_stage(cfg, interpret),
    )


def upsert_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    *,
    custom_scores: Optional[U64] = None,
    write_hit_values: bool = True,
    update_hit_scores: bool = True,
    insert_values: Optional[jax.Array] = None,
    return_evicted: bool = False,
    interpret: bool | None = None,
) -> merge_mod.MergeResult:
    """Kernel-backed drop-in for core.merge.upsert (Alg. 2/3 batch closure).

    Same orchestration, kernel stages: one fused probe pass (digest
    pre-filter -> full-key match -> occupancy/min-score -> dual-bucket
    selection), one claim pass (rank-r empty claim / argmin eviction /
    rejection), and gather/scatter row kernels for the value plane.
    Bit-identical to the pure-jnp path — statuses, evicted pairs, state.
    """
    return merge_mod.upsert(
        state, cfg, keys, values,
        custom_scores=custom_scores,
        write_hit_values=write_hit_values,
        update_hit_scores=update_hit_scores,
        insert_values=insert_values,
        return_evicted=return_evicted,
        stages=kernel_stages(cfg, interpret=interpret),
    )


def insert_and_evict_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    values: jax.Array,
    *,
    custom_scores: Optional[U64] = None,
    interpret: bool | None = None,
) -> merge_mod.MergeResult:
    """Kernel-backed insert_or_assign returning displaced entries in-launch
    (the paper's single-kernel eviction hand-off)."""
    return upsert_kernel(
        state, cfg, keys, values, custom_scores=custom_scores,
        return_evicted=True, interpret=interpret,
    )


def gather_rows_kernel(state: HKVState, loc: find_mod.Locate, dim: int,
                       *, interpret: bool | None = None) -> jax.Array:
    """Position-addressed value gather at `loc` via the row-pipeline kernel
    (hbm tier only — tier crossings stay on the jnp `tier_gather` path).
    Missing keys return zero rows, matching `find_mod.gather_values`."""
    if interpret is None:
        interpret = default_interpret()
    rows = jnp.clip(loc.row, 0, state.values.shape[0] - 1)
    return _ga.gather_rows(
        state.values, rows, loc.found.astype(jnp.int32), interpret=interpret,
    )[:, :dim]


def find_or_insert_kernel(
    state: HKVState,
    cfg: HKVConfig,
    keys: U64,
    init_values: jax.Array,
    *,
    custom_scores: Optional[U64] = None,
    interpret: bool | None = None,
):
    """Kernel-backed find_or_insert: ONE fused probe pass.

    The upsert closure publishes every key's post-op location
    (`MergeResult.loc`), so neither the pre-locate nor the post-insert
    re-locate this wrapper used to issue is needed: `found` comes from the
    closure's own probe and the value readback is a position-addressed
    `gather_rows` at the published rows.  Probe passes: the closure's
    locate + target-selection stages only (pinned, with bit-parity against
    the old three-pass sequence, in tests/test_upsert_kernel.py).

    Returns (state, values, found, status) with core.ops.find_or_insert
    semantics: hits keep their stored value, rejected keys get the caller's
    init row back (ephemeral).
    """
    if interpret is None:
        interpret = default_interpret()
    res = upsert_kernel(
        state, cfg, keys, init_values, custom_scores=custom_scores,
        write_hit_values=False, interpret=interpret,
    )
    if cfg.value_tier == "hbm":
        vals = gather_rows_kernel(res.state, res.loc, cfg.dim,
                                  interpret=interpret)
    else:
        vals = find_mod.gather_values(res.state, res.loc, cfg.dim,
                                      cfg.value_tier)
    vals = jnp.where(res.loc.found[:, None], vals, init_values[:, : cfg.dim])
    return res.state, vals, res.found, res.status


# Re-exported oracles for tests/benches
ref = _ref
