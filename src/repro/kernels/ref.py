"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose/equal).
These are also the implementations XLA runs where a kernel is not
profitable (tiny batches) — the wrapper in ops.py dispatches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import find, u64


def digest_scan_ref(
    tdigests: jax.Array,   # uint8  [B, S] table digest rows
    tkey_hi: jax.Array,    # uint32 [B, S]
    tkey_lo: jax.Array,    # uint32 [B, S]
    buckets: jax.Array,    # int32  [N] bucket per query
    qdigest: jax.Array,    # uint32 [N] query digest (widened for SMEM)
    qkey_hi: jax.Array,    # uint32 [N]
    qkey_lo: jax.Array,    # uint32 [N]
) -> tuple[jax.Array, jax.Array]:
    """(slot int32 [N], found int32 [N]) — Algorithm 1 over one bucket row.

    Digest pre-filter then full key compare; the first matching slot wins
    (at most one can match by the table's key-uniqueness invariant).
    """
    m = find.match_lanes(tkey_hi[buckets], tkey_lo[buckets],
                         qkey_hi[:, None], qkey_lo[:, None],
                         tdigests[buckets].astype(jnp.uint32),
                         qdigest[:, None])
    found = jnp.any(m, axis=1).astype(jnp.int32)
    slot = jnp.argmax(m, axis=1).astype(jnp.int32)
    return slot, found


def find_scan_ref(
    tdigests: jax.Array,   # uint8  [B, S]
    tkey_hi: jax.Array,    # uint32 [B, S]
    tkey_lo: jax.Array,    # uint32 [B, S]
    tscore_hi: jax.Array,  # uint32 [B, S]
    tscore_lo: jax.Array,  # uint32 [B, S]
    tvalues: jax.Array,    # [B*S, V] value plane (position addressing §3.6)
    bucket1: jax.Array,    # int32  [N] primary candidate bucket
    bucket2: jax.Array,    # int32  [N] secondary candidate (== bucket1 single)
    qdigest: jax.Array,    # uint32 [N]
    qkey_hi: jax.Array,    # uint32 [N]
    qkey_lo: jax.Array,    # uint32 [N]
    use_digest: bool = True,
):
    """Ground truth for the fused find kernel (find_scan.py).

    Per query, over both candidate bucket rows: digest pre-filter + full-key
    confirm (the `core.find._match_in_bucket` formula), dual-bucket merge
    (hit1 wins; miss defaults to bucket1/slot0), score readout at the hit
    slot, and the hit row's value slice (zeros on miss).

    Returns (found i32 [N], sel i32 [N] — 0=bucket1/1=bucket2, slot i32 [N],
    score_hi u32 [N], score_lo u32 [N], values [N, V]).
    """
    s = tdigests.shape[1]

    def match(buckets):
        if use_digest:
            m = find.match_lanes(tkey_hi[buckets], tkey_lo[buckets],
                                 qkey_hi[:, None], qkey_lo[:, None],
                                 tdigests[buckets].astype(jnp.uint32),
                                 qdigest[:, None])
        else:
            m = find.match_lanes(tkey_hi[buckets], tkey_lo[buckets],
                                 qkey_hi[:, None], qkey_lo[:, None])
        return jnp.any(m, axis=1), jnp.argmax(m, axis=1).astype(jnp.int32)

    hit1, slot1 = match(bucket1)
    hit2, slot2 = match(bucket2)
    found = hit1 | hit2
    sel = jnp.where(hit1, 0, jnp.where(hit2, 1, 0)).astype(jnp.int32)
    slot = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    bucket = jnp.where(sel == 1, bucket2, bucket1)
    shi = jnp.where(found, tscore_hi[bucket, slot], 0)
    slo = jnp.where(found, tscore_lo[bucket, slot], 0)
    vals = tvalues[bucket * s + slot]
    vals = jnp.where(found[:, None], vals, jnp.zeros_like(vals))
    return found.astype(jnp.int32), sel, slot, shi, slo, vals


def update_scan_ref(
    tdigests: jax.Array,   # uint8  [B, S]
    tkey_hi: jax.Array,    # uint32 [B, S]
    tkey_lo: jax.Array,    # uint32 [B, S]
    tvalues: jax.Array,    # [B*S, V] value plane (position addressing §3.6)
    bucket1: jax.Array,    # int32  [N] primary candidate bucket
    bucket2: jax.Array,    # int32  [N] secondary candidate (== bucket1 single)
    qdigest: jax.Array,    # uint32 [N]
    qkey_hi: jax.Array,    # uint32 [N]
    qkey_lo: jax.Array,    # uint32 [N]
    qvalid: jax.Array,     # int32  [N] — 0 gates the write (EMPTY padding)
    grads: jax.Array,      # [N, dim] segment-summed gradient rows
    opt,                   # SparseOptimizer (static variant)
    dim: int,
    use_digest: bool = True,
):
    """Ground truth for the fused updater kernel (update_scan.py).

    Per query, over both candidate bucket rows: digest pre-filter + full-key
    confirm (the shared `core.find.match_lanes` formula), dual-bucket merge
    (hit1 wins), then a masked row read-modify-write: the hit row becomes
    ``opt.apply(row, grads[i], dim)``; miss or qvalid==0 lanes leave the
    plane untouched (cache semantics — un-admitted keys never train).

    The qvalid gate exists because an EMPTY-padded query key *matches*
    empty slots (both are the all-ones sentinel in the key planes): a
    read-only kernel can re-mask afterwards, a writing kernel cannot.

    Returns (found i32 [N], new_values [B*S, V]).
    """
    s = tdigests.shape[1]

    def match(buckets):
        if use_digest:
            m = find.match_lanes(tkey_hi[buckets], tkey_lo[buckets],
                                 qkey_hi[:, None], qkey_lo[:, None],
                                 tdigests[buckets].astype(jnp.uint32),
                                 qdigest[:, None])
        else:
            m = find.match_lanes(tkey_hi[buckets], tkey_lo[buckets],
                                 qkey_hi[:, None], qkey_lo[:, None])
        return jnp.any(m, axis=1), jnp.argmax(m, axis=1).astype(jnp.int32)

    hit1, slot1 = match(bucket1)
    hit2, slot2 = match(bucket2)
    found = (hit1 | hit2) & (qvalid != 0)
    sel = jnp.where(hit1, 0, jnp.where(hit2, 1, 0)).astype(jnp.int32)
    slot = jnp.where(hit1, slot1, jnp.where(hit2, slot2, 0))
    bucket = jnp.where(sel == 1, bucket2, bucket1)
    row = bucket * s + slot
    raw = tvalues[row]
    new_rows = opt.apply(raw, grads, dim).astype(tvalues.dtype)
    r = jnp.where(found, row, tvalues.shape[0])  # OOB -> dropped
    new_values = tvalues.at[r].set(
        jnp.where(found[:, None], new_rows, raw), mode="drop")
    return found.astype(jnp.int32), new_values


def gather_rows_ref(
    values: jax.Array,  # [R, D]
    rows: jax.Array,    # int32 [N]
    mask: jax.Array,    # int32/bool [N] — rows with mask==0 return zeros
) -> jax.Array:
    """Position-addressed value gather (§3.6): out[i] = values[rows[i]]."""
    out = values[jnp.clip(rows, 0, values.shape[0] - 1)]
    return jnp.where(mask.astype(bool)[:, None], out, jnp.zeros_like(out))


def scatter_rows_ref(
    values: jax.Array,  # [R, D]
    rows: jax.Array,    # int32 [N] — must be unique where mask set
    updates: jax.Array,  # [N, D]
    mask: jax.Array,    # [N]
    add: bool,
) -> jax.Array:
    """Updater-role write-back: values[rows[i]] (+)= updates[i] where mask."""
    r = jnp.where(mask.astype(bool), rows, values.shape[0])  # OOB -> dropped
    if add:
        return values.at[r].add(updates.astype(values.dtype), mode="drop")
    return values.at[r].set(updates.astype(values.dtype), mode="drop")


def bucket_stats_ref(
    tkey_hi: jax.Array,   # uint32 [B, S]
    tkey_lo: jax.Array,   # uint32 [B, S]
    score_hi: jax.Array,  # uint32 [B, S]
    score_lo: jax.Array,  # uint32 [B, S]
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-bucket (occupancy, min-score hi, min-score lo, argmin slot).

    Empty slots (all-ones key sentinel) are excluded from the min; a fully
    empty bucket reports the all-ones max score and argmin slot 0.
    """
    occ_mask = ~u64.empty_lanes(tkey_hi, tkey_lo)
    occ = jnp.sum(occ_mask.astype(jnp.int32), axis=1)
    ones = jnp.uint32(0xFFFFFFFF)
    shi = jnp.where(occ_mask, score_hi, ones)
    slo = jnp.where(occ_mask, score_lo, ones)
    min_hi = jnp.min(shi, axis=1)
    lo_cand = jnp.where(shi == min_hi[:, None], slo, ones)
    min_lo = jnp.min(lo_cand, axis=1)
    is_min = find.match_lanes(shi, slo, min_hi[:, None], min_lo[:, None])
    argmin = jnp.argmax(is_min, axis=1).astype(jnp.int32)
    return occ, min_hi, min_lo, argmin
