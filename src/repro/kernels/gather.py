"""Pallas TPU kernel: position-addressed value gather (paper §3.6, §4.3 TLPv2).

After the digest scan resolves (bucket, slot) -> row = bucket*S + slot, the
value copy is a pure bandwidth problem.  The paper's TLPv2 regroups threads
into cooperative value-copy gangs with double-buffered shared memory; the
TPU analogue is a scalar-prefetch-indexed row pipeline: the row index stream
is prefetched into SMEM, each grid step's BlockSpec selects values[row] as
its input block, and the Pallas pipeline emitter overlaps row r+1's
HBM->VMEM DMA with row r's writeback — the same two-deep overlap, driven by
the hardware DMA engine.

Rows with mask==0 (misses) produce zero rows, matching `find`'s contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _gather_kernel(rows_ref, mask_ref, val_ref, out_ref):
    i = pl.program_id(0)
    live = mask_ref[i] != 0
    out_ref[0, :] = jnp.where(live, val_ref[0, :], jnp.zeros_like(val_ref[0, :]))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(values, rows, mask, *, interpret: bool = True):
    """out[i] = mask[i] ? values[rows[i]] : 0   (rows pre-clipped in wrapper)."""
    n = rows.shape[0]
    d = values.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=compat.SMEM),        # mask
            pl.BlockSpec((1, d), lambda i, r: (r[i], 0)),             # values row
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, r: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), values.dtype),
        interpret=interpret,
        name="hkv_gather_rows",
    )(rows, mask, values)
