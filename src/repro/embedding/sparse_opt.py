"""Sparse optimizers for HKV-backed embeddings (updater-role gradient path).

Optimizer slot state is colocated with each embedding row as aux value
columns (HugeCTR-style): a table row is [embedding dim | aux columns], so
an eviction carries the optimizer state away with the row and an admission
starts fresh — no separate slot-state table to keep consistent.

  rowwise_adagrad — 1 aux column: the row-wise accumulated squared-grad
                    mean (the DLRM production standard).
  adagrad         — `dim` aux columns: per-coordinate accumulator.
  sgd / sgdm      — 0 / `dim` aux columns.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def _rounded(p: jax.Array) -> jax.Array:
    """Pin ``p`` (a float product) to its own IEEE rounding.

    FMA contraction folds ``a*b + c`` into one rounding, and whether the
    compiler contracts depends on the fusion context around the expression
    — the identical formula can produce last-ULP-different results in two
    programs (the fused update kernel vs the batched jnp reference).
    ``llvm.fmuladd`` formation requires the multiply to have a SINGLE use,
    so giving the product a second, value-preserving use (``p - p`` cannot
    be folded to zero without fast-math: NaN/inf operands) forces the
    product to round separately in every context.  ``lax
    .optimization_barrier`` does NOT work for this — XLA:CPU strips it
    before LLVM sees the loop.  Cost: two extra vector ops per site.
    """
    return p + (p - p)


@dataclasses.dataclass(frozen=True)
class SparseOptimizer:
    name: str = "rowwise_adagrad"
    lr: float = 0.01
    eps: float = 1e-10
    momentum: float = 0.9

    def aux_dim(self, dim: int) -> int:
        return {"sgd": 0, "sgdm": dim, "rowwise_adagrad": 1, "adagrad": dim}[self.name]

    def apply(self, rows: jax.Array, grads: jax.Array, dim: int) -> jax.Array:
        """rows: [N, dim + aux] gathered table rows; grads: [N, dim].

        Returns updated rows (embedding + refreshed aux columns) — written
        back through the updater role (`assign`), never structurally.

        Every multiply feeding an add/sub is pinned to one IEEE rounding
        via ``_rounded``: XLA/LLVM otherwise contract mul+add into an FMA
        *depending on the surrounding fusion context*, so the same row
        would round differently inside the fused update kernel ([1, V]
        slices) than in the batched jnp reference — and the repo's
        acceptance bar for kernels is BIT-identity, not allclose.
        """
        emb, aux = rows[:, :dim], rows[:, dim:]
        g = grads.astype(emb.dtype)
        rnd = _rounded
        if self.name == "sgd":
            return emb - rnd(self.lr * g)
        if self.name == "sgdm":
            m = rnd(self.momentum * aux) + g
            return jnp.concatenate([emb - rnd(self.lr * m), m], axis=1)
        if self.name == "rowwise_adagrad":
            acc = aux[:, 0] + rnd(jnp.mean(g * g, axis=1))
            step = self.lr / (jnp.sqrt(acc) + self.eps)
            return jnp.concatenate(
                [emb - rnd(step[:, None] * g), acc[:, None]], axis=1)
        if self.name == "adagrad":
            acc = aux + rnd(g * g)
            return jnp.concatenate(
                [emb - rnd(self.lr * g / (jnp.sqrt(acc) + self.eps)), acc],
                axis=1,
            )
        raise ValueError(self.name)
