"""Sparse optimizers for HKV-backed embeddings (updater-role gradient path).

Optimizer slot state is colocated with each embedding row as aux value
columns (HugeCTR-style): a table row is [embedding dim | aux columns], so
an eviction carries the optimizer state away with the row and an admission
starts fresh — no separate slot-state table to keep consistent.

  rowwise_adagrad — 1 aux column: the row-wise accumulated squared-grad
                    mean (the DLRM production standard).
  adagrad         — `dim` aux columns: per-coordinate accumulator.
  sgd / sgdm      — 0 / `dim` aux columns.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparseOptimizer:
    name: str = "rowwise_adagrad"
    lr: float = 0.01
    eps: float = 1e-10
    momentum: float = 0.9

    def aux_dim(self, dim: int) -> int:
        return {"sgd": 0, "sgdm": dim, "rowwise_adagrad": 1, "adagrad": dim}[self.name]

    def apply(self, rows: jax.Array, grads: jax.Array, dim: int) -> jax.Array:
        """rows: [N, dim + aux] gathered table rows; grads: [N, dim].

        Returns updated rows (embedding + refreshed aux columns) — written
        back through the updater role (`assign`), never structurally.
        """
        emb, aux = rows[:, :dim], rows[:, dim:]
        g = grads.astype(emb.dtype)
        if self.name == "sgd":
            return emb - self.lr * g
        if self.name == "sgdm":
            m = self.momentum * aux + g
            return jnp.concatenate([emb - self.lr * m, m], axis=1)
        if self.name == "rowwise_adagrad":
            acc = aux[:, 0] + jnp.mean(g * g, axis=1)
            step = self.lr / (jnp.sqrt(acc) + self.eps)
            return jnp.concatenate([emb - step[:, None] * g, acc[:, None]], axis=1)
        if self.name == "adagrad":
            acc = aux + g * g
            return jnp.concatenate(
                [emb - self.lr * g / (jnp.sqrt(acc) + self.eps), acc], axis=1
            )
        raise ValueError(self.name)
