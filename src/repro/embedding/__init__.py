"""Embedding backends: the paper's workload layer (Fig. 1).

Every architecture's token/feature embedding can run on either backend:

  dense  — an ordinary learnable [vocab, dim] matrix, vocab-sharded over
           the model axis (the dictionary-semantic world; also the roofline
           baseline).
  hkv    — the paper's cache-semantic table as a first-class dynamic
           embedding: find_or_insert on the token batch (inserter role,
           admission-controlled), gradient application through the updater
           role, capacity decoupled from key-space size.
"""

from repro.embedding.dense import DenseEmbedding  # noqa: F401
from repro.embedding.dynamic import HKVEmbedding  # noqa: F401
from repro.embedding import sparse_opt  # noqa: F401
