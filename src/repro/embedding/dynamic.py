"""HKV-backed dynamic embedding — the paper's cache-semantic table wired
into a model input layer (the HugeCTR/TFRA integration pattern, §1/§6).

Training path (one step):
  1. find_or_insert on the (flattened) token batch — INSERTER role, the
     step's single structural op.  New tokens are admitted subject to
     score-based admission control; at λ=1.0 the table stays full and
     low-value embeddings are evicted in place (continuous online
     ingestion, paper Fig. 2).
  2. The model consumes the gathered rows; jax.grad gives d(loss)/d(rows).
  3. apply_grads — UPDATER role: per-unique-token gradient sums feed a
     sparse optimizer whose slot state lives in aux value columns, and the
     refreshed rows are written back with `assign` (non-structural, so XLA
     may overlap it with the next microbatch's compute; §3.5 adaptation).

Serving path: `find` only — READER role; unseen tokens fall back to the
same deterministic hash-derived init the training path would insert, so
train/serve disagree only by the not-yet-applied gradients.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import merge as merge_mod
from repro.core import find as find_mod
from repro.core import ops as hkv_ops
from repro.core import table as table_mod
from repro.core import u64
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64
from repro.embedding.sparse_opt import SparseOptimizer


@dataclasses.dataclass(frozen=True)
class HKVEmbedding:
    capacity: int                      # table slots (decoupled from key-space size!)
    dim: int
    optimizer: SparseOptimizer = SparseOptimizer("rowwise_adagrad")
    buckets_per_key: int = 2           # dual-bucket: §3.4 retention + utilization
    score_policy: str = "lru"
    value_dtype: jnp.dtype = jnp.float32
    value_tier: str = "hbm"
    backend: str = "auto"              # inserter backend: 'auto'|'jnp'|'kernel' (DESIGN.md §4)

    def config(self) -> HKVConfig:
        return HKVConfig(
            capacity=self.capacity,
            dim=self.dim,
            buckets_per_key=self.buckets_per_key,
            score_policy=self.score_policy,
            value_dtype=self.value_dtype,
            value_tier=self.value_tier,
            aux_value_dim=self.optimizer.aux_dim(self.dim),
        )

    def create(self) -> HKVState:
        return table_mod.create(self.config())

    # -- key & init derivation -------------------------------------------------

    def keys_of(self, tokens: jax.Array) -> U64:
        """Token ids -> u64 keys. Negative ids (padding) become the EMPTY
        sentinel and are ignored by every table op."""
        t = tokens.reshape(-1)
        neg = t < 0
        return U64(
            jnp.where(neg, jnp.uint32(u64.EMPTY_HI), jnp.uint32(0)),
            jnp.where(neg, jnp.uint32(u64.EMPTY_LO), t.astype(jnp.uint32)),
        )

    def default_rows(self, keys: U64) -> jax.Array:
        """Deterministic per-key init: counter-mode fmix32 bits -> uniform
        rows in ±1/sqrt(dim).  Restart-stable and identical on every shard."""
        h1, _ = u64.hash_pair(keys)
        col_salt = (
            jnp.arange(self.dim, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9)
        ) ^ jnp.uint32(0x85EBCA6B)
        bits = u64.fmix32(h1[:, None] ^ col_salt[None, :])
        uni = bits.astype(jnp.float32) * (1.0 / 4294967296.0)
        return ((uni - 0.5) * (2.0 / np.sqrt(self.dim))).astype(self.value_dtype)

    # -- roles -------------------------------------------------------------

    def lookup_train(self, state: HKVState, tokens: jax.Array):
        """INSERTER: find_or_insert the token batch. Returns (state, rows)."""
        cfg = self.config()
        keys = self.keys_of(tokens)
        init = self.default_rows(keys)
        res = hkv_ops.find_or_insert(state, cfg, keys, init, backend=self.backend)
        emb = res.values.reshape(tokens.shape + (self.dim,))
        return res.state, emb

    def lookup_serve(self, state: HKVState, tokens: jax.Array) -> jax.Array:
        """READER: find; misses fall back to the deterministic init row."""
        cfg = self.config()
        keys = self.keys_of(tokens)
        res = hkv_ops.find(state, cfg, keys)
        vals = jnp.where(res.found[:, None], res.values, self.default_rows(keys))
        return vals.reshape(tokens.shape + (self.dim,))

    def apply_grads(
        self, state: HKVState, tokens: jax.Array, grads: jax.Array
    ) -> HKVState:
        """UPDATER: sum grads per unique token, run the sparse optimizer on
        the gathered rows, write back with `assign` (non-structural)."""
        cfg = self.config()
        keys = self.keys_of(tokens)
        g = grads.reshape(-1, self.dim)
        n = g.shape[0]
        keys_s, idx_s, gid, _count, _last, rep = merge_mod._dedupe_sort(keys)
        g_sum = jax.ops.segment_sum(g[idx_s], gid, num_segments=n)
        g_rep = g_sum[gid]  # at each group's first slot: the group total
        uk = u64.select(rep, keys_s, u64.empty_sentinel((n,)))
        loc = find_mod.locate(state, cfg, uk)
        rows = table_mod.tier_gather(
            cfg.value_tier, state.values,
            jnp.clip(loc.row, 0, state.values.shape[0] - 1),
        )
        new_rows = self.optimizer.apply(rows, g_rep, self.dim)
        # rejected-admission tokens simply have no row to update (cache
        # semantics: un-admitted embeddings do not train)
        return hkv_ops.assign(state, cfg, uk, new_rows)

    def ingest(self, state: HKVState, tokens: jax.Array) -> HKVState:
        """Deferred-structural variant: admit this batch's new tokens without
        reading values (used by the overlapped-ingest schedule, §3.5/Exp#3e)."""
        cfg = self.config()
        keys = self.keys_of(tokens)
        init = self.default_rows(keys)
        return merge_mod.upsert(
            state, cfg, keys,
            hkv_ops._pad_aux(init, state),
            write_hit_values=False,
        ).state
