"""HKV-backed dynamic embedding — the paper's cache-semantic table wired
into a model input layer (the HugeCTR/TFRA integration pattern, §1/§6).

Training path (one step):
  1. find_or_insert on the (flattened) token batch — INSERTER role, the
     step's single structural op.  New tokens are admitted subject to
     score-based admission control; at λ=1.0 the table stays full and
     low-value embeddings are evicted in place (continuous online
     ingestion, paper Fig. 2).
  2. The model consumes the gathered rows; jax.grad gives d(loss)/d(rows).
  3. apply_grads — UPDATER role: per-unique-token gradient sums feed a
     sparse optimizer whose slot state lives in aux value columns, handed
     to the table as a structured `ops.RowUpdate` session op — on the
     kernel backend the whole step is ONE fused update_scan launch (probe
     + in-kernel optimizer apply + masked write-back; §3.5 adaptation).

Serving path: `find` only — READER role; unseen tokens fall back to the
same deterministic hash-derived init the training path would insert, so
train/serve disagree only by the not-yet-applied gradients.

All table traffic goes through the `HKVTable` handle (`repro.core.api`);
this module owns only token↔key derivation and the optimizer hookup.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as ops_mod
from repro.core import u64
from repro.core.api import HKVTable, dedupe_keys
from repro.core.table import HKVConfig
from repro.core.tiered import TieredHKVTable, TieredState
from repro.core.u64 import U64
from repro.embedding.sparse_opt import SparseOptimizer


@dataclasses.dataclass(frozen=True)
class HKVEmbedding:
    capacity: int                      # table slots (decoupled from key-space size!)
    dim: int
    optimizer: SparseOptimizer = SparseOptimizer("rowwise_adagrad")
    buckets_per_key: int = 2           # dual-bucket: §3.4 retention + utilization
    score_policy: str = "lru"
    value_dtype: jnp.dtype = jnp.float32
    value_tier: str = "hbm"
    backend: str = "auto"              # inserter backend: 'auto'|'jnp'|'kernel' (DESIGN.md §4)
    # Tier hierarchy (DESIGN.md §2.5): when `hot_capacity` is set the
    # embedding runs a TieredHKVTable — an HBM hot tier of `hot_capacity`
    # slots in front of a `capacity`-slot cold tier whose value plane uses
    # `cold_value_tier` placement.  The embedding contract is unchanged;
    # the table requirement relaxes from "fits in HBM" to "hot set fits".
    hot_capacity: Optional[int] = None
    cold_score_policy: str = "custom"  # demoted pairs keep translated scores
    cold_value_tier: str = "hmem"

    @property
    def is_tiered(self) -> bool:
        return self.hot_capacity is not None

    @property
    def total_capacity(self) -> int:
        return self.capacity + (self.hot_capacity or 0)

    def config(self) -> HKVConfig:
        """The flat table's config — the HOT tier's when tiered (capacity
        is the only field that differs between the two uses)."""
        return HKVConfig(
            capacity=self.hot_capacity if self.is_tiered else self.capacity,
            dim=self.dim,
            buckets_per_key=self.buckets_per_key,
            score_policy=self.score_policy,
            value_dtype=self.value_dtype,
            value_tier=self.value_tier,
            aux_value_dim=self.optimizer.aux_dim(self.dim),
        )

    def cold_config(self) -> HKVConfig:
        return dataclasses.replace(
            self.config(), capacity=self.capacity,
            score_policy=self.cold_score_policy,
            value_tier=self.cold_value_tier,
        )

    def create(self):
        if self.is_tiered:
            return TieredHKVTable.from_configs(
                self.config(), self.cold_config(), backend=self.backend)
        return HKVTable.create(self.config(), backend=self.backend)

    def wrap(self, state):
        """Re-bind a (shard-local) state with the right handle type — the
        one entry point shard_map bodies use, so the distributed layer is
        agnostic to flat-vs-tiered."""
        if self.is_tiered:
            return TieredHKVTable.wrap(
                TieredState(*state) if not isinstance(state, TieredState)
                else state,
                self.config(), self.cold_config(), backend=self.backend)
        return HKVTable.wrap(state, self.config(), backend=self.backend)

    # -- key & init derivation -------------------------------------------------

    def keys_of(self, tokens: jax.Array) -> U64:
        """Token ids -> u64 keys. Negative ids (padding) become the EMPTY
        sentinel and are ignored by every table op."""
        t = tokens.reshape(-1)
        neg = t < 0
        return U64(
            jnp.where(neg, jnp.uint32(u64.EMPTY_HI), jnp.uint32(0)),
            jnp.where(neg, jnp.uint32(u64.EMPTY_LO), t.astype(jnp.uint32)),
        )

    def default_rows(self, keys: U64) -> jax.Array:
        """Deterministic per-key init: counter-mode fmix32 bits -> uniform
        rows in ±1/sqrt(dim).  Restart-stable and identical on every shard."""
        h1, _ = u64.hash_pair(keys)
        col_salt = (
            jnp.arange(self.dim, dtype=jnp.uint32) * jnp.uint32(0x9E3779B9)
        ) ^ jnp.uint32(0x85EBCA6B)
        bits = u64.fmix32(h1[:, None] ^ col_salt[None, :])
        uni = bits.astype(jnp.float32) * (1.0 / 4294967296.0)
        return ((uni - 0.5) * (2.0 / np.sqrt(self.dim))).astype(self.value_dtype)

    # -- roles -------------------------------------------------------------

    def lookup_train(self, table: HKVTable, tokens: jax.Array):
        """INSERTER: find_or_insert the token batch. Returns (table, rows)."""
        keys = self.keys_of(tokens)
        res = table.find_or_insert(keys, self.default_rows(keys))
        emb = res.values.reshape(tokens.shape + (self.dim,))
        return res.table, emb

    def lookup_serve(self, table, tokens: jax.Array) -> jax.Array:
        """READER: find; misses fall back to the deterministic init row.

        On a tiered table this is the PURE-READER form (promote=False):
        the serve path discards the successor handle, so promotion work
        would be two structural upserts thrown away per lookup."""
        keys = self.keys_of(tokens)
        if isinstance(table, TieredHKVTable):
            res = table.find(keys, promote=False)
        else:
            res = table.find(keys)
        vals = jnp.where(res.found[:, None], res.values, self.default_rows(keys))
        return vals.reshape(tokens.shape + (self.dim,))

    def apply_grads(
        self, table: HKVTable, tokens: jax.Array, grads: jax.Array
    ) -> HKVTable:
        """UPDATER: sum grads per unique token, hand the table the
        structured gradient step (`ops.RowUpdate`) — so the whole update is
        dedupe (XLA) + ONE table op, and on backend='kernel' ONE fused
        update_scan launch (probe + optimizer apply + write-back).

        Dedupe is COMPACTED: group g's representative key lands at slot g,
        so the unique keys occupy a prefix (EMPTY-padded beyond) and the
        segment sums are already aligned with them — the old form
        re-broadcast the sums to every sorted slot (`g_sum[d.gid]`, a
        second batch-sized [N, dim] buffer) to line up with the
        sorted-space `d.unique`."""
        keys = self.keys_of(tokens)
        g = grads.reshape(-1, self.dim)
        n = g.shape[0]
        d = dedupe_keys(keys)
        uniq = U64(
            jnp.full((n,), u64.EMPTY_HI, jnp.uint32)
            .at[d.gid].set(keys.hi[d.idx_sorted]),
            jnp.full((n,), u64.EMPTY_LO, jnp.uint32)
            .at[d.gid].set(keys.lo[d.idx_sorted]),
        )
        g_sum = jax.ops.segment_sum(g[d.idx_sorted], d.gid, num_segments=n,
                                    indices_are_sorted=True)
        s = table.session()
        # rejected-admission tokens simply have no row to update (cache
        # semantics: un-admitted embeddings do not train)
        s.update_rows(uniq, ops_mod.RowUpdate(self.optimizer, g_sum))
        return s.commit()

    def ingest(self, table: HKVTable, tokens: jax.Array) -> HKVTable:
        """Deferred-structural variant: admit this batch's new tokens without
        reading values (used by the overlapped-ingest schedule, §3.5/Exp#3e)."""
        keys = self.keys_of(tokens)
        return table.ingest(keys, self.default_rows(keys)).table
