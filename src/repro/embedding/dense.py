"""Dense (static-vocabulary) embedding backend — the dictionary-semantic
baseline and the default backbone input layer for the assigned archs."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DenseEmbedding:
    vocab: int
    dim: int
    dtype: jnp.dtype = jnp.float32

    def init(self, key: jax.Array) -> dict:
        scale = 1.0 / jnp.sqrt(self.dim)
        return {
            "table": (jax.random.normal(key, (self.vocab, self.dim)) * scale).astype(
                self.dtype
            )
        }

    def lookup(self, params: dict, tokens: jax.Array) -> jax.Array:
        return params["table"][tokens]

    def attend(self, params: dict, x: jax.Array) -> jax.Array:
        """Tied-softmax logits: x @ table.T (used when lm_head is tied)."""
        return x @ params["table"].T.astype(x.dtype)
