"""TableStats — the observability half of the maintenance subsystem.

The scheduler's decisions (when to expire, when to rebalance, how hard)
need cheap whole-table summaries; operators need the same numbers to
size tiers.  `TableStats` is that summary, computed from nothing but the
metadata planes every table family carries (keys + scores), so ONE
implementation serves `HKVTable`, both tiers of `TieredHKVTable`, the
dictionary baselines (zero score planes), and `ShardedHKVTable` — whose
sharded state leaves are globally-addressable arrays, so the same jnp
reductions run unchanged over the whole mesh (stats never hash keys, so
shard-local bucket numbering is irrelevant).

Fields:

  size / capacity / load_factor   live entries vs slots
  occupancy_hist  int32 [S+1]     how many buckets hold exactly k live
                                  entries — the skew picture (a long tail
                                  at S means reactive evictions are near)
  score_q_{hi,lo} uint32 [5]      score quantiles (min, p25, p50, p75,
                                  max) over live entries in the u64 score
                                  order — where the eviction threshold
                                  sits, and what `evict_if` budgets reach

Eviction/demotion/expiry COUNTERS are runtime accumulations, not state
functions — they live on the `MaintenanceScheduler` (`.totals`) and in
the serving engine's per-wave reports (`WaveReport.demotions`), next to
the code that causes them.

Everything is jittable and static-shape; `stats_from_planes` is the
single implementation the handle `.stats()` methods delegate to.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.u64 import U64

QUANTILES = (0.0, 0.25, 0.5, 0.75, 1.0)


class TableStats(NamedTuple):
    size: jax.Array            # int32 []
    capacity: jax.Array        # int32 []
    load_factor: jax.Array     # float32 []
    occupancy_hist: jax.Array  # int32 [S+1] — buckets holding exactly k entries
    score_q_hi: jax.Array      # uint32 [5] — score quantiles (hi plane)
    score_q_lo: jax.Array      # uint32 [5]

    def score_quantiles(self) -> np.ndarray:
        """Host-side uint64 view of the score quantiles (min..max)."""
        hi = np.asarray(self.score_q_hi, np.uint64)
        lo = np.asarray(self.score_q_lo, np.uint64)
        return (hi << np.uint64(32)) | lo


def stats_from_planes(key_hi: jax.Array, key_lo: jax.Array,
                      score_hi: Optional[jax.Array] = None,
                      score_lo: Optional[jax.Array] = None,
                      *, live: Optional[jax.Array] = None) -> TableStats:
    """Compute TableStats from [B, S] metadata planes.

    `live` overrides the EMPTY-sentinel liveness test (the open-addressing
    baseline excludes tombstones); score planes default to zeros (the
    dictionary baselines carry none).
    """
    b, s = key_hi.shape
    if live is None:
        live = ~u64.is_empty(U64(key_hi, key_lo))
    if score_hi is None:
        score_hi = jnp.zeros((b, s), jnp.uint32)
    if score_lo is None:
        score_lo = jnp.zeros((b, s), jnp.uint32)
    occ_b = jnp.sum(live.astype(jnp.int32), axis=1)
    hist = jnp.zeros((s + 1,), jnp.int32).at[occ_b].add(1)
    n = jnp.sum(live.astype(jnp.int32))
    # live scores sorted ascending; empties ride at the top as the max
    # sentinel and are excluded by the quantile indexing below
    ONES = jnp.uint32(0xFFFFFFFF)
    sh = jnp.where(live, score_hi, ONES).reshape(-1)
    sl = jnp.where(live, score_lo, ONES).reshape(-1)
    sh_s, sl_s = jax.lax.sort((sh, sl), num_keys=2)
    q = jnp.asarray(QUANTILES, jnp.float32)
    idx = jnp.clip(jnp.round(q * jnp.maximum(n - 1, 0).astype(jnp.float32))
                   .astype(jnp.int32), 0, b * s - 1)
    nonempty = n > 0
    q_hi = jnp.where(nonempty, sh_s[idx], 0)
    q_lo = jnp.where(nonempty, sl_s[idx], 0)
    return TableStats(
        size=n,
        capacity=jnp.int32(b * s),
        load_factor=n.astype(jnp.float32) / float(b * s),
        occupancy_hist=hist,
        score_q_hi=q_hi.astype(jnp.uint32),
        score_q_lo=q_lo.astype(jnp.uint32),
    )


def combine_stats(a: TableStats, b: TableStats,
                  *, size: Optional[jax.Array] = None) -> TableStats:
    """Merge two tiers'/shards' stats into one table-level view.

    Histograms add elementwise (same slot width — the tier hierarchy
    shares value-row geometry, so S matches); quantiles MERGE by
    re-quantiling the two summaries' concatenation (an approximation —
    exact per-tier quantiles remain available on the inputs).  `size`
    overrides the sum for hierarchies that dedupe inclusive copies.
    """
    n = size if size is not None else a.size + b.size
    cap = a.capacity + b.capacity
    # approximate merged quantiles: sort the 10 summary points, take the
    # same 5 positions (exact when one side is empty)
    qh = jnp.concatenate([a.score_q_hi, b.score_q_hi])
    ql = jnp.concatenate([a.score_q_lo, b.score_q_lo])
    weight = jnp.concatenate([
        jnp.broadcast_to(a.size, (5,)), jnp.broadcast_to(b.size, (5,))])
    # empty side's zeros must not drag the min down: push them to the top
    ONES = jnp.uint32(0xFFFFFFFF)
    qh = jnp.where(weight > 0, qh, ONES)
    ql = jnp.where(weight > 0, ql, ONES)
    qh_s, ql_s = jax.lax.sort((qh, ql), num_keys=2)
    sel = jnp.asarray([0, 2, 4, 6, 9], jnp.int32)
    # one side empty -> the other side's quantiles, exactly
    a_only, b_only = b.size == 0, a.size == 0
    pick = lambda merged, av, bv: jnp.where(
        a_only, av, jnp.where(b_only, bv, merged))
    return TableStats(
        size=n,
        capacity=cap,
        load_factor=n.astype(jnp.float32) / jnp.maximum(
            cap.astype(jnp.float32), 1.0),
        occupancy_hist=a.occupancy_hist + b.occupancy_hist,
        score_q_hi=pick(qh_s[sel], a.score_q_hi, b.score_q_hi).astype(jnp.uint32),
        score_q_lo=pick(ql_s[sel], a.score_q_lo, b.score_q_lo).astype(jnp.uint32),
    )
