"""Proactive tier rebalancing — watermark-driven hot→cold demotion.

The tier hierarchy (`core/tiered.py`) demotes REACTIVELY: a full hot
bucket demotes its victim inside the serving-path upsert, so at steady
state every admission pays an eviction + a cold-tier upsert on the
latency-critical wave.  This module moves that work BETWEEN waves: when
the hot tier's occupancy rises past `high_watermark`, the coldest hot
entries (the ones reactive eviction would pick next anyway) are swept out
down to `low_watermark` — via `evict_if`'s coldest-first rank order —
and demoted through the EXISTING cascade (`TieredHKVTable.demote`, i.e.
the same `EvictionStream` transport and `translate_scores` crossing the
reactive path uses).  The next wave's admissions then land in empty
slots: no victim extraction, no rejection, no in-wave cold upsert.

The two-watermark hysteresis is deliberate: sweeping to `low` rather
than to `high` buys (high-low)*capacity admissions of headroom per
sweep, so the sweep cadence decouples from the admission rate.

Budgeted: at most `budget` moves per call (the scheduler's step budget —
maintenance must never stall the wave loop it runs between).  Everything
is jittable; the scheduler compiles one step function per table config.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ops as ops_mod
from repro.core.predicates import SweepPredicate
from repro.core.tiered import TieredHKVTable


class RebalanceResult(NamedTuple):
    table: TieredHKVTable
    moved: jax.Array     # int32 [] — entries demoted hot -> cold
    dropped: jax.Array   # int32 [] — pairs lost at the cold boundary


def rebalance(table: TieredHKVTable, *, low_watermark: float = 0.7,
              high_watermark: float = 0.9, budget: int = 256
              ) -> RebalanceResult:
    """One watermark sweep (see module docstring).

    No-op (moved == 0) while hot occupancy <= high_watermark * capacity;
    above it, demotes min(budget, occupancy - low_watermark * capacity)
    of the coldest hot entries.  The table successor is returned either
    way (jit-friendly: the sweep always executes, the dynamic `limit`
    masks it to zero moves below the trigger).
    """
    if not 0.0 <= low_watermark <= high_watermark <= 1.0:
        raise ValueError(
            f"watermarks must satisfy 0 <= low <= high <= 1; got "
            f"{low_watermark}/{high_watermark}")
    hot = table.hot
    cap = hot.capacity
    budget = min(budget, cap)
    occ = hot.size()
    need = jnp.clip(occ - jnp.int32(int(low_watermark * cap)), 0, budget)
    limit = jnp.where(occ > jnp.int32(int(high_watermark * cap)), need, 0)
    ev = ops_mod.evict_if(hot.state, hot.cfg, SweepPredicate.always(),
                          budget, limit=limit, backend=hot.backend)
    t2 = table.with_tiers(hot.with_state(ev.state), table.cold)
    dem = t2.demote(ev.evicted)
    return RebalanceResult(table=dem.table, moved=dem.demoted,
                           dropped=dem.dropped)
