"""Table maintenance subsystem (DESIGN.md §Maintenance).

Policy-driven eviction as a first-class BETWEEN-waves activity: the
predicated bulk sweeps (`erase_if` / `evict_if`, implemented in
`core/ops.py` against the declarative `SweepPredicate`), TTL/epoch
expiry, proactive tier rebalancing, whole-table observability
(`TableStats`), and the wave-interleaved `MaintenanceScheduler` the
serving engine drives them from.

    from repro.maintenance import (MaintenancePolicy, MaintenanceScheduler,
                                   SweepPredicate)
    sched = MaintenanceScheduler(MaintenancePolicy(
        every_waves=4, sweep_budget=512, ttl_epochs=3, advance_epoch=True))
    eng = OnlineEmbeddingEngine(pub, wave_size=1024, miss_policy="admit",
                                scheduler=sched)

`SweepPredicate` itself lives in `repro.core.predicates` (the sweep ops
in `core/ops.py` are defined against it); it is re-exported here as part
of the subsystem's public surface.
"""

from repro.core.predicates import SweepPredicate  # noqa: F401
from repro.maintenance.rebalance import RebalanceResult, rebalance  # noqa: F401
from repro.maintenance.scheduler import (  # noqa: F401
    MaintenancePolicy,
    MaintenanceReport,
    MaintenanceScheduler,
    MaintenanceTotals,
)
from repro.maintenance.stats import (  # noqa: F401
    TableStats,
    combine_stats,
    stats_from_planes,
)
