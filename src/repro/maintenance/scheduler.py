"""MaintenanceScheduler — wave-interleaved table maintenance.

The serving loop (`repro.serving.embedding_engine`) is wave-batched: one
device launch per wave, host control between launches.  Those gaps are
exactly where maintenance belongs — the paper's policy-driven eviction as
a first-class BETWEEN-waves activity instead of a tax inside every
serving upsert.  The scheduler is the driver: once every `every_waves`
waves it snapshots the current table from its `TableSource`, runs one
jit-compiled maintenance step under a fixed move budget, and offers the
successor handle back through the same compare-and-swap the engine's own
admissions use (`publisher.offer`) — so a concurrent trainer `publish`
beats maintenance exactly like it beats admissions, and a wave can never
observe a half-maintained table (the snapshot/offer consistency model of
DESIGN.md §Serving, unchanged).

One maintenance step, in order:

  1. epoch tick      (optional) advance the table epoch — the TTL clock;
                     one maintenance interval == one TTL window.
  2. TTL expiry      `erase_if(expire_before(epoch - ttl))` for tables on
                     an epoch_* score policy (both tiers when tiered —
                     the cold tier's translated scores keep the epoch
                     plane, see `translate_scores`).
  3. rebalance       watermark-driven hot→cold demotion on tiered tables
                     (`repro.maintenance.rebalance`), at most
                     `sweep_budget` moves.

The step compiles ONCE per scheduler (handles are pytrees with static
cfg aux); per-run cost is one device launch plus the host-side offer.
Counters accumulate on the scheduler (`.totals`) — the runtime half of
the observability story whose state half is `TableStats`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.api import table_signature
from repro.core.predicates import SweepPredicate
from repro.core.tiered import TieredHKVTable
from repro.maintenance.rebalance import rebalance as _rebalance
from repro.obs.trace import as_tracer


@dataclasses.dataclass(frozen=True)
class MaintenancePolicy:
    """Static knobs of one scheduler (everything the compiled step bakes in).

    every_waves     run cadence: one maintenance step per N waves.
    sweep_budget    max structural moves per step (evict_if lane count —
                    the step budget that bounds maintenance latency).
    ttl_epochs      expire entries untouched for this many epochs
                    (None = no expiry; requires an epoch_* score policy).
    advance_epoch   tick the table epoch at each step (one maintenance
                    interval == one TTL window).  Leave False when the
                    application owns the epoch clock (`set_epoch`).
    low/high_watermark   tiered rebalance hysteresis (repro.maintenance
                    .rebalance); `rebalance=False` disables the sweep.
    """

    every_waves: int = 1
    sweep_budget: int = 256
    ttl_epochs: Optional[int] = None
    advance_epoch: bool = False
    rebalance: bool = True
    low_watermark: float = 0.7
    high_watermark: float = 0.9

    def __post_init__(self):
        if self.every_waves < 1:
            raise ValueError("every_waves must be >= 1")
        if self.sweep_budget < 1:
            raise ValueError("sweep_budget must be >= 1")


class MaintenanceReport(NamedTuple):
    """One step's outcome (host-side ints/floats)."""

    expired: int        # entries removed by TTL expiry
    demoted: int        # entries proactively moved hot -> cold
    dropped: int        # pairs lost at the cold boundary during demotion
    elapsed_s: float    # host wall clock of the step (compile excluded
                        # only insofar as the first step pays it)
    table_version: int  # source version the step ran against
    applied: bool       # False when a concurrent publish beat the offer


class MaintenanceTotals(NamedTuple):
    runs: int
    expired: int
    demoted: int
    dropped: int
    skipped_offers: int  # steps whose successor lost the offer CAS
    time_s: float
    deferred: int = 0    # steps skipped because the between-wave slack
                         # budget was already spent on staging (the
                         # engine's host_budget_s contract — one budget
                         # for staging + maintenance)


class MaintenanceScheduler:
    """Drives maintenance steps between serving waves (see module doc).

        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=4, sweep_budget=512,
            ttl_epochs=3, advance_epoch=True))
        eng = OnlineEmbeddingEngine(publisher, wave_size=1024,
                                    miss_policy="admit", scheduler=sched)
        # ... eng.step() now runs sched.on_wave(source) after each wave
        print(sched.totals)

    Also usable directly (no engine): `table, report = sched.run(table)`.
    """

    def __init__(self, policy: MaintenancePolicy = MaintenancePolicy(),
                 *, tracer: Optional[Any] = None):
        self.policy = policy
        self.reports: list[MaintenanceReport] = []
        self._waves = 0
        self._step_fn = None
        self._step_sig = None     # table signature the step fn was built for
        self._cost_ewma = None    # smoothed per-step host cost (slack gating)
        self.deferred = 0         # steps skipped for lack of slack budget
        # span tracing: maintenance.run spans + maintenance.deferred
        # instants (repro.obs.trace; noop when unwired)
        self.tracer = as_tracer(tracer)

    # -- step construction -----------------------------------------------------

    def _supports_ttl(self, table: Any) -> bool:
        if self.policy.ttl_epochs is None:
            return False
        cfg = getattr(getattr(table, "hot", table), "cfg", None)
        if cfg is None or not hasattr(table, "set_epoch"):
            raise ValueError(
                "ttl_epochs requires a table with an epoch clock "
                f"(set_epoch + an epoch_* score policy); got "
                f"{type(table).__name__}")
        if not cfg.score_policy.startswith("epoch_"):
            raise ValueError(
                f"ttl_epochs requires an epoch_* score policy; table runs "
                f"{cfg.score_policy!r}")
        return True

    def _build(self, table: Any):
        pol = self.policy
        is_tiered = isinstance(table, TieredHKVTable)
        ttl_on = self._supports_ttl(table)
        rebalance_on = pol.rebalance and is_tiered
        can_sweep = hasattr(table, "erase_if")

        def step(t):
            zero = jnp.int32(0)
            expired, demoted, dropped = zero, zero, zero
            if pol.advance_epoch and hasattr(t, "set_epoch"):
                t = t.set_epoch(t.epoch + jnp.uint32(1))
            if ttl_on and can_sweep:
                ttl = jnp.uint32(pol.ttl_epochs)
                epoch = t.epoch
                thr = jnp.where(epoch >= ttl, epoch - ttl, jnp.uint32(0))
                r = t.erase_if(SweepPredicate.expire_before(thr))
                t, expired = r.table, r.swept
            if rebalance_on:
                rb = _rebalance(
                    t, low_watermark=pol.low_watermark,
                    high_watermark=pol.high_watermark,
                    budget=pol.sweep_budget)
                t, demoted, dropped = rb.table, rb.moved, rb.dropped
            return t, expired, demoted, dropped

        return jax.jit(step)

    # -- driving ---------------------------------------------------------------

    def run(self, table: Any, *, version: int = 0
            ) -> tuple[Any, MaintenanceReport]:
        """One maintenance step against a table the caller owns.  The
        compiled step is keyed on the table's static signature: a source
        that starts publishing a structurally different successor
        (flat→tiered retier, backend flip, dim change) gets a freshly
        built step instead of one with stale baked-in flags."""
        sig = table_signature(table)
        if self._step_fn is None or sig != self._step_sig:
            self._step_fn = self._build(table)
            self._step_sig = sig
        t0 = time.perf_counter()
        with self.tracer.span("maintenance.run", version=version):
            t2, expired, demoted, dropped = self._step_fn(table)
            expired, demoted, dropped = jax.block_until_ready(
                (expired, demoted, dropped))
        elapsed = time.perf_counter() - t0
        self._cost_ewma = (elapsed if self._cost_ewma is None
                           else 0.7 * self._cost_ewma + 0.3 * elapsed)
        rep = MaintenanceReport(
            expired=int(expired), demoted=int(demoted), dropped=int(dropped),
            elapsed_s=elapsed, table_version=version,
            applied=True)
        self.reports.append(rep)
        return t2, rep

    def on_wave(self, source: Any,
                slack_s: Optional[float] = None) -> Optional[MaintenanceReport]:
        """Wave-interleave hook: called by the engine after each wave.
        Runs a step every `every_waves` waves against the source's
        current snapshot and offers the successor back (CAS — a racing
        trainer publish wins, same as admission offers).

        `slack_s` is the remaining between-wave host budget after the
        engine's own staging work (pack/unpack) spent its share — one
        budget, competed for.  When the step's estimated cost (EWMA of
        past runs) exceeds the remaining slack, the step DEFERS to the
        next interval (`totals.deferred`); the first-ever step always
        runs so the estimate exists.  `slack_s=None` keeps the
        cadence-only contract."""
        self._waves += 1
        if self._waves % self.policy.every_waves:
            return None
        if (slack_s is not None and self._cost_ewma is not None
                and self._cost_ewma > slack_s):
            self.deferred += 1
            self.tracer.instant("maintenance.deferred", slack_s=slack_s,
                                cost_ewma_s=self._cost_ewma)
            return None
        version, table = source.snapshot()
        table2, rep = self.run(table, version=version)
        applied = bool(source.offer(version, table2))
        if not applied:
            rep = rep._replace(applied=False)
            self.reports[-1] = rep
        return rep

    # -- observability ---------------------------------------------------------

    @property
    def totals(self) -> MaintenanceTotals:
        return MaintenanceTotals(
            runs=len(self.reports),
            expired=sum(r.expired for r in self.reports),
            demoted=sum(r.demoted for r in self.reports),
            dropped=sum(r.dropped for r in self.reports),
            skipped_offers=sum(1 for r in self.reports if not r.applied),
            time_s=sum(r.elapsed_s for r in self.reports),
            deferred=self.deferred,
        )
