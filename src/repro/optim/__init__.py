from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    adamw8bit,
    adafactor,
    sgdm,
)
