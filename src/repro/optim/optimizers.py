"""Dense-parameter optimizers (backbone weights).

Minimal optax-style (init, update) pairs over pytrees, built here so the
framework has no external deps:

  adamw      — AdamW with bias correction and decoupled weight decay.
  adamw8bit  — AdamW with block-wise int8-quantized moments (the memory-
               side distributed-training trick: 4x moment memory saving;
               quantization error is re-absorbed each step because the
               quantizer is applied to the *updated* moment).
  adafactor  — factored second moment (row/col) for giant matrices.
  sgdm       — momentum SGD.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params) -> (updates, state)


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        return {
            "mu": _tree_zeros_like(params, jnp.float32),
            "nu": _tree_zeros_like(params, jnp.float32),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1**c
        bc2 = 1.0 - b2**c

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            step = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW with int8 block-quantized moments
# ---------------------------------------------------------------------------

_QBLOCK = 256


def _quantize_i8(x: jax.Array):
    """Block-wise absmax int8 quantization over the flattened tensor."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_i8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def adamw8bit(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    def init(params):
        def qz(p):
            q, s = _quantize_i8(jnp.zeros_like(p, jnp.float32))
            return {"q": q, "s": s}

        return {
            "mu": jax.tree.map(qz, params),
            "nu": jax.tree.map(qz, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1**c
        bc2 = 1.0 - b2**c

        def upd(g, mu_q, nu_q, p):
            g = g.astype(jnp.float32)
            mu = b1 * _dequantize_i8(mu_q["q"], mu_q["s"], p.shape) + (1 - b1) * g
            nu = b2 * _dequantize_i8(nu_q["q"], nu_q["s"], p.shape) + (1 - b2) * g * g
            step = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            mq, ms = _quantize_i8(mu)
            nq, ns = _quantize_i8(nu)
            return (-lr * step).astype(p.dtype), {"q": mq, "s": ms}, {"q": nq, "s": ns}

        isl = lambda x: isinstance(x, tuple)
        out = jax.tree.map(
            upd, grads, state["mu"], state["nu"], params,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x,
        )
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=isl)
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=isl)
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=isl)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no momentum)
# ---------------------------------------------------------------------------

def adafactor(lr=1e-3, decay=0.8, eps=1e-30) -> Optimizer:
    def init(params):
        def fz(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"v": jax.tree.map(fz, params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (
                    vr[..., :, None]
                    * vc[..., None, :]
                    / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps)
                )
                step = g / (jnp.sqrt(denom) + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                step = g / (jnp.sqrt(nv["v"]) + eps)
            return (-lr * step).astype(p.dtype), nv

        isl = lambda x: isinstance(x, tuple)
        out = jax.tree.map(
            upd, grads, state["v"], params,
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
        )
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=isl)
        v = jax.tree.map(lambda t: t[1], out, is_leaf=isl)
        return updates, {"v": v, "count": count}

    return Optimizer(init, update)


def sgdm(lr=1e-2, momentum=0.9) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (-lr * m).astype(p.dtype), m

        isl = lambda x: isinstance(x, tuple)
        out = jax.tree.map(upd, grads, state["m"], params)
        updates = jax.tree.map(lambda t: t[0], out, is_leaf=isl)
        m = jax.tree.map(lambda t: t[1], out, is_leaf=isl)
        return updates, {"m": m}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
