"""Train→serve publication: snapshot-consistent table hand-off.

The §3.5 triple-group taxonomy under REAL interleave: an online trainer
(updater + inserter roles) keeps mutating its working table while the
serving engine (reader role, `repro.serving.embedding_engine`) reads.
Handles are immutable pytrees, so publication is trivially atomic — a
single Python reference swap of a `(version, table)` tuple.  A reader
that snapshots once per wave can never observe a half-published table:
either it gets the pre-publish handle (whole) or the post-publish handle
(whole).  There is no state in between to observe.

Two publication paths:

  handle swap   same-process: `publish(table)` swaps the snapshot tuple.
                The engine's miss-path admissions flow back through
                `offer(version, table)` — a compare-and-swap that the
                trainer's own publication beats (admission effects on the
                read path are advisory; the trainer republishes promptly
                and re-admission costs one miss).  Under the engine's
                continuous admission the cadence is per DISPATCH: the
                snapshot is read when a wave launches and the successor
                is offered immediately — as a handle of still-computing
                async arrays, which is safe because handles are pytrees
                of device futures and the next wave's launch chains on
                them through ordinary data dependencies.
  delta export  cross-process: `export_delta(table)` drains the table
                through `export_batch` into a picklable numpy
                `TableDelta`; `ingest_delta(table, delta)` replays it via
                `ingest` (admission-controlled, scores carried as custom
                where the destination policy accepts them).  This is the
                multi-host publish seam — the transport (files, RPC) is
                the caller's.

`OnlineTrainer` is the reference updater: find_or_insert admission (the
step's single structural op) + a fused read-modify-write session
(`update_rows`, ONE shared locate) per gradient batch, publishing every
`publish_every` steps.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.u64 import U64
from repro.obs.trace import as_tracer


# =============================================================================
# Table sources — what the engine reads from
# =============================================================================


@runtime_checkable
class TableSource(Protocol):
    """Wave-granular table supply: `snapshot()` returns `(version, table)`
    atomically; `offer(version, table)` hands a read-path successor back
    (admission/promotion effects), applied only if `version` is still
    current."""

    def snapshot(self) -> tuple: ...

    def offer(self, version: int, table: Any) -> bool: ...


class StaticSource:
    """Engine-owned source (no trainer) with the SAME compare-and-swap
    offer contract as `TablePublisher`: an offer only applies when the
    offerer's snapshot version is still current, and the new version bumps
    from the CURRENT snapshot — never from the caller's argument.  Even
    without a trainer, two offer paths race here (the engine's wave
    admissions and the maintenance scheduler's between-wave steps), and a
    stale offer must lose rather than silently clobber a newer table or
    reuse a version number."""

    def __init__(self, table: Any):
        self._snap = (0, table)
        self.offered = 0             # offers accepted
        self.rejected_offers = 0     # offers beaten by a newer successor

    def snapshot(self) -> tuple:
        return self._snap

    def offer(self, version: int, table: Any) -> bool:
        if self._snap[0] != version:
            self.rejected_offers += 1
            return False
        self._snap = (self._snap[0] + 1, table)
        self.offered += 1
        return True

    @property
    def table(self) -> Any:
        return self._snap[1]


class TablePublisher:
    """The train→serve hand-off point.

    The trainer calls `publish(table)`; the engine calls `snapshot()` once
    per wave and `offer(...)` when its own policy mutated the table.  The
    snapshot tuple is swapped under a lock (offers need compare-and-swap);
    readers are lock-free — tuple read is atomic under the GIL and the
    tuple itself is immutable.
    """

    def __init__(self, table: Any, *, tracer: Optional[Any] = None):
        self._snap = (0, table)
        self._lock = threading.Lock()
        self.published = 0           # trainer publications
        self.offered = 0             # engine offers accepted
        self.rejected_offers = 0     # engine offers beaten by a publish
        # span tracing: publisher.publish / publisher.offer instants
        # (repro.obs.trace; noop when unwired)
        self.tracer = as_tracer(tracer)

    def snapshot(self) -> tuple:
        return self._snap

    @property
    def version(self) -> int:
        return self._snap[0]

    @property
    def table(self) -> Any:
        return self._snap[1]

    def publish(self, table: Any) -> int:
        """Unconditional swap (the trainer wins races); returns the new
        version."""
        with self._lock:
            v = self._snap[0] + 1
            self._snap = (v, table)
            self.published += 1
        self.tracer.instant("publisher.publish", version=v)
        return v

    def offer(self, version: int, table: Any) -> bool:
        """Compare-and-swap from the read path: applies only if the reader's
        snapshot is still current (a concurrent `publish` supersedes the
        offered admission effects — they are advisory; see module doc)."""
        with self._lock:
            if self._snap[0] != version:
                self.rejected_offers += 1
                accepted = False
            else:
                self._snap = (version + 1, table)
                self.offered += 1
                accepted = True
        self.tracer.instant("publisher.offer", version=version,
                            accepted=accepted)
        return accepted


# =============================================================================
# The delta path — export_batch → ingest, cross-process publishable
# =============================================================================


class TableDelta(NamedTuple):
    """Host-side (numpy, picklable) live-entry dump of a table."""

    keys: np.ndarray     # uint64 [n]
    values: np.ndarray   # float32 [n, total_value_dim]
    scores: np.ndarray   # uint64 [n]

    @property
    def count(self) -> int:
        return int(self.keys.shape[0])


def export_delta(table: Any, *, chunk_buckets: int = 64,
                 tracer: Optional[Any] = None) -> TableDelta:
    """Drain a table's live entries through `export_batch` in
    `chunk_buckets`-bucket chunks (any handle exposing
    `num_buckets`/`export_batch`: flat, tiered — whose concatenated bucket
    space dedupes inclusive copies — or the dict baselines)."""
    with as_tracer(tracer).span("delta.export"):
        return _export_delta(table, chunk_buckets=chunk_buckets)


def _export_delta(table: Any, *, chunk_buckets: int) -> TableDelta:
    ks, vs, ss = [], [], []
    nb = table.num_buckets
    for start in range(0, nb, chunk_buckets):
        exp = table.export_batch(start, min(chunk_buckets, nb - start))
        mask = np.asarray(exp.mask)
        if not mask.any():
            continue
        hi = np.asarray(exp.key_hi, np.uint64)[mask]
        lo = np.asarray(exp.key_lo, np.uint64)[mask]
        shi = np.asarray(exp.score_hi, np.uint64)[mask]
        slo = np.asarray(exp.score_lo, np.uint64)[mask]
        ks.append((hi << np.uint64(32)) | lo)
        ss.append((shi << np.uint64(32)) | slo)
        vs.append(np.asarray(exp.values)[mask])
    if not ks:
        width = getattr(table, "dim", 0)
        return TableDelta(keys=np.zeros(0, np.uint64),
                          values=np.zeros((0, width), np.float32),
                          scores=np.zeros(0, np.uint64))
    return TableDelta(keys=np.concatenate(ks),
                      values=np.concatenate(vs).astype(np.float32),
                      scores=np.concatenate(ss))


def ingest_delta(table: Any, delta: TableDelta, *, batch: int = 1024,
                 carry_scores: bool = False,
                 tracer: Optional[Any] = None,
                 telemetry: Optional[Any] = None) -> Any:
    """Replay a delta into any inserter-capable handle via `ingest`
    (admission-controlled: the destination's cache semantics decide what
    sticks — the cross-process analogue of the demotion cascade's
    boundary).  `carry_scores=True` forwards the exported scores as custom
    scores; only meaningful when the destination runs the 'custom' policy
    (other policies stamp their own, `translate_scores` semantics).
    `telemetry=` threads the device counter sink through every replayed
    `ingest` call (the op-telemetry seam, DESIGN.md §Observability)."""
    dim = delta.values.shape[1] if delta.values.ndim == 2 else 0
    with as_tracer(tracer).span("delta.ingest", count=delta.count):
        for start in range(0, delta.count, batch):
            kb = delta.keys[start:start + batch]
            vb = delta.values[start:start + batch]
            sb = delta.scores[start:start + batch]
            if len(kb) < batch:   # constant shapes: one jit entry per delta
                pad = batch - len(kb)
                kb = np.concatenate([kb, np.full(pad, _EMPTY_KEY, np.uint64)])
                vb = np.concatenate([vb, np.zeros((pad, dim), vb.dtype)])
                sb = np.concatenate([sb, np.zeros(pad, np.uint64)])
            kw = {}
            if carry_scores:
                kw["custom_scores"] = u64.from_uint64(sb)
            if telemetry is not None:
                kw["telemetry"] = telemetry
            res = table.ingest(u64.from_uint64(kb), jnp.asarray(vb), **kw)
            table = res.table
    return table


# =============================================================================
# OnlineTrainer — the reference updater/inserter loop
# =============================================================================


@dataclasses.dataclass
class OnlineTrainer:
    """Streaming trainer against a private successor chain, publishing
    whole handles.

    One `train_step(keys, grads)`:
      1. `find_or_insert` admits the step's keys (INSERTER — the single
         structural op; on a tiered table this also promotes cold hits);
      2. a session `update_rows` applies `update_fn(rows, grads)` over the
         same key batch (UPDATER — fused gather+write-back, one locate);
      3. every `publish_every` steps the successor handle is published.

    `update_fn(rows, grads) -> rows` sees full-width rows [n, dim+aux];
    the default is plain SGD on the embedding columns.

    `telemetry=` (a `repro.obs.telemetry.TelemetrySink`) accumulates the
    admission op's device counters across steps — the trainer-side half
    of the op-telemetry story (the update half runs through a session,
    which is out of the telemetry seam's scope).
    """

    publisher: TablePublisher
    publish_every: int = 1
    lr: float = 0.1
    update_fn: Optional[Callable] = None
    steps: int = 0
    telemetry: Optional[Any] = None

    def __post_init__(self):
        self._table = self.publisher.table

    @property
    def table(self) -> Any:
        return self._table

    def train_step(self, keys: Any, grads: jax.Array) -> Any:
        t = self._table
        dim = grads.shape[1]
        init = jnp.zeros((grads.shape[0], dim), jnp.float32)
        if self.telemetry is not None:
            res = t.find_or_insert(keys, init, telemetry=self.telemetry)
        else:
            res = t.find_or_insert(keys, init)
        t = res.table
        fn = self.update_fn or (
            lambda rows, g: rows.at[:, :dim].add(-self.lr * g))
        s = t.session()
        s.update_rows(keys, lambda rows: fn(rows, grads))
        t = s.commit()
        self._table = t
        self.steps += 1
        if self.steps % self.publish_every == 0:
            self.publish()
        return t

    def publish(self) -> int:
        """Swap the trainer's current successor in as the served table."""
        return self.publisher.publish(self._table)


_EMPTY_KEY = u64.EMPTY_KEY
